"""Guarded batched solvers for data-conditioned GP inference (§16).

``pcg`` is the batched preconditioned-CG engine (per-RHS masking,
quarantine isolation, fallback ladder, checkpoint/resume);
``gp_system`` builds the observation-space operator, the ICR-whitened
preconditioner and the dense fallback; ``reports`` defines the
structured ``SolveReport`` diagnostics surfaced by serving.
"""
from .pcg import (CGConfig, jacobi_precond, pcg_iterate, pcg_solve,
                  solve_guarded)
from .gp_system import (ConditionSystem, GridInterp, ObsSelect,
                        build_condition_system, condition_matvec,
                        icr_whitening_precond, obs_operator)
from .reports import (FallbackEvent, ResumeEvent, SolveReport,
                      STATUS_NAMES)

__all__ = [
    "CGConfig", "jacobi_precond", "pcg_iterate", "pcg_solve",
    "solve_guarded", "ConditionSystem", "GridInterp", "ObsSelect",
    "build_condition_system", "condition_matvec",
    "icr_whitening_precond", "obs_operator",
    "FallbackEvent", "ResumeEvent", "SolveReport", "STATUS_NAMES",
]
