"""Batched, guarded preconditioned conjugate gradients (DESIGN.md §16).

One batched solve runs every right-hand side of ``A x = b`` (RHS-leading
layout ``b: (k, n)``) through a single jitted ``lax.while_loop`` — the
matvec is the fused pyramid/megakernel hot path, so batching the RHS is
exactly the §10 sample-slab trick applied to inference. Robustness is
the design center:

  * **per-RHS masking** — every column carries its own status; converged
    columns freeze (``alpha = beta = 0``: their iterate is bit-identical
    from then on), and NaN/Inf or diverging columns are *quarantined* —
    their iterate is explicitly zeroed the moment the status flips, so a
    poisoned column can never re-enter the batched matvec and perturb
    its slab-mates (the PR 8 ``_admit`` isolation contract, enforced at
    the solver level);
  * **monitors** — residual tolerance (rtol·‖b‖ ∨ atol), divergence
    (‖r‖ > divergence_factor·‖b‖), stagnation (no relative improvement
    for ``stall_window`` iterations) and curvature/breakdown guards
    (pᵀAp ≤ 0, rᵀz ≤ 0) instead of the classic ``+ 1e-30`` silent-garbage
    denominators;
  * **fallback ladder** (:func:`solve_guarded`) — failed columns are
    re-solved down a rung sequence (ICR-whitened preconditioner →
    Jacobi/unpreconditioned → dense direct solve for small systems),
    each transition recorded as a :class:`~.reports.FallbackEvent`;
  * **preemption-safe state** (:func:`pcg_solve`) — the CG carry
    checkpoints through ``checkpoint.CheckpointManager`` every
    ``checkpoint_every`` iterations; a ``DeviceLossError`` raised by the
    fault hook or the runtime triggers the caller's re-plan callback
    (``elastic.shrink_mesh`` in serving), restores the latest
    checkpoint, re-pads the carry to the surviving mesh's capacity and
    continues — zero dropped RHS.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed.fault import DeviceLossError
from .reports import (ACTIVE, BREAKDOWN, CONVERGED, DENSE, DIVERGED,
                      MAXITER, NONFINITE, QUARANTINED, RETRYABLE, STALLED,
                      STATUS_NAMES, FallbackEvent, ResumeEvent, SolveReport)

Array = jnp.ndarray
_TINY = 1e-30  # rel-residual denominators only — never inside an update


@dataclasses.dataclass(frozen=True)
class CGConfig:
    """Solver policy knobs (hashable — closed over by jitted segments)."""

    rtol: float = 1e-6
    atol: float = 0.0
    max_iters: int = 1000
    divergence_factor: float = 1e4   # ‖r‖ > factor·‖b‖ ⇒ quarantine
    stall_window: int = 30           # iters without improvement ⇒ stalled
    stall_drop: float = 1e-3         # "improvement" = best shrinks by this
    checkpoint_every: int = 0        # iters between carry checkpoints (0: off)
    dense_max: int = 4096            # largest n the dense rung will factor


# -- the jittable core ----------------------------------------------------------
def _pcg_init(matvec, b: Array, precond, cfg: CGConfig,
              x0: Optional[Array] = None) -> dict:
    """Build the CG carry. Non-finite RHS columns are quarantined here
    (status NONFINITE, everything zeroed) so not even the first matvec
    sees them; trivially-zero columns converge at iteration 0."""
    b = jnp.asarray(b)
    finite = jnp.all(jnp.isfinite(b), axis=1)
    b0 = jnp.where(finite[:, None], b, 0.0)
    if x0 is None:
        x = jnp.zeros_like(b0)
        r = b0
    else:
        x = jnp.where(finite[:, None], jnp.asarray(x0, b0.dtype), 0.0)
        r = b0 - matvec(x)
    bnorm = jnp.sqrt(jnp.sum(b0 * b0, axis=1))
    tol = jnp.maximum(cfg.rtol * bnorm, cfg.atol)
    rnorm = jnp.sqrt(jnp.sum(r * r, axis=1))
    status = jnp.where(~finite, NONFINITE,
                       jnp.where(rnorm <= tol, CONVERGED, ACTIVE))
    status = status.astype(jnp.int32)
    z = precond(r) if precond is not None else r
    rz = jnp.sum(r * z, axis=1)
    active = status == ACTIVE
    # a preconditioner that returns NaN or a non-SPD direction is caught
    # before the first step, not after it has poisoned the iterate
    status = jnp.where(active & ~jnp.isfinite(rz), NONFINITE, status)
    status = jnp.where((status == ACTIVE) & (rz <= 0), BREAKDOWN, status)
    quar = (status == NONFINITE)[:, None]
    x = jnp.where(quar, 0.0, x)
    r = jnp.where(quar, 0.0, r)
    p = jnp.where((status == ACTIVE)[:, None], z, 0.0)
    k = b.shape[0]
    return {
        "x": x, "r": r, "p": p, "rz": rz,
        "bnorm": bnorm, "tol": tol, "rnorm": rnorm,
        "best": rnorm, "since": jnp.zeros(k, jnp.int32),
        "status": status, "iters": jnp.zeros(k, jnp.int32),
        "it": jnp.asarray(0, jnp.int32),
        "limit": jnp.asarray(cfg.max_iters, jnp.int32),
    }


def _pcg_cond(c: dict):
    return (c["it"] < c["limit"]) & jnp.any(c["status"] == ACTIVE)


def _pcg_body(matvec, precond, cfg: CGConfig) -> Callable[[dict], dict]:
    """One masked PCG iteration over the whole RHS batch.

    Frozen columns take exact zero steps (``alpha = beta = 0`` with
    finite directions), so their iterate is bit-identical to a run where
    they were solved alone — the isolation contract the solver tests pin.
    """

    def body(c: dict) -> dict:
        active = c["status"] == ACTIVE
        ap = matvec(c["p"])
        pap = jnp.sum(c["p"] * ap, axis=1)
        curv_ok = (pap > 0) & jnp.isfinite(pap)
        breakdown = active & ~curv_ok
        step = active & curv_ok
        alpha = jnp.where(step,
                          c["rz"] / jnp.where(pap == 0, 1.0, pap), 0.0)
        x = c["x"] + alpha[:, None] * c["p"]
        r = c["r"] - alpha[:, None] * ap
        rnorm = jnp.sqrt(jnp.sum(r * r, axis=1))
        z = precond(r) if precond is not None else r
        rz_new = jnp.sum(r * z, axis=1)

        nonfin = step & (~jnp.isfinite(rnorm) | ~jnp.isfinite(rz_new))
        conv = step & ~nonfin & (rnorm <= c["tol"])
        div = step & ~nonfin & ~conv & \
            (rnorm > cfg.divergence_factor * jnp.maximum(c["bnorm"], _TINY))
        improved = rnorm < c["best"] * (1.0 - cfg.stall_drop)
        best = jnp.where(step & ~nonfin & improved, rnorm, c["best"])
        since = jnp.where(step,
                          jnp.where(improved & ~nonfin, 0, c["since"] + 1),
                          c["since"])
        stall = step & ~nonfin & ~conv & ~div & \
            (since >= cfg.stall_window)
        pz_bad = step & ~nonfin & ~conv & ~div & ~stall & (rz_new <= 0)

        status = c["status"]
        for mask, code in ((breakdown, BREAKDOWN), (nonfin, NONFINITE),
                           (conv, CONVERGED), (div, DIVERGED),
                           (stall, STALLED), (pz_bad, BREAKDOWN)):
            status = jnp.where(mask & (status == ACTIVE), code, status)

        still = status == ACTIVE
        beta = jnp.where(still,
                         rz_new / jnp.where(c["rz"] == 0, 1.0, c["rz"]), 0.0)
        p = jnp.where(still[:, None], z + beta[:, None] * c["p"], c["p"])
        # quarantine: a poisoned or runaway column is zeroed *now* —
        # 0·NaN = NaN, so masking alone would let it leak back through the
        # batched matvec on the next iteration
        quar = (nonfin | div)[:, None]
        x = jnp.where(quar, 0.0, x)
        r = jnp.where(quar, 0.0, r)
        p = jnp.where(quar, 0.0, p)
        return {
            "x": x, "r": r, "p": p,
            "rz": jnp.where(still, rz_new, c["rz"]),
            "bnorm": c["bnorm"], "tol": c["tol"],
            "rnorm": jnp.where(step, rnorm, c["rnorm"]),
            "best": best, "since": since,
            "status": status,
            "iters": jnp.where(active, c["iters"] + 1, c["iters"]),
            "it": c["it"] + 1, "limit": c["limit"],
        }

    return body


def _finalize(c: dict) -> dict:
    c = dict(c)
    c["status"] = jnp.where(c["status"] == ACTIVE, MAXITER, c["status"])
    return c


def _stats(c: dict) -> dict:
    status = c["status"]
    relres = c["rnorm"] / jnp.maximum(c["bnorm"], _TINY)
    quarantined = (status == NONFINITE) | (status == DIVERGED)
    relres = jnp.where(quarantined, jnp.inf, relres)
    return {"status": status, "iters": c["iters"], "relres": relres,
            "it": c["it"]}


def pcg_iterate(matvec: Callable[[Array], Array], b: Array, *,
                precond: Optional[Callable] = None,
                cfg: CGConfig = CGConfig(),
                x0: Optional[Array] = None,
                carry: Optional[dict] = None,
                finalize: bool = True) -> Tuple[Array, dict, dict]:
    """The pure, jit-traceable solve: init (unless ``carry`` resumes one)
    + one bounded ``while_loop``. Returns ``(x, stats, carry)`` where
    ``stats`` holds per-RHS ``status``/``iters``/``relres`` arrays.

    This is what ``KissGP.solve`` and other in-graph callers use; the
    checkpoint/fallback drivers below wrap it with host-side control.
    """
    if carry is None:
        carry = _pcg_init(matvec, b, precond, cfg, x0=x0)
    carry = jax.lax.while_loop(_pcg_cond, _pcg_body(matvec, precond, cfg),
                               carry)
    if finalize:
        carry = _finalize(carry)
    return carry["x"], _stats(carry), carry


# -- carry plumbing (checkpoint/re-pad) ------------------------------------------
_SCALAR_KEYS = ("it", "limit")


def _repad_carry(carry: dict, k_new: int, cfg: CGConfig) -> dict:
    """Resize the RHS axis to ``k_new`` (elastic re-mesh changed the
    sharding capacity). Added columns are zero-RHS padding: status
    CONVERGED, everything zero — they take no steps and cost nothing but
    their share of the batched matvec."""
    k = int(np.shape(carry["status"])[0])
    if k_new == k:
        return carry
    out = {}
    for key, val in carry.items():
        if key in _SCALAR_KEYS:
            out[key] = val
            continue
        arr = jnp.asarray(val)
        if k_new < k:
            out[key] = arr[:k_new]
            continue
        pad_shape = (k_new - k,) + arr.shape[1:]
        if key == "status":
            pad = jnp.full(pad_shape, CONVERGED, arr.dtype)
        else:
            pad = jnp.zeros(pad_shape, arr.dtype)
        out[key] = jnp.concatenate([arr, pad], axis=0)
    return out


def pcg_solve(matvec, b: Array, *,
              precond: Optional[Callable] = None,
              cfg: CGConfig = CGConfig(),
              x0: Optional[Array] = None,
              manager=None,
              checkpoint_every: Optional[int] = None,
              fault_hook: Optional[Callable[[int], None]] = None,
              on_device_loss: Optional[Callable] = None,
              executor: Optional[Callable] = None) -> tuple:
    """Host driver: segmented :func:`pcg_iterate` with checkpoint/resume.

    The solve runs in segments of ``checkpoint_every`` iterations (one
    jitted ``while_loop`` each); between segments the carry is saved
    through ``manager`` (a ``checkpoint.CheckpointManager``). A
    ``DeviceLossError`` — raised by ``fault_hook`` (chaos injection) or
    the runtime — invokes ``on_device_loss(exc)``, which re-plans and
    returns ``(matvec, precond, k_pad)`` for the surviving mesh
    (``k_pad=None`` keeps the width); the carry is restored from the
    latest checkpoint (or the initial state), re-padded, and the solve
    continues. ``executor`` wraps each segment attempt (the serving
    layer passes ``ServingFaultSupervisor.execute`` for transient-retry
    + straggler accounting).

    Returns ``(x, stats, resumes, n_checkpoints)``.
    """
    executor = executor or (lambda fn: fn())
    seg = cfg.checkpoint_every if checkpoint_every is None \
        else checkpoint_every

    def make_seg_fn(mv, pc):
        def run(carry):
            carry = jax.lax.while_loop(_pcg_cond, _pcg_body(mv, pc, cfg),
                                       carry)
            return carry
        return jax.jit(run)

    seg_fn = make_seg_fn(matvec, precond)
    carry = _pcg_init(matvec, b, precond, cfg, x0=x0)
    k_cur = int(b.shape[0])
    resumes: list = []
    n_ckpt = 0
    # host template mirrors the latest durable state: the restore target
    # after a loss, and the restart point when no checkpoint exists yet
    host = jax.tree.map(np.asarray, carry)
    if manager is not None and seg:
        manager.save(0, carry, blocking=True)
        n_ckpt += 1
    while True:
        it = int(np.asarray(carry["it"]))
        still = np.any(np.asarray(carry["status"]) == ACTIVE)
        if not (still and it < cfg.max_iters):
            break
        limit = cfg.max_iters if not seg else min(it + seg, cfg.max_iters)
        carry = dict(carry)
        carry["limit"] = jnp.asarray(limit, jnp.int32)

        def attempt(carry=carry, it=it):
            if fault_hook is not None:
                fault_hook(it)
            out = seg_fn(carry)
            jax.block_until_ready(out)
            return out

        try:
            carry = executor(attempt)
        except DeviceLossError as exc:
            if on_device_loss is None:
                raise
            new_mv, new_pc, k_pad = on_device_loss(exc)
            matvec = new_mv if new_mv is not None else matvec
            precond = new_pc
            if manager is not None and manager.latest_step() is not None:
                step, carry = manager.restore(like=host)
            else:
                step, carry = 0, jax.tree.map(jnp.asarray, host)
            resumes.append(ResumeEvent(
                at_iter=it, restored_step=int(step),
                reason=f"device-loss {sorted(exc.device_ids)}"))
            if k_pad is not None:
                k_cur = int(k_pad)
            carry = _repad_carry(carry, k_cur, cfg)
            seg_fn = make_seg_fn(matvec, precond)
            continue
        if manager is not None and seg:
            manager.save(int(np.asarray(carry["it"])), carry,
                         blocking=True)
            n_ckpt += 1
            host = jax.tree.map(np.asarray, carry)
    carry = _finalize(carry)
    return carry["x"], _stats(carry), resumes, n_ckpt


# -- the fallback ladder ---------------------------------------------------------
def jacobi_precond(diag: Array) -> Callable[[Array], Array]:
    """Diagonal (Jacobi) preconditioner ``z = r / diag`` — the middle
    rung when a structured preconditioner misbehaves but scaling still
    helps. ``diag`` must be strictly positive."""
    inv = 1.0 / jnp.asarray(diag)

    def precond(r: Array) -> Array:
        return r * inv[None, :]

    return precond


def solve_guarded(matvec, b: Array, *,
                  preconds: Sequence[tuple] = (("none", None),),
                  cfg: CGConfig = CGConfig(),
                  dense_solve: Optional[Callable] = None,
                  manager=None,
                  checkpoint_every: Optional[int] = None,
                  fault_hook: Optional[Callable] = None,
                  on_device_loss: Optional[Callable] = None,
                  executor: Optional[Callable] = None,
                  n_report: Optional[int] = None,
                  tag: str = "pcg") -> Tuple[np.ndarray, SolveReport]:
    """Run the fallback ladder over a batched solve; returns
    ``(x, SolveReport)``.

    ``preconds`` is the rung sequence, ``(name, precond_fn_or_None)``
    best-first (e.g. ICR-whitened → Jacobi → unpreconditioned). Columns
    that end a rung with a retryable status (diverged, breakdown,
    stalled, maxiter) are re-solved on the next rung; *non-retried*
    columns ride along as zero-RHS padding (shapes — and therefore the
    compiled segment and any RHS sharding — never change between rungs),
    and their already-good results are kept. Columns still failing after
    the last rung go to ``dense_solve`` when the system is small enough
    (``cfg.dense_max``). Every transition emits a
    :class:`~.reports.FallbackEvent`; ``n_report`` trims the report to
    the first n columns (the serving layer's real, unpadded RHS count).

    ``on_device_loss(exc)`` may return its new preconditioner as a
    **dict** ``{rung_name: precond}`` — the ladder is updated in place so
    a loss on one rung re-plans every later rung too, and the returned
    ``k_pad`` (which must stay >= the original width — pad *up* to the
    new mesh's multiple) widens all subsequent rungs and the dense
    residual check.
    """
    t0 = time.perf_counter()
    b = jnp.asarray(b)
    k, n = b.shape
    finite = np.asarray(jnp.all(jnp.isfinite(b), axis=1))
    x_full = np.zeros(b.shape, np.dtype(str(b.dtype)))
    status_full = np.full(k, NONFINITE, np.int32)
    status_full[finite] = ACTIVE
    iters_full = np.zeros(k, np.int64)
    relres_full = np.full(k, np.inf)
    relres_full[finite] = 0.0

    rung_names = [name for name, _ in preconds]
    remaining = np.where(finite)[0]
    fallbacks: list = []
    resumes: list = []
    n_ckpt = 0
    total_it = 0
    rungs_tried: list = []

    # live operator state: a device loss mid-rung re-plans the matvec,
    # the preconditioners and the padded width, and *later* rungs (and
    # the dense residual check) must see the re-planned versions — never
    # the stale pre-loss operators
    cur = {"mv": matvec, "pcs": dict(preconds), "k": k}

    def _wrap_odl(rung):
        if on_device_loss is None:
            return None

        def odl(exc):
            new_mv, new_pc, k_pad = on_device_loss(exc)
            if new_mv is not None:
                cur["mv"] = new_mv
            if isinstance(new_pc, dict):
                cur["pcs"].update(new_pc)
                new_pc = cur["pcs"].get(rung)
            else:
                cur["pcs"][rung] = new_pc
            if k_pad is not None:
                cur["k"] = int(k_pad)
            return cur["mv"], new_pc, cur["k"]

        return odl

    def _pad_rows(arr):
        if cur["k"] == arr.shape[0]:
            return arr
        pad = jnp.zeros((cur["k"] - arr.shape[0],) + arr.shape[1:],
                        arr.dtype)
        return jnp.concatenate([arr, pad], axis=0)

    for ri, (name, _) in enumerate(list(preconds)):
        if remaining.size == 0:
            break
        rungs_tried.append(name)
        mask = np.zeros(k, bool)
        mask[remaining] = True
        b_r = _pad_rows(jnp.where(jnp.asarray(mask)[:, None], b, 0.0))
        # a fresh checkpoint namespace per rung: a later rung's restore
        # must never resurrect an earlier rung's (stale) carry
        mgr = manager if manager is None else type(manager)(
            os.path.join(manager.root, f"rung{ri}-{name}"),
            keep=manager.keep)
        x_r, stats, res, ck = pcg_solve(
            cur["mv"], b_r, precond=cur["pcs"].get(name), cfg=cfg,
            manager=mgr, checkpoint_every=checkpoint_every,
            fault_hook=fault_hook, on_device_loss=_wrap_odl(name),
            executor=executor)
        resumes.extend(res)
        n_ckpt += ck
        st = np.asarray(stats["status"])[:k]
        it = np.asarray(stats["iters"])[:k]
        rr = np.asarray(stats["relres"])[:k]
        x_np = np.asarray(x_r)[:k]
        x_full[mask] = x_np[mask]
        status_full[mask] = st[mask]
        iters_full[mask] += it[mask]
        relres_full[mask] = rr[mask]
        total_it += int(np.asarray(stats["it"]))
        retry = np.array([i for i in remaining if st[i] in RETRYABLE],
                         np.int64)
        if retry.size and ri + 1 < len(preconds):
            reasons: dict = {}
            for i in retry:
                nm = STATUS_NAMES[int(st[i])]
                reasons[nm] = reasons.get(nm, 0) + 1
            fallbacks.append(FallbackEvent(
                rung_from=name, rung_to=rung_names[ri + 1],
                at_iter=total_it, cols=tuple(int(i) for i in retry),
                reasons=tuple(sorted(reasons.items()))))
        remaining = retry

    if remaining.size and dense_solve is not None and n <= cfg.dense_max:
        rungs_tried.append("dense")
        reasons = {}
        for i in remaining:
            nm = STATUS_NAMES[int(status_full[i])]
            reasons[nm] = reasons.get(nm, 0) + 1
        fallbacks.append(FallbackEvent(
            rung_from=rungs_tried[-2] if len(rungs_tried) > 1 else "none",
            rung_to="dense", at_iter=total_it,
            cols=tuple(int(i) for i in remaining),
            reasons=tuple(sorted(reasons.items()))))
        mask = np.zeros(k, bool)
        mask[remaining] = True
        b_d = jnp.where(jnp.asarray(mask)[:, None], b, 0.0)
        x_d = np.asarray(dense_solve(b_d))[:k]
        r_d = np.asarray(_pad_rows(b_d)
                         - cur["mv"](_pad_rows(jnp.asarray(x_d))))[:k]
        rr_d = (np.linalg.norm(r_d, axis=1)
                / np.maximum(np.linalg.norm(np.asarray(b_d)[:k], axis=1),
                             _TINY))
        good = mask & np.isfinite(x_d).all(axis=1)
        x_full[good] = x_d[good]
        status_full[good] = DENSE
        relres_full[good] = rr_d[good]
        bad = mask & ~good
        status_full[bad] = NONFINITE
        x_full[bad] = 0.0

    m = k if n_report is None else int(n_report)
    quarantined = tuple(int(i) for i in range(m)
                        if status_full[i] in QUARANTINED)
    report = SolveReport(
        tag=tag, n_rhs=m, n_unknowns=n,
        rungs=tuple(rungs_tried),
        status=tuple(STATUS_NAMES[int(s)] for s in status_full[:m]),
        iterations=tuple(int(i) for i in iters_full[:m]),
        relres=tuple(float(r) for r in relres_full[:m]),
        quarantined=quarantined,
        fallbacks=tuple(fallbacks),
        resumes=tuple(resumes),
        checkpoints=n_ckpt,
        wall_s=time.perf_counter() - t0,
    )
    return x_full, report
