"""Structured diagnostics for the batched PCG subsystem (DESIGN.md §16).

Iterative solvers fail in ways that matter operationally — stagnation,
breakdown, NaN poisoning, preemption mid-solve — and a serving stack
must be able to *see* those events, not infer them from wrong numbers.
Every guarded solve therefore returns a :class:`SolveReport`: per-RHS
terminal status, iteration counts and residuals, the fallback rungs
taken (:class:`FallbackEvent`), checkpoint/resume history
(:class:`ResumeEvent`) and the quarantined column indices. The report is
plain data (JSON-able via :meth:`SolveReport.summary`) so it can ride in
``GPFieldServer.metrics()`` and the chaos harness unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# per-RHS terminal status codes (int32 inside the jitted carry)
ACTIVE = 0      # still iterating (never terminal after finalize)
CONVERGED = 1   # residual under max(rtol*||b||, atol)
NONFINITE = 2   # NaN/Inf in the RHS or the iterate — quarantined (zeroed)
DIVERGED = 3    # residual grew past divergence_factor*||b|| — quarantined
BREAKDOWN = 4   # non-positive curvature pᵀAp ≤ 0 or rᵀz ≤ 0 (frozen)
STALLED = 5     # no residual improvement for stall_window iterations
MAXITER = 6     # iteration budget exhausted while still active
DENSE = 7       # solved by the dense (exact) fallback rung

STATUS_NAMES = {
    ACTIVE: "active", CONVERGED: "converged", NONFINITE: "nonfinite",
    DIVERGED: "diverged", BREAKDOWN: "breakdown", STALLED: "stalled",
    MAXITER: "maxiter", DENSE: "dense",
}

# statuses that poison a column: its iterate is zeroed the moment the
# status is assigned so it can never re-enter the batched matvec
QUARANTINED = (NONFINITE, DIVERGED)
# statuses worth re-solving on the next fallback rung
RETRYABLE = (DIVERGED, BREAKDOWN, STALLED, MAXITER)
# statuses that count as a good solution
OK = (CONVERGED, DENSE)


@dataclasses.dataclass(frozen=True)
class FallbackEvent:
    """One transition down the fallback ladder.

    ``cols`` are the (original-batch) RHS indices handed to ``rung_to``;
    ``reasons`` histograms why (status name -> count) at the moment the
    rung ``rung_from`` gave up on them.
    """

    rung_from: str
    rung_to: str
    at_iter: int
    cols: Tuple[int, ...]
    reasons: Tuple[Tuple[str, int], ...]

    def summary(self) -> dict:
        return {
            "from": self.rung_from, "to": self.rung_to,
            "at_iter": self.at_iter, "cols": list(self.cols),
            "reasons": dict(self.reasons),
        }


@dataclasses.dataclass(frozen=True)
class ResumeEvent:
    """One checkpointed resume (preemption / device loss mid-solve)."""

    at_iter: int        # global iteration when the solve was interrupted
    restored_step: int  # checkpoint step the carry was restored from
    reason: str         # e.g. "device-loss [3]"

    def summary(self) -> dict:
        return {"at_iter": self.at_iter,
                "restored_step": self.restored_step,
                "reason": self.reason}


@dataclasses.dataclass(frozen=True)
class SolveReport:
    """Terminal diagnostics of one guarded batched solve.

    ``status``/``iterations``/``relres`` are per-RHS (original batch
    order); ``rungs`` lists every ladder rung attempted in order;
    ``quarantined`` are the column indices whose iterates were zeroed
    (NaN/divergence isolation); ``fallbacks``/``resumes`` are the event
    streams. ``ok`` is True iff every column ended converged or dense.
    """

    tag: str
    n_rhs: int
    n_unknowns: int
    rungs: Tuple[str, ...]
    status: Tuple[str, ...]
    iterations: Tuple[int, ...]
    relres: Tuple[float, ...]
    quarantined: Tuple[int, ...]
    fallbacks: Tuple[FallbackEvent, ...] = ()
    resumes: Tuple[ResumeEvent, ...] = ()
    checkpoints: int = 0
    wall_s: float = 0.0

    @property
    def ok(self) -> bool:
        return all(s in ("converged", "dense") for s in self.status)

    @property
    def max_iterations(self) -> int:
        return max(self.iterations) if self.iterations else 0

    def summary(self) -> dict:
        """JSON-able digest — what ``GPFieldServer.metrics()`` surfaces."""
        hist: dict = {}
        for s in self.status:
            hist[s] = hist.get(s, 0) + 1
        return {
            "tag": self.tag,
            "n_rhs": self.n_rhs,
            "n_unknowns": self.n_unknowns,
            "ok": self.ok,
            "rungs": list(self.rungs),
            "status": hist,
            "iterations": self.max_iterations,
            "final_relres": max(self.relres) if self.relres else 0.0,
            "quarantined": list(self.quarantined),
            "fallbacks": [f.summary() for f in self.fallbacks],
            "resumes": [r.summary() for r in self.resumes],
            "checkpoints": self.checkpoints,
            "wall_s": self.wall_s,
        }
