"""The data-conditioning linear system for ICR GPs (DESIGN.md §16).

Exact GP regression conditions the ICR prior on noisy observations
``y = W s + ε``, ``ε ~ N(0, σ²I)``: with ``K = S Sᵀ`` (``S`` the ICR
square root, applied matrix-free) the posterior mean is

    m = K Wᵀ α,   (W K Wᵀ + σ² I) α = y

so one matvec of the observation-space operator ``A = W K Wᵀ + σ²I``
is *two* applications of the square root (``Sᵀ`` then ``S`` — the
paper's §1 cost unit) bracketed by the sparse interpolation ``W``. This
module builds everything the guarded batched CG needs to solve with A:

  * observation operators — :class:`ObsSelect` for on-grid index
    observations and :class:`GridInterp` for off-grid points via the
    KISS-GP sparse linear interpolation (arXiv 2101.11751 cost model;
    ``core/kissgp.py`` is the 1-D reference implementation);
  * the batched matvec, optionally sharded over the RHS axis through
    ``shard_map`` on a device mesh (the serving path);
  * the **ICR-whitened preconditioner**: the coarse-level prefix of ξ
    spans the top of the kernel spectrum, so ``M = σ²I + U Uᵀ`` with
    ``U = W S_c`` (one batched sqrt application over coarse basis
    excitations) captures the dominant eigenspace; ``M⁻¹`` applies by a
    small Cholesky-factored Woodbury correction;
  * the dense fallback (materialize A column-block by batched matvec,
    ``jnp.linalg.solve``) for small charts — the ladder's last rung.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jnp.ndarray


# -- observation operators -------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ObsSelect:
    """On-grid observations: W selects ``idx`` out of the flattened field."""

    idx: tuple            # flat finest-grid indices (hashable for caching)
    n_grid: int

    @property
    def n_obs(self) -> int:
        return len(self.idx)

    def apply(self, f: Array) -> Array:
        """(k, N) field rows -> (k, O) observed rows."""
        return f[:, jnp.asarray(self.idx)]

    def apply_t(self, v: Array) -> Array:
        """(k, O) -> (k, N) scatter-add (Wᵀ)."""
        out = jnp.zeros((v.shape[0], self.n_grid), v.dtype)
        return out.at[:, jnp.asarray(self.idx)].add(v)

    def fingerprint(self) -> tuple:
        return ("select", self.n_grid, self.idx)


@dataclasses.dataclass(frozen=True)
class GridInterp:
    """Off-grid 1-D observations: sparse linear interpolation rows of W
    (two nonzeros per observation — the KISS-GP stencil, applied in
    O(n_obs) like ``KissGP.apply_w``/``apply_wt``)."""

    idx: tuple            # left grid neighbor per observation
    w_lo: tuple
    w_hi: tuple
    n_grid: int

    @classmethod
    def from_points(cls, grid_x: np.ndarray, x_obs: np.ndarray):
        """Build W from sorted uniform grid coordinates and observation
        locations (clipped to the grid span, as ``KissGP.interp_weights``
        does)."""
        grid_x = np.asarray(grid_x, np.float64)
        x_obs = np.asarray(x_obs, np.float64)
        h = float(grid_x[1] - grid_x[0])
        p = (x_obs - float(grid_x[0])) / h
        idx = np.clip(np.floor(p).astype(np.int64), 0, len(grid_x) - 2)
        frac = np.clip(p - idx, 0.0, 1.0)
        return cls(idx=tuple(int(i) for i in idx),
                   w_lo=tuple(float(w) for w in 1.0 - frac),
                   w_hi=tuple(float(w) for w in frac),
                   n_grid=len(grid_x))

    @property
    def n_obs(self) -> int:
        return len(self.idx)

    def apply(self, f: Array) -> Array:
        idx = jnp.asarray(self.idx)
        wl = jnp.asarray(self.w_lo, f.dtype)
        wr = jnp.asarray(self.w_hi, f.dtype)
        return wl[None, :] * f[:, idx] + wr[None, :] * f[:, idx + 1]

    def apply_t(self, v: Array) -> Array:
        idx = jnp.asarray(self.idx)
        wl = jnp.asarray(self.w_lo, v.dtype)
        wr = jnp.asarray(self.w_hi, v.dtype)
        out = jnp.zeros((v.shape[0], self.n_grid), v.dtype)
        out = out.at[:, idx].add(wl[None, :] * v)
        return out.at[:, idx + 1].add(wr[None, :] * v)

    def fingerprint(self) -> tuple:
        return ("interp", self.n_grid, self.idx, self.w_lo, self.w_hi)


def obs_operator(icr, *, obs_idx=None, x_obs=None):
    """Build the observation operator for a chart: flat finest-grid
    indices (any dimension) or off-grid 1-D locations, exactly one."""
    n = int(np.prod(icr.chart.final_shape))
    if (obs_idx is None) == (x_obs is None):
        raise ValueError("pass exactly one of obs_idx (on-grid) or "
                         "x_obs (off-grid 1-D)")
    if obs_idx is not None:
        idx = np.asarray(obs_idx, np.int64).ravel()
        if idx.size == 0 or idx.min() < 0 or idx.max() >= n:
            raise ValueError(f"obs_idx out of range for a {n}-pixel chart")
        return ObsSelect(idx=tuple(int(i) for i in idx), n_grid=n)
    if icr.chart.ndim != 1:
        raise ValueError("off-grid x_obs interpolation is 1-D only; "
                         "use on-grid obs_idx for N-D charts")
    grid_x = icr.chart.axis_coords(icr.chart.n_levels, 0)
    return GridInterp.from_points(grid_x, x_obs)


# -- the observation-space operator A = W K Wᵀ + σ²I ----------------------------
@dataclasses.dataclass
class ConditionSystem:
    """Everything one data-conditioning solve needs, built once per
    (chart, θ, obs, σ²) and cached by the serving layer."""

    icr: object
    obs: object
    noise_var: float
    mats: dict
    matvec: Callable[[Array], Array]     # (k, O) -> (k, O)
    precond: Optional[Callable]          # ICR-whitened M⁻¹, or None
    mesh: object = None

    @property
    def n_obs(self) -> int:
        return self.obs.n_obs

    def dense_solve(self, b: Array) -> Array:
        """Materialize A by batched matvec on the identity and solve
        directly — the ladder's dense rung (small charts only; gated by
        ``CGConfig.dense_max``). A is symmetric, so batching identity
        *rows* through the matvec yields A itself."""
        n = self.n_obs
        eye = jnp.eye(n, dtype=b.dtype)
        a = condition_matvec(self.icr, self.mats, self.obs,
                             self.noise_var, eye)
        return jnp.linalg.solve(a, jnp.asarray(b).T).T

    def correct(self, alpha: Array) -> Array:
        """K Wᵀ α for a batch of solutions: (k, O) -> (k, *final_shape)
        posterior corrections (one Sᵀ + one S application)."""
        xi = self.project_xi(alpha)
        return self.icr.apply_sqrt_batch(self.mats, xi)

    def project_xi(self, alpha: Array) -> list:
        """Sᵀ Wᵀ α: the whitened (ξ-space) representation of the
        conditioning correction — a delta ``Posterior.mean`` serves the
        CG posterior mean through the existing sampling slab unchanged."""
        shape = self.icr.chart.final_shape
        u = self.obs.apply_t(jnp.asarray(alpha))
        u = u.reshape((u.shape[0],) + tuple(shape))
        return _sqrt_t_batch(self.icr, self.mats, u)


def _sqrt_t_batch(icr, mats, u: Array) -> list:
    """Batched Sᵀ: VJP of ``apply_sqrt_batch`` at zero ξ (linear in ξ at
    fixed matrices, so the VJP *is* the transpose — ``ICR.apply_sqrt_T``
    batched over the sample axis)."""
    k = u.shape[0]
    zero = [jnp.zeros((k,) + tuple(s), u.dtype) for s in icr.xi_shapes()]
    out, vjp = jax.vjp(lambda xi: icr.apply_sqrt_batch(mats, xi), zero)
    # under a bf16 storage policy the sqrt emits bf16: the cotangent must
    # match the primal output dtype (f32 solves are unaffected)
    return vjp(u.astype(out.dtype))[0]


def condition_matvec(icr, mats, obs, noise_var, v: Array) -> Array:
    """(W S Sᵀ Wᵀ + σ²I) v for a batch of observation-space vectors."""
    k = v.shape[0]
    shape = tuple(icr.chart.final_shape)
    u = obs.apply_t(v).reshape((k,) + shape)
    xi = _sqrt_t_batch(icr, mats, u)
    f = icr.apply_sqrt_batch(mats, xi).reshape(k, -1)
    return obs.apply(f) + noise_var * v


def icr_whitening_precond(icr, mats, obs, noise_var: float, *,
                          max_basis: int = 512) -> Optional[Callable]:
    """The ICR-whitened (coarse-subspace Woodbury) preconditioner.

    Take the coarse prefix of ξ levels whose total size fits
    ``max_basis`` (always at least level 0): their span carries the
    top of the kernel spectrum — the slowly-converging CG directions.
    With ``U = W S_c`` (obs × m, built by ONE batched sqrt application
    over the m basis excitations) precondition with

        M = σ² I + U Uᵀ,
        M⁻¹ r = (r − U C⁻¹ Uᵀ r) / σ²,   C = σ² I_m + Uᵀ U  (Cholesky).

    Exact on the coarse subspace, identity/σ² on its complement —
    clusters the preconditioned spectrum near 1 ∪ {fine-scale tail}.
    Returns None when even level 0 exceeds ``max_basis`` (the ladder
    then starts at the unpreconditioned rung).
    """
    sizes = [int(np.prod(s)) for s in icr.xi_shapes()]
    take = 0
    total = 0
    for s in sizes:
        if take > 0 and total + s > max_basis:
            break
        take += 1
        total += s
    if total > max_basis:
        return None
    m = total
    # m basis excitations: row j is e_j within the taken coarse prefix
    basis = []
    off = 0
    for lvl, s in enumerate(sizes):
        shape = tuple(icr.xi_shapes()[lvl])
        if lvl < take:
            block = jnp.eye(m, dtype=jnp.float32)[:, off:off + s]
            basis.append(block.reshape((m,) + shape))
            off += s
        else:
            basis.append(jnp.zeros((m,) + shape, jnp.float32))
    fields = icr.apply_sqrt_batch(mats, basis).reshape(m, -1)
    fields = fields.astype(jnp.float32)
    u = obs.apply(fields).T                       # (O, m)
    c = noise_var * jnp.eye(m, dtype=u.dtype) + u.T @ u
    chol = jax.scipy.linalg.cho_factor(c)

    def precond(r: Array) -> Array:
        t = r @ u                                  # (k, m)
        s = jax.scipy.linalg.cho_solve(chol, t.T).T
        return (r - s @ u.T) / noise_var

    return precond


def build_condition_system(icr, obs, noise_var: float, *, theta=None,
                           mats=None, mesh=None,
                           precond_max_basis: int = 512,
                           use_precond: bool = True) -> ConditionSystem:
    """Assemble the jitted (optionally RHS-sharded) conditioning system.

    With ``mesh``, the matvec runs under ``shard_map`` split over the
    RHS axis (matrices replicated) — callers must pad the RHS batch to a
    multiple of the mesh size (``solve_guarded`` keeps widths constant
    across rungs, and ``pcg_solve`` re-pads the carry after an elastic
    shrink)."""
    if mats is None:
        mats = icr.matrices_cached(theta)
    noise_var = float(noise_var)

    def core(mats_, v):
        return condition_matvec(icr, mats_, obs, noise_var, v)

    if mesh is None:
        fn = jax.jit(core)
    else:
        from repro.compat import shard_map

        axes = tuple(mesh.axis_names)
        repl = jax.tree.map(lambda _: P(), mats)
        fn = jax.jit(shard_map(core, mesh=mesh, in_specs=(repl, P(axes)),
                               out_specs=P(axes), check_vma=False))

    matvec = lambda v: fn(mats, v)  # noqa: E731 — bound operator
    precond = (icr_whitening_precond(icr, mats, obs, noise_var,
                                     max_basis=precond_max_basis)
               if use_precond else None)
    return ConditionSystem(icr=icr, obs=obs, noise_var=noise_var,
                           mats=mats, matvec=matvec, precond=precond,
                           mesh=mesh)
