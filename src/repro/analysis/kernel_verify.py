"""Launch-plan verifier: prove properties of every exported Pallas launch.

DESIGN.md §14.  Every kernel entry point builds a declarative
:class:`~repro.kernels.launch.LaunchPlan` and launches *through* it
(``run_plan``), and ``dispatch.level_launch_plans`` /
``dispatch.chart_launch_plans`` export the same records — so proving a
property of the plan proves it of the launch.  For every route ×
autotuned tile × scenario cell this module checks:

* **coverage** — enumerate the grid, concretely evaluate every output
  index map, and require the multiset of written block indices to be
  exactly the cartesian block decomposition of the output array: no
  gaps, no double-writes, no out-of-range blocks, block shape divides
  the array shape.
* **bounds** — every input block fetched at every grid step lies inside
  the (padded) operand array.
* **halo** — for each overhang-carrying view, the union of the block
  intervals fetched by the view and its ``halo_of`` siblings covers the
  declared overhang at every grid step.
* **bytes** — the plan's double-buffered working set fits the VMEM lint
  budget (floor-exempt, like the autotuner); forward plans must not
  exceed the ``block1d_bytes`` / ``_fused_tile_bytes`` byte model the
  autotuner grew against; plan operand array bytes must dominate the
  ``roofline/level_traffic.py`` HBM model (the plan cannot claim to
  move fewer bytes than the roofline says the level needs).
* **transpose** — each registered custom_vjp pair is a true transpose:
  a taint-based jaxpr linearity walk of the forward in (field, ξ) at
  fixed matrices, plus an exact ``⟨Ax, y⟩ == ⟨x, Aᵀy⟩`` dot test run in
  interpret mode at the verified tile config and storage dtype.
* **hygiene** — every ``dot_general`` carries a
  ``preferred_element_type`` at least as wide as the accumulation
  dtype; no data-dependent control flow (``while``/``cond``); no bulk
  f32 upcast of sub-f32 storage operands inside kernel bodies.

Findings are :class:`~repro.analysis.lint.LintFinding` records with
``pass_name`` one of ``coverage | bounds | halo | bytes | transpose |
hygiene``.  ``python -m repro.analysis verify`` drives
:func:`verify_scenario` over every scenario cell and fails CI on any
finding; ``tools/update_fingerprints.py`` refuses to re-baseline the
compile-artifact goldens while the verifier reports findings.
"""
from __future__ import annotations

import itertools
import json
import math
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import matern32
from repro.core.refine import LevelGeom, axis_refinement_matrices_level
from repro.kernels import dispatch as dsp
from repro.roofline.level_traffic import refine_level_traffic

from .lint import LintFinding
from .scenarios import SCENARIOS

__all__ = [
    "check_coverage", "check_bounds", "check_halo", "check_bytes",
    "check_linearity", "check_hygiene", "transpose_dot_check",
    "verify_plan", "verify_group", "verify_scenario", "verify_all",
]


def _grid_steps(grid):
    return itertools.product(*(range(int(n)) for n in grid))


def _eval_map(op, g):
    idx = op.index_map(*g)
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(i) for i in idx)


# ---------------------------------------------------------------- coverage

def check_coverage(plan, *, scenario: str = "", location: str = "") -> list:
    """Exact output coverage: each output block written exactly once."""
    findings = []

    def find(msg):
        findings.append(LintFinding("coverage", scenario, location,
                                    f"{plan.kernel}: {msg}"))

    for op in plan.outputs:
        nblocks = []
        ok = True
        for d, (asz, bsz) in enumerate(zip(op.array_shape, op.block_shape)):
            if asz % bsz:
                find(f"output {op.name!r} dim {d}: array extent {asz} is "
                     f"not a multiple of block extent {bsz}")
                ok = False
            nblocks.append(asz // bsz)
        if not ok:
            continue
        counts = Counter()
        for g in _grid_steps(plan.grid):
            idx = _eval_map(op, g)
            if len(idx) != len(op.block_shape):
                find(f"output {op.name!r}: index map {op.index_map.name!r} "
                     f"returns rank {len(idx)}, block is rank "
                     f"{len(op.block_shape)}")
                counts = None
                break
            counts[idx] += 1
        if counts is None:
            continue
        expected = set(itertools.product(*(range(n) for n in nblocks)))
        written = set(counts)
        missing = sorted(expected - written)
        extra = sorted(written - expected)
        dupes = sorted(k for k, v in counts.items()
                       if v > 1 and k in expected)
        if missing:
            find(f"output {op.name!r}: {len(missing)} block(s) never "
                 f"written by index map {op.index_map.name!r} "
                 f"(e.g. {missing[:3]}) — coverage gap")
        if extra:
            find(f"output {op.name!r}: index map {op.index_map.name!r} "
                 f"writes {len(extra)} out-of-range block(s) "
                 f"(e.g. {extra[:3]})")
        if dupes:
            find(f"output {op.name!r}: {len(dupes)} block(s) written more "
                 f"than once (e.g. {dupes[:3]}) — double-write")
    return findings


# ------------------------------------------------------------------ bounds

def check_bounds(plan, *, scenario: str = "", location: str = "") -> list:
    """Every input block read at every grid step is inside its array."""
    findings = []

    def find(msg):
        findings.append(LintFinding("bounds", scenario, location,
                                    f"{plan.kernel}: {msg}"))

    for op in plan.inputs:
        for g in _grid_steps(plan.grid):
            idx = _eval_map(op, g)
            bad = None
            for d, (i, bsz, asz) in enumerate(
                    zip(idx, op.block_shape, op.array_shape)):
                lo, hi = i * bsz, i * bsz + bsz
                if lo < 0 or hi > asz:
                    bad = (d, lo, hi, asz)
                    break
            if bad is not None:
                d, lo, hi, asz = bad
                find(f"input {op.name!r} at grid step {g}: index map "
                     f"{op.index_map.name!r} reads [{lo}, {hi}) on dim {d} "
                     f"outside the padded operand extent {asz}")
                break  # one finding per operand is enough
    return findings


# -------------------------------------------------------------------- halo

def check_halo(plan, *, scenario: str = "", location: str = "") -> list:
    """Halo groups cover the declared overhang at every grid step."""
    findings = []

    def find(msg):
        findings.append(LintFinding("halo", scenario, location,
                                    f"{plan.kernel}: {msg}"))

    mains = {op.name: op for op in plan.inputs if op.overhang}
    halos = {}
    for op in plan.inputs:
        if op.halo_of:
            halos.setdefault(op.halo_of, []).append(op)
    for name in halos:
        if name not in mains:
            find(f"halo view(s) {[h.name for h in halos[name]]} reference "
                 f"main view {name!r} which declares no overhang")

    for main in mains.values():
        group = [main] + halos.get(main.name, [])
        over_dims = [d for d, (lo, hi) in enumerate(main.overhang)
                     if lo or hi]
        if len(over_dims) != 1:
            find(f"main view {main.name!r} declares overhang on "
                 f"{len(over_dims)} dims — the halo checker only models "
                 f"single-axis overhang")
            continue
        d = over_dims[0]
        lo_ov, hi_ov = main.overhang[d]
        mismatched = [s for s in group[1:]
                      if s.block_shape != main.block_shape
                      or s.array_shape != main.array_shape]
        if mismatched:
            find(f"halo view(s) {[s.name for s in mismatched]} do not "
                 f"share {main.name!r}'s block/array shape")
            continue
        bsz = main.block_shape[d]
        for g in _grid_steps(plan.grid):
            idxs = [_eval_map(op, g) for op in group]
            midx = idxs[0]
            diverged = False
            for op, idx in zip(group[1:], idxs[1:]):
                if any(idx[e] != midx[e] for e in range(len(idx)) if e != d):
                    find(f"halo view {op.name!r} at grid step {g} diverges "
                         f"from main {main.name!r} on a non-overhang dim")
                    diverged = True
            if diverged:
                break
            need_lo = midx[d] * bsz - lo_ov
            need_hi = midx[d] * bsz + bsz + hi_ov
            spans = sorted((idx[d] * bsz, idx[d] * bsz + bsz)
                           for idx in idxs)
            cur = need_lo
            for s_lo, s_hi in spans:
                if s_lo <= cur:
                    cur = max(cur, s_hi)
            if cur < need_hi:
                find(f"main view {main.name!r} at grid step {g}: overhang "
                     f"window [{need_lo}, {need_hi}) on dim {d} not covered "
                     f"by the fetched blocks {spans} of group "
                     f"{[op.name for op in group]}")
                break  # one grid step is enough to name the defect
    return findings


# ------------------------------------------------------------------- bytes

_TRAFFIC_ROUTES = (dsp.ROUTE_STATIONARY_1D, dsp.ROUTE_CHARTED_1D,
                   dsp.ROUTE_ND_FUSED)


def check_bytes(plan, *, geom=None, route=None, samples: int = 1,
                dtype=None, vmem_budget=None,
                scenario: str = "", location: str = "") -> list:
    """Working set vs budget and the autotuner/roofline byte models."""
    budget = dsp.VMEM_BUDGET_BYTES if vmem_budget is None else vmem_budget
    findings = []

    def find(msg):
        findings.append(LintFinding("bytes", scenario, location,
                                    f"{plan.kernel}: {msg}"))

    p = dict(plan.params)
    ws = plan.block_bytes()
    itemsize = plan.outputs[0].itemsize
    onedim = {"t", "n_csz", "n_fsz", "b_f", "b_b", "charted"} <= p.keys()
    exempt = onedim and p["b_f"] <= dsp.block1d_floor(
        p["t"], p["n_csz"], p["n_fsz"])
    if ws > budget and not exempt:
        find(f"plan working set {ws} B exceeds the VMEM budget {budget} B")

    if onedim and p.get("kind") == "fwd":
        model = dsp.block1d_bytes(
            p["t"], p["n_csz"], p["n_fsz"], charted=p["charted"],
            block_families=p["b_f"], batch_block=p["b_b"],
            itemsize=itemsize)
        if ws > model:
            find(f"plan working set {ws} B exceeds the block1d_bytes "
                 f"model {model} B at its own tile (b_f={p['b_f']}, "
                 f"b_b={p['b_b']}) — the autotuner model undercounts")
    if plan.kernel == "refine_nd_fused" and geom is not None:
        model = dsp._fused_tile_bytes(geom, tuple(p["charted"]), p["b_f"],
                                      p["s_b"], itemsize)
        if ws > model:
            find(f"plan working set {ws} B exceeds the _fused_tile_bytes "
                 f"model {model} B at its own tile (b_f={p['b_f']}, "
                 f"s_b={p['s_b']})")

    # roofline cross-check: the plan's concrete operand arrays cannot be
    # smaller than what the HBM traffic model says the level must move
    if (p.get("kind") == "fwd" and geom is not None
            and route in _TRAFFIC_ROUTES):
        tr = refine_level_traffic(geom, route, dtype=dtype or "float32",
                                  samples=samples)
        need_in = tr["field_read"] + tr["xi_read"] + tr["matrices"]
        have_in = sum(op.array_bytes for op in plan.inputs
                      if not op.halo_of)
        if have_in < need_in:
            find(f"plan input arrays total {have_in} B but the "
                 f"level_traffic model reads {need_in} B "
                 f"(field+xi+matrices) — the plan is missing traffic")
        have_out = sum(op.array_bytes for op in plan.outputs)
        if have_out < tr["fine_write"]:
            find(f"plan output arrays total {have_out} B but the "
                 f"level_traffic model writes {tr['fine_write']} B")
    return findings


# ------------------------------------------------- linearity (taint walk)

_LINEAR_PRIMS = frozenset({
    "add", "add_any", "sub", "neg", "pad", "slice", "reshape", "transpose",
    "concatenate", "broadcast_in_dim", "squeeze", "expand_dims", "rev",
    "convert_element_type", "reduce_sum", "cumsum", "real", "imag", "copy",
    "gather",
})
# name -> number of leading operands the primitive is linear in; taint on
# any later operand (indices, denominator, ...) is a finding
_PREFIX_LINEAR = {"dynamic_slice": 1, "dynamic_update_slice": 2, "div": 1}
_BILINEAR = frozenset({"mul", "dot_general", "conv_general_dilated"})
_CALL_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "remat", "remat2", "checkpoint",
    "custom_jvp_call", "custom_jvp_call_jaxpr",
    "custom_vjp_call", "custom_vjp_call_jaxpr",
})


def _callee(params):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = params.get(key)
        if sub is not None:
            return getattr(sub, "jaxpr", sub)
    return None


def _linear_walk(jaxpr, in_taints, find, path):
    """Propagate taint; flag any nonlinear primitive touching taint.

    Returns ``(out_taints, final_invar_taints)`` — the latter carries the
    end-state of mutable refs so ``pallas_call`` output refs resolve.
    """
    from jax.core import Literal

    env = {}
    for v, t in zip(jaxpr.invars, in_taints):
        env[v] = bool(t)
    for v in jaxpr.constvars:
        env[v] = False

    def rd(a):
        return False if isinstance(a, Literal) else env.get(a, False)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        ts = [rd(a) for a in eqn.invars]
        any_t = any(ts)
        if name in _CALL_PRIMS:
            sub = _callee(eqn.params)
            if sub is None:
                if any_t:
                    find(f"{path}: opaque call primitive {name} consumes "
                         f"tainted data")
                outs = [any_t] * len(eqn.outvars)
            else:
                outs, _ = _linear_walk(sub, ts, find, f"{path}/{name}")
            for v, t in zip(eqn.outvars, outs):
                env[v] = t
            continue
        if name == "pallas_call":
            sub = eqn.params["jaxpr"]
            n_extra = len(sub.invars) - len(ts)  # out refs (+ scratch)
            _, fin = _linear_walk(sub, ts + [False] * max(0, n_extra),
                                  find, f"{path}/pallas")
            out_t = fin[len(ts):len(ts) + len(eqn.outvars)]
            for v, t in zip(eqn.outvars, out_t):
                env[v] = t
            continue
        if name == "get":
            env[eqn.outvars[0]] = rd(eqn.invars[0])
            continue
        if name == "swap":
            ref, val = eqn.invars[0], eqn.invars[1]
            old = rd(ref)
            env[ref] = rd(ref) or rd(val)  # partial writes merge
            env[eqn.outvars[0]] = old
            continue
        if name == "addupdate":
            ref, val = eqn.invars[0], eqn.invars[1]
            env[ref] = rd(ref) or rd(val)
            continue
        if not any_t:
            for v in eqn.outvars:
                env[v] = False
            continue
        out_t = True
        if name in _LINEAR_PRIMS:
            pass
        elif name in _BILINEAR:
            if sum(1 for t in ts if t) > 1:
                find(f"{path}: bilinear {name} has more than one tainted "
                     f"operand — nonlinear in the linearized inputs")
        elif name in _PREFIX_LINEAR:
            if any(ts[_PREFIX_LINEAR[name]:]):
                find(f"{path}: {name} is tainted in a nonlinear operand "
                     f"position (index/denominator)")
        elif name == "select_n":
            if ts[0]:
                find(f"{path}: select_n predicate is tainted — "
                     f"data-dependent selection")
            out_t = any(ts[1:])
        else:
            find(f"{path}: primitive {name} is not linear (or unknown to "
                 f"the linearity checker) but consumes tainted data")
        for v in eqn.outvars:
            env[v] = out_t
    outs = [rd(v) for v in jaxpr.outvars]
    fin = [env.get(v, False) for v in jaxpr.invars]
    return outs, fin


def check_linearity(f, args, *, scenario: str = "", location: str = "",
                    label: str = "") -> list:
    """Prove ``f`` is linear in every array argument by jaxpr analysis."""
    findings = []

    def find(msg):
        findings.append(LintFinding("transpose", scenario, location, msg))

    jx = jax.make_jaxpr(f)(*args)
    _linear_walk(jx.jaxpr, [True] * len(jx.jaxpr.invars), find,
                 label or getattr(f, "__name__", "fn"))
    return findings


# ----------------------------------------------------------------- hygiene

def _child_jaxprs(eqn):
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for u in items:
            u = getattr(u, "jaxpr", u)
            if isinstance(u, jax.core.Jaxpr):
                yield u


def _hygiene_walk(jaxpr, find, path, *, storage_itemsize, accum_width,
                  in_kernel=False):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in ("while", "cond"):
            find(f"{path}: data-dependent control flow primitive "
                 f"`{name}` in the traced computation")
        if name == "dot_general":
            pet = eqn.params.get("preferred_element_type")
            if pet is None:
                find(f"{path}: dot_general without preferred_element_type "
                     f"— accumulation dtype left to the backend")
            elif jnp.dtype(pet).itemsize < accum_width:
                find(f"{path}: dot_general preferred_element_type "
                     f"{jnp.dtype(pet).name} is narrower than the "
                     f"accumulation dtype")
        if (name == "convert_element_type" and in_kernel
                and storage_itemsize < 4):
            aval = getattr(eqn.invars[0], "aval", None)
            new = jnp.dtype(eqn.params["new_dtype"])
            if (aval is not None
                    and jnp.issubdtype(aval.dtype, jnp.floating)
                    and jnp.issubdtype(new, jnp.floating)
                    and aval.dtype.itemsize < 4 and new.itemsize >= 4
                    and aval.size >= 4096):
                find(f"{path}: bulk f32 upcast of a {aval.dtype.name} "
                     f"storage operand ({aval.size} elements) inside a "
                     f"kernel body — defeats the storage dtype policy")
        for sub in _child_jaxprs(eqn):
            _hygiene_walk(sub, find, f"{path}/{name}",
                          storage_itemsize=storage_itemsize,
                          accum_width=accum_width,
                          in_kernel=in_kernel or name == "pallas_call")


def check_hygiene(f, args, *, storage=None, accum_dtype="float32",
                  scenario: str = "", location: str = "",
                  label: str = "") -> list:
    """dot_general accumulation, control flow and upcast hygiene of ``f``."""
    findings = []

    def find(msg):
        findings.append(LintFinding("hygiene", scenario, location, msg))

    storage = jnp.dtype(storage or jnp.float32)
    jx = jax.make_jaxpr(f)(*args)
    _hygiene_walk(jx.jaxpr, find, label or getattr(f, "__name__", "fn"),
                  storage_itemsize=storage.itemsize,
                  accum_width=jnp.dtype(accum_dtype).itemsize)
    return findings


# --------------------------------------------------------------- transpose

def transpose_dot_check(f, args, *, rtol: float, seed: int = 0,
                        scenario: str = "", location: str = "",
                        label: str = "") -> list:
    """Exact ``⟨Ax, y⟩ == ⟨x, Aᵀy⟩`` test of ``f`` and its VJP."""
    findings = []

    def find(msg):
        findings.append(LintFinding("transpose", scenario, location, msg))

    rng = np.random.default_rng(seed)
    out, vjpf = jax.vjp(f, *args)
    y = jnp.asarray(rng.normal(size=out.shape), out.dtype)
    cots = vjpf(y)

    def dot(a, b):
        return float(jnp.vdot(jnp.asarray(a, jnp.float32).ravel(),
                              jnp.asarray(b, jnp.float32).ravel()))

    lhs = dot(out, y)
    rhs = sum(dot(x, g) for x, g in zip(args, cots))
    denom = max(abs(lhs), abs(rhs), 1e-30)
    rel = abs(lhs - rhs) / denom
    if not math.isfinite(rel) or rel > rtol:
        find(f"{label or 'fn'}: adjoint is not the transpose of the "
             f"forward: <Ax, y> = {lhs:.6g} but <x, A^T y> = {rhs:.6g} "
             f"(relative error {rel:.3g} > {rtol:g})")
    return findings


# ----------------------------------------------------------------- drivers

def verify_plan(plan, *, geom=None, route=None, samples: int = 1,
                dtype=None, vmem_budget=None,
                scenario: str = "", location: str = "") -> list:
    """All static passes (coverage, bounds, halo, bytes) of one plan."""
    kw = dict(scenario=scenario, location=location)
    return (check_coverage(plan, **kw)
            + check_bounds(plan, **kw)
            + check_halo(plan, **kw)
            + check_bytes(plan, geom=geom, route=route, samples=samples,
                          dtype=dtype, vmem_budget=vmem_budget, **kw))


def _group_runner(grp, chart, kernel, *, storage, samples: int):
    """Build the route's differentiable runner at the group's verified
    tile config (interpret mode) plus random storage-dtype operands."""
    from repro.kernels.nd import refine_axes
    from repro.kernels.nd_fused import refine_nd_fused
    from repro.kernels.pyramid import refine_pyramid

    route = grp["route"]
    rng = np.random.default_rng(20260808)
    if route == dsp.ROUTE_PYRAMID:
        geoms = grp["geom"]
        mats, xis = [], []
        for lvl, g in enumerate(geoms):
            rs, ds = axis_refinement_matrices_level(chart, kernel, lvl)
            mats.append(([jnp.asarray(r, storage) for r in rs],
                         [jnp.asarray(d, storage) for d in ds]))
            nd = len(g.coarse_shape)
            xis.append(jnp.asarray(
                rng.normal(size=(samples, int(np.prod(g.T)),
                                 g.n_fsz ** nd)), storage))
        field = jnp.asarray(
            rng.normal(size=(samples,) + tuple(geoms[0].coarse_shape)),
            storage)
        s_b = grp["plans"][0].params["s_b"]

        def f(field, *xis):
            return refine_pyramid(field, list(xis), mats, geoms,
                                  interpret=True, sample_block=s_b,
                                  sample_axis=True)

        return f, (field, *xis)

    geom, lvl = grp["geom"], grp["level"]
    rs, ds = axis_refinement_matrices_level(chart, kernel, lvl)
    rs = [jnp.asarray(r, storage) for r in rs]
    ds = [jnp.asarray(d, storage) for d in ds]
    nd = len(geom.coarse_shape)
    field = jnp.asarray(
        rng.normal(size=(samples,) + tuple(geom.coarse_shape)), storage)
    xi = jnp.asarray(
        rng.normal(size=(samples, int(np.prod(geom.T)), geom.n_fsz ** nd)),
        storage)
    fwd = grp["plans"][0].params

    if route in (dsp.ROUTE_STATIONARY_1D, dsp.ROUTE_CHARTED_1D):
        xi = xi.reshape(samples, geom.T[0], geom.n_fsz)
        r, d = rs[0], ds[0]
        b_f, b_b = fwd["b_f"], fwd["b_b"]

        def f(field, xi):
            return dsp.refine(field, xi, r, d, geom, backend="interpret",
                              block_families=b_f, sample_block=b_b,
                              sample_axis=True)

        return f, (field, xi)
    if route == dsp.ROUTE_ND_FUSED:
        b_f, s_b = fwd["b_f"], fwd["s_b"]

        def f(field, xi):
            return refine_nd_fused(field, xi, rs, ds, geom,
                                   interpret=True, block_families=b_f,
                                   sample_block=s_b, sample_axis=True)

        return f, (field, xi)
    if route == dsp.ROUTE_AXES_ND:

        def f(field, xi):
            return refine_axes(field, xi, rs, ds, geom, interpret=True,
                               sample_axis=True)

        return f, (field, xi)
    raise ValueError(f"no runner for route {route!r}")


def verify_group(grp, chart, kernel, *, samples: int, storage,
                 vmem_budget=None, semantic: bool = True,
                 scenario: str = "") -> list:
    """Verify one launch group: static passes per plan + semantic
    (linearity, hygiene, transpose) checks of the route's custom VJP."""
    route, lvl = grp["route"], grp["level"]
    loc = (f"level={lvl}" if isinstance(lvl, int)
           else f"levels={lvl[0]}..{lvl[1]}")
    geom = grp["geom"] if isinstance(grp["geom"], LevelGeom) else None
    storage = jnp.dtype(storage)
    findings = []
    for plan in grp["plans"]:
        findings += verify_plan(plan, geom=geom, route=route,
                                samples=samples, dtype=storage,
                                vmem_budget=vmem_budget,
                                scenario=scenario, location=loc)
    if not semantic or not grp["plans"] or route == dsp.ROUTE_REFERENCE:
        return findings
    f, args = _group_runner(grp, chart, kernel, storage=storage,
                            samples=samples)
    kw = dict(scenario=scenario, location=loc)
    findings += check_linearity(f, args, label=f"{route}/fwd", **kw)
    findings += check_hygiene(f, args, storage=storage,
                              label=f"{route}/fwd", **kw)
    out, vjpf = jax.vjp(f, *args)
    y = jnp.zeros(out.shape, out.dtype)
    findings += check_hygiene(vjpf, (y,), storage=storage,
                              label=f"{route}/vjp", **kw)
    rtol = 2e-3 if storage.itemsize >= 4 else 0.2
    findings += transpose_dot_check(f, args, rtol=rtol,
                                    label=route, **kw)
    return findings


def verify_scenario(scn, *, vmem_budget=None, semantic: bool = True) -> list:
    """Run every verifier pass over one scenario cell.

    Both pyramid overlays are exported (``pyramid=True`` collapses the
    covered prefix into the single multi-level launch; ``pyramid=False``
    is the per-level execution ``ICR(use_pyramid=False)`` runs, whose
    1-D adjoints are also the pyramid's backward building blocks);
    identical groups between the two overlays are checked once.
    """
    from repro.kernels.policy import resolve as resolve_policy

    chart = scn.chart()
    pol = resolve_policy(scn.policy) if scn.policy else None
    storage = jnp.dtype(pol.storage_dtype) if pol else jnp.dtype(jnp.float32)
    kernel = matern32.with_defaults(rho=scn.rho)()
    findings, seen = [], set()
    for pyramid in (True, False):
        groups = dsp.chart_launch_plans(
            chart, samples=scn.samples, dtype=storage, pyramid=pyramid,
            vmem_budget=(vmem_budget or dsp.VMEM_BUDGET_BYTES))
        for grp in groups:
            key = json.dumps(
                [grp["route"], str(grp["level"]),
                 [p.describe() for p in grp["plans"]]],
                sort_keys=True, default=str)
            if key in seen:
                continue
            seen.add(key)
            findings += verify_group(grp, chart, kernel,
                                     samples=scn.samples, storage=storage,
                                     vmem_budget=vmem_budget,
                                     semantic=semantic, scenario=scn.label)
    return findings


def verify_all(*, scenarios=None, vmem_budget=None,
               semantic: bool = True) -> list:
    """Verify every scenario cell; the ``verify`` CLI entry point."""
    findings = []
    for scn in (scenarios if scenarios is not None else SCENARIOS()):
        findings += verify_scenario(scn, vmem_budget=vmem_budget,
                                    semantic=semantic)
    return findings
