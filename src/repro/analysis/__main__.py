"""CLI for the compile-artifact regression guard (DESIGN.md §13/§14).

    python -m repro.analysis                 # lint + diff vs tests/golden/
    python -m repro.analysis check           # same (explicit)
    python -m repro.analysis verify          # launch-plan verifier (§14)
    python -m repro.analysis shardcheck      # mesh-safety analyzer (§17)
    python -m repro.analysis --update        # regenerate the goldens
    python -m repro.analysis --scenario tod-bf16
    python -m repro.analysis --out DIR       # also dump current docs

The check mode is the CI ``static-analysis`` job: it recomputes every
scenario's fingerprint document, diffs it against the committed golden
(structured diff inline in the log), runs the three golden-free lint
passes, and exits non-zero on any difference or finding. ``--update`` is
the sanctioned regeneration path (``tools/update_fingerprints.py`` wraps
it): rewrite the goldens, then review the *git* diff of the JSON like any
other code change.

``verify`` runs ``kernel_verify.verify_scenario`` over every scenario
cell: exact output coverage / in-bounds halo reads of every exported
LaunchPlan, the VMEM + roofline byte cross-checks, the custom-VJP
transpose proof (jaxpr linearity walk + interpret-mode dot test at the
verified tile config) and the jaxpr hygiene passes. Exits non-zero on
any finding.

``shardcheck`` runs ``mesh_verify.shardcheck_all`` over every
shard_map'd entry point (DistributedICR sqrt apply, the GPFieldServer
slab step in samples/chart shard modes, the PCG conditioning matvec):
collective soundness, determinism, remesh invariance and cache-key
soundness (DESIGN.md §17). It forces 8 virtual CPU host devices (set
before jax initializes a backend) so the mesh sweep is real; findings
go to stdout and — with ``--out`` — to a JSON artifact. Exits non-zero
on any finding.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

from . import (
    SCENARIOS,
    canonical_json,
    diff_docs,
    fingerprint_scenario,
    format_diff,
    lint_scenario,
)

DEFAULT_GOLDEN_DIR = (
    pathlib.Path(__file__).resolve().parents[3] / "tests" / "golden"
)


def golden_path(golden_dir: pathlib.Path, label: str) -> pathlib.Path:
    return golden_dir / f"fingerprint-{label}.json"


def run_shardcheck(args) -> int:
    """The §17 mesh-safety analyzer over every shard_map'd entry point."""
    # the mesh sweep needs real devices to shard over; force virtual CPU
    # devices *before* jax initializes a backend (no-op once initialized
    # or when the caller already set XLA_FLAGS)
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    from .mesh_verify import SERVING_SCENARIOS, shardcheck_scenario

    names = list(SERVING_SCENARIOS)
    if args.scenario:
        # accept serving-scenario names; tolerate fingerprint labels
        # ("tod-fp32") so one --scenario flag works for every command
        want = {s.split("-")[0] for s in args.scenario}
        unknown = want - set(names)
        if unknown:
            print(f"unknown scenario(s) {sorted(unknown)}; have {names}")
            return 2
        names = [n for n in names if n in want]

    failed = False
    report = {"entries": [], "findings": []}
    for name in names:
        print(f"== {name} ==", flush=True)
        checked: list = []
        findings = shardcheck_scenario(name, checked=checked)
        report["entries"] += checked
        for f in findings:
            print(f"  FAIL: {f}")
            report["findings"].append(f.to_dict())
        if findings:
            failed = True
        else:
            print(f"  {len(checked)} entry point(s) verified (collective, "
                  "determinism, remesh, cache-key)")

    if args.out is not None:
        args.out.mkdir(parents=True, exist_ok=True)
        (args.out / "shardcheck-findings.json").write_text(
            json.dumps(report, indent=2, sort_keys=True))

    if failed:
        print("\nshardcheck FAILED", flush=True)
        return 1
    print("\nshardcheck OK", flush=True)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="HLO/route fingerprint diff + Pallas lint passes")
    ap.add_argument("command", nargs="?",
                    choices=("check", "verify", "shardcheck"),
                    default="check",
                    help="check: fingerprint diff + lint (default); "
                         "verify: the DESIGN.md §14 launch-plan verifier; "
                         "shardcheck: the §17 mesh-safety analyzer")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the goldens instead of diffing")
    ap.add_argument("--golden-dir", type=pathlib.Path,
                    default=DEFAULT_GOLDEN_DIR,
                    help=f"golden directory (default {DEFAULT_GOLDEN_DIR})")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="also write the freshly computed docs here "
                         "(CI artifact)")
    ap.add_argument("--scenario", action="append", default=None,
                    help="restrict to these scenario labels "
                         "(e.g. tod-bf16; repeatable)")
    ap.add_argument("--skip-lint", action="store_true",
                    help="fingerprint diff only")
    ap.add_argument("--samples", type=int, default=4,
                    help="slab/batch height of the batched entries")
    args = ap.parse_args(argv)

    if args.command == "shardcheck":
        return run_shardcheck(args)

    cells = SCENARIOS(samples=args.samples)
    if args.scenario:
        want = set(args.scenario)
        unknown = want - {s.label for s in cells}
        if unknown:
            ap.error(f"unknown scenario(s) {sorted(unknown)}; have "
                     f"{[s.label for s in cells]}")
        cells = [s for s in cells if s.label in want]

    if args.command == "verify":
        from .kernel_verify import verify_scenario

        failed = False
        for scn in cells:
            print(f"== {scn.label} ==", flush=True)
            findings = verify_scenario(scn)
            for f in findings:
                print(f"  FAIL: {f}")
            if findings:
                failed = True
            else:
                print("  launch plans verified (coverage, bounds, halo, "
                      "bytes, transpose, hygiene)")
        if failed:
            print("\nkernel verify FAILED", flush=True)
            return 1
        print("\nkernel verify OK", flush=True)
        return 0

    failed = False
    for scn in cells:
        print(f"== {scn.label} ==", flush=True)
        doc = fingerprint_scenario(scn)
        text = canonical_json(doc)
        if args.out is not None:
            args.out.mkdir(parents=True, exist_ok=True)
            (args.out / f"fingerprint-{scn.label}.json").write_text(text)

        gpath = golden_path(args.golden_dir, scn.label)
        if args.update:
            gpath.parent.mkdir(parents=True, exist_ok=True)
            gpath.write_text(text)
            print(f"  wrote {gpath}")
        elif not gpath.exists():
            print(f"  FAIL: no golden at {gpath} "
                  f"(run tools/update_fingerprints.py)")
            failed = True
        else:
            golden = json.loads(gpath.read_text())
            diffs = diff_docs(golden, doc)
            if diffs:
                print(f"  FAIL: fingerprint differs from {gpath.name} "
                      f"({len(diffs)} change(s)):")
                print(format_diff(diffs))
                failed = True
            else:
                print(f"  fingerprint matches {gpath.name}")

        if not args.skip_lint:
            findings = lint_scenario(scn)
            for f in findings:
                print(f"  FAIL: {f}")
            if findings:
                failed = True
            else:
                print("  lint passes clean (vmem, dtype, route)")

    if failed:
        print("\nstatic analysis FAILED", flush=True)
        return 1
    print("\nstatic analysis OK", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
