"""Normalized compile fingerprints of lowered entry points.

A fingerprint is everything about a compiled artifact that should be
*stable* across no-op re-lowers and *change* exactly when the compiled
structure changes:

  ``ops``           canonicalized post-optimization HLO op histogram
                    (instruction kinds with the SSA ``.N`` suffixes
                    stripped, across every computation — fusion bodies
                    included)
  ``dtypes``        dtype census: how many instruction outputs carry each
                    element type (every component of tuple outputs)
  ``custom_calls``  custom-call target inventory — on TPU this is where
                    the ``tpu_custom_call``/Mosaic Pallas launches show
                    up; an empty dict on the CPU/interpret path is itself
                    a locked-down fact
  ``cost``          the loop-aware ``roofline.hlo_cost`` flops/bytes
                    totals (ints), which count Pallas custom-calls at
                    their operand+output bytes so this column agrees with
                    the ``plan()`` byte model

and, per scenario, the ``plan()`` route + tile + byte signature
(``kernels.dispatch.plan_signature``) for both the TPU what-would-run
answer and the backend actually lowered against.

Everything is plain sorted-key JSON: ``canonical_json(doc)`` of two
lowers of the same scenario is byte-identical (the determinism the golden
diff relies on), and any structural change — a route flip, a lost
pyramid cover, an f32 upcast, a fusion-count change — surfaces as a
readable structured diff (:mod:`.diff`) instead of a wall-time blip.
"""
from __future__ import annotations

import json
import re
from collections import Counter, defaultdict

from repro.roofline.hlo_cost import (
    _CC_TARGET_RE,
    _SHAPE_RE,
    _split_def,
    module_costs,
)

SCHEMA_VERSION = 1

__all__ = ["SCHEMA_VERSION", "hlo_fingerprint", "dtype_element_counts",
           "fingerprint_scenario", "canonical_json", "chart_summary"]


def _instructions(hlo_text: str):
    """Yield ``(out_type, kind_base, line)`` for every instruction in the
    module — all computations, fusion bodies included; SSA suffixes
    stripped so histogram keys are canonical op kinds."""
    for raw in hlo_text.splitlines():
        s = raw.strip()
        if not s or s.startswith("//"):
            continue
        d = _split_def(s)
        if d is None:
            continue
        _, out_type, kind, _after = d
        yield out_type, re.sub(r"\.\d+$", "", kind), s


def hlo_fingerprint(hlo_text: str) -> dict:
    """The normalized fingerprint of one compiled module (module doc)."""
    ops, dtypes, custom = Counter(), Counter(), Counter()
    for out_type, kind, line in _instructions(hlo_text):
        ops[kind] += 1
        for dt, _dims in _SHAPE_RE.findall(out_type):
            dtypes[dt] += 1
        if kind == "custom-call":
            m = _CC_TARGET_RE.search(line)
            custom[m.group(1) if m else "<unknown>"] += 1
    cost = module_costs(hlo_text)
    return {
        "ops": dict(sorted(ops.items())),
        "dtypes": dict(sorted(dtypes.items())),
        "custom_calls": dict(sorted(custom.items())),
        "cost": {"flops": int(cost["flops"]), "bytes": int(cost["bytes"])},
    }


def dtype_element_counts(hlo_text: str) -> dict:
    """``{hlo_dtype: set(element counts)}`` over every instruction output
    in the module — what the dtype-policy lint pass walks to decide
    whether a level field is resident at the storage dtype or silently
    upcast (DESIGN.md §13)."""
    out = defaultdict(set)
    for out_type, _kind, _line in _instructions(hlo_text):
        for dt, dims in _SHAPE_RE.findall(out_type):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            out[dt].add(n)
    return dict(out)


def chart_summary(chart) -> dict:
    """JSON-stable geometry summary of a chart (no callables, no arrays)."""
    phi = getattr(chart, "phi_inv", None)
    return {
        "shape0": [int(x) for x in chart.shape0],
        "n_levels": int(chart.n_levels),
        "n_csz": int(chart.n_csz),
        "n_fsz": int(chart.n_fsz),
        "boundary": chart.boundary,
        "invariant": [bool(b) for b in chart.invariant],
        "phi_inv": (None if phi is None
                    else f"{getattr(phi, '__module__', '?')}."
                         f"{getattr(phi, '__qualname__', repr(phi))}"),
    }


def fingerprint_scenario(scn, *, backend: str = "interpret",
                         use_pallas: bool = True, use_pyramid: bool = True,
                         policy=None, _policy_set: bool = False) -> dict:
    """The full fingerprint document for one scenario cell.

    The default arguments are the production configuration the goldens
    lock down; the knobs exist for the self-tests' injected regressions
    (``policy`` is only honored with ``_policy_set=True`` so ``None`` can
    mean "inject fp32" rather than "default").
    """
    from repro.kernels import dispatch

    from .scenarios import _UNSET, lower_entries, pinned_backend

    pol_arg = policy if _policy_set else _UNSET
    icr = scn.icr(use_pallas=use_pallas, use_pyramid=use_pyramid,
                  policy=pol_arg)
    chart = icr.chart
    storage = icr.policy.storage_name
    have_axis = use_pallas and chart.ndim > 1
    pyramid = use_pallas and use_pyramid
    plan_kw = dict(have_axis_mats=have_axis, samples=scn.samples,
                   dtype=storage, pyramid=pyramid)
    with pinned_backend(backend):
        plan_lowered = dispatch.plan_signature(chart, **plan_kw)
    plan_tpu = dispatch.plan_signature(chart, platform="tpu", **plan_kw)

    lowered = lower_entries(scn, backend=backend, use_pallas=use_pallas,
                            use_pyramid=use_pyramid, policy=pol_arg)
    serving = lowered.pop("_serving")
    entries = {
        name: hlo_fingerprint(low.compile().as_text())
        for name, low in sorted(lowered.items())
    }
    return {
        "schema": SCHEMA_VERSION,
        "scenario": scn.label,
        "chart": chart_summary(chart),
        "storage_dtype": storage,
        "backend": backend,
        "samples": int(scn.samples),
        "plan": {"tpu": plan_tpu, "lowered": plan_lowered},
        "entries": entries,
        "serving": serving,
    }


def canonical_json(doc: dict) -> str:
    """The byte-stable serialization the goldens are stored and compared
    in: sorted keys, fixed separators, trailing newline."""
    return json.dumps(doc, sort_keys=True, indent=1) + "\n"
