"""Golden-free lint passes over plans and lowered HLO (DESIGN.md §13).

Three checkers, each re-deriving an invariant from first principles so it
holds with no golden to compare against:

  :func:`lint_vmem`            every autotuned tile in a plan fits the
                               §10/§11 VMEM working-set byte models, and
                               no tile is *degenerately small* (an
                               autotuner that stopped growing while the
                               next power of two still fit has silently
                               lost occupancy)
  :func:`lint_dtype_hlo`       for a sub-f32 storage policy, the lowered
                               HLO actually carries the level fields at
                               the storage dtype (no silent f32
                               residency) and never accumulates a dot
                               below the accumulation width
  :func:`lint_route_coverage`  no level of the TPU plan silently routes
                               to the jnp ``reference`` oracle — the
                               O(N)-with-small-constant story requires
                               every level on a structured kernel route

Findings are :class:`LintFinding` records; an empty list is a pass.
:func:`lint_scenario` runs all three over one scenario cell.
"""
from __future__ import annotations

import dataclasses

from repro.dtypes import HLO_DTYPE_BYTES, hlo_name, itemsize as dtype_itemsize

__all__ = ["LintFinding", "lint_vmem", "lint_dtype_hlo",
           "lint_route_coverage", "lint_scenario"]


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One lint violation: which pass, where, and what went wrong."""

    pass_name: str   # vmem | dtype | route
    scenario: str    # scenario label (or caller-supplied context)
    location: str    # level/entry the finding points at
    message: str

    def __str__(self):
        return f"[{self.pass_name}] {self.scenario} {self.location}: " \
               f"{self.message}"


def _prod(xs):
    out = 1
    for x in xs:
        out *= int(x)
    return out


def lint_vmem(chart, *, dtype=None, samples: int = 1, entries=None,
              vmem_budget=None, have_axis_mats=None, pyramid: bool = True,
              label: str = "") -> list:
    """Check every autotuned tile of the TPU plan against the VMEM budget.

    Re-derives each reported tile's working set through the same byte
    models the autotuners grow against (``block1d_bytes`` for the 1-D
    routes, ``_fused_tile_bytes`` for the megakernel and the pyramid
    residency) and flags:

      * **over-budget**: the reported tile's working set exceeds the
        budget — the autotuner output and the model disagree;
      * **degenerate**: the tile stopped below its natural ceiling
        (``T_0`` / ``samples``) although the next power-of-two step still
        fits — occupancy silently left on the table;
      * **mismatch**: the reported 1-D tile differs from what the
        autotuner derives today for the same geometry.

    ``entries`` defaults to the TPU ``plan()`` of `chart`; pass a stored
    ``plan_signature`` (or a doctored one — the negative tests do) to
    lint a plan that was not derived in this process.
    """
    from repro.core.refine import LevelGeom
    from repro.kernels import dispatch as dsp

    itemsize = dtype_itemsize(dtype or "float32")
    budget = dsp.VMEM_BUDGET_BYTES if vmem_budget is None else vmem_budget
    if entries is None:
        entries = dsp.plan_signature(
            chart, platform="tpu", have_axis_mats=have_axis_mats,
            samples=samples, dtype=dtype, pyramid=pyramid,
            vmem_budget=budget)
    findings = []

    def find(loc, msg):
        findings.append(LintFinding("vmem", label or chart.boundary,
                                    loc, msg))

    pyramid_geoms, pyramid_s_b = [], None
    for e in entries:
        lvl = int(e["level"])
        loc = f"level={lvl}"
        geom = LevelGeom.for_level(chart, lvl)
        route = e["route"]
        blocks = {int(k): int(v)
                  for k, v in (e.get("block_families") or {}).items()}
        s_b = e.get("sample_block")
        s_b = None if s_b is None else int(s_b)

        if route == dsp.ROUTE_PYRAMID:
            pyramid_geoms.append(geom)
            pyramid_s_b = s_b
            continue
        if route == dsp.ROUTE_REFERENCE:
            continue  # no tiles on the oracle path

        if route == dsp.ROUTE_ND_FUSED:
            charted = tuple(k > 1 for k in geom.kept_T)
            b_f = blocks.get(0)
            if b_f is None or s_b is None:
                find(loc, "nd-fused entry is missing its (b_f, s_b) tile")
                continue
            ws = dsp._fused_tile_bytes(geom, charted, b_f, s_b, itemsize)
            if ws > budget:
                find(loc, f"nd-fused tile (b_f={b_f}, s_b={s_b}) working "
                          f"set {ws} B exceeds VMEM budget {budget} B")
            t0 = geom.T[0]
            if b_f < t0:
                nxt = min(2 * b_f, t0)
                if dsp._fused_tile_bytes(geom, charted, nxt, s_b,
                                         itemsize) <= budget:
                    find(loc, f"degenerate nd-fused family block: b_f={b_f} "
                              f"but b_f={nxt} still fits the budget")
            if s_b < samples:
                nxt = min(2 * s_b, samples)
                if dsp._fused_tile_bytes(geom, charted, b_f, nxt,
                                         itemsize) <= budget:
                    find(loc, f"degenerate nd-fused sample block: s_b={s_b} "
                              f"but s_b={nxt} still fits the budget")
            continue

        if route in (dsp.ROUTE_STATIONARY_1D, dsp.ROUTE_CHARTED_1D):
            charted = route == dsp.ROUTE_CHARTED_1D
            b_f = blocks.get(0)
            if b_f is None:
                find(loc, f"{route} entry is missing its family block")
                continue
            t0, csz, fsz = geom.T[0], geom.n_csz, geom.n_fsz
            floor = dsp.block1d_floor(t0, csz, fsz)
            ws = dsp.block1d_bytes(t0, csz, fsz, charted=charted,
                                   block_families=b_f,
                                   batch_block=s_b or 1, itemsize=itemsize)
            if b_f > floor and ws > budget:
                find(loc, f"1-D tile b_f={b_f} (floor {floor}) working set "
                          f"{ws} B exceeds VMEM budget {budget} B")
            want_bf = dsp.autotune_block_families(
                t0, csz, fsz, charted=charted, itemsize=itemsize,
                vmem_budget=budget)
            if b_f != want_bf:
                find(loc, f"1-D family block {b_f} != autotuner answer "
                          f"{want_bf} for this geometry")
            if s_b is not None:
                want_sb = dsp.autotune_batch_block(
                    samples, t0, csz, fsz, charted=charted,
                    block_families=b_f, itemsize=itemsize,
                    vmem_budget=budget)
                if s_b != want_sb:
                    find(loc, f"1-D sample block {s_b} != autotuner answer "
                              f"{want_sb}")
            continue

        if route == dsp.ROUTE_AXES_ND:
            for a in range(len(geom.T)):
                ag = geom.axis(a)
                b_f = blocks.get(a)
                if b_f is None:
                    find(loc, f"axes-nd entry is missing the axis-{a} block")
                    continue
                want = dsp.autotune_block_families(
                    ag.T[0], ag.n_csz, ag.n_fsz, charted=ag.kept_T[0] > 1,
                    itemsize=itemsize, vmem_budget=budget)
                if b_f != want:
                    find(loc, f"axis-{a} family block {b_f} != autotuner "
                              f"answer {want}")
                ws = dsp.block1d_bytes(ag.T[0], ag.n_csz, ag.n_fsz,
                                       charted=ag.kept_T[0] > 1,
                                       block_families=b_f,
                                       itemsize=itemsize)
                floor = dsp.block1d_floor(ag.T[0], ag.n_csz, ag.n_fsz)
                if b_f > floor and ws > budget:
                    find(loc, f"axis-{a} tile b_f={b_f} working set {ws} B "
                              f"exceeds VMEM budget {budget} B")
            continue

        find(loc, f"unknown route {route!r} — lint pass out of date?")

    if pyramid_geoms:
        s_b = pyramid_s_b or 1
        total = sum(
            dsp._fused_tile_bytes(g, dsp._pyramid_charted(g), g.T[0], s_b,
                                  itemsize)
            for g in pyramid_geoms)
        loc = f"pyramid[0..{len(pyramid_geoms) - 1}]"
        if total > budget:
            find(loc, f"pyramid residency {total} B at s_b={s_b} exceeds "
                      f"VMEM budget {budget} B")
        if len(pyramid_geoms) < 2:
            find(loc, "single-level pyramid cover — the cover rule requires "
                      "at least two resident levels")
        if s_b < samples:
            nxt = min(2 * s_b, samples)
            grown = sum(
                dsp._fused_tile_bytes(g, dsp._pyramid_charted(g), g.T[0],
                                      nxt, itemsize)
                for g in pyramid_geoms)
            if grown <= budget:
                find(loc, f"degenerate pyramid sample block: s_b={s_b} but "
                          f"s_b={nxt} still fits the budget")
    return findings


def lint_dtype_hlo(hlo_text: str, *, chart, policy, samples: int = 1,
                   batched: bool = False, label: str = "",
                   entry: str = "") -> list:
    """Check a lowered module against the storage/accumulation contract.

    Only meaningful for sub-f32 storage policies (fp32 storage has
    nothing to violate — the pass returns no findings). Two invariants,
    both validated empirically against every chart × policy cell before
    being locked in here:

      * every intermediate level field (element count of
        ``LevelGeom.fine_shape`` for levels ``0..n_levels-2``, times
        ``samples`` when the entry is ``batched`` — a batched module's
        fields are slab-shaped, while its *unbatched* counts are the
        per-level posterior parameters / matrices, f32 by design) must
        appear at the storage dtype somewhere in the module; a count that
        appears **only** at f32 means the field is f32-resident — the
        §11 HBM-byte win silently gone;
      * no ``dot`` output may be narrower than the accumulation dtype —
        the kernels thread ``accum_dtype`` into every
        ``preferred_element_type`` and a bf16-output dot means bf16
        accumulation crept in.
    """
    from repro.core.refine import LevelGeom
    from repro.kernels.policy import resolve as resolve_policy

    from .fingerprint import _instructions, dtype_element_counts, _SHAPE_RE

    pol = resolve_policy(policy)
    if pol.storage_itemsize >= 4:
        return []
    storage = hlo_name(pol.storage_dtype)
    accum_width = dtype_itemsize(pol.accum_dtype)
    findings = []

    def find(loc, msg):
        findings.append(LintFinding("dtype", label, loc, msg))

    counts = dtype_element_counts(hlo_text)
    stored = counts.get(storage, set())
    f32 = counts.get("f32", set())
    for lvl in range(chart.n_levels - 1):
        n = _prod(LevelGeom.for_level(chart, lvl).fine_shape)
        c = samples * n if batched else n
        tag = f" (x{samples} samples)" if batched else ""
        if c in f32 and c not in stored:
            find(f"{entry or 'module'}/level={lvl}",
                 f"level field of {c} elements{tag} is f32-resident — "
                 f"expected {storage} storage under policy "
                 f"{pol.storage_name}/{pol.accum_name}")

    for out_type, kind, _line in _instructions(hlo_text):
        if kind != "dot":
            continue
        for dt, _dims in _SHAPE_RE.findall(out_type):
            if HLO_DTYPE_BYTES.get(dt, 4) < accum_width:
                find(entry or "module",
                     f"dot accumulates at {dt} (< {pol.accum_name} "
                     f"accumulation contract)")
    return findings


def lint_route_coverage(chart, *, dtype=None, samples: int = 1,
                        have_axis_mats=None, pyramid: bool = True,
                        label: str = "") -> list:
    """No level of the TPU plan may silently route to the jnp reference.

    ``plan(platform="tpu")`` is pure geometry (no lowering), so this pass
    answers the what-would-run-on-TPU question from any host. A level on
    ``route="reference"`` means the structured kernels declined the
    geometry — legitimate only as an explicit, visible decision, never as
    a silent fallback in a production scenario.
    """
    from repro.kernels import dispatch as dsp

    findings = []
    for e in dsp.plan(chart, platform="tpu", have_axis_mats=have_axis_mats,
                      samples=samples, dtype=dtype, pyramid=pyramid):
        if e["route"] == dsp.ROUTE_REFERENCE:
            findings.append(LintFinding(
                "route", label, f"level={e['level']}",
                "routes to the jnp reference on the TPU path — no "
                "structured kernel covers this level"))
        elif e["backend"] == dsp.BACKEND_REFERENCE \
                and e["route"] != dsp.ROUTE_REFERENCE:
            findings.append(LintFinding(
                "route", label, f"level={e['level']}",
                f"structured route {e['route']!r} reports the reference "
                f"backend on the TPU path"))
    return findings


def lint_scenario(scn, *, backend: str = "interpret") -> list:
    """All three passes over one scenario cell (see :mod:`.scenarios`).

    VMEM and route coverage lint the pure-geometry TPU plan; the dtype
    pass walks every lowered entry point's compiled HLO.
    """
    from .scenarios import lower_entries

    chart = scn.chart()
    icr = scn.icr()
    storage = icr.policy.storage_name
    have_axis = chart.ndim > 1
    findings = []
    findings += lint_vmem(chart, dtype=storage, samples=scn.samples,
                          have_axis_mats=have_axis, label=scn.label)
    findings += lint_route_coverage(chart, dtype=storage,
                                    samples=scn.samples,
                                    have_axis_mats=have_axis,
                                    label=scn.label)
    lowered = lower_entries(scn, backend=backend)
    lowered.pop("_serving", None)
    for name, low in sorted(lowered.items()):
        findings += lint_dtype_hlo(
            low.compile().as_text(), chart=chart, policy=scn.policy,
            samples=scn.samples,
            # condition_matvec is slab-shaped too: k RHS columns ride the
            # sample axis through apply_sqrt_batch and its VJP
            batched=("batch" in name or "slab" in name
                     or name == "condition_matvec"),
            label=scn.label, entry=name)
    return findings
