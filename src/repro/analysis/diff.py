"""Structured diff between fingerprint documents (DESIGN.md §13).

A golden mismatch must read like a code review comment, not a JSON blob:
``diff_docs`` walks two documents recursively and returns ``(path, kind,
old, new)`` tuples; ``format_diff`` renders them one change per line,

    ~ plan.tpu[0].route: 'pyramid' -> 'nd-fused'
    - entries.apply_sqrt.custom_calls.tpu_custom_call: 3
    + entries.apply_sqrt.ops.while: 2

so the CI job log states exactly which route/tile/op-count moved.
"""
from __future__ import annotations

__all__ = ["diff_docs", "format_diff"]

# diff kinds
ADDED = "added"        # key/index present only in the current doc
REMOVED = "removed"    # key/index present only in the golden
CHANGED = "changed"    # scalar value differs


def _join(path: str, key) -> str:
    if isinstance(key, int):
        return f"{path}[{key}]"
    return f"{path}.{key}" if path else str(key)


def diff_docs(golden, current, path: str = "") -> list:
    """All differences between two JSON-like documents, as a flat list of
    ``(path, kind, old, new)`` tuples (empty list == identical)."""
    if isinstance(golden, dict) and isinstance(current, dict):
        out = []
        for k in sorted(set(golden) | set(current), key=str):
            p = _join(path, k)
            if k not in golden:
                out.append((p, ADDED, None, current[k]))
            elif k not in current:
                out.append((p, REMOVED, golden[k], None))
            else:
                out.extend(diff_docs(golden[k], current[k], p))
        return out
    if isinstance(golden, list) and isinstance(current, list):
        out = []
        for i in range(max(len(golden), len(current))):
            p = _join(path, i)
            if i >= len(golden):
                out.append((p, ADDED, None, current[i]))
            elif i >= len(current):
                out.append((p, REMOVED, golden[i], None))
            else:
                out.extend(diff_docs(golden[i], current[i], p))
        return out
    if golden != current:
        return [(path or "<root>", CHANGED, golden, current)]
    return []


def _short(v) -> str:
    s = repr(v)
    return s if len(s) <= 60 else s[:57] + "..."


def format_diff(diffs) -> str:
    """One readable line per difference (``~`` changed, ``+`` added,
    ``-`` removed), golden on the left, current on the right."""
    lines = []
    for path, kind, old, new in diffs:
        if kind == CHANGED:
            lines.append(f"  ~ {path}: {_short(old)} -> {_short(new)}")
        elif kind == ADDED:
            lines.append(f"  + {path}: {_short(new)}")
        else:
            lines.append(f"  - {path}: {_short(old)}")
    return "\n".join(lines)
