"""Mesh-safety analyzer: static verification of the sharded layers (§17).

The §13 fingerprints and §14 launch verifier prove the single-device
kernel layer from its compile artifacts; this module applies the same
"prove it from the jaxpr, don't just sample it" discipline to the
distributed layer. Every ``shard_map``'d entry point — the
``DistributedICR`` sqrt apply, the ``GPFieldServer`` slab step in both
``shard="samples"`` and ``shard="chart"`` modes, and the PCG conditioning
matvec from ``solvers/gp_system.py`` — runs under ``check_vma=False``
(the 0.4.x shard_map shim disables jax's own replication checking), so
nothing at runtime verifies that the bodies reduce what their out_specs
claim to replicate. Four passes close that gap:

  **collective** (:func:`check_collectives`): a per-mesh-axis
  device-variance dataflow over the body jaxpr. Each body input starts
  varying along exactly the mesh axes its ``in_names`` shard it over;
  ``axis_index`` introduces variance, ``psum``/``pmax``/``pmin``/
  ``all_gather`` clear it on the reduced axes, ``ppermute`` and ordinary
  compute propagate it. An output whose ``out_names`` omit a mesh axis
  *claims replication* along it — if the value still carries variance
  there, the claim is unsound (under ``check_vma=False`` jax will happily
  emit one device's arbitrary answer). Axis names outside the mesh and
  redundant psums of already-replicated operands are flagged too.

  **determinism** (:func:`check_determinism`): the PR8 guarantee is that
  a replayed sample-sharded slab is *bit-identical* to the unfaulted run.
  The pass walks the entry jaxpr for unkeyed PRNG draws (a random-bits
  chain rooted in a constant instead of a traced seed), and — on
  replay-sensitive entries only — data-dependent control flow
  (``while``/``cond``), PRNG keys tainted by ``axis_index`` (draws that
  change when the mesh does), and any cross-device collective (reduction
  order and ring structure change across re-meshes). Chart-sharded
  serving promises fp-tolerance equality, not bit-identity, so its halo
  ``ppermute`` traffic is exempt.

  **remesh** (:func:`check_remesh` over :func:`local_dot_signatures`):
  abstract-eval the entry at ≥3 mesh sizes and prove the *local*
  ``dot_general``/conv shapes inside the shard_map bodies are invariant.
  Sample-sharded serving pins per-device rows at construction
  (``GPFieldServer._local_rows``) precisely so replayed slabs run the
  same local gemms on a shrunk mesh — full shape-multiset equality is
  required. Chart-sharded bodies and the RHS-sharded PCG matvec scale
  their spatial/batch extents with the ring by design; there the
  contraction extents (the refinement-matrix dimensions) must be
  invariant instead.

  **cachekey** (:func:`cachekey_audit`, :func:`plan_key_audit`): build
  the server under single-dimension config perturbations (θ, dtype
  policy, slab height, backend override, mesh, q-params), fingerprint
  everything that reaches the compiled executable (stored matrices,
  argument avals, traced jaxpr, routing plan), and require that two
  configs colliding on ``GPFieldServer._cache_key`` have identical
  artifacts — a collision with differing artifacts is a stale-cache
  hazard naming the uncovered input. ``dispatch.plan_cached`` gets a
  functional probe per keyword: perturbing any argument must never
  return the cached plan object.

Findings are structured :class:`MeshFinding` records; an empty list is a
pass. ``python -m repro.analysis shardcheck`` runs everything over the
serving matrix (samples/chart × tod/image/dust) and exits non-zero on
any finding; ``tools/update_fingerprints.py`` refuses to re-baseline
goldens while findings exist.
"""
from __future__ import annotations

import dataclasses
import hashlib
import re

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .kernel_verify import _CALL_PRIMS, _callee, _child_jaxprs

__all__ = [
    "MeshFinding", "iter_shard_maps", "analyze_entry", "analyze_jaxpr",
    "check_collectives", "check_determinism", "check_remesh",
    "local_dot_signatures", "cachekey_audit", "plan_key_audit",
    "shardcheck_scenario", "shardcheck_all", "SERVING_SCENARIOS",
]

SERVING_SCENARIOS = ("tod", "image", "dust")

# collectives that *reduce* device variance on their named axes vs ones
# that merely move data around the ring (variance-preserving)
_REDUCING_COLLECTIVES = frozenset({"psum", "pmax", "pmin", "all_gather",
                                   "reduce_scatter", "all_to_all"})
_PERMUTING_COLLECTIVES = frozenset({"ppermute", "pshuffle"})
_COLLECTIVES = _REDUCING_COLLECTIVES | _PERMUTING_COLLECTIVES
# primitives that materialize random bits from a key/seed chain
_DRAW_PRIMS = frozenset({"random_bits", "threefry2x32"})


@dataclasses.dataclass(frozen=True)
class MeshFinding:
    """One mesh-safety violation: which pass, which entry point, where."""

    pass_name: str   # collective | determinism | remesh | cachekey
    entry: str       # e.g. "serve[samples]:tod"
    location: str    # jaxpr path (top/eqn3:pjit/eqn0:shard_map/...)
    severity: str    # error | warning
    message: str

    def __str__(self):
        return (f"[{self.pass_name}/{self.severity}] {self.entry} "
                f"{self.location}: {self.message}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# -- jaxpr plumbing --------------------------------------------------------------
def _inner_jaxpr(obj):
    """Unwrap ClosedJaxpr → Jaxpr (shard_map/pjit params carry either)."""
    return getattr(obj, "jaxpr", obj)


def iter_shard_maps(jaxpr, path: str = "top"):
    """Yield ``(eqn, path)`` for every shard_map equation, recursively."""
    jaxpr = _inner_jaxpr(jaxpr)
    for i, eqn in enumerate(jaxpr.eqns):
        here = f"{path}/eqn{i}:{eqn.primitive.name}"
        if eqn.primitive.name == "shard_map":
            yield eqn, here
        for sub in _child_jaxprs(eqn):
            yield from iter_shard_maps(sub, here)


def _axes_param(eqn) -> tuple:
    """The mesh-axis names a collective/axis_index equation names."""
    for key in ("axes", "axis_name", "axis_index_groups_axis"):
        if key in eqn.params and eqn.params[key] is not None:
            v = eqn.params[key]
            if isinstance(v, (tuple, list)):
                # psum axes may mix positional ints (vmap axes) with names
                return tuple(a for a in v if isinstance(a, str))
            return (v,) if isinstance(v, str) else ()
    return ()


def _names_axes(names: dict) -> frozenset:
    """Mesh axes a shard_map in_names/out_names entry shards over."""
    out = set()
    for axs in names.values():
        axs = (axs,) if isinstance(axs, str) else axs
        out.update(axs)
    return frozenset(out)


# -- pass (a): collective soundness ----------------------------------------------
def _variance_walk(jaxpr, in_var, mesh_axes, entry, path, out):
    """Propagate per-mesh-axis device variance through a shard_map body.

    ``in_var`` is one frozenset of mesh-axis names per invar. Returns the
    variance sets of the body outputs. Control flow and ``pallas_call``
    are handled conservatively (variance in → variance out, never
    cleared), so a replication claim this walk accepts is genuinely
    reduction-backed.
    """
    from jax.core import Literal

    jaxpr = _inner_jaxpr(jaxpr)
    env = {}
    for v, t in zip(jaxpr.invars, in_var):
        env[v] = frozenset(t)
    for v in jaxpr.constvars:
        env[v] = frozenset()

    def rd(a):
        return frozenset() if isinstance(a, Literal) else env.get(a, frozenset())

    for i, eqn in enumerate(jaxpr.eqns):
        name = eqn.primitive.name
        here = f"{path}/eqn{i}:{name}"
        ts = [rd(a) for a in eqn.invars]
        joined = frozenset().union(*ts) if ts else frozenset()

        if name == "axis_index":
            env[eqn.outvars[0]] = joined | frozenset(_axes_param(eqn))
            continue
        if name in _COLLECTIVES:
            named = frozenset(_axes_param(eqn))
            unknown = named - mesh_axes
            if unknown:
                out(MeshFinding(
                    "collective", entry, here, "error",
                    f"`{name}` names mesh axis/axes {sorted(unknown)} not "
                    f"in this shard_map's mesh {sorted(mesh_axes)}"))
            if name in _REDUCING_COLLECTIVES:
                if name == "psum" and not (joined & named):
                    out(MeshFinding(
                        "collective", entry, here, "warning",
                        f"redundant psum over {sorted(named)}: the operand "
                        "is already replicated on those axes (it multiplies "
                        "replicated values by the axis size)"))
                res = joined - named
            else:
                # a partial ppermute leaves ring-edge devices with zeros:
                # the result varies along the permuted axes even from a
                # replicated operand
                res = joined | (named & mesh_axes)
            for v in eqn.outvars:
                env[v] = res
            continue
        if name == "shard_map":
            # nested shard_map: treat as opaque compute over its operands
            for v in eqn.outvars:
                env[v] = joined
            continue
        if name in _CALL_PRIMS:
            sub = _callee(eqn.params)
            if sub is not None and len(sub.invars) == len(ts):
                outs = _variance_walk(sub, ts, mesh_axes, entry, here, out)
            else:
                outs = [joined] * len(eqn.outvars)
            for v, t in zip(eqn.outvars, outs):
                env[v] = t
            continue
        # pallas_call, scan, while, cond, and every ordinary primitive:
        # any input variance reaches every output
        for v in eqn.outvars:
            env[v] = joined
    return [rd(v) for v in jaxpr.outvars]


def check_collectives(eqn, *, entry: str, path: str) -> list:
    """Pass (a) over one shard_map equation: replication claims must be
    backed by a reducing collective on every claimed axis."""
    findings = []
    mesh = eqn.params["mesh"]
    mesh_axes = frozenset(mesh.axis_names)
    in_names = eqn.params["in_names"]
    out_names = eqn.params["out_names"]
    body = _inner_jaxpr(eqn.params["jaxpr"])
    in_var = [_names_axes(n) for n in in_names]
    out_var = _variance_walk(body, in_var, mesh_axes, entry, path,
                             findings.append)
    for i, (names, var) in enumerate(zip(out_names, out_var)):
        resid = (var & mesh_axes) - _names_axes(names)
        if resid:
            findings.append(MeshFinding(
                "collective", entry, f"{path}/out{i}", "error",
                f"out_specs claim replication over mesh axis/axes "
                f"{sorted(resid)} but the output is device-varying there "
                "(no psum/all_gather on its path; under check_vma=False "
                "this silently serves one device's arbitrary shard)"))
    return findings


# -- pass (b): determinism -------------------------------------------------------
def _det_walk(jaxpr, in_taints, entry, path, out, *, replay: bool):
    """Taint walk for the determinism pass.

    Taint per var is ``(derived, meshy)``: *derived* = reachable from a
    body/entry input (a traced seed is derived; a baked-in PRNGKey(0) is
    not), *meshy* = influenced by ``axis_index`` or collective traffic.
    """
    from jax.core import Literal

    jaxpr = _inner_jaxpr(jaxpr)
    env = {}
    for v, t in zip(jaxpr.invars, in_taints):
        env[v] = t
    for v in jaxpr.constvars:
        env[v] = (False, False)

    def rd(a):
        return (False, False) if isinstance(a, Literal) \
            else env.get(a, (False, False))

    for i, eqn in enumerate(jaxpr.eqns):
        name = eqn.primitive.name
        here = f"{path}/eqn{i}:{name}"
        ts = [rd(a) for a in eqn.invars]
        derived = any(d for d, _ in ts)
        meshy = any(m for _, m in ts)

        if name == "axis_index":
            env[eqn.outvars[0]] = (True, True)
            continue
        if name in ("while", "cond") and replay:
            out(MeshFinding(
                "determinism", entry, here, "error",
                f"data-dependent control flow `{name}` on the "
                "bit-identical-replay path: iteration counts/branches may "
                "differ across re-meshed replays"))
        if name in _COLLECTIVES and replay:
            out(MeshFinding(
                "determinism", entry, here, "error",
                f"cross-device collective `{name}` feeds a replay-"
                "sensitive entry: reduction order and ring structure "
                "change when the mesh does, breaking bit-identical replay"))
            meshy = True
        if name in _DRAW_PRIMS:
            if not derived:
                out(MeshFinding(
                    "determinism", entry, here, "error",
                    f"unkeyed PRNG draw (`{name}` rooted in a constant "
                    "key, not a traced seed): every slab redraws the same "
                    "noise and replay cannot re-key it per request"))
            if meshy and replay:
                out(MeshFinding(
                    "determinism", entry, here, "error",
                    f"mesh-dependent PRNG draw (`{name}` keyed through "
                    "axis_index/collectives): replayed draws change when "
                    "the mesh shrinks"))
        if name == "shard_map":
            sub = _inner_jaxpr(eqn.params["jaxpr"])
            if len(sub.invars) == len(ts):
                outs = _det_walk(sub, ts, entry, here, out, replay=replay)
            else:
                outs = [(derived, meshy)] * len(eqn.outvars)
            for v, t in zip(eqn.outvars, outs):
                env[v] = t
            continue
        if name in _CALL_PRIMS:
            sub = _callee(eqn.params)
            if sub is not None and len(sub.invars) == len(ts):
                outs = _det_walk(sub, ts, entry, here, out, replay=replay)
            else:
                outs = [(derived, meshy)] * len(eqn.outvars)
            for v, t in zip(eqn.outvars, outs):
                env[v] = t
            continue
        for v in eqn.outvars:
            env[v] = (derived, meshy)
    return [rd(v) for v in jaxpr.outvars]


def check_determinism(closed_jaxpr, *, entry: str,
                      replay_sensitive: bool) -> list:
    """Pass (b) over one entry jaxpr (recurses into shard_map bodies)."""
    findings = []
    jx = _inner_jaxpr(closed_jaxpr)
    _det_walk(jx, [(True, False)] * len(jx.invars), entry, "top",
              findings.append, replay=replay_sensitive)
    return findings


# -- pass (c): remesh invariance -------------------------------------------------
def _collect_dots(jaxpr, sigs, *, contract_only: bool):
    jaxpr = _inner_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            lhs = tuple(eqn.invars[0].aval.shape)
            rhs = tuple(eqn.invars[1].aval.shape)
            (lc, rc), _ = eqn.params["dimension_numbers"]
            if contract_only:
                sig = ("dot",
                       tuple(lhs[i] for i in lc), tuple(rhs[j] for j in rc))
            else:
                sig = ("dot", lhs, rhs, eqn.params["dimension_numbers"])
            sigs[sig] = sigs.get(sig, 0) + 1
        elif name == "conv_general_dilated":
            lhs = tuple(eqn.invars[0].aval.shape)
            rhs = tuple(eqn.invars[1].aval.shape)
            sig = ("conv", rhs) if contract_only else ("conv", lhs, rhs)
            sigs[sig] = sigs.get(sig, 0) + 1
        for sub in _child_jaxprs(eqn):
            _collect_dots(sub, sigs, contract_only=contract_only)


def local_dot_signatures(closed_jaxpr, *, contract_only: bool = False) -> dict:
    """Multiset of dot_general/conv shape signatures *inside* the
    shard_map bodies of an entry jaxpr — the per-device local gemms.

    ``contract_only`` reduces each signature to its contraction extents
    (the refinement-matrix dimensions), the invariant for bodies whose
    spatial/batch extents legitimately scale with the ring size.
    """
    sigs: dict = {}
    for eqn, _ in iter_shard_maps(closed_jaxpr):
        _collect_dots(eqn.params["jaxpr"], sigs, contract_only=contract_only)
    return sigs


def check_remesh(entry: str, sigs_by_size: dict, *,
                 what: str = "local dot_general/conv shapes") -> list:
    """Pass (c): the signature multisets must agree across mesh sizes."""
    findings = []
    sizes = sorted(sigs_by_size)
    if len(sizes) < 2:
        return findings
    base_n = sizes[0]
    base = sigs_by_size[base_n]
    for n in sizes[1:]:
        cur = sigs_by_size[n]
        if cur == base:
            continue
        gone = {s: c for s, c in base.items() if cur.get(s) != c}
        new = {s: c for s, c in cur.items() if base.get(s) != c}
        sample = list(gone.items())[:2] + list(new.items())[:2]
        findings.append(MeshFinding(
            "remesh", entry, f"mesh[{base_n}]-vs-mesh[{n}]", "error",
            f"{what} depend on the mesh size: {len(gone)} signature(s) "
            f"changed between {base_n} and {n} device(s) (e.g. {sample}); "
            "per-device work must be pinned (the local_rows invariant) so "
            "replayed slabs run identical gemms after an elastic shrink"))
    return findings


# -- passes (a)+(b) driver over one entry ----------------------------------------
def analyze_jaxpr(closed_jaxpr, *, entry: str,
                  replay_sensitive: bool = False) -> list:
    """Collective + determinism passes over one traced entry point."""
    findings = []
    shard_maps = list(iter_shard_maps(closed_jaxpr))
    for eqn, path in shard_maps:
        findings += check_collectives(eqn, entry=entry, path=path)
    findings += check_determinism(closed_jaxpr, entry=entry,
                                  replay_sensitive=replay_sensitive)
    return findings


def analyze_entry(fn, args, *, entry: str,
                  replay_sensitive: bool = False) -> list:
    """Trace ``fn(*args)`` and run the collective + determinism passes."""
    return analyze_jaxpr(jax.make_jaxpr(fn)(*args), entry=entry,
                         replay_sensitive=replay_sensitive)


# -- pass (d): cache-key soundness -----------------------------------------------
def _digest(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def _mats_digest(mats) -> str:
    parts = []
    for leaf in jax.tree.leaves(mats):
        a = np.asarray(leaf)
        parts.append(f"{a.shape}:{a.dtype}:"
                     f"{hashlib.sha256(a.tobytes()).hexdigest()[:12]}")
    return _digest("|".join(parts))


def _artifact_fingerprint(srv) -> dict:
    """Per-component digests of everything reaching the compiled slab
    executable *except* the q-parameters (mean/std ride as jit arguments
    by design — swapping them is exactly what the cache is for)."""
    from repro.launch.serve_gp import _canonical_key

    e = srv._entry
    args = srv._slab_args(e, [])
    avals = jax.tree.map(lambda x: f"{jnp.shape(x)}:{jnp.asarray(x).dtype}",
                         list(args[3:]))  # seeds/rows/flags/xi overrides
    jx = jax.make_jaxpr(e["fn"])(*args)
    # custom_vjp residual-thunk params print with memory addresses;
    # two traces of the same program must digest identically
    jx_text = re.sub(r"0x[0-9a-f]+", "0x", str(jx))
    return {
        "mats": _mats_digest(e["mats"]),
        "arg_avals": _digest(repr(avals) + repr(e["capacity"])),
        "jaxpr": _digest(jx_text),
        "plan": _digest(_canonical_key(tuple(map(tuple, (
            sorted(p.items()) for p in _plan_rows(e["plan"])))))),
    }


def _plan_rows(plan) -> list:
    rows = []
    for level in plan:
        rows.append({str(k): repr(v) for k, v in sorted(level.items())})
    return rows


def _mk_server(name: str, *, mesh=None, shard: str = "samples",
               quick: bool = True, slab: int = 4, rho=None, policy=None,
               seed: int = 0, server_cls=None):
    from repro.launch.serve_gp import (GPFieldServer, SCENARIOS as RHO,
                                       demo_posterior, scenario_chart)

    chart = scenario_chart(name, quick=quick)
    post = demo_posterior(chart, RHO[name] if rho is None else rho,
                          dtype_policy=policy, seed=seed)
    cls = GPFieldServer if server_cls is None else server_cls
    return cls(post, slab=slab, mesh=mesh, shard=shard)


def cachekey_audit(name: str, *, quick: bool = True, slab: int = 4,
                   mesh=None, devices=None, server_cls=None,
                   backend: str = "reference") -> list:
    """Pass (d): single-dimension config perturbations must never collide
    on ``_cache_key`` while producing different compiled artifacts.

    The ``seed`` variant is the deliberate control: same config, new
    q-parameters — it *must* collide with the base key AND carry an
    identical artifact (q-params are jit arguments, not baked in).
    """
    from repro.analysis.scenarios import pinned_backend
    from repro.launch.serve_gp import SCENARIOS as RHO, _canonical_key

    rho = RHO[name]
    base = dict(quick=quick, slab=slab, mesh=mesh, rho=rho, policy=None,
                seed=0, server_cls=server_cls)
    variants = {
        "base": dict(base),
        "seed": {**base, "seed": 1},
        "theta": {**base, "rho": 2.0 * rho},
        "policy": {**base, "policy": "bf16"},
        "slab": {**base, "slab": slab + 4},
    }
    backends = {label: backend for label in variants}
    variants["backend"] = dict(base)
    backends["backend"] = "interpret" if backend != "interpret" \
        else "reference"
    if mesh is not None and int(np.asarray(mesh.devices).size) > 1:
        devs = list(np.asarray(mesh.devices).flat)
        variants["mesh"] = {**base, "mesh": Mesh(
            np.asarray(devs[:len(devs) // 2]), mesh.axis_names)}
        backends["mesh"] = backend

    entry = f"serve[samples]:{name}"
    findings = []
    groups: dict = {}
    for label, cfg in variants.items():
        with pinned_backend(backends[label]):
            srv = _mk_server(name, shard="samples", **cfg)
            key = _canonical_key(srv._cache_key(srv.posterior))
            fp = _artifact_fingerprint(srv)
        groups.setdefault(key, []).append((label, fp))

    for key, members in groups.items():
        if len(members) < 2:
            continue
        base_label, base_fp = members[0]
        for label, fp in members[1:]:
            diff = sorted(k for k in base_fp if fp[k] != base_fp[k])
            if diff:
                findings.append(MeshFinding(
                    "cachekey", entry, f"variant[{label}]", "error",
                    f"config variants {base_label!r} and {label!r} collide "
                    f"on _cache_key but their compiled artifacts differ in "
                    f"{diff}: that input reaches the executable without "
                    "being keyed (stale-cache hazard on re-fit/re-mesh)"))
    return findings


def plan_key_audit(name: str, *, quick: bool = True,
                   entry: str | None = None) -> list:
    """Functional probe of ``dispatch.plan_cached`` key coverage: for each
    keyword, a perturbed call must never return the *cached object* of the
    base call — identity here means the key dropped that input."""
    from repro.analysis.scenarios import pinned_backend
    from repro.kernels import dispatch
    from repro.launch.serve_gp import scenario_chart

    chart = scenario_chart(name, quick=quick)
    entry = entry or f"plan_cached:{name}"
    base = dict(have_axis_mats=False, samples=4, dtype=None, pyramid=True,
                vmem_budget=dispatch.VMEM_BUDGET_BYTES,
                mesh_key=("shardcheck", 0))
    perturbed = dict(have_axis_mats=True, samples=8, dtype=jnp.bfloat16,
                     pyramid=False,
                     vmem_budget=dispatch.VMEM_BUDGET_BYTES // 2,
                     mesh_key=("shardcheck", 1))
    findings = []
    with pinned_backend("reference"):
        p0 = dispatch.plan_cached(chart, **base)
        for kw, val in perturbed.items():
            p1 = dispatch.plan_cached(chart, **{**base, kw: val})
            if p1 is p0:
                findings.append(MeshFinding(
                    "cachekey", entry, f"kwarg[{kw}]", "error",
                    f"plan_cached returned the cached plan object for a "
                    f"different {kw}={val!r}: the plan-cache key does not "
                    "cover that input"))
        with pinned_backend("interpret"):
            p1 = dispatch.plan_cached(chart, **base)
        if p1 is p0:
            findings.append(MeshFinding(
                "cachekey", entry, "kwarg[backend]", "error",
                "plan_cached returned the cached plan object under a "
                "different REPRO_BACKEND: the key does not cover the "
                "effective backend"))
    return findings


# -- entry-point drivers ---------------------------------------------------------
def _mesh_sizes(n_dev: int) -> list:
    """Mesh sizes to sweep: the full device set plus halvings (≥3 sizes
    when the devices allow — 8 → [8, 4, 2])."""
    sizes = []
    n = n_dev
    while n >= 1 and len(sizes) < 3:
        sizes.append(n)
        n //= 2
    return sizes


def _mesh(devices, k: int, axis: str = "data") -> Mesh:
    return Mesh(np.asarray(devices[:k]), (axis,))


def _chart_ring_sizes(icr, devices, sizes) -> list:
    """Ring sizes over which this chart's family counts are shardable."""
    from repro.core.distributed import DistributedICR

    out = []
    for k in sizes:
        try:
            DistributedICR(icr=icr, mesh=_mesh(devices, k, "ring"),
                           axis_names=("ring",)).first_sharded_level()
        except ValueError:
            continue
        out.append(k)
    return out


def shardcheck_scenario(name: str, *, quick: bool = True, slab: int = 4,
                        devices=None, backend: str = "reference",
                        checked: list | None = None) -> list:
    """All four passes over every shard_map'd entry point of one serving
    scenario. ``checked`` (optional accumulator) collects the entry
    labels actually analyzed, for the CLI report."""
    from repro.analysis.scenarios import pinned_backend
    from repro.core.distributed import DistributedICR
    from repro.solvers.gp_system import build_condition_system, obs_operator

    devices = list(devices if devices is not None else jax.devices())
    sizes = _mesh_sizes(len(devices))
    checked = checked if checked is not None else []
    findings = []

    with pinned_backend(backend):
        # ---- serve[samples]: one server, re-meshed across sizes (the
        # pinned-local_rows path an elastic shrink actually takes)
        label = f"serve[samples]:{name}"
        srv = _mk_server(name, mesh=_mesh(devices, sizes[0]),
                         shard="samples", quick=quick, slab=slab)
        sigs = {}
        for k in sizes:
            srv.mesh = _mesh(devices, k)
            srv.set_posterior(srv.posterior)
            jx = jax.make_jaxpr(srv._entry["fn"])(
                *srv._slab_args(srv._entry, []))
            if k == sizes[0]:
                findings += analyze_jaxpr(jx, entry=label,
                                          replay_sensitive=True)
            sigs[k] = local_dot_signatures(jx)
        findings += check_remesh(label, sigs)
        checked.append(label)

        icr = srv.posterior.icr

        # ---- serve[chart]: fresh server per feasible ring size; the
        # local block scales with the ring, so the invariant is the
        # contraction extents, not the full local shapes
        ring_sizes = _chart_ring_sizes(icr, devices, sizes)
        if ring_sizes:
            label = f"serve[chart]:{name}"
            sigs = {}
            for k in ring_sizes:
                csrv = _mk_server(name, mesh=_mesh(devices, k, "ring"),
                                  shard="chart", quick=quick, slab=slab)
                jx = jax.make_jaxpr(csrv._entry["fn"])(
                    *csrv._slab_args(csrv._entry, []))
                if k == ring_sizes[0]:
                    findings += analyze_jaxpr(jx, entry=label,
                                              replay_sensitive=False)
                sigs[k] = local_dot_signatures(jx, contract_only=True)
            findings += check_remesh(
                label, sigs, what="local contraction extents")
            checked.append(label)

            # ---- DistributedICR.apply_sqrt (abstract-eval only)
            label = f"dist_icr:{name}"
            mats_s = jax.eval_shape(
                lambda: icr.matrices(None, joint=True, axes=False))
            sigs = {}
            for k in ring_sizes:
                dist = DistributedICR(icr=icr,
                                      mesh=_mesh(devices, k, "ring"),
                                      axis_names=("ring",))
                xi_s = [jax.ShapeDtypeStruct(s, jnp.float32)
                        for s in dist.xi_structure()]
                jx = jax.make_jaxpr(dist.apply_sqrt)(mats_s, xi_s)
                if k == ring_sizes[0]:
                    findings += analyze_jaxpr(jx, entry=label,
                                              replay_sensitive=False)
                sigs[k] = local_dot_signatures(jx, contract_only=True)
            findings += check_remesh(
                label, sigs, what="local contraction extents")
            checked.append(label)

        # ---- PCG conditioning matvec: RHS-sharded over the mesh
        label = f"pcg_matvec:{name}"
        n_pix = int(np.prod(icr.chart.final_shape))
        op = obs_operator(icr, obs_idx=np.arange(0, n_pix, 2))
        mats = icr.matrices_cached(None)
        rows = max(4, sizes[0])
        v_s = jax.ShapeDtypeStruct((rows, op.n_obs), jnp.float32)
        sigs = {}
        for k in sizes:
            sys_k = build_condition_system(
                icr, op, 0.05 ** 2, mats=mats, mesh=_mesh(devices, k),
                use_precond=False)
            jx = jax.make_jaxpr(sys_k.matvec)(v_s)
            if k == sizes[0]:
                findings += analyze_jaxpr(jx, entry=label,
                                          replay_sensitive=False)
            sigs[k] = local_dot_signatures(jx, contract_only=True)
        findings += check_remesh(label, sigs,
                                 what="local contraction extents")
        checked.append(label)

    # ---- cache-key soundness (pins its own backend per variant)
    findings += cachekey_audit(name, quick=quick, slab=slab,
                               mesh=_mesh(devices, sizes[0]),
                               backend=backend)
    findings += plan_key_audit(name, quick=quick)
    checked.append(f"cachekey:{name}")
    return findings


def shardcheck_all(names=SERVING_SCENARIOS, *, quick: bool = True,
                   slab: int = 4, devices=None,
                   checked: list | None = None) -> list:
    """The full shardcheck sweep (the CI ``static-analysis`` step)."""
    findings = []
    for name in names:
        findings += shardcheck_scenario(name, quick=quick, slab=slab,
                                        devices=devices, checked=checked)
    return findings
