"""The fingerprint scenario matrix and its lowered entry points.

A :class:`Scenario` is one cell of the serving matrix — a chart workload
(``tod``/``image``/``dust``, the `launch.serve_gp` scenarios) crossed
with a storage dtype (``fp32``/``bf16``). For each cell this module
builds the hot entry points as **lowerings** (no execution, shapes only
via ``jax.eval_shape`` where possible):

  ``apply_sqrt``            one forward field draw (contains the pyramid
                            launch when the cover fires — the plan
                            signature records the coverage explicitly)
  ``apply_sqrt_vjp``        its fixed-matrices gradient w.r.t. ξ — the
                            inference hot path (paper §1: two sqrt
                            applications + the VJP)
  ``apply_sqrt_batch``      the native sample-slab forward (§10)
  ``apply_sqrt_batch_vjp``  its ξ-gradient
  ``condition_matvec``      the §16 data-conditioning CG hot loop body:
                            (W K Wᵀ + σ²I) v on a batch of RHS vectors
                            (two sqrt applications per iteration)
  ``serve_slab``            the §12 serving slab step through a real
                            ``GPFieldServer`` (draw + refine + f32 cast),
                            plus the executable-cache key fingerprint

Lowering runs under :func:`pinned_backend` (default ``interpret``) so the
kernels' BlockSpec structure lands in the HLO deterministically,
independent of the ambient ``REPRO_BACKEND``/platform default. The
regression knobs (``use_pallas``/``use_pyramid``/``policy``/``backend``)
exist so the self-tests can inject exactly the failures the fingerprints
are meant to catch: a level forced to the jnp reference, a disabled
pyramid cover, a bf16 policy silently dropped to f32.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os

import jax
import jax.numpy as jnp

_UNSET = object()

# entry points every scenario lowers and fingerprints (module doc above)
ENTRY_POINTS = ("apply_sqrt", "apply_sqrt_vjp", "apply_sqrt_batch",
                "apply_sqrt_batch_vjp", "condition_matvec", "serve_slab")


@contextlib.contextmanager
def pinned_backend(backend: str | None):
    """Pin ``dispatch.select_backend()``'s runtime answer for the scope.

    ``None`` removes the override (the platform default). Fingerprints
    must not depend on the caller's environment, so every lowering in
    this module runs inside this context.
    """
    old = os.environ.get("REPRO_BACKEND")
    try:
        if backend is None:
            os.environ.pop("REPRO_BACKEND", None)
        else:
            os.environ["REPRO_BACKEND"] = backend
        yield
    finally:
        if old is None:
            os.environ.pop("REPRO_BACKEND", None)
        else:
            os.environ["REPRO_BACKEND"] = old


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the fingerprint matrix: chart workload × storage dtype.

    ``samples`` is the slab/batch height of the batched and serving entry
    points; ``quick`` picks the reduced CI chart geometries (the same ones
    ``launch.serve_gp --quick`` serves).
    """

    name: str              # tod | image | dust
    dtype: str             # fp32 | bf16
    quick: bool = True
    samples: int = 4

    @property
    def label(self) -> str:
        return f"{self.name}-{self.dtype}"

    @property
    def policy(self):
        """The ICR ``dtype_policy`` argument for this cell."""
        return None if self.dtype == "fp32" else "bf16"

    @property
    def rho(self) -> float:
        from repro.launch.serve_gp import SCENARIOS as RHO

        return RHO[self.name]

    def chart(self):
        from repro.launch.serve_gp import scenario_chart

        return scenario_chart(self.name, quick=self.quick)

    def icr(self, *, use_pallas: bool = True, use_pyramid: bool = True,
            policy=_UNSET):
        from repro.core import ICR, matern32

        return ICR(
            chart=self.chart(),
            kernel=matern32.with_defaults(rho=self.rho),
            use_pallas=use_pallas,
            use_pyramid=use_pyramid,
            dtype_policy=self.policy if policy is _UNSET else policy,
        )


def SCENARIOS(quick: bool = True, samples: int = 4) -> list:
    """The full matrix: tod/image/dust × fp32/bf16 (six cells)."""
    return [
        Scenario(name=n, dtype=d, quick=quick, samples=samples)
        for n in ("tod", "image", "dust")
        for d in ("fp32", "bf16")
    ]


def _xi_struct(icr, batch=None):
    return jax.eval_shape(lambda: icr.init_xi(jax.random.PRNGKey(0),
                                              batch=batch))


def lower_entries(scn: Scenario, *, backend: str = "interpret",
                  use_pallas: bool = True, use_pyramid: bool = True,
                  policy=_UNSET) -> dict:
    """Lower every entry point of `scn`; returns
    ``{entry: jax.stages.Lowered}`` plus ``"_serving"`` (the server's
    cache-key fingerprint dict, riding along for the scenario document).

    The ICR entries lower from ``jax.eval_shape`` structs — no matrices
    are computed. The serving entry builds a real (tiny) server because
    the slab executable is created inside ``GPFieldServer._build``; its
    matrices are the only concrete work here.
    """
    icr = scn.icr(use_pallas=use_pallas, use_pyramid=use_pyramid,
                  policy=policy)
    mats_s = jax.eval_shape(icr.matrices)
    xi_s = _xi_struct(icr)
    xib_s = _xi_struct(icr, batch=scn.samples)

    def loss(mats, xi):
        s = icr.apply_sqrt(mats, xi)
        return 0.5 * jnp.sum(jnp.square(s.astype(jnp.float32)))

    def loss_batch(mats, xi):
        s = icr.apply_sqrt_batch(mats, xi)
        return 0.5 * jnp.sum(jnp.square(s.astype(jnp.float32)))

    out = {}
    with pinned_backend(backend):
        out["apply_sqrt"] = jax.jit(icr.apply_sqrt).lower(mats_s, xi_s)
        out["apply_sqrt_vjp"] = jax.jit(
            jax.grad(loss, argnums=1)).lower(mats_s, xi_s)
        out["apply_sqrt_batch"] = jax.jit(
            icr.apply_sqrt_batch).lower(mats_s, xib_s)
        out["apply_sqrt_batch_vjp"] = jax.jit(
            jax.grad(loss_batch, argnums=1)).lower(mats_s, xib_s)

        # §16 conditioning matvec: observe every other finest-grid pixel
        from repro.solvers.gp_system import condition_matvec, obs_operator

        import numpy as np

        n_pix = int(np.prod(icr.chart.final_shape))
        op = obs_operator(icr, obs_idx=np.arange(0, n_pix, 2))
        v_s = jax.ShapeDtypeStruct((scn.samples, op.n_obs), jnp.float32)
        out["condition_matvec"] = jax.jit(
            lambda mats, v: condition_matvec(icr, mats, op, 0.05 ** 2, v)
        ).lower(mats_s, v_s)

        from repro.core.vi import Posterior
        from repro.launch.serve_gp import GPFieldServer

        mean = icr.init_xi(jax.random.PRNGKey(0), dtype=jnp.float32)
        log_std = [jnp.full_like(m, -1.5) for m in mean]
        srv = GPFieldServer(Posterior(icr=icr, mean=mean, log_std=log_std),
                            slab=scn.samples)
        out["serve_slab"] = srv.lowered_slab()
        out["_serving"] = srv.cache_key_fingerprint()
    return out
