"""The one dtype -> byte-width table for the whole repo (DESIGN.md §13).

Three subsystems account bytes per element and historically each carried
its own table: ``kernels.policy`` (storage itemsize for VMEM autotuning),
``roofline.level_traffic`` (the analytic HBM-traffic model) and
``roofline.hlo_cost`` (parsing dtypes out of HLO text, where the names are
the short XLA spellings ``f32``/``bf16``/``s8``/...). A dtype added to one
table but not the others silently desynchronizes the byte columns that
``dispatch.plan()`` and the benchmark JSON report, so all three now
resolve through this module.

Two name spaces meet here:

  * **framework names** — anything ``jnp.dtype`` accepts: numpy dtypes,
    ``"float32"``, ``"bfloat16"``, the ml_dtypes fp8 types, jnp scalar
    types. Resolved by :func:`itemsize` / :func:`canonical_name`.
  * **HLO short names** — what post-optimization HLO text spells:
    ``f32``, ``bf16``, ``s8``, ``f8e4m3fn``, ... Resolved by
    :data:`HLO_DTYPE_BYTES` (and mapped back from framework names by
    :func:`hlo_name`).

The fp8 rows (``f8e4m3fn``/``f8e5m2`` — 1 byte) are present ahead of the
int8/fp8 quantized-matrix PR (ROADMAP) so the traffic model, the VMEM
autotuners and the HLO parsers pick the new itemsize up from one place.
"""
from __future__ import annotations

import numpy as np

__all__ = ["HLO_DTYPE_BYTES", "itemsize", "canonical_name", "hlo_name"]

# HLO/XLA short spelling -> bytes per element. This is the table
# roofline.hlo_cost parses compiled modules with; "token"/"opaque" are
# zero-width pseudo-types (control deps, custom-call handles).
HLO_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}

# framework canonical name -> HLO short spelling (the reverse direction:
# np/ml_dtypes names as jnp.dtype(...).name reports them)
_CANONICAL_TO_HLO = {
    "bool": "pred",
    "int4": "s4", "uint4": "u4",
    "int8": "s8", "uint8": "u8",
    "int16": "s16", "uint16": "u16",
    "int32": "s32", "uint32": "u32",
    "int64": "s64", "uint64": "u64",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
    "float8_e4m3b11fnuz": "f8e4m3b11fnuz",
    "float8_e4m3fnuz": "f8e4m3fnuz", "float8_e5m2fnuz": "f8e5m2fnuz",
    "bfloat16": "bf16", "float16": "f16",
    "float32": "f32", "float64": "f64",
    "complex64": "c64", "complex128": "c128",
}


def _resolve(dtype) -> np.dtype:
    """``np.dtype`` over the extended (ml_dtypes) name space: bfloat16 and
    the fp8 types resolve because jax imports ml_dtypes, which registers
    them with numpy."""
    if isinstance(dtype, str) and dtype in HLO_DTYPE_BYTES:
        # accept the HLO spelling too: callers fingerprinting parsed HLO
        # shouldn't need to translate before asking for a width
        for canon, short in _CANONICAL_TO_HLO.items():
            if short == dtype:
                return np.dtype(canon)
        raise TypeError(f"HLO pseudo-type {dtype!r} has no framework dtype")
    return np.dtype(dtype)


def itemsize(dtype) -> int:
    """Bytes per element of `dtype` (framework or HLO spelling)."""
    if isinstance(dtype, str) and dtype in HLO_DTYPE_BYTES:
        return HLO_DTYPE_BYTES[dtype]
    return _resolve(dtype).itemsize


def canonical_name(dtype) -> str:
    """The framework canonical name (``jnp.dtype(...).name`` spelling)."""
    return _resolve(dtype).name


def hlo_name(dtype) -> str:
    """The HLO short spelling of `dtype` (``float32`` -> ``f32``)."""
    if isinstance(dtype, str) and dtype in HLO_DTYPE_BYTES:
        return dtype
    name = canonical_name(dtype)
    try:
        return _CANONICAL_TO_HLO[name]
    except KeyError:
        raise ValueError(f"no HLO spelling known for dtype {name!r}") \
            from None
