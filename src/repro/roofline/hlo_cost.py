"""Loop-aware cost model over post-optimization HLO text.

Why: ``compiled.cost_analysis()`` visits every computation ONCE — a lax.scan
body (While op) with trip count N contributes 1/N of its true flops/bytes,
and the same undercounting hits hand-parsed collective bytes. Since every
model here scans over layer groups / microbatches / sequence chunks, the
uncorrected numbers are off by 5–60x.

This parser:
  * splits the module into computations and builds a per-computation symbol
    table (%name -> shape) so operand sizes are known;
  * walks the while-op call graph and multiplies each computation's costs by
    the product of enclosing ``known_trip_count`` annotations (XLA emits
    them for counted loops, which all lax.scans are);
  * models per-op HBM traffic as (operand bytes + output bytes) of each
    *top-level* op — fusion internals are free, which matches how fused
    elementwise chains behave on real hardware;
  * counts MXU flops for dot/convolution via dimension_numbers;
  * accumulates collective payload bytes with the same ring-traffic
    semantics as analysis.collective_bytes.

It is a *cost model*, not ground truth — but it is consistent, loop-aware,
and good enough to rank optimizations (EXPERIMENTS.md §Roofline uses it for
all three terms).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Optional, Tuple

from repro.dtypes import HLO_DTYPE_BYTES as _DTYPE_BYTES

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
# the op kind is the first WORD( after the (possibly tuple) output type;
# tuple types contain /*index=N*/ comments, layouts {2,1,0} etc., but never
# "word(" sequences
_KIND_RE = re.compile(r"(?:^|[\]\}\)a-z0-9_]\s+)"
                      r"([a-z][a-z0-9\-]*(?:\.\d+)?)\(")


def _split_def(s: str):
    """Return (name, out_type, kind, rest_after_kind) or None."""
    m = _ASSIGN_RE.match(s)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    k = _KIND_RE.search(rest)
    if not k:
        return None
    return name, rest[: k.start(1)], k.group(1), rest[k.end(1):]
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*(?:\([^)]*\))?\s*->")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_TRIP_RE = re.compile(r"known_trip_count[\\\"':{ ]+n[\\\"': ]+(\d+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CC_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

# custom-call targets that are Pallas kernel launches: the TPU Mosaic
# custom call and the GPU Triton spellings. These move real HBM traffic
# (exactly the operand+output bytes — the kernel reads/writes each Blocked
# operand once per element) and must not be treated as zero-byte opaque
# ops the way unknown custom calls are.
PALLAS_TARGETS = ("tpu_custom_call", "mosaic", "triton_kernel_call",
                  "__gpu$xla.gpu.triton")


def is_pallas_target(target: str) -> bool:
    t = target.lower()
    return any(p in t for p in PALLAS_TARGETS)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _type_dims(type_str: str) -> list:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _type_width(type_str: str) -> int:
    """Byte width of the (first) element type in `type_str` (4 if none)."""
    m = _SHAPE_RE.search(type_str)
    return _DTYPE_BYTES.get(m.group(1), 4) if m else 4


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    out_type: str
    line: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    whiles: list = dataclasses.field(default_factory=list)  # (body, trips)
    calls: list = dataclasses.field(default_factory=list)   # fusion callees
    cc_counts: dict = dataclasses.field(      # custom-call target -> count
        default_factory=lambda: defaultdict(int))
    cc_bytes: dict = dataclasses.field(       # custom-call target -> bytes
        default_factory=lambda: defaultdict(float))


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def _dot_flops(line: str, out_dims: list, symbols: dict) -> float:
    ops = _OPERAND_RE.findall(line.split("(", 1)[1])
    if not ops:
        return 0.0
    lhs = symbols.get(ops[0])
    if lhs is None:
        return 0.0
    lhs = lhs[0]  # (dims, width) -> dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            idx = int(d)
            if idx < len(lhs):
                contract *= lhs[idx]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * contract


def parse_module(hlo: str) -> Tuple[Dict[str, CompCost], Optional[str]]:
    comps: Dict[str, CompCost] = {}
    current: Optional[str] = None
    entry: Optional[str] = None
    symbols: dict = {}
    in_header = False
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        # computation declarations start at column 0 (instructions are
        # indented); headers may span many lines before the opening "{"
        if line.startswith("ENTRY ") or (line.startswith("%")
                                         and not in_header):
            m = re.match(r"(?:ENTRY\s+)?(%[\w.\-]+)", s)
            if m:
                current = m.group(1)
                comps[current] = CompCost()
                symbols = {}
                if line.startswith("ENTRY "):
                    entry = current
                in_header = not s.endswith("{")
            continue
        if in_header:
            if s.endswith("{"):
                in_header = False
            continue
        if current is None:
            continue
        if s == "}":
            current = None
            continue
        d = _split_def(s)
        if d is None:
            continue
        name, out_type, kind, _after = d
        kind_base = re.sub(r"\.\d+$", "", kind)
        out_width = _type_width(out_type)
        symbols[name] = (_type_dims(out_type), out_width)
        cc = comps[current]
        out_bytes = _type_bytes(out_type)
        # HBM traffic: operands + output (fusion internals are free).
        # Operand widths come from the defining op's element type, so a
        # bf16 operand of an f32-accumulating op counts 2 bytes, not 4.
        operand_names = _OPERAND_RE.findall(s.split("(", 1)[1])
        op_bytes = 0
        for on in operand_names:
            sym = symbols.get(on)
            if sym is not None:
                dims, width = sym
                n = 1
                for d in dims:
                    n *= d
                op_bytes += n * width
        if kind_base == "custom-call":
            # Pallas kernel launches (tpu_custom_call / Mosaic / Triton)
            # move exactly their operand+output bytes through HBM — the
            # traffic plan() models. Unknown targets stay opaque (0 bytes)
            # but are inventoried either way, so a fingerprint sees every
            # custom call and the byte model sees the Pallas ones.
            m = _CC_TARGET_RE.search(s)
            target = m.group(1) if m else "<unknown>"
            cc.cc_counts[target] += 1
            if is_pallas_target(target):
                cc.bytes += out_bytes + op_bytes
                cc.cc_bytes[target] += out_bytes + op_bytes
        elif kind_base in ("dynamic-slice",) or "dynamic-slice" in name:
            # reads only the slice (operand = whole scan stack otherwise)
            cc.bytes += 2 * out_bytes
        elif kind_base == "dynamic-update-slice" or \
                "dynamic-update-slice" in name:
            # in-place slice write (XLA aliases the big buffer): traffic =
            # r/w of the update slice, not the whole stacked carry
            sizes = []
            for on in operand_names:
                sym = symbols.get(on)
                if sym is not None:
                    n = 1
                    for d in sym[0]:
                        n *= d
                    sizes.append(n)
            if sizes:
                cc.bytes += 2 * (sum(sizes) - max(sizes)) * out_width
        elif kind_base not in ("parameter", "constant", "tuple",
                               "get-tuple-element", "bitcast", "while",
                               "conditional", "call", "after-all"):
            cc.bytes += out_bytes + op_bytes

        if kind_base in ("dot", "convolution"):
            cc.flops += _dot_flops(s, _type_dims(out_type), symbols)
        elif kind_base == "while":
            body = _BODY_RE.search(s)
            trips = _TRIP_RE.search(s)
            n = int(trips.group(1)) if trips else 1
            if body:
                cc.whiles.append((body.group(1), n))
            cond = _COND_RE.search(s)
            if cond:
                cc.calls.append(cond.group(1))
        else:
            base = kind_base.replace("-start", "")
            if base in COLLECTIVES and not kind_base.endswith("-done"):
                b = out_bytes
                g = _group_size(s)
                if base == "all-reduce":
                    b *= 2
                elif base == "reduce-scatter":
                    b *= g
                cc.coll_bytes += b
                cc.coll_by_kind[base] += b
                cc.coll_counts[base] += 1
    return comps, entry


def module_costs(hlo: str, default_trip: int = 1) -> dict:
    """Loop-aware totals: flops, bytes, collective bytes/kind/counts.

    Only computations reachable from ENTRY via While bodies are counted —
    fusion/reducer computations contribute through their callers' op-level
    operand/output bytes (fusion internals are free by design).
    """
    comps, entry = parse_module(hlo)
    mult: Dict[str, float] = defaultdict(float)
    stack = [(entry, 1.0)] if entry else []
    while stack:
        name, m = stack.pop()
        if name not in comps:
            continue
        mult[name] += m
        for body, trips in comps[name].whiles:
            stack.append((body, m * max(trips, default_trip)))

    tot = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0,
           "coll_by_kind": defaultdict(float),
           "coll_counts": defaultdict(float),
           "custom_calls": defaultdict(lambda: {"count": 0.0, "bytes": 0.0})}
    for name, cc in comps.items():
        m = mult[name]
        if m == 0.0:
            continue
        tot["flops"] += m * cc.flops
        tot["bytes"] += m * cc.bytes
        tot["coll_bytes"] += m * cc.coll_bytes
        for k, v in cc.coll_by_kind.items():
            tot["coll_by_kind"][k] += m * v
        for k, v in cc.coll_counts.items():
            tot["coll_counts"][k] += m * v
        for k, v in cc.cc_counts.items():
            tot["custom_calls"][k]["count"] += m * v
            tot["custom_calls"][k]["bytes"] += m * cc.cc_bytes.get(k, 0.0)
    tot["coll_by_kind"] = dict(tot["coll_by_kind"])
    tot["coll_counts"] = {k: int(v) for k, v in tot["coll_counts"].items()}
    tot["custom_calls"] = {
        k: {"count": int(v["count"]), "bytes": v["bytes"],
            "pallas": is_pallas_target(k)}
        for k, v in tot["custom_calls"].items()
    }
    return tot
