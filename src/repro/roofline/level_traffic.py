"""Analytic HBM-traffic model for one ICR refinement level, per route.

This is the single source of truth for the per-level byte estimates that
``kernels.dispatch.plan()`` reports and that the benchmark JSON carries
(bandwidth-utilization column). The numbers are *model* estimates from the
level geometry alone — no arrays, no compiled HLO — mirroring how the
kernels actually move data:

  ``stationary-1d`` / ``charted-1d`` / ``nd-fused``
      read L (+ boundary/tile padding) + read ξ + write N + matrices —
      one launch, the minimal traffic (DESIGN.md §2/§10). The fused N-D
      halo re-read (q_max/b_f of the coarse tile) is below the model's
      resolution and ignored.

  ``pyramid``
      the VMEM-resident multi-level launch (DESIGN.md §11): a covered
      level reads its ξ and matrices only; the coarse field is read from
      HBM by the FIRST covered level alone (``first=True``) and the fine
      field written by the LAST alone (``last=True``) — inter-level field
      traffic inside the covered prefix is zero by construction.

  ``nd-axes``
      one launch per axis: each pass reads its input field and writes its
      output at mixed resolution, ξ is read by the final (axis-0) pass only
      (the noise=False mode killed the zero-ξ reads of the other passes),
      and every pass whose axis is not already minor pays a relayout —
      XLA materializes a contiguous transpose around the kernel call, a
      read+write of the field on each side.

  ``reference``
      the joint jnp einsum path: the (T, n_csz^d) window tensor is
      materialized in HBM (write + read) on top of the field read.

Matrix bytes are counted once per level (they are fetched per grid step on
chip but stay VMEM-resident across the sample slab — the batched-serving
amortization); with ``samples > 1`` every field/ξ term scales with the
sample count while the matrix term does not.

Byte accounting is **dtype-aware** (DESIGN.md §11): pass ``dtype`` (the
policy's storage dtype) and every term scales with its itemsize — the
``"dtype"`` key in the returned breakdown is the dtype column the
benchmark JSON and ``plan()`` carry. ``itemsize`` remains accepted for
callers that sized things by hand (the dtype column then reports the raw
byte width); passing both with conflicting widths is an error, so a row
can never carry a dtype label that disagrees with its numbers.
"""
from __future__ import annotations

import numpy as np

from repro.dtypes import canonical_name, itemsize as dtype_itemsize

__all__ = ["refine_level_traffic"]


def _prod(xs) -> int:
    xs = list(xs)
    return int(np.prod(xs)) if xs else 1


def _padded_extent(geom, a: int) -> int:
    """Coarse extent along axis ``a`` as the kernels see it: reflect adds
    ``b`` per side; the fused tile rounds up to ``(T_a + q_max)·s``."""
    n = geom.coarse_shape[a]
    if geom.boundary == "reflect":
        n += 2 * geom.b
    s = max(1, geom.n_fsz // 2)
    q_max = (geom.n_csz - 1) // s
    return max(n, (geom.T[a] + q_max) * s)


def _axis_mat_bytes(geom, itemsize: int) -> int:
    """Per-axis Kronecker factors (R_a, sqrtD_a)."""
    f, c = geom.n_fsz, geom.n_csz
    per = f * c + f * f
    return itemsize * sum(
        (geom.T[a] if geom.kept_T[a] > 1 else 1) * per
        for a in range(len(geom.coarse_shape))
    )


def _joint_mat_bytes(geom, itemsize: int) -> int:
    nd = len(geom.coarse_shape)
    f, c = geom.n_fsz**nd, geom.n_csz**nd
    return itemsize * _prod(geom.kept_T) * (f * c + f * f)


def refine_level_traffic(geom, route: str, *, itemsize: int | None = None,
                         dtype=None, samples: int = 1,
                         first: bool = True, last: bool = True) -> dict:
    """Estimated HBM bytes moved by one refinement level on ``route``.

    Returns a breakdown dict with a ``"total"`` key and a ``"dtype"``
    column. Field/ξ terms scale with ``samples``; matrices are counted once
    (see module docstring). ``dtype`` sets the storage itemsize (default
    float32); ``first``/``last`` only affect the ``"pyramid"`` route — a
    covered level's position in the VMEM-resident prefix.
    """
    if dtype is not None:
        # the shared table (repro.dtypes) resolves HLO spellings and the
        # fp8 types the same way the VMEM autotuners and HLO parsers do
        width, dtype_name = dtype_itemsize(dtype), canonical_name(dtype)
        if itemsize is not None and itemsize != width:
            raise ValueError(
                f"conflicting byte width: itemsize={itemsize} vs "
                f"dtype={dtype_name} ({width} bytes)"
            )
        itemsize = width
    elif itemsize is not None:
        dtype_name = f"{itemsize}-byte"  # hand-sized caller: honest label
    else:
        itemsize, dtype_name = 4, "float32"
    nd = len(geom.coarse_shape)
    fsz = geom.n_fsz
    n_out = _prod(geom.fine_shape)
    xi_elems = _prod(geom.T) * fsz**nd

    if route == "pyramid":
        field_read = (_prod(_padded_extent(geom, a) for a in range(nd))
                      if first else 0)
        out = {
            "field_read": samples * itemsize * field_read,
            "xi_read": samples * itemsize * xi_elems,
            "fine_write": samples * itemsize * (n_out if last else 0),
            "matrices": _axis_mat_bytes(geom, itemsize),
            "relayout": 0,
        }
    elif route in ("stationary-1d", "charted-1d", "nd-fused"):
        field_read = _prod(_padded_extent(geom, a) for a in range(nd))
        out = {
            "field_read": samples * itemsize * field_read,
            "xi_read": samples * itemsize * xi_elems,
            "fine_write": samples * itemsize * n_out,
            "matrices": _axis_mat_bytes(geom, itemsize),
            "relayout": 0,
        }
    elif route == "nd-axes":
        extents = list(geom.coarse_shape)
        kernel_bytes = 0
        relayout = 0
        for a in range(nd - 1, -1, -1):
            in_pad = list(extents)
            if geom.boundary == "reflect":
                in_pad[a] += 2 * geom.b
            n_in = _prod(extents)
            out_extents = list(extents)
            out_extents[a] = geom.T[a] * fsz
            n_pass_out = _prod(out_extents)
            kernel_bytes += _prod(in_pad) + n_pass_out
            if a == 0:
                kernel_bytes += xi_elems  # the only ξ read (noise=False mode)
            if a != nd - 1:
                # moveaxis relayout around the launch: read+write the field
                # on the way in and on the way out
                relayout += 2 * n_in + 2 * n_pass_out
            extents = out_extents
        out = {
            "field_read": samples * itemsize * kernel_bytes,
            "xi_read": 0,  # folded into field_read per pass above
            "fine_write": 0,
            "matrices": _axis_mat_bytes(geom, itemsize),
            "relayout": samples * itemsize * relayout,
        }
    elif route == "reference":
        n_in = _prod(_padded_extent(geom, a) for a in range(nd))
        win = _prod(geom.T) * geom.n_csz**nd
        out = {
            "field_read": samples * itemsize * (n_in + 2 * win),
            "xi_read": samples * itemsize * xi_elems,
            "fine_write": samples * itemsize * n_out,
            "matrices": _joint_mat_bytes(geom, itemsize),
            "relayout": 0,
        }
    else:
        raise ValueError(f"unknown route {route!r}")

    out["total"] = sum(out.values())
    out["dtype"] = dtype_name
    return out
