"""Roofline terms from a compiled (dry-run) executable.

Three terms, per device (equivalently: global / chips — the assignment's
formulae divide the global totals by chip count, which cancels because the
post-SPMD HLO module is already the per-device program):

  compute    = HLO_FLOPs / peak_FLOPs          [cost_analysis 'flops']
  memory     = HLO_bytes / HBM_bw              [cost_analysis 'bytes accessed']
  collective = collective_bytes / link_bw      [parsed from HLO text]

collective_bytes is NOT in cost_analysis: we parse the post-partitioning
HLO and sum operand sizes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute (ragged variants included). Shapes in the
SPMD module are local, so the sum is per-device traffic. all-reduce operands
are counted twice (reduce-scatter + all-gather phases of a ring).

The dominant term approximates the step's lower-bound time on the target
(TPU v5e constants in launch/mesh.py); the ratio MODEL_FLOPS/HLO_FLOPs
separates "useful" model math from remat/dispatch overhead.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.launch import mesh as hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

# f32[128,1024] / bf16[8]{0} / pred[] — first group dtype, second dims
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)
# multiplier: ring all-reduce moves ~2x the payload (RS + AG phases)
_COLL_FACTOR = {"all-reduce": 2.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form: [n_groups, group_size]<=[...]
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:  # explicit form: {{0,1,2,3},{...}} — size of the first group
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic from (post-SPMD) HLO text.

    Post-SPMD HLO prints operands without types, so operand sizes are
    reconstructed from the printed OUTPUT shape + op semantics + group size
    (ring traffic, up to the (g-1)/g factor):
      all-gather          out                (received payload = full array)
      reduce-scatter      out * g            (contributed payload = input)
      all-reduce          2 * out            (reduce-scatter + all-gather)
      all-to-all          out                (send == recv == array)
      collective-permute  out
    Async -start/-done pairs are counted once (at -start).
    """
    out: dict = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(
            r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([a-z\-]+?)(-start)?"
            r"(\.\d+)?\(", s)
        if not m:
            continue
        kind = m.group(2)
        if kind.endswith("-done") or kind not in _COLLECTIVES:
            continue
        shapes = _SHAPE_RE.findall(m.group(1))
        out_bytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        g = _group_size(s)
        if kind == "all-reduce":
            out_bytes *= 2
        elif kind == "reduce-scatter":
            out_bytes *= g
        out[kind] += int(out_bytes)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def collective_counts(hlo_text: str) -> dict:
    out: dict = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = re.match(
            r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*.+?\s+([a-z\-]+?)(-start)?"
            r"(\.\d+)?\(", line.strip())
        if m and m.group(1) in _COLLECTIVES:
            out[m.group(1)] += 1
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops (loop-aware)
    bytes_accessed: float        # per-device HBM traffic (loop-aware model)
    coll_bytes: float            # per-device collective traffic
    coll_breakdown: dict
    coll_counts: dict
    memory_per_device: Optional[dict] = None
    model_flops: float = 0.0     # 6·N·D (or 6·N_active·D) useful flops/device
    raw_xla: Optional[dict] = None  # uncorrected cost_analysis numbers

    @property
    def t_compute(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / hw.ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def flops_utilization(self) -> float:
        """Fraction of the roofline-bound step spent on useful model math
        (MODEL_FLOPS at peak): the dry-run analogue of MFU."""
        if self.bound_time == 0:
            return 0.0
        return (self.model_flops / hw.PEAK_FLOPS_BF16) / self.bound_time

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def summary(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "bound_time_s": self.bound_time,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.flops_utilization,
            "coll_breakdown": {k: v for k, v in
                               self.coll_breakdown.items() if v},
            "coll_counts": {k: v for k, v in self.coll_counts.items() if v},
            "memory_per_device": self.memory_per_device,
            "raw_xla": self.raw_xla,
        }


def analyze_compiled(compiled, model_flops_per_device: float = 0.0
                     ) -> RooflineTerms:
    """Extract the three roofline terms from a jax Compiled object.

    Primary numbers come from the LOOP-AWARE HLO cost model (hlo_cost.py):
    ``compiled.cost_analysis()`` visits While bodies once, undercounting
    scanned programs by the trip count (5-60x here). The raw XLA numbers
    are kept in the summary for reference.
    """
    from .hlo_cost import module_costs

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    text = compiled.as_text()
    la = module_costs(text)
    flops = float(la["flops"])
    bytes_accessed = float(la["bytes"])
    coll = dict(la["coll_by_kind"])
    coll["total"] = float(la["coll_bytes"])
    counts = la["coll_counts"]
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
                "generated_code_bytes": int(
                    getattr(ma, "generated_code_size_in_bytes", 0)),
            }
            # donated buffers alias input<->output (cache/params): counting
            # both sides would double-book them
            mem["total_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                                  + mem["temp_bytes"] - mem["alias_bytes"])
            mem["fits_hbm"] = mem["total_bytes"] <= hw.HBM_BYTES
    except Exception:
        pass
    terms = RooflineTerms(
        flops=flops, bytes_accessed=bytes_accessed,
        coll_bytes=float(coll["total"]), coll_breakdown=coll,
        coll_counts=counts, memory_per_device=mem,
        model_flops=model_flops_per_device,
    )
    terms.raw_xla = {"flops": float(cost.get("flops", 0.0)),
                     "bytes": float(cost.get("bytes accessed", 0.0))}
    return terms


def model_flops_train(n_params_active: int, tokens: int) -> float:
    """6·N·D (fwd 2ND + bwd 4ND) — global; divide by chips for per-device."""
    return 6.0 * n_params_active * tokens


def model_flops_decode(n_params_active: int, tokens: int) -> float:
    """2·N per generated token (fwd only)."""
    return 2.0 * n_params_active * tokens
