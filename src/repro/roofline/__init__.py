from .analysis import RooflineTerms, analyze_compiled, collective_bytes

__all__ = ["RooflineTerms", "analyze_compiled", "collective_bytes"]
