from .analysis import RooflineTerms, analyze_compiled, collective_bytes
from .level_traffic import refine_level_traffic

__all__ = ["RooflineTerms", "analyze_compiled", "collective_bytes",
           "refine_level_traffic"]
