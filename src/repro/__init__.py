"""repro — Sparse Kernel Gaussian Processes through Iterative Charted
Refinement (ICR), as a production multi-pod JAX framework.

  repro.core        — the paper (O(N) generative GP sampling + DistributedICR)
  repro.kernels     — Pallas TPU kernels for the refinement hot-spot
  repro.models      — the 10 assigned LM-family architectures
  repro.configs     — --arch registry (exact configs + reduced smoke variants)
  repro.distributed — FSDP x TP sharding rules, compression, elastic, fault
  repro.launch      — production meshes, multi-pod dry-run, train/serve
  repro.roofline    — loop-aware HLO cost model -> 3-term roofline

See README.md / DESIGN.md / EXPERIMENTS.md.
"""
__version__ = "1.0.0"
