"""Version compatibility shims for the pinned jax (0.4.x) vs newer APIs.

The repo targets the container's jax (currently 0.4.37) but tracks API names
from newer releases. Everything version-dependent is resolved HERE, once, so
call sites never touch ``jax.experimental`` or try/except imports themselves:

  shard_map   — ``jax.shard_map`` (>= 0.6) or ``jax.experimental.shard_map``
                (0.4.x, where ``check_vma`` is spelled ``check_rep``).
  make_mesh   — ``jax.make_mesh``; passes ``axis_types`` only when the
                installed jax has ``jax.sharding.AxisType``.
  use_mesh    — ``jax.set_mesh`` / ``jax.sharding.use_mesh`` context manager,
                falling back to the legacy ``with mesh:`` context on 0.4.x.

tests/test_compat.py asserts the whole public API imports cleanly against the
pinned jax.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax

# -- shard_map -----------------------------------------------------------------
_NEW_SHARD_MAP = getattr(jax, "shard_map", None)
if _NEW_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _LEGACY_SHARD_MAP
else:
    _LEGACY_SHARD_MAP = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              **kwargs):
    """``jax.shard_map`` with the modern keyword signature on any jax.

    On 0.4.x this resolves to ``jax.experimental.shard_map.shard_map`` and the
    ``check_vma`` flag is translated to its old name ``check_rep``.
    """
    if _NEW_SHARD_MAP is not None:
        return _NEW_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma,
                              **kwargs)
    return _LEGACY_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma,
                             **kwargs)


def axis_size(axis_name):
    """``jax.lax.axis_size`` (>= 0.6); on 0.4.x, ``psum(1, name)``.

    Only valid inside shard_map/pmap-style contexts, like the original.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# -- meshes --------------------------------------------------------------------
AxisType = getattr(jax.sharding, "AxisType", None)


def make_mesh(axis_shapes, axis_names, *, axis_types: Any = None,
              devices=None):
    """``jax.make_mesh`` that only forwards ``axis_types`` when supported.

    On jax 0.4.x there is no ``AxisType`` (all axes behave as the later
    "auto" type inside shard_map), so the argument is dropped.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if AxisType is not None:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(tuple(axis_names))
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def use_mesh(mesh):
    """Context manager making `mesh` the ambient mesh, on any jax version."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    # 0.4.x: Mesh is itself a context manager; shard_map/NamedSharding in this
    # repo always receive the mesh explicitly, so this is belt-and-braces.
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)
