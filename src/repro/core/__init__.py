"""ICR — the paper's primary contribution (generative O(N) GP sampling).

Public API:
  Chart / regular_chart / log_chart / galactic_dust_chart — paper §4.3 charts
  Kernel zoo (matern32, ...) — paper §3.1
  ICR — sqrt(K_ICR) application, paper §4 / Alg. 1
  DistributedICR — shard_map spatial sharding + halo exchange (multi-pod)
  KissGP — baseline, paper §5.2
  map_fit / advi_fit — standardized inference, paper §3.2
"""
from .charts import (
    Chart,
    galactic_dust_chart,
    log_chart,
    log_polar_chart,
    regular_chart,
)
from .kernels import KERNELS, Kernel, exponential, kernel_matrix, matern32, matern52, rbf
from .refine import (
    LevelGeom,
    axis_refinement_matrices_level,
    level0_sqrt,
    refine_level,
    refine_level_T,
    refinement_matrices_level,
)
from .icr import ICR
from .exact import cov_errors, exact_cov, exact_posterior, exact_sample, gauss_kl
from .kissgp import KissGP
from .standardize import (
    Prior,
    StandardizedModel,
    lognormal_prior,
    normal_prior,
    uniform_prior,
)
from .vi import (
    Posterior,
    advi_fit,
    advi_posterior,
    cg_posterior,
    gaussian_log_likelihood,
    map_fit,
    map_posterior,
    neg_log_joint,
    poisson_log_likelihood,
)

__all__ = [
    "Chart", "regular_chart", "log_chart", "log_polar_chart",
    "galactic_dust_chart",
    "Kernel", "KERNELS", "matern32", "matern52", "rbf", "exponential",
    "kernel_matrix",
    "LevelGeom", "refine_level", "refine_level_T",
    "refinement_matrices_level",
    "axis_refinement_matrices_level", "level0_sqrt",
    "ICR",
    "cov_errors", "exact_cov", "exact_posterior", "exact_sample", "gauss_kl",
    "KissGP",
    "Prior", "StandardizedModel", "lognormal_prior", "normal_prior",
    "uniform_prior",
    "map_fit", "advi_fit", "neg_log_joint", "gaussian_log_likelihood",
    "poisson_log_likelihood",
    "Posterior", "map_posterior", "advi_posterior", "cg_posterior",
]
