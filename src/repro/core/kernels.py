"""Stationary covariance kernels (paper §3.1, Eq. 14).

Kernels are represented as factories: ``kernel(theta) -> k`` where ``k`` is a
callable acting on *distances* ``d >= 0``. All kernels are isotropic on the
modeled space ``D`` — anisotropy/irregularity is supplied by the coordinate
chart (paper §4.3), not the kernel.

theta is a flat dict of scalars so it can be standardized (core.standardize)
and learned jointly with the field (paper Eq. 2/3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import jax.numpy as jnp

Array = jnp.ndarray
KernelFn = Callable[[Array], Array]


@dataclasses.dataclass(frozen=True)
class Kernel:
    """A stationary kernel family ``k_theta(d)``."""

    name: str
    fn: Callable[[Mapping[str, Array]], KernelFn]
    default_theta: Mapping[str, float]

    def __call__(self, theta: Mapping[str, Array] | None = None) -> KernelFn:
        theta = dict(self.default_theta) if theta is None else dict(theta)
        return self.fn(theta)

    def with_defaults(self, **kw) -> "Kernel":
        d = dict(self.default_theta)
        d.update(kw)
        return dataclasses.replace(self, default_theta=d)


def _matern32_fn(theta):
    rho, sigma = theta["rho"], theta.get("sigma", 1.0)

    def k(d):
        z = jnp.sqrt(3.0) * d / rho
        return sigma**2 * (1.0 + z) * jnp.exp(-z)

    return k


def _matern52_fn(theta):
    rho, sigma = theta["rho"], theta.get("sigma", 1.0)

    def k(d):
        z = jnp.sqrt(5.0) * d / rho
        return sigma**2 * (1.0 + z + z**2 / 3.0) * jnp.exp(-z)

    return k


def _rbf_fn(theta):
    rho, sigma = theta["rho"], theta.get("sigma", 1.0)

    def k(d):
        return sigma**2 * jnp.exp(-0.5 * (d / rho) ** 2)

    return k


def _exponential_fn(theta):
    rho, sigma = theta["rho"], theta.get("sigma", 1.0)

    def k(d):
        return sigma**2 * jnp.exp(-d / rho)

    return k


#: Matérn-3/2 — the paper's experimental kernel (Eq. 14).
matern32 = Kernel("matern32", _matern32_fn, {"rho": 1.0, "sigma": 1.0})
matern52 = Kernel("matern52", _matern52_fn, {"rho": 1.0, "sigma": 1.0})
rbf = Kernel("rbf", _rbf_fn, {"rho": 1.0, "sigma": 1.0})
exponential = Kernel("exponential", _exponential_fn, {"rho": 1.0, "sigma": 1.0})

KERNELS = {k.name: k for k in (matern32, matern52, rbf, exponential)}


def kernel_matrix(k: KernelFn, x: Array, y: Array | None = None) -> Array:
    """Dense kernel matrix ``K[i, j] = k(||x_i - y_j||)``.

    x: (N, dim) or (N,) points in the modeled space D.
    """
    y = x if y is None else y
    x = jnp.atleast_2d(x.T).T if x.ndim == 1 else x
    y = jnp.atleast_2d(y.T).T if y.ndim == 1 else y
    if x.ndim == 1:
        x = x[:, None]
    if y.ndim == 1:
        y = y[:, None]
    d = jnp.linalg.norm(x[:, None, :] - y[None, :, :], axis=-1)
    return k(d)
