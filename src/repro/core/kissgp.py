"""KISS-GP baseline (paper §2, §5.2; Wilson & Nickisch 2015).

K_XX ≈ W K_UU Wᵀ with M regularly spaced inducing points, sparse linear
interpolation W and Toeplitz K_UU applied via circulant (FFT) embedding on a
padded circle — exactly the paper's Eq. 15 representation
``K = W · F · P · Fᵀ · Wᵀ`` with padding factor 0.5.

The timed "forward pass" matches the paper's §5.2 protocol: apply the inverse
kernel matrix with 40 CG iterations + stochastically estimate the
log-determinant with 10 probes × 15 Lanczos iterations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class KissGP:
    """KISS-GP on 1-D modeled points `x` (sorted, arbitrary spacing)."""

    x: np.ndarray                 # (N,) modeled point locations in D
    kernel_fn: Callable           # stationary kernel k(d)
    m: int | None = None          # inducing points (default M = N)
    padding: float = 0.5          # circle padding factor (paper §5.2)
    jitter: float = 1e-6

    # -- geometry -------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.x)

    @property
    def m_ind(self) -> int:
        return self.m or self.n

    @property
    def mp(self) -> int:
        return int(round(self.m_ind * (1.0 + self.padding)))

    def _grid(self):
        x = np.asarray(self.x)
        lo, hi = float(x.min()), float(x.max())
        h = (hi - lo) / (self.m_ind - 1)
        return lo, h

    def interp_weights(self):
        """Sparse linear interpolation W: (idx_lo, w_lo, w_hi) per point."""
        lo, h = self._grid()
        p = (np.asarray(self.x) - lo) / h
        idx = np.clip(np.floor(p).astype(np.int64), 0, self.m_ind - 2)
        frac = p - idx
        return (jnp.asarray(idx), jnp.asarray(1.0 - frac), jnp.asarray(frac))

    def spectrum(self) -> Array:
        """P: circulant eigenvalues of the padded-circle kernel (Eq. 15)."""
        _, h = self._grid()
        j = np.arange(self.mp)
        d = h * np.minimum(j, self.mp - j)  # circle distance
        c = self.kernel_fn(jnp.asarray(d))
        p = jnp.fft.rfft(c).real
        return jnp.maximum(p, 0.0)  # clip tiny negative leakage

    # -- operator applications -------------------------------------------------
    def apply_w(self, u: Array) -> Array:
        idx, wl, wr = self.interp_weights()
        return wl * u[idx] + wr * u[idx + 1]

    def apply_wt(self, v: Array) -> Array:
        idx, wl, wr = self.interp_weights()
        out = jnp.zeros(self.m_ind, v.dtype)
        out = out.at[idx].add(wl * v)
        out = out.at[idx + 1].add(wr * v)
        return out

    def apply_kuu(self, u: Array, p: Array | None = None) -> Array:
        p = self.spectrum() if p is None else p
        up = jnp.zeros(self.mp, u.dtype).at[: self.m_ind].set(u)
        return jnp.fft.irfft(jnp.fft.rfft(up) * p, n=self.mp)[: self.m_ind]

    def matvec(self, v: Array, p: Array | None = None) -> Array:
        """K v = W K_UU Wᵀ v (+ jitter v to keep CG well-posed, §5.2)."""
        p = self.spectrum() if p is None else p
        return self.apply_w(self.apply_kuu(self.apply_wt(v), p)) + self.jitter * v

    def apply_sqrt(self, xi: Array, p: Array | None = None) -> Array:
        """Generative sqrt: s = W F⁻¹ sqrt(P) ξ (harmonic-domain sqrt)."""
        p = self.spectrum() if p is None else p
        half = self.mp // 2 + 1
        u = jnp.fft.irfft(jnp.sqrt(p) * xi[:half], n=self.mp) * np.sqrt(self.mp)
        return self.apply_w(u[: self.m_ind])

    @property
    def xi_size(self) -> int:
        return self.mp // 2 + 1

    # -- dense (validation only, paper Fig. 3 bottom) ---------------------------
    def dense_cov(self) -> Array:
        _, h = self._grid()
        j = np.arange(self.mp)
        d = h * np.minimum(j, self.mp - j)
        c = np.asarray(self.kernel_fn(jnp.asarray(d)))
        kuu = c[np.abs(np.subtract.outer(np.arange(self.m_ind),
                                         np.arange(self.m_ind))) % self.mp]
        idx, wl, wr = map(np.asarray, self.interp_weights())
        w = np.zeros((self.n, self.m_ind))
        w[np.arange(self.n), idx] = wl
        w[np.arange(self.n), idx + 1] = wr
        return jnp.asarray(w @ kuu @ w.T)

    # -- paper §5.2 forward pass -------------------------------------------------
    def solve(self, y: Array, *, rtol: float = 1e-6, max_iters: int = 40,
              p: Array | None = None) -> tuple:
        """K⁻¹ y through the guarded batched CG core (solvers.pcg).

        Bounded ``while_loop`` with a tolerance early-exit (the paper's
        40-iteration budget stays as the cap) plus the §16 monitors:
        breakdown (pᵀAp ≤ 0 freezes the column instead of the old
        ``+ 1e-30`` silent garbage), divergence/NaN quarantine and
        stagnation. Returns ``(x, stats)`` with per-solve ``status`` /
        ``iters`` / ``relres`` scalars; fully jit-traceable.
        """
        from repro.solvers import CGConfig, pcg_iterate

        p = self.spectrum() if p is None else p

        def mv(v):
            return jax.vmap(lambda c: self.matvec(c, p))(v)

        cfg = CGConfig(rtol=rtol, max_iters=max_iters)
        x, stats, _ = pcg_iterate(mv, y[None, :], cfg=cfg)
        return x[0], {k: v[0] if getattr(v, "ndim", 0) else v
                      for k, v in stats.items()}

    def solve_cg(self, y: Array, iters: int = 40, p: Array | None = None) -> Array:
        """Deprecated shim: pre-§16 signature of :meth:`solve`.

        The fixed ``fori_loop(0, iters)`` body is gone — this now runs
        the guarded core with ``iters`` as the cap and the default rtol
        early-exit, returning only x as before.
        """
        import warnings

        warnings.warn("KissGP.solve_cg is deprecated; use KissGP.solve "
                      "(guarded CG with tolerance early-exit and "
                      "breakdown reporting)", DeprecationWarning,
                      stacklevel=2)
        return self.solve(y, max_iters=iters, p=p)[0]

    def logdet_slq(self, key, probes: int = 10, lanczos_iters: int = 15,
                   p: Array | None = None) -> Array:
        """Stochastic Lanczos quadrature log-det (paper: 10 × 15)."""
        p = self.spectrum() if p is None else p

        def mv(v):
            return self.matvec(v, p)

        def one_probe(k):
            z = jax.random.rademacher(k, (self.n,), jnp.float32).astype(p.dtype)
            nz = jnp.linalg.norm(z)
            q0 = z / nz
            m_it = lanczos_iters

            def body(i, carry):
                q_prev, q, alpha, beta, live = carry
                w = mv(q) - beta[i] * q_prev
                a = w @ q
                w = w - a * q
                # one-shot full reorthogonalization is skipped (matches the
                # cheap setting the paper grants KISS-GP)
                b = jnp.linalg.norm(w)
                # Lanczos breakdown: ||w|| ≈ 0 means the Krylov space is
                # exhausted (K effectively low-rank). Normalizing w/(b+eps)
                # would emit a junk direction and poison every later step;
                # instead truncate — zero the coupling β so T becomes block
                # diagonal, park the dead block's diagonal at 1 (log 1 = 0,
                # so even degenerate-eigenvalue leakage contributes nothing
                # to the quadrature) and stop iterating this probe.
                ok = live & (b > 1e-6 * (jnp.abs(a) + beta[i] + 1e-30))
                alpha = alpha.at[i].set(jnp.where(live, a, 1.0))
                beta = beta.at[i + 1].set(jnp.where(ok, b, 0.0))
                q_next = jnp.where(ok, w / jnp.where(b == 0, 1.0, b),
                                   jnp.zeros_like(w))
                return q, q_next, alpha, beta, ok

            alpha = jnp.zeros(m_it, p.dtype)
            beta = jnp.zeros(m_it + 1, p.dtype)
            carry = (jnp.zeros_like(q0), q0, alpha, beta,
                     jnp.asarray(True))
            _, _, alpha, beta, _ = jax.lax.fori_loop(0, m_it, body, carry)
            t = (jnp.diag(alpha) + jnp.diag(beta[1:m_it], 1)
                 + jnp.diag(beta[1:m_it], -1))
            evals, evecs = jnp.linalg.eigh(t)
            evals = jnp.maximum(evals, self.jitter)
            return nz**2 * jnp.sum(evecs[0, :] ** 2 * jnp.log(evals))

        keys = jax.random.split(key, probes)
        return jnp.mean(jax.vmap(one_probe)(keys))

    def forward_pass(self, y: Array, key) -> tuple:
        """The §5.2 timed unit: K⁻¹y (40 CG) + logdet (10×15 SLQ)."""
        p = self.spectrum()
        return (self.solve(y, max_iters=40, p=p)[0],
                self.logdet_slq(key, 10, 15, p))
