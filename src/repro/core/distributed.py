"""Distributed ICR: spatial sharding with halo exchange (DESIGN.md §6).

The paper's 122-billion-DOF application (§6, ref [24]) needs the refinement
to run across pods. ICR's conditioning is *local* (each family reads n_csz
coarse neighbors), so the natural distribution is a spatial domain
decomposition: every device owns a contiguous block along one chart axis and
each refinement level exchanges a ``b = (n_csz-1)//2`` halo with its ring
neighbors via ``lax.ppermute`` — O(b) elements per device per level,
independent of N. Interior compute is identical to the single-device path,
so ``sharded == unsharded`` exactly (tests/test_distributed_icr.py).

Requirements: ``boundary="reflect"`` (uniform 2x level sizes) and the family
count along the shard axis divisible by the device count from the first
sharded level on (doubling preserves divisibility). Earlier (tiny) levels are
computed replicated on every device — identical math, no communication.

Multi-pod: the shard axis may span several mesh axes (e.g. ("pod", "space"));
the halo ppermute runs over the flattened ring, so cross-pod boundaries are
just two of the 512 ring edges (DCN links), everything else stays on ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from .charts import Chart
from .icr import ICR
from .refine import LevelGeom, refine_level

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class DistributedICR:
    """Spatially sharded wrapper around an ICR model.

    Attributes:
      icr: the underlying model; its chart must use boundary="reflect".
      mesh: device mesh.
      axis_names: mesh axis name(s) forming the spatial ring (flattened).
      shard_axis: which chart axis is decomposed (default: the largest).
    """

    icr: ICR
    mesh: Mesh
    axis_names: tuple = ("space",)
    shard_axis: int = 0

    def __post_init__(self):
        if self.icr.chart.boundary != "reflect":
            raise ValueError("DistributedICR requires boundary='reflect'")
        if isinstance(self.axis_names, str):
            object.__setattr__(self, "axis_names", (self.axis_names,))

    # -- partitioning geometry -------------------------------------------------
    @property
    def n_dev(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.axis_names]))

    @property
    def chart(self) -> Chart:
        return self.icr.chart

    def first_sharded_level(self) -> int:
        """First level whose *input* (coarse grid) is sharded.

        Constraints: family count divisible by the ring size, and the
        per-device coarse block must cover the halo + edge reflection
        (block >= b + 1) so halos are single-hop.
        """
        c = self.chart
        for lvl in range(c.n_levels):
            t = c.family_count(lvl, self.shard_axis)
            blk = c.shape(lvl)[self.shard_axis] // self.n_dev
            if t % self.n_dev == 0 and t >= self.n_dev and blk >= c.b + 1:
                return lvl
        raise ValueError(
            f"no refinement level is shardable over {self.n_dev} devices "
            f"along axis {self.shard_axis} (need family count divisible by "
            f"the ring and a coarse block >= {c.b + 1}); grow shape0 or "
            "reduce devices"
        )

    def xi_structure(self):
        """Per-level xi shapes (families kept *shaped*, not flattened):
        level 0: shape0-prod vector; level l>=1: (*T_l, n_fsz^d)."""
        c = self.chart
        nd = c.ndim
        shapes = [(int(np.prod(c.shape0)),)]
        for lvl in range(c.n_levels):
            t = tuple(c.family_count(lvl, a) for a in range(nd))
            shapes.append(t + (c.n_fsz**nd,))
        return shapes

    def xi_specs(self):
        """PartitionSpec per xi leaf: replicated until first sharded level."""
        k = self.first_sharded_level()
        specs = [P()]  # level-0 excitation replicated
        for lvl in range(self.chart.n_levels):
            if lvl < k:
                specs.append(P())
            else:
                spec = [None] * (self.chart.ndim + 1)
                spec[self.shard_axis] = self.axis_names
                specs.append(P(*spec))
        return specs

    def mat_specs(self):
        """PartitionSpecs for the refinement-matrix pytree."""
        c = self.chart
        k = self.first_sharded_level()
        r_specs, d_specs = [], []
        for lvl in range(c.n_levels):
            if lvl >= k and not c.invariant[self.shard_axis]:
                spec = [None] * (c.ndim + 2)
                spec[self.shard_axis] = self.axis_names
                r_specs.append(P(*spec))
                d_specs.append(P(*spec))
            else:
                r_specs.append(P())
                d_specs.append(P())
        return {"sqrt0": P(), "R": r_specs, "sqrtD": d_specs}

    def out_spec(self):
        spec = [None] * self.chart.ndim
        spec[self.shard_axis] = self.axis_names
        return P(*spec)

    def shardings(self):
        """NamedShardings for (matrices, xi, out) — feed these to jax.jit."""
        ns = lambda spec: NamedSharding(self.mesh, spec)
        mats = jax.tree.map(ns, self.mat_specs(),
                            is_leaf=lambda x: isinstance(x, P))
        xis = [ns(s) for s in self.xi_specs()]
        return mats, xis, ns(self.out_spec())

    def _refine(self, field: Array, xl: Array, r: Array, d: Array,
                geom: LevelGeom) -> Array:
        """Interior compute of one level — per-device, identical math to the
        single-device path. With ``icr.use_pallas`` it goes through
        ``dispatch.refine`` (the fused 1-D kernels where the geometry is
        covered, honoring the dtype policy; N-D levels have no axis factors
        under the joint sharding specs and dispatch falls back to the jnp
        reference there), else straight to ``refine_level``."""
        if not self.icr.use_pallas:
            return refine_level(field, xl, r, d, geom)
        from repro.kernels import dispatch

        pol = (self.icr.policy if self.icr.dtype_policy is not None
               else None)
        return dispatch.refine(field, xl, r, d, geom, policy=pol)

    # -- the sharded program ----------------------------------------------------
    def _halo_exchange(self, local: Array, b: int) -> Array:
        """Append ring halos of width b along shard_axis; global edges use
        local reflection (= the chart's reflect boundary)."""
        ax, names = self.shard_axis, self.axis_names
        n = self.n_dev
        idx = lax.axis_index(names)

        def take(arr, sl):
            ind = [slice(None)] * arr.ndim
            ind[ax] = sl
            return arr[tuple(ind)]

        def rev(arr):
            ind = [slice(None)] * arr.ndim
            ind[ax] = slice(None, None, -1)
            return arr[tuple(ind)]

        fwd = [(i, i + 1) for i in range(n - 1)]
        bwd = [(i + 1, i) for i in range(n - 1)]
        from_left = lax.ppermute(take(local, slice(-b, None)), names, fwd)
        from_right = lax.ppermute(take(local, slice(None, b)), names, bwd)
        # reflect at the global edges (chart reflect boundary condition)
        left = jnp.where(idx == 0, rev(take(local, slice(1, b + 1))),
                         from_left)
        right = jnp.where(idx == n - 1,
                          rev(take(local, slice(-b - 1, -1))), from_right)
        return jnp.concatenate([left, local, right], axis=ax)

    def _local_geom(self, lvl: int, sharded: bool) -> LevelGeom:
        """Geometry of the per-device refine: the local block is pre-padded
        on every axis, so window extraction is plain 'shrink' indexing."""
        c = self.chart
        nd = c.ndim
        t = [c.family_count(lvl, a) for a in range(nd)]
        kept = tuple(
            1 if c.invariant[a] else t[a] for a in range(nd)
        )
        coarse = list(c.shape(lvl))
        fine = list(c.shape(lvl + 1))
        if sharded:
            t[self.shard_axis] //= self.n_dev
            coarse[self.shard_axis] //= self.n_dev
            fine[self.shard_axis] //= self.n_dev
            if not c.invariant[self.shard_axis]:
                kept = tuple(
                    t[a] if a == self.shard_axis else kept[a]
                    for a in range(nd)
                )
        padded = tuple(coarse[a] + 2 * c.b for a in range(nd))
        return LevelGeom(
            coarse_shape=padded, fine_shape=tuple(fine), T=tuple(t),
            kept_T=kept, n_csz=c.n_csz, n_fsz=c.n_fsz, stride=c.stride,
            b=c.b, boundary="shrink",
        )

    def _pad_unsharded_axes(self, local: Array) -> Array:
        c = self.chart
        pads = [(c.b, c.b)] * c.ndim
        pads[self.shard_axis] = (0, 0)
        return jnp.pad(local, pads, mode="reflect")

    def _sharded_body(self, mats: dict, xi: Sequence[Array]) -> Array:
        c = self.chart
        nd = c.ndim
        k = self.first_sharded_level()
        fsz = c.n_fsz**nd

        # replicated prologue (levels < k): identical on every device
        field = (mats["sqrt0"] @ xi[0]).reshape(c.shape0)
        for lvl in range(k):
            geom = LevelGeom.for_level(c, lvl)
            xl = xi[lvl + 1].reshape(-1, fsz)
            field = self._refine(field, xl, mats["R"][lvl],
                                 mats["sqrtD"][lvl], geom)

        # transition: slice my block along shard_axis
        blk = c.shape(k)[self.shard_axis] // self.n_dev
        idx = lax.axis_index(self.axis_names)
        field = lax.dynamic_slice_in_dim(field, idx * blk, blk,
                                         axis=self.shard_axis)

        # sharded levels with halo exchange
        for lvl in range(k, c.n_levels):
            padded = self._halo_exchange(field, c.b)
            padded = self._pad_unsharded_axes(padded)
            geom = self._local_geom(lvl, sharded=True)
            xl = xi[lvl + 1].reshape(-1, fsz)
            r, d = mats["R"][lvl], mats["sqrtD"][lvl]
            field = self._refine(padded, xl, r, d, geom)
        return field

    def apply_sqrt(self, mats: dict, xi: Sequence[Array]) -> Array:
        """shard_map'd sqrt(K_ICR) application. xi leaves must be laid out per
        ``xi_structure()``; use ``shardings()`` to place them."""
        mat_specs = self.mat_specs()
        xi_specs = self.xi_specs()

        # inside shard_map, sharded xi arrive as local blocks along shard_axis
        fn = shard_map(
            self._sharded_body,
            mesh=self.mesh,
            in_specs=(mat_specs, tuple(xi_specs)),
            out_specs=self.out_spec(),
            check_vma=False,
        )
        return fn(mats, tuple(xi))

    def init_xi(self, key, dtype=None):
        dtype = self.icr.policy.storage_dtype if dtype is None else dtype
        shapes = self.xi_structure()
        keys = jax.random.split(key, len(shapes))
        _, xi_sh, _ = self.shardings()
        return [
            jax.device_put(jax.random.normal(k, s, dtype), sh)
            for k, s, sh in zip(keys, shapes, xi_sh)
        ]

    def matrices(self, theta=None):
        # the sharded body runs the joint reference path: force the joint
        # build (a pallas N-D ICR skips it by default) and skip the per-axis
        # factors, which have no sharding spec
        mats = self.icr.matrices(theta, joint=True, axes=False)
        mat_sh, _, _ = self.shardings()
        return jax.tree.map(jax.device_put, mats, mat_sh)

    def sample(self, key, theta=None, dtype=jnp.float32) -> Array:
        return self.apply_sqrt(self.matrices(theta), self.init_xi(key, dtype))
