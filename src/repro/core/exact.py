"""Exact (dense) GP reference — the oracle ICR is validated against (§5.1).

Everything here is O(N^3)/O(N^2) and only used for small N in tests and the
accuracy benchmarks (paper Fig. 3), never in the production path.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .charts import Chart
from .kernels import kernel_matrix

Array = jnp.ndarray


def exact_cov(chart: Chart, kernel_fn: Callable, level: int | None = None) -> Array:
    """Dense K_XX at the finest (or given) level's charted positions."""
    level = chart.n_levels if level is None else level
    pos = chart.grid_positions(level)
    return kernel_matrix(kernel_fn, pos)


def exact_sample(key, cov: Array, jitter: float = 1e-10) -> Array:
    n = cov.shape[0]
    chol = jnp.linalg.cholesky(cov + jitter * jnp.eye(n, dtype=cov.dtype))
    return chol @ jax.random.normal(key, (n,), cov.dtype)


def cov_errors(approx: Array, exact: Array) -> dict:
    """Error metrics used in paper §5.1/§5.2 (MAE, max err, diag err)."""
    diff = jnp.abs(approx - exact)
    return {
        "mae": jnp.mean(diff),
        "max_abs_err": jnp.max(diff),
        "max_diag_err": jnp.max(jnp.abs(jnp.diag(approx) - jnp.diag(exact))),
        "rel_fro": jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact),
    }


def gauss_kl(cov_p: Array, cov_q: Array, jitter: float = 1e-10) -> Array:
    """KL( N(0, cov_q) || N(0, cov_p) ) — the paper's §5.1 model-selection
    measure for picking (n_csz, n_fsz): information lost when using the
    approximation q (ICR) in place of the truth p (exact kernel).
    """
    n = cov_p.shape[0]
    eye = jnp.eye(n, dtype=cov_p.dtype)
    chol_p = jnp.linalg.cholesky(cov_p + jitter * eye)
    chol_q = jnp.linalg.cholesky(cov_q + jitter * eye)
    # tr(P^-1 Q) via triangular solves
    a = jax.scipy.linalg.solve_triangular(chol_p, chol_q, lower=True)
    tr = jnp.sum(a * a)
    logdet_p = 2.0 * jnp.sum(jnp.log(jnp.diag(chol_p)))
    logdet_q = 2.0 * jnp.sum(jnp.log(jnp.diag(chol_q)))
    return 0.5 * (tr - n + logdet_p - logdet_q)


def exact_posterior(cov: Array, obs_idx: Array, y: Array,
                    noise_var: float) -> tuple:
    """Exact GP regression posterior (mean, cov) on all points given noisy
    observations of a subset. Oracle for the VI driver tests.
    """
    k_oo = cov[obs_idx][:, obs_idx]
    k_xo = cov[:, obs_idx]
    n = k_oo.shape[0]
    g = k_oo + noise_var * jnp.eye(n, dtype=cov.dtype)
    sol = jnp.linalg.solve(g, y)
    mean = k_xo @ sol
    post_cov = cov - k_xo @ jnp.linalg.solve(g, k_xo.T)
    return mean, post_cov
