"""Iterative Charted Refinement — the paper's core algorithm (§4, Alg. 1).

``ICR`` is a *generative* representation of a GP: it applies an O(N)
approximate square root of the kernel matrix to a standard-normal excitation
vector ξ (paper §3.2):

    s = sqrt(K_ICR)(ξ)  with  <s sᵀ> ≈ K_XX.

There is no inversion and no log-determinant anywhere — evaluating the model
(and its VJP) is two applications of the square root (paper §1).

The excitation ξ is a list of arrays, one per level:
  ξ[0]: (prod(shape0),)           — exact coarse-grid excitation
  ξ[l]: (F_l, n_fsz^d), l=1..L    — per-family fine corrections

Matrices depend on the kernel parameters θ and are (re)computed *inside* the
jitted step when θ is learned; they are a pytree so they can also be
precomputed and donated for fixed-θ sampling.
"""
from __future__ import annotations

import dataclasses
from typing import List, Mapping, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .charts import Chart
from .kernels import Kernel
from .refine import (
    LevelGeom,
    axis_refinement_matrices_level,
    level0_sqrt,
    refine_level,
    refinement_matrices_level,
)

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ICR:
    """Iterative Charted Refinement model over `chart` with `kernel`.

    ``dtype_policy`` (DESIGN.md §11) sets the storage/accumulation dtypes
    of the whole refinement stack: ``"bf16"`` (or ``DtypePolicy()`` — the
    policy's own default) stores fields/ξ/matrices in bfloat16 with f32
    accumulation, halving HBM bytes per level; ``None`` keeps the
    historical all-float32 behavior (the ``"fp32"`` opt-out), bit-stable
    with the fp32 reference suites.

    ``use_pyramid`` (with ``use_pallas=True``): run all consecutive early
    levels whose combined working set fits VMEM as ONE kernel launch
    (repro.kernels.pyramid) — their intermediate fields never touch HBM.
    The remaining big levels run per level through ``dispatch.refine``.
    """

    chart: Chart
    kernel: Kernel
    jitter: float = 1e-6
    use_pallas: bool = False  # route stationary levels through repro.kernels
    dtype_policy: object = None  # None -> fp32; "bf16"/DtypePolicy() -> mixed
    use_pyramid: bool = True  # VMEM-resident multi-level prefix (needs pallas)

    @property
    def policy(self):
        """The resolved DtypePolicy (fp32 when ``dtype_policy`` is None)."""
        from repro.kernels.policy import resolve

        return resolve(self.dtype_policy)

    # -- shapes ---------------------------------------------------------------
    def xi_shapes(self) -> List[tuple]:
        nd = self.chart.ndim
        shapes = [(int(np.prod(self.chart.shape0)),)]
        for lvl in range(self.chart.n_levels):
            t = tuple(
                self.chart.family_count(lvl, a) for a in range(nd)
            )
            shapes.append((int(np.prod(t)), self.chart.n_fsz**nd))
        return shapes

    def xi_size(self) -> int:
        return sum(int(np.prod(s)) for s in self.xi_shapes())

    @property
    def out_shape(self) -> tuple:
        return self.chart.final_shape

    # -- parameters -----------------------------------------------------------
    def init_xi(self, key, dtype=None, *,
                batch: int | None = None) -> List[Array]:
        """Standard-normal excitations; ``batch`` prepends a sample dim to
        every level (the layout ``apply_sqrt_batch`` consumes). ``dtype``
        defaults to the dtype policy's storage dtype (f32 without one)."""
        dtype = self.policy.storage_dtype if dtype is None else dtype
        keys = jax.random.split(key, self.chart.n_levels + 1)
        lead = () if batch is None else (batch,)
        return [
            jax.random.normal(k, lead + s, dtype)
            for k, s in zip(keys, self.xi_shapes())
        ]

    def zero_xi(self, dtype=None) -> List[Array]:
        dtype = self.policy.storage_dtype if dtype is None else dtype
        return [jnp.zeros(s, dtype) for s in self.xi_shapes()]

    # -- matrices (functions of theta) ----------------------------------------
    def matrices(self, theta: Mapping[str, Array] | None = None, *,
                 joint: bool | None = None,
                 axes: bool | None = None) -> dict:
        """Refinement matrices for kernel parameters theta (paper Eq. 7/8).

        O(n_csz^{3d} · N) work, dominated by the finest level; differentiable
        w.r.t. theta.

        `axes` adds the per-axis Kronecker factors consumed by the fused N-D
        path (tiny next to the joint matrices); default: ``use_pallas`` on an
        N-D chart. `joint` builds the joint per-level (R, sqrtD); default:
        skipped exactly when the factors are built, because apply_sqrt then
        routes every level through them and the joint O(n_csz^{3d}) build
        would be dead weight. DistributedICR forces ``joint=True`` (its
        sharded body runs the joint reference).
        """
        build_axes = (self.use_pallas and self.chart.ndim > 1
                      if axes is None else axes)
        build_joint = (not build_axes) if joint is None else joint
        k = self.kernel(theta)
        out = {"sqrt0": level0_sqrt(self.chart, k, jitter=self.jitter)}
        if build_joint:
            out["R"], out["sqrtD"] = [], []
            for lvl in range(self.chart.n_levels):
                r, sd = refinement_matrices_level(
                    self.chart, k, lvl, jitter=self.jitter
                )
                out["R"].append(r)
                out["sqrtD"].append(sd)
        if build_axes:
            out["Rax"], out["sqrtDax"] = [], []
            for lvl in range(self.chart.n_levels):
                rs, ds = axis_refinement_matrices_level(
                    self.chart, k, lvl, jitter=self.jitter
                )
                out["Rax"].append(rs)
                out["sqrtDax"].append(ds)
        pol = self.policy
        if jnp.dtype(pol.storage_dtype) != jnp.float32:
            # matrix *math* stays f32 (solves/eigh above); only what is
            # stored — and re-read every level — drops to the storage dtype
            out = pol.cast_storage(out)
        return out

    def _theta_key(self, theta: Mapping | None):
        """Hashable fingerprint of θ (None for traced values — uncacheable)."""
        if theta is None:
            return ()
        items = []
        for name in sorted(theta):
            v = theta[name]
            if isinstance(v, jax.core.Tracer):
                return None
            a = np.asarray(v)
            items.append((name, a.dtype.str, a.shape, a.tobytes()))
        return tuple(items)

    def matrices_cached(self, theta: Mapping[str, Array] | None = None, *,
                        joint: bool | None = None,
                        axes: bool | None = None) -> dict:
        """``matrices()`` behind a per-instance cache keyed on θ
        (DESIGN.md §12). The instance already pins the chart geometry and
        the dtype policy, so the full serving cache key
        (chart geometry, θ, dtype policy) is (instance, θ): repeat traffic
        against a fitted posterior rebuilds nothing, a θ change is a miss.
        Traced θ (learning θ inside a jitted step) bypasses the cache —
        the matrices are rebuilt inside the trace exactly as before."""
        tkey = self._theta_key(theta)
        if tkey is None:
            return self.matrices(theta, joint=joint, axes=axes)
        key = (tkey, joint, axes)
        cache = self.__dict__.get("_mats_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_mats_cache", cache)
            object.__setattr__(self, "matrices_cache_stats",
                               {"hits": 0, "misses": 0})
        hit = cache.pop(key, None)  # re-insert below: LRU order
        if hit is not None:
            self.matrices_cache_stats["hits"] += 1
            cache[key] = hit
            return hit
        self.matrices_cache_stats["misses"] += 1
        out = cache[key] = self.matrices(theta, joint=joint, axes=axes)
        while len(cache) > 8:  # bound: don't pin every historical θ's mats
            cache.pop(next(iter(cache)))
        return out

    # -- forward --------------------------------------------------------------
    def _level_axis_mats(self, mats: dict, lvl: int):
        """Per-axis factor convention for level `lvl`: the Kronecker factors
        when built, else the 1-D joint matrices squeezed to the factor
        shapes (a 1-D chart's joint (kept_T, f, c) IS its only factor)."""
        if "Rax" in mats:
            return mats["Rax"][lvl], mats["sqrtDax"][lvl]
        r, d = mats["R"][lvl], mats["sqrtD"][lvl]
        if r.shape[0] == 1:
            r, d = r.reshape(r.shape[-2:]), d.reshape(d.shape[-2:])
        return [r], [d]

    def _refine_levels(self, mats: dict, xi: Sequence[Array], field: Array,
                       *, sample_axis: bool) -> Array:
        """Run every refinement level on `field` (the shared body of
        apply_sqrt and apply_sqrt_batch; `sample_axis` marks a leading
        sample dimension that the kernels consume natively).

        With ``use_pallas``: the pyramid prefix (all early levels whose
        combined working set fits VMEM, DESIGN.md §11) runs as ONE launch,
        then each remaining level goes through ``dispatch.refine`` (buffer
        donation deliberately does not apply to the expansive ping-pong
        chain — see the note in kernels/dispatch.py).
        """
        start = 0
        if not self.use_pallas:
            for lvl in range(self.chart.n_levels):
                geom = LevelGeom.for_level(self.chart, lvl)
                ref = lambda f, x: refine_level(
                    f, x, mats["R"][lvl], mats["sqrtD"][lvl], geom)
                field = (jax.vmap(ref)(field, xi[lvl + 1]) if sample_axis
                         else ref(field, xi[lvl + 1]))
            return field

        from repro.kernels import dispatch, pyramid

        pol = self.policy if self.dtype_policy is not None else None
        if pol is not None:
            field = field.astype(pol.storage_dtype)
        n_s = field.shape[0] if sample_axis else 1
        itemsize = jnp.dtype(field.dtype).itemsize
        covered = (self.chart.ndim == 1) or ("Rax" in mats)
        cover = (dispatch.pyramid_cover(
            self.chart, have_axis_mats="Rax" in mats, samples=n_s,
            itemsize=itemsize) if self.use_pyramid and covered else None)
        if cover is not None:
            start, s_b = cover
            geoms = [LevelGeom.for_level(self.chart, l)
                     for l in range(start)]
            pmats = [self._level_axis_mats(mats, l) for l in range(start)]
            if pol is not None:
                pmats = pol.cast_storage(pmats)
            field = pyramid.refine_pyramid(
                field, [xi[l + 1] for l in range(start)], pmats, geoms,
                sample_axis=sample_axis, sample_block=s_b,
                accum_dtype=(pol.accum_name if pol is not None
                             else "float32"),
            )

        for lvl in range(start, self.chart.n_levels):
            geom = LevelGeom.for_level(self.chart, lvl)
            axis_mats = None
            if "Rax" in mats:
                axis_mats = (mats["Rax"][lvl], mats["sqrtDax"][lvl])
            r = mats["R"][lvl] if "R" in mats else None
            d = mats["sqrtD"][lvl] if "sqrtD" in mats else None
            field = dispatch.refine(
                field, xi[lvl + 1], r, d, geom, axis_mats=axis_mats,
                sample_axis=sample_axis, policy=pol,
            )
        return field

    def apply_sqrt(self, mats: dict, xi: Sequence[Array]) -> Array:
        """Apply sqrt(K_ICR) to ξ (paper Alg. 1). Returns the finest field."""
        field = (mats["sqrt0"] @ xi[0]).reshape(self.chart.shape0)
        return self._refine_levels(mats, xi, field, sample_axis=False)

    def apply_sqrt_batch(self, mats: dict, xi: Sequence[Array]) -> Array:
        """Apply sqrt(K_ICR) to a whole batch of excitations at once.

        xi: ξ-shaped list with a leading sample dimension S on every level
        (``init_xi(key, batch=S)``). Returns (S, *final_shape).

        This is the batched-serving fast path (DESIGN.md §10): with
        ``use_pallas=True`` the sample dimension is threaded through the
        kernels natively — a whole sample slab per VMEM tile, matrices
        fetched once per tile — instead of being lifted into the launch
        grid the way ``jax.vmap(apply_sqrt)`` would. The reference path
        falls back to a vmap of the per-level jnp apply.
        """
        n_s = xi[0].shape[0]
        field = (xi[0] @ mats["sqrt0"].T).reshape(
            (n_s,) + self.chart.shape0)
        return self._refine_levels(mats, xi, field, sample_axis=True)

    def sample_batch(self, key, n: int, theta=None,
                     dtype=None) -> Array:
        """Draw ``n`` approximate GP samples in one batched application —
        (n, *final_shape). Amortizes every matrix load across the batch."""
        return self.apply_sqrt_batch(
            self.matrices(theta), self.init_xi(key, dtype, batch=n))

    def apply_sqrt_T(self, mats: dict, v: Array) -> List[Array]:
        """Apply sqrt(K_ICR)ᵀ to a field-space vector (paper §3.2, Eq. 3).

        The transpose of the generative map — the second half of one
        inference evaluation ("two applications of the square root and its
        VJP", paper §1) and the workhorse of Wiener-filter-style residual
        diagnostics ``sqrt(K)ᵀ (y − s)``. apply_sqrt is linear in ξ at fixed
        matrices, so the VJP at the origin IS the transpose; with
        ``use_pallas=True`` it runs the hand-written adjoint kernels level
        by level in reverse (kernels/icr_refine.py), never the jnp
        reference.

        v: (*final_shape)  ->  ξ-shaped list (see xi_shapes).

        Jitted (cached per instance) so XLA dead-code-eliminates the
        zero-ξ forward the VJP construction would otherwise execute — an
        eager call pays only the adjoint chain.
        """
        fn = self.__dict__.get("_apply_sqrt_T_jit")
        if fn is None:
            def transpose(mats, v):
                zero = self.zero_xi(dtype=v.dtype)
                _, vjp = jax.vjp(lambda xi: self.apply_sqrt(mats, xi), zero)
                return vjp(v)[0]

            fn = jax.jit(transpose)
            object.__setattr__(self, "_apply_sqrt_T_jit", fn)
        return fn(mats, v)

    def _stationary_level(self, lvl: int) -> bool:
        """True iff level `lvl` refines with a single shared stencil.

        Per-level, not per-chart: a charted axis whose family count is 1 at
        some level is stationary there (kept_T == 1), and a single charted
        axis makes the whole level non-stationary even when every other axis
        is invariant (the old ``all(chart.invariant)`` ignored `lvl` and
        both of these cases).
        """
        geom = LevelGeom.for_level(self.chart, lvl)
        return all(k == 1 for k in geom.kept_T)

    def __call__(self, xi: Sequence[Array],
                 theta: Mapping[str, Array] | None = None) -> Array:
        return self.apply_sqrt(self.matrices(theta), xi)

    def sample(self, key, theta=None, dtype=None) -> Array:
        """Draw one approximate GP sample (paper Alg. 1; dtype defaults to
        the policy's storage dtype)."""
        return self(self.init_xi(key, dtype), theta)

    # -- diagnostics ----------------------------------------------------------
    def implicit_sqrt(self, theta=None, dtype=jnp.float64) -> Array:
        """Dense sqrt(K_ICR) as an (N, n_xi) matrix via one jacobian.

        Only for small N (validation vs. the exact kernel, paper §5.1).
        """
        mats = self.matrices(theta)
        shapes = self.xi_shapes()
        sizes = [int(np.prod(s)) for s in shapes]

        def flat_apply(xi_flat):
            xs, o = [], 0
            for s, n in zip(shapes, sizes):
                xs.append(xi_flat[o : o + n].reshape(s))
                o += n
            return self.apply_sqrt(mats, xs).reshape(-1)

        return jax.jacfwd(flat_apply)(jnp.zeros(sum(sizes), dtype))

    def implicit_cov(self, theta=None, dtype=jnp.float64) -> Array:
        """Dense K_ICR = sqrt(K_ICR) sqrt(K_ICR)ᵀ (paper Fig. 3)."""
        a = self.implicit_sqrt(theta, dtype)
        return a @ a.T
