"""Coordinate charts and the regular refinement grid ladder (paper §4.2–4.3).

ICR refines a ladder of *regular Euclidean grids* (the chart codomain). The
user-provided chart ``phi_inv`` maps chart coordinates to the modeled space
``D``; the kernel is always evaluated at charted positions
``k(phi_inv(x), phi_inv(x'))`` (paper §4.3).

Geometry convention (paper §4.1, §4.4 and Fig. 1/2):

* level-l grid: per-axis size ``N_l``, spacing ``Δ_l``, origin ``o_l``.
* one refinement *family* sits on a central coarse pixel ``i`` and conditions
  ``n_fsz`` fine pixels on the ``n_csz`` nearest coarse pixels
  (``i-b … i+b`` with ``b = (n_csz-1)//2``).
* fine pixels have **half the coarse pixel volume** (paper §5.1): fine spacing
  is ``Δ_l / 2`` always; a family's children sit at
  ``c_i + (k - (n_fsz-1)/2) · Δ_l/2``. Consecutive families therefore stride
  ``n_fsz//2`` coarse pixels, which keeps the fine level a *regular* grid of
  spacing ``Δ_l/2`` (for (3,2) this reduces exactly to paper Eq. 11–13:
  ``N_{l+1} = 2 (N_l - 2)``).

Boundary handling:

* ``"shrink"`` — paper-faithful: border pixels without a full neighborhood are
  not refined, the grid loses ``n_csz - 1`` pixels per level (paper §4.2).
* ``"reflect"`` — production/sharded path: *every* stride-th pixel anchors a
  family; edge neighborhoods reflect out-of-range indices. The interior math
  is identical to "shrink"; only O(b) border families per level differ. This
  makes every refinement level an exact 2x of its parent, so spatial sharding
  is uniform across devices (see core/distributed.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np
import jax.numpy as jnp


def _as_tuple(x, ndim, name):
    if x is None:
        return None
    if np.isscalar(x):
        return (x,) * ndim
    t = tuple(x)
    if len(t) != ndim:
        raise ValueError(f"{name} must have length {ndim}, got {t}")
    return t


@dataclasses.dataclass(frozen=True)
class Chart:
    """Refinement grid ladder + coordinate chart.

    Attributes:
      shape0: per-axis level-0 grid size.
      n_levels: number of refinement steps (level 0 is the exact coarse grid).
      n_csz: coarse neighborhood size per axis (odd, >= 3).
      n_fsz: fine family size per axis (even, >= 2).
      delta0: level-0 spacing per axis in chart units.
      origin0: chart coordinate of pixel 0 per axis.
      boundary: "shrink" (paper) or "reflect" (uniform 2x, shardable).
      phi_inv: chart map, ``(..., ndim) -> (..., dim_D)``; ``None`` = identity.
      invariant: per-axis flags; True means the chart/kernel are translation
        invariant along that axis so refinement matrices are computed once and
        broadcast (paper §4.3 symmetry optimization).
    """

    shape0: tuple
    n_levels: int
    n_csz: int = 3
    n_fsz: int = 2
    delta0: tuple = None
    origin0: tuple = None
    boundary: str = "shrink"
    phi_inv: Callable = None
    invariant: tuple = None

    def __post_init__(self):
        shape0 = (self.shape0,) if np.isscalar(self.shape0) else tuple(self.shape0)
        object.__setattr__(self, "shape0", shape0)
        nd = len(shape0)
        object.__setattr__(
            self, "delta0", _as_tuple(self.delta0, nd, "delta0") or (1.0,) * nd
        )
        object.__setattr__(
            self, "origin0", _as_tuple(self.origin0, nd, "origin0") or (0.0,) * nd
        )
        inv = self.invariant
        if inv is None:
            # identity chart => fully invariant; custom chart => not invariant
            inv = (self.phi_inv is None,) * nd
        object.__setattr__(self, "invariant", _as_tuple(inv, nd, "invariant"))
        if self.n_csz % 2 != 1 or self.n_csz < 3:
            raise ValueError("n_csz must be odd and >= 3")
        if self.n_fsz % 2 != 0 or self.n_fsz < 2:
            raise ValueError("n_fsz must be even and >= 2")
        if self.boundary not in ("shrink", "reflect"):
            raise ValueError(f"unknown boundary {self.boundary!r}")
        for lvl in range(self.n_levels):
            for n in self.shape(lvl):
                if n < self.n_csz:
                    raise ValueError(
                        f"level {lvl} has size {n} < n_csz={self.n_csz}; "
                        "increase shape0 or reduce n_levels"
                    )

    # -- static geometry ----------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.shape0)

    @property
    def b(self) -> int:
        return (self.n_csz - 1) // 2

    @property
    def stride(self) -> int:
        return self.n_fsz // 2

    def family_count(self, level: int, axis: int) -> int:
        """Number of refinement families along `axis` refining level `level`."""
        n = self.shape(level)[axis]
        if self.boundary == "shrink":
            return (n - 2 * self.b - 1) // self.stride + 1
        if n % self.stride != 0:
            raise ValueError(
                f"reflect boundary requires size % (n_fsz//2) == 0, got {n}"
            )
        return n // self.stride

    def shape(self, level: int) -> tuple:
        """Per-axis grid size at `level` (0 = coarsest)."""
        s = self.shape0
        for lvl in range(level):
            s = tuple(
                self.n_fsz * self._family_count_for(n)
                for n in s
            )
        return s

    def _family_count_for(self, n: int) -> int:
        if self.boundary == "shrink":
            return (n - 2 * self.b - 1) // self.stride + 1
        return n // self.stride

    def delta(self, level: int) -> tuple:
        return tuple(d / (2.0**level) for d in self.delta0)

    def origin(self, level: int) -> tuple:
        o = list(self.origin0)
        for lvl in range(level):
            for a in range(self.ndim):
                da = self.delta0[a] / (2.0**lvl)
                anchor0 = self.b if self.boundary == "shrink" else 0
                o[a] = o[a] + anchor0 * da - (self.n_fsz - 1) * da / 4.0
        return tuple(o)

    @property
    def final_shape(self) -> tuple:
        return self.shape(self.n_levels)

    @property
    def size(self) -> int:
        return int(np.prod(self.final_shape))

    # -- chart coordinates ---------------------------------------------------
    def axis_coords(self, level: int, axis: int) -> np.ndarray:
        """Chart coordinates of all pixels along `axis` at `level`."""
        n = self.shape(level)[axis]
        return self.origin(level)[axis] + np.arange(n) * self.delta(level)[axis]

    def _family_centers_idx(self, level: int, axis: int) -> np.ndarray:
        t = np.arange(self.family_count(level, axis))
        anchor0 = self.b if self.boundary == "shrink" else 0
        return anchor0 + t * self.stride

    def axis_coarse_windows(self, level: int, axis: int) -> np.ndarray:
        """(T_a, n_csz) chart coords of each family's coarse neighbors."""
        n = self.shape(level)[axis]
        centers = self._family_centers_idx(level, axis)
        idx = centers[:, None] + np.arange(-self.b, self.b + 1)[None, :]
        if self.boundary == "reflect":
            idx = np.abs(idx)
            idx = np.minimum(idx, 2 * (n - 1) - idx)
        else:
            assert (idx >= 0).all() and (idx < n).all()
        return self.origin(level)[axis] + idx * self.delta(level)[axis]

    def axis_fine_windows(self, level: int, axis: int) -> np.ndarray:
        """(T_a, n_fsz) chart coords of each family's fine children."""
        centers = self._family_centers_idx(level, axis)
        d = self.delta(level)[axis]
        c = self.origin(level)[axis] + centers * d
        off = (np.arange(self.n_fsz) - (self.n_fsz - 1) / 2.0) * d / 2.0
        return c[:, None] + off[None, :]

    def grid_positions(self, level: int) -> jnp.ndarray:
        """All charted positions at `level`, shape (prod(shape_l), dim_D).

        Only call on small levels (tests, level-0 exact sqrt).
        """
        axes = [self.axis_coords(level, a) for a in range(self.ndim)]
        mesh = np.stack(np.meshgrid(*axes, indexing="ij"), axis=-1)
        pts = mesh.reshape(-1, self.ndim)
        return self.map_to_D(jnp.asarray(pts))

    def map_to_D(self, chart_pts: jnp.ndarray) -> jnp.ndarray:
        """Map chart coordinates (..., ndim) to the modeled space D."""
        if self.phi_inv is None:
            return chart_pts
        out = self.phi_inv(chart_pts)
        if out.ndim == chart_pts.ndim - 1:  # scalar-valued map
            out = out[..., None]
        return out


# -- common chart constructors ------------------------------------------------
def regular_chart(shape0, n_levels, *, n_csz=3, n_fsz=2, delta0=1.0,
                  boundary="shrink") -> Chart:
    """Identity chart: regularly spaced modeled points (paper §4.1–4.2)."""
    return Chart(shape0=shape0, n_levels=n_levels, n_csz=n_csz, n_fsz=n_fsz,
                 delta0=delta0, boundary=boundary, phi_inv=None)


def log_chart(shape0, n_levels, *, n_csz=3, n_fsz=2, delta0=1.0, origin0=0.0,
              base_scale=1.0, boundary="shrink") -> Chart:
    """1-D logarithmic chart: ``phi_inv(x) = base_scale * exp(x)``.

    This is the paper's §5 experimental setup — nearest-neighbor distances of
    the modeled points vary exponentially along the grid.
    """

    def phi_inv(x):
        return base_scale * jnp.exp(x)

    return Chart(shape0=shape0, n_levels=n_levels, n_csz=n_csz, n_fsz=n_fsz,
                 delta0=delta0, origin0=origin0, boundary=boundary,
                 phi_inv=phi_inv, invariant=(False,))


def log_polar_chart(shape0, n_levels, *, n_csz=3, n_fsz=2, delta_logr=0.05,
                    origin_logr=0.0, boundary="reflect") -> Chart:
    """2-D chart (log-r, azimuth) -> R^2; azimuth axis is *rotation* invariant
    only at fixed r, so neither axis is globally invariant; we still mark the
    angular axis non-invariant and rely on per-pixel matrices. Used in tests.
    """

    def phi_inv(x):
        r = jnp.exp(x[..., 0])
        phi = x[..., 1]
        return jnp.stack([r * jnp.cos(phi), r * jnp.sin(phi)], axis=-1)

    n_phi = shape0[1] if not np.isscalar(shape0) else shape0
    return Chart(shape0=shape0, n_levels=n_levels, n_csz=n_csz, n_fsz=n_fsz,
                 delta0=(delta_logr, 2 * math.pi / n_phi),
                 origin0=(origin_logr, 0.0), boundary=boundary,
                 phi_inv=phi_inv, invariant=(False, False))


def galactic_dust_chart(shape0, n_levels, *, n_csz=5, n_fsz=4,
                        delta_logr=0.02, origin_logr=0.0,
                        angular_extent=1.0, boundary="reflect") -> Chart:
    """3-D (log-r, u, v) chart used for the Galactic dust application
    (paper §6, ref [24]): logarithmic radial axis, locally-flat angular axes.

    The angular axes are treated as translation invariant (flat-sky
    approximation at each radial shell scaled into the chart), so refinement
    matrices are computed per-radial-pixel only and broadcast over angles —
    the §4.3 symmetry optimization that made the 122-billion-DOF run feasible.
    """

    def phi_inv(x):
        # Radial distance enters the kernel in log-space-scaled Euclidean
        # coordinates: locally the metric is ~ (dr, r*du, r*dv); we absorb the
        # r factor into the invariant-axis approximation and use chart-space
        # distances scaled by base radius. Distances along (u, v) are chart
        # distances (flat patch); along log-r we map to true radii.
        r = jnp.exp(x[..., 0])
        return jnp.stack([r, x[..., 1], x[..., 2]], axis=-1)

    d_ang = angular_extent / (shape0[1] if not np.isscalar(shape0) else shape0)
    return Chart(shape0=shape0, n_levels=n_levels, n_csz=n_csz, n_fsz=n_fsz,
                 delta0=(delta_logr, d_ang, d_ang),
                 origin0=(origin_logr, 0.0, 0.0), boundary=boundary,
                 phi_inv=phi_inv, invariant=(False, True, True))
