"""Inference drivers over the standardized model (paper §3.2, Eq. 3).

The joint is ``log p(y, ξ) = log p(y | s(ξ)) - ||ξ||²/2 + const``; because
``s(ξ) = sqrt(K_ICR)(ξ_s)`` the evaluation (and its gradient) never inverts
the kernel matrix — the paper's central point. We provide:

* ``map_fit`` — MAP over ξ (the mode of Eq. 3),
* ``advi_fit`` — mean-field Gaussian VI with the reparametrization trick,
  the "popular choice" referenced by the paper (§3.2, refs [15–17]).

Both work with arbitrary (non-Gaussian) likelihoods.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import adamw, linear_warmup_cosine

PyTree = Any


def _tree_sqnorm(t):
    return sum(jnp.sum(jnp.square(x)) for x in jax.tree_util.tree_leaves(t))


def neg_log_joint(log_likelihood: Callable, forward: Callable):
    """-log p(y, ξ) up to a constant (paper Eq. 3)."""

    def loss(xi, y):
        s = forward(xi)
        return -log_likelihood(y, s) + 0.5 * _tree_sqnorm(xi)

    return loss


def map_fit(log_likelihood, forward, xi0: PyTree, y,
            steps: int = 300, lr: float = 3e-2, jit: bool = True):
    """MAP estimate of ξ (deterministic — no PRNG key involved).

    Returns (xi_hat, losses). `forward` may route through the fused Pallas
    path (``ICR(use_pallas=True)``): every gradient step then runs the
    hand-written adjoint kernels, not the jnp reference. With ``jit=True``
    the whole scan is compiled once; ``jit=False`` runs op-by-op (debugging).
    """
    loss_fn = neg_log_joint(log_likelihood, forward)
    opt = adamw(linear_warmup_cosine(lr, steps // 10 + 1, steps),
                weight_decay=0.0)

    def step(carry, _):
        xi, st = carry
        l, g = jax.value_and_grad(loss_fn)(xi, y)
        xi, st = opt.update(g, st, xi)
        return (xi, st), l

    def run(xi0, state):
        return jax.lax.scan(step, (xi0, state), None, length=steps)

    if jit:
        run = jax.jit(run)
    (xi, _), losses = run(xi0, opt.init(xi0))
    return xi, losses


def advi_fit(key, log_likelihood, forward, xi0: PyTree, y,
             steps: int = 300, lr: float = 2e-2, n_mc: int = 2):
    """Mean-field ADVI over ξ with closed-form Gaussian KL.

    Returns ((mean, log_std), elbo_trace); sample via
    ``mean + exp(log_std) * eps``.
    """
    mean0 = xi0
    logstd0 = jax.tree.map(lambda x: jnp.full_like(x, -2.0), xi0)
    params0 = (mean0, logstd0)

    def elbo_loss(params, key, y):
        mean, logstd = params

        def one(k):
            leaves, treedef = jax.tree_util.tree_flatten(mean)
            ks = jax.random.split(k, len(leaves))
            eps = [jax.random.normal(kk, l.shape, l.dtype)
                   for kk, l in zip(ks, leaves)]
            eps = jax.tree_util.tree_unflatten(treedef, eps)
            xi = jax.tree.map(lambda m, ls, e: m + jnp.exp(ls) * e,
                              mean, logstd, eps)
            return log_likelihood(y, forward(xi))

        ll = jnp.mean(jax.vmap(one)(jax.random.split(key, n_mc)))
        # KL(q || N(0,1)) closed form, per-leaf
        kl = sum(
            jnp.sum(0.5 * (jnp.exp(2 * ls) + jnp.square(m) - 1.0) - ls)
            for m, ls in zip(jax.tree_util.tree_leaves(mean),
                             jax.tree_util.tree_leaves(logstd))
        )
        return -(ll - kl)

    opt = adamw(linear_warmup_cosine(lr, steps // 10 + 1, steps),
                weight_decay=0.0)
    state = opt.init(params0)

    def step(carry, k):
        params, st = carry
        l, g = jax.value_and_grad(elbo_loss)(params, k, y)
        params, st = opt.update(g, st, params)
        return (params, st), -l

    keys = jax.random.split(key, steps)
    (params, _), elbos = jax.lax.scan(step, (params0, state), keys)
    return params, elbos


# -- posterior export (the serving handoff, DESIGN.md §12) ---------------------
@dataclasses.dataclass(frozen=True)
class Posterior:
    """Self-contained GP posterior product — what a fit hands the server.

    ``q(ξ)`` is mean-field Gaussian over the excitations: ``mean`` is a
    ξ-shaped list (one array per refinement level); ``log_std`` is ξ-shaped
    too, or None for a MAP fit's delta posterior (every draw IS ξ̂ and the
    predictive std is exactly zero). ``theta`` holds the fitted kernel
    parameters. The ICR instance pins the chart geometry and the dtype
    policy, so ``(icr, theta)`` is the complete serving cache key
    (``ICR.matrices_cached`` / ``launch.serve_gp``): repeat traffic against
    the same posterior never rebuilds matrices or recompiles.

    A posterior *field* draw is ``sqrt(K_ICR)(mean + exp(log_std)·ε)`` —
    one application of the square root per sample (paper §1), which is why
    many-sample serving rides ``ICR.apply_sqrt_batch`` (the §10 sample-slab
    path) rather than a per-sample loop.
    """

    icr: Any
    mean: PyTree
    log_std: PyTree = None
    theta: Any = None

    def matrices(self) -> dict:
        """The (cached) refinement matrices at the fitted θ."""
        return self.icr.matrices_cached(self.theta)

    def std(self):
        """Per-level excitation std (zeros for a MAP delta posterior)."""
        if self.log_std is None:
            return [jnp.zeros_like(m) for m in self.mean]
        return [jnp.exp(ls) for ls in self.log_std]

    def sample_xi(self, key, n: int):
        """n ξ draws from q, sample dim leading (the apply_sqrt_batch
        layout)."""
        if self.log_std is None:
            return [jnp.broadcast_to(m, (n,) + m.shape) for m in self.mean]
        keys = jax.random.split(key, len(self.mean))
        return [
            m[None] + jnp.exp(ls)[None]
            * jax.random.normal(k, (n,) + m.shape, m.dtype)
            for m, ls, k in zip(self.mean, self.log_std, keys)
        ]

    def sample_fields(self, key, n: int):
        """n posterior field draws, (n, *final_shape) — the convenience
        path for small n; serving traffic goes through launch.serve_gp's
        slab packing instead."""
        return self.icr.apply_sqrt_batch(self.matrices(), self.sample_xi(key, n))

    def moments(self, key, n: int):
        """MC predictive mean/std over n draws (one batched application)."""
        f = self.sample_fields(key, n)
        return jnp.mean(f, axis=0), jnp.std(f, axis=0)


def map_posterior(icr, xi_hat: PyTree, theta=None) -> Posterior:
    """Export a MAP fit (``map_fit``'s ξ̂) as a delta Posterior."""
    return Posterior(icr=icr, mean=list(xi_hat), theta=theta)


def advi_posterior(icr, params, theta=None) -> Posterior:
    """Export an ADVI fit (``advi_fit``'s ``(mean, log_std)``)."""
    mean, log_std = params
    return Posterior(icr=icr, mean=list(mean), log_std=list(log_std),
                     theta=theta)


def cg_posterior(icr, obs, y, *, noise_std: float = 0.05, theta=None,
                 config=None, use_precond: bool = True,
                 dense_fallback: bool = True, mesh=None, manager=None,
                 checkpoint_every: int = 0) -> tuple:
    """Exact data-conditioned posterior via guarded batched CG (§16).

    Solves ``(W K Wᵀ + σ²I) α = y`` matrix-free — the covariance action
    is two ICR square-root applications per matvec — then whitens the
    correction: ``ξ̂ = Sᵀ Wᵀ α``, so the returned delta
    :class:`Posterior` (``mean = ξ̂``, ``log_std = None``) reproduces the
    exact GP regression posterior mean ``K Wᵀ α`` through the ordinary
    serving path (``sqrt(K)(ξ̂)``), with θ/chart/caching semantics
    unchanged.

    ``obs`` is an observation spec: flat finest-grid indices (any
    dimension), off-grid 1-D locations (float array — KISS-GP sparse
    interpolation rows), or a prebuilt operator from
    ``solvers.gp_system``. The solve runs the fallback ladder
    (ICR-whitened preconditioner → unpreconditioned → dense for small
    charts) with per-RHS quarantine isolation; ``manager`` +
    ``checkpoint_every`` opt into preemption-safe checkpointing and
    ``mesh`` shards the matvec over the RHS axis.

    Returns ``(posterior, report)`` — the report is the structured
    :class:`~repro.solvers.SolveReport` (iterations, residuals, fallback
    path, quarantined RHS).
    """
    import numpy as np

    from repro.solvers import (CGConfig, build_condition_system,
                               solve_guarded)
    from repro.solvers.gp_system import obs_operator

    if hasattr(obs, "apply") and hasattr(obs, "apply_t"):
        op = obs
    else:
        arr = np.asarray(obs)
        if np.issubdtype(arr.dtype, np.integer):
            op = obs_operator(icr, obs_idx=arr)
        else:
            op = obs_operator(icr, x_obs=arr)
    y = jnp.asarray(y, jnp.float32).reshape(1, -1)
    if y.shape[1] != op.n_obs:
        raise ValueError(f"y has {y.shape[1]} entries but the observation "
                         f"operator expects {op.n_obs}")
    system = build_condition_system(icr, op, float(noise_std) ** 2,
                                    theta=theta, mesh=mesh,
                                    use_precond=use_precond)
    cfg = config or CGConfig(rtol=1e-7, max_iters=max(4 * op.n_obs, 200))
    ladder = ([("icr", system.precond)] if system.precond is not None
              else []) + [("none", None)]
    alpha, report = solve_guarded(
        system.matvec, y, preconds=ladder,
        dense_solve=system.dense_solve if dense_fallback else None,
        cfg=cfg, manager=manager, checkpoint_every=checkpoint_every,
        tag="cg_posterior")
    xi_hat = system.project_xi(jnp.asarray(alpha))
    mean = [leaf[0] for leaf in xi_hat]
    return Posterior(icr=icr, mean=mean, theta=theta), report


def gaussian_log_likelihood(noise_std: float, obs_idx=None):
    """Factory: Gaussian likelihood on (a subset of) the field."""

    def ll(y, s):
        pred = s.reshape(-1)[obs_idx] if obs_idx is not None else s.reshape(-1)
        r = (y - pred) / noise_std
        return -0.5 * jnp.sum(jnp.square(r))

    return ll


def poisson_log_likelihood(obs_idx=None):
    """Poisson counts with log-rate = field — a non-Gaussian likelihood
    exercising the 'arbitrary likelihood' claim of paper §3.2."""

    def ll(y, s):
        lam = s.reshape(-1)[obs_idx] if obs_idx is not None else s.reshape(-1)
        return jnp.sum(y * lam - jnp.exp(lam) - jax.lax.lgamma(y + 1.0))

    return ll
