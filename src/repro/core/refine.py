"""Refinement matrices and one refinement step (paper §4.1–4.4).

A refinement family conditions ``n_fsz^d`` fine pixels on their ``n_csz^d``
nearest coarse pixels:

    R      = K_fc K_cc^{-1}                      (paper Eq. 7)
    D      = K_ff − K_fc K_cc^{-1} K_cf          (paper Eq. 8)
    s_f    = R s_c + sqrt(D) ξ_f                 (paper Eq. 9)

On chart-invariant axes the matrices are identical for every family along
that axis and are broadcast (paper §4.3). The reference apply path below is
pure jnp; the TPU hot path lives in repro.kernels (Pallas).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .charts import Chart
from .kernels import kernel_matrix

Array = jnp.ndarray


def _family_positions(chart: Chart, level: int):
    """Per-axis chart coords of family windows, collapsed on invariant axes.

    Returns (coarse_axes, fine_axes, full_T, kept_T):
      coarse_axes[a]: (T'_a, n_csz) chart coords (T'_a == 1 if invariant)
      fine_axes[a]:   (T'_a, n_fsz)
      full_T: true family counts per axis; kept_T: materialized counts.
    """
    coarse_axes, fine_axes, full_T, kept_T = [], [], [], []
    for a in range(chart.ndim):
        cw = chart.axis_coarse_windows(level, a)
        fw = chart.axis_fine_windows(level, a)
        full_T.append(cw.shape[0])
        if chart.invariant[a]:
            # representative family: interior one (away from reflect boundary)
            rep = min(cw.shape[0] - 1, chart.b)
            cw, fw = cw[rep : rep + 1], fw[rep : rep + 1]
        coarse_axes.append(cw)
        fine_axes.append(fw)
        kept_T.append(cw.shape[0])
    return coarse_axes, fine_axes, tuple(full_T), tuple(kept_T)


def _psd_sqrt(mat: Array, eps: Array) -> Array:
    """Square root of a (nearly) PSD matrix via eigh with eigenvalue clipping.

    The paper only requires SOME sqrt with sqrt·sqrtᵀ = D (§3.2: "the
    square-root ... is not uniquely defined"). For strongly correlated fine
    points D is numerically semi-definite in f32; eigh+clip is robust where
    Cholesky NaNs.
    """
    evals, evecs = jnp.linalg.eigh(mat)
    evals = jnp.maximum(evals, eps)
    return evecs * jnp.sqrt(evals)[..., None, :]


def _nd_points(axes_windows: Sequence[Array]) -> Array:
    """Tensor-product of per-axis window coords -> (..., W^d, ndim).

    axes_windows[a]: (w_a,) chart coords along axis a for ONE family.
    Returns (prod(w_a), ndim).
    """
    grids = jnp.meshgrid(*axes_windows, indexing="ij")
    return jnp.stack([g.reshape(-1) for g in grids], axis=-1)


def refinement_matrices_level(chart: Chart, kernel_fn: Callable, level: int,
                              *, jitter: float = 1e-6):
    """Refinement matrices (R, sqrt(D)) for all families refining `level`.

    Returns (R, sqrtD) with leading dims = kept_T (invariant axes collapsed
    to 1): R: (*kept_T, n_fsz^d, n_csz^d), sqrtD: (*kept_T, n_fsz^d, n_fsz^d).
    """
    coarse_axes, fine_axes, full_T, kept_T = _family_positions(chart, level)
    nd = chart.ndim
    csz, fsz = chart.n_csz**nd, chart.n_fsz**nd

    def one_family(cws, fws):
        # cws[a]: (n_csz,), fws[a]: (n_fsz,) chart coords
        cpos = chart.map_to_D(_nd_points(cws))  # (csz, dim_D)
        fpos = chart.map_to_D(_nd_points(fws))  # (fsz, dim_D)
        k_cc = kernel_matrix(kernel_fn, cpos)
        k_fc = kernel_matrix(kernel_fn, fpos, cpos)
        k_ff = kernel_matrix(kernel_fn, fpos)
        eps = jitter * jnp.mean(jnp.diag(k_cc))
        k_cc = k_cc + eps * jnp.eye(csz, dtype=k_cc.dtype)
        r = jnp.linalg.solve(k_cc, k_fc.T).T              # (fsz, csz), Eq. 7
        d = k_ff - r @ k_fc.T                             # Eq. 8
        d = 0.5 * (d + d.T)
        sqrt_d = _psd_sqrt(d, jitter * jnp.mean(jnp.diag(k_ff)))
        return r, sqrt_d

    fn = one_family
    # vmap over each axis' family dimension (size 1 on invariant axes)
    for a in reversed(range(nd)):
        in_axes = ([0 if i == a else None for i in range(nd)],
                   [0 if i == a else None for i in range(nd)])
        fn = jax.vmap(fn, in_axes=in_axes)
    cws = [jnp.asarray(coarse_axes[a]) for a in range(nd)]
    fws = [jnp.asarray(fine_axes[a]) for a in range(nd)]
    r, sqrt_d = fn(cws, fws)
    return r, sqrt_d


def axis_refinement_matrices_level(chart: Chart, kernel_fn: Callable,
                                   level: int, *, jitter: float = 1e-6):
    """Per-axis 1-D refinement factors for the separable N-D fast path.

    For each chart axis ``a`` this computes 1-D refinement matrices (Eq. 7/8)
    from the axis-``a`` coarse/fine windows, with every other coordinate
    pinned at a representative chart position (the grid midpoint). The fused
    N-D path (repro.kernels.nd) applies them as a sequence of per-axis
    passes, which is exactly the Kronecker-factored refinement

        R_joint = R_0 ⊗ ... ⊗ R_{d-1},   sqrtD_joint = sqrtD_0 ⊗ ...

    For product (separable) kernels the interpolation factorization of R is
    exact; for isotropic kernels it is the nearest-separable surrogate in the
    spirit of the paper's §4.3 chart approximations (and of KISS-GP-style
    Kronecker interpolation). The noise factors are normalized so the product
    carries the kernel variance ``k(0)`` exactly once.

    Returns ``(rs, ds)``: ``rs[a]`` is ``(n_fsz, n_csz)`` on invariant axes,
    else ``(T_a, n_fsz, n_csz)``; ``ds[a]`` likewise with ``n_csz -> n_fsz``.
    """
    nd = chart.ndim
    csz, fsz = chart.n_csz, chart.n_fsz
    k0 = kernel_matrix(kernel_fn, jnp.zeros((1, max(1, nd))))[0, 0]
    rep_coord = [
        chart.axis_coords(level, o)[chart.shape(level)[o] // 2]
        for o in range(nd)
    ]

    rs, ds = [], []
    for a in range(nd):
        cw = jnp.asarray(chart.axis_coarse_windows(level, a))  # (T_a, csz)
        fw = jnp.asarray(chart.axis_fine_windows(level, a))    # (T_a, fsz)
        if chart.invariant[a]:
            rep = min(cw.shape[0] - 1, chart.b)
            cw, fw = cw[rep : rep + 1], fw[rep : rep + 1]

        def one_family(cw_t, fw_t, axis=a):
            def pts(wins):
                cols = [
                    wins if o == axis
                    else jnp.full(wins.shape, rep_coord[o], wins.dtype)
                    for o in range(nd)
                ]
                return chart.map_to_D(jnp.stack(cols, axis=-1))

            cpos, fpos = pts(cw_t), pts(fw_t)
            k_cc = kernel_matrix(kernel_fn, cpos)
            k_fc = kernel_matrix(kernel_fn, fpos, cpos)
            k_ff = kernel_matrix(kernel_fn, fpos)
            eps = jitter * jnp.mean(jnp.diag(k_cc))
            k_cc = k_cc + eps * jnp.eye(csz, dtype=k_cc.dtype)
            r = jnp.linalg.solve(k_cc, k_fc.T).T
            d = k_ff - r @ k_fc.T
            d = 0.5 * (d + d.T)
            if axis > 0:  # variance enters the Kronecker product once
                d = d / k0
                k_ff = k_ff / k0
            return r, _psd_sqrt(d, jitter * jnp.mean(jnp.diag(k_ff)))

        r, sqrt_d = jax.vmap(one_family)(cw, fw)
        if chart.invariant[a]:
            r, sqrt_d = r[0], sqrt_d[0]
        rs.append(r)
        ds.append(sqrt_d)
    return rs, ds


def level0_sqrt(chart: Chart, kernel_fn: Callable, *, jitter: float = 1e-6):
    """Exact Cholesky sqrt of the level-0 kernel matrix (small by design)."""
    pos = chart.grid_positions(0)
    k = kernel_matrix(kernel_fn, pos)
    return _psd_sqrt(0.5 * (k + k.T), jitter * jnp.mean(jnp.diag(k)))


@dataclasses.dataclass(frozen=True)
class LevelGeom:
    """Static geometry of one refinement application (trace-time constants)."""

    coarse_shape: tuple
    fine_shape: tuple
    T: tuple          # families per axis
    kept_T: tuple     # materialized matrix counts per axis (1 on invariant)
    n_csz: int
    n_fsz: int
    stride: int
    b: int
    boundary: str

    @classmethod
    def for_level(cls, chart: Chart, level: int) -> "LevelGeom":
        _, _, full_T, kept_T = _family_positions(chart, level)
        return cls(
            coarse_shape=chart.shape(level),
            fine_shape=chart.shape(level + 1),
            T=full_T,
            kept_T=kept_T,
            n_csz=chart.n_csz,
            n_fsz=chart.n_fsz,
            stride=chart.stride,
            b=chart.b,
            boundary=chart.boundary,
        )

    def axis(self, a: int) -> "LevelGeom":
        """1-D geometry of the per-axis pass along `a` (N-D fast path)."""
        return LevelGeom(
            coarse_shape=(self.coarse_shape[a],),
            fine_shape=(self.T[a] * self.n_fsz,),
            T=(self.T[a],),
            kept_T=(self.kept_T[a],),
            n_csz=self.n_csz,
            n_fsz=self.n_fsz,
            stride=self.stride,
            b=self.b,
            boundary=self.boundary,
        )


def _axis_windows(arr: Array, axis: int, geom: LevelGeom) -> Array:
    """Extract per-family coarse windows along `axis` with shifted strided
    slices (TPU-friendly: no gather). Appends a window dim at the end.

    arr: (..., N_axis, ...) -> (..., T_axis, ..., n_csz) with the window dim
    appended as the new last dimension.
    """
    t = geom.T[axis]
    if geom.boundary == "reflect":
        b = geom.b
        pad = [(0, 0)] * arr.ndim
        pad[axis] = (b, b)
        arr = jnp.pad(arr, pad, mode="reflect")
    slices = []
    for k in range(geom.n_csz):
        limit = k + geom.stride * (t - 1) + 1
        slices.append(
            lax.slice_in_dim(arr, k, limit, stride=geom.stride, axis=axis)
        )
    return jnp.stack(slices, axis=-1)


def refine_level(coarse: Array, xi: Array, r: Array, sqrt_d: Array,
                 geom: LevelGeom) -> Array:
    """One refinement application (paper Eq. 9 / Alg. 1 inner loop).

    coarse: (*coarse_shape); xi: (prod(T), n_fsz^d)
    r: (*kept_T, fsz^d, csz^d); sqrt_d: (*kept_T, fsz^d, fsz^d)
    Returns fine field (*fine_shape).
    """
    nd = len(geom.coarse_shape)
    w = coarse
    for a in range(nd):
        w = _axis_windows(w, a, geom)
    # w: (T_0..T_{nd-1}, csz, csz, ...) -> (*T, csz^d)
    csz, fsz = geom.n_csz**nd, geom.n_fsz**nd
    w = w.reshape(geom.T + (csz,))

    # Batched GEMM over the NON-invariant family dims only: invariant axes
    # become the GEMM row dim, so the shared matrices are NEVER broadcast-
    # materialized to (F, fsz, csz) — at dust-map scale that expansion is
    # ~100 GB/device (EXPERIMENTS.md §Perf iteration 4).
    kept_axes = [a for a in range(nd) if geom.kept_T[a] > 1]
    inv_axes = [a for a in range(nd) if geom.kept_T[a] == 1]
    perm = kept_axes + inv_axes
    k_tot = int(np.prod([geom.T[a] for a in kept_axes])) or 1
    i_tot = int(np.prod([geom.T[a] for a in inv_axes])) or 1

    w_p = w.transpose(perm + [nd]).reshape(k_tot, i_tot, csz)
    xi_p = xi.reshape(geom.T + (fsz,)).transpose(perm + [nd]) \
        .reshape(k_tot, i_tot, fsz)
    r_b = r.reshape(k_tot, fsz, csz)
    d_b = sqrt_d.reshape(k_tot, fsz, fsz)

    fine = jnp.einsum("kic,kfc->kif", w_p, r_b)
    fine = fine + jnp.einsum("kif,kgf->kig", xi_p, d_b)

    # back to (*T, fsz^d), then interleave family and child dims
    t_perm = [geom.T[a] for a in perm]
    inv_perm = [perm.index(a) for a in range(nd)]
    fine = fine.reshape(t_perm + [fsz]).transpose(inv_perm + [nd])
    fine = fine.reshape(geom.T + (geom.n_fsz,) * nd)
    interleave = []
    for a in range(nd):
        interleave += [a, nd + a]
    fine = fine.transpose(interleave)
    return fine.reshape(geom.fine_shape)


def refine_level_T(fine_cot: Array, r: Array, sqrt_d: Array,
                   geom: LevelGeom):
    """Adjoint of ``refine_level`` in (coarse, xi) at fixed matrices.

    The refinement application is linear in (coarse, xi), so its VJP at the
    origin IS the transpose operator. This is the jnp reference the fused
    adjoint kernels (repro.kernels) are validated against, and the per-level
    building block of ``ICR.apply_sqrt_T``.

    fine_cot: (*fine_shape) -> (dcoarse: (*coarse_shape),
    dxi: (prod(T), n_fsz^d)).
    """
    nd = len(geom.coarse_shape)
    zc = jnp.zeros(geom.coarse_shape, fine_cot.dtype)
    zx = jnp.zeros((int(np.prod(geom.T)), geom.n_fsz**nd), fine_cot.dtype)
    _, vjp = jax.vjp(lambda c, x: refine_level(c, x, r, sqrt_d, geom), zc, zx)
    return vjp(fine_cot)
