"""Standardization of model parameters (paper §3.2).

Every parameter is expressed as a deterministic map of a standard-normal
latent ξ: ``theta = CDF_theta^{-1}(CDF_xi(xi))`` (inverse transform sampling,
paper §3.2). After standardization the joint density is Eq. 3 — a Gaussian
prior over ξ plus the likelihood — with no kernel inversion/log-det anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm as _norm

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Prior:
    """A 1-D prior as a push-forward of N(0, 1)."""

    name: str
    forward: Callable[[Array], Array]  # xi -> theta

    def __call__(self, xi: Array) -> Array:
        return self.forward(xi)


def lognormal_prior(mean: float, std: float) -> Prior:
    """LogNormal with the given *linear-space* mean/std."""
    s2 = jnp.log1p((std / mean) ** 2)
    mu = jnp.log(mean) - 0.5 * s2
    sig = jnp.sqrt(s2)
    return Prior("lognormal", lambda xi: jnp.exp(mu + sig * xi))


def normal_prior(mean: float, std: float) -> Prior:
    return Prior("normal", lambda xi: mean + std * xi)


def uniform_prior(lo: float, hi: float) -> Prior:
    return Prior("uniform", lambda xi: lo + (hi - lo) * _norm.cdf(xi))


@dataclasses.dataclass(frozen=True)
class StandardizedModel:
    """Bundle of named priors: maps flat standard-normal dict -> theta dict."""

    priors: Mapping[str, Prior]

    def init_xi(self, key) -> dict:
        ks = jax.random.split(key, len(self.priors))
        return {n: 0.1 * jax.random.normal(k, ()) for n, k in
                zip(sorted(self.priors), ks)}

    def zero_xi(self) -> dict:
        return {n: jnp.zeros(()) for n in sorted(self.priors)}

    def __call__(self, xi: Mapping[str, Array]) -> dict:
        return {n: self.priors[n](xi[n]) for n in self.priors}
