"""VMEM-resident pyramid kernel: many refinement levels, ONE launch
(DESIGN.md §11).

Every per-level route — even the §10 megakernel — writes its fine field to
HBM and reads it back as the next level's coarse input. For the *early*
levels of a chart that round trip is pure waste: a 3-D chart's levels grow
8x per step, so the first k levels are tiny and their combined working set
(fields + excitations + matrices) fits comfortably in VMEM. This module
collapses all consecutive early levels whose combined working set fits the
VMEM budget (``dispatch.autotune_pyramid`` owns the residency criterion)
into one ``pallas_call``:

  * the grid runs over sample slabs only (``s_b`` samples per step) — no
    spatial tiling, the whole field of every covered level is resident,
  * each level's body is the §10 contraction chain at full extent: reflect
    pad (in VMEM, via flip+concat — no HBM pre-pad), per-axis
    window-from-reshape (`_axis_windows`) + Kronecker contraction, then the
    fused noise add ``sqrt(D_0)·ξ`` (trailing noise factors pre-contracted
    into ξ outside, exactly like §10),
  * the fine field of level l feeds level l+1 *in registers/VMEM* — the
    inter-level HBM field traffic of the covered prefix is ZERO,
  * only the final level's field is written to HBM.

HBM traffic for the covered prefix drops from ``Σ_l (read L_l + read ξ_l +
write N_l)`` to ``read L_0 + Σ_l read ξ_l + write N_{k-1}`` (+ matrices) —
``roofline.level_traffic`` carries the per-level model (``route=
"pyramid"`` with first/last flags).

1-D charts are covered too (the per-axis factor list has one entry); the
dtype policy (§11) threads through: storage dtype = operand dtype, every
contraction accumulates in ``accum_dtype``, and each level's in-VMEM output
is rounded to the storage dtype so the pyramid is numerically identical to
the level-by-level routes under the same policy.

Backward: the core carries a ``jax.custom_vjp`` that replays an
*independent jnp reference* of the same chain under ``jax.vjp`` — at fixed
matrices only w.r.t. (field, ξ) (the chain is linear there, and the
parameter-sized window einsums are gated by ``symbolic_zeros`` exactly like
§9/§10). The covered levels are by construction the smallest in the chart
(<= a VMEM's worth of work), so an HBM-roundtripping backward is a rounding
error next to the uncovered big levels; the forward is where the pyramid
pays for itself.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.custom_derivatives import SymbolicZero

from .launch import IndexMap, LaunchPlan, OperandSpec, run_plan
from .nd_fused import (
    _axis_windows,
    _contract_windows,
    _slice_axis,
    prepare_xi0,
)

Array = jnp.ndarray


def _reflect_pad_axis(x: Array, ax: int, b: int) -> Array:
    """jnp.pad(mode="reflect") along one axis from flips + concat — the
    in-VMEM form (Pallas-safe: static slices + lax.rev, no gather)."""
    if b == 0:
        return x
    lo = [slice(None)] * x.ndim
    lo[ax] = slice(1, b + 1)
    hi = [slice(None)] * x.ndim
    hi[ax] = slice(-b - 1, -1)
    return jnp.concatenate(
        [jnp.flip(x[tuple(lo)], axis=ax), x, jnp.flip(x[tuple(hi)], axis=ax)],
        axis=ax,
    )


def _fit_axis(x: Array, ax: int, want: int) -> Array:
    """Slice or zero-pad axis ``ax`` to exactly ``want`` — the window build
    needs ``(T + q_max)·s`` elements, never more (§10 tile rule)."""
    have = x.shape[ax]
    if have > want:
        return _slice_axis(x, ax, want)
    if have < want:
        pads = [(0, 0)] * x.ndim
        pads[ax] = (0, want - have)
        return jnp.pad(x, pads)
    return x


def _level_body(x: Array, xi0: Array, rl, d0, *, T: tuple, csz: int,
                fsz: int, boundary: str, b: int, accum, storage) -> Array:
    """One refinement level at full extent, entirely in VMEM.

    x: (s_b, *coarse_shape) -> (s_b, *fine_shape); xi0: (s_b, T0·fsz,
    prod_f) with trailing noise factors pre-contracted (§10 layout).
    """
    nd = len(T)
    s = fsz // 2
    q_max = (csz - 1) // s
    s_b = x.shape[0]

    if boundary == "reflect":
        for a in range(nd):
            x = _reflect_pad_axis(x, 1 + a, b)
    for a in range(nd):
        x = _fit_axis(x, 1 + a, (T[a] + q_max) * s)

    for a in range(nd - 1, 0, -1):
        ax = 1 + a
        w = _axis_windows(x, ax, T[a], s, csz)
        x = _contract_windows(w, rl[a], ax, accum=accum)

    w0 = _axis_windows(x, 1, T[0], s, csz)
    fine = _contract_windows(w0, rl[0], 1, merge=False, accum=accum)
    f_trail = fine.shape[3:]
    prod_f = int(np.prod(f_trail)) if f_trail else 1
    fine = fine.reshape(s_b, T[0], fsz, prod_f)

    xi = xi0.reshape(s_b, T[0], fsz, prod_f)
    if d0.ndim == 2:
        fine = fine + jnp.einsum("stjp,fj->stfp", xi, d0,
                                 preferred_element_type=accum)
    else:
        fine = fine + jnp.einsum("stjp,tfj->stfp", xi, d0,
                                 preferred_element_type=accum)
    # round to the storage dtype between levels: bit-identical to what the
    # per-level routes would have written to (and re-read from) HBM
    return fine.reshape((s_b, T[0] * fsz) + f_trail).astype(storage)


def _apply_levels(meta, field: Array, xi0s, r_all, d0s) -> Array:
    """The whole covered prefix, as pure array ops — the single source of
    the pyramid math. Runs inside the Pallas kernel body on refs' values
    AND standalone as the jnp reference for the backward replay."""
    (csz, fsz, boundary, b, levels, s_b, interpret, accum_name) = meta
    accum = jnp.dtype(accum_name)
    storage = field.dtype
    x = field
    for lvl, (T, _) in enumerate(levels):
        x = _level_body(x, xi0s[lvl], r_all[lvl], d0s[lvl], T=T, csz=csz,
                        fsz=fsz, boundary=boundary, b=b, accum=accum,
                        storage=storage)
    T_last = levels[-1][0]
    prod_f = int(np.prod([t * fsz for t in T_last[1:]])) or 1
    return x.reshape(s_b, T_last[0] * fsz, prod_f)


def _pyramid_kernel(*refs, meta):
    field_ref = refs[0]
    out_ref = refs[-1]
    per_level = refs[1:-1]
    xi0s, r_all, d0s = [], [], []
    i = 0
    for T, _ in meta[4]:
        nd = len(T)
        xi0s.append(per_level[i][...])
        r_all.append(tuple(per_level[i + 1 + a][...] for a in range(nd)))
        d0s.append(per_level[i + 1 + nd][...])
        i += 2 + nd
    out = _apply_levels(meta, field_ref[...], xi0s, r_all, d0s)
    out_ref[...] = out.astype(out_ref.dtype)


def _sample_blocked_spec(name: str, shape, s_b: int, dtype) -> OperandSpec:
    """Sample-slab operand: the grid runs over sample blocks only."""
    zeros = (0,) * (len(shape) - 1)
    im = IndexMap("(s" + ", 0" * len(zeros) + ")",
                  lambda s, _z=zeros: (s,) + _z)
    return OperandSpec(name, (s_b,) + tuple(shape[1:]), im, tuple(shape),
                       dtype)


def _resident_spec(name: str, shape, dtype) -> OperandSpec:
    """Fully VMEM-resident operand (matrices): one block, zero index map."""
    zeros = (0,) * len(shape)
    im = IndexMap("(" + ", ".join(["0"] * len(shape)) + ")",
                  lambda s, _z=zeros: _z)
    return OperandSpec(name, tuple(shape), im, tuple(shape), dtype)


def pyramid_launch_plan(*, field_shape, xi_shapes, r_shapes, d_shapes,
                        levels, s_b: int, fsz: int, dtype,
                        accum_dtype) -> LaunchPlan:
    """Declarative launch geometry of one pyramid (multi-level) launch.

    One grid axis — sample slabs — and per covered level the operand
    bundle [ξ0, per-axis R factors, sqrt(D)0]; only the last level's fine
    field is an output (inter-level fields never touch HBM).
    """
    sp = field_shape[0]
    T_last = levels[-1][0]
    prod_f = int(np.prod([t * fsz for t in T_last[1:]])) or 1
    dtype = jnp.dtype(dtype).name
    inputs = [_sample_blocked_spec("field", field_shape, s_b, dtype)]
    for lvl in range(len(levels)):
        inputs.append(_sample_blocked_spec(f"xi{lvl}", xi_shapes[lvl], s_b,
                                           dtype))
        for a, r_shape in enumerate(r_shapes[lvl]):
            inputs.append(_resident_spec(f"r{lvl}_{a}", r_shape, dtype))
        inputs.append(_resident_spec(f"d{lvl}", d_shapes[lvl], dtype))
    out = OperandSpec("fine", (s_b, T_last[0] * fsz, prod_f),
                      IndexMap("(s, 0, 0)", lambda s: (s, 0, 0)),
                      (sp, T_last[0] * fsz, prod_f), dtype)
    return LaunchPlan(
        kernel="refine_pyramid", grid=(sp // s_b,),
        inputs=tuple(inputs), outputs=(out,),
        accum_dtype=jnp.dtype(accum_dtype).name,
        params=dict(kind="fwd", levels=tuple(levels), s_b=s_b, fsz=fsz,
                    n_levels=len(levels), prod_f=prod_f),
    )


def _pyramid_impl(meta, field: Array, xi0s, r_all, d0s) -> Array:
    (csz, fsz, boundary, b, levels, s_b, interpret, accum_name) = meta
    if interpret == "reference":
        # production off-TPU backend (dispatch.select_backend): the same
        # fused multi-level chain as ONE jnp jit region — no Pallas
        # interpret emulation, which is slower than plain jnp on CPU
        return _pyramid_ref(meta, field, xi0s, r_all, d0s)
    plan = pyramid_launch_plan(
        field_shape=field.shape,
        xi_shapes=[x.shape for x in xi0s],
        r_shapes=[[r.shape for r in rl] for rl in r_all],
        d_shapes=[d.shape for d in d0s],
        levels=levels, s_b=s_b, fsz=fsz, dtype=field.dtype,
        accum_dtype=accum_name)
    operands = [field]
    for lvl in range(len(levels)):
        operands.append(xi0s[lvl])
        operands.extend(r_all[lvl])
        operands.append(d0s[lvl])
    return run_plan(functools.partial(_pyramid_kernel, meta=meta), plan,
                    operands, interpret=interpret)


def _pyramid_ref(meta, field: Array, xi0s, r_all, d0s) -> Array:
    """jnp replay of the chain over the full sample batch (backward path)."""
    meta_full = meta[:5] + (field.shape[0],) + meta[6:]
    return _apply_levels(meta_full, field, xi0s, r_all, d0s)


# -- custom VJP -----------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pyramid_core(meta, field, xi0s, r_all, d0s):
    return _pyramid_impl(meta, field, xi0s, r_all, d0s)


def _core_fwd(meta, field, xi0s, r_all, d0s):
    vals = (field.value,
            tuple(x.value for x in xi0s),
            tuple(tuple(r.value for r in rl) for rl in r_all),
            tuple(d.value for d in d0s))
    out = _pyramid_impl(meta, *vals)
    mats_pert = (any(r.perturbed for rl in r_all for r in rl)
                 or any(d.perturbed for d in d0s))
    return out, vals + (() if mats_pert else None,)


def _core_bwd(meta, res, g):
    field, xi0s, r_all, d0s, mats_pert = res
    zeros_r = tuple(tuple(jnp.zeros_like(r) for r in rl) for rl in r_all)
    zeros_d = tuple(jnp.zeros_like(d) for d in d0s)
    if isinstance(g, SymbolicZero):
        return (jnp.zeros_like(field),
                tuple(jnp.zeros_like(x) for x in xi0s), zeros_r, zeros_d)
    if mats_pert is not None:
        # learning θ: parameter-sized window einsums via the reference VJP
        # (§9 gating — never the fixed-matrix inference path)
        _, vjp = jax.vjp(
            lambda f, x, r, d: _pyramid_ref(meta, f, x, r, d),
            field, xi0s, r_all, d0s)
        return vjp(g)
    # fixed matrices: the chain is linear in (field, ξ) — transpose only
    _, vjp = jax.vjp(
        lambda f, x: _pyramid_ref(meta, f, x, r_all, d0s), field, xi0s)
    df, dxi = vjp(g)
    return df, dxi, zeros_r, zeros_d


_pyramid_core.defvjp(_core_fwd, _core_bwd, symbolic_zeros=True)


# -- public wrapper -------------------------------------------------------------
def refine_pyramid(field: Array, xis, mats, geoms, *,
                   interpret: bool | None = None,
                   sample_block: int | None = None,
                   sample_axis: bool = False,
                   accum_dtype: str = "float32") -> Array:
    """Run the covered level prefix as ONE Pallas launch.

    field: (*geoms[0].coarse_shape) (or (S, ...) with ``sample_axis``);
    xis[l]: (prod(T_l), n_fsz^d) per covered level (sample dim leading when
    ``sample_axis``); mats[l] = (rs_l, ds_l) per-axis factors (1-D charts:
    single-entry lists). geoms must be consecutive:
    ``geoms[l+1].coarse_shape == geoms[l].fine_shape``.
    """
    from .dispatch import autotune_pyramid  # lazy: avoid import cycle

    g0 = geoms[0]
    nd = len(g0.coarse_shape)
    fsz, csz, b, boundary = g0.n_fsz, g0.n_csz, g0.b, g0.boundary
    if interpret is None:
        # follow the dispatch backend: pallas on TPU, the jnp chain off-TPU
        # (the "reference" sentinel in meta — one jit region, no interpret
        # emulation), REPRO_BACKEND=interpret forces the tiled emulation
        from .dispatch import BACKEND_PALLAS, BACKEND_REFERENCE, \
            select_backend

        backend = select_backend()
        interpret = ("reference" if backend == BACKEND_REFERENCE
                     else backend != BACKEND_PALLAS)
    accum = jnp.dtype(accum_dtype)
    for lo, hi in zip(geoms[:-1], geoms[1:]):
        if tuple(hi.coarse_shape) != tuple(lo.fine_shape):
            raise ValueError("pyramid levels must be consecutive")

    if not sample_axis:
        field = field[None]
        xis = [x[None] for x in xis]
    n_s = field.shape[0]
    storage = field.dtype

    if interpret == "reference":
        # one jnp jit region: VMEM sample blocking (and the padding to a
        # block multiple) is meaningless here — run the whole batch
        s_b = n_s
    else:
        s_b = sample_block
        if s_b is None:
            tuned = autotune_pyramid(
                geoms, samples=n_s, itemsize=jnp.dtype(storage).itemsize)
            s_b = tuned[1] if tuned is not None else 1
    s_b = max(1, min(s_b, n_s))

    xi0s, r_all, d0s, levels = [], [], [], []
    for lvl, geom in enumerate(geoms):
        rs, ds = mats[lvl]
        T = tuple(geom.T)
        xi0s.append(prepare_xi0(xis[lvl], ds, T, fsz, accum=accum,
                                storage=storage))
        r_all.append(tuple(jnp.asarray(r, storage) for r in rs))
        d0s.append(jnp.asarray(ds[0], storage))
        levels.append((T, tuple(geom.coarse_shape)))

    nbs = -(-n_s // s_b)
    pad_s = nbs * s_b - n_s
    if pad_s > 0:
        field = jnp.pad(field, [(0, pad_s)] + [(0, 0)] * nd)
        xi0s = [jnp.pad(x, [(0, pad_s), (0, 0), (0, 0)]) for x in xi0s]

    meta = (csz, fsz, boundary, b, tuple(levels), s_b, interpret,
            accum_dtype)
    out = _pyramid_core(meta, field.astype(storage), tuple(xi0s),
                        tuple(r_all), tuple(d0s))
    out = out[:n_s]
    fine_shape = tuple(geoms[-1].fine_shape)
    out = out.reshape((n_s,) + fine_shape)
    return out if sample_axis else out[0]
