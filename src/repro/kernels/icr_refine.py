"""Pallas TPU kernels for the ICR refinement hot-spot (paper Eq. 11–12).

Why a kernel: one refinement level reads the coarse field once, builds
overlapping ``n_csz``-windows, contracts them with the stencil(s) and adds the
correlated correction ``sqrt(D) ξ``. Done naively in XLA this materializes the
(T, n_csz) window tensor in HBM (n_csz-fold read amplification) and runs the
noise add as a separate elementwise pass. The fused kernel keeps the window
construction in VMEM/VREGs and feeds the MXU directly:

  HBM traffic per level  : read L + read T·n_fsz (ξ) + write T·n_fsz
  naive XLA              : + read/write T·n_csz (window tensor materialized)

TPU adaptation (DESIGN.md §3): windows are built from *contiguous reshapes*
plus static row-shifted slices — element ``t·s + k`` (s = n_fsz//2) equals
``buf.reshape(-1, s)[t + k//s, k % s]`` — so there is NO gather; TPUs hate
gathers and love static slices. Halo across family blocks is handled by a
second (shifted) view of the same coarse array, a standard Pallas stencil
trick that keeps every BlockSpec a plain Blocked map.

Two variants:
  * ``_stationary_kernel``  — one shared (n_fsz, n_csz) stencil (regular
    chart axes, paper Eq. 11–12).
  * ``_charted_kernel``     — per-family matrices (irregular/charted axes,
    paper §4.3), a batched small-matmul.

Both carry arbitrary leading batch dims (chart-invariant axes broadcast,
paper §4.3 symmetry optimization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _window_cols(buf: Array, b_f: int, s: int, n_csz: int) -> Array:
    """(B_f, n_csz) window matrix from a (B_f + q_max)*s element buffer.

    Element (t, k) = buf[t*s + k] built with static slices of the (rows, s)
    reshape — no gather, no strided access.
    """
    q_max = (n_csz - 1) // s
    resh = buf[: (b_f + q_max) * s].reshape(b_f + q_max, s)
    cols = []
    for k in range(n_csz):
        q, r = divmod(k, s)
        cols.append(resh[q : q + b_f, r])
    return jnp.stack(cols, axis=-1)


def _stationary_kernel(coarse_ref, halo_ref, xi_ref, r_ref, d_ref, out_ref,
                       *, b_f: int, s: int, n_csz: int, n_fsz: int):
    q_max = (n_csz - 1) // s
    buf = jnp.concatenate(
        [coarse_ref[0], halo_ref[0, : q_max * s]], axis=-1
    )
    w = _window_cols(buf, b_f, s, n_csz)                  # (B_f, n_csz)
    r = r_ref[...]                                        # (n_fsz, n_csz)
    d = d_ref[...]                                        # (n_fsz, n_fsz)
    xi = xi_ref[0]                                        # (B_f, n_fsz)
    fine = jnp.dot(w, r.T, preferred_element_type=jnp.float32)
    fine = fine + jnp.dot(xi, d.T, preferred_element_type=jnp.float32)
    out_ref[0] = fine.reshape(b_f * n_fsz).astype(out_ref.dtype)


def _charted_kernel(coarse_ref, halo_ref, xi_ref, r_ref, d_ref, out_ref,
                    *, b_f: int, s: int, n_csz: int, n_fsz: int):
    q_max = (n_csz - 1) // s
    buf = jnp.concatenate(
        [coarse_ref[0], halo_ref[0, : q_max * s]], axis=-1
    )
    w = _window_cols(buf, b_f, s, n_csz)                  # (B_f, n_csz)
    r = r_ref[...]                                        # (B_f, n_fsz, n_csz)
    d = d_ref[...]                                        # (B_f, n_fsz, n_fsz)
    xi = xi_ref[0]                                        # (B_f, n_fsz)
    # batched matvec on the MXU: (B_f; n_fsz, n_csz) x (B_f; n_csz)
    fine = jax.lax.dot_general(
        r, w, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                     # (B_f, n_fsz)
    fine = fine + jax.lax.dot_general(
        d, xi, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    out_ref[0] = fine.reshape(b_f * n_fsz).astype(out_ref.dtype)


def _common_shapes(coarse, xi, n_csz, n_fsz, block_families):
    if xi.ndim < 2:
        raise ValueError("xi must be (..., T, n_fsz)")
    t = xi.shape[-2]
    s = n_fsz // 2
    b_f = min(block_families, t)
    nblk = -(-t // b_f)  # ceil
    return t, s, b_f, nblk


def _pad_operands(coarse, xi, t, s, b_f, nblk, n_csz):
    """Pad coarse so every block's main+halo view is in bounds, xi to a
    whole number of blocks. Garbage families are sliced off by the caller."""
    b_c = b_f * s
    need = (nblk + 1) * b_c  # +1 block: the shifted halo view of the last blk
    pad_c = need - coarse.shape[-1]
    if pad_c > 0:
        coarse = jnp.pad(coarse, [(0, 0)] * (coarse.ndim - 1) + [(0, pad_c)])
    pad_t = nblk * b_f - t
    if pad_t > 0:
        xi = jnp.pad(
            xi, [(0, 0)] * (xi.ndim - 2) + [(0, pad_t), (0, 0)]
        )
    return coarse, xi


@functools.partial(
    jax.jit,
    static_argnames=("n_csz", "n_fsz", "block_families", "interpret"),
)
def refine_stationary_pallas(coarse: Array, xi: Array, r: Array, d: Array,
                             *, n_csz: int, n_fsz: int,
                             block_families: int = 256,
                             interpret: bool = False) -> Array:
    """Fused stationary refinement. See module docstring.

    coarse: (B, L) halo-padded (L >= T*s + n_csz - s); xi: (B, T, n_fsz)
    r: (n_fsz, n_csz); d: (n_fsz, n_fsz)  ->  fine: (B, T*n_fsz)
    """
    t, s, b_f, nblk = _common_shapes(coarse, xi, n_csz, n_fsz, block_families)
    coarse, xi = _pad_operands(coarse, xi, t, s, b_f, nblk, n_csz)
    batch = coarse.shape[0]
    b_c = b_f * s

    kern = functools.partial(
        _stationary_kernel, b_f=b_f, s=s, n_csz=n_csz, n_fsz=n_fsz
    )
    out = pl.pallas_call(
        kern,
        grid=(batch, nblk),
        in_specs=[
            pl.BlockSpec((1, b_c), lambda b, i: (b, i)),        # main
            pl.BlockSpec((1, b_c), lambda b, i: (b, i + 1)),    # halo view
            pl.BlockSpec((1, b_f, n_fsz), lambda b, i: (b, i, 0)),
            pl.BlockSpec((n_fsz, n_csz), lambda b, i: (0, 0)),
            pl.BlockSpec((n_fsz, n_fsz), lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b_f * n_fsz), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((batch, nblk * b_f * n_fsz),
                                       coarse.dtype),
        interpret=interpret,
    )(coarse, coarse, xi, r, d)
    return out[:, : t * n_fsz]


@functools.partial(
    jax.jit,
    static_argnames=("n_csz", "n_fsz", "block_families", "interpret"),
)
def refine_charted_pallas(coarse: Array, xi: Array, r: Array, d: Array,
                          *, n_csz: int, n_fsz: int,
                          block_families: int = 256,
                          interpret: bool = False) -> Array:
    """Fused charted refinement with per-family matrices (paper §4.3).

    coarse: (B, L); xi: (B, T, n_fsz); r: (T, n_fsz, n_csz);
    d: (T, n_fsz, n_fsz)  ->  fine: (B, T*n_fsz)
    """
    t, s, b_f, nblk = _common_shapes(coarse, xi, n_csz, n_fsz, block_families)
    coarse, xi = _pad_operands(coarse, xi, t, s, b_f, nblk, n_csz)
    pad_t = nblk * b_f - t
    if pad_t > 0:
        r = jnp.pad(r, [(0, pad_t), (0, 0), (0, 0)])
        d = jnp.pad(d, [(0, pad_t), (0, 0), (0, 0)])
    batch = coarse.shape[0]
    b_c = b_f * s

    kern = functools.partial(
        _charted_kernel, b_f=b_f, s=s, n_csz=n_csz, n_fsz=n_fsz
    )
    out = pl.pallas_call(
        kern,
        grid=(batch, nblk),
        in_specs=[
            pl.BlockSpec((1, b_c), lambda b, i: (b, i)),
            pl.BlockSpec((1, b_c), lambda b, i: (b, i + 1)),
            pl.BlockSpec((1, b_f, n_fsz), lambda b, i: (b, i, 0)),
            pl.BlockSpec((b_f, n_fsz, n_csz), lambda b, i: (i, 0, 0)),
            pl.BlockSpec((b_f, n_fsz, n_fsz), lambda b, i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b_f * n_fsz), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((batch, nblk * b_f * n_fsz),
                                       coarse.dtype),
        interpret=interpret,
    )(coarse, coarse, xi, r, d)
    return out[:, : t * n_fsz]
