"""Pallas TPU kernels for the ICR refinement hot-spot (paper Eq. 11–12).

Why a kernel: one refinement level reads the coarse field once, builds
overlapping ``n_csz``-windows, contracts them with the stencil(s) and adds the
correlated correction ``sqrt(D) ξ``. Done naively in XLA this materializes the
(T, n_csz) window tensor in HBM (n_csz-fold read amplification) and runs the
noise add as a separate elementwise pass. The fused kernel keeps the window
construction in VMEM/VREGs and feeds the MXU directly:

  HBM traffic per level  : read L + read T·n_fsz (ξ) + write T·n_fsz
  naive XLA              : + read/write T·n_csz (window tensor materialized)

TPU adaptation (DESIGN.md §3): windows are built from *contiguous reshapes*
plus static row-shifted slices — element ``t·s + k`` (s = n_fsz//2) equals
``buf.reshape(-1, s)[t + k//s, k % s]`` — so there is NO gather; TPUs hate
gathers and love static slices. Halo across family blocks is handled by a
second (shifted) view of the same coarse array, a standard Pallas stencil
trick that keeps every BlockSpec a plain Blocked map.

Two variants:
  * ``_stationary_kernel``  — one shared (n_fsz, n_csz) stencil (regular
    chart axes, paper Eq. 11–12).
  * ``_charted_kernel``     — per-family matrices (irregular/charted axes,
    paper §4.3), a batched small-matmul.

Both carry arbitrary leading batch dims (chart-invariant axes broadcast,
paper §4.3 symmetry optimization) and a **batch block** (``batch_block``,
DESIGN.md §10): the kernel processes ``b_b`` leading-batch rows per grid
step instead of one, so the stencil matrices are fetched once per family
block for the whole batch slab and the MXU sees ``b_b``-fold taller GEMMs.
That is how batched posterior sampling / serving amortizes matrix loads —
the sample dimension rides *inside* the kernel block, it is not lifted into
the grid the way a plain ``vmap`` would.

``noise=False`` mode (DESIGN.md §10): every N-D per-axis pass except the
final one injects no excitation — its noise factor is pre-contracted into ξ
outside the kernel — so those passes used to read an all-zeros ξ array from
HBM for nothing. The noise-free variants drop the ξ and sqrt(D) operands
entirely (forward skips the read and the add, the adjoint skips the ``dxi``
computation and its write).

Dtype policy (DESIGN.md §11): every entry point takes ``accum_dtype`` (a
static dtype name, default ``"float32"``) — the ``preferred_element_type``
of every MXU contraction and the dtype of the adjoint overlap-add
accumulator. The *storage* dtype is simply the dtype of the operands: pass
bf16 arrays and the kernels read/write bf16 HBM while accumulating fp32
(the ``DtypePolicy`` default of ``repro.kernels.policy``), halving HBM
bytes per element on every route.

Adjoints (DESIGN.md §9): all entry points carry a ``jax.custom_vjp`` whose
backward runs hand-written *adjoint* Pallas kernels. The transpose of the
window-contract is a halo-overlapped scatter-add — coarse element ``t·s + k``
receives ``Rᵀ g`` contributions from the ≤ ``q_max+1`` families whose window
covers it — which fuses exactly like the forward: the adjoint kernel reads
the fine cotangent twice (main + previous-block halo view), contracts on the
MXU, and overlap-adds via the same static row-shifted slices as
``_window_cols`` run in reverse. No gather, no atomic, every BlockSpec stays
a plain Blocked map. Matrix cotangents (∂R, ∂sqrtD) are parameter-sized
reductions, computed as jnp einsums outside the kernel and *only* when the
matrices are perturbed (``symbolic_zeros``): fixed-matrix MAP/ADVI inference
never materializes the window tensor on the backward pass either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.custom_derivatives import SymbolicZero

from .launch import IndexMap, LaunchPlan, OperandSpec, pad_to, run_plan
from .ref import windows_1d

Array = jnp.ndarray


def interpret_default() -> bool:
    """Pallas interpret mode off-TPU (the shared backend-default predicate
    for every kernel module)."""
    return jax.default_backend() != "tpu"


def _window_cols(buf: Array, b_f: int, s: int, n_csz: int) -> Array:
    """(b_b, B_f, n_csz) window matrix from (b_b, >= (B_f + q_max)*s) buffers.

    Element (·, t, k) = buf[·, t*s + k] built with static slices of the
    (b_b, rows, s) reshape — no gather, no strided access.
    """
    q_max = (n_csz - 1) // s
    resh = buf[:, : (b_f + q_max) * s].reshape(buf.shape[0], b_f + q_max, s)
    cols = []
    for k in range(n_csz):
        q, r = divmod(k, s)
        cols.append(resh[:, q : q + b_f, r])
    return jnp.stack(cols, axis=-1)


def _stationary_kernel(coarse_ref, halo_ref, xi_ref, r_ref, d_ref, out_ref,
                       *, b_b: int, b_f: int, s: int, n_csz: int, n_fsz: int,
                       accum):
    q_max = (n_csz - 1) // s
    buf = jnp.concatenate(
        [coarse_ref[...], halo_ref[:, : q_max * s]], axis=-1
    )
    w = _window_cols(buf, b_f, s, n_csz)                  # (b_b, B_f, n_csz)
    r = r_ref[...]                                        # (n_fsz, n_csz)
    d = d_ref[...]                                        # (n_fsz, n_fsz)
    xi = xi_ref[...].reshape(b_b * b_f, n_fsz)
    fine = jnp.dot(w.reshape(b_b * b_f, n_csz), r.T,
                   preferred_element_type=accum)
    fine = fine + jnp.dot(xi, d.T, preferred_element_type=accum)
    out_ref[...] = fine.reshape(b_b, b_f * n_fsz).astype(out_ref.dtype)


def _stationary_nn_kernel(coarse_ref, halo_ref, r_ref, out_ref,
                          *, b_b: int, b_f: int, s: int, n_csz: int,
                          n_fsz: int, accum):
    """Noise-free stationary forward: no ξ read, no sqrt(D) operand."""
    q_max = (n_csz - 1) // s
    buf = jnp.concatenate(
        [coarse_ref[...], halo_ref[:, : q_max * s]], axis=-1
    )
    w = _window_cols(buf, b_f, s, n_csz)
    fine = jnp.dot(w.reshape(b_b * b_f, n_csz), r_ref[...].T,
                   preferred_element_type=accum)
    out_ref[...] = fine.reshape(b_b, b_f * n_fsz).astype(out_ref.dtype)


def _charted_kernel(coarse_ref, halo_ref, xi_ref, r_ref, d_ref, out_ref,
                    *, b_b: int, b_f: int, s: int, n_csz: int, n_fsz: int,
                    accum):
    buf = jnp.concatenate(
        [coarse_ref[...], halo_ref[:, : ((n_csz - 1) // s) * s]], axis=-1
    )
    w = _window_cols(buf, b_f, s, n_csz)                  # (b_b, B_f, n_csz)
    # batched matvec on the MXU, families as the dot_general batch dim,
    # batch rows as the free dim: matrices are loaded once per family block
    fine = jnp.einsum("btc,tfc->btf", w, r_ref[...],
                      preferred_element_type=accum)
    fine = fine + jnp.einsum("btj,tfj->btf", xi_ref[...], d_ref[...],
                             preferred_element_type=accum)
    out_ref[...] = fine.reshape(b_b, b_f * n_fsz).astype(out_ref.dtype)


def _charted_nn_kernel(coarse_ref, halo_ref, r_ref, out_ref,
                       *, b_b: int, b_f: int, s: int, n_csz: int, n_fsz: int,
                       accum):
    buf = jnp.concatenate(
        [coarse_ref[...], halo_ref[:, : ((n_csz - 1) // s) * s]], axis=-1
    )
    w = _window_cols(buf, b_f, s, n_csz)
    fine = jnp.einsum("btc,tfc->btf", w, r_ref[...],
                      preferred_element_type=accum)
    out_ref[...] = fine.reshape(b_b, b_f * n_fsz).astype(out_ref.dtype)


def _overlap_add_cols(dw: Array, b_f: int, s: int, n_csz: int) -> Array:
    """(b_b, B_f, s) coarse-cotangent rows from (b_b, B_f + q_max, n_csz)
    window-cotangent rows — ``_window_cols`` run in reverse.

    dcoarse[t'·s + r] = Σ_q dw[t' − q, q·s + r]: each q-term is the same
    static row-shifted slice the forward used to *build* column ``q·s + r``,
    only shifted the other way (``q_max − q`` instead of ``q``). The halo
    families (previous block's tail) arrive as the leading q_max rows, so the
    scatter-add across the block boundary is a plain slice — no gather.
    """
    q_max = (n_csz - 1) // s
    b_b = dw.shape[0]
    acc = jnp.zeros((b_b, b_f, s), dw.dtype)
    for q in range(q_max + 1):
        width = min(s, n_csz - q * s)
        if width <= 0:
            break
        piece = dw[:, q_max - q : q_max - q + b_f, q * s : q * s + width]
        if width < s:
            piece = jnp.concatenate(
                [piece, jnp.zeros((b_b, b_f, s - width), piece.dtype)],
                axis=-1,
            )
        acc = acc + piece
    return acc


def _stationary_adjoint_kernel(g_ref, gh_ref, r_ref, d_ref, dc_ref, dxi_ref,
                               *, b_b: int, b_f: int, s: int, n_csz: int,
                               n_fsz: int, accum):
    q_max = (n_csz - 1) // s
    g = g_ref[...]                                        # (b_b, B_f, n_fsz)
    r = r_ref[...]
    d = d_ref[...]
    g_ext = g
    if q_max > 0:
        g_ext = jnp.concatenate([gh_ref[:, b_f - q_max :], g], axis=1)
    dw = jnp.dot(g_ext.reshape(-1, n_fsz), r,
                 preferred_element_type=accum)
    dw = dw.reshape(b_b, b_f + q_max, n_csz)
    acc = _overlap_add_cols(dw, b_f, s, n_csz)            # (b_b, B_f, s)
    dc_ref[...] = acc.reshape(b_b, b_f * s).astype(dc_ref.dtype)
    dxi = jnp.dot(g.reshape(-1, n_fsz), d,
                  preferred_element_type=accum)
    dxi_ref[...] = dxi.reshape(b_b, b_f, n_fsz).astype(dxi_ref.dtype)


def _stationary_adjoint_nn_kernel(g_ref, gh_ref, r_ref, dc_ref,
                                  *, b_b: int, b_f: int, s: int, n_csz: int,
                                  n_fsz: int, accum):
    """Noise-free adjoint: scatter-add only, no dxi output."""
    q_max = (n_csz - 1) // s
    g = g_ref[...]
    g_ext = g
    if q_max > 0:
        g_ext = jnp.concatenate([gh_ref[:, b_f - q_max :], g], axis=1)
    dw = jnp.dot(g_ext.reshape(-1, n_fsz), r_ref[...],
                 preferred_element_type=accum)
    dw = dw.reshape(b_b, b_f + q_max, n_csz)
    acc = _overlap_add_cols(dw, b_f, s, n_csz)
    dc_ref[...] = acc.reshape(b_b, b_f * s).astype(dc_ref.dtype)


def _charted_adjoint_kernel(g_ref, gh_ref, rm_ref, rh_ref, d_ref,
                            dc_ref, dxi_ref,
                            *, b_b: int, b_f: int, s: int, n_csz: int,
                            n_fsz: int, accum):
    q_max = (n_csz - 1) // s
    g = g_ref[...]                                        # (b_b, B_f, n_fsz)
    # dw[·, t] = R[t]ᵀ g[·, t] — batched matvec, per-family stencils
    dw = jnp.einsum("btf,tfc->btc", g, rm_ref[...],
                    preferred_element_type=accum)
    if q_max > 0:
        g_h = gh_ref[:, b_f - q_max :]                    # (b_b, q_max, f)
        r_h = rh_ref[b_f - q_max :]                       # (q_max, f, c)
        dw_h = jnp.einsum("bqf,qfc->bqc", g_h, r_h,
                          preferred_element_type=accum)
        dw = jnp.concatenate([dw_h, dw], axis=1)
    acc = _overlap_add_cols(dw, b_f, s, n_csz)
    dc_ref[...] = acc.reshape(b_b, b_f * s).astype(dc_ref.dtype)
    dxi = jnp.einsum("btf,tfj->btj", g, d_ref[...],
                     preferred_element_type=accum)
    dxi_ref[...] = dxi.astype(dxi_ref.dtype)


def _charted_adjoint_nn_kernel(g_ref, gh_ref, rm_ref, rh_ref, dc_ref,
                               *, b_b: int, b_f: int, s: int, n_csz: int,
                               n_fsz: int, accum):
    q_max = (n_csz - 1) // s
    g = g_ref[...]
    dw = jnp.einsum("btf,tfc->btc", g, rm_ref[...],
                    preferred_element_type=accum)
    if q_max > 0:
        g_h = gh_ref[:, b_f - q_max :]
        r_h = rh_ref[b_f - q_max :]
        dw_h = jnp.einsum("bqf,qfc->bqc", g_h, r_h,
                          preferred_element_type=accum)
        dw = jnp.concatenate([dw_h, dw], axis=1)
    acc = _overlap_add_cols(dw, b_f, s, n_csz)
    dc_ref[...] = acc.reshape(b_b, b_f * s).astype(dc_ref.dtype)


def halo_floor(n_csz: int, n_fsz: int) -> int:
    """Minimum family block ``q_max``: the kernels' one-block halo view must
    cover the window overhang, forward and adjoint alike. The single source
    of truth for this clamp (dispatch autotune uses it too)."""
    s = max(1, n_fsz // 2)
    return (n_csz - 1) // s


def _block_shapes(t: int, batch: int, n_csz: int, n_fsz: int,
                  block_families: int, batch_block: int):
    s = n_fsz // 2
    b_f = max(min(block_families, t), halo_floor(n_csz, n_fsz))
    nblk = -(-t // b_f)  # ceil
    b_b = max(1, min(batch_block, batch))
    nbb = -(-batch // b_b)
    return s, b_f, nblk, b_b, nbb


# Named index maps (DESIGN.md §14): the grid is (family block i, batch
# block b) with batch innermost, so blocked matrix operands stay VMEM-
# resident across the whole batch. The names appear verbatim in verifier
# findings and plan descriptions.
_IM_BI = IndexMap("(b, i)", lambda i, b: (b, i))
_IM_BI1 = IndexMap("(b, i + 1)", lambda i, b: (b, i + 1))
_IM_BI0 = IndexMap("(b, i, 0)", lambda i, b: (b, i, 0))
_IM_BI10 = IndexMap("(b, i + 1, 0)", lambda i, b: (b, i + 1, 0))
_IM_00 = IndexMap("(0, 0)", lambda i, b: (0, 0))
_IM_I00 = IndexMap("(i, 0, 0)", lambda i, b: (i, 0, 0))
_IM_I100 = IndexMap("(i + 1, 0, 0)", lambda i, b: (i + 1, 0, 0))


def refine_fwd_launch_plan(*, batch: int, t: int, coarse_len: int,
                           n_csz: int, n_fsz: int, block_families: int,
                           batch_block: int, dtype, accum_dtype,
                           charted: bool, noise: bool = True) -> LaunchPlan:
    """Declarative launch geometry of one forward 1-D refinement launch.

    This is the single source of truth: the impls pad their operands to
    the plan's array shapes and :func:`run_plan` builds the pallas_call
    from it, and ``dispatch.level_launch_plans`` exports the identical
    record to ``analysis.kernel_verify`` for coverage/bounds proofs.
    """
    s, b_f, nblk, b_b, nbb = _block_shapes(
        t, batch, n_csz, n_fsz, block_families, batch_block)
    b_c = b_f * s
    q_max = (n_csz - 1) // s
    dtype = jnp.dtype(dtype).name
    # +1 block: the shifted halo view of the last block must stay in
    # bounds; round a longer incoming buffer up to whole blocks.
    l_pad = max((nblk + 1) * b_c, -(-coarse_len // b_c) * b_c)
    coarse_shape = (nbb * b_b, l_pad)
    inputs = [
        OperandSpec("coarse", (b_b, b_c), _IM_BI, coarse_shape, dtype,
                    overhang=((0, 0), (0, q_max * s))),
        OperandSpec("coarse_halo", (b_b, b_c), _IM_BI1, coarse_shape, dtype,
                    halo_of="coarse"),
    ]
    if noise:
        inputs.append(OperandSpec("xi", (b_b, b_f, n_fsz), _IM_BI0,
                                  (nbb * b_b, nblk * b_f, n_fsz), dtype))
    if charted:
        inputs.append(OperandSpec("r", (b_f, n_fsz, n_csz), _IM_I00,
                                  (nblk * b_f, n_fsz, n_csz), dtype))
        if noise:
            inputs.append(OperandSpec("d", (b_f, n_fsz, n_fsz), _IM_I00,
                                      (nblk * b_f, n_fsz, n_fsz), dtype))
    else:
        inputs.append(OperandSpec("r", (n_fsz, n_csz), _IM_00,
                                  (n_fsz, n_csz), dtype))
        if noise:
            inputs.append(OperandSpec("d", (n_fsz, n_fsz), _IM_00,
                                      (n_fsz, n_fsz), dtype))
    out = OperandSpec("fine", (b_b, b_f * n_fsz), _IM_BI,
                      (nbb * b_b, nblk * b_f * n_fsz), dtype)
    name = ("charted" if charted else "stationary") + ("" if noise else "_nn")
    return LaunchPlan(
        kernel=f"refine_{name}_fwd", grid=(nblk, nbb),
        inputs=tuple(inputs), outputs=(out,),
        accum_dtype=jnp.dtype(accum_dtype).name,
        params=dict(kind="fwd", charted=charted, noise=noise, t=t,
                    batch=batch, coarse_len=coarse_len, n_csz=n_csz,
                    n_fsz=n_fsz, s=s, b_f=b_f, b_b=b_b, nblk=nblk, nbb=nbb),
    )


_FWD_KERNELS = {
    (False, True): _stationary_kernel,
    (False, False): _stationary_nn_kernel,
    (True, True): _charted_kernel,
    (True, False): _charted_nn_kernel,
}


def _run_fwd(plan: LaunchPlan, coarse, xi, r, d, interpret) -> Array:
    p = plan.params
    kern = functools.partial(
        _FWD_KERNELS[(p["charted"], p["noise"])], b_b=p["b_b"], b_f=p["b_f"],
        s=p["s"], n_csz=p["n_csz"], n_fsz=p["n_fsz"],
        accum=jnp.dtype(plan.accum_dtype),
    )
    coarse = pad_to(coarse, plan.operand("coarse").array_shape)
    operands = [coarse, coarse]
    if p["noise"]:
        operands.append(pad_to(xi, plan.operand("xi").array_shape))
    operands.append(pad_to(r, plan.operand("r").array_shape))
    if p["noise"]:
        operands.append(pad_to(d, plan.operand("d").array_shape))
    out = run_plan(kern, plan, operands, interpret=interpret)
    return out[: p["batch"], : p["t"] * p["n_fsz"]]


def _refine_stationary_impl(meta, coarse: Array, xi: Array, r: Array,
                            d: Array) -> Array:
    n_csz, n_fsz, block_families, batch_block, interpret, accum_name = meta
    plan = refine_fwd_launch_plan(
        batch=coarse.shape[0], t=xi.shape[-2], coarse_len=coarse.shape[-1],
        n_csz=n_csz, n_fsz=n_fsz, block_families=block_families,
        batch_block=batch_block, dtype=coarse.dtype, accum_dtype=accum_name,
        charted=False)
    return _run_fwd(plan, coarse, xi, r, d, interpret)


def _refine_stationary_nn_impl(meta, coarse: Array, r: Array) -> Array:
    t, n_csz, n_fsz, block_families, batch_block, interpret, accum_name = meta
    plan = refine_fwd_launch_plan(
        batch=coarse.shape[0], t=t, coarse_len=coarse.shape[-1],
        n_csz=n_csz, n_fsz=n_fsz, block_families=block_families,
        batch_block=batch_block, dtype=coarse.dtype, accum_dtype=accum_name,
        charted=False, noise=False)
    return _run_fwd(plan, coarse, None, r, None, interpret)


def _refine_charted_impl(meta, coarse: Array, xi: Array, r: Array,
                         d: Array) -> Array:
    n_csz, n_fsz, block_families, batch_block, interpret, accum_name = meta
    plan = refine_fwd_launch_plan(
        batch=coarse.shape[0], t=xi.shape[-2], coarse_len=coarse.shape[-1],
        n_csz=n_csz, n_fsz=n_fsz, block_families=block_families,
        batch_block=batch_block, dtype=coarse.dtype, accum_dtype=accum_name,
        charted=True)
    return _run_fwd(plan, coarse, xi, r, d, interpret)


def _refine_charted_nn_impl(meta, coarse: Array, r: Array) -> Array:
    t, n_csz, n_fsz, block_families, batch_block, interpret, accum_name = meta
    plan = refine_fwd_launch_plan(
        batch=coarse.shape[0], t=t, coarse_len=coarse.shape[-1],
        n_csz=n_csz, n_fsz=n_fsz, block_families=block_families,
        batch_block=batch_block, dtype=coarse.dtype, accum_dtype=accum_name,
        charted=True, noise=False)
    return _run_fwd(plan, coarse, None, r, None, interpret)


# -- adjoint launches -----------------------------------------------------------
def refine_adjoint_launch_plan(*, batch: int, t: int, coarse_len: int,
                               n_csz: int, n_fsz: int, block_families: int,
                               batch_block: int, dtype, accum_dtype,
                               charted: bool, noise: bool = True
                               ) -> LaunchPlan:
    """Declarative launch geometry of one adjoint (transpose) launch.

    The adjoint flips the halo direction: coarse-block i receives window
    cotangents from its own g-block plus the *previous* block's tail.
    Front-padding g by one zero block lets the halo view use index map
    ``(b, i, 0)`` while the main view uses ``(b, i + 1, 0)`` (in-bounds at
    i = 0, zero contribution). One extra grid step (nblk + 1) covers the
    coarse tail the last windows overhang into; its main g-block is the
    zero back-padding. In the charted variant the halo families' window
    cotangents need the *previous* block's stencils, so r rides along
    twice exactly like g (main + shifted view).
    """
    s, b_f, nblk, b_b, nbb = _block_shapes(
        t, batch, n_csz, n_fsz, block_families, batch_block)
    b_c = b_f * s
    q_max = (n_csz - 1) // s
    dtype = jnp.dtype(dtype).name
    g_shape = (nbb * b_b, (nblk + 2) * b_f, n_fsz)
    inputs = [
        OperandSpec("g", (b_b, b_f, n_fsz), _IM_BI10, g_shape, dtype,
                    overhang=((0, 0), (q_max, 0), (0, 0))),
        OperandSpec("g_halo", (b_b, b_f, n_fsz), _IM_BI0, g_shape, dtype,
                    halo_of="g"),
    ]
    if charted:
        r_shape = ((nblk + 2) * b_f, n_fsz, n_csz)
        inputs.append(OperandSpec("r", (b_f, n_fsz, n_csz), _IM_I100,
                                  r_shape, dtype,
                                  overhang=((q_max, 0), (0, 0), (0, 0))))
        inputs.append(OperandSpec("r_halo", (b_f, n_fsz, n_csz), _IM_I00,
                                  r_shape, dtype, halo_of="r"))
        if noise:
            inputs.append(OperandSpec("d", (b_f, n_fsz, n_fsz), _IM_I100,
                                      ((nblk + 2) * b_f, n_fsz, n_fsz),
                                      dtype))
    else:
        inputs.append(OperandSpec("r", (n_fsz, n_csz), _IM_00,
                                  (n_fsz, n_csz), dtype))
        if noise:
            inputs.append(OperandSpec("d", (n_fsz, n_fsz), _IM_00,
                                      (n_fsz, n_fsz), dtype))
    outputs = [OperandSpec("dcoarse", (b_b, b_c), _IM_BI,
                           (nbb * b_b, (nblk + 1) * b_c), dtype)]
    if noise:
        outputs.append(OperandSpec("dxi", (b_b, b_f, n_fsz), _IM_BI0,
                                   (nbb * b_b, (nblk + 1) * b_f, n_fsz),
                                   dtype))
    name = ("charted" if charted else "stationary") + ("" if noise else "_nn")
    return LaunchPlan(
        kernel=f"refine_{name}_adjoint", grid=(nblk + 1, nbb),
        inputs=tuple(inputs), outputs=tuple(outputs),
        accum_dtype=jnp.dtype(accum_dtype).name,
        params=dict(kind="bwd", charted=charted, noise=noise, t=t,
                    batch=batch, coarse_len=coarse_len, n_csz=n_csz,
                    n_fsz=n_fsz, s=s, b_f=b_f, b_b=b_b, nblk=nblk, nbb=nbb),
    )


_ADJ_KERNELS = {
    (False, True): _stationary_adjoint_kernel,
    (False, False): _stationary_adjoint_nn_kernel,
    (True, True): _charted_adjoint_kernel,
    (True, False): _charted_adjoint_nn_kernel,
}


def _run_adjoint(plan: LaunchPlan, g, r, d, interpret):
    p = plan.params
    batch, t, b_f, nblk = p["batch"], p["t"], p["b_f"], p["nblk"]
    kern = functools.partial(
        _ADJ_KERNELS[(p["charted"], p["noise"])], b_b=p["b_b"], b_f=b_f,
        s=p["s"], n_csz=p["n_csz"], n_fsz=p["n_fsz"],
        accum=jnp.dtype(plan.accum_dtype),
    )
    # front-pad one zero block (halo at i = 0), back-pad to whole blocks
    pad_fam = (b_f, (nblk + 1) * b_f - t)
    g_pad = jnp.pad(g, [(0, p["nbb"] * p["b_b"] - batch), pad_fam, (0, 0)])
    operands = [g_pad, g_pad]
    if p["charted"]:
        r_pad = jnp.pad(r, [pad_fam, (0, 0), (0, 0)])
        operands += [r_pad, r_pad]
        if p["noise"]:
            operands.append(jnp.pad(d, [pad_fam, (0, 0), (0, 0)]))
    else:
        operands.append(r)
        if p["noise"]:
            operands.append(d)
    out = run_plan(kern, plan, operands, interpret=interpret)
    if p["noise"]:
        dc, dxi = out
        return dc[:batch, :p["coarse_len"]], dxi[:batch, :t]
    return out[:batch, :p["coarse_len"]]


@functools.partial(
    jax.jit,
    static_argnames=("coarse_len", "n_csz", "n_fsz", "block_families",
                     "batch_block", "interpret", "noise", "accum_dtype"),
)
def refine_stationary_adjoint_pallas(g: Array, r: Array, d: Array = None, *,
                                     coarse_len: int, n_csz: int, n_fsz: int,
                                     block_families: int = 256,
                                     batch_block: int = 1,
                                     interpret: bool = False,
                                     noise: bool = True,
                                     accum_dtype: str = "float32"):
    """Fused adjoint of ``refine_stationary_pallas`` in (coarse, xi).

    g: (B, T*n_fsz) fine cotangent -> (dcoarse: (B, coarse_len),
    dxi: (B, T, n_fsz)). One launch computes both: the halo-overlapped
    scatter-add of the window cotangents ``g R`` and the noise transpose
    ``g D`` share the fine-cotangent read. With ``noise=False`` the launch
    computes (and returns) only ``dcoarse``.
    """
    batch = g.shape[0]
    g = g.reshape(batch, -1, n_fsz)
    plan = refine_adjoint_launch_plan(
        batch=batch, t=g.shape[-2], coarse_len=coarse_len, n_csz=n_csz,
        n_fsz=n_fsz, block_families=block_families, batch_block=batch_block,
        dtype=g.dtype, accum_dtype=accum_dtype, charted=False, noise=noise)
    return _run_adjoint(plan, g, r, d, interpret)


@functools.partial(
    jax.jit,
    static_argnames=("coarse_len", "n_csz", "n_fsz", "block_families",
                     "batch_block", "interpret", "noise", "accum_dtype"),
)
def refine_charted_adjoint_pallas(g: Array, r: Array, d: Array = None, *,
                                  coarse_len: int, n_csz: int, n_fsz: int,
                                  block_families: int = 256,
                                  batch_block: int = 1,
                                  interpret: bool = False,
                                  noise: bool = True,
                                  accum_dtype: str = "float32"):
    """Fused adjoint of ``refine_charted_pallas`` (per-family matrices).

    See ``refine_adjoint_launch_plan`` for the halo-flip geometry; r rides
    along twice exactly like g (main + shifted view).
    """
    batch = g.shape[0]
    g = g.reshape(batch, -1, n_fsz)
    plan = refine_adjoint_launch_plan(
        batch=batch, t=g.shape[-2], coarse_len=coarse_len, n_csz=n_csz,
        n_fsz=n_fsz, block_families=block_families, batch_block=batch_block,
        dtype=g.dtype, accum_dtype=accum_dtype, charted=True, noise=noise)
    return _run_adjoint(plan, g, r, d, interpret)


# -- custom VJP registration ----------------------------------------------------
# The matrices (r, d) only need cotangents when the kernel parameters θ are
# being learned; symbolic_zeros=True lets the forward record perturbation per
# argument so fixed-matrix inference skips the window-tensor einsums. The
# flags are encoded in the residue *structure* (() vs None) — pytree treedefs
# are static, so the backward branches at trace time.
def _matrix_cotangents(coarse, xi, g3, r, d, r_pert, d_pert, *, charted,
                       accum=jnp.float32):
    s = r.shape[-2] // 2
    t = g3.shape[-2]
    if r_pert is not None:
        w = windows_1d(coarse, t, r.shape[-1], s)
        eq = "...tf,...tc->tfc" if charted else "...tf,...tc->fc"
        dr = jnp.einsum(eq, g3, w,
                        preferred_element_type=accum).astype(r.dtype)
    else:
        dr = jnp.zeros_like(r)
    if d_pert is not None:
        eq = "...tf,...tj->tfj" if charted else "...tf,...tj->fj"
        dd = jnp.einsum(eq, g3, xi,
                        preferred_element_type=accum).astype(d.dtype)
    else:
        dd = jnp.zeros_like(d)
    return dr, dd


def _make_refine_vjp(impl, adjoint, *, charted):
    """custom_vjp wrapper shared by both kernel variants: residual packing,
    symbolic-zero handling, adjoint dispatch and matrix-cotangent gating
    differ only in (impl, adjoint, charted)."""

    @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
    def refine(meta, coarse, xi, r, d):
        return impl(meta, coarse, xi, r, d)

    def fwd(meta, coarse, xi, r, d):
        out = impl(meta, coarse.value, xi.value, r.value, d.value)
        res = (coarse.value, xi.value, r.value, d.value,
               () if r.perturbed else None, () if d.perturbed else None)
        return out, res

    def bwd(meta, res, g):
        n_csz, n_fsz, block_families, batch_block, interpret, accum_name \
            = meta
        coarse, xi, r, d, r_pert, d_pert = res
        if isinstance(g, SymbolicZero):
            return (jnp.zeros_like(coarse), jnp.zeros_like(xi),
                    jnp.zeros_like(r), jnp.zeros_like(d))
        dc, dxi = adjoint(
            g, r, d, coarse_len=coarse.shape[-1], n_csz=n_csz, n_fsz=n_fsz,
            block_families=block_families, batch_block=batch_block,
            interpret=interpret, accum_dtype=accum_name,
        )
        g3 = g.reshape(g.shape[:-1] + (xi.shape[-2], n_fsz))
        dr, dd = _matrix_cotangents(coarse, xi, g3, r, d, r_pert, d_pert,
                                    charted=charted,
                                    accum=jnp.dtype(accum_name))
        return dc.astype(coarse.dtype), dxi.astype(xi.dtype), dr, dd

    refine.defvjp(fwd, bwd, symbolic_zeros=True)
    return refine


def _make_refine_nn_vjp(impl, adjoint, *, charted):
    """Noise-free counterpart: two diff args (coarse, r), the adjoint launch
    skips the dxi computation entirely."""

    @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
    def refine(meta, coarse, r):
        return impl(meta, coarse, r)

    def fwd(meta, coarse, r):
        out = impl(meta, coarse.value, r.value)
        return out, (coarse.value, r.value, () if r.perturbed else None)

    def bwd(meta, res, g):
        t, n_csz, n_fsz, block_families, batch_block, interpret, accum_name \
            = meta
        coarse, r, r_pert = res
        if isinstance(g, SymbolicZero):
            return jnp.zeros_like(coarse), jnp.zeros_like(r)
        dc = adjoint(
            g, r, coarse_len=coarse.shape[-1], n_csz=n_csz, n_fsz=n_fsz,
            block_families=block_families, batch_block=batch_block,
            interpret=interpret, noise=False, accum_dtype=accum_name,
        )
        if r_pert is not None:
            g3 = g.reshape(g.shape[:-1] + (t, n_fsz))
            w = windows_1d(coarse, t, n_csz, n_fsz // 2)
            eq = "...tf,...tc->tfc" if charted else "...tf,...tc->fc"
            dr = jnp.einsum(eq, g3, w,
                            preferred_element_type=jnp.dtype(accum_name)
                            ).astype(r.dtype)
        else:
            dr = jnp.zeros_like(r)
        return dc.astype(coarse.dtype), dr

    refine.defvjp(fwd, bwd, symbolic_zeros=True)
    return refine


_refine_stationary = _make_refine_vjp(
    _refine_stationary_impl, refine_stationary_adjoint_pallas, charted=False)
_refine_charted = _make_refine_vjp(
    _refine_charted_impl, refine_charted_adjoint_pallas, charted=True)
_refine_stationary_nn = _make_refine_nn_vjp(
    _refine_stationary_nn_impl, refine_stationary_adjoint_pallas,
    charted=False)
_refine_charted_nn = _make_refine_nn_vjp(
    _refine_charted_nn_impl, refine_charted_adjoint_pallas, charted=True)


# -- public entry points --------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("n_csz", "n_fsz", "block_families", "batch_block",
                     "interpret", "noise", "t", "accum_dtype"),
)
def refine_stationary_pallas(coarse: Array, xi: Array, r: Array,
                             d: Array = None, *, n_csz: int, n_fsz: int,
                             block_families: int = 256,
                             batch_block: int = 1,
                             interpret: bool = False,
                             noise: bool = True,
                             t: int = None,
                             accum_dtype: str = "float32") -> Array:
    """Fused stationary refinement (differentiable). See module docstring.

    coarse: (B, L) halo-padded (L >= T*s + n_csz - s); xi: (B, T, n_fsz)
    r: (n_fsz, n_csz); d: (n_fsz, n_fsz)  ->  fine: (B, T*n_fsz)

    batch_block: leading-batch rows processed per kernel invocation (the
    sample-batch slab; matrices are fetched once per slab).
    noise=False skips the ξ read and the noise add entirely (``xi``/``d``
    may be None); the family count then comes from ``t`` (static).
    """
    if noise:
        return _refine_stationary(
            (n_csz, n_fsz, block_families, batch_block, interpret,
             accum_dtype),
            coarse, xi, r, d,
        )
    tt = t if t is not None else (xi.shape[-2] if xi is not None else None)
    if tt is None:
        raise ValueError("noise=False needs the family count: pass t=")
    return _refine_stationary_nn(
        (tt, n_csz, n_fsz, block_families, batch_block, interpret,
         accum_dtype),
        coarse, r,
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_csz", "n_fsz", "block_families", "batch_block",
                     "interpret", "noise", "t", "accum_dtype"),
)
def refine_charted_pallas(coarse: Array, xi: Array, r: Array,
                          d: Array = None, *, n_csz: int, n_fsz: int,
                          block_families: int = 256,
                          batch_block: int = 1,
                          interpret: bool = False,
                          noise: bool = True,
                          t: int = None,
                          accum_dtype: str = "float32") -> Array:
    """Fused charted refinement with per-family matrices (paper §4.3),
    differentiable via the hand-written adjoint kernels.

    coarse: (B, L); xi: (B, T, n_fsz); r: (T, n_fsz, n_csz);
    d: (T, n_fsz, n_fsz)  ->  fine: (B, T*n_fsz)

    See ``refine_stationary_pallas`` for batch_block / noise semantics;
    with noise=False the family count is taken from ``r``.
    """
    if noise:
        return _refine_charted(
            (n_csz, n_fsz, block_families, batch_block, interpret,
             accum_dtype),
            coarse, xi, r, d,
        )
    return _refine_charted_nn(
        (r.shape[0], n_csz, n_fsz, block_families, batch_block, interpret,
         accum_dtype),
        coarse, r,
    )
