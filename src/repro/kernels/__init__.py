"""Pallas TPU kernels for the ICR refinement hot-spot.

  launch.py     — declarative LaunchPlan records every kernel launches
                  through (DESIGN.md §14) and the verifier analyzes
  icr_refine.py — pl.pallas_call kernels (stationary + charted variants),
                  forward AND adjoint, glued by jax.custom_vjp
  nd.py         — fused N-D refinement as per-axis 1-D passes
  nd_fused.py   — single-launch fused N-D level megakernel
  pyramid.py    — VMEM-resident multi-level launch (DESIGN.md §11)
  policy.py     — storage/accumulation dtype policy (DESIGN.md §11)
  dispatch.py   — per-level backend/route selection + VMEM autotune +
                  launch-plan export (level_launch_plans / chart_launch_plans)
  ref.py        — pure-jnp oracles the kernels are validated against
"""
from . import dispatch, launch, nd, policy, pyramid, ref
from .icr_refine import (
    refine_charted_adjoint_pallas,
    refine_charted_pallas,
    refine_stationary_adjoint_pallas,
    refine_stationary_pallas,
)
from .launch import IndexMap, LaunchPlan, OperandSpec, PlanMismatchError
from .nd import refine_axes
from .policy import BF16, FP32, DtypePolicy
from .pyramid import refine_pyramid

__all__ = [
    "dispatch", "launch", "nd", "policy", "pyramid", "ref",
    "refine_stationary_pallas", "refine_charted_pallas", "refine_axes",
    "refine_stationary_adjoint_pallas", "refine_charted_adjoint_pallas",
    "refine_pyramid", "DtypePolicy", "BF16", "FP32",
    "IndexMap", "OperandSpec", "LaunchPlan", "PlanMismatchError",
]
