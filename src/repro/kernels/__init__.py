"""Pallas TPU kernels for the ICR refinement hot-spot.

  icr_refine.py — pl.pallas_call kernels (stationary + charted variants)
  ops.py        — jit'd wrappers (auto interpret=True off-TPU)
  ref.py        — pure-jnp oracles the kernels are validated against
"""
from . import ops, ref
from .icr_refine import refine_charted_pallas, refine_stationary_pallas

__all__ = ["ops", "ref", "refine_stationary_pallas", "refine_charted_pallas"]
