"""Pallas TPU kernels for the ICR refinement hot-spot.

  icr_refine.py — pl.pallas_call kernels (stationary + charted variants),
                  forward AND adjoint, glued by jax.custom_vjp
  nd.py         — fused N-D refinement as per-axis 1-D passes
  dispatch.py   — per-level backend/route selection + VMEM autotune
  ops.py        — jit'd wrappers (auto interpret=True off-TPU)
  ref.py        — pure-jnp oracles the kernels are validated against
"""
from . import dispatch, nd, ops, ref
from .icr_refine import (
    refine_charted_adjoint_pallas,
    refine_charted_pallas,
    refine_stationary_adjoint_pallas,
    refine_stationary_pallas,
)
from .nd import refine_axes

__all__ = [
    "dispatch", "nd", "ops", "ref",
    "refine_stationary_pallas", "refine_charted_pallas", "refine_axes",
    "refine_stationary_adjoint_pallas", "refine_charted_adjoint_pallas",
]
