"""Fused N-D refinement as per-axis 1-D Pallas passes (DESIGN.md §4).

The per-axis N-D path applies Kronecker-factored refinement matrices
(``core.refine.axis_refinement_matrices_level``) one axis at a time, folding
every other axis into the 1-D kernels' leading batch dimension — the same
batch mechanism that carries the paper's §4.3 invariant-axis broadcast. Each
pass is one fused kernel launch: window build, MXU contraction and (on the
final pass) the correlated noise add all happen in VMEM, so nothing
``(T, n_csz)``-shaped is ever materialized in HBM, for any ndim.

This is the *fallback* N-D route since the single-launch megakernel landed
(``nd_fused.refine_nd_fused``, DESIGN.md §10): dispatch prefers the fused
level kernel and only comes here when the joint tile + halos exceed the
VMEM budget. The per-axis passes pay ``d`` field round-trips through HBM
plus a relayout around every pass (the byte model in
``roofline.level_traffic`` quantifies both).

Pass order is axis ``d-1 .. 0``; mixed stationary/charted axes are supported
per axis (shared ``(n_fsz, n_csz)`` stencil vs per-family ``(T_a, ...)``
matrices). Only the final (axis-0) pass injects the excitation ξ: the noise
factors of axes ``1..d-1`` are pre-contracted into ξ outside the kernel
(cheap batched small GEMMs at fine resolution). Every non-final pass runs
the kernels in ``noise=False`` mode — no ξ operand at all, where they used
to read an all-zeros array from HBM per pass.

With ``sample_axis=True`` the leading dimension of ``field``/``xi`` is a
sample batch; it simply folds into the kernels' batch dimension, so batched
sampling shares every matrix fetch.

Boundaries are handled per axis: ``"shrink"`` needs no padding (family ``t``
reads ``coarse[t*s : t*s + n_csz]`` directly), ``"reflect"`` pre-pads ``b``
pixels per side in HBM once per pass.

The jnp ground truth is ``repro.kernels.ref.refine_axes_ref`` (written
independently); parity is asserted in tests/test_kernels_pallas.py.

Differentiation: the 1-D kernel entry points carry custom VJPs (fused
adjoint kernels, DESIGN.md §9; the noise-free passes use the dxi-free
adjoint), and everything else here — moveaxis, reshapes, the ξ
pre-contraction einsums, the reflect pad — is plain jnp. So ``jax.grad``
through ``refine_axes`` runs the per-axis passes in reverse, each one a
fused adjoint launch: the N-D backward is Kronecker-factored exactly like
the forward, with no joint window tensor ever materialized.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.refine import LevelGeom

from .icr_refine import (
    interpret_default as _interpret_default,
    refine_charted_pallas,
    refine_stationary_pallas,
)
from .nd_fused import precontract_noise

Array = jnp.ndarray


def refine_axes(field: Array, xi: Array, rs, ds, geom: LevelGeom, *,
                interpret: bool | None = None,
                block_families: int | None = None,
                sample_axis: bool = False,
                accum_dtype: str = "float32") -> Array:
    """Fused per-axis N-D refinement (drop-in for refine_level given factors).

    field: (*geom.coarse_shape); xi: (prod(geom.T), n_fsz^ndim) — each with
    an extra leading sample dimension when ``sample_axis=True``.
    rs[a]: (n_fsz, n_csz) on stationary axes else (T_a, n_fsz, n_csz);
    ds[a]:  likewise with n_csz -> n_fsz.
    Returns the fine field, shape ``geom.fine_shape`` (sample dim leading
    when ``sample_axis``). Storage dtype follows the operands; every
    contraction (in-kernel and the ξ pre-contraction here) accumulates in
    ``accum_dtype`` (DESIGN.md §11).
    """
    from .dispatch import autotune_block_families  # lazy: avoid import cycle

    nd = len(geom.coarse_shape)
    fsz, csz, b = geom.n_fsz, geom.n_csz, geom.b
    T = tuple(geom.T)
    interpret = _interpret_default() if interpret is None else interpret
    accum = jnp.dtype(accum_dtype)
    off = 1 if sample_axis else 0
    lead = field.shape[:off]

    # -- excitation: pre-contract noise factors of axes 1..d-1 -----------------
    xi_nd = precontract_noise(xi.reshape(lead + T + (fsz,) * nd), ds,
                              off=off, accum=accum)
    # interleave (T_a, f_a) for a>=1 into the final pass' fine batch layout
    perm = list(range(off))
    for a in range(1, nd):
        perm += [off + a, off + nd + a]
    perm += [off, off + nd]
    xi0 = xi_nd.transpose(perm).reshape(-1, T[0], fsz).astype(field.dtype)

    # -- field: one fused kernel pass per axis, orthogonal axes as batch -------
    out = field
    for a in range(nd - 1, -1, -1):
        ag = geom.axis(a)  # 1-D geometry of this pass
        arr = jnp.moveaxis(out, off + a, -1)
        bshape = arr.shape[:-1]
        coarse = arr.reshape(-1, arr.shape[-1])
        if ag.boundary == "reflect":
            coarse = jnp.pad(coarse, [(0, 0), (b, b)], mode="reflect")
        charted = rs[a].ndim == 3
        bf = block_families or autotune_block_families(
            ag.T[0], csz, fsz, charted=charted,
            itemsize=jnp.dtype(field.dtype).itemsize,
        )
        kern = refine_charted_pallas if charted else refine_stationary_pallas
        if a == 0:
            res = kern(
                coarse, xi0, rs[a], ds[a], n_csz=csz, n_fsz=fsz,
                block_families=bf, interpret=interpret,
                accum_dtype=accum_dtype,
            )
        else:
            # noise already folded into xi0: run the ξ-free kernel variant
            # (no zero-excitation array is ever built or read)
            res = kern(
                coarse, None, rs[a], None, n_csz=csz, n_fsz=fsz,
                block_families=bf, interpret=interpret, noise=False,
                t=ag.T[0], accum_dtype=accum_dtype,
            )
        out = jnp.moveaxis(res.reshape(bshape + (T[a] * fsz,)), -1, off + a)
    return out
