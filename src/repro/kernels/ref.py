"""Pure-jnp oracles for the Pallas refinement kernels.

These are the ground truth every kernel sweep asserts against
(tests/test_kernels_pallas.py). They mirror core.refine.refine_level but are
specialized to the kernel calling conventions:

* 1-D refinement over the last axis, arbitrary leading batch dims (the
  batch dims carry chart-invariant axes, paper §4.3 symmetry broadcast).
* the coarse input is already *halo-padded*: for T families with stride
  ``s = n_fsz//2`` and window ``n_csz`` the coarse length is
  ``T*s + (n_csz - s)`` so family t reads ``coarse[t*s : t*s + n_csz]``.
"""
from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray


def accum_dtype_for(*arrs) -> jnp.dtype:
    """Accumulation dtype matching the kernels' policy default: sub-f32
    storage (bf16/f16) accumulates in f32, wider dtypes in themselves —
    so the oracles stay bit-faithful ground truth for every storage dtype
    (DESIGN.md §11)."""
    dt = jnp.result_type(*[a for a in arrs if a is not None])
    return jnp.float32 if jnp.dtype(dt).itemsize < 4 else jnp.dtype(dt)


def coarse_len(t: int, n_csz: int, n_fsz: int) -> int:
    s = n_fsz // 2
    return t * s + (n_csz - s)


def windows_1d(coarse: Array, t: int, n_csz: int, s: int) -> Array:
    """(..., T, n_csz) family windows from a halo-padded (..., L) coarse
    array via static strided slices (window t = coarse[t*s : t*s + n_csz])."""
    return jnp.stack([coarse[..., k : k + s * (t - 1) + 1 : s]
                      for k in range(n_csz)], axis=-1)


def refine_stationary_ref(coarse: Array, xi: Array, r: Array,
                          sqrt_d: Array = None) -> Array:
    """Stationary refinement (paper Eq. 11–12), one shared stencil.

    coarse: (..., L) halo-padded, L = T*s + (n_csz - s)
    xi:     (..., T, n_fsz)  (None: noise-free — mirrors the kernels'
            ``noise=False`` mode; T is recovered from L)
    r:      (n_fsz, n_csz);  sqrt_d: (n_fsz, n_fsz)
    -> fine (..., T * n_fsz)
    """
    n_fsz, n_csz = r.shape
    s = n_fsz // 2
    t = (xi.shape[-2] if xi is not None
         else (coarse.shape[-1] - (n_csz - s)) // s)
    acc = accum_dtype_for(coarse, xi, r)
    w = windows_1d(coarse, t, n_csz, s)  # (..., T, n_csz)
    fine = jnp.einsum("...tc,fc->...tf", w, r, preferred_element_type=acc)
    if xi is not None:
        fine = fine + jnp.einsum("...tj,fj->...tf", xi, sqrt_d,
                                 preferred_element_type=acc)
    return fine.reshape(*fine.shape[:-2], t * n_fsz).astype(coarse.dtype)


def refine_axes_ref(field: Array, xi: Array, rs, ds, *, T, n_fsz: int,
                    boundary: str = "shrink", b: int = 1) -> Array:
    """Separable N-D refinement oracle: per-axis 1-D passes (Kronecker math).

    Ground truth for repro.kernels.nd.refine_axes, written independently on
    top of the 1-D oracles above. Applies the Kronecker-factored refinement

        fine = (R_0 ⊗ ... ⊗ R_{d-1}) windows(coarse)
             + (D_0 ⊗ ... ⊗ D_{d-1}) xi

    as axis passes d-1..0, folding all other axes into the batch dims of the
    1-D oracles. Only the final (axis-0) pass injects xi; the noise factors
    of the other axes are pre-contracted into it.

    field: (*coarse_shape); xi: (prod(T), n_fsz^d)
    rs[a]: (n_fsz, n_csz) shared or (T_a, n_fsz, n_csz) per-family;
    ds[a]:  likewise with n_csz -> n_fsz.
    -> fine (T_0*n_fsz, ..., T_{d-1}*n_fsz)
    """
    nd = field.ndim
    T = tuple(T)
    fsz = n_fsz

    # pre-contract the noise factors of axes 1..d-1 into xi
    acc = accum_dtype_for(field, xi)
    xi_nd = xi.reshape(T + (fsz,) * nd)
    for a in range(1, nd):
        x2 = jnp.moveaxis(xi_nd, (a, nd + a), (-2, -1))  # (..., T_a, f_a)
        if ds[a].ndim == 2:
            x2 = jnp.einsum("...tj,fj->...tf", x2, ds[a],
                            preferred_element_type=acc)
        else:
            x2 = jnp.einsum("...tj,tfj->...tf", x2, ds[a],
                            preferred_element_type=acc)
        xi_nd = jnp.moveaxis(x2, (-2, -1), (a, nd + a))
    # interleave (T_a, f_a) for a>=1 into the fine batch layout of the
    # final pass: (N^f_1, ..., N^f_{d-1}, T_0, f_0)
    perm = []
    for a in range(1, nd):
        perm += [a, nd + a]
    perm += [0, nd]
    xi0 = xi_nd.transpose(perm).reshape(-1, T[0], fsz).astype(field.dtype)

    out = field
    for a in range(nd - 1, -1, -1):
        arr = jnp.moveaxis(out, a, -1)
        bshape = arr.shape[:-1]
        coarse = arr.reshape(-1, arr.shape[-1])
        if boundary == "reflect":
            coarse = jnp.pad(coarse, [(0, 0), (b, b)], mode="reflect")
        if a == 0:
            xi_a = xi0
        else:
            xi_a = jnp.zeros((coarse.shape[0], T[a], fsz), coarse.dtype)
        if rs[a].ndim == 2:
            res = refine_stationary_ref(coarse, xi_a, rs[a], ds[a])
        else:
            res = refine_charted_ref(coarse, xi_a, rs[a], ds[a])
        out = jnp.moveaxis(res.reshape(bshape + (T[a] * fsz,)), -1, a)
    return out


def refine_charted_ref(coarse: Array, xi: Array, r: Array,
                       sqrt_d: Array = None) -> Array:
    """Charted (non-stationary) refinement: per-family matrices (paper §4.3).

    coarse: (..., L) halo-padded
    xi:     (..., T, n_fsz)  (None: noise-free, kernels' ``noise=False``)
    r:      (T, n_fsz, n_csz);  sqrt_d: (T, n_fsz, n_fsz)
    -> fine (..., T * n_fsz)
    """
    t, n_fsz, n_csz = r.shape
    s = n_fsz // 2
    acc = accum_dtype_for(coarse, xi, r)
    w = windows_1d(coarse, t, n_csz, s)  # (..., T, n_csz)
    fine = jnp.einsum("...tc,tfc->...tf", w, r, preferred_element_type=acc)
    if xi is not None:
        fine = fine + jnp.einsum("...tj,tfj->...tf", xi, sqrt_d,
                                 preferred_element_type=acc)
    return fine.reshape(*fine.shape[:-2], t * n_fsz).astype(coarse.dtype)


# -- adjoints (ground truth for the custom-VJP Pallas kernels) ------------------
def overlap_add_1d(dw: Array, coarse_len: int, s: int) -> Array:
    """Adjoint of ``windows_1d``: scatter-add overlapping window cotangents
    back onto the coarse grid. dw: (..., T, n_csz) -> (..., coarse_len).

    dcoarse[t*s + k] += dw[t, k]; written with the same static strided
    slices as the forward (``.at[...].add`` on a strided view — the scatter
    pattern is an overlap-add, never a gather).
    """
    t, n_csz = dw.shape[-2], dw.shape[-1]
    dc = jnp.zeros(dw.shape[:-2] + (coarse_len,), dw.dtype)
    for k in range(n_csz):
        dc = dc.at[..., k : k + s * (t - 1) + 1 : s].add(dw[..., k])
    return dc


def refine_stationary_vjp_ref(coarse: Array, xi: Array, r: Array,
                              sqrt_d: Array, g: Array):
    """Hand-derived VJP of ``refine_stationary_ref`` (all four cotangents).

    g: (..., T*n_fsz) cotangent of fine -> (dcoarse, dxi, dr, dd).
    """
    n_fsz, n_csz = r.shape
    s = n_fsz // 2
    t = xi.shape[-2]
    g3 = g.reshape(g.shape[:-1] + (t, n_fsz))
    dw = jnp.einsum("...tf,fc->...tc", g3, r)
    dcoarse = overlap_add_1d(dw, coarse.shape[-1], s)
    dxi = jnp.einsum("...tf,fj->...tj", g3, sqrt_d)
    w = windows_1d(coarse, t, n_csz, s)
    dr = jnp.einsum("...tf,...tc->fc", g3, w)
    dd = jnp.einsum("...tf,...tj->fj", g3, xi)
    return dcoarse, dxi, dr, dd


def refine_charted_vjp_ref(coarse: Array, xi: Array, r: Array,
                           sqrt_d: Array, g: Array):
    """Hand-derived VJP of ``refine_charted_ref`` (per-family matrices)."""
    t, n_fsz, n_csz = r.shape
    s = n_fsz // 2
    g3 = g.reshape(g.shape[:-1] + (t, n_fsz))
    dw = jnp.einsum("...tf,tfc->...tc", g3, r)
    dcoarse = overlap_add_1d(dw, coarse.shape[-1], s)
    dxi = jnp.einsum("...tf,tfj->...tj", g3, sqrt_d)
    w = windows_1d(coarse, t, n_csz, s)
    dr = jnp.einsum("...tf,...tc->tfc", g3, w)
    dd = jnp.einsum("...tf,...tj->tfj", g3, xi)
    return dcoarse, dxi, dr, dd
