"""Pure-jnp oracles for the Pallas refinement kernels.

These are the ground truth every kernel sweep asserts against
(tests/test_kernels_pallas.py). They mirror core.refine.refine_level but are
specialized to the kernel calling conventions:

* 1-D refinement over the last axis, arbitrary leading batch dims (the
  batch dims carry chart-invariant axes, paper §4.3 symmetry broadcast).
* the coarse input is already *halo-padded*: for T families with stride
  ``s = n_fsz//2`` and window ``n_csz`` the coarse length is
  ``T*s + (n_csz - s)`` so family t reads ``coarse[t*s : t*s + n_csz]``.
"""
from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray


def coarse_len(t: int, n_csz: int, n_fsz: int) -> int:
    s = n_fsz // 2
    return t * s + (n_csz - s)


def refine_stationary_ref(coarse: Array, xi: Array, r: Array,
                          sqrt_d: Array) -> Array:
    """Stationary refinement (paper Eq. 11–12), one shared stencil.

    coarse: (..., L) halo-padded, L = T*s + (n_csz - s)
    xi:     (..., T, n_fsz)
    r:      (n_fsz, n_csz);  sqrt_d: (n_fsz, n_fsz)
    -> fine (..., T * n_fsz)
    """
    n_fsz, n_csz = r.shape
    s = n_fsz // 2
    t = xi.shape[-2]
    w = jnp.stack([coarse[..., k : k + s * (t - 1) + 1 : s]
                   for k in range(n_csz)], axis=-1)  # (..., T, n_csz)
    fine = jnp.einsum("...tc,fc->...tf", w, r)
    fine = fine + jnp.einsum("...tj,fj->...tf", xi, sqrt_d)
    return fine.reshape(*fine.shape[:-2], t * n_fsz)


def refine_charted_ref(coarse: Array, xi: Array, r: Array,
                       sqrt_d: Array) -> Array:
    """Charted (non-stationary) refinement: per-family matrices (paper §4.3).

    coarse: (..., L) halo-padded
    xi:     (..., T, n_fsz)
    r:      (T, n_fsz, n_csz);  sqrt_d: (T, n_fsz, n_fsz)
    -> fine (..., T * n_fsz)
    """
    t, n_fsz, n_csz = r.shape
    s = n_fsz // 2
    w = jnp.stack([coarse[..., k : k + s * (t - 1) + 1 : s]
                   for k in range(n_csz)], axis=-1)  # (..., T, n_csz)
    fine = jnp.einsum("...tc,tfc->...tf", w, r)
    fine = fine + jnp.einsum("...tj,tfj->...tf", xi, sqrt_d)
    return fine.reshape(*fine.shape[:-2], t * n_fsz)
