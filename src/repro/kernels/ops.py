"""Jit'd public wrappers around the Pallas refinement kernels.

These adapt core.refine's calling convention (LevelGeom + matrices as
produced by ``refinement_matrices_level``) to the kernel layer. Since the
dispatch layer landed (dispatch.py), both wrappers are thin aliases of
``dispatch.refine``: the backend (pallas on TPU, interpret elsewhere,
reference for uncovered geometry) and the kernel variant (stationary vs
charted) are selected from the level geometry, not from which wrapper the
caller picked — the old ad-hoc shape guards live there now.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.refine import LevelGeom

from . import dispatch, ref as _ref

Array = jnp.ndarray


def _backend(interpret: bool | None) -> str | None:
    if interpret is None:
        return None  # dispatch auto-selects from the runtime platform
    return dispatch.BACKEND_INTERPRET if interpret else dispatch.BACKEND_PALLAS


def refine_stationary(field: Array, xi: Array, r: Array, d: Array,
                      geom: LevelGeom, *, interpret: bool | None = None,
                      block_families: int | None = None) -> Array:
    """Drop-in replacement for core.refine.refine_level on 1-D charts.

    Falls back to the jnp reference for geometry the kernels don't cover
    (joint N-D refinement without per-axis factors)."""
    return dispatch.refine(field, xi, r, d, geom,
                           backend=_backend(interpret),
                           block_families=block_families)


def refine_charted(field: Array, xi: Array, r: Array, d: Array,
                   geom: LevelGeom, *, interpret: bool | None = None,
                   block_families: int | None = None) -> Array:
    """Charted 1-D refinement with per-family matrices (paper §4.3)."""
    return dispatch.refine(field, xi, r, d, geom,
                           backend=_backend(interpret),
                           block_families=block_families)


# -- flat functional forms (benchmarks / tests) --------------------------------
def refine_stationary_jnp(coarse, xi, r, d):
    return _ref.refine_stationary_ref(coarse, xi, r, d)


def refine_charted_jnp(coarse, xi, r, d):
    return _ref.refine_charted_ref(coarse, xi, r, d)
