"""DEPRECATED shim — use ``repro.kernels.dispatch.refine`` directly.

The ops layer predates the dispatch layer; its wrappers were already
thin aliases of :func:`repro.kernels.dispatch.refine`, and with the
launch-plan refactor (DESIGN.md §14) every caller goes through dispatch
so the executed launch matches the exported plans.  This module stays
importable only for backward compatibility and emits a
``DeprecationWarning`` on use; it will be removed once nothing imports
it.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.core.refine import LevelGeom

from . import dispatch, ref as _ref

Array = jnp.ndarray


def _warn(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{name} is deprecated; call {repl} instead",
        DeprecationWarning, stacklevel=3)


def _backend(interpret: bool | None) -> str | None:
    if interpret is None:
        return None  # dispatch auto-selects from the runtime platform
    return dispatch.BACKEND_INTERPRET if interpret else dispatch.BACKEND_PALLAS


def refine_stationary(field: Array, xi: Array, r: Array, d: Array,
                      geom: LevelGeom, *, interpret: bool | None = None,
                      block_families: int | None = None) -> Array:
    """Deprecated alias of ``dispatch.refine`` (stationary 1-D route)."""
    _warn("refine_stationary", "repro.kernels.dispatch.refine")
    return dispatch.refine(field, xi, r, d, geom,
                           backend=_backend(interpret),
                           block_families=block_families)


def refine_charted(field: Array, xi: Array, r: Array, d: Array,
                   geom: LevelGeom, *, interpret: bool | None = None,
                   block_families: int | None = None) -> Array:
    """Deprecated alias of ``dispatch.refine`` (charted 1-D route)."""
    _warn("refine_charted", "repro.kernels.dispatch.refine")
    return dispatch.refine(field, xi, r, d, geom,
                           backend=_backend(interpret),
                           block_families=block_families)


def refine_stationary_jnp(coarse, xi, r, d):
    """Deprecated alias of ``ref.refine_stationary_ref``."""
    _warn("refine_stationary_jnp", "repro.kernels.ref.refine_stationary_ref")
    return _ref.refine_stationary_ref(coarse, xi, r, d)


def refine_charted_jnp(coarse, xi, r, d):
    """Deprecated alias of ``ref.refine_charted_ref``."""
    _warn("refine_charted_jnp", "repro.kernels.ref.refine_charted_ref")
    return _ref.refine_charted_ref(coarse, xi, r, d)
