"""Jit'd public wrappers around the Pallas refinement kernels.

The wrappers adapt core.refine's calling convention (LevelGeom + matrices as
produced by ``refinement_matrices_level``) to the kernels' flat layout, pick
``interpret=True`` automatically off-TPU (the kernel body then runs as a pure
Python/jnp program — bit-for-bit checkable on CPU), and fall back to the jnp
reference for shapes the kernels don't cover (ND joint refinement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.refine import LevelGeom, refine_level
from . import ref as _ref
from .icr_refine import refine_charted_pallas, refine_stationary_pallas

Array = jnp.ndarray


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def refine_stationary(field: Array, xi: Array, r: Array, d: Array,
                      geom: LevelGeom, *, interpret: bool | None = None,
                      block_families: int = 256) -> Array:
    """Drop-in replacement for core.refine.refine_level on stationary 1-D
    charts (all axes invariant, ndim == 1)."""
    if len(geom.coarse_shape) != 1 or geom.boundary not in ("shrink", "reflect"):
        return refine_level(field, xi, r, d, geom)
    interpret = _interpret_default() if interpret is None else interpret
    n_csz, n_fsz = geom.n_csz, geom.n_fsz
    t = geom.T[0]
    coarse = field.reshape(1, -1)
    if geom.boundary == "reflect":
        coarse = jnp.pad(coarse, [(0, 0), (geom.b, geom.b)], mode="reflect")
    r2 = r.reshape(n_fsz, n_csz)
    d2 = d.reshape(n_fsz, n_fsz)
    out = refine_stationary_pallas(
        coarse, xi.reshape(1, t, n_fsz), r2, d2,
        n_csz=n_csz, n_fsz=n_fsz, block_families=block_families,
        interpret=interpret,
    )
    return out.reshape(geom.fine_shape)


def refine_charted(field: Array, xi: Array, r: Array, d: Array,
                   geom: LevelGeom, *, interpret: bool | None = None,
                   block_families: int = 256) -> Array:
    """Charted 1-D refinement with per-family matrices (paper §4.3)."""
    if len(geom.coarse_shape) != 1:
        return refine_level(field, xi, r, d, geom)
    interpret = _interpret_default() if interpret is None else interpret
    n_csz, n_fsz = geom.n_csz, geom.n_fsz
    t = geom.T[0]
    coarse = field.reshape(1, -1)
    if geom.boundary == "reflect":
        coarse = jnp.pad(coarse, [(0, 0), (geom.b, geom.b)], mode="reflect")
    out = refine_charted_pallas(
        coarse, xi.reshape(1, t, n_fsz),
        r.reshape(t, n_fsz, n_csz), d.reshape(t, n_fsz, n_fsz),
        n_csz=n_csz, n_fsz=n_fsz, block_families=block_families,
        interpret=interpret,
    )
    return out.reshape(geom.fine_shape)


# -- flat functional forms (benchmarks / tests) --------------------------------
def refine_stationary_jnp(coarse, xi, r, d):
    return _ref.refine_stationary_ref(coarse, xi, r, d)


def refine_charted_jnp(coarse, xi, r, d):
    return _ref.refine_charted_ref(coarse, xi, r, d)
