"""Single-launch fused N-D refinement level — the megakernel (DESIGN.md §10).

The per-axis N-D path (``nd.refine_axes``) executes one refinement level as
``d`` separate Pallas launches with the intermediate field round-tripping
through HBM between axis passes (plus a relayout around every pass). For the
flagship 3-D dust map that is ~3x the minimal field traffic. This module
collapses a whole level into ONE ``pallas_call``:

  * each grid step loads a coarse tile — a slab of ``b_f`` axis-0 families
    (halo via the second-shifted-view trick, DESIGN.md §3) times the FULL
    extent of every trailing axis — into VMEM,
  * performs all ``d`` per-axis Kronecker contractions back-to-back in
    VMEM/VREGs (window build per axis is the same contiguous-reshape +
    static-row-shift trick as the 1-D kernels: no gather, no strided loads),
  * adds the correlated noise ``sqrt(D_0) ξ`` (the noise factors of axes
    ``1..d-1`` are pre-contracted into ξ outside, exactly like
    ``nd.refine_axes``), and
  * writes the fine tile once.

HBM traffic per level drops from ``d·(read+write N)`` plus relayouts to
``read L + read ξ + write N`` — the 1-D kernel's traffic equation, now at
any dimensionality (``roofline.level_traffic`` carries the model).

Tiling is along axis 0 only; the trailing axes ride whole inside the tile.
When the joint tile + halos exceed the VMEM budget the dispatch layer falls
back to the per-axis passes (``dispatch.autotune_nd_fused`` returns None —
the fallback rule of DESIGN.md §10).

A native leading **sample-batch dimension** (``s_b`` samples per tile) lets
batched posterior sampling / serving amortize every matrix load across the
slab instead of lifting the batch into the grid.

Differentiation: the core carries a ``jax.custom_vjp``. At fixed matrices
(MAP/ADVI inference, ``apply_sqrt_T``) the backward hand-composes the
existing 1-D *adjoint* kernels in reverse axis order — each a fused
gather-free launch, the non-axis-0 ones in ``noise=False`` mode (no dxi).
When the matrices are perturbed (learning θ) the backward falls back to
``jax.vjp`` of the independent jnp reference ``_nd_fused_ref`` — the
parameter-sized window einsums of DESIGN.md §9, gated by
``symbolic_zeros`` so inference never pays them.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.custom_derivatives import SymbolicZero

from repro.core.refine import LevelGeom

from .icr_refine import (
    interpret_default as _interpret_default,
    refine_charted_adjoint_pallas,
    refine_stationary_adjoint_pallas,
)
from .launch import IndexMap, LaunchPlan, OperandSpec, pad_to, run_plan
from .ref import windows_1d

Array = jnp.ndarray


# -- in-VMEM building blocks ----------------------------------------------------
def _slice_axis(x: Array, ax: int, length: int) -> Array:
    if x.shape[ax] == length:
        return x
    idx = [slice(None)] * x.ndim
    idx[ax] = slice(0, length)
    return x[tuple(idx)]


def _axis_windows(x: Array, ax: int, t: int, s: int, n_csz: int) -> Array:
    """Window matrix along axis ``ax``: (..., rows*s, ...) -> (..., t, n_csz,
    ...) with the window dim inserted right after ``ax``.

    Same contiguous-reshape + static-row-shift construction as the 1-D
    ``_window_cols`` (element ``t·s + k`` = reshape(rows, s)[t + k//s, k%s])
    applied to an interior axis — no gather, no strided access.
    """
    q_max = (n_csz - 1) // s
    shp = x.shape
    rows = shp[ax] // s  # == t + q_max by construction
    assert rows >= t + q_max
    resh = x.reshape(shp[:ax] + (rows, s) + shp[ax + 1 :])
    cols = []
    for k in range(n_csz):
        q, r = divmod(k, s)
        idx = [slice(None)] * resh.ndim
        idx[ax] = slice(q, q + t)
        idx[ax + 1] = r
        cols.append(resh[tuple(idx)])
    return jnp.stack(cols, axis=ax + 1)


def _contract_windows(w: Array, r: Array, ax: int, *, merge: bool = True,
                      accum=jnp.float32) -> Array:
    """Contract the window dim (at ``ax + 1``) with a refinement factor.

    w: (..., t, n_csz, ...); r: (n_fsz, n_csz) shared or (t, n_fsz, n_csz)
    per-family -> (..., t*n_fsz, ...) (or unmerged (..., t, n_fsz, ...)).
    """
    n = w.ndim
    ls = [chr(ord("a") + i) for i in range(n)]
    t_l, c_l = ls[ax], ls[ax + 1]
    f_l = chr(ord("a") + n)
    out_ls = list(ls)
    out_ls[ax + 1] = f_l
    rsub = (t_l + f_l + c_l) if r.ndim == 3 else (f_l + c_l)
    out = jnp.einsum(f"{''.join(ls)},{rsub}->{''.join(out_ls)}", w, r,
                     preferred_element_type=accum)
    if merge:
        shp = out.shape
        out = out.reshape(shp[:ax] + (shp[ax] * shp[ax + 1],) + shp[ax + 2 :])
    return out


# -- the megakernel body --------------------------------------------------------
def _nd_fused_kernel(*refs, nd: int, csz: int, fsz: int, T: tuple,
                     charted: tuple, b_f: int, s_b: int, accum):
    coarse_ref, halo_ref, xi_ref, r0_ref, d0_ref = refs[:5]
    rt_refs = refs[5 : 5 + nd - 1]
    out_ref = refs[-1]
    s = fsz // 2
    q_max = (csz - 1) // s

    x = jnp.concatenate([coarse_ref[...], halo_ref[:, : q_max * s]], axis=1)
    # (s_b, (b_f + q_max)*s, *Lp_trail) — all d contractions happen on this
    # tile in VMEM; nothing intermediate ever goes back to HBM.
    for a in range(nd - 1, 0, -1):
        ax = 1 + a
        x = _slice_axis(x, ax, (T[a] + q_max) * s)
        w = _axis_windows(x, ax, T[a], s, csz)
        x = _contract_windows(w, rt_refs[a - 1][...], ax, accum=accum)

    w0 = _axis_windows(x, 1, b_f, s, csz)          # (s_b, b_f, csz, *F_trail)
    fine = _contract_windows(w0, r0_ref[...], 1, merge=False, accum=accum)
    prod_f = int(np.prod(fine.shape[3:])) if nd > 1 else 1
    fine = fine.reshape(s_b, b_f, fsz, prod_f)

    xi = xi_ref[...].reshape(s_b, b_f, fsz, prod_f)
    d0 = d0_ref[...]
    if d0.ndim == 2:
        fine = fine + jnp.einsum("sbjp,fj->sbfp", xi, d0,
                                 preferred_element_type=accum)
    else:
        fine = fine + jnp.einsum("sbjp,bfj->sbfp", xi, d0,
                                 preferred_element_type=accum)
    out_ref[...] = fine.reshape(s_b, b_f * fsz, prod_f).astype(out_ref.dtype)


# Named index maps — grid (family block i, sample block b), samples
# innermost so the blocked matrices stay VMEM-resident (DESIGN.md §14).
_IM_BI0 = IndexMap("(b, i, 0)", lambda i, b: (b, i, 0))
_IM_00 = IndexMap("(0, 0)", lambda i, b: (0, 0))
_IM_000 = IndexMap("(0, 0, 0)", lambda i, b: (0, 0, 0))
_IM_I00 = IndexMap("(i, 0, 0)", lambda i, b: (i, 0, 0))


def fused_launch_shapes(geom: LevelGeom, *, samples: int, b_f: int,
                        s_b: int) -> dict:
    """Padded operand extents of one megakernel launch.

    The single source of truth shared by ``refine_nd_fused``'s padding and
    the geom-level plan export (``dispatch.level_launch_plans``) — the
    shapes the wrapper pads to and the shapes the verifier proves coverage
    for cannot drift apart.
    """
    nd = len(geom.coarse_shape)
    fsz, csz, b = geom.n_fsz, geom.n_csz, geom.b
    s = fsz // 2
    q_max = (csz - 1) // s
    T = tuple(geom.T)
    pad = 2 * b if geom.boundary == "reflect" else 0
    lp_trail = tuple(max(geom.coarse_shape[a] + pad, (T[a] + q_max) * s)
                     for a in range(1, nd))
    nblk = -(-T[0] // b_f)
    l0 = geom.coarse_shape[0] + pad
    nblk2 = max(nblk + 1, -(-l0 // (b_f * s)))
    prod_f = 1
    for a in range(1, nd):
        prod_f *= T[a] * fsz
    return dict(nd=nd, T=T, nblk=nblk, l0p=nblk2 * b_f * s,
                lp_trail=lp_trail, sp=-(-samples // s_b) * s_b,
                prod_f=prod_f)


def nd_fused_launch_plan(*, nd: int, csz: int, fsz: int, T: tuple,
                         charted: tuple, b_f: int, s_b: int, sp: int,
                         l0p: int, lp_trail: tuple, nblk: int, prod_f: int,
                         dtype, accum_dtype) -> LaunchPlan:
    """Declarative launch geometry of one fused N-D level launch."""
    s = fsz // 2
    q_max = (csz - 1) // s
    nbs = sp // s_b
    dtype = jnp.dtype(dtype).name
    zeros_t = (0,) * (nd - 1)
    trail = ", 0" * (nd - 1)
    im_main = IndexMap(f"(b, i{trail})", lambda i, b: (b, i) + zeros_t)
    im_halo = IndexMap(f"(b, i + 1{trail})",
                       lambda i, b: (b, i + 1) + zeros_t)
    field_shape = (sp, l0p) + tuple(lp_trail)
    field_blk = (s_b, b_f * s) + tuple(lp_trail)
    inputs = [
        OperandSpec("field", field_blk, im_main, field_shape, dtype,
                    overhang=((0, 0), (0, q_max * s)) + ((0, 0),) * (nd - 1)),
        OperandSpec("field_halo", field_blk, im_halo, field_shape, dtype,
                    halo_of="field"),
        OperandSpec("xi", (s_b, b_f * fsz, prod_f), _IM_BI0,
                    (sp, nblk * b_f * fsz, prod_f), dtype),
    ]
    if charted[0]:
        inputs.append(OperandSpec("r0", (b_f, fsz, csz), _IM_I00,
                                  (nblk * b_f, fsz, csz), dtype))
        inputs.append(OperandSpec("d0", (b_f, fsz, fsz), _IM_I00,
                                  (nblk * b_f, fsz, fsz), dtype))
    else:
        inputs.append(OperandSpec("r0", (fsz, csz), _IM_00,
                                  (fsz, csz), dtype))
        inputs.append(OperandSpec("d0", (fsz, fsz), _IM_00,
                                  (fsz, fsz), dtype))
    for a in range(1, nd):
        if charted[a]:
            inputs.append(OperandSpec(f"r{a}", (T[a], fsz, csz), _IM_000,
                                      (T[a], fsz, csz), dtype))
        else:
            inputs.append(OperandSpec(f"r{a}", (fsz, csz), _IM_00,
                                      (fsz, csz), dtype))
    out = OperandSpec("fine", (s_b, b_f * fsz, prod_f), _IM_BI0,
                      (sp, nblk * b_f * fsz, prod_f), dtype)
    return LaunchPlan(
        kernel="refine_nd_fused", grid=(nblk, nbs),
        inputs=tuple(inputs), outputs=(out,),
        accum_dtype=jnp.dtype(accum_dtype).name,
        params=dict(kind="fwd", nd=nd, csz=csz, fsz=fsz, T=tuple(T),
                    charted=tuple(charted), s=s, b_f=b_f, s_b=s_b,
                    nblk=nblk, nbs=nbs, l0p=l0p,
                    lp_trail=tuple(lp_trail), prod_f=prod_f),
    )


def _nd_fused_impl(meta, field: Array, xi0: Array, r0: Array, d0: Array,
                   rts: tuple) -> Array:
    nd, csz, fsz, T, charted, b_f, s_b, interpret, accum_name = meta
    plan = nd_fused_launch_plan(
        nd=nd, csz=csz, fsz=fsz, T=T, charted=charted, b_f=b_f, s_b=s_b,
        sp=field.shape[0], l0p=field.shape[1], lp_trail=field.shape[2:],
        nblk=xi0.shape[1] // (b_f * fsz), prod_f=xi0.shape[2],
        dtype=field.dtype, accum_dtype=accum_name)
    kern = functools.partial(
        _nd_fused_kernel, nd=nd, csz=csz, fsz=fsz, T=T, charted=charted,
        b_f=b_f, s_b=s_b, accum=jnp.dtype(accum_name),
    )
    return run_plan(kern, plan, (field, field, xi0, r0, d0, *rts),
                    interpret=interpret)


def _nd_fused_ref(meta, field: Array, xi0: Array, r0: Array, d0: Array,
                  rts: tuple) -> Array:
    """Pure-jnp reference of the megakernel core (same padded operands).

    Ground truth for the parity tests and the learned-θ backward: windows
    via strided slices, contractions as einsums — materializes what the
    kernel keeps in VMEM.
    """
    nd, csz, fsz, T, charted, b_f, s_b, interpret, accum_name = meta
    accum = jnp.dtype(accum_name)
    s = fsz // 2
    q_max = (csz - 1) // s
    sp = field.shape[0]
    t0p = xi0.shape[1] // fsz
    prod_f = xi0.shape[2]

    x = field
    for a in range(nd - 1, 0, -1):
        ax = 1 + a
        arr = jnp.moveaxis(x, ax, -1)[..., : (T[a] + q_max) * s]
        w = windows_1d(arr, T[a], csz, s)
        eq = "...tc,tfc->...tf" if rts[a - 1].ndim == 3 else "...tc,fc->...tf"
        fine = jnp.einsum(eq, w, rts[a - 1], preferred_element_type=accum)
        fine = fine.reshape(arr.shape[:-1] + (T[a] * fsz,))
        x = jnp.moveaxis(fine, -1, ax)

    arr = jnp.moveaxis(x, 1, -1)                  # (sp, *F_trail, L0p)
    w = windows_1d(arr, t0p, csz, s)
    eq = "...tc,tfc->...tf" if r0.ndim == 3 else "...tc,fc->...tf"
    fine = jnp.einsum(eq, w, r0,                  # (sp, *F_trail, T0p, fsz)
                      preferred_element_type=accum)
    fine = fine.reshape(sp, prod_f, t0p, fsz).transpose(0, 2, 3, 1)

    xi3 = xi0.reshape(sp, t0p, fsz, prod_f)
    eq = "stjp,tfj->stfp" if d0.ndim == 3 else "stjp,fj->stfp"
    fine = fine + jnp.einsum(eq, xi3, d0, preferred_element_type=accum)
    return fine.reshape(sp, t0p * fsz, prod_f).astype(field.dtype)


# -- custom VJP -----------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _nd_fused_core(meta, field, xi0, r0, d0, rts):
    return _nd_fused_impl(meta, field, xi0, r0, d0, rts)


def _core_fwd(meta, field, xi0, r0, d0, rts):
    vals = (field.value, xi0.value, r0.value, d0.value,
            tuple(t.value for t in rts))
    out = _nd_fused_impl(meta, *vals[:4], vals[4])
    mats_pert = (r0.perturbed or d0.perturbed
                 or any(t.perturbed for t in rts))
    return out, vals + (() if mats_pert else None,)


def _core_bwd(meta, res, g):
    nd, csz, fsz, T, charted, b_f, s_b, interpret, accum_name = meta
    field, xi0, r0, d0, rts, mats_pert = res
    zeros = (jnp.zeros_like(field), jnp.zeros_like(xi0),
             jnp.zeros_like(r0), jnp.zeros_like(d0),
             tuple(jnp.zeros_like(t) for t in rts))
    if isinstance(g, SymbolicZero):
        return zeros
    if mats_pert is not None:
        # learning θ: the matrix cotangents need the per-stage window
        # tensors; replay the jnp reference under jax.vjp (parameter-sized
        # einsums, DESIGN.md §9 — never the hot inference path).
        _, vjp = jax.vjp(
            lambda fl, x, a, b, c: _nd_fused_ref(meta, fl, x, a, b, c),
            field, xi0, r0, d0, rts)
        return vjp(g)

    # fixed matrices: compose the 1-D adjoint kernels in reverse axis order.
    from .dispatch import autotune_block_families  # lazy: import cycle

    s = fsz // 2
    q_max = (csz - 1) // s
    sp = field.shape[0]
    l0p = field.shape[1]
    lp_trail = field.shape[2:]
    t0p = xi0.shape[1] // fsz
    prod_f = xi0.shape[2]
    f_trail = tuple(T[a] * fsz for a in range(1, nd))

    # axis-0 adjoint (with noise: dxi shares the fine-cotangent read)
    gb = g.reshape(sp, t0p * fsz, prod_f)
    gb = jnp.moveaxis(gb, 1, -1).reshape(sp * prod_f, t0p * fsz)
    bf0 = autotune_block_families(t0p, csz, fsz, charted=charted[0])
    adj0 = (refine_charted_adjoint_pallas if charted[0]
            else refine_stationary_adjoint_pallas)
    dc0, dxi0 = adj0(gb, r0, d0, coarse_len=l0p, n_csz=csz, n_fsz=fsz,
                     block_families=bf0, interpret=interpret,
                     accum_dtype=accum_name)
    dxi = dxi0.reshape(sp, prod_f, t0p, fsz).transpose(0, 2, 3, 1)
    dxi = dxi.reshape(sp, t0p * fsz, prod_f).astype(xi0.dtype)
    cur = dc0.reshape((sp,) + f_trail + (l0p,))
    cur = jnp.moveaxis(cur, -1, 1)                # (sp, L0p, *F_trail)

    # trailing-axis adjoints, noise=False: no ξ was injected on those passes
    for a in range(1, nd):
        ax = 1 + a
        arr = jnp.moveaxis(cur, ax, -1)
        bshape = arr.shape[:-1]
        g_a = arr.reshape(-1, T[a] * fsz)
        bf_a = autotune_block_families(T[a], csz, fsz, charted=charted[a])
        adj = (refine_charted_adjoint_pallas if charted[a]
               else refine_stationary_adjoint_pallas)
        used = (T[a] + q_max) * s
        dca = adj(g_a, rts[a - 1], coarse_len=used, n_csz=csz, n_fsz=fsz,
                  block_families=bf_a, interpret=interpret, noise=False,
                  accum_dtype=accum_name)
        if lp_trail[a - 1] > used:  # tail the forward's tile slice dropped
            dca = jnp.pad(dca, [(0, 0), (0, lp_trail[a - 1] - used)])
        cur = jnp.moveaxis(dca.reshape(bshape + (lp_trail[a - 1],)), -1, ax)

    return (cur.astype(field.dtype), dxi, zeros[2], zeros[3], zeros[4])


_nd_fused_core.defvjp(_core_fwd, _core_bwd, symbolic_zeros=True)


# -- excitation layout (shared by the megakernel, the per-axis passes and
# the §11 pyramid — one implementation of the §10 ξ convention) ---------------
def precontract_noise(xi_nd, ds, *, off: int, accum) -> Array:
    """Fold the trailing-axis noise factors ``sqrt(D_a)``, a >= 1, into the
    ``(..., T_0..T_{d-1}, f_0..f_{d-1})`` excitation tensor (only the
    axis-0 pass injects noise in-kernel; ``off`` counts leading batch/sample
    dims). Cheap batched small GEMMs, accumulated in ``accum``."""
    nd = (xi_nd.ndim - off) // 2
    for a in range(1, nd):
        x2 = jnp.moveaxis(xi_nd, (off + a, off + nd + a), (-2, -1))
        if ds[a].ndim == 2:
            x2 = jnp.einsum("...tj,fj->...tf", x2, ds[a],
                            preferred_element_type=accum)
        else:
            x2 = jnp.einsum("...tj,tfj->...tf", x2, ds[a],
                            preferred_element_type=accum)
        xi_nd = jnp.moveaxis(x2, (-2, -1), (off + a, off + nd + a))
    return xi_nd


def prepare_xi0(xi: Array, ds, T: tuple, fsz: int, *, accum,
                storage) -> Array:
    """``(S, prod T, fsz^d)`` ξ -> the megakernel tile layout
    ``(S, T_0·fsz, prod_f)`` with trailing noise pre-contracted."""
    nd = len(T)
    n_s = xi.shape[0]
    xi_nd = precontract_noise(
        xi.reshape((n_s,) + tuple(T) + (fsz,) * nd), ds, off=1, accum=accum)
    perm = [0, 1, 1 + nd]
    for a in range(1, nd):
        perm += [1 + a, 1 + nd + a]
    return xi_nd.transpose(perm).reshape(n_s, T[0] * fsz, -1).astype(storage)


# -- public wrapper -------------------------------------------------------------
def refine_nd_fused(field: Array, xi: Array, rs, ds, geom: LevelGeom, *,
                    interpret: bool | None = None,
                    block_families: int | None = None,
                    sample_block: int | None = None,
                    sample_axis: bool = False,
                    accum_dtype: str = "float32") -> Array:
    """One fused Pallas launch for a whole N-D refinement level.

    Drop-in for ``nd.refine_axes`` (bit-compatible at 1e-5 given the same
    per-axis factors). With ``sample_axis=True`` the leading dimension of
    ``field``/``xi`` is a sample batch processed natively inside the kernel
    tiles (``s_b`` samples per grid step).

    field: (*geom.coarse_shape) or (S, *coarse_shape);
    xi: (prod(T), n_fsz^d) or (S, prod(T), n_fsz^d);
    rs[a]/ds[a]: per-axis factors from ``axis_refinement_matrices_level``.
    """
    from .dispatch import autotune_nd_fused  # lazy: avoid import cycle

    nd = len(geom.coarse_shape)
    if nd < 2:
        raise ValueError("refine_nd_fused needs an N-D level (ndim >= 2)")
    fsz, csz, b = geom.n_fsz, geom.n_csz, geom.b
    s = fsz // 2
    q_max = (csz - 1) // s
    T = tuple(geom.T)
    charted = tuple(rs[a].ndim == 3 for a in range(nd))
    interpret = _interpret_default() if interpret is None else interpret
    accum = jnp.dtype(accum_dtype)

    if not sample_axis:
        field, xi = field[None], xi[None]
    n_s = field.shape[0]

    blocks = autotune_nd_fused(geom, charted=charted, samples=n_s,
                               itemsize=jnp.dtype(field.dtype).itemsize)
    if blocks is None:
        raise ValueError(
            "fused N-D tile exceeds the VMEM budget; dispatch should have "
            "routed this level to the per-axis passes (nd.refine_axes)"
        )
    b_f, s_b = blocks
    if block_families is not None:
        b_f = max(min(block_families, T[0]), q_max, 1)
    if sample_block is not None:
        s_b = max(1, min(sample_block, n_s))

    # -- excitation: pre-contract noise factors of axes 1..d-1 -----------------
    xi0 = prepare_xi0(xi, ds, T, fsz, accum=accum, storage=field.dtype)

    # -- pad to the plan's operand extents (reflect pre-pad is a real array
    # op, the rest is zero fill up to the shared launch-shape record) ---------
    shapes = fused_launch_shapes(geom, samples=n_s, b_f=b_f, s_b=s_b)
    nblk, sp = shapes["nblk"], shapes["sp"]
    if geom.boundary == "reflect":
        field = jnp.pad(field, [(0, 0)] + [(b, b)] * nd, mode="reflect")
    field = pad_to(field, (sp, shapes["l0p"]) + shapes["lp_trail"])
    xi0 = pad_to(xi0, (sp, nblk * b_f * fsz, xi0.shape[2]))
    r0, d0 = rs[0], ds[0]
    if charted[0]:
        r0 = pad_to(r0, (nblk * b_f,) + r0.shape[1:])
        d0 = pad_to(d0, (nblk * b_f,) + d0.shape[1:])

    meta = (nd, csz, fsz, T, charted, b_f, s_b, interpret, accum_dtype)
    out = _nd_fused_core(meta, field, xi0, r0, d0,
                         tuple(rs[a] for a in range(1, nd)))
    out = out[:n_s, : T[0] * fsz]
    f_trail = tuple(T[a] * fsz for a in range(1, nd))
    out = out.reshape((n_s, T[0] * fsz) + f_trail)
    return out if sample_axis else out[0]
