"""Mixed-precision dtype policy for the ICR kernel stack (DESIGN.md §11).

ICR refinement is memory-bound (the roofline model in
``repro.roofline.level_traffic``): one level moves ``read L + read ξ +
write N`` bytes of field data and a rounding error of matrices. The lever
that remains after the fused megakernel (§10) is *bytes per element*, so
the policy splits every array's life in two:

  ``storage_dtype`` — what lives in HBM and crosses the HBM<->VMEM
      boundary: the field between levels, the excitations ξ, the
      refinement matrices. Default **bfloat16** — halves the modeled HBM
      bytes of every large level.
  ``accum_dtype``   — what the MXU/VPU accumulate in inside the kernels
      (``preferred_element_type`` of every contraction, the overlap-add
      accumulator of the adjoints). Default **float32** — refinement is a
      long chain of small contractions and bf16 accumulation would lose
      the paper's §5.1 accuracy story.

``DtypePolicy()`` with no arguments is the default mixed policy
(bf16 storage + fp32 accumulation); ``FP32`` is the explicit opt-out that
reproduces the historical all-float32 behavior bit-for-bit. ``ICR``
resolves ``dtype_policy=None`` to ``FP32`` so existing fp32 call sites
(and the 1e-5 parity suites pinning them) are unchanged — mixed precision
is engaged per model with ``ICR(dtype_policy="bf16")`` or any explicit
``DtypePolicy``.

Everything downstream keys off this object: ``dispatch`` sizes VMEM tiles
by ``storage_itemsize`` (bf16 doubles the families per tile), ``plan()``
and ``roofline.level_traffic`` account bytes per dtype, and the kernels
thread ``accum_dtype`` into every ``preferred_element_type``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    """Storage/accumulation dtype pair for the refinement stack.

    Hashable and usable as a jit-static argument: the fields are
    normalized to canonical ``numpy.dtype`` objects on construction, so
    policies built from any spelling (``jnp.bfloat16``, ``"bfloat16"``,
    ``jnp.dtype("bfloat16")``) compare AND hash equal — one jit cache
    slot per semantic policy. The *default* policy is mixed precision
    (bf16 storage, fp32 accumulation); pass ``FP32`` (or
    ``ICR(dtype_policy="fp32")``) to opt out.
    """

    storage_dtype: jnp.dtype = jnp.bfloat16
    accum_dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        object.__setattr__(self, "storage_dtype",
                           jnp.dtype(self.storage_dtype))
        object.__setattr__(self, "accum_dtype", jnp.dtype(self.accum_dtype))

    @property
    def storage_itemsize(self) -> int:
        # resolved through the shared table (repro.dtypes) so the VMEM
        # autotuners, the traffic model and the HLO parsers can never
        # disagree on a width — fp8 policies included
        from repro.dtypes import itemsize

        return itemsize(self.storage_dtype)

    @property
    def storage_name(self) -> str:
        return jnp.dtype(self.storage_dtype).name

    @property
    def accum_name(self) -> str:
        return jnp.dtype(self.accum_dtype).name

    def cast_storage(self, tree):
        """Cast every array leaf of `tree` to the storage dtype (None leaves
        pass through — the noise-free kernel modes use them)."""
        import jax

        return jax.tree.map(
            lambda x: None if x is None else jnp.asarray(
                x, self.storage_dtype),
            tree,
            is_leaf=lambda x: x is None,
        )


BF16 = DtypePolicy()                                # the default mixed policy
FP32 = DtypePolicy(jnp.float32, jnp.float32)        # the opt-out

_ALIASES = {
    "bf16": BF16, "bfloat16": BF16, "mixed": BF16, "default": BF16,
    "fp32": FP32, "float32": FP32, "f32": FP32,
}


def resolve(policy) -> DtypePolicy:
    """Coerce ``None`` / alias strings / DtypePolicy to a DtypePolicy.

    ``None`` resolves to ``FP32``: the policy system is opt-in per model so
    the fp32 reference suites stay bit-stable (see module docstring).
    """
    if policy is None:
        return FP32
    if isinstance(policy, DtypePolicy):
        return policy
    if isinstance(policy, str):
        try:
            return _ALIASES[policy.lower()]
        except KeyError:
            raise ValueError(
                f"unknown dtype policy {policy!r}; expected one of "
                f"{sorted(_ALIASES)} or a DtypePolicy"
            ) from None
    raise TypeError(f"cannot resolve dtype policy from {type(policy)}")
