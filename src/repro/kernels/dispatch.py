"""Backend dispatch for the fused ICR refinement kernels (DESIGN.md §5/§10).

One refinement application (paper Eq. 9) can execute three ways:

  * ``"pallas"``    — the fused TPU kernels (icr_refine.py, nd_fused.py);
                      chosen on TPU.
  * ``"interpret"`` — the same kernels in Pallas interpret mode (the body
                      runs as pure jnp); chosen off-TPU so CPU/GPU runs
                      exercise the exact BlockSpec tiling bit-for-bit.
  * ``"reference"`` — ``core.refine.refine_level`` (joint jnp einsum path);
                      the fallback for anything the kernels don't cover.

Routing is decided per level from the geometry alone:

  1-D, all ``kept_T == 1``   -> stationary kernel (one shared stencil)
  1-D, per-family matrices   -> charted kernel (batched small-matmul)
  N-D, tile fits VMEM        -> single-launch fused level megakernel
                                (repro.kernels.nd_fused, DESIGN.md §10)
  N-D, tile too large        -> per-axis fused passes (repro.kernels.nd)
  otherwise                  -> reference

This replaces the ad-hoc shape guards that used to live in
``repro.kernels.ops``. VMEM tile sizes (``block_families`` for the 1-D
kernels, the ``(b_f, s_b)`` family/sample blocks for the N-D megakernel)
are autotuned against a per-core VMEM budget instead of being hard-coded.

``refine`` is fully differentiable on every route: the kernel entry points
carry hand-written adjoint Pallas kernels via ``jax.custom_vjp``
(icr_refine.py, DESIGN.md §9; the megakernel's backward composes them in
reverse axis order), so ``jax.grad``/``jax.vjp`` through any structured
route — including the interpret backend — runs the fused backward, never
the jnp reference. ``plan()`` reports the backward routing per level next
to the forward, plus the per-level HBM-byte estimates of
``repro.roofline.level_traffic`` for every candidate route.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.refine import LevelGeom, refine_level
from repro.roofline.level_traffic import refine_level_traffic

from . import nd as _nd
from .icr_refine import (
    halo_floor,
    refine_charted_pallas,
    refine_stationary_pallas,
)

Array = jnp.ndarray

BACKEND_PALLAS = "pallas"
BACKEND_INTERPRET = "interpret"
BACKEND_REFERENCE = "reference"

ROUTE_STATIONARY_1D = "stationary-1d"
ROUTE_CHARTED_1D = "charted-1d"
ROUTE_ND_FUSED = "nd-fused"
ROUTE_AXES_ND = "nd-axes"
ROUTE_REFERENCE = "reference"

# ~half of a TPU core's VMEM (launch.mesh.VMEM_BYTES = 128 MiB): the pipeline
# double-buffers every Blocked operand, and we leave headroom for the
# compiler's own temporaries.
VMEM_BUDGET_BYTES = 64 * 2**20


def autotune_block_families(t: int, n_csz: int, n_fsz: int, *, charted: bool,
                            batch_block: int = 1, itemsize: int = 4,
                            vmem_budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest power-of-two family block whose working set fits the budget,
    clamped to the family count ``t`` (a block larger than the level is pure
    padding — tiny levels used to get the floor of 8 regardless of ``t``).

    Per grid step the kernel holds: the coarse block + its halo view
    (``2*b_f*s``), the xi block and the output block (``2*b_f*n_fsz``) —
    each times the ``batch_block`` slab — and the matrices: shared
    ``(n_fsz, n_csz)+(n_fsz, n_fsz)`` when stationary, per-family (scaling
    with ``b_f``) when charted. Everything is double buffered by the Pallas
    pipeline, hence the factor 2.

    The returned block never drops below ``q_max = (n_csz-1)//s``: the
    kernels' one-block halo view must cover the window overhang.
    """
    s = max(1, n_fsz // 2)
    b_b = max(1, batch_block)
    floor = max(min(8, t), halo_floor(n_csz, n_fsz), 1)
    best, b_f = floor, floor
    while True:
        per = b_b * (2 * b_f * s + 2 * b_f * n_fsz) \
            + n_fsz * n_csz + n_fsz * n_fsz
        if charted:
            per += b_f * (n_fsz * n_csz + n_fsz * n_fsz)
        if b_f > floor and 2 * itemsize * per > vmem_budget:
            break  # floor is always returned, budget-fitting or not
        best = b_f
        if b_f >= t:
            break
        b_f = min(2 * b_f, t)
    return best


def autotune_batch_block(samples: int, t: int, n_csz: int, n_fsz: int, *,
                         charted: bool, block_families: int,
                         itemsize: int = 4,
                         vmem_budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest power-of-two sample slab the 1-D kernels can hold per grid
    step at the given family block — the native sample-batch dimension that
    amortizes matrix loads across batched sampling / serving."""
    s = max(1, n_fsz // 2)
    b_f = block_families
    mats = n_fsz * n_csz + n_fsz * n_fsz
    if charted:
        mats += b_f * (n_fsz * n_csz + n_fsz * n_fsz)
    best, b_b = 1, 1
    while True:
        per = b_b * (2 * b_f * s + 2 * b_f * n_fsz) + mats
        if b_b > 1 and 2 * itemsize * per > vmem_budget:
            break
        best = b_b
        if b_b >= samples:
            break
        b_b = min(2 * b_b, samples)
    return best


def _fused_tile_bytes(geom: LevelGeom, charted: tuple, b_f: int, s_b: int,
                      itemsize: int) -> int:
    """VMEM working set of one megakernel grid step (model, DESIGN.md §10).

    Counted: the coarse tile + its axis-0 halo view, the ξ and output tiles
    (all double-buffered by the pipeline), the matrices (axis-0 factors
    blocked when charted), and the peak in-flight stage of the back-to-back
    contraction chain (input + window tensor + output of the widest stage).
    """
    nd = len(geom.coarse_shape)
    fsz, csz = geom.n_fsz, geom.n_csz
    s = max(1, fsz // 2)
    q = (csz - 1) // s
    T = geom.T
    lp_trail = []
    for a in range(1, nd):
        n = geom.coarse_shape[a] + (2 * geom.b if geom.boundary == "reflect"
                                    else 0)
        lp_trail.append(max(n, (T[a] + q) * s))
    prod_f = 1
    for a in range(1, nd):
        prod_f *= T[a] * fsz

    def prod(xs):
        out = 1
        for x in xs:
            out *= x
        return out

    tile_in = 2 * s_b * b_f * s * prod(lp_trail)         # main + halo views
    xi_tile = s_b * b_f * fsz * prod_f
    out_tile = s_b * b_f * fsz * prod_f

    # contraction chain peak: stage extents start at the coarse tile and
    # graduate one axis at a time to fine resolution
    stage = [(b_f + q) * s] + [(T[a] + q) * s for a in range(1, nd)]
    peak = 0
    for a in range(nd - 1, -1, -1):
        before = prod(stage)
        win = stage.copy()
        win[a] = (T[a] if a else b_f) * csz
        after = stage.copy()
        after[a] = (T[a] if a else b_f) * fsz
        peak = max(peak, before + prod(win) + prod(after))
        stage = after
    scratch = s_b * peak

    mats = 0
    per = fsz * csz + fsz * fsz
    mats += (b_f if charted[0] else 1) * per
    for a in range(1, nd):
        mats += (T[a] if charted[a] else 1) * per

    return itemsize * (2 * (tile_in + xi_tile + out_tile + mats) + scratch)


def autotune_nd_fused(geom: LevelGeom, *, charted: tuple | None = None,
                      samples: int = 1, itemsize: int = 4,
                      vmem_budget: int = VMEM_BUDGET_BYTES):
    """Family/sample blocks ``(b_f, s_b)`` for the fused N-D level kernel,
    or None when even the minimal tile busts the VMEM budget — the fallback
    rule: dispatch then routes the level to the per-axis passes.

    Grows the axis-0 family block first (powers of two up to ``T_0``), then
    the sample slab (up to ``samples``), keeping the §10 working-set model
    under the budget.
    """
    nd = len(geom.coarse_shape)
    if nd < 2:
        return None
    if charted is None:
        charted = tuple(k > 1 for k in geom.kept_T)
    q = halo_floor(geom.n_csz, geom.n_fsz)
    floor = max(min(8, geom.T[0]), q, 1)
    if _fused_tile_bytes(geom, charted, floor, 1, itemsize) > vmem_budget:
        return None
    b_f = floor
    while b_f < geom.T[0]:
        nxt = min(2 * b_f, geom.T[0])
        if _fused_tile_bytes(geom, charted, nxt, 1, itemsize) > vmem_budget:
            break
        b_f = nxt
    s_b = 1
    while s_b < samples:
        nxt = min(2 * s_b, samples)
        if _fused_tile_bytes(geom, charted, b_f, nxt, itemsize) > vmem_budget:
            break
        s_b = nxt
    return b_f, s_b


def select_backend(*, platform: str | None = None) -> str:
    """Kernel backend for `platform` (default: the runtime jax backend)."""
    platform = platform or jax.default_backend()
    return BACKEND_PALLAS if platform == "tpu" else BACKEND_INTERPRET


def route_for(geom: LevelGeom, *, have_axis_mats: bool = False) -> str:
    """Which structured path covers this level's geometry (see module doc)."""
    if geom.boundary not in ("shrink", "reflect"):
        return ROUTE_REFERENCE
    if len(geom.coarse_shape) == 1:
        if all(k == 1 for k in geom.kept_T):
            return ROUTE_STATIONARY_1D
        return ROUTE_CHARTED_1D
    if not have_axis_mats:
        return ROUTE_REFERENCE
    if autotune_nd_fused(geom) is not None:
        return ROUTE_ND_FUSED
    return ROUTE_AXES_ND


def plan(chart, *, have_axis_mats: bool | None = None,
         platform: str | None = None, samples: int = 1) -> list:
    """Per-level forward AND backward routing decisions for `chart` —
    introspection for examples, benchmarks and tests (no arrays touched).

    have_axis_mats defaults to ``chart.ndim > 1`` (ICR.matrices computes the
    per-axis factors for every N-D chart when use_pallas=True).

    Each entry carries a ``"vjp"`` sub-dict describing how the *backward*
    pass of that level executes (structured routes run the hand-written
    adjoint kernels; the megakernel's backward composes the 1-D adjoints in
    reverse axis order; the reference route is jnp autodiff) and an
    ``"hbm_bytes"`` sub-dict: the ``roofline.level_traffic`` estimate for
    the selected route next to every candidate route, so the traffic win of
    the fused path is visible without running anything.
    """
    if have_axis_mats is None:
        have_axis_mats = chart.ndim > 1
    out = []
    for lvl in range(chart.n_levels):
        geom = LevelGeom.for_level(chart, lvl)
        route = route_for(geom, have_axis_mats=have_axis_mats)
        backend = (BACKEND_REFERENCE if route == ROUTE_REFERENCE
                   else select_backend(platform=platform))
        blocks = {}
        sample_block = None
        if route in (ROUTE_STATIONARY_1D, ROUTE_CHARTED_1D):
            blocks[0] = autotune_block_families(
                geom.T[0], geom.n_csz, geom.n_fsz,
                charted=route == ROUTE_CHARTED_1D,
            )
            sample_block = autotune_batch_block(
                samples, geom.T[0], geom.n_csz, geom.n_fsz,
                charted=route == ROUTE_CHARTED_1D,
                block_families=blocks[0],
            )
        elif route == ROUTE_ND_FUSED:
            b_f, s_b = autotune_nd_fused(geom, samples=samples)
            blocks[0] = b_f
            sample_block = s_b
        elif route == ROUTE_AXES_ND:
            for a in range(len(geom.T)):
                ag = geom.axis(a)
                blocks[a] = autotune_block_families(
                    ag.T[0], ag.n_csz, ag.n_fsz,
                    charted=ag.kept_T[0] > 1,
                )
        candidates = ([ROUTE_ND_FUSED, ROUTE_AXES_ND, ROUTE_REFERENCE]
                      if len(geom.coarse_shape) > 1
                      else [route, ROUTE_REFERENCE])
        hbm = {
            rt: refine_level_traffic(geom, rt, samples=samples)["total"]
            for rt in candidates
        }
        hbm["selected"] = hbm[route]
        vjp = {
            "route": (ROUTE_REFERENCE if route == ROUTE_REFERENCE
                      else route + "-adjoint"),
            "backend": backend,
            "block_families": dict(blocks),
        }
        out.append({"level": lvl, "route": route, "backend": backend,
                    "block_families": blocks, "sample_block": sample_block,
                    "hbm_bytes": hbm, "vjp": vjp})
    return out


def refine(field: Array, xi: Array, r: Array, d: Array, geom: LevelGeom, *,
           axis_mats=None, backend: str | None = None,
           block_families: int | None = None,
           sample_axis: bool = False,
           sample_block: int | None = None) -> Array:
    """Route one refinement application to the best available backend.

    Arguments follow ``core.refine.refine_level``; ``axis_mats`` optionally
    carries the per-axis factors ``(rs, ds)`` from
    ``axis_refinement_matrices_level``, enabling the fused N-D paths (when
    present, the joint ``r``/``d`` are ignored on N-D levels).

    ``sample_axis=True`` marks the leading dimension of ``field``/``xi`` as
    a sample batch: the kernels process a whole sample slab per grid step
    (matrix loads amortized — DESIGN.md §10) instead of looping.

    Differentiable w.r.t. every array argument on every route: the kernel
    entry points carry custom VJPs running the fused adjoint kernels, the
    surrounding pads/reshapes are plain jnp.
    """
    route = route_for(geom, have_axis_mats=axis_mats is not None)
    if backend is None and route != ROUTE_REFERENCE:
        backend = select_backend()
    if route == ROUTE_REFERENCE or backend == BACKEND_REFERENCE:
        if r is None or d is None:
            raise ValueError(
                "reference route needs the joint (r, d) matrices; this level "
                "has none (ICR.matrices skipped the joint build) — pass "
                "matrices(joint=True) or provide axis_mats covering it"
            )
        if sample_axis:
            return jax.vmap(
                lambda f, x: refine_level(f, x, r, d, geom))(field, xi)
        return refine_level(field, xi, r, d, geom)
    interpret = backend != BACKEND_PALLAS

    if route == ROUTE_ND_FUSED:
        from . import nd_fused  # lazy: keeps import order flexible

        return nd_fused.refine_nd_fused(
            field, xi, axis_mats[0], axis_mats[1], geom,
            interpret=interpret, block_families=block_families,
            sample_block=sample_block, sample_axis=sample_axis,
        )
    if route == ROUTE_AXES_ND:
        return _nd.refine_axes(field, xi, axis_mats[0], axis_mats[1], geom,
                               interpret=interpret,
                               block_families=block_families,
                               sample_axis=sample_axis)

    n_csz, n_fsz = geom.n_csz, geom.n_fsz
    t = geom.T[0]
    charted = route == ROUTE_CHARTED_1D
    if sample_axis:
        n_s = field.shape[0]
        coarse = field.reshape(n_s, -1)
        xi_k = xi.reshape(n_s, t, n_fsz)
    else:
        n_s = 1
        coarse = field.reshape(1, -1)
        xi_k = xi.reshape(1, t, n_fsz)
    if geom.boundary == "reflect":
        coarse = jnp.pad(coarse, [(0, 0), (geom.b, geom.b)], mode="reflect")
    b_f = block_families or autotune_block_families(
        t, n_csz, n_fsz, charted=charted
    )
    b_b = sample_block or autotune_batch_block(
        n_s, t, n_csz, n_fsz, charted=charted, block_families=b_f
    )
    if charted:
        out = refine_charted_pallas(
            coarse, xi_k, r.reshape(t, n_fsz, n_csz),
            d.reshape(t, n_fsz, n_fsz), n_csz=n_csz, n_fsz=n_fsz,
            block_families=b_f, batch_block=b_b, interpret=interpret,
        )
    else:
        out = refine_stationary_pallas(
            coarse, xi_k, r.reshape(n_fsz, n_csz),
            d.reshape(n_fsz, n_fsz), n_csz=n_csz, n_fsz=n_fsz,
            block_families=b_f, batch_block=b_b, interpret=interpret,
        )
    if sample_axis:
        return out.reshape((n_s,) + geom.fine_shape)
    return out.reshape(geom.fine_shape)
