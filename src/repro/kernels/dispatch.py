"""Backend dispatch for the fused ICR refinement kernels (DESIGN.md §5).

One refinement application (paper Eq. 9) can execute three ways:

  * ``"pallas"``    — the fused TPU kernels (icr_refine.py); chosen on TPU.
  * ``"interpret"`` — the same kernels in Pallas interpret mode (the body
                      runs as pure jnp); chosen off-TPU so CPU/GPU runs
                      exercise the exact BlockSpec tiling bit-for-bit.
  * ``"reference"`` — ``core.refine.refine_level`` (joint jnp einsum path);
                      the fallback for anything the kernels don't cover.

Routing is decided per level from the geometry alone:

  1-D, all ``kept_T == 1``   -> stationary kernel (one shared stencil)
  1-D, per-family matrices   -> charted kernel (batched small-matmul)
  N-D with per-axis factors  -> per-axis fused passes (repro.kernels.nd)
  otherwise                  -> reference

This replaces the ad-hoc shape guards that used to live in
``repro.kernels.ops``. The VMEM tile size (``block_families``) is autotuned
against a per-core VMEM budget instead of being a hard-coded 256.

``refine`` is fully differentiable on every route: the 1-D kernel entry
points carry hand-written adjoint Pallas kernels via ``jax.custom_vjp``
(icr_refine.py, DESIGN.md §9), so ``jax.grad``/``jax.vjp`` through any
structured route — including the per-axis N-D passes and the interpret
backend — runs the fused backward, never the jnp reference. ``plan()``
reports the backward routing per level next to the forward.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.refine import LevelGeom, refine_level

from . import nd as _nd
from .icr_refine import (
    halo_floor,
    refine_charted_pallas,
    refine_stationary_pallas,
)

Array = jnp.ndarray

BACKEND_PALLAS = "pallas"
BACKEND_INTERPRET = "interpret"
BACKEND_REFERENCE = "reference"

ROUTE_STATIONARY_1D = "stationary-1d"
ROUTE_CHARTED_1D = "charted-1d"
ROUTE_AXES_ND = "nd-axes"
ROUTE_REFERENCE = "reference"

# ~half of a TPU core's VMEM (launch.mesh.VMEM_BYTES = 128 MiB): the pipeline
# double-buffers every Blocked operand, and we leave headroom for the
# compiler's own temporaries.
VMEM_BUDGET_BYTES = 64 * 2**20


def autotune_block_families(t: int, n_csz: int, n_fsz: int, *, charted: bool,
                            itemsize: int = 4,
                            vmem_budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest power-of-two family block whose working set fits the budget,
    clamped to the family count ``t`` (a block larger than the level is pure
    padding — tiny levels used to get the floor of 8 regardless of ``t``).

    Per grid step the kernel holds: the coarse block + its halo view
    (``2*b_f*s``), the xi block and the output block (``2*b_f*n_fsz``), and
    the matrices — shared ``(n_fsz, n_csz)+(n_fsz, n_fsz)`` when stationary,
    per-family (scaling with ``b_f``) when charted. Everything is double
    buffered by the Pallas pipeline, hence the factor 2.

    The returned block never drops below ``q_max = (n_csz-1)//s``: the
    kernels' one-block halo view must cover the window overhang.
    """
    s = max(1, n_fsz // 2)
    floor = max(min(8, t), halo_floor(n_csz, n_fsz), 1)
    best, b_f = floor, floor
    while True:
        per = 2 * b_f * s + 2 * b_f * n_fsz + n_fsz * n_csz + n_fsz * n_fsz
        if charted:
            per += b_f * (n_fsz * n_csz + n_fsz * n_fsz)
        if b_f > floor and 2 * itemsize * per > vmem_budget:
            break  # floor is always returned, budget-fitting or not
        best = b_f
        if b_f >= t:
            break
        b_f = min(2 * b_f, t)
    return best


def select_backend(*, platform: str | None = None) -> str:
    """Kernel backend for `platform` (default: the runtime jax backend)."""
    platform = platform or jax.default_backend()
    return BACKEND_PALLAS if platform == "tpu" else BACKEND_INTERPRET


def route_for(geom: LevelGeom, *, have_axis_mats: bool = False) -> str:
    """Which structured path covers this level's geometry (see module doc)."""
    if geom.boundary not in ("shrink", "reflect"):
        return ROUTE_REFERENCE
    if len(geom.coarse_shape) == 1:
        if all(k == 1 for k in geom.kept_T):
            return ROUTE_STATIONARY_1D
        return ROUTE_CHARTED_1D
    return ROUTE_AXES_ND if have_axis_mats else ROUTE_REFERENCE


def plan(chart, *, have_axis_mats: bool | None = None,
         platform: str | None = None) -> list:
    """Per-level forward AND backward routing decisions for `chart` —
    introspection for examples, benchmarks and tests (no arrays touched).

    have_axis_mats defaults to ``chart.ndim > 1`` (ICR.matrices computes the
    per-axis factors for every N-D chart when use_pallas=True).

    Each entry carries a ``"vjp"`` sub-dict describing how the *backward*
    pass of that level executes: structured routes run the hand-written
    adjoint kernels (same backend, same tiling — the adjoint's working set
    mirrors the forward's), the reference route is jnp autodiff.
    """
    if have_axis_mats is None:
        have_axis_mats = chart.ndim > 1
    out = []
    for lvl in range(chart.n_levels):
        geom = LevelGeom.for_level(chart, lvl)
        route = route_for(geom, have_axis_mats=have_axis_mats)
        backend = (BACKEND_REFERENCE if route == ROUTE_REFERENCE
                   else select_backend(platform=platform))
        blocks = {}
        if route in (ROUTE_STATIONARY_1D, ROUTE_CHARTED_1D):
            blocks[0] = autotune_block_families(
                geom.T[0], geom.n_csz, geom.n_fsz,
                charted=route == ROUTE_CHARTED_1D,
            )
        elif route == ROUTE_AXES_ND:
            for a in range(len(geom.T)):
                ag = geom.axis(a)
                blocks[a] = autotune_block_families(
                    ag.T[0], ag.n_csz, ag.n_fsz,
                    charted=ag.kept_T[0] > 1,
                )
        vjp = {
            "route": (ROUTE_REFERENCE if route == ROUTE_REFERENCE
                      else route + "-adjoint"),
            "backend": backend,
            "block_families": dict(blocks),
        }
        out.append({"level": lvl, "route": route, "backend": backend,
                    "block_families": blocks, "vjp": vjp})
    return out


def refine(field: Array, xi: Array, r: Array, d: Array, geom: LevelGeom, *,
           axis_mats=None, backend: str | None = None,
           block_families: int | None = None) -> Array:
    """Route one refinement application to the best available backend.

    Arguments follow ``core.refine.refine_level``; ``axis_mats`` optionally
    carries the per-axis factors ``(rs, ds)`` from
    ``axis_refinement_matrices_level``, enabling the fused N-D path (when
    present, the joint ``r``/``d`` are ignored on N-D levels).

    Differentiable w.r.t. every array argument on every route: the kernel
    entry points carry custom VJPs running the fused adjoint kernels, the
    surrounding pads/reshapes are plain jnp.
    """
    route = route_for(geom, have_axis_mats=axis_mats is not None)
    if backend is None and route != ROUTE_REFERENCE:
        backend = select_backend()
    if route == ROUTE_REFERENCE or backend == BACKEND_REFERENCE:
        if r is None or d is None:
            raise ValueError(
                "reference route needs the joint (r, d) matrices; this level "
                "has none (ICR.matrices skipped the joint build) — pass "
                "matrices(joint=True) or provide axis_mats covering it"
            )
        return refine_level(field, xi, r, d, geom)
    interpret = backend != BACKEND_PALLAS

    if route == ROUTE_AXES_ND:
        return _nd.refine_axes(field, xi, axis_mats[0], axis_mats[1], geom,
                               interpret=interpret,
                               block_families=block_families)

    n_csz, n_fsz = geom.n_csz, geom.n_fsz
    t = geom.T[0]
    coarse = field.reshape(1, -1)
    if geom.boundary == "reflect":
        coarse = jnp.pad(coarse, [(0, 0), (geom.b, geom.b)], mode="reflect")
    charted = route == ROUTE_CHARTED_1D
    b_f = block_families or autotune_block_families(
        t, n_csz, n_fsz, charted=charted
    )
    if charted:
        out = refine_charted_pallas(
            coarse, xi.reshape(1, t, n_fsz), r.reshape(t, n_fsz, n_csz),
            d.reshape(t, n_fsz, n_fsz), n_csz=n_csz, n_fsz=n_fsz,
            block_families=b_f, interpret=interpret,
        )
    else:
        out = refine_stationary_pallas(
            coarse, xi.reshape(1, t, n_fsz), r.reshape(n_fsz, n_csz),
            d.reshape(n_fsz, n_fsz), n_csz=n_csz, n_fsz=n_fsz,
            block_families=b_f, interpret=interpret,
        )
    return out.reshape(geom.fine_shape)
