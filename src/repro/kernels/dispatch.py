"""Backend dispatch for the fused ICR refinement kernels (DESIGN.md §5/§10).

One refinement application (paper Eq. 9) can execute three ways:

  * ``"pallas"``    — the fused TPU kernels (icr_refine.py, nd_fused.py);
                      chosen on TPU.
  * ``"interpret"`` — the same kernels in Pallas interpret mode (the body
                      runs as pure jnp); the CI/test harness off-TPU, so
                      CPU/GPU runs exercise the exact BlockSpec tiling
                      bit-for-bit (``REPRO_BACKEND=interpret``).
  * ``"reference"`` — the jnp oracle path: ``core.refine.refine_level``
                      for joint matrices, ``kernels.ref.refine_axes_ref``
                      for structured N-D levels carrying only the per-axis
                      factors; chosen off-TPU in production (interpret mode
                      is slower than plain jnp on CPU) and the fallback for
                      anything the kernels don't cover.

Routing is decided per level from the geometry alone:

  1-D, all ``kept_T == 1``   -> stationary kernel (one shared stencil)
  1-D, per-family matrices   -> charted kernel (batched small-matmul)
  N-D, tile fits VMEM        -> single-launch fused level megakernel
                                (repro.kernels.nd_fused, DESIGN.md §10)
  N-D, tile too large        -> per-axis fused passes (repro.kernels.nd)
  otherwise                  -> reference

On top of the per-level routes, ``plan()``/``ICR.apply_sqrt`` overlay the
**pyramid** route (repro.kernels.pyramid, DESIGN.md §11): all consecutive
early levels whose combined full-extent working set fits the VMEM budget
run back-to-back in ONE launch — their inter-level field traffic never
touches HBM. ``autotune_pyramid`` owns the residency criterion.

All VMEM accounting is **dtype-aware** (DESIGN.md §11): the autotuners take
the storage itemsize (bf16 halves it, doubling what fits per tile) and
``plan(dtype=...)`` reports HBM bytes at the policy's storage dtype —
the byte model grows a dtype column.

This replaces the ad-hoc shape guards that used to live in the retired
``repro.kernels.ops`` shim. VMEM tile sizes (``block_families`` for the 1-D
kernels, the ``(b_f, s_b)`` family/sample blocks for the N-D megakernel)
are autotuned against a per-core VMEM budget instead of being hard-coded.

``refine`` is fully differentiable on every route: the kernel entry points
carry hand-written adjoint Pallas kernels via ``jax.custom_vjp``
(icr_refine.py, DESIGN.md §9; the megakernel's backward composes them in
reverse axis order), so ``jax.grad``/``jax.vjp`` through any structured
route — including the interpret backend — runs the fused backward, never
the jnp reference. ``plan()`` reports the backward routing per level next
to the forward, plus the per-level HBM-byte estimates of
``repro.roofline.level_traffic`` for every candidate route.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.refine import LevelGeom, refine_level
from repro.roofline.level_traffic import refine_level_traffic

from . import nd as _nd
from .icr_refine import (
    halo_floor,
    refine_charted_pallas,
    refine_stationary_pallas,
)
from .policy import DtypePolicy, resolve as resolve_policy

Array = jnp.ndarray

BACKEND_PALLAS = "pallas"
BACKEND_INTERPRET = "interpret"
BACKEND_REFERENCE = "reference"

ROUTE_STATIONARY_1D = "stationary-1d"
ROUTE_CHARTED_1D = "charted-1d"
ROUTE_ND_FUSED = "nd-fused"
ROUTE_AXES_ND = "nd-axes"
ROUTE_PYRAMID = "pyramid"
ROUTE_REFERENCE = "reference"

# ~half of a TPU core's VMEM (launch.mesh.VMEM_BYTES = 128 MiB): the pipeline
# double-buffers every Blocked operand, and we leave headroom for the
# compiler's own temporaries.
VMEM_BUDGET_BYTES = 64 * 2**20


def block1d_bytes(t: int, n_csz: int, n_fsz: int, *, charted: bool,
                  block_families: int, batch_block: int = 1,
                  itemsize: int = 4) -> int:
    """VMEM working set of one 1-D kernel grid step (the model both 1-D
    autotuners grow against, and the static re-derivation the VMEM lint
    pass checks autotuned plans with — repro.analysis, DESIGN.md §13).

    Per grid step the kernel holds: the coarse block + its halo view
    (``2*b_f*s``), the xi block and the output block (``2*b_f*n_fsz``) —
    each times the ``batch_block`` slab — and the matrices: shared
    ``(n_fsz, n_csz)+(n_fsz, n_fsz)`` when stationary, per-family (scaling
    with ``b_f``) when charted. Everything is double buffered by the Pallas
    pipeline, hence the factor 2.
    """
    s = max(1, n_fsz // 2)
    b_f, b_b = block_families, max(1, batch_block)
    per = b_b * (2 * b_f * s + 2 * b_f * n_fsz) \
        + n_fsz * n_csz + n_fsz * n_fsz
    if charted:
        per += b_f * (n_fsz * n_csz + n_fsz * n_fsz)
    return 2 * itemsize * per


def block1d_floor(t: int, n_csz: int, n_fsz: int) -> int:
    """Smallest family block the 1-D kernels accept: ``min(8, t)`` but
    never below ``q_max = (n_csz-1)//s`` — the one-block halo view must
    cover the window overhang. The floor is returned by the autotuner
    whether or not it fits the budget (a level cannot tile finer)."""
    return max(min(8, t), halo_floor(n_csz, n_fsz), 1)


def autotune_block_families(t: int, n_csz: int, n_fsz: int, *, charted: bool,
                            batch_block: int = 1, itemsize: int = 4,
                            vmem_budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest power-of-two family block whose working set (the
    ``block1d_bytes`` model) fits the budget, clamped to the family count
    ``t`` (a block larger than the level is pure padding — tiny levels
    used to get the floor of 8 regardless of ``t``) and floored at
    ``block1d_floor`` (always returned, budget-fitting or not).
    """
    floor = block1d_floor(t, n_csz, n_fsz)
    best, b_f = floor, floor
    while True:
        ws = block1d_bytes(t, n_csz, n_fsz, charted=charted,
                           block_families=b_f, batch_block=batch_block,
                           itemsize=itemsize)
        if b_f > floor and ws > vmem_budget:
            break
        best = b_f
        if b_f >= t:
            break
        b_f = min(2 * b_f, t)
    return best


def autotune_batch_block(samples: int, t: int, n_csz: int, n_fsz: int, *,
                         charted: bool, block_families: int,
                         itemsize: int = 4,
                         vmem_budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest power-of-two sample slab the 1-D kernels can hold per grid
    step at the given family block — the native sample-batch dimension that
    amortizes matrix loads across batched sampling / serving."""
    best, b_b = 1, 1
    while True:
        ws = block1d_bytes(t, n_csz, n_fsz, charted=charted,
                           block_families=block_families, batch_block=b_b,
                           itemsize=itemsize)
        if b_b > 1 and ws > vmem_budget:
            break
        best = b_b
        if b_b >= samples:
            break
        b_b = min(2 * b_b, samples)
    return best


def _fused_tile_bytes(geom: LevelGeom, charted: tuple, b_f: int, s_b: int,
                      itemsize: int) -> int:
    """VMEM working set of one megakernel grid step (model, DESIGN.md §10).

    Counted: the coarse tile + its axis-0 halo view, the ξ and output tiles
    (all double-buffered by the pipeline), the matrices (axis-0 factors
    blocked when charted), and the peak in-flight stage of the back-to-back
    contraction chain (input + window tensor + output of the widest stage).
    """
    nd = len(geom.coarse_shape)
    fsz, csz = geom.n_fsz, geom.n_csz
    s = max(1, fsz // 2)
    q = (csz - 1) // s
    T = geom.T
    lp_trail = []
    for a in range(1, nd):
        n = geom.coarse_shape[a] + (2 * geom.b if geom.boundary == "reflect"
                                    else 0)
        lp_trail.append(max(n, (T[a] + q) * s))
    prod_f = 1
    for a in range(1, nd):
        prod_f *= T[a] * fsz

    def prod(xs):
        out = 1
        for x in xs:
            out *= x
        return out

    tile_in = 2 * s_b * b_f * s * prod(lp_trail)         # main + halo views
    xi_tile = s_b * b_f * fsz * prod_f
    out_tile = s_b * b_f * fsz * prod_f

    # contraction chain peak: stage extents start at the coarse tile and
    # graduate one axis at a time to fine resolution
    stage = [(b_f + q) * s] + [(T[a] + q) * s for a in range(1, nd)]
    peak = 0
    for a in range(nd - 1, -1, -1):
        before = prod(stage)
        win = stage.copy()
        win[a] = (T[a] if a else b_f) * csz
        after = stage.copy()
        after[a] = (T[a] if a else b_f) * fsz
        peak = max(peak, before + prod(win) + prod(after))
        stage = after
    scratch = s_b * peak

    mats = 0
    per = fsz * csz + fsz * fsz
    mats += (b_f if charted[0] else 1) * per
    for a in range(1, nd):
        mats += (T[a] if charted[a] else 1) * per

    return itemsize * (2 * (tile_in + xi_tile + out_tile + mats) + scratch)


def autotune_nd_fused(geom: LevelGeom, *, charted: tuple | None = None,
                      samples: int = 1, itemsize: int = 4,
                      vmem_budget: int = VMEM_BUDGET_BYTES):
    """Family/sample blocks ``(b_f, s_b)`` for the fused N-D level kernel,
    or None when even the minimal tile busts the VMEM budget — the fallback
    rule: dispatch then routes the level to the per-axis passes.

    Grows the axis-0 family block first (powers of two up to ``T_0``), then
    the sample slab (up to ``samples``), keeping the §10 working-set model
    under the budget.
    """
    nd = len(geom.coarse_shape)
    if nd < 2:
        return None
    if charted is None:
        charted = tuple(k > 1 for k in geom.kept_T)
    q = halo_floor(geom.n_csz, geom.n_fsz)
    floor = max(min(8, geom.T[0]), q, 1)
    if _fused_tile_bytes(geom, charted, floor, 1, itemsize) > vmem_budget:
        return None
    b_f = floor
    while b_f < geom.T[0]:
        nxt = min(2 * b_f, geom.T[0])
        if _fused_tile_bytes(geom, charted, nxt, 1, itemsize) > vmem_budget:
            break
        b_f = nxt
    s_b = 1
    while s_b < samples:
        nxt = min(2 * s_b, samples)
        if _fused_tile_bytes(geom, charted, b_f, nxt, itemsize) > vmem_budget:
            break
        s_b = nxt
    return b_f, s_b


def _pyramid_charted(geom: LevelGeom) -> tuple:
    return tuple(k > 1 for k in geom.kept_T)


def autotune_pyramid(geoms, *, samples: int = 1, itemsize: int = 4,
                     vmem_budget: int = VMEM_BUDGET_BYTES):
    """How many consecutive levels (from ``geoms[0]``) fit VMEM together,
    and the sample slab: ``(k, s_b)``, or None when fewer than two fit —
    a one-level "pyramid" is just the per-level route.

    The residency criterion reuses the §10 working-set model at FULL
    axis-0 extent (``b_f = T_0``, i.e. no spatial tiling): a covered
    level's coarse+fine fields, ξ, matrices and contraction scratch are
    all simultaneously resident, so the models simply add up. The storage
    ``itemsize`` makes the criterion dtype-aware — bf16 fits twice the
    levels' bytes of fp32.
    """

    def level_bytes(geom, s_b):
        return _fused_tile_bytes(geom, _pyramid_charted(geom), geom.T[0],
                                 s_b, itemsize)

    k, total = 0, 0
    for geom in geoms:
        lb = level_bytes(geom, 1)
        if total + lb > vmem_budget:
            break
        total += lb
        k += 1
    if k < 2:
        return None
    s_b = 1
    while s_b < samples:
        nxt = min(2 * s_b, samples)
        if sum(level_bytes(g, nxt) for g in geoms[:k]) > vmem_budget:
            break
        s_b = nxt
    return k, s_b


def pyramid_cover(chart, *, have_axis_mats: bool | None = None,
                  samples: int = 1, itemsize: int = 4,
                  vmem_budget: int = VMEM_BUDGET_BYTES):
    """The pyramid prefix of `chart`: ``(k, s_b)`` covering levels
    ``0..k-1``, or None. Only structured levels can be covered (a level
    that would route to the jnp reference ends the prefix)."""
    if have_axis_mats is None:
        have_axis_mats = chart.ndim > 1
    geoms = []
    for lvl in range(chart.n_levels):
        geom = LevelGeom.for_level(chart, lvl)
        if route_for(geom, have_axis_mats=have_axis_mats,
                     itemsize=itemsize) == ROUTE_REFERENCE:
            break
        geoms.append(geom)
    if len(geoms) < 2:
        return None
    return autotune_pyramid(geoms, samples=samples, itemsize=itemsize,
                            vmem_budget=vmem_budget)


def select_backend(*, platform: str | None = None) -> str:
    """Kernel backend for `platform` (default: the runtime jax backend).

    TPU runs the compiled Pallas kernels; everywhere else the *production*
    answer is the jnp reference/oracle path — Pallas interpret mode emulates
    the BlockSpec machinery step by step and is strictly slower than plain
    jnp on CPU, so it is a correctness harness, not a serving path. When
    ``platform`` is None (the runtime decision) the ``REPRO_BACKEND`` env
    var overrides it (``pallas`` / ``interpret`` / ``reference``): CI sets
    ``REPRO_BACKEND=interpret`` on its test steps so the exact kernel
    tiling keeps running bit-for-bit off-TPU, and the tiling-coverage test
    modules pin the same override via a monkeypatch fixture. An explicit
    ``platform`` is a pure what-would-run-there question (``plan()``
    introspection) and ignores the override.
    """
    if platform is None:
        override = os.environ.get("REPRO_BACKEND")
        if override:
            if override not in (BACKEND_PALLAS, BACKEND_INTERPRET,
                                BACKEND_REFERENCE):
                raise ValueError(
                    f"REPRO_BACKEND={override!r}: expected one of "
                    f"{BACKEND_PALLAS!r}, {BACKEND_INTERPRET!r}, "
                    f"{BACKEND_REFERENCE!r}"
                )
            return override
        platform = jax.default_backend()
    return BACKEND_PALLAS if platform == "tpu" else BACKEND_REFERENCE


def route_for(geom: LevelGeom, *, have_axis_mats: bool = False,
              itemsize: int = 4) -> str:
    """Which structured path covers this level's geometry (see module doc).

    ``itemsize`` is the storage-dtype byte width: the megakernel-vs-
    per-axis decision is a VMEM-fit question, so a borderline level that
    busts the budget at f32 can still take the fused route at bf16.
    """
    if geom.boundary not in ("shrink", "reflect"):
        return ROUTE_REFERENCE
    if len(geom.coarse_shape) == 1:
        if all(k == 1 for k in geom.kept_T):
            return ROUTE_STATIONARY_1D
        return ROUTE_CHARTED_1D
    if not have_axis_mats:
        return ROUTE_REFERENCE
    if autotune_nd_fused(geom, itemsize=itemsize) is not None:
        return ROUTE_ND_FUSED
    return ROUTE_AXES_ND


def plan(chart, *, have_axis_mats: bool | None = None,
         platform: str | None = None, samples: int = 1,
         dtype=None, pyramid: bool = True,
         vmem_budget: int = VMEM_BUDGET_BYTES) -> list:
    """Per-level forward AND backward routing decisions for `chart` —
    introspection for examples, benchmarks and tests (no arrays touched).

    have_axis_mats defaults to ``chart.ndim > 1`` (ICR.matrices computes the
    per-axis factors for every N-D chart when use_pallas=True).

    ``dtype`` is the storage dtype of the policy the chart will run under
    (default float32): it scales every byte estimate AND the VMEM
    autotuning — bf16 halves modeled HBM bytes and doubles what fits per
    tile. Each entry carries the dtype column (``"dtype"``).

    ``pyramid=True`` (the execution default) overlays the DESIGN.md §11
    VMEM-resident prefix: covered levels report ``route="pyramid"`` with
    zero inter-level field traffic (the first covered level carries the
    coarse read, the last the fine write). ``pyramid=False`` shows the
    per-level routing underneath — what runs when the pyramid is disabled
    (``ICR(use_pyramid=False)``) and what the covered levels fall back to.

    Each entry carries a ``"vjp"`` sub-dict describing how the *backward*
    pass of that level executes (structured routes run the hand-written
    adjoint kernels; the megakernel's backward composes the 1-D adjoints in
    reverse axis order; the pyramid's backward replays the jnp reference
    chain — its covered levels are VMEM-sized by construction; the
    reference route is jnp autodiff) and an ``"hbm_bytes"`` sub-dict: the
    ``roofline.level_traffic`` estimate for the selected route next to
    every candidate route, so the traffic win of the fused paths is visible
    without running anything.

    ``vmem_budget`` bounds the pyramid overlay only (tests shrink it to
    exercise the fallback rule); the per-level autotuners keep the global
    ``VMEM_BUDGET_BYTES``.
    """
    if have_axis_mats is None:
        have_axis_mats = chart.ndim > 1
    dtype = jnp.dtype(dtype or jnp.float32)
    itemsize = dtype.itemsize
    cover = (pyramid_cover(chart, have_axis_mats=have_axis_mats,
                           samples=samples, itemsize=itemsize,
                           vmem_budget=vmem_budget)
             if pyramid else None)
    k_cov, s_b_cov = cover if cover is not None else (0, None)
    out = []
    for lvl in range(chart.n_levels):
        geom = LevelGeom.for_level(chart, lvl)
        route = route_for(geom, have_axis_mats=have_axis_mats,
                          itemsize=itemsize)
        covered = lvl < k_cov
        backend = (BACKEND_REFERENCE if route == ROUTE_REFERENCE
                   else select_backend(platform=platform))
        blocks = {}
        sample_block = None
        if covered:
            sample_block = s_b_cov
        elif route in (ROUTE_STATIONARY_1D, ROUTE_CHARTED_1D):
            blocks[0] = autotune_block_families(
                geom.T[0], geom.n_csz, geom.n_fsz,
                charted=route == ROUTE_CHARTED_1D, itemsize=itemsize,
            )
            sample_block = autotune_batch_block(
                samples, geom.T[0], geom.n_csz, geom.n_fsz,
                charted=route == ROUTE_CHARTED_1D,
                block_families=blocks[0], itemsize=itemsize,
            )
        elif route == ROUTE_ND_FUSED:
            b_f, s_b = autotune_nd_fused(geom, samples=samples,
                                         itemsize=itemsize)
            blocks[0] = b_f
            sample_block = s_b
        elif route == ROUTE_AXES_ND:
            for a in range(len(geom.T)):
                ag = geom.axis(a)
                blocks[a] = autotune_block_families(
                    ag.T[0], ag.n_csz, ag.n_fsz,
                    charted=ag.kept_T[0] > 1, itemsize=itemsize,
                )
        candidates = ([ROUTE_ND_FUSED, ROUTE_AXES_ND, ROUTE_REFERENCE]
                      if len(geom.coarse_shape) > 1
                      else [route, ROUTE_REFERENCE])
        hbm = {
            rt: refine_level_traffic(geom, rt, samples=samples,
                                     dtype=dtype)["total"]
            for rt in candidates
        }
        if covered:
            hbm[ROUTE_PYRAMID] = refine_level_traffic(
                geom, ROUTE_PYRAMID, samples=samples, dtype=dtype,
                first=lvl == 0, last=lvl == k_cov - 1)["total"]
            route = ROUTE_PYRAMID
        hbm["selected"] = hbm[route]
        vjp = {
            "route": (ROUTE_REFERENCE if route == ROUTE_REFERENCE
                      else route + ("-ref" if covered else "-adjoint")),
            "backend": backend,
            "block_families": dict(blocks),
        }
        out.append({"level": lvl, "route": route, "backend": backend,
                    "block_families": blocks, "sample_block": sample_block,
                    "hbm_bytes": hbm, "dtype": dtype.name, "vjp": vjp})
    return out


# -- plan cache (serving warm path, DESIGN.md §12) ------------------------------
# plan() walks every level's autotuners and traffic models — pure geometry,
# so repeat traffic against the same (chart, dtype, backend, sample count)
# must not redo it. Charts are frozen dataclasses (hashable); the effective
# backend is part of the key so a REPRO_BACKEND flip is a miss.
_PLAN_CACHE: dict = {}
plan_cache_stats = {"hits": 0, "misses": 0}


def plan_cached(chart, *, have_axis_mats: bool | None = None,
                platform: str | None = None, samples: int = 1,
                dtype=None, pyramid: bool = True,
                vmem_budget: int = VMEM_BUDGET_BYTES,
                mesh_key=None) -> list:
    """Memoized ``plan()`` — the serving fast path asks for the same
    routing decision on every batch. The returned list is shared across
    callers: treat it as read-only.

    ``mesh_key`` is an opaque hashable describing the device mesh the plan
    will execute under (the sharded server passes its mesh fingerprint, see
    DESIGN.md §15). It does not change the per-device routing decision —
    ``samples`` is already the *local* slab height — but it keys the cache,
    so an elastic re-mesh is a deliberate plan-cache miss and can never be
    served a stale pre-resize plan.
    """
    backend = select_backend(platform=platform)
    key = (chart, have_axis_mats, backend, samples,
           jnp.dtype(dtype or jnp.float32).name, pyramid, vmem_budget,
           mesh_key)
    hit = _PLAN_CACHE.pop(key, None)
    if hit is not None:
        plan_cache_stats["hits"] += 1
        _PLAN_CACHE[key] = hit  # re-insert: LRU order, hits refresh recency
        return hit
    plan_cache_stats["misses"] += 1
    out = plan(chart, have_axis_mats=have_axis_mats, platform=platform,
               samples=samples, dtype=dtype, pyramid=pyramid,
               vmem_budget=vmem_budget)
    _PLAN_CACHE[key] = out
    while len(_PLAN_CACHE) > 32:  # bound: long-lived servers, many charts
        _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
    return out


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    plan_cache_stats.update(hits=0, misses=0)


def plan_signature(chart, **plan_kwargs) -> list:
    """Canonical JSON-serializable export of ``plan()`` — the route + tile
    + byte signature the compile-fingerprint subsystem (repro.analysis,
    DESIGN.md §13) locks down as a golden.

    One dict per level, primitives only (dict keys stringified, byte
    totals as ints), deterministically ordered: ``json.dumps(...,
    sort_keys=True)`` of two signatures of the same geometry is
    byte-identical, and any routing/tiling/byte-model change shows up as a
    structured diff against the golden rather than a wall-time blip.
    """
    out = []
    for e in plan(chart, **plan_kwargs):
        out.append({
            "level": e["level"],
            "route": e["route"],
            "backend": e["backend"],
            "block_families": {str(k): int(v)
                               for k, v in e["block_families"].items()},
            "sample_block": (None if e["sample_block"] is None
                             else int(e["sample_block"])),
            "dtype": e["dtype"],
            "hbm_bytes": {str(k): int(v) for k, v in e["hbm_bytes"].items()},
            "vjp": {"route": e["vjp"]["route"],
                    "backend": e["vjp"]["backend"]},
        })
    return out


# -- declarative launch-plan export (DESIGN.md §14) -----------------------------
# The kernel impls build LaunchPlan records (kernels.launch) and hand them
# to run_plan; these exports rebuild the *identical* records from geometry
# alone — same builders, same autotuned tiles — so analysis.kernel_verify
# can prove coverage/bounds/halo/byte properties about exactly the
# launches that would run, without touching an array.
def level_launch_plans(geom: LevelGeom, route: str | None = None, *,
                       samples: int = 1, dtype=None,
                       accum_dtype: str = "float32",
                       have_axis_mats: bool | None = None,
                       block_families: int | None = None,
                       sample_block: int | None = None) -> list:
    """Every Pallas launch one refinement level executes on ``route``:
    the forward launch(es) followed by the adjoint launch(es) its custom
    VJP runs at fixed matrices (``[]`` for the reference route — no
    Pallas launch to verify).

    ``route`` defaults to ``route_for`` of the geometry; tiles are the
    autotuners' answers at the storage ``dtype`` unless overridden, so
    the records match the kernel impls' own plans bit for bit. The N-D
    routes mirror the composed backward exactly: the megakernel's
    ``_core_bwd`` runs the 1-D adjoints in reverse axis order (axis 0
    with noise, trailing axes without), the per-axis route one
    forward/adjoint pair per axis with orthogonal axes folded into the
    batch dimension.
    """
    from .icr_refine import refine_adjoint_launch_plan, refine_fwd_launch_plan

    dtype = jnp.dtype(dtype or jnp.float32)
    itemsize = dtype.itemsize
    if have_axis_mats is None:
        have_axis_mats = len(geom.coarse_shape) > 1
    if route is None:
        route = route_for(geom, have_axis_mats=have_axis_mats,
                          itemsize=itemsize)
    if route == ROUTE_REFERENCE:
        return []
    csz, fsz = geom.n_csz, geom.n_fsz
    s = max(1, fsz // 2)
    q_max = (csz - 1) // s
    pad = 2 * geom.b if geom.boundary == "reflect" else 0

    if route in (ROUTE_STATIONARY_1D, ROUTE_CHARTED_1D):
        charted = route == ROUTE_CHARTED_1D
        t = geom.T[0]
        b_f = block_families or autotune_block_families(
            t, csz, fsz, charted=charted, itemsize=itemsize)
        b_b = sample_block or autotune_batch_block(
            samples, t, csz, fsz, charted=charted, block_families=b_f,
            itemsize=itemsize)
        kw = dict(batch=samples, t=t, coarse_len=geom.coarse_shape[0] + pad,
                  n_csz=csz, n_fsz=fsz, block_families=b_f, batch_block=b_b,
                  dtype=dtype, accum_dtype=accum_dtype, charted=charted)
        return [refine_fwd_launch_plan(**kw),
                refine_adjoint_launch_plan(**kw)]

    if route == ROUTE_ND_FUSED:
        from .nd_fused import fused_launch_shapes, nd_fused_launch_plan

        charted = _pyramid_charted(geom)
        T = tuple(geom.T)
        tuned = autotune_nd_fused(geom, charted=charted, samples=samples,
                                  itemsize=itemsize)
        if tuned is None:
            raise ValueError(
                "nd-fused route on a level whose minimal tile busts the "
                "VMEM budget — dispatch would route it to the per-axis "
                "passes")
        b_f, s_b = tuned
        if block_families is not None:
            b_f = max(min(block_families, T[0]), q_max, 1)
        if sample_block is not None:
            s_b = max(1, min(sample_block, samples))
        sh = fused_launch_shapes(geom, samples=samples, b_f=b_f, s_b=s_b)
        nd, sp, l0p = sh["nd"], sh["sp"], sh["l0p"]
        lp_trail, prod_f = sh["lp_trail"], sh["prod_f"]
        plans = [nd_fused_launch_plan(
            nd=nd, csz=csz, fsz=fsz, T=T, charted=charted, b_f=b_f,
            s_b=s_b, sp=sp, l0p=l0p, lp_trail=lp_trail, nblk=sh["nblk"],
            prod_f=prod_f, dtype=dtype, accum_dtype=accum_dtype)]
        # fixed-matrix backward (nd_fused._core_bwd): 1-D adjoints in
        # reverse axis order on the padded operand extents
        t0p = sh["nblk"] * b_f
        f_trail = tuple(T[a] * fsz for a in range(1, nd))
        bf0 = autotune_block_families(t0p, csz, fsz, charted=charted[0])
        plans.append(refine_adjoint_launch_plan(
            batch=sp * prod_f, t=t0p, coarse_len=l0p, n_csz=csz, n_fsz=fsz,
            block_families=bf0, batch_block=1, dtype=dtype,
            accum_dtype=accum_dtype, charted=charted[0]))
        for a in range(1, nd):
            batch_a = sp * l0p
            for j in range(1, a):
                batch_a *= lp_trail[j - 1]
            for j in range(a + 1, nd):
                batch_a *= f_trail[j - 1]
            bf_a = autotune_block_families(T[a], csz, fsz,
                                           charted=charted[a])
            plans.append(refine_adjoint_launch_plan(
                batch=batch_a, t=T[a], coarse_len=(T[a] + q_max) * s,
                n_csz=csz, n_fsz=fsz, block_families=bf_a, batch_block=1,
                dtype=dtype, accum_dtype=accum_dtype, charted=charted[a],
                noise=False))
        return plans

    if route == ROUTE_AXES_ND:
        nd = len(geom.coarse_shape)
        T = tuple(geom.T)
        fwd, bwd = [], []
        for a in range(nd - 1, -1, -1):
            ag = geom.axis(a)
            charted_a = ag.kept_T[0] > 1
            batch_a = samples
            for j in range(a):
                batch_a *= geom.coarse_shape[j]
            for j in range(a + 1, nd):
                batch_a *= T[j] * fsz
            bf = block_families or autotune_block_families(
                ag.T[0], csz, fsz, charted=charted_a, itemsize=itemsize)
            kw = dict(batch=batch_a, t=T[a],
                      coarse_len=geom.coarse_shape[a] + pad, n_csz=csz,
                      n_fsz=fsz, block_families=bf, batch_block=1,
                      dtype=dtype, accum_dtype=accum_dtype,
                      charted=charted_a, noise=a == 0)
            fwd.append(refine_fwd_launch_plan(**kw))
            bwd.append(refine_adjoint_launch_plan(**kw))
        return fwd + bwd[::-1]

    raise ValueError(f"no launch plans for route {route!r}")


def chart_launch_plans(chart, *, samples: int = 1, dtype=None,
                       accum_dtype: str = "float32",
                       have_axis_mats: bool | None = None,
                       pyramid: bool = True,
                       sample_block: int | None = None,
                       vmem_budget: int = VMEM_BUDGET_BYTES) -> list:
    """Launch-plan export for a whole chart, mirroring ``plan()`` routing.

    One group dict per launch unit: ``{"level", "route", "geom",
    "plans"}``. When the §11 pyramid cover fires, the covered prefix is
    ONE group (``route="pyramid"``, ``level=(0, k-1)``, ``geom`` the list
    of covered geometries) whose single plan is the multi-level launch;
    the remaining levels follow with their per-level forward + adjoint
    plans. Reference-routed levels appear with an empty plan list so the
    verifier can still see them. ``sample_block`` overrides the pyramid
    cover's autotuned sample block (the tile-sweep tests drive it).
    """
    from .pyramid import pyramid_launch_plan

    if have_axis_mats is None:
        have_axis_mats = chart.ndim > 1
    dtype = jnp.dtype(dtype or jnp.float32)
    itemsize = dtype.itemsize
    cover = (pyramid_cover(chart, have_axis_mats=have_axis_mats,
                           samples=samples, itemsize=itemsize,
                           vmem_budget=vmem_budget) if pyramid else None)
    k_cov, s_b_cov = cover if cover is not None else (0, None)
    groups = []
    if k_cov:
        geoms = [LevelGeom.for_level(chart, lvl) for lvl in range(k_cov)]
        fsz, csz = geoms[0].n_fsz, geoms[0].n_csz
        s_b = max(1, min(sample_block or s_b_cov or 1, samples))
        sp = -(-samples // s_b) * s_b
        xi_shapes, r_shapes, d_shapes, levels = [], [], [], []
        for g in geoms:
            T = tuple(g.T)
            ch = _pyramid_charted(g)
            prod_f = 1
            for a in range(1, len(T)):
                prod_f *= T[a] * fsz
            xi_shapes.append((sp, T[0] * fsz, prod_f))
            r_shapes.append([(T[a], fsz, csz) if ch[a] else (fsz, csz)
                             for a in range(len(T))])
            d_shapes.append((T[0], fsz, fsz) if ch[0] else (fsz, fsz))
            levels.append((T, tuple(g.coarse_shape)))
        groups.append({
            "level": (0, k_cov - 1), "route": ROUTE_PYRAMID, "geom": geoms,
            "plans": [pyramid_launch_plan(
                field_shape=(sp,) + tuple(geoms[0].coarse_shape),
                xi_shapes=xi_shapes, r_shapes=r_shapes, d_shapes=d_shapes,
                levels=levels, s_b=s_b, fsz=fsz, dtype=dtype,
                accum_dtype=accum_dtype)],
        })
    for lvl in range(k_cov, chart.n_levels):
        geom = LevelGeom.for_level(chart, lvl)
        route = route_for(geom, have_axis_mats=have_axis_mats,
                          itemsize=itemsize)
        groups.append({"level": lvl, "route": route, "geom": geom,
                       "plans": level_launch_plans(
                           geom, route, samples=samples, dtype=dtype,
                           accum_dtype=accum_dtype,
                           have_axis_mats=have_axis_mats)})
    return groups


def refine(field: Array, xi: Array, r: Array, d: Array, geom: LevelGeom, *,
           axis_mats=None, backend: str | None = None,
           block_families: int | None = None,
           sample_axis: bool = False,
           sample_block: int | None = None,
           policy: DtypePolicy | str | None = None) -> Array:
    """Route one refinement application to the best available backend.

    Arguments follow ``core.refine.refine_level``; ``axis_mats`` optionally
    carries the per-axis factors ``(rs, ds)`` from
    ``axis_refinement_matrices_level``, enabling the fused N-D paths (when
    present, the joint ``r``/``d`` are ignored on N-D levels).

    ``sample_axis=True`` marks the leading dimension of ``field``/``xi`` as
    a sample batch: the kernels process a whole sample slab per grid step
    (matrix loads amortized — DESIGN.md §10) instead of looping.

    ``policy`` (DESIGN.md §11): when given, every operand is cast to the
    policy's storage dtype on entry and the kernels accumulate in its accum
    dtype; when None, the storage dtype is whatever the operands carry and
    accumulation is f32. VMEM autotuning always follows the actual storage
    itemsize, so bf16 operands get twice the families per tile.

    Differentiable w.r.t. every array argument on every route: the kernel
    entry points carry custom VJPs running the fused adjoint kernels, the
    surrounding pads/reshapes are plain jnp.
    """
    accum_name = "float32"
    if policy is not None:
        pol = resolve_policy(policy)
        field, xi, r, d, axis_mats = pol.cast_storage(
            (field, xi, r, d, axis_mats))
        accum_name = pol.accum_name
    itemsize = jnp.dtype(field.dtype).itemsize
    route = route_for(geom, have_axis_mats=axis_mats is not None,
                      itemsize=itemsize)
    if backend is None and route != ROUTE_REFERENCE:
        backend = select_backend()
    if route == ROUTE_REFERENCE or backend == BACKEND_REFERENCE:
        if r is None or d is None:
            if axis_mats is None:
                raise ValueError(
                    "reference route needs the joint (r, d) matrices; this "
                    "level has none (ICR.matrices skipped the joint build) — "
                    "pass matrices(joint=True) or provide axis_mats covering "
                    "it"
                )
            # structured N-D level carrying only the per-axis factors: run
            # the jnp oracle of the factored path (kernels/ref.py) — the
            # production CPU answer. Honor the accumulation contract the
            # same way the joint branch below does: sub-accum storage is
            # upcast for the math and the result rounded back once per
            # level (the oracle's own per-pass accumulation rule then
            # operates at the policy's accum width or wider).
            from . import ref as _ref  # lazy: keeps import order flexible

            rs, ds = axis_mats
            out_dtype = field.dtype
            accum = jnp.dtype(accum_name)
            if jnp.dtype(out_dtype).itemsize < accum.itemsize:
                field, xi = field.astype(accum), xi.astype(accum)
                rs = [a.astype(accum) for a in rs]
                ds = [a.astype(accum) for a in ds]
            oracle = lambda f, x: _ref.refine_axes_ref(
                f, x, rs, ds, T=geom.T, n_fsz=geom.n_fsz,
                boundary=geom.boundary, b=geom.b)
            out = jax.vmap(oracle)(field, xi) if sample_axis \
                else oracle(field, xi)
            return out.astype(out_dtype)
        # honor the policy's accumulation contract here too: refine_level's
        # einsums carry no preferred_element_type, so sub-f32 storage is
        # upcast for the math and the result rounded back — same per-level
        # rounding the kernels produce, not a bf16-accumulated level
        out_dtype = field.dtype
        accum = jnp.dtype(accum_name)
        if jnp.dtype(out_dtype).itemsize < jnp.dtype(accum).itemsize:
            field, xi, r, d = (a.astype(accum) for a in (field, xi, r, d))
        if sample_axis:
            out = jax.vmap(
                lambda f, x: refine_level(f, x, r, d, geom))(field, xi)
        else:
            out = refine_level(field, xi, r, d, geom)
        return out.astype(out_dtype)
    interpret = backend != BACKEND_PALLAS

    if route == ROUTE_ND_FUSED:
        from . import nd_fused  # lazy: keeps import order flexible

        return nd_fused.refine_nd_fused(
            field, xi, axis_mats[0], axis_mats[1], geom,
            interpret=interpret, block_families=block_families,
            sample_block=sample_block, sample_axis=sample_axis,
            accum_dtype=accum_name,
        )
    if route == ROUTE_AXES_ND:
        return _nd.refine_axes(field, xi, axis_mats[0], axis_mats[1], geom,
                               interpret=interpret,
                               block_families=block_families,
                               sample_axis=sample_axis,
                               accum_dtype=accum_name)

    n_csz, n_fsz = geom.n_csz, geom.n_fsz
    t = geom.T[0]
    charted = route == ROUTE_CHARTED_1D
    if sample_axis:
        n_s = field.shape[0]
        coarse = field.reshape(n_s, -1)
        xi_k = xi.reshape(n_s, t, n_fsz)
    else:
        n_s = 1
        coarse = field.reshape(1, -1)
        xi_k = xi.reshape(1, t, n_fsz)
    if geom.boundary == "reflect":
        coarse = jnp.pad(coarse, [(0, 0), (geom.b, geom.b)], mode="reflect")
    b_f = block_families or autotune_block_families(
        t, n_csz, n_fsz, charted=charted, itemsize=itemsize
    )
    b_b = sample_block or autotune_batch_block(
        n_s, t, n_csz, n_fsz, charted=charted, block_families=b_f,
        itemsize=itemsize
    )
    if charted:
        out = refine_charted_pallas(
            coarse, xi_k, r.reshape(t, n_fsz, n_csz),
            d.reshape(t, n_fsz, n_fsz), n_csz=n_csz, n_fsz=n_fsz,
            block_families=b_f, batch_block=b_b, interpret=interpret,
            accum_dtype=accum_name,
        )
    else:
        out = refine_stationary_pallas(
            coarse, xi_k, r.reshape(n_fsz, n_csz),
            d.reshape(n_fsz, n_fsz), n_csz=n_csz, n_fsz=n_fsz,
            block_families=b_f, batch_block=b_b, interpret=interpret,
            accum_dtype=accum_name,
        )
    if sample_axis:
        return out.reshape((n_s,) + geom.fine_shape)
    return out.reshape(geom.fine_shape)


# A note on buffer donation (investigated for the §11 ping-pong chain and
# deliberately NOT used): jax donation is input->output aliasing, which
# needs a donated input whose shape/dtype matches an output. Refinement is
# strictly expansive — the fine output is 2^d times the coarse input, the
# adjoint's the reverse — so no level has an aliasable pair; a
# donate_argnums wrapper here compiles to a no-op plus a "donated buffer
# not usable" warning per geometry. Inside a jitted apply, XLA's buffer
# liveness already reclaims the coarse buffer for temporaries after its
# last read, which is all a donation could have achieved.
