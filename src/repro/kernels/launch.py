"""Declarative launch plans for every Pallas kernel in the repo.

A :class:`LaunchPlan` is a pure-static record of one ``pallas_call``:
the grid, every input/output operand with its block shape, *named*
index map, full (padded) array shape and dtype, plus the accumulation
dtype and the tile parameters the kernel was specialized with.

The plan is the single source of truth for the launch geometry — the
kernel impls in ``icr_refine.py`` / ``nd_fused.py`` / ``pyramid.py``
build a plan first and then hand it to :func:`run_plan`, which
constructs the actual ``pallas_call`` from the plan (after asserting
the concrete operands match the plan's array shapes).  The same plan
objects are exported through ``dispatch.level_launch_plans`` /
``dispatch.chart_launch_plans`` so ``analysis/kernel_verify.py`` can
*prove* properties about the launch (exact output coverage, in-bounds
halo reads, VMEM working-set bytes) without running the kernel.

Halo-overlapped operands are modeled explicitly: the *main* view
carries an ``overhang`` — per-dimension ``(lo, hi)`` element counts it
needs beyond its own block — and each shifted *halo* view names the
main view via ``halo_of``.  The verifier checks that the union of the
blocks fetched by the group covers the overhang at every grid step.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclasses.dataclass(frozen=True)
class IndexMap:
    """A BlockSpec index map with a printable name.

    ``name`` is the human-readable form used in verifier findings and
    plan descriptions (e.g. ``"(b, i + 1)"``); ``fn`` is the actual
    callable handed to ``pl.BlockSpec`` — it takes the grid indices and
    returns *block* indices (Pallas multiplies by the block shape).
    """

    name: str
    fn: Callable[..., Tuple[int, ...]]

    def __call__(self, *grid_idx):
        return self.fn(*grid_idx)


@dataclasses.dataclass(frozen=True)
class OperandSpec:
    """One input or output operand of a planned launch.

    ``array_shape`` is the shape of the concrete (padded) array passed
    to ``pallas_call`` — not the logical pre-padding shape.  For
    halo-overlapped reads, the main view sets ``overhang`` (per-dim
    ``(lo, hi)`` extra elements the kernel consumes beyond the view's
    own block) and each shifted sibling sets ``halo_of`` to the main
    view's name; siblings alias the same concrete array.
    """

    name: str
    block_shape: Tuple[int, ...]
    index_map: IndexMap
    array_shape: Tuple[int, ...]
    dtype: str
    halo_of: Optional[str] = None
    overhang: Optional[Tuple[Tuple[int, int], ...]] = None

    @property
    def itemsize(self) -> int:
        return jnp.dtype(self.dtype).itemsize

    @property
    def block_bytes(self) -> int:
        return math.prod(self.block_shape) * self.itemsize

    @property
    def array_bytes(self) -> int:
        return math.prod(self.array_shape) * self.itemsize

    def block_spec(self) -> pl.BlockSpec:
        return pl.BlockSpec(self.block_shape, self.index_map.fn)


@dataclasses.dataclass(frozen=True)
class LaunchPlan:
    """A complete, statically analyzable description of one pallas_call."""

    kernel: str
    grid: Tuple[int, ...]
    inputs: Tuple[OperandSpec, ...]
    outputs: Tuple[OperandSpec, ...]
    accum_dtype: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def grid_size(self) -> int:
        return math.prod(self.grid)

    def operand(self, name: str) -> OperandSpec:
        for op in (*self.inputs, *self.outputs):
            if op.name == name:
                return op
        raise KeyError(name)

    def block_bytes(self) -> int:
        """Double-buffered VMEM working set implied by the plan."""
        return 2 * sum(op.block_bytes for op in (*self.inputs, *self.outputs))

    def describe(self) -> dict:
        """JSON-safe plain-dict form for fingerprints / CLI output."""
        def op_desc(op):
            d = {"name": op.name, "block_shape": list(op.block_shape),
                 "index_map": op.index_map.name,
                 "array_shape": list(op.array_shape), "dtype": op.dtype}
            if op.halo_of:
                d["halo_of"] = op.halo_of
            if op.overhang:
                d["overhang"] = [list(p) for p in op.overhang]
            return d

        return {
            "kernel": self.kernel,
            "grid": list(self.grid),
            "accum_dtype": self.accum_dtype,
            "inputs": [op_desc(op) for op in self.inputs],
            "outputs": [op_desc(op) for op in self.outputs],
            "params": {k: (list(v) if isinstance(v, tuple) else v)
                       for k, v in dict(self.params).items()},
        }


def pad_to(arr, shape):
    """Zero-pad ``arr`` (trailing pad per dim) up to a plan's array shape."""
    pads = [(0, sz - cur) for cur, sz in zip(arr.shape, shape)]
    if any(hi for _lo, hi in pads):
        arr = jnp.pad(arr, pads)
    return arr


class PlanMismatchError(ValueError):
    """A concrete operand does not match the plan that claims to launch it."""


def run_plan(kern, plan: LaunchPlan, operands, *, interpret: bool):
    """Build and invoke the ``pallas_call`` described by ``plan``.

    The plan IS the launch: grid, BlockSpecs and out_shape are all
    constructed from the plan record, and every concrete operand is
    checked against the plan's array shapes/dtypes first — so the
    geometry the verifier analyzed is exactly the geometry that runs.
    """
    if len(operands) != len(plan.inputs):
        raise PlanMismatchError(
            f"{plan.kernel}: plan has {len(plan.inputs)} inputs, "
            f"got {len(operands)} operands")
    for arr, op in zip(operands, plan.inputs):
        if tuple(arr.shape) != op.array_shape:
            raise PlanMismatchError(
                f"{plan.kernel}: operand {op.name!r} has shape "
                f"{tuple(arr.shape)}, plan says {op.array_shape}")
        if jnp.dtype(arr.dtype) != jnp.dtype(op.dtype):
            raise PlanMismatchError(
                f"{plan.kernel}: operand {op.name!r} has dtype "
                f"{jnp.dtype(arr.dtype).name}, plan says {op.dtype}")

    in_specs = [op.block_spec() for op in plan.inputs]
    out_specs = [op.block_spec() for op in plan.outputs]
    out_shape = [jax.ShapeDtypeStruct(op.array_shape, jnp.dtype(op.dtype))
                 for op in plan.outputs]
    single = len(plan.outputs) == 1
    call = pl.pallas_call(
        kern,
        grid=plan.grid,
        in_specs=in_specs,
        out_specs=out_specs[0] if single else out_specs,
        out_shape=out_shape[0] if single else out_shape,
        interpret=interpret,
    )
    return call(*operands)
