from .optimizers import (
    OptState,
    Optimizer,
    adamw,
    adafactor,
    sgd,
    clip_by_global_norm,
    global_norm,
)
from .schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = [
    "OptState", "Optimizer", "adamw", "adafactor", "sgd",
    "clip_by_global_norm", "global_norm",
    "constant", "cosine_decay", "linear_warmup_cosine",
]
