"""Optimizers as pure pytree transforms (no external deps).

Design mirrors optax's (init, update) pair but stays dependency-free and
sharding-transparent: every state leaf has the same shape (or a factored
shape) as its parameter leaf, so the same PartitionSpec rules apply and
optimizer state is *fully sharded* alongside FSDP params.

``adafactor`` keeps a factored second moment (row/col statistics) so the
>=200B-parameter MoE configs fit in one pod's HBM (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: PyTree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple]  # (grads, state, params) -> (new_params, new_state)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> tuple:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, tree), norm


def sgd(lr_schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        inner = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return OptState(jnp.zeros((), jnp.int32), inner)

    def update(grads, state, params):
        lr = lr_schedule(state.step)
        if momentum:
            vel = jax.tree.map(lambda v, g: momentum * v + g, state.inner, grads)
            new = jax.tree.map(lambda p, v: p - lr * v, params, vel)
            return new, OptState(state.step + 1, vel)
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new, OptState(state.step + 1, None)

    return Optimizer(init, update)


def adamw(lr_schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, clip_norm: float | None = 1.0) -> Optimizer:
    """AdamW with fp32 moments; state leaves mirror param shapes (FSDP-safe)."""

    def init(params):
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), {"m": m, "v": v})

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = lr_schedule(state.step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.inner["m"])
        flat_v = treedef.flatten_up_to(state.inner["v"])
        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
            new_m.append(m)
            new_v.append(v)
        unflatten = jax.tree_util.tree_unflatten
        return unflatten(treedef, new_p), OptState(
            step,
            {"m": unflatten(treedef, new_m), "v": unflatten(treedef, new_v)},
        )

    return Optimizer(init, update)


def adafactor(lr_schedule, eps: float = 1e-30, clip_norm: float | None = 1.0,
              min_dim_size_to_factor: int = 128,
              decay_rate: float = 0.8) -> Optimizer:
    """Adafactor (factored second moment, no momentum).

    Memory: O(rows + cols) per matrix instead of O(rows*cols) — the reason the
    236B/400B MoE configs' optimizer state fits a 256-chip pod (DESIGN.md §5).
    """

    def _factored(shape):
        return len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor and \
            shape[-2] >= min_dim_size_to_factor

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(one, params, is_leaf=None))

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = lr_schedule(state.step)
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay_rate)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.inner)

        new_p, new_s = [], []
        for p, g, s in zip(flat_p, flat_g, flat_s):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "v" in s:
                v = beta * s["v"] + (1 - beta) * g2
                pre = jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            else:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps
                )
                cfac = jax.lax.rsqrt(vc + eps)
                pre = rfac[..., None] * cfac[..., None, :]
                ns = {"vr": vr, "vc": vc}
            upd = g32 * pre
            # update clipping (Adafactor's RMS-1 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms)
            new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
            new_s.append(ns)

        return (jax.tree_util.tree_unflatten(treedef, new_p),
                OptState(step, jax.tree_util.tree_unflatten(treedef, new_s)))

    return Optimizer(init, update)
