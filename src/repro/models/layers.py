"""Shared layers: norms, rotary embeddings, MLPs, embedding tables.

Pure functions over explicit parameter pytrees. Convention: ``init_*``
returns a params dict; the matching ``apply`` is a plain function. All
matmuls run in the activation dtype with fp32 accumulation
(``preferred_element_type``), norms/softmax in fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (scale * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def matmul(x: Array, w: Array) -> Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


# -- norms ---------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def qk_norm(x: Array, eps: float = 1e-6) -> Array:
    """Parameter-free RMS over the head dim (gemma3-style qk-norm, sans
    learned scale for simplicity of the stacked layout)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# -- rotary --------------------------------------------------------------------
def rope_freqs(dim: int, theta) -> Array:
    """Inverse frequencies (fp32). theta may be a traced scalar (gemma3
    selects a different theta on global layers inside the layer scan)."""
    exponents = jnp.arange(0, dim, 2, dtype=jnp.float32) / dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: Array, positions: Array, theta) -> Array:
    """x: (B, S, H, Dh); positions: (B, S) int32. Half-rotation convention."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                          # (Dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLP -----------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype, *, glu: bool,
             use_bias: bool) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": _dense_init(k2, (d_ff, d_model), dtype)}
    if glu:
        p["gate"] = _dense_init(k1, (d_model, d_ff), dtype)
        p["up"] = _dense_init(k3, (d_model, d_ff), dtype)
    else:
        p["up"] = _dense_init(k1, (d_model, d_ff), dtype)
    if use_bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


def mlp(params: dict, x: Array, *, act: str, glu: bool) -> Array:
    from .shard_ctx import constrain

    actfn = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = matmul(x, params["up"])
    if "b_up" in params:
        up = up + params["b_up"]
    h = actfn(matmul(x, params["gate"])) * up if glu else actfn(up)
    h = constrain(h, ("data", None, "model"))  # d_ff over TP
    out = matmul(h, params["down"])
    if "b_down" in params:
        out = out + params["b_down"]
    return out


# -- embeddings ------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype) -> dict:
    # GPT-2-style small init: keeps tied-embedding logits O(1) at init
    return {"table": _dense_init(key, (vocab, d_model), dtype, scale=0.02)}


def embed(params: dict, tokens: Array) -> Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed_chunked(table: Array, h: Array, labels: Array,
                    chunk: int, mask: Optional[Array] = None) -> Array:
    """Mean cross-entropy WITHOUT materializing full (B, S, V) logits.

    Scans the sequence in ``chunk``-sized slices: per-slice logits are
    (B, chunk, V) — with V sharded over 'model' this keeps the transient
    per-device footprint at B*chunk*V/n_model elements (DESIGN.md §5).
    """
    b, s, d = h.shape
    nchunk = max(s // chunk, 1)
    chunk = s // nchunk
    hc = h[:, : nchunk * chunk].reshape(b, nchunk, chunk, d).transpose(1, 0, 2, 3)
    lc = labels[:, : nchunk * chunk].reshape(b, nchunk, chunk).transpose(1, 0, 2)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mc = mask[:, : nchunk * chunk].reshape(b, nchunk, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        from .shard_ctx import constrain

        hm, lm, mm = xs
        logits = jnp.dot(hm, table.T,
                         preferred_element_type=jnp.float32)  # (B, C, V)
        logits = constrain(logits, ("data", None, "model"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lm[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return (carry[0] + nll.sum(), carry[1] + mm.sum()), None

    # remat: the (B, chunk, V) logits are recomputed in backward rather
    # than saved per chunk (V up to 262k — this is the big-vocab guard)
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.float32)),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
