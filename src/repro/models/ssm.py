"""State-space & recurrent blocks: Mamba2 (SSD, chunkwise), xLSTM (mLSTM /
sLSTM). All O(N) in sequence length with O(1) decode state — these are the
architectures that run the ``long_500k`` shape cell (DESIGN.md §4).

Chunkwise scan pattern (both Mamba2 and mLSTM): within a chunk the
recurrence is unrolled as small matmuls (MXU work), across chunks a
lax.scan carries the O(1) state — the standard TPU-friendly linearization
(quadratic only in chunk size, linear in sequence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _dense_init, matmul

Array = jnp.ndarray


# =============================== Mamba2 (SSD) ===================================
def init_mamba2(key, d_model: int, ssm, dtype) -> dict:
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    ks = jax.random.split(key, 5)
    return {
        # fused in-projection: [z (gate), x, B, C, dt]
        "w_in": _dense_init(
            ks[0],
            (d_model, 2 * d_inner + 2 * ssm.d_state + n_heads), dtype),
        "w_out": _dense_init(ks[1], (d_inner, d_model), dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "dt_bias": jnp.full((n_heads,), -2.0, jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
    }


def _ssd_chunk_scan(xh, bmat, cmat, dt, a, chunk):
    """Chunkwise SSD: xh (B,S,H,P), bmat/cmat (B,S,N), dt (B,S,H) fp32,
    a (H,) fp32 negative. Returns y (B,S,H,P)."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    xc = xh.reshape(b, nc, chunk, h, p)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)
    dtc = dt.reshape(b, nc, chunk, h)

    # per-chunk cumulative log decay  (B,nc,chunk,H)
    seg = dtc * a[None, None, None, :]
    cum = jnp.cumsum(seg, axis=2)

    def chunk_body(state, xs):
        xcb, bcb, ccb, dtb, cumb, segb = xs
        # state: (B, H, P, N)
        # intra-chunk (triangular) term
        li = cumb[:, :, None, :] - cumb[:, None, :, :]      # (B,c,c,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        gamma = jnp.where(causal[None, :, :, None], jnp.exp(li), 0.0)
        sc = jnp.einsum("bqn,bkn->bqk", ccb, bcb,
                        preferred_element_type=jnp.float32)
        att = sc[:, :, :, None] * gamma * dtb[:, None, :, :]
        y = jnp.einsum("bqkh,bkhp->bqhp", att, xcb,
                       preferred_element_type=jnp.float32)
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(cumb)                            # (B,c,H)
        y = y + jnp.einsum("bqn,bhpn,bqh->bqhp", ccb, state, decay_in,
                           preferred_element_type=jnp.float32)
        # state update
        decay_out = jnp.exp(cumb[:, -1:, :] - cumb)         # (B,c,H)
        upd = jnp.einsum("bkn,bkhp,bkh,bkh->bhpn", bcb, xcb, dtb, decay_out,
                         preferred_element_type=jnp.float32)
        state = state * jnp.exp(cumb[:, -1, :])[:, :, None, None] + upd
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, bc, cc, dtc, cum, seg))
    # remat: the (B, c, c, H) intra-chunk decay/attention tensors are
    # recomputed in backward (they dwarf HBM if saved per chunk)
    _, ys = jax.lax.scan(jax.checkpoint(chunk_body), state0, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)


def _mamba2_inproj(params, x, ssm, d_model):
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    n = ssm.d_state
    zxbcdt = matmul(x, params["w_in"])
    z, xs, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n,
                 2 * d_inner + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])               # (B,S,H)
    a = -jnp.exp(params["a_log"])                           # (H,) negative
    return z, xs, bmat.astype(jnp.float32), cmat.astype(jnp.float32), dt, a, \
        n_heads, d_inner


def mamba2_train(params: dict, x: Array, ssm, d_model: int) -> Array:
    b, s, _ = x.shape
    z, xs, bmat, cmat, dt, a, n_heads, d_inner = _mamba2_inproj(
        params, x, ssm, d_model)
    xh = xs.reshape(b, s, n_heads, ssm.head_dim).astype(jnp.float32)
    chunk = min(ssm.chunk, s)
    assert s % chunk == 0
    y = _ssd_chunk_scan(xh, bmat, cmat, dt, a, chunk)
    y = y + params["d_skip"][None, None, :, None] * xh
    y = (y.reshape(b, s, d_inner) * jax.nn.silu(
        z.astype(jnp.float32))).astype(x.dtype)
    return matmul(y, params["w_out"])


def mamba2_decode(params: dict, state: Array, x: Array, ssm,
                  d_model: int) -> tuple:
    """One-step recurrence. state: (B, H, P, N) fp32. x: (B, 1, D)."""
    b = x.shape[0]
    z, xs, bmat, cmat, dt, a, n_heads, d_inner = _mamba2_inproj(
        params, x, ssm, d_model)
    xh = xs.reshape(b, n_heads, ssm.head_dim).astype(jnp.float32)
    dt1 = dt[:, 0]                                          # (B,H)
    decay = jnp.exp(dt1 * a[None, :])                       # (B,H)
    upd = jnp.einsum("bn,bhp,bh->bhpn", bmat[:, 0], xh, dt1)
    state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], state)
    y = y + params["d_skip"][None, :, None] * xh
    y = (y.reshape(b, 1, d_inner) * jax.nn.silu(
        z.astype(jnp.float32))).astype(x.dtype)
    return matmul(y, params["w_out"]), state


def mamba2_state_shape(batch: int, d_model: int, ssm) -> tuple:
    d_inner = ssm.expand * d_model
    h = d_inner // ssm.head_dim
    return (batch, h, ssm.head_dim, ssm.d_state)


# ================================ xLSTM: mLSTM ==================================
def init_mlstm(key, d_model: int, n_heads: int, dtype) -> dict:
    ks = jax.random.split(key, 5)
    return {
        "wqkv": _dense_init(ks[0], (d_model, 3 * d_model), dtype),
        "wif": _dense_init(ks[1], (d_model, 2 * n_heads), dtype, scale=0.02),
        "wo_gate": _dense_init(ks[2], (d_model, d_model), dtype),
        "wo": _dense_init(ks[3], (d_model, d_model), dtype),
    }


def mlstm_train(params: dict, x: Array, n_heads: int,
                chunk: int = 256) -> Array:
    """Chunkwise mLSTM (matrix memory + exponential gating, xLSTM paper).

    Stabilized formulation: per-step log input gate i_t and log forget
    gate accumulate; within a chunk the pairwise decay matrix is built from
    cumulative log-gates (like SSD with data-dependent scalar decay).
    """
    b, s, d = x.shape
    dh = d // n_heads
    qkv = matmul(x, params["wqkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, n_heads, dh).astype(jnp.float32) / np.sqrt(dh)
    k = k.reshape(b, s, n_heads, dh).astype(jnp.float32)
    v = v.reshape(b, s, n_heads, dh).astype(jnp.float32)
    gif = matmul(x, params["wif"]).astype(jnp.float32)
    ig = gif[..., :n_heads]                                  # (B,S,H) log-ish
    fg = jax.nn.log_sigmoid(gif[..., n_heads:] + 1.0)        # (B,S,H) <= 0

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, n_heads, dh)
    kc = k.reshape(b, nc, chunk, n_heads, dh)
    vc = v.reshape(b, nc, chunk, n_heads, dh)
    ic = ig.reshape(b, nc, chunk, n_heads)
    fc = fg.reshape(b, nc, chunk, n_heads)
    cumf = jnp.cumsum(fc, axis=2)

    def body(carry, xs):
        cstate, nstate, mstate = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qb, kb, vb, ib, fb, cfb = xs
        # log weights of source k at target q within chunk
        lw = cfb[:, :, None, :] - cfb[:, None, :, :] + ib[:, None, :, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        lw = jnp.where(causal[None, :, :, None], lw, -jnp.inf)
        # carried-state log weight at each target
        lw_state = cfb + mstate[:, None, :]                  # (B,c,H)
        m_new = jnp.maximum(jnp.max(lw, axis=2), lw_state)   # (B,c,H)
        wmat = jnp.exp(lw - m_new[:, :, None, :])
        wstate = jnp.exp(lw_state - m_new)
        scores = jnp.einsum("bqhd,bkhd->bqkh", qb, kb) * wmat
        num = jnp.einsum("bqkh,bkhd->bqhd", scores, vb)
        num = num + wstate[..., None] * jnp.einsum(
            "bqhd,bhde->bqhe", qb, cstate)
        den = scores.sum(2) + wstate * jnp.einsum(
            "bqhd,bhd->bqh", qb, nstate)
        y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # state update to end of chunk
        lw_out = cfb[:, -1:, :] - cfb + ib                   # (B,c,H)
        m_up = jnp.maximum(jnp.max(lw_out, axis=1),
                           cfb[:, -1, :] + mstate)           # (B,H)
        wout = jnp.exp(lw_out - m_up[:, None, :])
        wcarry = jnp.exp(cfb[:, -1, :] + mstate - m_up)
        cstate = wcarry[:, :, None, None] * cstate + jnp.einsum(
            "bkh,bkhd,bkhe->bhde", wout, kb, vb)
        nstate = wcarry[..., None] * nstate + jnp.einsum(
            "bkh,bkhd->bhd", wout, kb)
        return (cstate, nstate, m_up), y

    c0 = jnp.zeros((b, n_heads, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, n_heads, dh), jnp.float32)
    m0 = jnp.full((b, n_heads), -1e30, jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, ic, fc, cumf))
    _, ys = jax.lax.scan(jax.checkpoint(body), (c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = y * jax.nn.silu(matmul(x, params["wo_gate"]))
    return matmul(y, params["wo"])


def mlstm_decode(params: dict, state: tuple, x: Array,
                 n_heads: int) -> tuple:
    """One-step mLSTM. state = (C (B,H,dh,dh), n (B,H,dh), m (B,H)) fp32."""
    b, _, d = x.shape
    dh = d // n_heads
    cstate, nstate, mstate = state
    qkv = matmul(x, params["wqkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, n_heads, dh).astype(jnp.float32) / np.sqrt(dh)
    k = k.reshape(b, n_heads, dh).astype(jnp.float32)
    v = v.reshape(b, n_heads, dh).astype(jnp.float32)
    gif = matmul(x, params["wif"]).astype(jnp.float32)[:, 0]
    ig, fg = gif[:, :n_heads], jax.nn.log_sigmoid(gif[:, n_heads:] + 1.0)
    m_new = jnp.maximum(fg + mstate, ig)
    wf = jnp.exp(fg + mstate - m_new)
    wi = jnp.exp(ig - m_new)
    cstate = wf[:, :, None, None] * cstate + wi[:, :, None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    nstate = wf[..., None] * nstate + wi[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, cstate)
    den = jnp.einsum("bhd,bhd->bh", q, nstate)
    y = (num / jnp.maximum(jnp.abs(den), 1.0)[..., None]).reshape(b, 1, d)
    y = y.astype(x.dtype) * jax.nn.silu(matmul(x, params["wo_gate"]))
    return matmul(y, params["wo"]), (cstate, nstate, m_new)


def mlstm_state_shape(batch: int, d_model: int, n_heads: int) -> tuple:
    dh = d_model // n_heads
    return ((batch, n_heads, dh, dh), (batch, n_heads, dh), (batch, n_heads))


# ================================ xLSTM: sLSTM ==================================
def init_slstm(key, d_model: int, n_heads: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    dh = d_model // n_heads
    return {
        "w_gates": _dense_init(ks[0], (d_model, 4 * d_model), dtype),
        # block-diagonal recurrent weights, per head: (H, dh, 4*dh)
        "r_gates": _dense_init(ks[1], (n_heads, dh, 4 * dh), dtype,
                               scale=1.0 / np.sqrt(dh)),
        "wo": _dense_init(ks[2], (d_model, d_model), dtype),
    }


def _slstm_step(params, carry, xg, n_heads, dh):
    """carry: (c, n, h, m) each (B, H, dh) fp32 except m (B,H,dh)."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,hde->bhe", h, params["r_gates"].astype(jnp.float32))
    g = xg + rec                                             # (B,H,4*dh)
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    lf = jax.nn.log_sigmoid(ft + 1.0)
    m_new = jnp.maximum(lf + m, it)
    wf, wi = jnp.exp(lf + m - m_new), jnp.exp(it - m_new)
    c = wf * c + wi * zt
    n = wf * n + wi
    h = ot * c / jnp.maximum(jnp.abs(n), 1.0)
    return (c, n, h, m_new)


def slstm_train(params: dict, x: Array, n_heads: int) -> Array:
    b, s, d = x.shape
    dh = d // n_heads
    xg = matmul(x, params["w_gates"]).astype(jnp.float32).reshape(
        b, s, n_heads, 4 * dh)

    def body(carry, xt):
        carry = _slstm_step(params, carry, xt, n_heads, dh)
        return carry, carry[2]

    z = jnp.zeros((b, n_heads, dh), jnp.float32)
    m0 = jnp.full((b, n_heads, dh), -1e30, jnp.float32)
    _, hs = jax.lax.scan(jax.checkpoint(body), (z, z, z, m0),
                         jnp.moveaxis(xg, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    return matmul(y, params["wo"])


def slstm_decode(params: dict, state: tuple, x: Array,
                 n_heads: int) -> tuple:
    b, _, d = x.shape
    dh = d // n_heads
    xg = matmul(x, params["w_gates"]).astype(jnp.float32).reshape(
        b, n_heads, 4 * dh)
    state = _slstm_step(params, state, xg, n_heads, dh)
    y = state[2].reshape(b, 1, d).astype(x.dtype)
    return matmul(y, params["wo"]), state


def slstm_state_shape(batch: int, d_model: int, n_heads: int) -> tuple:
    dh = d_model // n_heads
    s = (batch, n_heads, dh)
    return (s, s, s, s)
