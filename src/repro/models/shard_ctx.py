"""Logical activation-sharding context for model code.

Model code calls ``constrain(x, ("data", None, "model", None))`` with
*logical* axis roles; steps.py binds the roles to concrete mesh axes before
tracing. Outside a distributed context (CPU smoke tests) everything no-ops.
Divisibility is checked per-dim — a dim that doesn't divide its axes is
left unconstrained (same graceful rule as distributed/sharding.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

_CTX: dict = {"active": False, "data": None, "model": None,
              "data_size": 1, "model_size": 1, "mesh": None}


def set_axes(mesh, data_axes, model_axes) -> None:
    _CTX.update(
        active=True,
        data=tuple(data_axes),
        model=tuple(model_axes),
        data_size=int(np.prod([mesh.shape[a] for a in data_axes])),
        model_size=int(np.prod([mesh.shape[a] for a in model_axes])),
        mesh=mesh,
    )


def clear() -> None:
    _CTX.update(active=False, data=None, model=None, data_size=1,
                model_size=1)


def model_size() -> int:
    return _CTX["model_size"] if _CTX["active"] else 1


def gather_fsdp(param_tree):
    """Explicit FSDP all-gather at use site: constrain every weight leaf to
    its model-only sharding (data/FSDP dims dropped). Inside the layer-group
    scan this gathers one group's weights, which XLA frees after the
    iteration — ZeRO-3 semantics with GSPMD doing the bookkeeping.

    Without this, contraction-dim FSDP shards bait the SPMD partitioner
    into partial-sum strategies that replicate activations (measured: 137 GB
    -> fits; see EXPERIMENTS.md §Perf)."""
    if not _CTX["active"]:
        return param_tree
    from repro.distributed.sharding import param_specs

    from jax.sharding import NamedSharding

    specs = param_specs(param_tree, _CTX["mesh"], data_axes=(),
                        model_axes=_CTX["model"])
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(_CTX["mesh"], s)),
        param_tree, specs)


def constrain(x, roles: Sequence[Optional[str]]):
    """Apply with_sharding_constraint mapping 'data'/'model' roles to the
    bound mesh axes; no-op when no context is active."""
    if not _CTX["active"]:
        return x
    dims = []
    for size, role in zip(x.shape, roles):
        if role is None:
            dims.append(None)
            continue
        axes = _CTX[role]
        if size % _CTX[f"{role}_size"] == 0 and size >= _CTX[f"{role}_size"]:
            dims.append(axes)
        else:
            dims.append(None)
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX["mesh"], P(*dims)))
