"""Attention variants: GQA (full / sliding-window banded), MLA, cross.

Training path is *query-chunked* (flash-style blocking at the XLA level):
scores for one (B, H, Cq, K) tile at a time inside a lax.scan, so the
(S x S) score matrix is never materialized — the binding memory constraint
for train_4k/prefill_32k on the production mesh. Static sliding windows use
a banded path that only reads the (window + Cq) key slice per query chunk
(sub-quadratic; this is what makes gemma3's long_500k cells viable).

Decode path scores one new token against the cache; with
``kv_heads % model_axis != 0`` the cache is sequence-sharded and the softmax
reductions over the sharded axis become psums inserted by GSPMD
(flash-decode equivalent; DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _dense_init, apply_rope, matmul, qk_norm
from .shard_ctx import constrain

Array = jnp.ndarray
NEG_INF = -1e30


# -- parameter init -------------------------------------------------------------
def init_gqa(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
             dtype, *, use_bias: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d_model, n_heads * d_head), dtype),
        "wk": _dense_init(ks[1], (d_model, n_kv * d_head), dtype),
        "wv": _dense_init(ks[2], (d_model, n_kv * d_head), dtype),
        "wo": _dense_init(ks[3], (n_heads * d_head, d_model), dtype),
    }
    if use_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv * d_head,), dtype)
        p["bo"] = jnp.zeros((d_model,), dtype)
    return p


def init_mla(key, d_model: int, n_heads: int, mla, dtype) -> dict:
    ks = jax.random.split(key, 6)
    qk = mla.qk_nope_dim + mla.qk_rope_dim
    return {
        "w_dq": _dense_init(ks[0], (d_model, mla.q_lora_rank), dtype),
        "w_uq": _dense_init(ks[1], (mla.q_lora_rank, n_heads * qk), dtype),
        "w_dkv": _dense_init(
            ks[2], (d_model, mla.kv_lora_rank + mla.qk_rope_dim), dtype),
        "w_uk": _dense_init(
            ks[3], (mla.kv_lora_rank, n_heads * mla.qk_nope_dim), dtype),
        "w_uv": _dense_init(
            ks[4], (mla.kv_lora_rank, n_heads * mla.v_dim), dtype),
        "wo": _dense_init(ks[5], (n_heads * mla.v_dim, d_model), dtype),
    }


# -- shared helpers ---------------------------------------------------------------
def _split_heads(x: Array, n: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _proj_qkv(params, x, x_kv, n_heads, n_kv):
    q = matmul(x, params["wq"])
    k = matmul(x_kv, params["wk"])
    v = matmul(x_kv, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    spec = ("data", None, "model", None)  # heads over TP when divisible
    return (constrain(_split_heads(q, n_heads), spec),
            constrain(_split_heads(k, n_kv), spec),
            constrain(_split_heads(v, n_kv), spec))


def head_tp_available(h: int, hkv: int) -> bool:
    """Can attention shard over heads on the model axis? Either kv heads
    divide it, or q heads do (then kv is repeated group-wise)."""
    from .shard_ctx import model_size

    msz = model_size()
    return (hkv % msz == 0 and hkv >= msz) or (h % msz == 0 and h >= msz)


def _sdpa(q, k, v, mask, scale, *, train_layout: str | bool = False):
    """q: (B, Q, H, Dh); k/v: (B, K, Hkv, Dh); mask: (B, Q, K) bool or None.
    GQA via head grouping; scores fp32.

    train_layout: False (decode — the cache's own sharding rules, psums from
    GSPMD), "head" (TP over heads; kv repeated group-wise when only q-heads
    divide — Megatron GQA), or "key" (KEY-dim parallel: scores shard over
    the key/sequence dim of k/v, softmax reductions become psums — the
    layout for few-head archs like gemma3-4b/llama4/whisper where heads
    don't divide the model axis; composes with the q-chunk scan because q
    slicing happens on unsharded dims).
    """
    from .shard_ctx import constrain, model_size

    b, cq, h, dh = q.shape
    hkv = k.shape[2]
    msz = model_size()
    if train_layout == "head" and hkv % msz != 0 and h % msz == 0 \
            and h > hkv:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
        hkv = h
    rep = h // hkv
    qg = q.reshape(b, cq, hkv, rep, dh)
    if train_layout == "head":
        qg = constrain(qg, ("data", None, "model", None, None))
        k = constrain(k, ("data", None, "model", None))
        v = constrain(v, ("data", None, "model", None))
    elif train_layout == "key":
        qg = constrain(qg, ("data", None, None, None, None))
        k = constrain(k, ("data", "model", None, None))
        v = constrain(v, ("data", "model", None, None))
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if train_layout == "head":
        s = constrain(s, ("data", "model", None, None, None))
    elif train_layout == "key":
        s = constrain(s, ("data", None, None, None, "model"))
    if mask is not None:
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)  # key mode: max/sum psums from GSPMD
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    if train_layout == "head":
        o = constrain(o, ("data", None, "model", None, None))
    elif train_layout == "key":
        o = constrain(o, ("data", None, None, None, None))
    # note: v's head dim may differ from q/k's (MLA: qk=192, v=128)
    return o.reshape(b, cq, h, v.shape[-1]).astype(q.dtype)


def attention_train(params: dict, x: Array, positions: Array, *,
                    n_heads: int, n_kv: int, d_head: int,
                    rope_theta: float | None, causal: bool = True,
                    window: int | None = None, use_qk_norm: bool = False,
                    q_chunk: int = 512, x_kv: Optional[Array] = None,
                    kv_positions: Optional[Array] = None) -> Array:
    """Full-sequence attention (training / prefill), query-chunked.

    window: static int for banded sliding-window attention, None for full.
    x_kv/kv_positions: cross-attention source (whisper decoder).
    """
    b, s, _ = x.shape
    cross = x_kv is not None
    src = x_kv if cross else x
    kv_pos = kv_positions if cross else positions
    q, k, v = _proj_qkv(params, x, src, n_heads, n_kv)
    if use_qk_norm:
        q, k = qk_norm(q), qk_norm(k)
    if rope_theta is not None and not cross:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, kv_pos, rope_theta)
    scale = 1.0 / np.sqrt(d_head)
    # few-head archs (gemma3-4b: 8, llama4: 40, whisper: 8) can't shard
    # heads over the model axis — shard the KEY dim instead (softmax psums
    # from GSPMD). Key sharding precludes the banded dynamic key slice, so
    # local layers fall back to the masked full-key path.
    mode = "head" if head_tp_available(n_heads, n_kv) else "key"

    cq = min(q_chunk, s)
    nch = s // cq if s % cq == 0 else 1
    cq = s // nch

    sk = src.shape[1]
    if window is not None and not cross and mode == "head":
        # banded: only the (window + cq) key slice can be visible to a chunk
        band = min(window + cq, sk)

        def chunk_body(carry, idx):
            start = idx * cq
            qs = jax.lax.dynamic_slice_in_dim(q, start, cq, axis=1)
            qp = jax.lax.dynamic_slice_in_dim(positions, start, cq, axis=1)
            kstart = jnp.maximum(start + cq - band, 0)
            ks = jax.lax.dynamic_slice_in_dim(k, kstart, band, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, kstart, band, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kv_pos, kstart, band, axis=1)
            m = (qp[:, :, None] >= kp[:, None, :]) & (
                qp[:, :, None] - kp[:, None, :] < window)
            return carry, _sdpa(qs, ks, vs, m, scale, train_layout=mode)
    else:
        def chunk_body(carry, idx):
            start = idx * cq
            qs = jax.lax.dynamic_slice_in_dim(q, start, cq, axis=1)
            qp = jax.lax.dynamic_slice_in_dim(positions, start, cq, axis=1)
            if causal and not cross:
                m = qp[:, :, None] >= kv_pos[:, None, :]
                if window is not None:  # key mode: window folded into mask
                    m &= qp[:, :, None] - kv_pos[:, None, :] < window
            else:
                m = None
            return carry, _sdpa(qs, k, vs_full, m, scale, train_layout=mode)

        vs_full = v

    # remat the chunk body: scores/softmax are recomputed in backward
    # instead of residing per-chunk in HBM (the difference between fitting
    # 16 GB and not at train_4k scale)
    _, chunks = jax.lax.scan(jax.checkpoint(chunk_body), (),
                             jnp.arange(nch))
    out = chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, n_heads * d_head)
    out = matmul(out, params["wo"])
    if "bo" in params:
        out = out + params["bo"]
    return out


def attention_decode(params: dict, cache: dict, x: Array, positions: Array,
                     *, n_heads: int, n_kv: int, d_head: int,
                     rope_theta: float | None, window: int | None = None,
                     use_qk_norm: bool = False) -> tuple:
    """One-token decode against a (B, S_max, Hkv, Dh) cache.

    cache: {"k": ..., "v": ...}; positions: (B,) write/attend index.
    Returns (out (B, 1, D), new_cache). Sliding-window layers use a
    ring-buffer cache of size `window` (slot = pos % window).
    """
    b = x.shape[0]
    q, k_new, v_new = _proj_qkv(params, x, x, n_heads, n_kv)
    if use_qk_norm:
        q, k_new = qk_norm(q), qk_norm(k_new)
    if rope_theta is not None:
        q = apply_rope(q, positions[:, None], rope_theta)
        k_new = apply_rope(k_new, positions[:, None], rope_theta)
    s_max = cache["k"].shape[1]
    slot = positions % s_max if window is not None else positions

    def write(c, new):
        def one(cb, nb, sb):
            return jax.lax.dynamic_update_slice_in_dim(cb, nb, sb, axis=0)
        return jax.vmap(one)(c, new, slot)

    k = write(cache["k"], k_new)
    v = write(cache["v"], v_new)

    # visibility: cache slot j holds absolute position pos_j
    idx = jnp.arange(s_max)[None, :]
    if window is not None:
        # ring buffer: slot j holds position p with p % s_max == j, the
        # largest such p <= current position
        cur = positions[:, None]
        p_j = cur - ((cur - idx) % s_max)
        visible = (p_j >= 0) & (cur - p_j < window) & (p_j <= cur)
    else:
        visible = idx <= positions[:, None]
    scale = 1.0 / np.sqrt(d_head)
    out = _sdpa(q, k, v, visible[:, None, :].astype(bool), scale)
    out = matmul(out.reshape(b, 1, n_heads * d_head), params["wo"])
    if "bo" in params:
        out = out + params["bo"]
    return out, {"k": k, "v": v}


# -- MLA (deepseek-v2) -------------------------------------------------------------
def mla_train(params: dict, x: Array, positions: Array, *, n_heads: int,
              mla, q_chunk: int = 512) -> Array:
    b, s, _ = x.shape
    nope, rope, vd = mla.qk_nope_dim, mla.qk_rope_dim, mla.v_dim
    qk = nope + rope
    cq_lat = matmul(x, params["w_dq"])
    q = _split_heads(matmul(cq_lat, params["w_uq"]), n_heads)  # (B,S,H,qk)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, 10_000.0)

    ckv = matmul(x, params["w_dkv"])
    c_kv, k_pe = ckv[..., : mla.kv_lora_rank], ckv[..., mla.kv_lora_rank:]
    k_pe = apply_rope(k_pe[:, :, None, :], positions, 10_000.0)  # (B,S,1,rope)
    k_nope = _split_heads(matmul(c_kv, params["w_uk"]), n_heads)
    v = _split_heads(matmul(c_kv, params["w_uv"]), n_heads)

    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (b, s, n_heads, rope))], axis=-1)
    qq = jnp.concatenate([q_nope, q_pe], axis=-1)
    scale = 1.0 / np.sqrt(qk)

    cqs = min(q_chunk, s)
    nch = s // cqs if s % cqs == 0 else 1
    cqs = s // nch

    def body(carry, idx):
        start = idx * cqs
        qs = jax.lax.dynamic_slice_in_dim(qq, start, cqs, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(positions, start, cqs, axis=1)
        m = qp[:, :, None] >= positions[:, None, :]
        return carry, _sdpa(qs, k, v, m, scale, train_layout='head')

    _, chunks = jax.lax.scan(jax.checkpoint(body), (), jnp.arange(nch))
    out = chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, n_heads * vd)
    return matmul(out, params["wo"])


def mla_decode(params: dict, cache: dict, x: Array, positions: Array, *,
               n_heads: int, mla) -> tuple:
    """Absorbed-matrix MLA decode: the cache holds only the latent
    (kv_lora + rope) per token — 64x smaller than full GQA KV at deepseek-v2
    scale, the reason MLA decode is HBM-friendly.

    cache: {"ckv": (B, S, kv_lora), "kpe": (B, S, rope)}.
    """
    b = x.shape[0]
    nope, rope, vd = mla.qk_nope_dim, mla.qk_rope_dim, mla.v_dim
    lat = mla.kv_lora_rank
    cq_lat = matmul(x, params["w_dq"])
    q = _split_heads(matmul(cq_lat, params["w_uq"]), n_heads)  # (B,1,H,qk)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions[:, None], 10_000.0)

    ckv_new = matmul(x, params["w_dkv"])
    c_new, kpe_new = ckv_new[..., :lat], ckv_new[..., lat:]
    kpe_new = apply_rope(kpe_new[:, :, None, :], positions[:, None],
                         10_000.0)[:, :, 0, :]

    def write(cb, nb):
        def one(c, n, p):
            return jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0)
        return jax.vmap(one)(cb, nb, positions)

    ckv = write(cache["ckv"], c_new)
    kpe = write(cache["kpe"], kpe_new)

    # absorb W_uk into q: q_lat (B,1,H,lat)
    w_uk = params["w_uk"].reshape(lat, n_heads, nope)
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    s_max = ckv.shape[1]
    scores = (
        jnp.einsum("bqhl,bkl->bhqk", q_lat, ckv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bqhr,bkr->bhqk", q_pe, kpe,
                     preferred_element_type=jnp.float32)
    ) / np.sqrt(nope + rope)
    visible = jnp.arange(s_max)[None, None, None, :] <= \
        positions[:, None, None, None]
    scores = jnp.where(visible, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqk,bkl->bqhl", p.astype(x.dtype), ckv,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    w_uv = params["w_uv"].reshape(lat, n_heads, vd)
    o = jnp.einsum("bqhl,lhv->bqhv", o_lat, w_uv,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = matmul(o.reshape(b, 1, n_heads * vd), params["wo"])
    return out, {"ckv": ckv, "kpe": kpe}
