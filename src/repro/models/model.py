"""Public model API: build_model(cfg) -> Model.

Model bundles init, the training loss, prefill and one-token decode for any
ArchConfig, including the whisper enc-dec special case and the VLM stub
frontend. Vocab is padded to a multiple of 128 so the unembedding always
shards over the 'model' mesh axis (internvl2's 92553 is the offender).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from . import attention as attn
from .layers import (
    _dense_init,
    embed,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    matmul,
    mlp,
    rmsnorm,
    unembed_chunked,
)
from .transformer import (
    _init_shared_block,
    _init_slot,
    decode_hidden,
    forward_hidden,
    init_slot_cache,
    layer_plan,
)

Array = jnp.ndarray


def padded_vocab(v: int) -> int:
    return ((v + 127) // 128) * 128


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    @property
    def vocab_pad(self) -> int:
        return padded_vocab(self.cfg.vocab_size)

    # ---------------- params -----------------------------------------------
    def init_params(self, key) -> dict:
        cfg = self.cfg
        dtype = cfg.dtype()
        if cfg.encoder is not None:
            return self._whisper_init(key, dtype)
        head, period, n_groups, tail = layer_plan(cfg)
        ks = jax.random.split(key, 8)
        params: dict = {
            "embed": init_embedding(ks[0], self.vocab_pad, cfg.d_model,
                                    dtype),
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
        }
        params["head"] = [
            _init_slot(jax.random.fold_in(ks[1], i), cfg, s, dtype)
            for i, s in enumerate(head)
        ]
        if n_groups > 0:
            def one_group(k):
                return {
                    f"slot{j}": _init_slot(jax.random.fold_in(k, j), cfg, s,
                                           dtype)
                    for j, s in enumerate(period)
                }
            params["groups"] = jax.vmap(one_group)(
                jax.random.split(ks[2], n_groups))
        else:
            params["groups"] = {}
        params["tail"] = [
            _init_slot(jax.random.fold_in(ks[3], i), cfg, s, dtype)
            for i, s in enumerate(tail)
        ]
        if cfg.shared_attn_every:
            params["shared"] = _init_shared_block(ks[4], cfg, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = _dense_init(
                ks[5], (cfg.d_model, self.vocab_pad), dtype)
        if cfg.frontend == "vision_stub":
            params["frontend"] = _dense_init(
                ks[6], (cfg.d_model, cfg.d_model), dtype)
        return params

    def params_spec(self) -> Any:
        """ShapeDtypeStruct pytree — used by the dry-run, never allocates."""
        return jax.eval_shape(
            lambda: self.init_params(jax.random.PRNGKey(0)))

    def param_count(self, spec=None) -> int:
        spec = spec or self.params_spec()
        return sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(spec))

    # ---------------- embedding / unembedding --------------------------------
    def _embed_in(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        h = embed(params["embed"], tokens).astype(jnp.dtype(cfg.act_dtype))
        if cfg.scale_embed:
            # cast the scale to h.dtype: a f32 scalar would promote the
            # entire residual stream to f32 (2x activation memory)
            h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            pe = matmul(batch["patch_embeds"].astype(h.dtype),
                        params["frontend"])
            h = jnp.concatenate([pe, h], axis=1)
        b, s = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        return h, positions

    def _unembed_table(self, params) -> Array:
        if self.cfg.tie_embeddings:
            return params["embed"]["table"]
        return params["lm_head"].T  # (Vpad, D)

    # ---------------- train loss ----------------------------------------------
    def loss_fn(self, params, batch) -> tuple:
        cfg = self.cfg
        if cfg.encoder is not None:
            return self._whisper_loss(params, batch)
        h, positions = self._embed_in(params, batch)
        h, aux = forward_hidden(cfg, params, h, positions)
        labels = batch["labels"]
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            h = h[:, -labels.shape[1]:]  # loss on text positions only
        mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)
        nll = unembed_chunked(self._unembed_table(params), h, labels,
                              cfg.loss_chunk, mask)
        loss = nll + aux
        return loss, {"nll": nll, "aux": aux}

    # ---------------- prefill (forward only) -----------------------------------
    def prefill_fn(self, params, batch) -> Array:
        """Forward pass, last-position logits (the inference-prefill cell)."""
        cfg = self.cfg
        if cfg.encoder is not None:
            return self._whisper_prefill(params, batch)
        h, positions = self._embed_in(params, batch)
        h, _ = forward_hidden(cfg, params, h, positions)
        last = h[:, -1]
        logits = jnp.dot(last, self._unembed_table(params).T,
                         preferred_element_type=jnp.float32)
        return logits[:, : cfg.vocab_size]

    # ---------------- decode ----------------------------------------------------
    def init_cache(self, batch: int, s_max: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.act_dtype)
        if cfg.encoder is not None:
            return self._whisper_cache(batch, dtype)
        head, period, n_groups, tail = layer_plan(cfg)
        cache = {
            "head": [init_slot_cache(cfg, s, batch, s_max, dtype)
                     for s in head],
            "tail": [init_slot_cache(cfg, s, batch, s_max, dtype)
                     for s in tail],
        }
        if n_groups > 0:
            one = {f"slot{j}": init_slot_cache(cfg, s, batch, s_max, dtype)
                   for j, s in enumerate(period)}
            cache["groups"] = jax.tree.map(
                lambda x: jnp.zeros((n_groups,) + x.shape, x.dtype), one)
        else:
            cache["groups"] = {}
        return cache

    def cache_spec(self, batch: int, s_max: int):
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, s_max))

    def serve_step(self, params, cache, tokens: Array,
                   positions: Array) -> tuple:
        """One decode step: tokens (B, 1), positions (B,) ->
        (logits (B, V), new_cache)."""
        cfg = self.cfg
        if cfg.encoder is not None:
            return self._whisper_serve(params, cache, tokens, positions)
        h = embed(params["embed"], tokens).astype(jnp.dtype(cfg.act_dtype))
        if cfg.scale_embed:
            # cast the scale to h.dtype: a f32 scalar would promote the
            # entire residual stream to f32 (2x activation memory)
            h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
        h, cache = decode_hidden(cfg, params, cache, h, positions)
        logits = jnp.dot(h[:, 0], self._unembed_table(params).T,
                         preferred_element_type=jnp.float32)
        return logits[:, : cfg.vocab_size], cache

    # ======================= whisper (enc-dec) ================================
    def _whisper_init(self, key, dtype) -> dict:
        cfg = self.cfg
        enc_l = cfg.encoder.n_layers
        ks = jax.random.split(key, 8)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "norm1": init_rmsnorm(cfg.d_model, dtype),
                "attn": attn.init_gqa(k1, cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim, dtype,
                                      use_bias=cfg.use_bias),
                "norm2": init_rmsnorm(cfg.d_model, dtype),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype,
                                glu=cfg.glu, use_bias=cfg.use_bias),
            }

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "norm1": init_rmsnorm(cfg.d_model, dtype),
                "self_attn": attn.init_gqa(k1, cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.head_dim,
                                           dtype, use_bias=cfg.use_bias),
                "norm_x": init_rmsnorm(cfg.d_model, dtype),
                "cross_attn": attn.init_gqa(k2, cfg.d_model, cfg.n_heads,
                                            cfg.n_kv_heads, cfg.head_dim,
                                            dtype, use_bias=cfg.use_bias),
                "norm2": init_rmsnorm(cfg.d_model, dtype),
                "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype,
                                glu=cfg.glu, use_bias=cfg.use_bias),
            }

        return {
            "embed": init_embedding(ks[0], self.vocab_pad, cfg.d_model,
                                    dtype),
            "pos_embed": _dense_init(
                ks[1], (cfg.encoder.max_target, cfg.d_model), dtype,
                scale=0.02),
            "enc": [enc_layer(jax.random.fold_in(ks[2], i))
                    for i in range(enc_l)],
            "enc_norm": init_rmsnorm(cfg.d_model, dtype),
            "dec": [dec_layer(jax.random.fold_in(ks[3], i))
                    for i in range(cfg.n_layers)],
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
        }

    def _whisper_encode(self, params, enc_embeds: Array) -> Array:
        cfg = self.cfg
        h = enc_embeds.astype(jnp.dtype(cfg.act_dtype))
        b, s, _ = h.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        for lp in params["enc"]:
            hn = rmsnorm(lp["norm1"], h, cfg.norm_eps)
            h = h + attn.attention_train(
                lp["attn"], hn, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, d_head=cfg.head_dim, rope_theta=None,
                causal=False)
            h = h + mlp(lp["mlp"], rmsnorm(lp["norm2"], h, cfg.norm_eps),
                        act=cfg.act, glu=cfg.glu)
        return rmsnorm(params["enc_norm"], h, cfg.norm_eps)

    def _whisper_decode_stack(self, params, h, pos, enc_out, enc_pos):
        cfg = self.cfg
        for lp in params["dec"]:
            hn = rmsnorm(lp["norm1"], h, cfg.norm_eps)
            h = h + attn.attention_train(
                lp["self_attn"], hn, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, d_head=cfg.head_dim, rope_theta=None,
                causal=True)
            hx = rmsnorm(lp["norm_x"], h, cfg.norm_eps)
            h = h + attn.attention_train(
                lp["cross_attn"], hx, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, d_head=cfg.head_dim, rope_theta=None,
                causal=False, x_kv=enc_out, kv_positions=enc_pos)
            h = h + mlp(lp["mlp"], rmsnorm(lp["norm2"], h, cfg.norm_eps),
                        act=cfg.act, glu=cfg.glu)
        return rmsnorm(params["final_norm"], h, cfg.norm_eps)

    def _whisper_hidden(self, params, batch):
        enc_out = self._whisper_encode(params, batch["enc_embeds"])
        b, se, _ = enc_out.shape
        enc_pos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32)[None],
                                   (b, se))
        tokens = batch["tokens"]
        sd = tokens.shape[1]
        h = embed(params["embed"], tokens).astype(enc_out.dtype)
        h = h + params["pos_embed"][None, :sd]
        pos = jnp.broadcast_to(jnp.arange(sd, dtype=jnp.int32)[None], (b, sd))
        return self._whisper_decode_stack(params, h, pos, enc_out, enc_pos)

    def _whisper_loss(self, params, batch):
        h = self._whisper_hidden(params, batch)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        nll = unembed_chunked(params["embed"]["table"], h,
                              jnp.maximum(labels, 0), self.cfg.loss_chunk,
                              mask)
        return nll, {"nll": nll, "aux": jnp.zeros((), jnp.float32)}

    def _whisper_prefill(self, params, batch):
        h = self._whisper_hidden(params, batch)
        logits = jnp.dot(h[:, -1], params["embed"]["table"].T,
                         preferred_element_type=jnp.float32)
        return logits[:, : self.cfg.vocab_size]

    def _whisper_cache(self, batch: int, dtype):
        cfg = self.cfg
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        tmax = cfg.encoder.max_target
        nf = cfg.encoder.n_frames
        return {
            "self": [
                {"k": jnp.zeros((batch, tmax, hkv, dh), dtype),
                 "v": jnp.zeros((batch, tmax, hkv, dh), dtype)}
                for _ in range(cfg.n_layers)
            ],
            # cross K/V precomputed from the encoder at prefill
            "cross": [
                {"k": jnp.zeros((batch, nf, hkv, dh), dtype),
                 "v": jnp.zeros((batch, nf, hkv, dh), dtype)}
                for _ in range(cfg.n_layers)
            ],
        }

    def prepare_cross_cache(self, params, cache, enc_embeds: Array):
        """Fill the cross-attention cache from encoder output (prefill)."""
        cfg = self.cfg
        enc_out = self._whisper_encode(params, enc_embeds)
        for i, lp in enumerate(params["dec"]):
            k = matmul(enc_out, lp["cross_attn"]["wk"])
            v = matmul(enc_out, lp["cross_attn"]["wv"])
            if "bk" in lp["cross_attn"]:
                k = k + lp["cross_attn"]["bk"]
                v = v + lp["cross_attn"]["bv"]
            b, s, _ = k.shape
            cache["cross"][i] = {
                "k": k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim),
                "v": v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim),
            }
        return cache

    def _whisper_serve(self, params, cache, tokens, positions):
        cfg = self.cfg
        b = tokens.shape[0]
        h = embed(params["embed"], tokens).astype(jnp.dtype(cfg.act_dtype))
        pos_emb = jnp.take(params["pos_embed"],
                           jnp.minimum(positions, cfg.encoder.max_target - 1),
                           axis=0)
        h = h + pos_emb[:, None, :]
        for i, lp in enumerate(params["dec"]):
            hn = rmsnorm(lp["norm1"], h, cfg.norm_eps)
            y, cache["self"][i] = attn.attention_decode(
                lp["self_attn"], cache["self"][i], hn, positions,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                d_head=cfg.head_dim, rope_theta=None)
            h = h + y
            # cross attention against the precomputed encoder cache
            hx = rmsnorm(lp["norm_x"], h, cfg.norm_eps)
            q = matmul(hx, lp["cross_attn"]["wq"])
            if "bq" in lp["cross_attn"]:
                q = q + lp["cross_attn"]["bq"]
            q = q.reshape(b, 1, cfg.n_heads, cfg.head_dim)
            kc, vc = cache["cross"][i]["k"], cache["cross"][i]["v"]
            scale = 1.0 / np.sqrt(cfg.head_dim)
            from .attention import _sdpa
            o = _sdpa(q, kc, vc, None, scale)
            o = matmul(o.reshape(b, 1, cfg.n_heads * cfg.head_dim),
                       lp["cross_attn"]["wo"])
            if "bo" in lp["cross_attn"]:
                o = o + lp["cross_attn"]["bo"]
            h = h + o
            h = h + mlp(lp["mlp"], rmsnorm(lp["norm2"], h, cfg.norm_eps),
                        act=cfg.act, glu=cfg.glu)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = jnp.dot(h[:, 0], params["embed"]["table"].T,
                         preferred_element_type=jnp.float32)
        return logits[:, : cfg.vocab_size], cache


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg)
