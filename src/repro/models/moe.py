"""Mixture-of-Experts with capacity-based dispatch (GShard/Switch style).

Scalable layout (DESIGN.md §5):
  * expert weight stacks (E, D, F) sharded E over 'model' (expert
    parallelism) and D/F over data (FSDP);
  * tokens are dispatched per (sample x sequence-chunk): the dispatch
    one-hot (B, g, E, C) keeps the batch dim, which is sharded over 'data',
    and produces expert buffers (B, E, C, D) with E over 'model' — the
    dispatch/combine einsums are then *local* (B and E are output dims on
    their own shards) and the only collective is the combine psum over the
    model axis, exactly like a tensor-parallel MLP;
  * sequence chunks of ``router_group_size`` run under a lax.scan so the
    one-hot transient is VMEM-scale, not HBM-resident;
  * shared experts (deepseek-v2: 2, llama4: 1) run densely for every token.

Top-k routing with softmax-renormalized gates and per-expert capacity
``C = ceil(g * k / E * capacity_factor)`` per sample-chunk; overflow tokens
fall through to the residual path (standard dropping semantics). Router
load-balance + z losses are returned for the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _dense_init, init_mlp, matmul, mlp

Array = jnp.ndarray


def init_moe(key, d_model: int, moe_cfg, dtype) -> dict:
    ks = jax.random.split(key, 4)
    e, f = moe_cfg.n_experts, moe_cfg.d_ff_expert
    p = {
        "router": _dense_init(ks[0], (d_model, e), dtype, scale=0.02),
        # stacked expert GLU weights: (E, D, F) / (E, F, D)
        "gate": _dense_init(ks[1], (e, d_model, f), dtype),
        "up": _dense_init(ks[2], (e, d_model, f), dtype),
        "down": _dense_init(ks[3], (e, f, d_model), dtype),
    }
    if moe_cfg.n_shared:
        p["shared"] = init_mlp(
            jax.random.fold_in(key, 7), d_model,
            moe_cfg.n_shared * f, dtype, glu=True, use_bias=False)
    return p


def _dispatch_chunk(params: dict, x: Array, moe_cfg, capacity: int) -> tuple:
    """One sequence chunk: x (B, g, D) -> (out (B, g, D), aux losses)."""
    e, k = moe_cfg.n_experts, moe_cfg.top_k
    b, g, d = x.shape
    logits = matmul(x, params["router"]).astype(jnp.float32)    # (B, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (B, g, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) in its expert's capacity buffer,
    # counted independently per sample (batch stays shardable over 'data')
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)     # (B, g, k, E)
    flat = onehot.reshape(b, g * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(b, g, k, e)
    pos = (pos_in_expert * onehot).sum(-1)                      # (B, g, k)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    cap_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                            dtype=x.dtype)                      # (B, g, k, C)
    disp = (onehot.astype(x.dtype)[..., None]
            * cap_oh[..., None, :]).sum(2)                      # (B, g, E, C)
    comb = ((onehot.astype(jnp.float32) * gate_vals[..., None]
             ).astype(x.dtype)[..., None] * cap_oh[..., None, :]).sum(2)

    # local dispatch: B (data) and E (model) are both output dims
    from .shard_ctx import constrain

    xin = jnp.einsum("bgec,bgd->becd", disp, x,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    xin = constrain(xin, ("data", "model", None, None))
    h = jax.nn.silu(
        jnp.einsum("becd,edf->becf", xin, params["gate"],
                   preferred_element_type=jnp.float32)
    ).astype(x.dtype) * jnp.einsum(
        "becd,edf->becf", xin, params["up"],
        preferred_element_type=jnp.float32).astype(x.dtype)
    xout = jnp.einsum("becf,efd->becd", h, params["down"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    # combine: contraction over (E, C) -> psum over 'model' (GSPMD)
    out = jnp.einsum("bgec,becd->bgd", comb, xout,
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # aux: load-balance (Switch) + router z-loss
    density = jnp.mean(onehot.sum(2).astype(jnp.float32), axis=(0, 1))
    prob_mass = jnp.mean(probs, axis=(0, 1))
    lb = e * jnp.sum(density / k * prob_mass)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, (lb, z)


def moe_apply(params: dict, x: Array, moe_cfg) -> tuple:
    """x: (B, S, D) -> (out, aux_loss). Sequence chunks are scanned."""
    b, s, d = x.shape
    g = min(moe_cfg.router_group_size, s)
    nch = s // g
    assert nch * g == s, f"seq {s} not divisible by router group {g}"
    capacity = int(np.ceil(g * moe_cfg.top_k / moe_cfg.n_experts
                           * moe_cfg.capacity_factor))
    capacity = max(capacity, 4)

    if nch == 1:
        out, (lb, z) = _dispatch_chunk(params, x, moe_cfg, capacity)
        aux = (lb - 1.0) * 1e-2 + z * 1e-3
    else:
        chunks = jnp.moveaxis(x.reshape(b, nch, g, d), 1, 0)

        def body(carry, xg):
            o, (lb, z) = _dispatch_chunk(params, xg, moe_cfg, capacity)
            return (carry[0] + lb, carry[1] + z), o

        # remat: dispatch one-hots + expert buffers recomputed in backward
        (lb, z), outs = jax.lax.scan(
            jax.checkpoint(body),
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            chunks)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)
        aux = (lb / nch - 1.0) * 1e-2 + (z / nch) * 1e-3
    if "shared" in params:
        out = out + mlp(params["shared"], x, act="silu", glu=True)
    return out, aux
