"""Block assembly: layer plan, scan-over-groups forward, decode-with-cache.

Every architecture is described by a *layer plan*: a periodic pattern of
slots (mixer kind + ffn kind). The period's worth of parameters is stacked
along a leading group axis and the forward runs ``lax.scan`` over groups —
keeping HLO size O(period) instead of O(n_layers), the binding constraint
for compiling 40–80 layer models on a 512-device mesh. Remainder layers
(e.g. gemma3-27b: 62 = 10*6 + 2) live in an explicit unscanned tail;
special leading layers (deepseek-v2's first dense FFN) in a head.

Cache layout mirrors the plan: one stacked leaf per slot per group, plus
head/tail entries. Local-attention slots use ring buffers of size
``sliding_window`` — this is what keeps gemma3's long_500k decode cache
dominated by its 1-in-6 global layers only (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .layers import init_mlp, init_rmsnorm, mlp, rmsnorm

Array = jnp.ndarray


# ============================ layer plan ========================================
@dataclasses.dataclass(frozen=True)
class Slot:
    mixer: str          # global | local | mla | mamba | mlstm | slstm | shared_attn
    ffn: str            # mlp | moe | dense_big | none
    theta: float = 10_000.0


def layer_plan(cfg: ArchConfig):
    """Returns (head: [Slot], period: [Slot], n_groups, tail: [Slot])."""
    def mixer_for(i: int) -> Slot:
        if cfg.ssm and cfg.shared_attn_every:      # zamba2
            if (i + 1) % cfg.shared_attn_every == 0:
                return Slot("shared_attn", "none")
            return Slot("mamba", "none")
        if cfg.ssm and cfg.ssm.slstm_every:        # xlstm
            if (i + 1) % cfg.ssm.slstm_every == 0:
                return Slot("slstm", "none")
            return Slot("mlstm", "none")
        if cfg.ssm:
            return Slot("mamba", "none")
        if cfg.mla:
            ffn = "moe"
            if cfg.moe and i < cfg.moe.first_dense:
                ffn = "dense_big"
            return Slot("mla", ffn)
        if cfg.moe:                                # llama4: MoE every k-th
            step = cfg.moe.interleave_step
            ffn = "moe" if (i % step == step - 1) else "dense_big"
            return Slot("global", ffn, cfg.rope_theta)
        if cfg.local_global_ratio:                 # gemma3
            period = cfg.local_global_ratio + 1
            if (i + 1) % period == 0:
                return Slot("global", "mlp",
                            cfg.rope_theta_global or cfg.rope_theta)
            return Slot("local", "mlp", cfg.rope_theta)
        return Slot("global", "mlp", cfg.rope_theta)

    slots = [mixer_for(i) for i in range(cfg.n_layers)]
    # head: leading slots that break the periodic pattern
    n_head = cfg.moe.first_dense if (cfg.moe and cfg.moe.first_dense) else 0
    head, rest = slots[:n_head], slots[n_head:]
    # find the period of the remaining pattern
    period_len = 1
    for cand in range(1, min(len(rest), 12) + 1):
        if all(rest[i] == rest[i % cand] for i in range(len(rest))
               if i < (len(rest) // cand) * cand):
            period_len = cand
            break
    n_groups = len(rest) // period_len
    tail = rest[n_groups * period_len:]
    period = rest[:period_len]
    return head, period, n_groups, tail


# ============================ slot params =======================================
def _init_slot(key, cfg: ArchConfig, slot: Slot, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if slot.mixer in ("global", "local"):
        p["attn"] = attn.init_gqa(ks[0], cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.head_dim, dtype,
                                  use_bias=cfg.use_bias)
    elif slot.mixer == "mla":
        p["attn"] = attn.init_mla(ks[0], cfg.d_model, cfg.n_heads,
                                  cfg.mla, dtype)
    elif slot.mixer == "mamba":
        p["mamba"] = ssm.init_mamba2(ks[0], cfg.d_model, cfg.ssm, dtype)
    elif slot.mixer == "mlstm":
        p["mlstm"] = ssm.init_mlstm(ks[0], cfg.d_model,
                                    cfg.ssm.mlstm_heads, dtype)
    elif slot.mixer == "slstm":
        p["slstm"] = ssm.init_slstm(ks[0], cfg.d_model,
                                    cfg.ssm.mlstm_heads, dtype)
    elif slot.mixer == "shared_attn":
        pass  # weights live in params["shared"], reused at every occurrence
    if slot.ffn != "none" and slot.mixer != "shared_attn":
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        if slot.ffn == "mlp":
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                                glu=cfg.glu, use_bias=cfg.use_bias)
        elif slot.ffn == "dense_big":
            dff = cfg.moe.dense_d_ff if cfg.moe else cfg.d_ff
            p["mlp"] = init_mlp(ks[1], cfg.d_model, dff, dtype,
                                glu=cfg.glu, use_bias=cfg.use_bias)
        elif slot.ffn == "moe":
            p["moe"] = moe_mod.init_moe(ks[1], cfg.d_model, cfg.moe, dtype)
    return p


def _init_shared_block(key, cfg: ArchConfig, dtype) -> dict:
    """zamba2: one transformer block reused at every shared_attn slot."""
    ks = jax.random.split(key, 3)
    return {
        "norm1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_gqa(ks[0], cfg.d_model, cfg.n_heads,
                              cfg.n_kv_heads, cfg.head_dim, dtype),
        "norm2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, glu=cfg.glu,
                        use_bias=False),
    }


# ============================ train-path blocks ==================================
def _mixer_train(cfg: ArchConfig, slot: Slot, p: dict, shared: Optional[dict],
                 h: Array, positions: Array) -> Array:
    if slot.mixer in ("global", "local"):
        window = cfg.sliding_window if slot.mixer == "local" else None
        return attn.attention_train(
            p["attn"], h, positions, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
            rope_theta=slot.theta, window=window,
            use_qk_norm=cfg.qk_norm)
    if slot.mixer == "mla":
        return attn.mla_train(p["attn"], h, positions, n_heads=cfg.n_heads,
                              mla=cfg.mla)
    if slot.mixer == "mamba":
        return ssm.mamba2_train(p["mamba"], h, cfg.ssm, cfg.d_model)
    if slot.mixer == "mlstm":
        return ssm.mlstm_train(p["mlstm"], h, cfg.ssm.mlstm_heads,
                               cfg.ssm.chunk)
    if slot.mixer == "slstm":
        return ssm.slstm_train(p["slstm"], h, cfg.ssm.mlstm_heads)
    if slot.mixer == "shared_attn":
        y = attn.attention_train(
            shared["attn"], rmsnorm(shared["norm1"], h), positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
            rope_theta=cfg.rope_theta, use_qk_norm=cfg.qk_norm)
        y = y + mlp(shared["mlp"], rmsnorm(shared["norm2"], h + y),
                    act=cfg.act, glu=cfg.glu)
        return y
    raise ValueError(slot.mixer)


def _slot_train(cfg: ArchConfig, slot: Slot, p: dict, shared, h, positions,
                aux):
    if slot.mixer == "shared_attn":
        # zamba2 shared block handles its own norms/residual internally
        return h + _mixer_train(cfg, slot, p, shared, h, positions), aux
    hn = rmsnorm(p["norm1"], h, cfg.norm_eps)
    mix = _mixer_train(cfg, slot, p, shared, hn, positions)
    if cfg.parallel_block and slot.ffn != "none":
        ff = mlp(p["mlp"], hn, act=cfg.act, glu=cfg.glu)
        return h + mix + ff, aux
    h = h + mix
    if slot.ffn == "none":
        return h, aux
    hn2 = rmsnorm(p["norm2"], h, cfg.norm_eps)
    if slot.ffn == "moe":
        ff, a = moe_mod.moe_apply(p["moe"], hn2, cfg.moe)
        aux = aux + a
    else:
        ff = mlp(p["mlp"], hn2, act=cfg.act, glu=cfg.glu)
    return h + ff, aux


def forward_hidden(cfg: ArchConfig, params: dict, h: Array,
                   positions: Array) -> tuple:
    """Run all layers on embedded input h. Returns (h, aux_loss)."""
    head, period, n_groups, tail = layer_plan(cfg)
    shared = params.get("shared")
    aux0 = jnp.zeros((), jnp.float32)

    from .shard_ctx import gather_fsdp

    shared = gather_fsdp(shared) if shared is not None else None
    aux = aux0
    for i, slot in enumerate(head):
        h, aux = _slot_train(cfg, slot, gather_fsdp(params["head"][i]),
                             shared, h, positions, aux)

    def group_body(carry, gp):
        # FSDP: gather THIS group's weights (model-only sharding); freed by
        # XLA after the iteration — ZeRO-3 working set = one group
        gp = gather_fsdp(gp)
        hh, au = carry
        for j, slot in enumerate(period):
            hh, au = _slot_train(cfg, slot, gp[f"slot{j}"], shared, hh,
                                 positions, au)
        return (hh, au), None

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    if n_groups > 0:
        (h, aux), _ = jax.lax.scan(body, (h, aux), params["groups"])

    for i, slot in enumerate(tail):
        h, aux = _slot_train(cfg, slot, gather_fsdp(params["tail"][i]),
                             shared, h, positions, aux)
    return rmsnorm(params["final_norm"], h, cfg.norm_eps), aux


# ============================ decode-path blocks ==================================
def init_slot_cache(cfg: ArchConfig, slot: Slot, batch: int, s_max: int,
                    dtype):
    """Zeros-cache (or ShapeDtypeStruct via jax.eval_shape upstream)."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if slot.mixer == "local":
        w = min(cfg.sliding_window, s_max)
        return {"k": jnp.zeros((batch, w, hkv, dh), dtype),
                "v": jnp.zeros((batch, w, hkv, dh), dtype)}
    if slot.mixer in ("global", "shared_attn"):
        return {"k": jnp.zeros((batch, s_max, hkv, dh), dtype),
                "v": jnp.zeros((batch, s_max, hkv, dh), dtype)}
    if slot.mixer == "mla":
        return {"ckv": jnp.zeros((batch, s_max, cfg.mla.kv_lora_rank), dtype),
                "kpe": jnp.zeros((batch, s_max, cfg.mla.qk_rope_dim), dtype)}
    if slot.mixer == "mamba":
        return jnp.zeros(ssm.mamba2_state_shape(batch, cfg.d_model, cfg.ssm),
                         jnp.float32)
    if slot.mixer == "mlstm":
        return tuple(jnp.zeros(s, jnp.float32) for s in
                     ssm.mlstm_state_shape(batch, cfg.d_model,
                                           cfg.ssm.mlstm_heads))
    if slot.mixer == "slstm":
        return tuple(jnp.zeros(s, jnp.float32) for s in
                     ssm.slstm_state_shape(batch, cfg.d_model,
                                           cfg.ssm.mlstm_heads))
    raise ValueError(slot.mixer)


def _mixer_decode(cfg: ArchConfig, slot: Slot, p: dict, shared, cache,
                  h: Array, positions: Array):
    if slot.mixer in ("global", "local"):
        window = cfg.sliding_window if slot.mixer == "local" else None
        return attn.attention_decode(
            p["attn"], cache, h, positions, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, d_head=cfg.head_dim, rope_theta=slot.theta,
            window=window, use_qk_norm=cfg.qk_norm)
    if slot.mixer == "mla":
        return attn.mla_decode(p["attn"], cache, h, positions,
                               n_heads=cfg.n_heads, mla=cfg.mla)
    if slot.mixer == "mamba":
        return ssm.mamba2_decode(p["mamba"], cache, h, cfg.ssm, cfg.d_model)
    if slot.mixer == "mlstm":
        return ssm.mlstm_decode(p["mlstm"], cache, h, cfg.ssm.mlstm_heads)
    if slot.mixer == "slstm":
        return ssm.slstm_decode(p["slstm"], cache, h, cfg.ssm.mlstm_heads)
    if slot.mixer == "shared_attn":
        hn = rmsnorm(shared["norm1"], h)
        y, cache = attn.attention_decode(
            shared["attn"], cache, hn, positions, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
            rope_theta=cfg.rope_theta, use_qk_norm=cfg.qk_norm)
        y = y + mlp(shared["mlp"], rmsnorm(shared["norm2"], h + y),
                    act=cfg.act, glu=cfg.glu)
        return y, cache
    raise ValueError(slot.mixer)


def _slot_decode(cfg: ArchConfig, slot: Slot, p: dict, shared, cache, h,
                 positions):
    if slot.mixer == "shared_attn":
        y, cache = _mixer_decode(cfg, slot, p, shared, cache, h, positions)
        return h + y, cache
    hn = rmsnorm(p["norm1"], h, cfg.norm_eps)
    mix, cache = _mixer_decode(cfg, slot, p, shared, cache, hn, positions)
    if cfg.parallel_block and slot.ffn != "none":
        return h + mix + mlp(p["mlp"], hn, act=cfg.act, glu=cfg.glu), cache
    h = h + mix
    if slot.ffn == "none":
        return h, cache
    hn2 = rmsnorm(p["norm2"], h, cfg.norm_eps)
    if slot.ffn == "moe":
        ff, _ = moe_mod.moe_apply(p["moe"], hn2, cfg.moe)
    else:
        ff = mlp(p["mlp"], hn2, act=cfg.act, glu=cfg.glu)
    return h + ff, cache


def decode_hidden(cfg: ArchConfig, params: dict, cache: dict, h: Array,
                  positions: Array) -> tuple:
    from .shard_ctx import gather_fsdp

    head, period, n_groups, tail = layer_plan(cfg)
    shared = params.get("shared")
    shared = gather_fsdp(shared) if shared is not None else None

    for i, slot in enumerate(head):
        h, cache["head"][i] = _slot_decode(
            cfg, slot, gather_fsdp(params["head"][i]), shared,
            cache["head"][i], h, positions)

    def group_body(hh, xs):
        gp, gc = xs
        gp = gather_fsdp(gp)
        new_c = {}
        for j, slot in enumerate(period):
            hh, new_c[f"slot{j}"] = _slot_decode(
                cfg, slot, gp[f"slot{j}"], shared, gc[f"slot{j}"], hh,
                positions)
        return hh, new_c

    if n_groups > 0:
        h, cache["groups"] = jax.lax.scan(
            group_body, h, (params["groups"], cache["groups"]))

    for i, slot in enumerate(tail):
        h, cache["tail"][i] = _slot_decode(
            cfg, slot, gather_fsdp(params["tail"][i]), shared,
            cache["tail"][i], h, positions)
    return rmsnorm(params["final_norm"], h, cfg.norm_eps), cache
