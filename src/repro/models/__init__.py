"""Model zoo substrate: the 10 assigned architectures as pure-pytree JAX
models (no flax). See model.py:build_model for the public entry point."""
from .model import build_model, Model

__all__ = ["build_model", "Model"]
