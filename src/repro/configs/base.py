"""Architecture configuration schema.

Every assigned architecture is a frozen ``ArchConfig``; ``reduced()`` derives
the CPU-smoke-test variant (same family/topology, tiny widths). Input shapes
(the 4 assigned shape cells) live in ``SHAPES``; ``input_specs`` builds
ShapeDtypeStruct stand-ins for the dry-run (never allocates).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0           # per-expert hidden size
    capacity_factor: float = 1.25
    router_group_size: int = 4096  # tokens per dispatch group (scan chunk)
    interleave_step: int = 1       # MoE every k-th layer (1 = every layer)
    dense_d_ff: int = 0            # d_ff of the interleaved dense layers
    first_dense: int = 0           # leading dense layers (deepseek-v2: 1)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    slstm_every: int = 0           # xLSTM: every k-th block is sLSTM
    mlstm_heads: int = 4


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int = 6
    n_frames: int = 1500           # whisper: encoder positions (stub frontend)
    max_target: int = 448          # whisper: decoder context limit


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 -> d_model // n_heads
    # block pattern
    local_global_ratio: int = 0    # gemma3: k local per 1 global (0 = all global)
    sliding_window: int = 1024
    shared_attn_every: int = 0     # zamba2: shared attn block every k slots
    use_bias: bool = False
    parallel_block: bool = False   # command-r: attn & mlp in parallel
    qk_norm: bool = False
    act: str = "silu"              # silu (GLU) | gelu (plain MLP)
    glu: bool = True
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # gemma3: different theta on global layers
    tie_embeddings: bool = False
    scale_embed: bool = False       # gemma: h *= sqrt(d_model)
    norm_eps: float = 1e-6
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None  # vision_stub | audio_stub
    n_frontend_tokens: int = 0      # vlm: patch tokens prepended
    # numerics / execution
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512           # xent sequence-chunk (big-vocab safe)
    # which shape cells apply (DESIGN.md §4): e.g. skip long_500k for
    # pure-full-attention archs
    shape_cells: Tuple[str, ...] = (
        "train_4k", "prefill_32k", "decode_32k",
    )
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def reduced(self) -> "ArchConfig":
        """Tiny same-topology variant for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=16,
            d_ff=128,
            vocab_size=512,
            sliding_window=8,
            param_dtype="float32",
            act_dtype="float32",
            loss_chunk=16,
            remat=False,
        )
        if self.local_global_ratio:
            kw["local_global_ratio"] = 2
            kw["n_layers"] = 7  # 2 groups of (2 local + 1 global) + 1 tail
        if self.shared_attn_every:
            kw["shared_attn_every"] = 3
            kw["n_layers"] = 6
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=8,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64, dense_d_ff=128 if self.moe.dense_d_ff else 0,
                router_group_size=64,
                # drop-free at smoke scale so decode (per-token capacity,
                # never drops) matches teacher forcing exactly
                capacity_factor=8.0,
            )
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                  qk_nope_dim=16, qk_rope_dim=8, v_dim=16)
            kw["d_head"] = 0
        if self.ssm:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=8, head_dim=8, chunk=16)
        if self.encoder:
            kw["encoder"] = EncoderConfig(n_layers=2, n_frames=24,
                                          max_target=32)
        if self.frontend == "vision_stub":
            kw["n_frontend_tokens"] = 8
        return dataclasses.replace(self, name=self.name + "-smoke", **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def input_specs(cfg: ArchConfig, cell: ShapeCell, *, for_train: bool = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    decode cells describe ONE serve_step: a single new token per sequence
    with a seq_len-deep KV cache (the cache spec itself is built by the
    model's init_cache_spec, launch/dryrun.py wires them together).
    """
    s, b = cell.seq_len, cell.global_batch
    i32 = jnp.int32
    if cfg.encoder is not None:
        if cell.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "positions": jax.ShapeDtypeStruct((b,), i32),
            }
        # whisper: decoder length is capped (DESIGN.md §4 adaptation)
        dec = min(s, cfg.encoder.max_target)
        specs = {
            "enc_embeds": jax.ShapeDtypeStruct(
                (b, cfg.encoder.n_frames, cfg.d_model), jnp.dtype(cfg.act_dtype)),
            "tokens": jax.ShapeDtypeStruct((b, dec), i32),
            "labels": jax.ShapeDtypeStruct((b, dec), i32),
        }
        return specs
    if cell.kind in ("train", "prefill"):
        s_text = s
        specs = {}
        if cfg.frontend == "vision_stub":
            # patch tokens count toward the cell's sequence length
            s_text = s - cfg.n_frontend_tokens
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.act_dtype))
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s_text), i32)
        if cell.kind == "prefill":
            specs.pop("labels")
        return specs
    # decode: one token per sequence + positions; cache comes separately
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "positions": jax.ShapeDtypeStruct((b,), i32),
    }
