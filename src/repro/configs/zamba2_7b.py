"""Zamba2-7B — hybrid Mamba2 backbone + shared attention block
[arXiv:2411.15242; unverified].

81 layer-slots, d_model=3584, ssm_state=64; every 3rd slot applies the
SHARED attention+MLP block (one set of weights reused — Zamba's signature
parameter sharing; we use a 2:1 mamba:shared pattern, see DESIGN.md §6),
32H (kv 32), shared-block d_ff=14336. O(1) mamba state + ring-buffer
shared-attn cache => long_500k runs.
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32_000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, chunk=256),
    shared_attn_every=3,
    shape_cells=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    notes="hybrid: mamba2 + shared attention block (weights reused)",
)
