"""Whisper-base — enc-dec audio [arXiv:2212.04356; unverified].

6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865. The conv frontend is
a STUB per the assignment: input_specs provides 1500 precomputed frame
embeddings. ADAPTATION (DESIGN.md §4): whisper's decoder context is 448
tokens, so the 4k/32k sequence lengths are capped at 448 on the decoder
side; decode cells run with the (448-deep self + 1500-deep cross) cache;
long_500k skipped.
"""
from .base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    use_bias=True,
    act="gelu",
    glu=False,
    encoder=EncoderConfig(n_layers=6, n_frames=1500, max_target=448),
    frontend="audio_stub",
    shape_cells=("train_4k", "prefill_32k", "decode_32k"),
    notes="conv frontend stubbed; decoder ctx capped at 448; "
          "long_500k skipped",
)
