"""xLSTM-1.3B — recurrent (mLSTM matrix memory + sLSTM) [arXiv:2405.04517].

48 blocks, d_model=2048, 4 heads, vocab=50304, d_ff=0 (blocks carry their
own gating projections). 7:1 mLSTM:sLSTM ratio (every 8th block sLSTM).
O(1) decode state => long_500k runs.
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    ssm=SSMConfig(slstm_every=8, mlstm_heads=4, chunk=256),
    shape_cells=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    notes="recurrent: constant-size decode state",
)
