"""InternVL2-2B — VLM: InternViT frontend (STUB) + InternLM2-1.8B backbone
[arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. Per the assignment,
the vision frontend is a stub: input_specs provides precomputed patch
embeddings (256 tokens) that a linear projector maps into the LM. Full
attention backbone => long_500k skipped.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    frontend="vision_stub",
    n_frontend_tokens=256,
    rope_theta=1_000_000.0,
    shape_cells=("train_4k", "prefill_32k", "decode_32k"),
    notes="vision frontend stubbed (patch embeddings as inputs); "
          "long_500k skipped: full attention",
)
