"""Gemma3-27B — dense, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family; unverified].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144. Sliding window
1024 on local layers; global layers use rope_theta=1e6. qk-norm; tied
embeddings with sqrt(d) input scaling. Sub-quadratic (5/6 of layers) =>
long_500k RUNS (global-layer KV is sequence-sharded; DESIGN.md §4).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,                 # 10 groups of (5 local + 1 global) + 2 tail
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab_size=262_144,
    local_global_ratio=5,
    sliding_window=1024,
    qk_norm=True,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
    scale_embed=True,
    shape_cells=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    notes="long_500k runs: 5/6 layers sliding-window",
)
