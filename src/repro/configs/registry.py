"""``--arch`` registry: the 10 assigned architectures + the paper's own
ICR configurations (DESIGN.md §4)."""
from __future__ import annotations

import dataclasses

from .base import ArchConfig
from .starcoder2_15b import CONFIG as starcoder2_15b
from .gemma3_27b import CONFIG as gemma3_27b
from .command_r_35b import CONFIG as command_r_35b
from .gemma3_4b import CONFIG as gemma3_4b
from .internvl2_2b import CONFIG as internvl2_2b
from .xlstm_1_3b import CONFIG as xlstm_1_3b
from .deepseek_v2_236b import CONFIG as deepseek_v2_236b
from .llama4_maverick_400b import CONFIG as llama4_maverick_400b
from .whisper_base import CONFIG as whisper_base
from .zamba2_7b import CONFIG as zamba2_7b

ARCHS = {
    c.name: c for c in (
        starcoder2_15b, gemma3_27b, command_r_35b, gemma3_4b,
        internvl2_2b, xlstm_1_3b, deepseek_v2_236b, llama4_maverick_400b,
        whisper_base, zamba2_7b,
    )
}


# -- the paper's own configurations (ICR models; see repro/core) ---------------
@dataclasses.dataclass(frozen=True)
class ICRArchConfig:
    """ICR 'architecture': chart + kernel selection (paper §5 / §6)."""

    name: str
    kind: str                    # log1d | dust3d
    shape0: tuple
    n_levels: int
    n_csz: int = 5
    n_fsz: int = 4
    notes: str = ""

    def build(self):
        from repro.core import ICR, log_chart, matern32
        from repro.core.charts import galactic_dust_chart
        if self.kind == "log1d":
            chart = log_chart(self.shape0[0], self.n_levels,
                              n_csz=self.n_csz, n_fsz=self.n_fsz,
                              delta0=0.02, boundary="reflect")
        else:
            chart = galactic_dust_chart(self.shape0, self.n_levels,
                                        n_csz=self.n_csz, n_fsz=self.n_fsz)
        return ICR(chart=chart, kernel=matern32.with_defaults(rho=1.0))


ICR_ARCHS = {
    # the paper's §5 experiment geometry, scaled to production
    "icr-log1d": ICRArchConfig(
        name="icr-log1d", kind="log1d", shape0=(1024,), n_levels=17,
        notes="1-D log chart; 1024 * 2^17 ≈ 134M points"),
    # the 122-billion-DOF Galactic dust application (paper §6, ref [24]);
    # wide angular axis 1 so the spatial ring shards early (block >= b+1)
    "icr-dust122b": ICRArchConfig(
        name="icr-dust122b", kind="dust3d", shape0=(32, 128, 12),
        n_levels=7, notes="(32,128,12) * 2^(3*7) ≈ 103B points; wide "
        "angular axis => the ring shards from level 3 (pod) / 4 (multipod)"
        " and the replicated prologue stays <1 GB"),
    # a pod-scale variant used for the perf hillclimb
    "icr-dust-pod": ICRArchConfig(
        name="icr-dust-pod", kind="dust3d", shape0=(16, 128, 16),
        n_levels=5, notes="≈1.1B points; angular axis 1 shards over 512"),
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)} "
            f"+ ICR: {sorted(ICR_ARCHS)}")
    return ARCHS[name]


def arch_names():
    return sorted(ARCHS)
