"""Gemma3-4B — dense, 5:1 local:global [hf:google/gemma-3-1b-pt family].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,                 # 5 groups of 6 + 4 tail local
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10240,
    vocab_size=262_144,
    local_global_ratio=5,
    sliding_window=1024,
    qk_norm=True,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
    scale_embed=True,
    shape_cells=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    notes="long_500k runs: 5/6 layers sliding-window",
)
