"""StarCoder2-15B — dense GQA decoder [arXiv:2402.19173; hf].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152; RoPE; biased
projections and plain-GELU MLP per the HF config. Pure full attention =>
long_500k skipped (DESIGN.md §4).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    use_bias=True,
    act="gelu",
    glu=False,
    rope_theta=100_000.0,
    shape_cells=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: pure full attention",
)
