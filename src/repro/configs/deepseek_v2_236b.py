"""DeepSeek-V2 236B — MLA + fine-grained MoE [arXiv:2405.04434; hf].

60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536, rope 64, nope 128,
v 128); MoE: 160 routed experts top-6 + 2 shared, expert d_ff=1536; first
layer dense (d_ff 12288). Full attention => long_500k skipped. MLA latent
cache makes decode_32k HBM-cheap (DESIGN.md §5).
"""
from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab_size=102_400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536,
                  dense_d_ff=12288, first_dense=1, router_group_size=4096),
    shape_cells=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: full attention (MLA)",
)
