from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig, SHAPES, \
    ShapeCell, input_specs
from .registry import ARCHS, ICR_ARCHS, arch_names, get_arch

__all__ = [
    "ArchConfig", "MLAConfig", "MoEConfig", "SSMConfig", "SHAPES",
    "ShapeCell", "input_specs", "ARCHS", "ICR_ARCHS", "arch_names",
    "get_arch",
]
