"""Command-R 35B — dense GQA, parallel attn+MLP block, no biases
[hf:CohereForAI/c4ai-command-r-v01; unverified].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000. Pure full
attention => long_500k skipped.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256_000,
    parallel_block=True,
    use_bias=False,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    shape_cells=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: pure full attention",
)
