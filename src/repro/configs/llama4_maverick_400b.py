"""Llama-4 Maverick 400B-A17B — interleaved MoE
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified].

48L d_model=5120 40H (GQA kv=8), MoE on every 2nd layer: 128 routed
experts top-1 + 1 shared (expert d_ff=8192), dense layers d_ff=16384.
~400B total / ~17B active. We model the text tower (early-fusion vision
omitted per assignment). Full attention here => long_500k skipped.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    moe=MoEConfig(n_experts=128, top_k=1, n_shared=1, d_ff_expert=8192,
                  interleave_step=2, dense_d_ff=16384,
                  router_group_size=4096),
    rope_theta=500_000.0,
    shape_cells=("train_4k", "prefill_32k", "decode_32k"),
    notes="long_500k skipped: full attention; text tower only",
)
