"""Self-check driver: sharded ICR == unsharded ICR, bit-level (up to f32).

Run with multiple host devices, e.g.::

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch._dist_icr_check

Used by tests/test_distributed_icr.py (subprocess) and by hand when
bringing up a new mesh. Prints one line per case: ``case max_abs_diff``.
Exit code 0 iff all diffs < 1e-5.
"""
import os
import sys

if __name__ == "__main__" and "--xla" not in sys.argv:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402


def main() -> int:
    from repro.core import ICR, matern32, regular_chart, log_chart
    from repro.core.charts import galactic_dust_chart
    from repro.core.distributed import DistributedICR
    from repro.compat import use_mesh
    from repro.launch.mesh import make_mesh

    n_dev = len(jax.devices())
    mesh1d = make_mesh((n_dev,), ("space",))
    mesh2d = make_mesh((2, n_dev // 2), ("pod", "space"))

    cases = []

    # 1-D stationary (regular chart)
    cases.append((
        "1d_regular",
        ICR(chart=regular_chart(32, 4, boundary="reflect"),
            kernel=matern32.with_defaults(rho=16.0)),
        mesh1d, ("space",), 0,
    ))
    # 1-D charted (log chart, per-family matrices)
    cases.append((
        "1d_log_charted",
        ICR(chart=log_chart(32, 4, n_csz=5, n_fsz=4, delta0=0.01,
                            boundary="reflect"),
            kernel=matern32.with_defaults(rho=1.0)),
        mesh1d, ("space",), 0,
    ))
    # multi-axis ring spanning two mesh axes (the multi-pod layout)
    cases.append((
        "1d_multipod_ring",
        ICR(chart=regular_chart(64, 3, boundary="reflect"),
            kernel=matern32.with_defaults(rho=20.0)),
        mesh2d, ("pod", "space"), 0,
    ))
    # 3-D dust chart: shard an invariant angular axis
    cases.append((
        "3d_dust_angular_shard",
        ICR(chart=galactic_dust_chart((6, 32, 16), 2),
            kernel=matern32.with_defaults(rho=0.5)),
        mesh1d, ("space",), 1,
    ))
    # interior compute through dispatch.refine (fused 1-D kernels inside
    # shard_map) == the unsharded fused path — ISSUE 4 satellite
    cases.append((
        "1d_regular_pallas",
        ICR(chart=regular_chart(32, 4, boundary="reflect"),
            kernel=matern32.with_defaults(rho=16.0), use_pallas=True),
        mesh1d, ("space",), 0,
    ))
    cases.append((
        "1d_log_charted_pallas",
        ICR(chart=log_chart(32, 4, n_csz=5, n_fsz=4, delta0=0.01,
                            boundary="reflect"),
            kernel=matern32.with_defaults(rho=1.0), use_pallas=True),
        mesh1d, ("space",), 0,
    ))

    ok = True
    for name, icr, mesh, axes, shard_axis in cases:
        dist = DistributedICR(icr=icr, mesh=mesh, axis_names=axes,
                              shard_axis=shard_axis)
        key = jax.random.PRNGKey(42)
        with use_mesh(mesh):
            xi = dist.init_xi(key)
            mats = dist.matrices()
            sharded = jax.jit(dist.apply_sqrt)(mats, xi)
        # unsharded reference on the same xi values
        mats_ref = icr.matrices()
        fsz = icr.chart.n_fsz**icr.chart.ndim
        xi_ref = [np.asarray(xi[0])] + [
            np.asarray(x).reshape(-1, fsz) for x in xi[1:]
        ]
        ref = icr.apply_sqrt(mats_ref, [jnp.asarray(x) for x in xi_ref])
        diff = float(np.abs(np.asarray(sharded) - np.asarray(ref)).max())
        scale = float(np.abs(np.asarray(ref)).max())
        rel = diff / max(scale, 1e-30)
        print(f"{name} max_abs_diff={diff:.3e} rel={rel:.3e}")
        ok &= rel < 1e-5
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
