"""Production device meshes (DESIGN.md §8).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run driver must be able to set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax
initializes.

Mesh layouts (TPU v5e pod = 16x16 = 256 chips):

* LM configs:   (data=16, model=16); multi-pod (pod=2, data=16, model=16).
  The ``pod`` axis extends data parallelism across the DCN; gradient
  all-reduce over ("pod", "data") is hierarchical (ICI first, DCN once).
* ICR configs:  the same meshes, re-labelled by the caller: the spatial ring
  is ("data", "model") flattened (single pod) or ("pod", "data", "model")
  (multi-pod) — halo ppermute traffic crosses the DCN on exactly two ring
  edges (core/distributed.py).
"""
from __future__ import annotations


import jax
import numpy as np
from jax.sharding import Mesh

from repro import compat


def _make(shape, axes) -> Mesh:
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"mesh {dict(zip(axes, shape))} needs {n} devices but only "
            f"{len(jax.devices())} are visible; the dry-run driver must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax"
        )
    # axis_types is resolved by repro.compat: Auto on jax with AxisType,
    # omitted entirely on 0.4.x.
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh with Auto axis types (tests, small CPU runs)."""
    return _make(tuple(shape), tuple(axes))


def make_host_mesh(model: int | None = None) -> Mesh:
    """Best-effort mesh over whatever devices exist (CPU tests/examples)."""
    n = len(jax.devices())
    model = model or 1
    return _make((n // model, model), ("data", "model"))


# -- hardware constants (TPU v5e, per chip) -----------------------------------
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (~per-device usable bisection)
DCN_BW = 25e9                # B/s per host, cross-pod
HBM_BYTES = 16 * 1024**3     # 16 GiB
VMEM_BYTES = 128 * 1024**2   # ~128 MiB vector memory
