"""Step builders: jitted+sharded train / prefill / serve steps for any arch.

Shared by the dry-run (AOT lower+compile on ShapeDtypeStructs) and the real
drivers (train.py / serve.py). All sharding policy lives in
repro.distributed.sharding; optimizer selection follows DESIGN.md §5
(AdamW < 100B params, Adafactor above — factored state keeps the 236B/400B
MoE configs inside one pod's HBM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed.sharding import (
    batch_spec,
    cache_specs,
    opt_state_specs,
    param_specs,
    shardings_for,
)
from repro.models import build_model
from repro.optim import adafactor, adamw, linear_warmup_cosine

ADAFACTOR_THRESHOLD = 100e9


def data_model_axes(mesh: Mesh):
    axes = dict(mesh.shape)
    data = ("pod", "data") if "pod" in axes else ("data",)
    return data, ("model",)


def active_param_count(model) -> int:
    """Active-per-token parameters (MoE: top_k/E of routed experts)."""
    cfg = model.cfg
    spec = model.params_spec()
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(spec)[0]:
        names = [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]
        n = float(np.prod(leaf.shape))
        if cfg.moe and "moe" in names and names[-1] in ("gate", "up",
                                                        "down"):
            n *= cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return int(total)


def select_optimizer(model, total_steps: int = 10_000):
    n = model.param_count()
    # cap warmup by the run length: a short run (tests, smoke examples) must
    # reach a useful lr, not spend every step inside a 200-step ramp
    warmup = min(200, max(1, total_steps // 10))
    sched = linear_warmup_cosine(3e-4, warmup, total_steps)
    if n > ADAFACTOR_THRESHOLD:
        return adafactor(sched), "adafactor"
    return adamw(sched, weight_decay=0.1), "adamw"


@dataclasses.dataclass
class TrainStep:
    fn: Callable                 # jitted (params, opt_state, batch) -> ...
    params_sh: Any
    opt_sh: Any
    batch_sh: Any
    opt_name: str
    model: Any
    optimizer: Any

    def init_state(self, key):
        params = jax.jit(
            self.model.init_params, out_shardings=self.params_sh)(key)
        opt_state = jax.jit(
            self.optimizer.init, out_shardings=self.opt_sh)(params)
        return params, opt_state


def choose_accum(model, cell: ShapeCell, mesh: Mesh) -> int:
    """Gradient-accumulation factor targeting ~10 GB/device of activation
    pressure. Peak model (calibrated against XLA buffer dumps on this
    backend, see EXPERIMENTS.md §Perf):

        peak ≈ carries + backward working set
             = n_groups·b_loc·S·D·2B  +  ~9 f32 copies ·
               layers_per_group·b_loc·S·D·4B

    Both terms scale 1/accum, so accum = ceil(peak / 10 GB) (pow2, capped
    so the microbatch still divides the data axes)."""
    cfg = model.cfg
    data_axes, _ = data_model_axes(mesh)
    dsz = int(np.prod([mesh.shape[a] for a in data_axes]))
    from repro.models.transformer import layer_plan
    if cfg.encoder is not None:
        # whisper: encoder self-attention scores (B_loc, H, F, F) f32 are
        # the peak (1500 frames don't chunk evenly -> single-chunk path);
        # ~16 co-live f32 copies across fwd+bwd per the buffer dumps
        b_loc = max(cell.global_batch // dsz, 1)
        fr = cfg.encoder.n_frames
        peak = 16 * b_loc * cfg.n_heads * fr * fr * 4
        accum = 1
        while peak / accum > 10e9 and accum < 16:
            accum *= 2
        while accum > 1 and (cell.global_batch // accum) % dsz != 0:
            accum //= 2
        return accum
    _, period, n_groups, _ = layer_plan(cfg)
    b_loc = max(cell.global_batch // dsz, 1)
    tok_bytes = b_loc * cell.seq_len * cfg.d_model
    # 6B/elem: bf16 saved carries + an f32 copy XLA hoists for the backward
    # (buffer dumps: command-r shows both stacks resident)
    carries = n_groups * tok_bytes * 6
    working = 9 * len(period) * tok_bytes * 4
    peak = carries + working
    accum = 1
    while peak / accum > 10e9 and accum < 16:
        accum *= 2
    while accum > 1 and (cell.global_batch // accum) % dsz != 0:
        accum //= 2
    return accum


def make_train_step(cfg: ArchConfig, mesh: Mesh, *, donate: bool = True,
                    accum: int = 1, total_steps: int = 10_000) -> TrainStep:
    from repro.models import shard_ctx

    model = build_model(cfg)
    data_axes, model_axes = data_model_axes(mesh)
    shard_ctx.set_axes(mesh, data_axes, model_axes)
    opt, opt_name = select_optimizer(model, total_steps=total_steps)

    p_spec = model.params_spec()
    p_specs = param_specs(p_spec, mesh, data_axes, model_axes)
    o_spec = jax.eval_shape(opt.init, p_spec)
    o_specs = opt_state_specs(o_spec, mesh, data_axes, model_axes)

    def micro_spec(x):
        # (A, B/A, ...) microbatch layout: batch dim stays on data axes
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(
                mesh, P(None, data_axes, *([None] * (x.ndim - 2)))))

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: micro_spec(
                    x.reshape(accum, x.shape[0] // accum, *x.shape[1:])),
                batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics}

    params_sh = shardings_for(p_specs, mesh)
    opt_sh = shardings_for(o_specs, mesh)

    def jit_for(batch_tree):
        b_specs = batch_spec(batch_tree, mesh, data_axes)
        batch_sh = shardings_for(b_specs, mesh)
        return jax.jit(
            train_step,
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh,
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1) if donate else (),
        ), batch_sh

    return TrainStep(fn=jit_for, params_sh=params_sh, opt_sh=opt_sh,
                     batch_sh=None, opt_name=opt_name, model=model,
                     optimizer=opt)


def make_prefill_step(cfg: ArchConfig, mesh: Mesh):
    from repro.models import shard_ctx

    model = build_model(cfg)
    data_axes, model_axes = data_model_axes(mesh)
    shard_ctx.set_axes(mesh, data_axes, model_axes)
    p_specs = param_specs(model.params_spec(), mesh, data_axes, model_axes)
    params_sh = shardings_for(p_specs, mesh)

    def jit_for(batch_tree):
        b_specs = batch_spec(batch_tree, mesh, data_axes)
        batch_sh = shardings_for(b_specs, mesh)
        # logits are sliced to the raw (unpadded) vocab -> replicate dim 1
        out_sh = NamedSharding(mesh, P(data_axes, None))
        return jax.jit(model.prefill_fn, in_shardings=(params_sh, batch_sh),
                       out_shardings=out_sh), batch_sh

    return model, params_sh, jit_for


def make_serve_step(cfg: ArchConfig, mesh: Mesh, batch: int, s_max: int,
                    *, donate: bool = True):
    from repro.models import shard_ctx

    model = build_model(cfg)
    data_axes, model_axes = data_model_axes(mesh)
    shard_ctx.set_axes(mesh, data_axes, model_axes)
    p_specs = param_specs(model.params_spec(), mesh, data_axes, model_axes)
    params_sh = shardings_for(p_specs, mesh)
    c_spec = model.cache_spec(batch, s_max)
    c_specs = cache_specs(c_spec, mesh, data_axes, model_axes)
    cache_sh = shardings_for(c_specs, mesh)
    dsz = int(np.prod([mesh.shape[a] for a in data_axes]))
    bdim = data_axes if batch % dsz == 0 and batch >= dsz else None
    tok_sh = NamedSharding(mesh, P(bdim, None))
    pos_sh = NamedSharding(mesh, P(bdim))
    logits_sh = NamedSharding(mesh, P(bdim, None))

    step = jax.jit(
        model.serve_step,
        in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,) if donate else (),
    )
    return model, step, params_sh, cache_sh, c_spec
