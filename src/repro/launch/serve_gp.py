"""Batched GP posterior field server (DESIGN.md §12).

The `launch.serve` BatchedServer pattern applied to the GP side of the
repo: clients submit posterior-sample and predictive-moment requests
against a fitted ICR posterior (`core.vi.Posterior` — a MAP ξ̂ or ADVI
`(mean, log_std)` export), and the server

  * packs heterogeneous requests into fixed-size **sample slabs** executed
    through `ICR.apply_sqrt_batch` — the native §10 sample-block path, so
    the refinement matrices are fetched once per VMEM tile for the whole
    slab and the work is bandwidth-bound on the field, not the matrices;
  * computes predictive mean/std by **streaming Welford accumulation**
    over slabs (Chan parallel merge per slab — no request ever needs its
    full MC budget resident at once);
  * never recompiles or rebuilds structure for repeat traffic: the
    executable cache is keyed on (chart geometry, θ, dtype policy) and
    holds the matrices (`ICR.matrices_cached`), the routing decision
    (`dispatch.plan_cached`) and the jitted slab executable.

Per-row excitation noise is keyed by (request seed, row index) only —
`fold_in(PRNGKey(seed), row)` — so a request's draws are independent of
how they were packed: a packed heterogeneous batch reproduces the
per-request loop exactly (the slab-parity test pins this at 1e-5).

Run:  PYTHONPATH=src python -m repro.launch.serve_gp [--scenario dust]
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.vi import Posterior
from repro.kernels import dispatch


@dataclasses.dataclass
class GPRequest:
    """One client request against the served posterior.

    kind="sample": return ``n`` posterior field draws (in ``fields``).
    kind="moments": MC predictive mean/std over an ``n``-draw budget
    (in ``mean``/``std``; the draws themselves are never retained).
    """

    kind: str
    n: int
    seed: int = 0
    done: bool = False
    error: Optional[str] = None
    fields: list = dataclasses.field(default_factory=list)
    mean: Optional[np.ndarray] = None
    std: Optional[np.ndarray] = None
    # internal: rows drawn so far (the per-request eps stream index) and
    # the streaming Welford state (count, running mean, running M2)
    _next_row: int = 0
    _wcount: int = 0
    _wmean: Optional[np.ndarray] = None
    _wm2: Optional[np.ndarray] = None


def _canonical_key(x) -> str:
    """Deterministic printable form of an executable-cache key component.

    ``repr`` alone is not reproducible across processes: charts carry
    ``phi_inv`` function objects (repr embeds a memory address) and θ
    fingerprints carry raw bytes. Functions canonicalize to their
    qualified name, bytes to a content hash, dataclasses (Chart,
    DtypePolicy) recurse over their fields — so two servers built from
    equal configs print (and digest) identically in any process.
    """
    if isinstance(x, tuple):
        return "(" + ",".join(_canonical_key(v) for v in x) + ")"
    if isinstance(x, bytes):
        return "bytes<sha256:" + hashlib.sha256(x).hexdigest()[:12] + ">"
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        fields = ",".join(f"{f.name}={_canonical_key(getattr(x, f.name))}"
                          for f in dataclasses.fields(x))
        return f"{type(x).__name__}({fields})"
    if callable(x) and hasattr(x, "__qualname__"):
        return f"fn:{getattr(x, '__module__', '?')}.{x.__qualname__}"
    return repr(x)


def _welford_merge(count, m, m2, batch: np.ndarray):
    """Chan et al. parallel merge of a k-sample batch into (count, m, m2)."""
    k = batch.shape[0]
    bm = batch.mean(axis=0)
    bm2 = ((batch - bm) ** 2).sum(axis=0)
    if count == 0:
        return k, bm, bm2
    tot = count + k
    delta = bm - m
    m = m + delta * (k / tot)
    m2 = m2 + bm2 + delta**2 * (count * k / tot)
    return tot, m, m2


class GPFieldServer:
    """Continuous-batching server over one (swappable) fitted Posterior.

    ``slab`` is the fixed sample-slab height: every step draws exactly one
    (slab, *final_shape) batch of posterior fields through one jitted
    executable — static shapes, so repeat traffic never retraces. Rows are
    assigned to queued requests greedily in queue order; short steps pad
    with throwaway rows (their keys index past every request's stream).
    """

    def __init__(self, posterior: Posterior, slab: int = 8,
                 max_cached: int = 8):
        self.slab = int(slab)
        # (key -> entry) executable cache, LRU-bounded: a long-running
        # server periodically re-fit at new θ must not pin one matrices
        # set + compiled executable per historical θ forever
        self.max_cached = int(max_cached)
        self._exec: dict = {}
        self.cache_hits = 0
        self.cache_misses = 0
        self.slabs_run = 0
        self.rows_served = 0      # non-padding rows (posterior draws)
        self.fields_delivered = 0  # arrays handed back to clients
        self.posterior = None
        self.set_posterior(posterior)

    # -- executable cache ------------------------------------------------------
    def _cache_key(self, post: Posterior):
        icr = post.icr
        tkey = icr._theta_key(post.theta)
        if tkey is None:
            raise ValueError("serving requires concrete (untraced) theta")
        # the kernel must be fingerprinted too: θ is often baked into the
        # kernel's defaults (with_defaults) with theta=None, and two such
        # posteriors must not collide on an equal chart. Kernel.default_theta
        # is a dict (unhashable), so flatten it.
        kern = icr.kernel
        kkey = (kern.fn, kern.name,
                tuple(sorted((k, float(v))
                             for k, v in kern.default_theta.items())))
        # routing flags and the effective backend belong in the key: an
        # equal-chart/θ/policy ICR with a different executor config (or a
        # REPRO_BACKEND flip) must not be served the cached executable
        return (icr.chart, kkey, icr.jitter, tkey, icr.policy,
                icr.use_pallas, icr.use_pyramid,
                dispatch.select_backend(), self.slab)

    def set_posterior(self, post: Posterior):
        """Point the server at a (new) fit. Same (chart geometry, θ, dtype
        policy) ⇒ cache hit: the matrices, plan and compiled executable are
        reused even across re-fits (only the q-parameters swap); anything
        else is a miss and builds a fresh entry."""
        key = self._cache_key(post)
        entry = self._exec.pop(key, None)  # re-insert below: LRU order
        if entry is not None:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
            entry = self._build(post)
        self._exec[key] = entry
        while len(self._exec) > self.max_cached:
            self._exec.pop(next(iter(self._exec)))  # evict least recent
        # q-parameters ride as jit arguments (same shapes ⇒ no retrace)
        entry["mean"] = list(post.mean)
        entry["std"] = post.std()
        self.posterior = post
        self._entry = entry
        return entry

    def _build(self, post: Posterior) -> dict:
        icr = post.icr
        mats = icr.matrices_cached(post.theta)
        # model what this ICR actually executes: no pyramid overlay when
        # it is disabled, no axis factors without the fused path
        plan = dispatch.plan_cached(
            icr.chart, samples=self.slab, dtype=icr.policy.storage_dtype,
            pyramid=icr.use_pallas and icr.use_pyramid,
            have_axis_mats=icr.use_pallas and icr.chart.ndim > 1)
        shapes = icr.xi_shapes()

        def slab_fn(mats, mean, std, seeds, rows):
            def draw(seed, row):
                k = jax.random.fold_in(jax.random.PRNGKey(seed), row)
                ks = jax.random.split(k, len(shapes))
                return [
                    m + s * jax.random.normal(kk, m.shape, m.dtype)
                    for kk, m, s in zip(ks, mean, std)
                ]

            xi = jax.vmap(draw)(seeds, rows)
            # clients get f32 fields whatever the internal storage dtype
            return icr.apply_sqrt_batch(mats, xi).astype(jnp.float32)

        return {"mats": mats, "plan": plan, "fn": jax.jit(slab_fn)}

    # -- serving loop ----------------------------------------------------------
    def _admit(self, queue: List[GPRequest]):
        for req in queue:
            if req.done or req.error:
                continue
            if req.kind not in ("sample", "moments") \
                    or not isinstance(req.n, (int, np.integer)) \
                    or req.n <= 0 or not 0 <= int(req.seed) < 2**31:
                req.error = (f"bad request: kind={req.kind!r} n={req.n} "
                             f"seed={req.seed} (seed must fit int32)")
                req.done = True

    def step(self, queue: List[GPRequest]) -> bool:
        """Pack one slab from the queue, execute it, scatter the results.
        Returns False when no request had demand (queue drained)."""
        self._admit(queue)
        rows = []  # (request, row index in its eps stream)
        for req in queue:
            if req.done:
                continue
            take = min(req.n - req._next_row, self.slab - len(rows))
            rows.extend((req, req._next_row + j) for j in range(take))
            req._next_row += take
            if len(rows) == self.slab:
                break
        if not rows:
            return False
        seeds = np.zeros(self.slab, np.int32)
        idxs = np.full(self.slab, 2**30, np.int32)  # padding: throwaway rows
        for i, (req, ridx) in enumerate(rows):
            seeds[i], idxs[i] = req.seed, ridx
        e = self._entry
        out = np.asarray(
            e["fn"](e["mats"], e["mean"], e["std"],
                    jnp.asarray(seeds), jnp.asarray(idxs)),
            dtype=np.float32)
        self.slabs_run += 1
        self.rows_served += len(rows)
        # scatter: contiguous runs per request (greedy packing keeps order)
        i = 0
        while i < len(rows):
            req = rows[i][0]
            j = i
            while j < len(rows) and rows[j][0] is req:
                j += 1
            chunk = out[i:j]
            if req.kind == "sample":
                # copies, not views: a retained row must not pin the slab
                req.fields.extend(np.array(row) for row in chunk)
            else:
                req._wcount, req._wmean, req._wm2 = _welford_merge(
                    req._wcount, req._wmean, req._wm2, chunk)
            if req._next_row >= req.n:
                if req.kind == "moments":
                    req.mean = req._wmean
                    req.std = np.sqrt(np.maximum(req._wm2 / req._wcount, 0.0))
                    self.fields_delivered += 2
                else:
                    self.fields_delivered += len(req.fields)
                req.done = True
            i = j
        return True

    def run(self, requests: List[GPRequest], max_iters: int = 1_000_000):
        queue = list(requests)
        # re-resolve the executable for this batch: warm traffic against the
        # same (chart, θ, policy) counts a hit and reuses everything
        self.set_posterior(self.posterior)
        it = 0
        while any(not r.done for r in queue) and it < max_iters:
            if not self.step(queue):
                break
            it += 1
        for r in queue:
            if not r.done:  # max_iters exhausted: signal, never silently
                r.error = (f"server stopped after max_iters={max_iters} "
                           f"slabs with {r.n - r._next_row} rows pending")
                r.done = True
        return requests

    # -- introspection ---------------------------------------------------------
    def modeled_slab_bytes(self) -> int:
        """Roofline HBM bytes one slab application moves (plan estimate)."""
        return sum(e["hbm_bytes"]["selected"] for e in self._entry["plan"])

    @property
    def route(self) -> str:
        """Dispatch route of the finest (dominant) refinement level."""
        return self._entry["plan"][-1]["route"]

    def cache_key_fingerprint(self) -> dict:
        """Deterministic printable fingerprint of the active
        executable-cache key (DESIGN.md §13) — the serving column of the
        compile fingerprints (repro.analysis). Equal server configs
        produce byte-identical fingerprints in any process; anything that
        would be a cache miss (chart geometry, θ, dtype policy, routing
        flags, effective backend, slab height) changes the digest."""
        canon = _canonical_key(self._cache_key(self.posterior))
        icr = self.posterior.icr
        return {
            "digest": hashlib.sha256(canon.encode()).hexdigest()[:16],
            "key": canon,
            "slab": self.slab,
            "backend": dispatch.select_backend(),
            "storage_dtype": icr.policy.storage_name,
        }

    def lowered_slab(self):
        """``jax.stages.Lowered`` of the active entry's slab executable —
        the §12 hot step as one lowering, handed to the compile-fingerprint
        subsystem (repro.analysis) so a serving-path route or dtype
        regression is caught by the golden diff, not by wall-time noise."""
        e = self._entry
        seeds = jnp.zeros(self.slab, jnp.int32)
        rows = jnp.zeros(self.slab, jnp.int32)
        return e["fn"].lower(e["mats"], e["mean"], e["std"], seeds, rows)


# -- demo / smoke entry point ---------------------------------------------------
def demo_posterior(chart, rho: float, dtype_policy=None,
                   seed: int = 0) -> Posterior:
    """A synthetic ADVI-shaped posterior (prior-sample mean, constant
    log-std) for benchmarks and smoke runs — no fit required. Real fits
    export through `core.vi.map_posterior` / `advi_posterior`."""
    from repro.core import ICR, matern32

    icr = ICR(chart=chart, kernel=matern32.with_defaults(rho=rho),
              use_pallas=True, dtype_policy=dtype_policy)
    mean = icr.init_xi(jax.random.PRNGKey(seed), dtype=jnp.float32)
    log_std = [jnp.full_like(m, -1.5) for m in mean]
    return Posterior(icr=icr, mean=mean, log_std=log_std)


def scenario_chart(name: str, quick: bool = False):
    """The three serving scenarios: 1-D time-ordered data, 2-D image,
    3-D dust map (the paper's flagship chart, reduced)."""
    from repro.core import regular_chart
    from repro.core.charts import galactic_dust_chart

    if name == "tod":
        return regular_chart(64, 3 if quick else 5, boundary="reflect")
    if name == "image":
        return regular_chart((16, 16) if quick else (32, 32), 2,
                             boundary="reflect")
    if name == "dust":
        return galactic_dust_chart((6, 8, 8), n_levels=2)
    raise ValueError(f"unknown scenario {name!r}")


SCENARIOS = {"tod": 8.0, "image": 4.0, "dust": 0.5}  # name -> kernel rho


def mixed_requests(n_fields: int = 3, mc: int = 8) -> List[GPRequest]:
    """A heterogeneous batch: sample + moments requests of varying size."""
    return [
        GPRequest(kind="sample", n=n_fields, seed=1),
        GPRequest(kind="moments", n=mc, seed=2),
        GPRequest(kind="sample", n=1, seed=3),
        GPRequest(kind="moments", n=mc // 2, seed=4),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="dust", choices=[*SCENARIOS, "all"])
    ap.add_argument("--slab", type=int, default=8)
    ap.add_argument("--fields", type=int, default=3)
    ap.add_argument("--mc", type=int, default=16)
    ap.add_argument("--dtype", default="fp32", choices=["fp32", "bf16"])
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    names = list(SCENARIOS) if args.scenario == "all" else [args.scenario]
    for name in names:
        chart = scenario_chart(name, quick=args.quick)
        pol = None if args.dtype == "fp32" else "bf16"
        post = demo_posterior(chart, SCENARIOS[name], dtype_policy=pol)
        srv = GPFieldServer(post, slab=args.slab)
        shape = chart.final_shape
        print(f"[{name}] chart {shape} = {int(np.prod(shape)):,} px, "
              f"slab={args.slab}, dtype={post.icr.policy.storage_name}")

        t0 = time.time()
        srv.run(mixed_requests(args.fields, args.mc))
        cold = time.time() - t0
        t0 = time.time()
        reqs = srv.run(mixed_requests(args.fields, args.mc))
        warm = time.time() - t0

        assert all(r.done and r.error is None for r in reqs)
        mom = next(r for r in reqs if r.kind == "moments")
        print(f"  cold {cold*1e3:.0f} ms, warm {warm*1e3:.0f} ms "
              f"({cold/max(warm, 1e-9):.1f}x), "
              f"{srv.rows_served} rows in {srv.slabs_run} slabs, "
              f"{srv.rows_served/ (cold+warm):.1f} samples/s")
        print(f"  exec cache: {srv.cache_hits} hits / "
              f"{srv.cache_misses} misses; est {srv.modeled_slab_bytes():,} "
              f"HBM bytes/slab (route={srv.route})")
        print(f"  moments({mom.n}): mean std over field = "
              f"{float(np.mean(mom.std)):.3f}")


if __name__ == "__main__":
    main()
