"""Batched GP posterior field server (DESIGN.md §12, §15).

The `launch.serve` BatchedServer pattern applied to the GP side of the
repo: clients submit posterior-sample and predictive-moment requests
against a fitted ICR posterior (`core.vi.Posterior` — a MAP ξ̂ or ADVI
`(mean, log_std)` export), and the server

  * packs heterogeneous requests into fixed-size **sample slabs** executed
    through `ICR.apply_sqrt_batch` — the native §10 sample-block path, so
    the refinement matrices are fetched once per VMEM tile for the whole
    slab and the work is bandwidth-bound on the field, not the matrices;
  * computes predictive mean/std by **streaming Welford accumulation**
    over slabs (Chan parallel merge per slab — no request ever needs its
    full MC budget resident at once);
  * never recompiles or rebuilds structure for repeat traffic: the
    executable cache is keyed on (chart geometry, θ, dtype policy, mesh)
    and holds the matrices (`ICR.matrices_cached`), the routing decision
    (`dispatch.plan_cached`) and the jitted slab executable.

Per-row excitation noise is keyed by (request seed, row index) only —
`fold_in(PRNGKey(seed), row)` — so a request's draws are independent of
how they were packed **and of the mesh they ran on**: a packed
heterogeneous batch reproduces the per-request loop exactly (the
slab-parity test pins this at 1e-5), and a slab replayed after a device
loss reproduces the unfaulted run bit-for-bit (tests/test_chaos.py).

Mesh serving (DESIGN.md §15): pass ``mesh=`` to shard slabs over devices.
``shard="samples"`` runs data-parallel over the sample axis through
`shard_map` (the axis the PR3 kernels tile innermost); ``shard="chart"``
routes each row through the `DistributedICR` halo-exchange body for
fields that exceed one device. On a `DeviceLossError` the server runs
detect → remesh (``elastic.shrink_mesh`` + ``remesh_report`` with
structured degradation records) → rewarm (background compile on the
surviving mesh) → replay (the in-flight slab re-executes; same
(seed, row) keys ⇒ identical results). When the mesh collapses to one
device it degrades to the single-device path (pallas on TPU, jnp
reference elsewhere) and keeps serving.

Run:  PYTHONPATH=src python -m repro.launch.serve_gp [--scenario dust]
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import threading
import time
from typing import Callable, List, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.vi import Posterior
from repro.distributed import elastic
from repro.distributed.fault import DeviceLossError, ServingFaultSupervisor
from repro.kernels import dispatch

_PAD_ROW = 2**30  # padding rows index past every request's eps stream


@dataclasses.dataclass(frozen=True)
class RequestError:
    """Structured per-request admission/serving error.

    Truthy (so existing ``if req.error`` call sites keep working), with a
    stable machine-readable ``code`` — clients branch on the code, humans
    read the message.
    """

    code: str
    message: str

    def __str__(self) -> str:
        return f"[{self.code}] {self.message}"

    def __bool__(self) -> bool:
        return True


@dataclasses.dataclass
class GPRequest:
    """One client request against the served posterior.

    kind="sample": return ``n`` posterior field draws (in ``fields``).
    kind="moments": MC predictive mean/std over an ``n``-draw budget
    (in ``mean``/``std``; the draws themselves are never retained).

    ``xi`` optionally replaces the posterior mean for this request's rows
    (a client-supplied excitation, e.g. a conditioning point estimate):
    leaf shapes must match the served chart's ``xi_shapes()`` and all
    values must be finite — both are checked at admission, so one bad
    request is rejected with a structured error instead of NaN-poisoning
    the slab it would have been packed into. ``theta`` optionally pins the
    hyperparameters the client expects to be served; a mismatch with the
    active posterior is an admission error, not silent wrong answers.
    """

    kind: str
    n: int
    seed: int = 0
    xi: Optional[list] = None
    theta: Optional[dict] = None
    # kind="condition" inputs: observed values plus exactly one of
    # on-grid flat indices (obs_idx) or off-grid 1-D locations (x_obs);
    # noise_std is the observation noise σ. ``n`` is the Matheron
    # pathwise-sample budget for the predictive std (n >= 2 for a
    # non-trivial std; the mean is exact either way).
    y: Optional[np.ndarray] = None
    obs_idx: Optional[np.ndarray] = None
    x_obs: Optional[np.ndarray] = None
    noise_std: float = 0.05
    done: bool = False
    error: Optional[object] = None  # RequestError (or legacy str)
    fields: list = dataclasses.field(default_factory=list)
    mean: Optional[np.ndarray] = None
    std: Optional[np.ndarray] = None
    report: Optional[object] = None  # solvers.SolveReport (condition)
    # internal: rows drawn so far (the per-request eps stream index),
    # the streaming Welford state (count, running mean, running M2),
    # and whether admission validation already ran
    _next_row: int = 0
    _wcount: int = 0
    _wmean: Optional[np.ndarray] = None
    _wm2: Optional[np.ndarray] = None
    _admitted: bool = False


def _canonical_key(x) -> str:
    """Deterministic printable form of an executable-cache key component.

    ``repr`` alone is not reproducible across processes: charts carry
    ``phi_inv`` function objects (repr embeds a memory address) and θ
    fingerprints carry raw bytes. Functions canonicalize to their
    qualified name, bytes to a content hash, dataclasses (Chart,
    DtypePolicy) recurse over their fields — so two servers built from
    equal configs print (and digest) identically in any process.
    """
    if isinstance(x, tuple):
        return "(" + ",".join(_canonical_key(v) for v in x) + ")"
    if isinstance(x, bytes):
        return "bytes<sha256:" + hashlib.sha256(x).hexdigest()[:12] + ">"
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        fields = ",".join(f"{f.name}={_canonical_key(getattr(x, f.name))}"
                          for f in dataclasses.fields(x))
        return f"{type(x).__name__}({fields})"
    if callable(x) and hasattr(x, "__qualname__"):
        return f"fn:{getattr(x, '__module__', '?')}.{x.__qualname__}"
    return repr(x)


def _welford_merge(count, m, m2, batch: np.ndarray):
    """Chan et al. parallel merge of a k-sample batch into (count, m, m2)."""
    k = batch.shape[0]
    bm = batch.mean(axis=0)
    bm2 = ((batch - bm) ** 2).sum(axis=0)
    if count == 0:
        return k, bm, bm2
    tot = count + k
    delta = bm - m
    m = m + delta * (k / tot)
    m2 = m2 + bm2 + delta**2 * (count * k / tot)
    return tot, m, m2


def _all_finite(x) -> bool:
    return bool(np.isfinite(np.asarray(x, np.float64)).all())


class GPFieldServer:
    """Continuous-batching server over one (swappable) fitted Posterior.

    ``slab`` is the fixed sample-slab height: every step draws one
    fixed-shape batch of posterior fields through one jitted executable —
    static shapes, so repeat traffic never retraces. Rows are assigned to
    queued requests greedily in queue order; short steps pad with
    throwaway rows (their keys index past every request's stream).

    ``mesh`` (optional) shards execution. ``shard="samples"``: the slab is
    rounded up to a multiple of the mesh size (the *capacity*) and split
    over all mesh axes via `shard_map` — each device draws and refines its
    own rows. ``shard="chart"``: rows stay whole but each field is
    spatially decomposed through the `DistributedICR` halo-exchange body.
    The executable cache key includes the mesh fingerprint, so an elastic
    re-mesh is always a deliberate miss, never a stale executable.
    """

    def __init__(self, posterior: Posterior, slab: int = 8,
                 max_cached: int = 8, mesh=None, shard: str = "samples",
                 supervisor: Optional[ServingFaultSupervisor] = None,
                 fault_injector: Optional[Callable] = None,
                 ckpt_root: Optional[str] = None,
                 solver_checkpoint_every: int = 8,
                 solver_config=None):
        if shard not in ("samples", "chart"):
            raise ValueError(f"shard={shard!r}: expected 'samples' or "
                             "'chart'")
        self.slab = int(slab)
        self.mesh = mesh
        self.shard = shard
        # per-device rows are pinned at construction and survive re-meshes:
        # a replayed slab must run the *same local gemm shapes* on the
        # shrunk mesh, else batch-size-dependent rounding breaks the
        # bit-identical replay guarantee (capacity shrinks with the mesh,
        # local work per device stays constant)
        n0 = (int(np.asarray(mesh.devices).size)
              if mesh is not None and shard == "samples" else 1)
        self._local_rows = -(-self.slab // n0)
        self.supervisor = supervisor or ServingFaultSupervisor()
        # test/chaos hook: called once per slab attempt with the server;
        # may raise DeviceLossError (kill), sleep (straggler), or no-op
        self.fault_injector = fault_injector
        # (key -> entry) executable cache, LRU-bounded: a long-running
        # server periodically re-fit at new θ must not pin one matrices
        # set + compiled executable per historical θ forever
        self.max_cached = int(max_cached)
        self._exec: dict = {}
        self.cache_hits = 0
        self.cache_misses = 0
        self.slabs_run = 0
        self.slabs_attempted = 0  # execution attempts incl. faulted/retried
        self.rows_served = 0      # non-padding rows (posterior draws)
        self.fields_delivered = 0  # arrays handed back to clients
        self.replans = 0           # device-loss re-mesh events
        self.replayed_slabs = 0    # in-flight slabs re-executed after loss
        self.dead_devices: set = set()
        self.degradations: list = []  # elastic.Degradation records
        self.last_recovery_s: Optional[float] = None  # fault -> first slab
        # data-conditioned solves (kind="condition", DESIGN.md §16)
        self.ckpt_root = ckpt_root
        self.solver_checkpoint_every = int(solver_checkpoint_every)
        self.solver_config = solver_config
        self.condition_requests = 0
        self.condition_rhs = 0       # real (unpadded) RHS columns solved
        self.solve_segments = 0      # CG segment attempts (chaos hook)
        self.solve_reports: list = []  # last few SolveReports
        self._cond_cache: dict = {}
        self._cond_seq = 0
        self.posterior = None
        self.set_posterior(posterior)

    # -- mesh geometry ---------------------------------------------------------
    def _n_shards(self) -> int:
        """Sample-axis parallelism: mesh size in "samples" mode, else 1."""
        if self.mesh is None or self.shard != "samples":
            return 1
        return int(np.asarray(self.mesh.devices).size)

    @property
    def capacity(self) -> int:
        """Rows per executed slab. Unsharded / chart-sharded: the slab
        height. Sample-sharded: ``local_rows * n_dev`` with the per-device
        ``local_rows`` pinned at construction — on the full mesh this is
        ``slab`` rounded up to divide evenly; after an elastic shrink the
        capacity contracts with the mesh (local shapes never change)."""
        n = self._n_shards()
        return self.slab if n == 1 else self._local_rows * n

    def _mesh_key(self):
        """Hashable mesh fingerprint for the executable-cache key: shard
        mode, axis names, mesh shape, and the exact device set — so a
        re-mesh (even to an equal-size mesh on different devices) is a
        deliberate miss, never a stale executable."""
        if self.mesh is None:
            return None
        devs = np.asarray(self.mesh.devices)
        return (self.shard, tuple(self.mesh.axis_names),
                tuple(int(s) for s in devs.shape),
                tuple((int(d.id), str(d.platform)) for d in devs.flat))

    def _mesh_desc(self) -> str:
        """Printable mesh dimension for fingerprints/metrics."""
        if self.mesh is None:
            return "unsharded"
        devs = np.asarray(self.mesh.devices)
        shape = "x".join(str(int(s)) for s in devs.shape)
        return f"{self.shard}:{shape}:{','.join(self.mesh.axis_names)}"

    @property
    def serving_mode(self) -> str:
        """Where on the degradation ladder this server executes: sharded
        pallas → single-device pallas → jnp reference (DESIGN.md §15)."""
        tier = "single" if self.mesh is None else f"sharded-{self.shard}"
        return f"{tier}:{dispatch.select_backend()}"

    # -- executable cache ------------------------------------------------------
    def _cache_key(self, post: Posterior):
        icr = post.icr
        tkey = icr._theta_key(post.theta)
        if tkey is None:
            raise ValueError("serving requires concrete (untraced) theta")
        # the kernel must be fingerprinted too: θ is often baked into the
        # kernel's defaults (with_defaults) with theta=None, and two such
        # posteriors must not collide on an equal chart. Kernel.default_theta
        # is a dict (unhashable), so flatten it.
        kern = icr.kernel
        kkey = (kern.fn, kern.name,
                tuple(sorted((k, float(v))
                             for k, v in kern.default_theta.items())))
        # routing flags, the effective backend and the mesh belong in the
        # key: an equal-chart/θ/policy ICR with a different executor config
        # (a REPRO_BACKEND flip, or a resized/re-homed mesh after an
        # elastic re-plan) must not be served the cached executable
        return (icr.chart, kkey, icr.jitter, tkey, icr.policy,
                icr.use_pallas, icr.use_pyramid,
                dispatch.select_backend(), self.slab, self._mesh_key())

    def _validate_posterior(self, post: Posterior):
        """A poisoned fit can never be installed: non-finite θ or
        q-parameters would NaN every slab for every client."""
        # std() rather than log_std: log_std = -inf is a legitimate delta
        # posterior (sigma = 0), but NaN or +inf sigma poisons every slab
        for name, leaves in (("theta", list((post.theta or {}).values())),
                             ("mean", list(post.mean)),
                             ("std", list(post.std()))):
            for leaf in leaves:
                if not _all_finite(leaf):
                    raise ValueError(
                        f"posterior rejected: non-finite values in {name}")

    def set_posterior(self, post: Posterior, *, rewarm: bool = False):
        """Point the server at a (new) fit. Same (chart geometry, θ, dtype
        policy, mesh) ⇒ cache hit: the matrices, plan and compiled
        executable are reused even across re-fits (only the q-parameters
        swap); anything else is a miss and builds a fresh entry.

        ``rewarm=True`` (the fault-recovery path) compiles a fresh entry's
        executable in a background thread; the first slab on that entry
        joins it before executing."""
        self._validate_posterior(post)
        key = self._cache_key(post)
        entry = self._exec.pop(key, None)  # re-insert below: LRU order
        if entry is not None:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
            entry = self._build(post)
        self._exec[key] = entry
        while len(self._exec) > self.max_cached:
            self._exec.pop(next(iter(self._exec)))  # evict least recent
        # q-parameters ride as jit arguments (same shapes ⇒ no retrace)
        entry["mean"] = list(post.mean)
        entry["std"] = post.std()
        self.posterior = post
        self._entry = entry
        if rewarm and "warm" not in entry:
            args = self._slab_args(entry, [])
            t = threading.Thread(
                target=lambda: jax.block_until_ready(entry["fn"](*args)),
                daemon=True)
            t.start()
            entry["warm"] = t
        return entry

    def _build(self, post: Posterior) -> dict:
        icr = post.icr
        shapes = icr.xi_shapes()
        n_shards = self._n_shards()
        capacity = self.capacity
        local_rows = capacity // n_shards
        # model what this ICR actually executes on *one device*: no pyramid
        # overlay when disabled, no axis factors without the fused path;
        # the mesh fingerprint keys the plan so a re-mesh re-plans
        plan = dispatch.plan_cached(
            icr.chart, samples=local_rows, dtype=icr.policy.storage_dtype,
            pyramid=icr.use_pallas and icr.use_pyramid,
            have_axis_mats=icr.use_pallas and icr.chart.ndim > 1,
            mesh_key=self._mesh_key())

        def draw(mean, std, seed, row, use_xi, xi_row):
            """One row's excitation: (seed, row)-keyed noise around the
            posterior mean — or the request's own ξ when it supplied one."""
            k = jax.random.fold_in(jax.random.PRNGKey(seed), row)
            ks = jax.random.split(k, len(shapes))
            out = []
            for kk, m, s, x in zip(ks, mean, std, xi_row):
                base = jnp.where(use_xi, x.astype(m.dtype), m)
                out.append(base + s * jax.random.normal(kk, m.shape, m.dtype))
            return out

        if self.mesh is not None and self.shard == "chart":
            entry = self._build_chart_sharded(post, draw, shapes)
        elif self.mesh is not None:
            entry = self._build_sample_sharded(post, draw)
        else:
            def slab_fn(mats, mean, std, seeds, rows, use_xi, xi_rows):
                one = lambda se, ro, fl, xp: draw(mean, std, se, ro, fl, xp)
                xi = jax.vmap(one)(seeds, rows, use_xi, xi_rows)
                # clients get f32 fields whatever the internal storage dtype
                return icr.apply_sqrt_batch(mats, xi).astype(jnp.float32)

            mats = icr.matrices_cached(post.theta)
            entry = {"mats": mats, "fn": jax.jit(slab_fn)}
        entry.update(plan=plan, capacity=capacity, shapes=shapes,
                     mode=self.serving_mode)
        return entry

    def _build_sample_sharded(self, post: Posterior, draw) -> dict:
        """Data-parallel over the sample axis: each device draws and
        refines ``capacity / n_dev`` rows; matrices and q-params are
        replicated, seeds/rows/ξ-overrides are split. Row keying is
        (seed, row) — device-independent — so the global result is
        identical to the unsharded slab."""
        icr = post.icr
        mesh = self.mesh
        axes = tuple(mesh.axis_names)

        def slab_fn(mats, mean, std, seeds, rows, use_xi, xi_rows):
            one = lambda se, ro, fl, xp: draw(mean, std, se, ro, fl, xp)
            xi = jax.vmap(one)(seeds, rows, use_xi, xi_rows)
            return icr.apply_sqrt_batch(mats, xi).astype(jnp.float32)

        mats = icr.matrices_cached(post.theta)
        repl = lambda tree: jax.tree.map(lambda _: P(), tree)
        # exercise the elastic placement path (replicated specs never
        # degrade, but a future param-sharded layout reports through the
        # same channel)
        mats, report = elastic.remesh_report(mats, mesh, repl(mats))
        self.degradations.extend(report)
        n_levels = len(post.mean)
        in_specs = (repl(mats), [P()] * n_levels, [P()] * n_levels,
                    P(axes), P(axes), P(axes), [P(axes)] * n_levels)
        fn = jax.jit(shard_map(slab_fn, mesh=mesh, in_specs=in_specs,
                               out_specs=P(axes), check_vma=False))
        return {"mats": mats, "fn": fn}

    def _build_chart_sharded(self, post: Posterior, draw, shapes) -> dict:
        """Spatial decomposition via the DistributedICR halo-exchange body:
        every device owns a block of the field along ``shard_axis`` and
        each row's refinement exchanges halos with ring neighbors. The ξ
        draw itself is replicated per row (same (seed, row) keys on every
        device) and each device keeps its local block — numerics match the
        single-device path to fp tolerance (interior math identical; see
        tests/test_distributed_icr.py)."""
        from repro.core.distributed import DistributedICR

        icr = post.icr
        mesh = self.mesh
        dist = DistributedICR(icr=icr, mesh=mesh,
                              axis_names=tuple(mesh.axis_names))
        k = dist.first_sharded_level()
        struct = dist.xi_structure()
        n_dev = dist.n_dev
        ax = dist.shard_axis

        def slab_fn(mats, mean, std, seeds, rows, use_xi, xi_rows):
            idx = jax.lax.axis_index(dist.axis_names)

            def one(seed, row, flag, xi_row):
                xi = draw(mean, std, seed, row, flag, xi_row)
                loc = [xi[0]]
                for lvl in range(icr.chart.n_levels):
                    leaf = xi[lvl + 1].reshape(struct[lvl + 1])
                    if lvl >= k:
                        blk = struct[lvl + 1][ax] // n_dev
                        leaf = jax.lax.dynamic_slice_in_dim(
                            leaf, idx * blk, blk, axis=ax)
                    loc.append(leaf)
                return dist._sharded_body(mats, loc)

            out = jax.vmap(one)(seeds, rows, use_xi, xi_rows)
            return out.astype(jnp.float32)

        # the sharded body runs the joint path; place the matrices with the
        # distributed specs and surface any degradation (e.g. a ring the
        # family counts don't divide would replicate — reported, not hidden)
        mats = icr.matrices_cached(post.theta, joint=True, axes=False)
        mat_specs = dist.mat_specs()
        mats, report = elastic.remesh_report(mats, mesh, mat_specs)
        self.degradations.extend(report)
        n_levels = len(post.mean)
        in_specs = (mat_specs, [P()] * n_levels, [P()] * n_levels,
                    P(), P(), P(), [P()] * n_levels)
        fn = jax.jit(shard_map(slab_fn, mesh=mesh, in_specs=in_specs,
                               out_specs=P(None, *dist.out_spec()),
                               check_vma=False))
        return {"mats": mats, "fn": fn}

    # -- admission -------------------------------------------------------------
    def _reject(self, req: GPRequest, code: str, message: str):
        req.error = RequestError(code=code, message=message)
        req.done = True

    def _admit(self, queue: List[GPRequest]):
        """Validate each request once, before any of its rows are packed:
        a rejected request never enters a slab, so it cannot poison the
        streaming moments of the healthy requests packed beside it."""
        shapes = self.posterior.icr.xi_shapes()
        served_theta = dict(self.posterior.icr.kernel.default_theta)
        served_theta.update(self.posterior.theta or {})
        for req in queue:
            if req.done or req.error or req._admitted:
                continue
            req._admitted = True
            if req.kind not in ("sample", "moments", "condition") \
                    or not isinstance(req.n, (int, np.integer)) \
                    or req.n <= 0 or not 0 <= int(req.seed) < 2**31:
                self._reject(req, "bad-request",
                             f"kind={req.kind!r} n={req.n} seed={req.seed} "
                             "(seed must fit int32)")
                continue
            if req.theta is not None:
                bad = [k for k, v in req.theta.items()
                       if not _all_finite(v)]
                if bad:
                    self._reject(req, "theta-nonfinite",
                                 f"non-finite theta entries {bad}")
                    continue
                stale = [k for k, v in req.theta.items()
                         if k not in served_theta
                         or not np.allclose(served_theta[k], v)]
                if stale:
                    self._reject(
                        req, "theta-mismatch",
                        f"request pinned theta {sorted(req.theta)} but the "
                        f"server is fitted at {sorted(served_theta)} with "
                        f"different values for {stale}")
                    continue
            if req.xi is not None:
                got = [tuple(np.shape(leaf)) for leaf in req.xi]
                want = [tuple(s) for s in shapes]
                if got != want:
                    self._reject(req, "xi-geometry",
                                 f"xi leaves {got} do not match the served "
                                 f"chart's xi_shapes() {want}")
                    continue
                if not all(_all_finite(leaf) for leaf in req.xi):
                    self._reject(req, "xi-nonfinite",
                                 "xi contains NaN/Inf values")
                    continue
            if req.kind == "condition":
                self._admit_condition(req)

    def _admit_condition(self, req: GPRequest):
        """Conditioning inputs are validated before any solve work runs:
        a non-finite y or a malformed observation spec is a structured
        rejection at the queue, while runtime divergence/NaN *inside* the
        solve is the solver quarantine's job (per-RHS isolation) — either
        way no other request's answer is perturbed."""
        y = None if req.y is None else np.asarray(req.y, np.float64).ravel()
        if y is None or y.size == 0:
            return self._reject(req, "y-missing",
                                "kind='condition' requires observed "
                                "values y")
        if not np.isfinite(y).all():
            return self._reject(req, "y-nonfinite",
                                "y contains NaN/Inf values")
        if (req.obs_idx is None) == (req.x_obs is None):
            return self._reject(req, "obs-spec",
                                "pass exactly one of obs_idx (on-grid) "
                                "or x_obs (off-grid 1-D)")
        chart = self.posterior.icr.chart
        n_grid = int(np.prod(chart.final_shape))
        if req.obs_idx is not None:
            idx = np.asarray(req.obs_idx)
            if idx.size and not np.issubdtype(idx.dtype, np.integer):
                return self._reject(req, "obs-dtype",
                                    "obs_idx must be integer flat indices")
            if idx.size == 0 or idx.min() < 0 or idx.max() >= n_grid:
                return self._reject(req, "obs-range",
                                    "obs_idx empty or out of range for a "
                                    f"{n_grid}-pixel chart")
            n_obs = idx.size
        else:
            x = np.asarray(req.x_obs, np.float64).ravel()
            if chart.ndim != 1:
                return self._reject(req, "obs-ndim",
                                    "off-grid x_obs interpolation is 1-D "
                                    "only; use obs_idx for N-D charts")
            if x.size == 0 or not np.isfinite(x).all():
                return self._reject(req, "obs-nonfinite",
                                    "x_obs is empty or non-finite")
            n_obs = x.size
        if y.size != n_obs:
            return self._reject(req, "obs-length",
                                f"y has {y.size} entries but the "
                                f"observation spec has {n_obs}")
        if not (np.isfinite(req.noise_std) and float(req.noise_std) > 0):
            return self._reject(req, "noise-invalid",
                                f"noise_std={req.noise_std!r} must be a "
                                "finite positive float")

    # -- slab execution --------------------------------------------------------
    def _slab_args(self, entry: dict, rows: list) -> tuple:
        """Device arguments for one slab: fixed ``capacity`` height, rows
        beyond the packed prefix are padding (keys past every stream)."""
        cap = entry["capacity"]
        seeds = np.zeros(cap, np.int32)
        idxs = np.full(cap, _PAD_ROW, np.int32)
        flags = np.zeros(cap, bool)
        xi_rows = [np.zeros((cap,) + tuple(s), np.float32)
                   for s in entry["shapes"]]
        for i, (req, ridx) in enumerate(rows):
            seeds[i], idxs[i] = req.seed, ridx
            if req.xi is not None:
                flags[i] = True
                for lvl, leaf in enumerate(req.xi):
                    xi_rows[lvl][i] = np.asarray(leaf, np.float32)
        return (entry["mats"], entry["mean"], entry["std"],
                jnp.asarray(seeds), jnp.asarray(idxs), jnp.asarray(flags),
                [jnp.asarray(x) for x in xi_rows])

    def _execute_once(self, entry: dict, args: tuple) -> np.ndarray:
        """One slab attempt under the fault supervisor: transient errors
        retry with backoff, DeviceLossError propagates to the re-plan
        path, wall time feeds the straggler monitor."""
        warm = entry.pop("warm", None)
        if warm is not None:
            warm.join()

        def attempt():
            self.slabs_attempted += 1
            if self.fault_injector is not None:
                self.fault_injector(self)
            return np.asarray(entry["fn"](*args), dtype=np.float32)

        return self.supervisor.execute(attempt)

    def _on_device_loss(self, exc: DeviceLossError):
        """detect → remesh → rewarm: shrink the mesh to the surviving
        devices, re-key the executable cache (the mesh is in the key, so
        this is a deliberate miss), rebuild matrices/plan on the new mesh
        and start the compile in the background. The caller then replays
        the in-flight slab."""
        if self.mesh is None:
            raise exc  # single device lost: nothing left to shrink onto
        self.dead_devices.update(exc.device_ids)
        new_mesh = elastic.shrink_mesh(self.mesh, self.dead_devices)
        if new_mesh is not None and self.shard == "chart":
            new_mesh = self._feasible_chart_mesh(new_mesh)
        if new_mesh is None:
            self.degradations.append(elastic.Degradation(
                path="<mesh>", requested=self._mesh_desc(),
                applied="unsharded",
                reason=f"lost device(s) {sorted(self.dead_devices)}; "
                       "degrading to the single-device path"))
        self.mesh = new_mesh
        self.replans += 1
        self.set_posterior(self.posterior, rewarm=True)

    def _feasible_chart_mesh(self, mesh):
        """Chart sharding needs the family counts divisible by the ring:
        shrink to the largest feasible ring ≤ the survivor count (recorded
        as a degradation when devices must idle), or None when no ring ≥ 2
        is feasible."""
        from repro.core.distributed import DistributedICR

        devs = list(np.asarray(mesh.devices).flat)
        for n in range(len(devs), 1, -1):
            cand = type(mesh)(np.asarray(devs[:n]), mesh.axis_names)
            try:
                DistributedICR(icr=self.posterior.icr, mesh=cand,
                               axis_names=tuple(cand.axis_names)
                               ).first_sharded_level()
            except ValueError:
                continue
            if n < len(devs):
                self.degradations.append(elastic.Degradation(
                    path="<mesh>", requested=f"{self.shard}:{len(devs)}",
                    applied=f"{self.shard}:{n}",
                    reason=f"no refinement level shardable over {len(devs)} "
                           f"survivors; largest feasible ring is {n}"))
            return cand
        self.degradations.append(elastic.Degradation(
            path="<mesh>", requested=f"{self.shard}:{len(devs)}",
            applied="unsharded",
            reason="no feasible chart ring over the survivors"))
        return None

    def _run_rows(self, rows: list) -> np.ndarray:
        """Execute packed rows, chunked to the active entry's capacity.
        A DeviceLossError mid-chunk re-plans onto the surviving mesh and
        replays that chunk — the (seed, row) noise keys make the replay
        reproduce the unfaulted results exactly."""
        outs = []
        i = 0
        recovery_t0 = None
        while i < len(rows):
            entry = self._entry
            chunk = rows[i:i + entry["capacity"]]
            args = self._slab_args(entry, chunk)
            try:
                out = self._execute_once(entry, args)
            except DeviceLossError as exc:
                if recovery_t0 is None:
                    recovery_t0 = time.perf_counter()
                self._on_device_loss(exc)
                self.replayed_slabs += 1
                continue  # replay the same chunk on the new entry
            if recovery_t0 is not None:
                self.last_recovery_s = time.perf_counter() - recovery_t0
                recovery_t0 = None
            outs.append(out[:len(chunk)])
            self.slabs_run += 1
            i += len(chunk)
        return outs[0] if len(outs) == 1 else np.concatenate(outs, axis=0)

    # -- data-conditioned solves (kind="condition", DESIGN.md §16) -------------
    def _cond_mesh(self):
        """RHS-axis sharding mesh for the conditioning matvec: the serving
        mesh in "samples" mode (the RHS batch *is* a sample batch, split
        the same way); chart-sharded serving solves unsharded — the
        conditioning batch is small and the halo-exchange body has no RHS
        axis to split."""
        return self.mesh if self.shard == "samples" else None

    def _condition_system(self, op, noise_var: float):
        """LRU-cached ConditionSystem keyed like the executable cache plus
        the observation fingerprint and σ² — a re-fit, re-mesh or new
        observation pattern is a deliberate miss."""
        from repro.solvers import build_condition_system

        post = self.posterior
        key = (self._cache_key(post), op.fingerprint(), float(noise_var))
        sys_ = self._cond_cache.pop(key, None)
        if sys_ is None:
            sys_ = build_condition_system(post.icr, op, noise_var,
                                          theta=post.theta,
                                          mesh=self._cond_mesh())
        self._cond_cache[key] = sys_
        while len(self._cond_cache) > self.max_cached:
            self._cond_cache.pop(next(iter(self._cond_cache)))
        return sys_

    def _solver_manager(self):
        """Per-solve CheckpointManager rooted under ``ckpt_root`` (lazily
        a tempdir): every solve gets its own directory so a resumed carry
        can never alias another request's checkpoints."""
        if self.solver_checkpoint_every <= 0:
            return None
        import os
        import tempfile

        from repro.checkpoint.checkpointer import CheckpointManager

        if self.ckpt_root is None:
            self.ckpt_root = tempfile.mkdtemp(prefix="gp-serve-solve-")
        self._cond_seq += 1
        return CheckpointManager(
            os.path.join(self.ckpt_root, f"solve_{self._cond_seq}"))

    def _run_condition(self, req: GPRequest):
        """Serve one kind="condition" request end to end (§16).

        RHS layout: column 0 solves the posterior-mean system
        ``(W K Wᵀ + σ²I) α = y``; columns 1..n are Matheron pathwise
        targets ``y − W f_j − σ ε_j`` for prior draws ``f_j = S ξ_j``
        keyed ``fold_in(seed, row)`` exactly like the sampling slab — a
        re-meshed replay reproduces the same draws. The batch is padded
        to a multiple of the mesh size (zero-RHS columns converge at
        iteration 0) and re-padded after an elastic shrink. The solve
        runs the guarded fallback ladder under the fault supervisor with
        checkpoint/resume; the structured SolveReport rides back on the
        request and in ``metrics()``."""
        from repro.solvers import CGConfig, solve_guarded
        from repro.solvers.gp_system import obs_operator

        self.condition_requests += 1
        post = self.posterior
        icr = post.icr
        try:
            op = obs_operator(icr, obs_idx=req.obs_idx, x_obs=req.x_obs)
        except ValueError as e:  # race-proofing: _admit already checks
            return self._reject(req, "obs-invalid", str(e))
        noise_std = float(req.noise_std)
        noise_var = noise_std ** 2
        state = {"system": self._condition_system(op, noise_var)}
        shapes = icr.xi_shapes()
        shape = tuple(icr.chart.final_shape)
        n = int(req.n)
        k_real = 1 + n

        def draw(row):
            k = jax.random.fold_in(jax.random.PRNGKey(req.seed), row)
            ks = jax.random.split(k, len(shapes) + 1)
            xi = [jax.random.normal(kk, tuple(s), jnp.float32)
                  for kk, s in zip(ks[:-1], shapes)]
            eps = jax.random.normal(ks[-1], (op.n_obs,), jnp.float32)
            return xi, eps

        xi, eps = jax.vmap(draw)(jnp.arange(n))
        fields = np.asarray(
            icr.apply_sqrt_batch(state["system"].mats, xi)
        ).astype(np.float32).reshape(n, -1)
        y = jnp.asarray(np.asarray(req.y, np.float32).ravel())[None, :]
        b = jnp.concatenate(
            [y, y - op.apply(jnp.asarray(fields)) - noise_std * eps],
            axis=0)

        def shards_of(sys_):
            return (1 if sys_.mesh is None
                    else int(np.asarray(sys_.mesh.devices).size))

        k_pad = -(-k_real // shards_of(state["system"])) \
            * shards_of(state["system"])
        if k_pad > k_real:
            b = jnp.concatenate(
                [b, jnp.zeros((k_pad - k_real, op.n_obs), b.dtype)],
                axis=0)

        def fault_hook(it):
            self.solve_segments += 1
            if self.fault_injector is not None:
                self.fault_injector(self)

        def on_device_loss(exc):
            # shrink the mesh + rewarm the sampling entry, then rebuild
            # the conditioning system on the survivors; the new width
            # pads *up* to the survivors' multiple so the already-running
            # solve_guarded ladder never narrows below its batch
            self._on_device_loss(exc)
            sys_ = self._condition_system(op, noise_var)
            state["system"] = sys_
            n_sh = shards_of(sys_)
            k_new = -(-max(k_real, k_pad) // n_sh) * n_sh
            return sys_.matvec, {"icr": sys_.precond, "none": None}, k_new

        cfg = self.solver_config or CGConfig(
            rtol=1e-7, max_iters=max(4 * op.n_obs, 200))
        ladder = ([("icr", state["system"].precond)]
                  if state["system"].precond is not None else []) \
            + [("none", None)]
        alpha, report = solve_guarded(
            state["system"].matvec, b, preconds=ladder, cfg=cfg,
            dense_solve=lambda bb: state["system"].dense_solve(bb),
            manager=self._solver_manager(),
            checkpoint_every=self.solver_checkpoint_every or None,
            fault_hook=fault_hook, on_device_loss=on_device_loss,
            executor=self.supervisor.execute,
            n_report=k_real, tag=f"condition:{op.n_obs}obs")

        req.report = report
        self.solve_reports.append(report)
        del self.solve_reports[:-16]
        self.condition_rhs += k_real
        if report.status[0] not in ("converged", "dense"):
            req.done = True
            req.error = RequestError(
                "solve-failed",
                f"posterior-mean solve ended '{report.status[0]}' "
                f"(relres {report.relres[0]:.2e}) after rungs "
                f"{list(report.rungs)}")
            return
        corr = np.asarray(state["system"].correct(
            jnp.asarray(alpha[:k_real], jnp.float32))).reshape(k_real, -1)
        req.mean = corr[0].reshape(shape)
        # predictive std over the *non-quarantined* Matheron samples: a
        # diverged/NaN sample column is excluded, never averaged in
        good = [j for j in range(1, k_real)
                if report.status[j] in ("converged", "dense")]
        if len(good) >= 2:
            samples = np.stack([fields[j - 1] + corr[j] for j in good])
            req.std = samples.std(axis=0).reshape(shape)
        else:
            req.std = np.zeros(shape, np.float32)
        self.fields_delivered += 2
        req.done = True

    # -- serving loop ----------------------------------------------------------
    def step(self, queue: List[GPRequest]) -> bool:
        """Pack one slab from the queue, execute it, scatter the results.
        Condition requests are served one per step (a whole batched solve
        is one unit of work); sample/moments rows pack into slabs.
        Returns False when no request had demand (queue drained)."""
        self._admit(queue)
        for req in queue:
            if not req.done and req.kind == "condition":
                self._run_condition(req)
                return True
        cap = self._entry["capacity"]
        rows = []  # (request, row index in its eps stream)
        for req in queue:
            if req.done:
                continue
            take = min(req.n - req._next_row, cap - len(rows))
            rows.extend((req, req._next_row + j) for j in range(take))
            req._next_row += take
            if len(rows) == cap:
                break
        if not rows:
            return False
        out = self._run_rows(rows)
        self.rows_served += len(rows)
        # scatter: contiguous runs per request (greedy packing keeps order)
        i = 0
        while i < len(rows):
            req = rows[i][0]
            j = i
            while j < len(rows) and rows[j][0] is req:
                j += 1
            chunk = out[i:j]
            if req.kind == "sample":
                # copies, not views: a retained row must not pin the slab
                req.fields.extend(np.array(row) for row in chunk)
            else:
                req._wcount, req._wmean, req._wm2 = _welford_merge(
                    req._wcount, req._wmean, req._wm2, chunk)
            if req._next_row >= req.n:
                if req.kind == "moments":
                    req.mean = req._wmean
                    req.std = np.sqrt(np.maximum(req._wm2 / req._wcount, 0.0))
                    self.fields_delivered += 2
                else:
                    self.fields_delivered += len(req.fields)
                req.done = True
            i = j
        return True

    def run(self, requests: List[GPRequest], max_iters: int = 1_000_000):
        queue = list(requests)
        # re-resolve the executable for this batch: warm traffic against the
        # same (chart, θ, policy, mesh) counts a hit and reuses everything
        self.set_posterior(self.posterior)
        it = 0
        while any(not r.done for r in queue) and it < max_iters:
            if not self.step(queue):
                break
            it += 1
        for r in queue:
            if not r.done:  # max_iters exhausted: signal, never silently
                r.error = RequestError(
                    code="max-iters",
                    message=f"server stopped after max_iters={max_iters} "
                            f"slabs with {r.n - r._next_row} rows pending")
                r.done = True
        return requests

    # -- introspection ---------------------------------------------------------
    def modeled_slab_bytes(self) -> int:
        """Roofline HBM bytes one *per-device* slab application moves
        (plan estimate for the local rows)."""
        return sum(e["hbm_bytes"]["selected"] for e in self._entry["plan"])

    @property
    def route(self) -> str:
        """Dispatch route of the finest (dominant) refinement level."""
        return self._entry["plan"][-1]["route"]

    def metrics(self) -> dict:
        """Serving + fault counters for dashboards and the chaos suite."""
        return {
            "slabs_run": self.slabs_run,
            "slabs_attempted": self.slabs_attempted,
            "rows_served": self.rows_served,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "replans": self.replans,
            "replayed_slabs": self.replayed_slabs,
            "dead_devices": sorted(self.dead_devices),
            "mesh": self._mesh_desc(),
            "mode": self.serving_mode,
            "capacity": self.capacity,
            "last_recovery_s": self.last_recovery_s,
            "degradations": [str(d) for d in self.degradations],
            "condition_requests": self.condition_requests,
            "condition_rhs": self.condition_rhs,
            "solve_segments": self.solve_segments,
            "solve_fallbacks": sum(len(r.fallbacks)
                                   for r in self.solve_reports),
            "solve_resumes": sum(len(r.resumes)
                                 for r in self.solve_reports),
            "solve_reports": [r.summary() for r in self.solve_reports[-4:]],
            **{f"fault_{k}": v
               for k, v in self.supervisor.metrics().items()},
        }

    def cache_key_fingerprint(self) -> dict:
        """Deterministic printable fingerprint of the active
        executable-cache key (DESIGN.md §13) — the serving column of the
        compile fingerprints (repro.analysis). Equal server configs
        produce byte-identical fingerprints in any process; anything that
        would be a cache miss (chart geometry, θ, dtype policy, routing
        flags, effective backend, slab height, mesh) changes the digest."""
        canon = _canonical_key(self._cache_key(self.posterior))
        icr = self.posterior.icr
        return {
            "digest": hashlib.sha256(canon.encode()).hexdigest()[:16],
            "key": canon,
            "slab": self.slab,
            "backend": dispatch.select_backend(),
            "storage_dtype": icr.policy.storage_name,
            "mesh": self._mesh_desc(),
        }

    def lowered_slab(self):
        """``jax.stages.Lowered`` of the active entry's slab executable —
        the §12 hot step as one lowering, handed to the compile-fingerprint
        subsystem (repro.analysis) so a serving-path route or dtype
        regression is caught by the golden diff, not by wall-time noise."""
        e = self._entry
        return e["fn"].lower(*self._slab_args(e, []))


# -- demo / smoke entry point ---------------------------------------------------
def demo_posterior(chart, rho: float, dtype_policy=None,
                   seed: int = 0) -> Posterior:
    """A synthetic ADVI-shaped posterior (prior-sample mean, constant
    log-std) for benchmarks and smoke runs — no fit required. Real fits
    export through `core.vi.map_posterior` / `advi_posterior`."""
    from repro.core import ICR, matern32

    icr = ICR(chart=chart, kernel=matern32.with_defaults(rho=rho),
              use_pallas=True, dtype_policy=dtype_policy)
    mean = icr.init_xi(jax.random.PRNGKey(seed), dtype=jnp.float32)
    log_std = [jnp.full_like(m, -1.5) for m in mean]
    return Posterior(icr=icr, mean=mean, log_std=log_std)


def scenario_chart(name: str, quick: bool = False):
    """The three serving scenarios: 1-D time-ordered data, 2-D image,
    3-D dust map (the paper's flagship chart, reduced)."""
    from repro.core import regular_chart
    from repro.core.charts import galactic_dust_chart

    if name == "tod":
        return regular_chart(64, 3 if quick else 5, boundary="reflect")
    if name == "image":
        return regular_chart((16, 16) if quick else (32, 32), 2,
                             boundary="reflect")
    if name == "dust":
        return galactic_dust_chart((6, 8, 8), n_levels=2)
    raise ValueError(f"unknown scenario {name!r}")


SCENARIOS = {"tod": 8.0, "image": 4.0, "dust": 0.5}  # name -> kernel rho


def mixed_requests(n_fields: int = 3, mc: int = 8) -> List[GPRequest]:
    """A heterogeneous batch: sample + moments requests of varying size."""
    return [
        GPRequest(kind="sample", n=n_fields, seed=1),
        GPRequest(kind="moments", n=mc, seed=2),
        GPRequest(kind="sample", n=1, seed=3),
        GPRequest(kind="moments", n=mc // 2, seed=4),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="dust", choices=[*SCENARIOS, "all"])
    ap.add_argument("--slab", type=int, default=8)
    ap.add_argument("--fields", type=int, default=3)
    ap.add_argument("--mc", type=int, default=16)
    ap.add_argument("--dtype", default="fp32", choices=["fp32", "bf16"])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard over the first N local devices (0: off)")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(jax.devices()[:args.mesh]), ("data",))

    names = list(SCENARIOS) if args.scenario == "all" else [args.scenario]
    for name in names:
        chart = scenario_chart(name, quick=args.quick)
        pol = None if args.dtype == "fp32" else "bf16"
        post = demo_posterior(chart, SCENARIOS[name], dtype_policy=pol)
        srv = GPFieldServer(post, slab=args.slab, mesh=mesh)
        shape = chart.final_shape
        print(f"[{name}] chart {shape} = {int(np.prod(shape)):,} px, "
              f"slab={args.slab}, dtype={post.icr.policy.storage_name}, "
              f"mesh={srv._mesh_desc()}")

        t0 = time.time()
        srv.run(mixed_requests(args.fields, args.mc))
        cold = time.time() - t0
        t0 = time.time()
        reqs = srv.run(mixed_requests(args.fields, args.mc))
        warm = time.time() - t0

        assert all(r.done and r.error is None for r in reqs)
        mom = next(r for r in reqs if r.kind == "moments")
        print(f"  cold {cold*1e3:.0f} ms, warm {warm*1e3:.0f} ms "
              f"({cold/max(warm, 1e-9):.1f}x), "
              f"{srv.rows_served} rows in {srv.slabs_run} slabs, "
              f"{srv.rows_served/ (cold+warm):.1f} samples/s")
        print(f"  exec cache: {srv.cache_hits} hits / "
              f"{srv.cache_misses} misses; est {srv.modeled_slab_bytes():,} "
              f"HBM bytes/slab (route={srv.route})")
        print(f"  moments({mom.n}): mean std over field = "
              f"{float(np.mean(mom.std)):.3f}")


if __name__ == "__main__":
    main()
