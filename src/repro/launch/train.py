"""Training driver: sharded train loop with fault tolerance.

Wires together the whole substrate (assignment deliverable b's end-to-end
driver for the LM zoo; the paper-native end-to-end driver is
examples/gp_regression_vi.py):

  * deterministic sharded data pipeline (repro.data),
  * jitted+sharded train step with optional gradient accumulation,
  * async atomic checkpoints every ``ckpt_every`` steps,
  * FaultSupervisor: restore-from-checkpoint + retry on step failure,
  * StragglerMonitor: robust step-time outlier detection,
  * restart safety: ``python -m repro.launch.train --arch X`` resumes from
    the latest checkpoint with the exact data order.

CPU-friendly: pass --smoke to train the reduced config (the real configs
need the TPU fleet this code is written for).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.configs.base import ShapeCell
from repro.data import SyntheticLMData, make_batch_iterator
from repro.distributed.fault import FaultSupervisor, StragglerMonitor
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import choose_accum, make_train_step


@dataclasses.dataclass
class TrainLoopResult:
    steps_done: int
    final_loss: float
    losses: list
    restarts: int
    stragglers: int


def train_loop(cfg, mesh, *, steps: int, global_batch: int, seq_len: int,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
               seed: int = 0, fail_at: Optional[int] = None,
               log_every: int = 10) -> TrainLoopResult:
    """Run `steps` optimizer steps. `fail_at` injects one synthetic failure
    (tests/fault drills)."""
    cell = ShapeCell("train", seq_len, global_batch, "train")
    from repro.models import build_model
    accum = choose_accum(build_model(cfg), cell, mesh)
    ts = make_train_step(cfg, mesh, accum=accum, donate=False,
                         total_steps=steps)

    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=seq_len,
                           global_batch=global_batch, seed=seed)
    sample = data.batch(0)
    batch_shape = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), sample)
    step_fn, batch_sh = ts.fn(batch_shape)

    params, opt_state = ts.init_state(jax.random.PRNGKey(seed))
    start_step = 0
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        start_step, (params, opt_state) = ckpt.restore(
            (params, opt_state), mesh=mesh)
        print(f"resumed from checkpoint step {start_step}")

    def restore():
        s, (p, o) = ckpt.restore((params, opt_state), mesh=mesh)
        return s, (p, o)

    supervisor = FaultSupervisor(restore_fn=restore) if ckpt else None
    straggler = StragglerMonitor()
    losses = []
    it = make_batch_iterator(data, start_step=start_step,
                             shardings=batch_sh)
    state = (params, opt_state)
    step = start_step
    injected = False
    try:
        while step < steps:
            batch = next(it)
            t0 = time.time()

            def one(state):
                nonlocal injected
                if fail_at is not None and step == fail_at and not injected:
                    injected = True
                    raise RuntimeError("injected device failure (drill)")
                p, o, metrics = step_fn(state[0], state[1], batch)
                return (p, o), metrics

            if supervisor is not None:
                out, step_new, failed = supervisor.run(one, state, step)
                if failed:
                    step = step_new
                    state = out
                    it.close()
                    it = make_batch_iterator(data, start_step=step,
                                             shardings=batch_sh)
                    continue
                state, metrics = out
                step = step_new
            else:
                state, metrics = one(state)
                step += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            straggler.observe(time.time() - t0)
            if step % log_every == 0:
                print(f"step {step}: loss={loss:.4f} "
                      f"({time.time() - t0:.2f}s/step)", flush=True)
            if ckpt and step % ckpt_every == 0:
                ckpt.save(step, state, spec_tree=(
                    None if ts.params_sh is None else None))
        if ckpt:
            ckpt.save(steps, state, blocking=True)
    finally:
        it.close()
    return TrainLoopResult(
        steps_done=step, final_loss=losses[-1] if losses else float("nan"),
        losses=losses, restarts=supervisor.restarts if supervisor else 0,
        stragglers=straggler.stragglers)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    res = train_loop(cfg, mesh, steps=args.steps,
                     global_batch=args.global_batch, seq_len=args.seq_len,
                     ckpt_dir=args.ckpt_dir)
    print(f"done: {res.steps_done} steps, final loss {res.final_loss:.4f}, "
          f"{res.restarts} restarts, {res.stragglers} stragglers")


if __name__ == "__main__":
    main()
