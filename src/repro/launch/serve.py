"""Serving driver: batched decode with a continuous request queue.

A minimal production pattern: fixed-size batch slots, each slot owns a
sequence (prompt + generation state); finished slots are refilled from the
queue. One jitted serve_step decodes a token for every active slot per
iteration (static shapes — slots carry an active mask). Prefill for a new
request is token-by-token through the same step (CPU-friendly; a fused
prefill kernel is the obvious TPU upgrade and is what prefill_32k lowers).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import build_model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None


class BatchedServer:
    def __init__(self, cfg, batch_slots: int = 4, s_max: int = 128,
                 seed: int = 0, temperature: float = 0.0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init_params(jax.random.PRNGKey(seed))
        self.b = batch_slots
        self.s_max = s_max
        self.temperature = temperature
        self._rng = np.random.default_rng(seed)
        self.cache = self.model.init_cache(batch_slots, s_max)
        self.pos = np.zeros(batch_slots, np.int32)
        self._slot_dirty = [False] * batch_slots  # slot held a request before
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pending: List[list] = [[] for _ in range(batch_slots)]
        self._step = jax.jit(self.model.serve_step)
        # prefill and decode are separate throughput regimes: prefill tokens
        # re-ingest the prompt, only decode tokens are generated output
        self.prefill_tokens = 0
        self.decode_tokens = 0

    @property
    def tokens_served(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    def _next_token(self, logits_i: np.ndarray) -> int:
        """Greedy at temperature 0, softmax sampling above."""
        if self.temperature <= 0.0:
            return int(np.argmax(logits_i))
        z = logits_i.astype(np.float64) / self.temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._rng.choice(p.size, p=p))

    # attention caches are position-indexed: `attention_decode`/`mla_decode`
    # mask cache slot j invisible until the new request's own write at
    # position j (or its ring image) has overwritten it, so stale rows are
    # unreachable and need no clearing — pinned by the slot-reuse decode-
    # consistency test. Everything else (mamba/mlstm/slstm recurrent state)
    # has no positions and WOULD leak the finished request's state forward.
    _POS_MASKED_KEYS = ({"k", "v"}, {"ckv", "kpe"})

    def _clear_slot(self, i: int):
        """Zero slot i's rows in every non-position-masked cache leaf
        before reuse (see _POS_MASKED_KEYS). head/tail slot caches carry
        batch at axis 0, the grouped caches at axis 1 (n_groups leads)."""

        def clear(c, batch_axis=0):
            if isinstance(c, dict) and set(c) in self._POS_MASKED_KEYS:
                return c  # attention KV: stale rows proven unreachable
            idx = (slice(None),) * batch_axis + (i,)
            return jax.tree.map(
                lambda x: x.at[idx].set(jnp.zeros_like(x[idx])), c)

        for key in ("head", "tail"):
            if key in self.cache:
                self.cache[key] = [clear(c) for c in self.cache[key]]
        if self.cache.get("groups"):
            self.cache["groups"] = {
                name: clear(c, batch_axis=1)
                for name, c in self.cache["groups"].items()
            }

    def _admit(self, queue: list):
        for i in range(self.b):
            while self.slot_req[i] is None and queue:
                req = queue.pop(0)
                if len(req.prompt) >= self.s_max:
                    # the prompt alone fills the KV cache: prefill would
                    # never finish (step() only decodes once the pending
                    # prompt is drained) and pos would run past the cache
                    # bounds — the old server spun to max_iters here
                    req.error = (f"prompt length {len(req.prompt)} >= "
                                 f"cache size s_max={self.s_max}")
                    req.done = True
                    continue
                if self._slot_dirty[i]:
                    self._clear_slot(i)
                self.slot_req[i] = req
                self.slot_pending[i] = list(req.prompt)
                self.pos[i] = 0
                self._slot_dirty[i] = True

    def step(self, queue: list):
        """One decode iteration across all slots."""
        self._admit(queue)
        tok = np.zeros((self.b, 1), np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[i]:
                tok[i, 0] = self.slot_pending[i].pop(0)  # prefill token
            else:
                tok[i, 0] = req.out[-1]                  # autoregressive
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tok),
            jnp.asarray(self.pos))
        logits = np.asarray(logits)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.pos[i] += 1
            if self.slot_pending[i]:  # still ingesting the prompt
                self.prefill_tokens += 1
                continue
            self.decode_tokens += 1
            req.out.append(self._next_token(logits[i]))
            if len(req.out) >= req.max_new or \
                    self.pos[i] >= self.s_max - 1:
                req.done = True
                self.slot_req[i] = None

    def run(self, requests: list, max_iters: int = 10_000):
        queue = list(requests)
        it = 0
        while (queue or any(self.slot_req)) and it < max_iters:
            self.step(queue)
            it += 1
        return requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-15b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    cfg = get_arch(args.arch).reduced()
    server = BatchedServer(cfg, temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=8),
                    max_new=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    assert all(r.done for r in reqs)
    # decode tok/s is the serving figure of merit; lumping prefill into it
    # inflated the old number
    print(f"served {len(reqs)} requests in {dt:.1f}s: "
          f"{server.decode_tokens} decode tokens "
          f"({server.decode_tokens / dt:.1f} decode tok/s), "
          f"{server.prefill_tokens} prefill tokens "
          f"({server.tokens_served / dt:.1f} total tok/s)")


if __name__ == "__main__":
    main()
