"""Serving driver: batched decode with a continuous request queue.

A minimal production pattern: fixed-size batch slots, each slot owns a
sequence (prompt + generation state); finished slots are refilled from the
queue. One jitted serve_step decodes a token for every active slot per
iteration (static shapes — slots carry an active mask). Prefill for a new
request is token-by-token through the same step (CPU-friendly; a fused
prefill kernel is the obvious TPU upgrade and is what prefill_32k lowers).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch.mesh import make_host_mesh
from repro.models import build_model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    def __init__(self, cfg, batch_slots: int = 4, s_max: int = 128,
                 seed: int = 0, temperature: float = 0.0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init_params(jax.random.PRNGKey(seed))
        self.b = batch_slots
        self.s_max = s_max
        self.temperature = temperature
        self.cache = self.model.init_cache(batch_slots, s_max)
        self.pos = np.zeros(batch_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pending: List[list] = [[] for _ in range(batch_slots)]
        self._step = jax.jit(self.model.serve_step)
        self.tokens_served = 0

    def _admit(self, queue: list):
        for i in range(self.b):
            if self.slot_req[i] is None and queue:
                req = queue.pop(0)
                self.slot_req[i] = req
                self.slot_pending[i] = list(req.prompt)
                self.pos[i] = 0

    def step(self, queue: list):
        """One decode iteration across all slots."""
        self._admit(queue)
        tok = np.zeros((self.b, 1), np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[i]:
                tok[i, 0] = self.slot_pending[i].pop(0)  # prefill token
            else:
                tok[i, 0] = req.out[-1]                  # autoregressive
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tok),
            jnp.asarray(self.pos))
        logits = np.asarray(logits)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.pos[i] += 1
            self.tokens_served += 1
            if not self.slot_pending[i]:  # generating
                nxt = int(np.argmax(logits[i]))
                req.out.append(nxt)
                if len(req.out) >= req.max_new or \
                        self.pos[i] >= self.s_max - 1:
                    req.done = True
                    self.slot_req[i] = None

    def run(self, requests: list, max_iters: int = 10_000):
        queue = list(requests)
        it = 0
        while (queue or any(self.slot_req)) and it < max_iters:
            self.step(queue)
            it += 1
        return requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-15b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = get_arch(args.arch).reduced()
    server = BatchedServer(cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=8),
                    max_new=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    assert all(r.done for r in reqs)
    print(f"served {len(reqs)} requests, {server.tokens_served} tokens in "
          f"{dt:.1f}s ({server.tokens_served / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
