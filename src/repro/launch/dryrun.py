import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any jax import: jax locks the device
# count at first initialization (assignment: MULTI-POD DRY-RUN §0).

"""Multi-pod dry-run driver (assignment deliverable e).

For every (architecture x input-shape) cell and both production meshes
(16x16 single pod, 2x16x16 multi-pod) this driver:

  1. builds the jitted, sharded step (train / prefill / serve),
  2. ``.lower(**ShapeDtypeStructs).compile()`` — no buffers are allocated,
  3. prints ``compiled.memory_analysis()`` (proves the cell fits 16 GB HBM)
     and ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline),
  4. parses collective bytes out of the post-SPMD HLO,
  5. emits a JSON row consumed by EXPERIMENTS.md §Dry-run / §Roofline.

Run one cell:   python -m repro.launch.dryrun --cell gemma3-27b:train_4k:pod
Run everything: python -m repro.launch.dryrun --all --out experiments/dryrun
ICR cells:      python -m repro.launch.dryrun --cell icr-dust122b:sample:pod
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from concurrent.futures import ThreadPoolExecutor

import numpy as np


def _mesh_for(kind: str):
    import jax
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(kind == "multipod"))


def run_lm_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    import jax
    from repro.configs import SHAPES, get_arch, input_specs
    from repro.launch.steps import (
    active_param_count,
    choose_accum,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
    from repro.models import build_model
    from repro.roofline.analysis import (
        analyze_compiled,
        model_flops_decode,
        model_flops_train,
    )

    cfg = get_arch(arch)
    cell = SHAPES[shape]
    mesh = _mesh_for(mesh_kind)
    n_chips = int(np.prod(list(mesh.shape.values())))
    row = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "chips": n_chips, "status": "?"}

    if shape not in cfg.shape_cells:
        row.update(status="SKIP", reason=cfg.notes)
        return row

    model = build_model(cfg)
    n_active = active_param_count(model)
    row["params_total"] = model.param_count()
    row["params_active"] = n_active
    t0 = time.time()

    if cell.kind == "train":
        accum = choose_accum(model, cell, mesh)
        ts = make_train_step(cfg, mesh, accum=accum)
        row["accum"] = accum
        row["optimizer"] = ts.opt_name
        specs = input_specs(cfg, cell)
        jit_fn, batch_sh = ts.fn(specs)
        p_spec = ts.model.params_spec()
        o_spec = jax.eval_shape(ts.optimizer.init, p_spec)
        lowered = jit_fn.lower(p_spec, o_spec, specs)
        tokens = cell.global_batch * (
            min(cell.seq_len, cfg.encoder.max_target)
            if cfg.encoder else cell.seq_len)
        mf = model_flops_train(n_active, tokens) / n_chips
    elif cell.kind == "prefill":
        model, params_sh, jit_for = make_prefill_step(cfg, mesh)
        specs = input_specs(cfg, cell)
        specs.pop("labels", None)
        fn, _ = jit_for(specs)
        lowered = fn.lower(model.params_spec(), specs)
        tokens = cell.global_batch * (
            min(cell.seq_len, cfg.encoder.max_target)
            if cfg.encoder else cell.seq_len)
        mf = model_flops_decode(n_active, tokens) / n_chips
    else:  # decode
        s_max = min(cell.seq_len,
                    cfg.encoder.max_target) if cfg.encoder else cell.seq_len
        model, step, params_sh, cache_sh, c_spec = make_serve_step(
            cfg, mesh, cell.global_batch, s_max)
        specs = input_specs(cfg, cell)
        lowered = step.lower(model.params_spec(), c_spec, specs["tokens"],
                             specs["positions"])
        mf = model_flops_decode(n_active, cell.global_batch) / n_chips

    row["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    row["compile_s"] = round(time.time() - t1, 1)

    terms = analyze_compiled(compiled, model_flops_per_device=mf)
    row.update(status="OK", **terms.summary())
    return row


def run_icr_cell(arch: str, mesh_kind: str) -> dict:
    import jax
    from repro.compat import use_mesh
    from repro.configs.registry import ICR_ARCHS
    from repro.core.distributed import DistributedICR
    from repro.roofline.analysis import analyze_compiled

    spec = ICR_ARCHS[arch]
    mesh = _mesh_for(mesh_kind)
    n_chips = int(np.prod(list(mesh.shape.values())))
    axes = ("pod", "data", "model") if mesh_kind == "multipod" else \
        ("data", "model")
    row = {"arch": arch, "shape": "sample", "mesh": mesh_kind,
           "chips": n_chips, "status": "?"}
    icr = spec.build()
    dist = DistributedICR(icr=icr, mesh=mesh, axis_names=axes,
                          shard_axis=0 if spec.kind == "log1d" else 1)
    row["points"] = int(np.prod(icr.chart.final_shape))
    t0 = time.time()
    mats_spec = jax.eval_shape(icr.matrices)
    xi_spec = [jax.ShapeDtypeStruct(s, np.float32)
               for s in dist.xi_structure()]
    mat_sh, xi_sh, out_sh = dist.shardings()
    with use_mesh(mesh):
        fn = jax.jit(dist.apply_sqrt, in_shardings=(mat_sh, tuple(xi_sh)),
                     out_shardings=out_sh)
        lowered = fn.lower(mats_spec, tuple(xi_spec))
        row["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
    row["compile_s"] = round(time.time() - t1, 1)
    # useful flops: refinement einsums, Sum_l F_l * (2*fsz*csz + 2*fsz^2)
    c = icr.chart
    nd, fsz, csz = c.ndim, c.n_fsz**c.ndim, c.n_csz**c.ndim
    mf = 0.0
    for lvl in range(c.n_levels):
        f_l = np.prod([c.family_count(lvl, a) for a in range(nd)])
        mf += f_l * (2 * fsz * csz + 2 * fsz * fsz)
    terms = analyze_compiled(compiled, model_flops_per_device=mf / n_chips)
    row.update(status="OK", **terms.summary())
    return row


def run_cell(cell_id: str) -> dict:
    arch, shape, mesh_kind = cell_id.split(":")
    try:
        if arch.startswith("icr-"):
            return run_icr_cell(arch, mesh_kind)
        return run_lm_cell(arch, shape, mesh_kind)
    except Exception as exc:  # noqa: BLE001
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "FAIL", "error": f"{type(exc).__name__}: {exc}",
                "traceback": traceback.format_exc()[-2000:]}


def all_cells():
    from repro.configs import SHAPES, ARCHS
    from repro.configs.registry import ICR_ARCHS
    cells = []
    for arch in sorted(ARCHS):
        for shape in SHAPES:
            for mesh_kind in ("pod", "multipod"):
                cells.append(f"{arch}:{shape}:{mesh_kind}")
    for arch in sorted(ICR_ARCHS):
        for mesh_kind in ("pod", "multipod"):
            cells.append(f"{arch}:sample:{mesh_kind}")
    return cells


def _run_in_subprocess(cell_id: str, timeout: int = 3600) -> dict:
    """Each cell gets a fresh process: jax device state is per-process and a
    pathological compile can't take down the sweep."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--cell", cell_id,
           "--json-only"]
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    try:
        out = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=timeout, env=env)
        for line in reversed(out.stdout.strip().splitlines()):
            if line.startswith("{"):
                return json.loads(line)
        arch, shape, mesh_kind = cell_id.split(":")
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "FAIL",
                "error": (out.stderr or out.stdout)[-1500:]}
    except subprocess.TimeoutExpired:
        arch, shape, mesh_kind = cell_id.split(":")
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "TIMEOUT"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape:mesh — run one cell in-proc")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--arch", help="run all shapes/meshes for one arch")
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--json-only", action="store_true")
    args = ap.parse_args()

    if args.cell:
        row = run_cell(args.cell)
        if args.json_only:
            print(json.dumps(row))
        else:
            print(json.dumps(row, indent=2))
        return 0 if row["status"] in ("OK", "SKIP") else 1

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c.startswith(args.arch + ":")]
    os.makedirs(args.out, exist_ok=True)
    results = []
    with ThreadPoolExecutor(max_workers=args.workers) as pool:
        for row in pool.map(_run_in_subprocess, cells):
            results.append(row)
            tag = f"{row['arch']}:{row.get('shape')}:{row['mesh']}"
            print(f"[{len(results)}/{len(cells)}] {tag}: {row['status']} "
                  f"dom={row.get('dominant', '-')} "
                  f"frac={row.get('roofline_fraction', 0):.3f}",
                  flush=True)
            with open(os.path.join(args.out, "dryrun.json"), "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    print(f"done: {n_ok} OK, {n_skip} SKIP, "
          f"{len(results) - n_ok - n_skip} FAIL")
    return 0 if n_ok + n_skip == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
