"""Fault-tolerant checkpointing (assignment: checkpoint/restart).

Design (multi-host ready, single-host exercised here):

  * **atomic publish** — writes go to ``step_N.tmp/`` and are renamed to
    ``step_N/`` only after every leaf + manifest is fsync'd; a crash mid-save
    can never corrupt the latest checkpoint;
  * **async** — ``save(...)`` snapshots device arrays to host (blocking only
    on transfer) and hands serialization to a background thread, so the
    train loop overlaps checkpoint I/O with the next steps;
  * **sharding-aware** — each process writes only the addressable shards of
    every leaf; on restore, leaves are placed back with the recorded
    PartitionSpec against the *current* mesh (works after an elastic
    re-mesh, see distributed/elastic.py);
  * **retention** — keeps the newest ``keep`` checkpoints, never deleting
    the one currently being restored from.

Format: one ``.npy`` per leaf (tree-path-encoded filename) + a JSON
manifest with the treedef, dtypes and PartitionSpecs.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d+)$")


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "__".join(parts) or "leaf"


def _spec_to_json(spec) -> list:
    if spec is None:
        return []
    out = []
    for axes in spec:
        if axes is None:
            out.append(None)
        elif isinstance(axes, str):
            out.append(axes)
        else:
            out.append(list(axes))
    return out


def _spec_from_json(lst) -> P:
    dims = []
    for axes in lst:
        if axes is None:
            dims.append(None)
        elif isinstance(axes, str):
            dims.append(axes)
        else:
            dims.append(tuple(axes))
    return P(*dims)


def save_pytree(tree: PyTree, directory: str, spec_tree: PyTree = None):
    """Blocking single-shot save (the async path calls this in a thread)."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    specs = (jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)
        if spec_tree is not None else [None] * len(leaves))
    manifest = {"leaves": []}
    for (path, leaf), spec in zip(leaves, specs):
        name = _path_str(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
            # numpy can't serialize ml_dtypes; store as f32, restore via
            # the manifest-recorded dtype
            arr = np.asarray(leaf, np.float32)
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append({
            "name": name,
            "dtype": str(leaf.dtype) if hasattr(leaf, "dtype") else "float32",
            "shape": list(np.shape(leaf)),
            "spec": _spec_to_json(spec),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)  # atomic publish


def load_pytree(directory: str, like: PyTree,
                mesh: Optional[Mesh] = None) -> PyTree:
    """Restore into the structure of `like` (values ignored). With `mesh`,
    leaves are device_put with their recorded PartitionSpecs."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    leaves_meta = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_meta[0]:
        name = _path_str(path)
        meta = by_name[name]
        arr = np.load(os.path.join(directory, name + ".npy"))
        val = jax.numpy.asarray(arr).astype(meta["dtype"])
        if mesh is not None and meta["spec"]:
            val = jax.device_put(
                val, NamedSharding(mesh, _spec_from_json(meta["spec"])))
        out.append(val)
    return jax.tree_util.tree_unflatten(leaves_meta[1], out)


class CheckpointManager:
    """Async checkpoint manager with retention and latest-step discovery."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write ----------------------------------------------------------------
    def save(self, step: int, tree: PyTree, spec_tree: PyTree = None,
             blocking: bool = False):
        self.wait()  # one in-flight save at a time
        # snapshot to host while devices are idle; cheap for sharded arrays
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        target = os.path.join(self.root, f"step_{step}")

        def work():
            try:
                save_pytree(host_tree, target, spec_tree)
                self._gc()
            except BaseException as exc:  # noqa: BLE001
                self._error = exc

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- read -----------------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.root):
            m = _STEP_RE.match(d)
            if m and os.path.exists(
                    os.path.join(self.root, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: PyTree, step: Optional[int] = None,
                mesh: Optional[Mesh] = None) -> tuple:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        tree = load_pytree(os.path.join(self.root, f"step_{step}"), like,
                           mesh)
        return step, tree

    # -- retention ---------------------------------------------------------------
    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"),
                          ignore_errors=True)
