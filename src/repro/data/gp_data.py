"""Synthetic GP regression datasets on charted grids (paper §5 setting)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def charted_gp_dataset(icr, key, *, obs_frac: float = 0.5,
                       noise_std: float = 0.05):
    """Draw a ground-truth field from the ICR prior, observe a random subset
    with Gaussian noise. Returns (truth, obs_idx, y)."""
    k1, k2, k3 = jax.random.split(key, 3)
    truth = icr.sample(k1).reshape(-1)
    n = truth.shape[0]
    n_obs = max(int(n * obs_frac), 1)
    obs_idx = jnp.sort(jax.random.choice(k2, n, (n_obs,), replace=False))
    y = truth[obs_idx] + noise_std * jax.random.normal(k3, (n_obs,))
    return truth, obs_idx, y
