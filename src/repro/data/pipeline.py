"""Deterministic synthetic data pipeline.

Production shape: per-host sharded loading (each host materializes only its
addressable slice), double-buffered prefetch on a background thread, and
step-indexed determinism — batch(step) is a pure function of (seed, step),
so restarts from a checkpoint resume the exact data order with no persisted
iterator state (the same property real pipelines get from deterministic
sharded file indexes).

The token stream is a mixture of Zipf-distributed unigrams and short
repeated motifs, giving next-token structure a model can actually learn in
a few hundred steps (examples/lm_train.py).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np
import jax


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 8
    n_motifs: int = 64

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def _motifs(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed + 7)
        return rng.integers(0, self.vocab_size,
                            (self.n_motifs, self.motif_len))

    def batch(self, step: int, *, host_id: int = 0,
              host_count: int = 1) -> dict:
        """Batch for `step`; hosts materialize disjoint row slices."""
        assert self.global_batch % host_count == 0
        rows = self.global_batch // host_count
        rng = self._rng(step * host_count + host_id)
        motifs = self._motifs()
        # Zipf-ish unigram floor
        ranks = np.arange(1, self.vocab_size + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(self.vocab_size, size=(rows, self.seq_len + 1),
                          p=probs)
        # plant motifs: ~25% of positions covered by copyable patterns
        n_plant = max((self.seq_len // self.motif_len) // 4, 1)
        for r in range(rows):
            for _ in range(n_plant):
                m = motifs[rng.integers(0, self.n_motifs)]
                at = rng.integers(0, self.seq_len + 1 - self.motif_len)
                toks[r, at : at + self.motif_len] = m
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


def make_batch_iterator(source: SyntheticLMData, *, start_step: int = 0,
                        prefetch: int = 2, host_id: int = 0,
                        host_count: int = 1,
                        shardings=None) -> Iterator[dict]:
    """Double-buffered iterator: batch N+1 is built (and device_put) while
    the model runs step N. Restart-safe: pass the checkpointed step as
    `start_step` and the stream resumes exactly."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def put(step):
        b = source.batch(step, host_id=host_id, host_count=host_count)
        if shardings is not None:
            b = jax.tree.map(jax.device_put, b, shardings)
        return b

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(put(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
