from .pipeline import SyntheticLMData, make_batch_iterator
from .gp_data import charted_gp_dataset

__all__ = ["SyntheticLMData", "make_batch_iterator", "charted_gp_dataset"]
