"""Fault tolerance & straggler mitigation for the training driver.

On a real fleet the runtime signals failures as exceptions from the jitted
step (device halt, DCN timeout) or through the coordination service. The
driver policy implemented here (launch/train.py):

  1. every step runs under the FaultSupervisor; an exception triggers
     restore-from-latest-checkpoint and (optionally) an elastic re-mesh to
     the surviving device set;
  2. the StragglerMonitor tracks a robust step-time estimate (median + MAD);
     a step slower than ``threshold`` MADs is counted against the culprit —
     on TPU fleets, persistent stragglers get the host marked for hot-spare
     swap at the next checkpoint boundary (here: reported via callback);
  3. checkpoint cadence adapts: after a failure the next checkpoint is
     immediate, then cadence decays back to the configured interval.

Tests inject synthetic failures/stragglers (tests/test_fault.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


class DeviceLossError(RuntimeError):
    """A device (or its runtime) is gone.

    Unlike a transient collective hiccup this is **not retryable in place**:
    re-running the slab on the same mesh cannot succeed. The serving layer
    catches it and runs the re-plan path (shrink mesh → remesh cached state
    → rewarm executable → replay the in-flight slab); the training driver
    maps it onto restore + elastic re-mesh.
    """

    def __init__(self, device_ids, message: str = ""):
        self.device_ids = tuple(int(i) for i in device_ids)
        super().__init__(
            message or f"lost device(s) {list(self.device_ids)}")


@dataclasses.dataclass
class StragglerMonitor:
    threshold_mads: float = 6.0
    window: int = 64
    min_samples: int = 8
    _times: list = dataclasses.field(default_factory=list)
    stragglers: int = 0

    def observe(self, step_time: float) -> bool:
        """Record a step time; returns True if it was a straggler step."""
        times = self._times
        is_straggler = False
        if len(times) >= self.min_samples:
            med = float(np.median(times))
            mad = float(np.median(np.abs(np.asarray(times) - med))) + 1e-9
            if step_time > med + self.threshold_mads * mad and \
                    step_time > 1.5 * med:
                is_straggler = True
                self.stragglers += 1
        times.append(step_time)
        if len(times) > self.window:
            times.pop(0)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0


@dataclasses.dataclass
class FaultSupervisor:
    """Wraps the train step with restore-and-retry semantics."""

    restore_fn: Callable[[], tuple]        # () -> (step, state)
    max_restarts: int = 5
    on_failure: Optional[Callable] = None  # (exc, restart_count) -> None
    restarts: int = 0

    def run(self, step_fn: Callable, state, step: int):
        """Run one step; on failure restore from checkpoint and signal the
        caller to rebuild (returns (state, step, failed=True))."""
        try:
            return step_fn(state), step + 1, False
        except Exception as exc:  # noqa: BLE001 — any device/runtime error
            self.restarts += 1
            if self.on_failure is not None:
                self.on_failure(exc, self.restarts)
            if self.restarts > self.max_restarts:
                raise
            step, state = self.restore_fn()
            return state, step, True


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/timeout/backoff policy for one slab execution.

    ``timeout_s`` is *post-hoc*: a blocking XLA dispatch cannot be aborted
    portably, so an attempt that completes but overruns the deadline is
    counted as a timeout (and feeds the straggler monitor) rather than
    cancelled mid-flight.
    """

    max_retries: int = 2
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    timeout_s: float = 120.0

    def backoff(self, attempt: int) -> float:
        return self.backoff_s * self.backoff_factor ** attempt


@dataclasses.dataclass
class ServingFaultSupervisor:
    """Request-level fault policy for the GP serving layer (DESIGN.md §15).

    Transient slab errors are retried in place with exponential backoff;
    :class:`DeviceLossError` is never retried in place — it propagates to
    the server's detect → remesh → rewarm → replay path. Every attempt's
    wall time feeds the :class:`StragglerMonitor`, so serving step times
    drive the same straggler detection as training steps.
    """

    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    monitor: StragglerMonitor = dataclasses.field(
        default_factory=StragglerMonitor)
    device_losses: int = 0
    transient_retries: int = 0
    timeouts: int = 0

    def execute(self, attempt_fn: Callable[[], "object"]):
        """Run one slab attempt to completion, retrying transient errors."""
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                out = attempt_fn()
            except DeviceLossError:
                self.device_losses += 1
                raise
            except Exception:  # noqa: BLE001 — runtime/collective errors
                if attempt >= self.retry.max_retries:
                    raise
                self.transient_retries += 1
                time.sleep(self.retry.backoff(attempt))
                attempt += 1
                continue
            dt = time.perf_counter() - t0
            if dt > self.retry.timeout_s:
                self.timeouts += 1
            self.monitor.observe(dt)
            return out

    def metrics(self) -> dict:
        return {
            "device_losses": self.device_losses,
            "transient_retries": self.transient_retries,
            "timeouts": self.timeouts,
            "stragglers": self.monitor.stragglers,
            "median_step_s": self.monitor.median,
        }
