"""Fault tolerance & straggler mitigation for the training driver.

On a real fleet the runtime signals failures as exceptions from the jitted
step (device halt, DCN timeout) or through the coordination service. The
driver policy implemented here (launch/train.py):

  1. every step runs under the FaultSupervisor; an exception triggers
     restore-from-latest-checkpoint and (optionally) an elastic re-mesh to
     the surviving device set;
  2. the StragglerMonitor tracks a robust step-time estimate (median + MAD);
     a step slower than ``threshold`` MADs is counted against the culprit —
     on TPU fleets, persistent stragglers get the host marked for hot-spare
     swap at the next checkpoint boundary (here: reported via callback);
  3. checkpoint cadence adapts: after a failure the next checkpoint is
     immediate, then cadence decays back to the configured interval.

Tests inject synthetic failures/stragglers (tests/test_fault.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    threshold_mads: float = 6.0
    window: int = 64
    min_samples: int = 8
    _times: list = dataclasses.field(default_factory=list)
    stragglers: int = 0

    def observe(self, step_time: float) -> bool:
        """Record a step time; returns True if it was a straggler step."""
        times = self._times
        is_straggler = False
        if len(times) >= self.min_samples:
            med = float(np.median(times))
            mad = float(np.median(np.abs(np.asarray(times) - med))) + 1e-9
            if step_time > med + self.threshold_mads * mad and \
                    step_time > 1.5 * med:
                is_straggler = True
                self.stragglers += 1
        times.append(step_time)
        if len(times) > self.window:
            times.pop(0)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0


@dataclasses.dataclass
class FaultSupervisor:
    """Wraps the train step with restore-and-retry semantics."""

    restore_fn: Callable[[], tuple]        # () -> (step, state)
    max_restarts: int = 5
    on_failure: Optional[Callable] = None  # (exc, restart_count) -> None
    restarts: int = 0

    def run(self, step_fn: Callable, state, step: int):
        """Run one step; on failure restore from checkpoint and signal the
        caller to rebuild (returns (state, step, failed=True))."""
        try:
            return step_fn(state), step + 1, False
        except Exception as exc:  # noqa: BLE001 — any device/runtime error
            self.restarts += 1
            if self.on_failure is not None:
                self.on_failure(exc, self.restarts)
            if self.restarts > self.max_restarts:
                raise
            step, state = self.restore_fn()
            return state, step, True
