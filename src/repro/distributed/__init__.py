from .sharding import (
    batch_spec,
    cache_specs,
    param_specs,
    shardings_for,
    with_batch_constraint,
)
from .compression import compressed_psum, make_error_feedback_state
from .elastic import remesh
from .fault import FaultSupervisor, StragglerMonitor

__all__ = [
    "param_specs", "batch_spec", "cache_specs", "shardings_for",
    "with_batch_constraint",
    "compressed_psum", "make_error_feedback_state",
    "remesh", "FaultSupervisor", "StragglerMonitor",
]
