"""Fault-injection harness for the sharded GP serving subsystem (§15).

Chaos faults are injected at the slab-execution boundary through the
server's ``fault_injector`` hook — the same place a real runtime raises
(device halt, collective timeout) — so the recovery path exercised here
(detect → remesh → rewarm → replay) is exactly the production path:

  * :class:`KillDevice` — raise a :class:`DeviceLossError` for one (or
    more) mesh devices at a chosen slab attempt; the server must shrink
    the mesh, re-plan, and replay the in-flight slab bit-identically.
  * :class:`Straggler` — a delayed-collective straggler: sleep inside the
    attempt so the slab wall time spikes; the serving-side
    :class:`~repro.distributed.fault.StragglerMonitor` must flag it.
  * :func:`poison_request` — a NaN-poisoned ξ request; admission must
    reject it with a structured error before it can touch a slab.

The acceptance suite (``--check``) runs on 8 virtual CPU devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.distributed.chaos --check

``--check-solvers`` runs the §16 *solver* chaos suite on the same mesh:
kill a device mid-CG-solve (checkpoint/resume on the shrunk mesh, zero
dropped RHS) and NaN-poison one RHS column of a sharded batched solve
(quarantine isolation — siblings bit-identical to the clean run).

``--bench`` emits JSON benchmark rows (mesh 1 vs 8 throughput and the
fault → first-completed-slab recovery time) consumed by
``benchmarks.speed.run_serving_mesh``.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # before jax initializes (conftest rule: only
    # standalone drivers may set XLA_FLAGS, never the test process)
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses
import json
import time
from typing import List

import numpy as np

from .fault import DeviceLossError, ServingFaultSupervisor, StragglerMonitor


@dataclasses.dataclass
class KillDevice:
    """Lose device(s) at slab attempt ``at_slab`` (0-based attempt index)."""

    at_slab: int
    device_indices: tuple = (0,)


@dataclasses.dataclass
class Straggler:
    """Delay slab attempt ``at_slab`` by ``delay_s`` (slow collective)."""

    at_slab: int
    delay_s: float = 0.25


class ChaosInjector:
    """``GPFieldServer.fault_injector`` hook: fires each fault once, at its
    configured slab-attempt index, then lets execution proceed normally."""

    def __init__(self, faults: List):
        self.pending = list(faults)
        self.fired: list = []
        self.attempts = 0
        self.fault_times: list = []  # perf_counter at each fired fault

    def __call__(self, server):
        idx = self.attempts
        self.attempts += 1
        due = [f for f in self.pending if f.at_slab <= idx]
        kill_ids: list = []
        for f in due:
            self.pending.remove(f)
            self.fired.append((idx, f))
            if isinstance(f, Straggler):
                time.sleep(f.delay_s)
            elif isinstance(f, KillDevice):
                devs = (list(np.asarray(server.mesh.devices).flat)
                        if server.mesh is not None else [])
                if devs:
                    kill_ids.extend(
                        int(devs[i % len(devs)].id) for i in f.device_indices)
                else:
                    kill_ids.append(0)
        if kill_ids:
            self.fault_times.append(time.perf_counter())
            raise DeviceLossError(sorted(set(kill_ids)))


def poison_request(icr, kind: str = "moments", n: int = 3, seed: int = 0):
    """A request whose ξ override carries a NaN — admission must reject it
    (code ``xi-nonfinite``) before it shares a slab with healthy traffic."""
    from repro.launch.serve_gp import GPRequest

    xi = [np.zeros(s, np.float32) for s in icr.xi_shapes()]
    xi[-1].flat[0] = np.nan
    return GPRequest(kind=kind, n=n, seed=seed, xi=xi)


# -- acceptance checks (run under 8 virtual devices) ----------------------------
def _mk_server(mesh, *, slab: int = 8, shard: str = "samples",
               injector=None, supervisor=None, scenario: str = "tod"):
    from repro.launch.serve_gp import (GPFieldServer, SCENARIOS,
                                       demo_posterior, scenario_chart)

    chart = scenario_chart(scenario, quick=True)
    post = demo_posterior(chart, SCENARIOS[scenario])
    return GPFieldServer(post, slab=slab, mesh=mesh, shard=shard,
                         supervisor=supervisor, fault_injector=injector)


def _requests():
    from repro.launch.serve_gp import GPRequest

    return [GPRequest(kind="sample", n=5, seed=11),
            GPRequest(kind="moments", n=9, seed=12),
            GPRequest(kind="sample", n=3, seed=13)]


def _assert_equal_results(base, got, *, exact: bool = True, tol: float = 0.0):
    for a, b in zip(base, got):
        assert a.done and b.done and b.error is None, (a, b.error)
        pairs = (list(zip(a.fields, b.fields)) if a.kind == "sample"
                 else [(a.mean, b.mean), (a.std, b.std)])
        for xa, xb in pairs:
            if exact:
                assert np.array_equal(np.asarray(xa), np.asarray(xb)), \
                    "results differ from the unfaulted run"
            else:
                np.testing.assert_allclose(xa, xb, rtol=tol, atol=tol)


def _full_mesh(axis: str = "data"):
    import jax
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()), (axis,))


def check_kill_midstream() -> str:
    """ISSUE 8 acceptance: kill one device mid-stream on an 8-mesh.
    Zero dropped requests, a re-planned mesh of 7, bit-identical results
    vs the unfaulted run, and a provable executable-cache invalidation."""
    import jax

    n_dev = len(jax.devices())
    base = _requests()
    _mk_server(_full_mesh()).run(base)

    inj = ChaosInjector([KillDevice(at_slab=1, device_indices=(3,))])
    srv = _mk_server(_full_mesh(), injector=inj)
    fp_before = srv.cache_key_fingerprint()["digest"]
    misses_before = srv.cache_misses
    got = _requests()
    srv.run(got)

    assert inj.fired, "fault never fired"
    assert all(r.done and r.error is None for r in got), "dropped requests"
    assert srv.mesh is not None, "mesh collapsed instead of shrinking"
    live = int(np.asarray(srv.mesh.devices).size)
    assert live == n_dev - 1, f"expected mesh of {n_dev - 1}, got {live}"
    assert srv.replans == 1 and srv.replayed_slabs >= 1, srv.metrics()
    # the re-mesh is a *deliberate* executable-cache miss, never a stale hit
    assert srv.cache_misses == misses_before + 1, srv.metrics()
    assert srv.cache_key_fingerprint()["digest"] != fp_before
    _assert_equal_results(base, got, exact=True)
    return (f"kill-midstream: mesh {n_dev}->{live}, "
            f"{srv.replayed_slabs} slab(s) replayed bit-identically, "
            f"cache miss on re-mesh")


def check_collapse_to_single_device() -> str:
    """Degradation ladder: losing all but one device drops to the
    single-device path and keeps serving, with the collapse recorded as a
    structured degradation."""
    import jax

    n_dev = len(jax.devices())
    base = _requests()
    _mk_server(None).run(base)

    inj = ChaosInjector([KillDevice(at_slab=0,
                                    device_indices=tuple(range(n_dev - 1)))])
    srv = _mk_server(_full_mesh(), injector=inj)
    got = _requests()
    srv.run(got)

    assert all(r.done and r.error is None for r in got)
    assert srv.mesh is None and srv.serving_mode.startswith("single")
    assert any(d.applied == "unsharded" for d in srv.degradations), \
        srv.metrics()
    _assert_equal_results(base, got, exact=True)
    return (f"collapse: {n_dev}->1 device, degraded to "
            f"{srv.serving_mode!r}, results bit-identical to unsharded")


def check_straggler_detection() -> str:
    """A delayed-collective straggler must be flagged by the serving-side
    StragglerMonitor fed from slab step times."""
    sup = ServingFaultSupervisor(monitor=StragglerMonitor(min_samples=6))
    inj = ChaosInjector([Straggler(at_slab=10, delay_s=0.5)])
    srv = _mk_server(_full_mesh(), injector=inj, supervisor=sup)
    from repro.launch.serve_gp import GPRequest

    srv.run([GPRequest(kind="sample", n=96, seed=5)])  # 12 slabs of 8
    assert inj.fired, "straggler never fired"
    assert sup.monitor.stragglers >= 1, sup.metrics()
    return (f"straggler: flagged {sup.monitor.stragglers} of "
            f"{srv.slabs_run} slabs (median {sup.monitor.median*1e3:.1f} ms)")


def check_chart_sharded_kill() -> str:
    """Chart-sharded serving (DistributedICR halo body) survives a device
    loss: the ring shrinks to the largest feasible size and results match
    the unsharded server to fp tolerance (halo math reorders reductions)."""
    base = _requests()
    _mk_server(None).run(base)

    inj = ChaosInjector([KillDevice(at_slab=1, device_indices=(2,))])
    srv = _mk_server(_full_mesh("space"), shard="chart", injector=inj)
    got = _requests()
    srv.run(got)

    assert all(r.done and r.error is None for r in got)
    assert srv.replans == 1, srv.metrics()
    _assert_equal_results(base, got, exact=False, tol=1e-5)
    ring = (int(np.asarray(srv.mesh.devices).size)
            if srv.mesh is not None else 1)
    return f"chart-kill: ring shrank to {ring}, results within 1e-5"


def check_poison_isolation() -> str:
    """A NaN-ξ request packed beside healthy traffic is rejected at
    admission and the healthy results are untouched."""
    from repro.launch.serve_gp import GPRequest

    srv = _mk_server(_full_mesh())
    clean = GPRequest(kind="moments", n=6, seed=2)
    _mk_server(_full_mesh()).run([clean])

    bad = poison_request(srv.posterior.icr)
    good = GPRequest(kind="moments", n=6, seed=2)
    srv.run([bad, good])
    assert bad.error is not None and bad.error.code == "xi-nonfinite"
    assert good.error is None
    assert np.array_equal(good.mean, clean.mean)
    assert np.isfinite(good.mean).all() and np.isfinite(good.std).all()
    return "poison: rejected at admission, healthy neighbor bit-identical"


CHECKS = [check_kill_midstream, check_collapse_to_single_device,
          check_straggler_detection, check_chart_sharded_kill,
          check_poison_isolation]


# -- §16 solver chaos (kind="condition" / guarded batched CG) -------------------
def _condition_inputs(srv):
    icr = srv.posterior.icr
    n = int(np.prod(icr.chart.final_shape))
    obs_idx = np.arange(0, n, 4)
    rng = np.random.default_rng(3)
    y = (np.sin(np.linspace(0.0, 6.0, obs_idx.size))
         + 0.05 * rng.standard_normal(obs_idx.size))
    return y, obs_idx


def check_solver_kill_midsolve() -> str:
    """Kill one of 8 devices mid-CG-solve: the solve must checkpoint,
    re-plan onto the 7-survivor mesh, resume from the saved carry and
    finish with zero dropped RHS — the posterior mean matching the
    unfaulted run (fp tolerance: shard reductions reorder on 7 vs 8)."""
    import jax
    from repro.launch.serve_gp import GPRequest

    n_dev = len(jax.devices())
    base_srv = _mk_server(_full_mesh())
    base_srv.solver_checkpoint_every = 2
    y, obs_idx = _condition_inputs(base_srv)
    base = GPRequest(kind="condition", n=7, seed=21, y=y, obs_idx=obs_idx)
    base_srv.run([base])
    assert base.error is None and base.report.ok, base.report

    inj = ChaosInjector([KillDevice(at_slab=1, device_indices=(3,))])
    srv = _mk_server(_full_mesh(), injector=inj)
    srv.solver_checkpoint_every = 2
    req = GPRequest(kind="condition", n=7, seed=21, y=y, obs_idx=obs_idx)
    srv.run([req])

    assert inj.fired, "fault never fired"
    assert req.error is None, req.error
    assert req.report.ok, f"dropped RHS: {req.report.summary()}"
    assert req.report.resumes, "no checkpoint resume recorded"
    assert srv.mesh is not None, "mesh collapsed instead of shrinking"
    live = int(np.asarray(srv.mesh.devices).size)
    assert live == n_dev - 1, f"expected mesh of {n_dev - 1}, got {live}"
    rel = (np.linalg.norm(req.mean - base.mean)
           / np.linalg.norm(base.mean))
    assert rel < 1e-5, f"resumed mean off by rel {rel:.2e}"
    np.testing.assert_allclose(req.std, base.std, atol=1e-4)
    ev = req.report.resumes[0]
    return (f"solver-kill: mesh {n_dev}->{live} at iter {ev.at_iter}, "
            f"resumed from checkpoint step {ev.restored_step}, "
            f"{req.report.n_rhs} RHS all converged (mean rel {rel:.1e})")


def check_solver_divergence_isolation() -> str:
    """NaN-poison one RHS column of a mesh-sharded batched solve: the
    column is quarantined (iterate zeroed, status nonfinite) and every
    sibling column is bit-identical to the clean run."""
    import jax
    import jax.numpy as jnp
    from repro.launch.serve_gp import (SCENARIOS, demo_posterior,
                                      scenario_chart)
    from repro.solvers import (CGConfig, build_condition_system,
                               obs_operator, pcg_solve)

    mesh = _full_mesh()
    chart = scenario_chart("tod", quick=True)
    post = demo_posterior(chart, SCENARIOS["tod"])
    icr = post.icr
    n = int(np.prod(chart.final_shape))
    op = obs_operator(icr, obs_idx=np.arange(0, n, 4))
    system = build_condition_system(icr, op, 0.05 ** 2, mesh=mesh)
    k = len(jax.devices())
    rng = np.random.default_rng(5)
    b = rng.standard_normal((k, op.n_obs)).astype(np.float32)
    cfg = CGConfig(rtol=1e-7, max_iters=200)
    x_clean, _, _, _ = pcg_solve(system.matvec, jnp.asarray(b),
                                 precond=system.precond, cfg=cfg)
    bad = b.copy()
    bad[3, 0] = np.nan
    x_bad, st_bad, _, _ = pcg_solve(system.matvec, jnp.asarray(bad),
                                    precond=system.precond, cfg=cfg)
    keep = [i for i in range(k) if i != 3]
    assert np.array_equal(np.asarray(x_clean)[keep],
                          np.asarray(x_bad)[keep]), \
        "sibling columns perturbed by the poisoned RHS"
    assert int(np.asarray(st_bad["status"])[3]) == 2, st_bad  # NONFINITE
    assert np.all(np.asarray(x_bad)[3] == 0.0), "quarantine not zeroed"
    return (f"solver-isolation: NaN column quarantined on mesh {k}, "
            f"{len(keep)} siblings bit-identical to the clean run")


SOLVER_CHECKS = [check_solver_kill_midsolve,
                 check_solver_divergence_isolation]


def run_checks(checks=None, label: str = "chaos") -> int:
    import jax

    checks = CHECKS if checks is None else checks
    n_dev = len(jax.devices())
    print(f"{label} acceptance suite on {n_dev} {jax.default_backend()} "
          "devices")
    if n_dev < 2:
        print("FAIL need >= 2 devices (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)")
        return 1
    failed = 0
    for check in checks:
        try:
            msg = check()
        except Exception as exc:  # noqa: BLE001 — report every check
            failed += 1
            print(f"FAIL {check.__name__}: {type(exc).__name__}: {exc}")
        else:
            print(f"PASS {msg}")
    return 1 if failed else 0


# -- benchmark mode (consumed by benchmarks.speed.run_serving_mesh) -------------
def run_bench(quick: bool = True) -> list:
    """Throughput at mesh sizes 1 and N plus fault-recovery time, as JSON
    rows on stdout (one object per line, prefixed ``BENCH ``)."""
    import jax
    from jax.sharding import Mesh
    from repro.launch.serve_gp import GPRequest

    devs = jax.devices()
    rows = []
    for n_mesh in sorted({1, len(devs)}):
        mesh = (None if n_mesh == 1
                else Mesh(np.asarray(devs[:n_mesh]), ("data",)))
        srv = _mk_server(mesh, slab=8)
        work = lambda: [GPRequest(kind="sample", n=32, seed=9)]
        srv.run(work())  # cold: compile
        t0 = time.perf_counter()
        reps = 2 if quick else 8
        for _ in range(reps):
            srv.run(work())
        dt = time.perf_counter() - t0
        rows.append({"mesh": n_mesh, "mode": srv.serving_mode,
                     "samples_per_s": 32 * reps / dt,
                     "warm_s": dt / reps})
    # recovery: kill one device mid-stream, measure fault -> first slab
    if len(devs) >= 2:
        inj = ChaosInjector([KillDevice(at_slab=1, device_indices=(1,))])
        srv = _mk_server(Mesh(np.asarray(devs), ("data",)), injector=inj)
        srv.run([GPRequest(kind="sample", n=32, seed=9)])
        rows.append({"mesh": len(devs), "mode": "recovery",
                     "recovery_s": srv.last_recovery_s,
                     "replayed_slabs": srv.replayed_slabs})
    for row in rows:
        print("BENCH " + json.dumps(row, sort_keys=True))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="run the chaos acceptance suite")
    ap.add_argument("--check-solvers", action="store_true",
                    help="run the §16 solver chaos suite (mid-solve kill "
                         "+ sharded divergence isolation)")
    ap.add_argument("--bench", action="store_true",
                    help="emit mesh-throughput + recovery benchmark rows")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rc = 0
    if args.check:
        rc = run_checks()
    if args.check_solvers:
        rc = max(rc, run_checks(SOLVER_CHECKS, label="solver chaos"))
    if args.bench:
        run_bench(quick=not args.full)
    if not (args.check or args.check_solvers or args.bench):
        rc = run_checks()
    raise SystemExit(rc)


if __name__ == "__main__":
    main()
