"""Sharding rules: param/optimizer/activation/cache PartitionSpecs.

Strategy (DESIGN.md §5) — FSDP x TP hybrid:
  * column-parallel weights (in -> heads/ff/experts): last dim over 'model',
    second-to-last over data axes (FSDP slice; GSPMD all-gathers at use and
    reduce-scatters the gradient — ZeRO-3 semantics for free);
  * row-parallel weights (wo / down): 'model' on the input dim, data on the
    output dim;
  * MoE expert stacks: experts over 'model' (expert parallelism), FSDP over
    the next dim;
  * embedding (V, D): V over 'model', D over data; lm_head (D, V): V over
    'model' so logits are vocab-sharded (the chunked loss relies on it);
  * optimizer state inherits its parameter's spec leaf-by-leaf (moments have
    identical shapes; adafactor row/col stats drop the factored-away axis);
  * KV caches: heads over 'model' when divisible, else the *sequence* dim
    (distributed flash-decode: GSPMD inserts the softmax psums);
  * every rule degrades gracefully: a dim that doesn't divide its mesh axes
    is replicated instead.

Multi-pod: pass data_axes=("pod", "data") — batch and FSDP shards then span
pods; gradient all-reduces become hierarchical (ICI within pod, DCN across).
"""
from __future__ import annotations

from typing import Any

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# leaf-name -> role tables (names come from models/*.py init functions)
_COL = {
    "wq", "wk", "wv", "gate", "up", "w_in", "wqkv", "w_gates", "w_dq",
    "w_uq", "w_dkv", "w_uk", "w_uv", "wo_gate", "wif", "router", "r_gates",
    "lm_head", "frontend", "pos_embed",
}
_ROW = {"wo", "down", "w_out"}
_EMBED = {"table"}
# always replicated (tiny, used every layer; stacked variants included)
_REPLICATE = {"scale", "b_up", "b_down", "bq", "bk", "bv", "bo",
              "a_log", "dt_bias", "d_skip"}


def _axes_size(mesh: Mesh, axes) -> int:
    if not axes:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh: Mesh, dim: int, axes):
    """Return axes if dim divides their product, else None (replicate)."""
    return axes if axes and dim % _axes_size(mesh, axes) == 0 else None


def _leaf_spec(path_names, shape, mesh, data_axes, model_axes) -> P:
    name = path_names[-1] if path_names else ""
    nd = len(shape)
    spec = [None] * nd
    in_moe = "moe" in path_names
    if nd == 0 or name in _REPLICATE:
        return P()
    if name in _EMBED and nd >= 2:
        # vocab over model ONLY — data-sharding d_model would put the FSDP
        # slice on the unembed contraction dim and bait GSPMD into a
        # partial-sum + giant all-reduce strategy (see §Perf log)
        spec[-2] = _fit(mesh, shape[-2], model_axes)   # vocab
        spec[-1] = None
    elif in_moe and name in ("gate", "up") and nd >= 3:
        spec[-3] = _fit(mesh, shape[-3], model_axes)   # experts (EP)
        spec[-2] = _fit(mesh, shape[-2], data_axes)    # FSDP
    elif in_moe and name == "down" and nd >= 3:
        spec[-3] = _fit(mesh, shape[-3], model_axes)
        spec[-1] = _fit(mesh, shape[-1], data_axes)
    elif name in _ROW and nd >= 2:
        spec[-2] = _fit(mesh, shape[-2], model_axes)
        spec[-1] = _fit(mesh, shape[-1], data_axes)
    elif name in _COL and nd >= 2:
        spec[-2] = _fit(mesh, shape[-2], data_axes)
        spec[-1] = _fit(mesh, shape[-1], model_axes)
    elif nd >= 2:
        # unknown 2D+ leaf: FSDP the last dim only
        spec[-1] = _fit(mesh, shape[-1], data_axes)
    else:
        # 1-D (norm scales, biases): replicate (tiny, used every layer)
        return P()
    return P(*spec)


def _path_names(path) -> tuple:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(str(e.idx))
    return tuple(names)


def param_specs(params_shape: PyTree, mesh: Mesh,
                data_axes=("data",), model_axes=("model",)) -> PyTree:
    """PartitionSpec tree matching a params (or ShapeDtypeStruct) tree."""
    def one(path, leaf):
        return _leaf_spec(_path_names(path), leaf.shape, mesh,
                          data_axes, model_axes)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_specs(opt_state_shape: PyTree, mesh: Mesh,
                    data_axes=("data",), model_axes=("model",)) -> PyTree:
    """Optimizer state: same rules (moments mirror params; factored stats
    match by name so vr/vc get the surviving parameter dims' specs)."""
    def one(path, leaf):
        names = _path_names(path)
        # strip the optimizer-state wrapper names (m/v/vr/vc/inner/step)
        core = tuple(n for n in names if n not in
                     ("m", "v", "vr", "vc", "inner"))
        if names and names[-1] in ("vr", "vc"):
            # factored stats lost one dim; FSDP the last dim if it fits
            spec = [None] * len(leaf.shape)
            if len(leaf.shape) >= 1:
                spec[-1] = _fit(mesh, leaf.shape[-1], data_axes)
            return P(*spec)
        return _leaf_spec(core, leaf.shape, mesh, data_axes, model_axes)

    return jax.tree_util.tree_map_with_path(one, opt_state_shape)


def batch_spec(batch_shape: PyTree, mesh: Mesh,
               data_axes=("data",)) -> PyTree:
    """Input batches: leading (batch) dim over the data axes."""
    def one(leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1:
            spec[0] = _fit(mesh, leaf.shape[0], data_axes)
        return P(*spec)

    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape: PyTree, mesh: Mesh,
                data_axes=("data",), model_axes=("model",)) -> PyTree:
    """Decode caches. Leaves look like:
      attention k/v:     (B, S, Hkv, Dh)   [stacked: (G, B, S, Hkv, Dh)]
      MLA latent:        (B, S, R)
      mamba state:       (B, H, P, N)
      mlstm C/n/m:       (B, H, Dh[, Dh])
    Batch over data; heads over model when divisible, else sequence over
    model (flash-decode; softmax psums inserted by GSPMD)."""
    def one(leaf):
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        # find the batch dim: first dim whose size matches nothing stacked —
        # heuristically: stacked caches have a small leading group dim; we
        # shard the first dim that divides the data axes and is >= their size
        dsz = _axes_size(mesh, data_axes)
        bdim = None
        for i, s in enumerate(shape[: max(nd - 2, 1)]):
            if s % dsz == 0 and s >= dsz:
                bdim = i
                break
        if bdim is not None:
            spec[bdim] = data_axes
        start = (bdim + 1) if bdim is not None else 0
        if bdim is None:
            # batch too small (long_500k: B=1): put the data axes on the
            # largest divisible dim instead (the sequence for KV caches) so
            # a 500k-deep cache still spreads across the whole pod
            cand_d = [i for i in range(nd - 1)
                      if shape[i] % dsz == 0 and shape[i] >= dsz]
            if cand_d:
                best_d = max(cand_d, key=lambda i: shape[i])
                spec[best_d] = data_axes
        # model axis: prefer a heads-like dim (not the last), else the
        # largest remaining divisible dim (sequence)
        msz = _axes_size(mesh, model_axes)
        cand = [i for i in range(start, nd)
                if spec[i] is None and shape[i] % msz == 0
                and shape[i] >= msz]
        if cand:
            # prefer the heads-like dim (second-to-last) when it divides,
            # else the biggest remaining (sequence, for long KV caches)
            best = max(cand, key=lambda i: (i == nd - 2, shape[i]))
            spec[best] = model_axes
        return P(*spec)

    return jax.tree.map(one, cache_shape)


def shardings_for(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def with_batch_constraint(x, data_axes=("data",)):
    """Constrain an activation's leading dim onto the data axes."""
    spec = [None] * x.ndim
    spec[0] = data_axes
    return jax.lax.with_sharding_constraint(x, P(*spec))
