"""Gradient compression for the data-parallel all-reduce.

int8 quantization with error feedback (EF-SGD style): each step quantizes
(grad + carried error) to int8 with a per-tensor scale, all-reduces the int8
payload (4x less DCN/ICI traffic than f32, 2x less than bf16), dequantizes,
and carries the quantization residual into the next step. With EF the
compression error telescopes instead of accumulating — convergence parity is
checked in tests/test_compression.py.

Used by launch/train.py via ``grad_compression="int8"``; the all-reduce runs
inside shard_map over the data axes so the quantize/dequant stays fused with
the collective.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import axis_size, shard_map

PyTree = Any


def make_error_feedback_state(params: PyTree) -> PyTree:
    """Per-parameter carried quantization residual (fp32)."""
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _psum_one(g: jnp.ndarray, err: jnp.ndarray, axes) -> tuple:
    """Quantize(g + err) -> int8 psum -> dequantize; returns (mean_g, err')."""
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n = n * axis_size(a)
    x = g.astype(jnp.float32) + err
    q, scale = _quantize(x)
    # the scale must be identical on every shard for the int8 sum to be
    # meaningful -> use the max scale across the group
    scale = lax.pmax(scale, axes)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    summed = lax.psum(q.astype(jnp.int32), axes)
    mean = summed.astype(jnp.float32) * (scale / n)
    err_new = x - q.astype(jnp.float32) * scale
    return mean.astype(g.dtype), err_new


def compressed_psum(grads: PyTree, err: PyTree, mesh: Mesh,
                    data_axes=("data",)) -> tuple:
    """Mean-all-reduce `grads` over `data_axes` with int8 + error feedback.

    grads must be *unreduced* per-shard gradients (e.g. from a shard_map'd
    microbatch). Returns (mean_grads, new_error_state).
    """
    def inner(g_tree, e_tree):
        flat_g, tree = jax.tree_util.tree_flatten(g_tree)
        flat_e = tree.flatten_up_to(e_tree)
        out_g, out_e = [], []
        for g, e in zip(flat_g, flat_e):
            mg, ne = _psum_one(g, e, data_axes)
            out_g.append(mg)
            out_e.append(ne)
        return (jax.tree_util.tree_unflatten(tree, out_g),
                jax.tree_util.tree_unflatten(tree, out_e))

    rep = jax.tree.map(lambda _: P(), grads)
    fn = shard_map(inner, mesh=mesh, in_specs=(rep, rep),
                   out_specs=(rep, rep), check_vma=False)
    return fn(grads, err)
