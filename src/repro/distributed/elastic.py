"""Elastic re-meshing: move a sharded pytree onto a different mesh.

When the fleet shrinks (node failure, preemption) or grows (nodes return),
the supervisor rebuilds the mesh and calls ``remesh`` on params + optimizer
state; training resumes at the same step with the new device count — only
the per-device batch slice changes. Resharding is a device_put with the new
NamedShardings (XLA moves only the bytes that must move).

Serving (DESIGN.md §15) uses the same machinery: on device loss the
GP server builds the surviving mesh with :func:`shrink_mesh` and re-places
its cached matrices/q-parameters through :func:`remesh_report`. A spec that
cannot be honored on the new mesh is **never silently dropped** anymore:
every degraded leaf produces a structured :class:`Degradation` record
(leaf path, requested spec, what was applied, why) that the caller logs
and the serving metrics surface — replication is still the fallback, but
it is now a reported decision, not a hidden one.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, List, Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class Degradation:
    """One leaf whose requested PartitionSpec could not be honored.

    ``path`` is the pytree path of the leaf (``"w"``, ``"R/1"``, ...),
    ``requested`` / ``applied`` are the printable specs, ``reason`` says
    which dim degraded and why (mesh axis missing, or the dim size not
    divisible by the mesh-axes product).
    """

    path: str
    requested: str
    applied: str
    reason: str

    def __str__(self) -> str:
        return (f"{self.path}: {self.requested} -> {self.applied} "
                f"({self.reason})")


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return "/".join(parts) or "<root>"


def _fit_spec(spec, leaf, new_mesh) -> Tuple[P, List[str]]:
    """Per-dim fit of `spec` onto `new_mesh`; returns the applied spec and
    the list of degradation reasons (empty when honored exactly)."""
    dims, reasons = [], []
    for i, axes in enumerate(tuple(spec) + (None,) * (leaf.ndim - len(spec))):
        if axes is None:
            dims.append(None)
            continue
        ax = (axes,) if isinstance(axes, str) else tuple(axes)
        missing = [a for a in ax if a not in new_mesh.shape]
        if missing:
            dims.append(None)
            reasons.append(f"dim {i}: mesh axis {missing[0]!r} not on the "
                           f"new mesh (axes {tuple(new_mesh.shape)})")
            continue
        size = 1
        for a in ax:
            size *= new_mesh.shape[a]
        if leaf.shape[i] % size != 0:
            dims.append(None)
            reasons.append(f"dim {i}: size {leaf.shape[i]} not divisible "
                           f"by mesh axes {ax} (= {size})")
        else:
            dims.append(axes)
    return P(*dims), reasons


def remesh_report(tree: PyTree, new_mesh: Mesh,
                  spec_tree: PyTree) -> Tuple[PyTree, List[Degradation]]:
    """Re-shard `tree` onto `new_mesh`; returns ``(tree, degradations)``.

    Specs whose axes don't exist or don't divide on the new mesh degrade to
    replication on that dim — each such leaf/dim yields a
    :class:`Degradation` record instead of being silently swallowed.
    """
    report: List[Degradation] = []

    def one(path, leaf, spec):
        applied, reasons = _fit_spec(spec, leaf, new_mesh)
        if reasons:
            report.append(Degradation(
                path=_path_str(path), requested=str(spec),
                applied=str(applied), reason="; ".join(reasons)))
        return jax.device_put(leaf, NamedSharding(new_mesh, applied))

    out = jax.tree_util.tree_map_with_path(one, tree, spec_tree)
    return out, report


def remesh(tree: PyTree, new_mesh: Mesh, spec_tree: PyTree, *,
           on_degrade: Optional[Callable[[Degradation], None]] = None
           ) -> PyTree:
    """Re-shard `tree` onto `new_mesh` with `spec_tree` PartitionSpecs.

    Same graceful per-dim fallback to replication as before, but every
    degradation is logged (and handed to ``on_degrade`` when given) — use
    :func:`remesh_report` to get the records back directly.
    """
    out, report = remesh_report(tree, new_mesh, spec_tree)
    for d in report:
        logger.warning("remesh degradation: %s", d)
        if on_degrade is not None:
            on_degrade(d)
    return out


def surviving_devices(mesh: Mesh, dead_ids) -> list:
    """Devices of `mesh` whose ``.id`` is not in `dead_ids`, in mesh order."""
    dead = set(dead_ids)
    return [d for d in np.asarray(mesh.devices).flat if d.id not in dead]


def shrink_mesh(mesh: Mesh, dead_ids, *,
                axis_name: str | None = None) -> Optional[Mesh]:
    """The surviving mesh after losing `dead_ids`: a 1-axis mesh over the
    remaining devices (elastic-resize pattern — the ring/data axis simply
    shrinks; per-device work grows, the program re-plans and resumes).

    Returns ``None`` when one device (or fewer) survives: the caller's
    degradation ladder drops to the single-device path. Raises when no
    device survives at all.
    """
    live = surviving_devices(mesh, dead_ids)
    if not live:
        raise RuntimeError(
            f"no devices survive (mesh had {np.asarray(mesh.devices).size}, "
            f"all in dead set)")
    if len(live) < 2:
        return None
    return Mesh(np.asarray(live), (axis_name or mesh.axis_names[0],))
