"""Elastic re-meshing: move a sharded pytree onto a different mesh.

When the fleet shrinks (node failure, preemption) or grows (nodes return),
the supervisor rebuilds the mesh and calls ``remesh`` on params + optimizer
state; training resumes at the same step with the new device count — only
the per-device batch slice changes. Resharding is a device_put with the new
NamedShardings (XLA moves only the bytes that must move).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def remesh(tree: PyTree, new_mesh: Mesh, spec_tree: PyTree) -> PyTree:
    """Re-shard `tree` onto `new_mesh` with `spec_tree` PartitionSpecs.

    Specs whose axes don't divide on the new mesh degrade to replication
    (same graceful rule as sharding.py).
    """
    def fit(spec, leaf):
        dims = []
        for i, axes in enumerate(tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            if axes is None:
                dims.append(None)
                continue
            ax = (axes,) if isinstance(axes, str) else tuple(axes)
            size = 1
            ok = True
            for a in ax:
                if a not in new_mesh.shape:
                    ok = False
                    break
                size *= new_mesh.shape[a]
            dims.append(axes if ok and leaf.shape[i] % size == 0 else None)
        return NamedSharding(new_mesh, P(*dims))

    return jax.tree.map(
        lambda leaf, spec: jax.device_put(leaf, fit(spec, leaf)),
        tree, spec_tree)
