"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.json."""
import json
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "sample"]


def fmt_row(r):
    if r["status"] == "SKIP":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — | — "
                f"| — | — | — | {r.get('reason', '')[:46]} |")
    if r["status"] != "OK":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']} | — | — | — | — | — | |")
    mem = r.get("memory_per_device") or {}
    fits = "Y" if mem.get("fits_hbm") else "OVER"
    note = ""
    if r.get("accum"):
        note = f"accum={r['accum']}"
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
        f"{r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} | "
        f"{r['t_collective_s']:.3f} | {r['dominant'][:4]} | "
        f"{r['roofline_fraction']:.3f} | "
        f"{mem.get('total_bytes', 0)/1e9:.1f}GB {fits} {note} |")


def main(path="experiments/dryrun/dryrun.json"):
    rows = json.load(open(path))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r.get("shape",
                                                                "sample")),
                             r["mesh"] != "pod"))
    print("| arch | shape | mesh | status | t_comp(s) | t_mem(s) | "
          "t_coll(s) | dom | roofline frac | mem/device |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(fmt_row(r))
    ok = sum(r["status"] == "OK" for r in rows)
    skip = sum(r["status"] == "SKIP" for r in rows)
    print(f"\n{ok} OK, {skip} SKIP (documented), "
          f"{len(rows) - ok - skip} FAIL of {len(rows)} cells")
    over = [r for r in rows if r["status"] == "OK"
            and r.get("memory_per_device")
            and not r["memory_per_device"]["fits_hbm"]]
    print(f"cells over 16 GiB HBM: {len(over)}")
    for r in over:
        print(f"  {r['arch']}:{r['shape']}:{r['mesh']} "
              f"{r['memory_per_device']['total_bytes']/1e9:.1f}GB")


if __name__ == "__main__":
    main(*sys.argv[1:])
