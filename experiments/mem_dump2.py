import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_dump_to=/tmp/xladump2 "
                           "--xla_dump_hlo_as_text")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import SHAPES, get_arch, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import data_model_axes
from repro.distributed.sharding import batch_spec, param_specs, shardings_for
from repro.models import build_model, shard_ctx
from jax.sharding import NamedSharding, PartitionSpec as P
cfg = get_arch("gemma3-4b")
cell = SHAPES["train_4k"]
mesh = make_production_mesh()
da, ma = data_model_axes(mesh)
shard_ctx.set_axes(mesh, da, ma)
model = build_model(cfg)
specs = input_specs(cfg, cell)
p_spec = model.params_spec()
p_sh = shardings_for(param_specs(p_spec, mesh, da, ma), mesh)
b_sh = shardings_for(batch_spec(specs, mesh, da), mesh)
rep = NamedSharding(mesh, P())
g = jax.jit(lambda p, b: jax.value_and_grad(
    lambda pp: model.loss_fn(pp, b)[0])(p),
    in_shardings=(p_sh, b_sh), out_shardings=(rep, p_sh))
g.lower(p_spec, specs).compile()
print("done")
