import json, os, sys
sys.path.insert(0, 'src')
from repro.launch.dryrun import _run_in_subprocess

path = 'experiments/dryrun/dryrun.json'
rows = json.load(open(path))
failed = [r for r in rows if r['status'] not in ('OK', 'SKIP')]
print(f"retrying {len(failed)} cells")
by_key = {(r['arch'], r.get('shape'), r['mesh']): i for i, r in enumerate(rows)}
from concurrent.futures import ThreadPoolExecutor
cells = [f"{r['arch']}:{r.get('shape')}:{r['mesh']}" for r in failed]
with ThreadPoolExecutor(max_workers=2) as pool:
    for new in pool.map(_run_in_subprocess, cells):
        key = (new['arch'], new.get('shape'), new['mesh'])
        rows[by_key[key]] = new
        print(key, new['status'], new.get('dominant'), (new.get('error') or '')[:150], flush=True)
        json.dump(rows, open(path, 'w'), indent=1)
