import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_dump_to=/tmp/xladump "
                           "--xla_dump_hlo_as_text")
import sys
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_arch, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import data_model_axes
from repro.distributed.sharding import batch_spec, param_specs, shardings_for
from repro.models import build_model, shard_ctx
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = get_arch("gemma3-4b")
cell = SHAPES["train_4k"]
mesh = make_production_mesh()
da, ma = data_model_axes(mesh)
shard_ctx.set_axes(mesh, da, ma)
model = build_model(cfg)
specs = input_specs(cfg, cell)
p_spec = model.params_spec()
p_sh = shardings_for(param_specs(p_spec, mesh, da, ma), mesh)
b_sh = shardings_for(batch_spec(specs, mesh, da), mesh)
rep = NamedSharding(mesh, P())
fwd = jax.jit(lambda p, b: model.loss_fn(p, b)[0],
              in_shardings=(p_sh, b_sh), out_shardings=rep)
fwd.lower(p_spec, specs).compile()
print("done")
