import os, sys
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_dump_to=/tmp/xladump3 "
                           "--xla_dump_hlo_as_text")
import jax
from repro.configs import SHAPES, get_arch, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step, choose_accum
from repro.models import build_model

arch = sys.argv[1] if len(sys.argv) > 1 else "command-r-35b"
cfg = get_arch(arch)
cell = SHAPES["train_4k"]
mesh = make_production_mesh()
model = build_model(cfg)
accum = choose_accum(model, cell, mesh)
print("accum:", accum)
ts = make_train_step(cfg, mesh, accum=accum)
specs = input_specs(cfg, cell)
jit_fn, _ = ts.fn(specs)
p = ts.model.params_spec()
o = jax.eval_shape(ts.optimizer.init, p)
c = jit_fn.lower(p, o, specs).compile()
ma = c.memory_analysis()
print(f"temp={ma.temp_size_in_bytes/1e9:.1f} args={ma.argument_size_in_bytes/1e9:.1f} alias={ma.alias_size_in_bytes/1e9:.1f}")
