import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_arch, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step, data_model_axes
from repro.distributed.sharding import batch_spec, param_specs, shardings_for
from repro.models import build_model, shard_ctx

arch = sys.argv[1] if len(sys.argv) > 1 else "gemma3-4b"
cfg = get_arch(arch)
cell = SHAPES["train_4k"]
mesh = make_production_mesh()
data_axes, model_axes = data_model_axes(mesh)
shard_ctx.set_axes(mesh, data_axes, model_axes)
model = build_model(cfg)
specs = input_specs(cfg, cell)
p_spec = model.params_spec()
p_specs = param_specs(p_spec, mesh, data_axes, model_axes)
p_sh = shardings_for(p_specs, mesh)
b_sh = shardings_for(batch_spec(specs, mesh, data_axes), mesh)

from jax.sharding import NamedSharding, PartitionSpec as P
rep = NamedSharding(mesh, P())


def report(tag, lowered):
    c = lowered.compile()
    ma = c.memory_analysis()
    print(f"{tag}: temp={ma.temp_size_in_bytes/1e9:.1f}GB "
          f"args={ma.argument_size_in_bytes/1e9:.1f}GB", flush=True)


# (a) forward loss only
fwd = jax.jit(lambda p, b: model.loss_fn(p, b)[0],
              in_shardings=(p_sh, b_sh), out_shardings=rep)
report("fwd-only", fwd.lower(p_spec, specs))

# (b) loss + grad
grad = jax.jit(lambda p, b: jax.value_and_grad(
    lambda pp: model.loss_fn(pp, b)[0])(p),
    in_shardings=(p_sh, b_sh), out_shardings=(rep, p_sh))
report("fwd+grad", grad.lower(p_spec, specs))
