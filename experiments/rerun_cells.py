import json, sys
sys.path.insert(0, 'src')
from repro.launch.dryrun import _run_in_subprocess
from concurrent.futures import ThreadPoolExecutor

cells = []
for mesh in ("pod", "multipod"):
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        cells.append(f"llama4-maverick-400b-a17b:{shape}:{mesh}")
    cells += [
        f"command-r-35b:train_4k:{mesh}",
        f"deepseek-v2-236b:train_4k:{mesh}",
        f"whisper-base:train_4k:{mesh}",
        f"zamba2-7b:decode_32k:{mesh}",
        f"internvl2-2b:prefill_32k:{mesh}",
        f"gemma3-4b:train_4k:{mesh}",
        f"gemma3-4b:prefill_32k:{mesh}",
        f"icr-dust122b:sample:{mesh}",
        f"icr-dust-pod:sample:{mesh}",
        f"icr-log1d:sample:{mesh}",
    ]

path = 'experiments/dryrun/dryrun.json'
rows = json.load(open(path))
by_key = {(r['arch'], r.get('shape'), r['mesh']): i for i, r in enumerate(rows)}
with ThreadPoolExecutor(max_workers=2) as pool:
    for new in pool.map(_run_in_subprocess, cells):
        key = (new['arch'], new.get('shape'), new['mesh'])
        rows[by_key[key]] = new
        mem = new.get('memory_per_device') or {}
        print(key, new['status'], new.get('dominant'),
              f"mem={mem.get('total_bytes',0)/1e9:.1f}GB fits={mem.get('fits_hbm')}",
              (new.get('error') or '')[:100], flush=True)
        json.dump(rows, open(path, 'w'), indent=1)
