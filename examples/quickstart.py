"""Quickstart: sample a GP with ICR and compare against the exact GP.

The 60-second tour of the paper: build a chart, pick a kernel, draw O(N)
GP samples with sqrt(K_ICR), and check the implied covariance against the
dense kernel matrix (only possible at small N — that's the point!).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.core import (
    ICR,
    cov_errors,
    exact_cov,
    log_chart,
    matern32,
    regular_chart,
)


def main():
    # --- 1. a GP on 200 log-spaced points (the paper's §5 setting) --------
    chart = log_chart(11, 5, n_csz=5, n_fsz=4, delta0=0.0197)
    n = chart.final_shape[0]
    xs = np.asarray(chart.grid_positions(chart.n_levels))[:, 0]
    rho = float(np.diff(xs).max())
    print(f"modeling {n} points; nearest-neighbor spacing spans "
          f"{np.diff(xs).min()/rho*100:.1f}%..100% of rho")

    icr = ICR(chart=chart, kernel=matern32.with_defaults(rho=rho))

    # --- 2. draw samples (O(N), no inversion, no log-det) ------------------
    mats = icr.matrices()          # refinement matrices (paper Eq. 7/8)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    samples = [icr.apply_sqrt(mats, icr.init_xi(k)) for k in keys]
    print("sample[0][:5] =", np.asarray(samples[0]).reshape(-1)[:5])

    # --- 3. validate the implied covariance against the exact kernel -------
    cov_icr = icr.implicit_cov(dtype=np.float32)
    cov_true = exact_cov(chart, matern32.with_defaults(rho=rho)())
    errs = {k: float(v) for k, v in cov_errors(cov_icr, cov_true).items()}
    print(f"covariance errors vs exact GP: MAE={errs['mae']:.2e} "
          f"(paper: 5.8e-3), max={errs['max_abs_err']:.2e} (paper: 0.13)")

    # --- 4. the same API scales: 1M-point regular chart ---------------------
    big = ICR(chart=regular_chart(1024, 10, boundary="reflect"),
              kernel=matern32.with_defaults(rho=5000.0))
    s = big.sample(jax.random.PRNGKey(1))
    print(f"1M-point sample: shape={s.shape}, std={float(s.std()):.3f} "
          "(same O(N) code path)")


if __name__ == "__main__":
    main()
