"""End-to-end driver (the paper's kind of workload): train a GP field +
kernel parameters on noisy observations with the standardized generative
model (paper §3.2) — a few hundred optimizer steps, no kernel inversion.

  field prior : ICR on a 4096-point chart (sqrt(K_ICR) applications only),
                running the fused Pallas path — forward AND backward: every
                optimizer step's gradient goes through the hand-written
                adjoint kernels, never the jnp reference
  theta prior : LogNormal on the kernel scale rho, via inverse-CDF
  inference   : MAP over (xi_field, xi_theta), then mean-field ADVI for
                uncertainties

Run:  PYTHONPATH=src python examples/gp_regression_vi.py [--steps 300]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    ICR,
    StandardizedModel,
    advi_fit,
    advi_posterior,
    gaussian_log_likelihood,
    lognormal_prior,
    map_fit,
    matern32,
    regular_chart,
)
from repro.data import charted_gp_dataset
from repro.kernels import dispatch
from repro.launch.serve_gp import GPFieldServer, GPRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--n0", type=int, default=64)
    ap.add_argument("--levels", type=int, default=6)
    args = ap.parse_args()

    chart = regular_chart(args.n0, args.levels, boundary="reflect")
    n = chart.size
    true_rho = 0.04 * n
    icr = ICR(chart=chart, kernel=matern32.with_defaults(rho=true_rho),
              use_pallas=True)
    truth, obs_idx, y = charted_gp_dataset(
        icr, jax.random.PRNGKey(0), obs_frac=0.3, noise_std=0.05)
    print(f"N={n} points, {len(np.asarray(obs_idx))} noisy observations, "
          f"true rho={true_rho:.0f}")

    # every level must run fused — forward and backward (no jnp reference)
    for entry in dispatch.plan(chart):
        print(f"  level {entry['level']}: fwd={entry['route']} "
              f"bwd={entry['vjp']['route']} backend={entry['backend']}")
        assert entry["route"] != dispatch.ROUTE_REFERENCE, entry
        assert entry["vjp"]["route"] != dispatch.ROUTE_REFERENCE, entry

    # joint (field, theta) inference — matrices recomputed inside the step
    priors = StandardizedModel({"rho": lognormal_prior(0.06 * n, 0.03 * n)})
    ll = gaussian_log_likelihood(0.05, obs_idx)

    def fwd(latent):
        xi_s, xi_t = latent
        theta = dict(priors(xi_t))
        theta["sigma"] = 1.0
        return icr(xi_s, theta)

    latent0 = (icr.zero_xi(), priors.zero_xi())
    t0 = time.time()
    latent, losses = map_fit(ll, fwd, latent0, y, steps=args.steps, lr=2e-2)
    dt = time.time() - t0
    rec = np.asarray(fwd(latent).reshape(-1))
    rho_hat = float(priors(latent[1])["rho"])
    rmse = float(np.sqrt(np.mean((rec - np.asarray(truth)) ** 2)))
    print(f"MAP: {args.steps} steps in {dt:.1f}s "
          f"({dt/args.steps*1e3:.1f} ms/step)")
    print(f"  loss {float(losses[0]):.1f} -> {float(losses[-1]):.1f}")
    print(f"  field RMSE={rmse:.3f}  rho_hat={rho_hat:.0f} "
          f"(true {true_rho:.0f})")

    # uncertainties via mean-field ADVI over the field excitations
    mats = icr.matrices({"rho": rho_hat, "sigma": 1.0})
    fwd_field = lambda xi: icr.apply_sqrt(mats, xi)
    (mean, logstd), elbos = advi_fit(
        jax.random.PRNGKey(2), ll, fwd_field, latent[0], y,
        steps=max(args.steps // 2, 50))
    post_std = float(jnp.mean(jnp.exp(logstd[-1])))
    print(f"ADVI: ELBO {float(elbos[0]):.1f} -> {float(elbos[-1]):.1f}, "
          f"mean finest-level posterior std={post_std:.3f} (prior: 1.0)")

    # export the fit as a self-contained Posterior and serve it: posterior
    # field draws and MC predictive moments through the slab-packed GP
    # server (DESIGN.md §12) — the ADVI products no longer die here
    post = advi_posterior(icr, (mean, logstd),
                          theta={"rho": rho_hat, "sigma": 1.0})
    srv = GPFieldServer(post, slab=4)
    reqs = [GPRequest(kind="sample", n=2, seed=1),
            GPRequest(kind="moments", n=8, seed=2)]
    t0 = time.time()
    srv.run(reqs)
    dt = time.time() - t0
    assert all(r.done and r.error is None for r in reqs)
    mom = reqs[1]
    print(f"serve: {srv.rows_served} posterior draws in {srv.slabs_run} "
          f"slabs ({dt*1e3:.0f} ms, cache "
          f"{srv.cache_hits} hits/{srv.cache_misses} miss); "
          f"{len(reqs[0].fields)} fields + moments({mom.n}): "
          f"mean predictive std={float(np.mean(mom.std)):.3f}")


if __name__ == "__main__":
    main()
