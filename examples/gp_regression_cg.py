"""Exact data-conditioned GP posteriors through the §16 guarded CG path.

Two routes to the same posterior, both matrix-free (the covariance only
ever acts through ICR square-root applications):

  direct      : ``core.vi.cg_posterior`` — solve (W K Wᵀ + σ²I) α = y with
                the ICR-whitened preconditioner, whiten the correction and
                serve the exact posterior mean through the ordinary
                sampling path. The structured SolveReport (iterations,
                residuals, fallback rungs, quarantined RHS) rides back.
  serving     : a ``kind="condition"`` request against a GPFieldServer —
                the same solve slab-batched with Matheron pathwise
                samples, so the response carries a predictive std too.

Run:  PYTHONPATH=src python examples/gp_regression_cg.py [--n0 32]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ICR, matern32, regular_chart
from repro.core.vi import cg_posterior
from repro.launch.serve_gp import GPFieldServer, GPRequest, demo_posterior


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n0", type=int, default=32)
    ap.add_argument("--levels", type=int, default=3)
    ap.add_argument("--noise", type=float, default=0.25)
    ap.add_argument("--samples", type=int, default=16)
    args = ap.parse_args()

    chart = regular_chart(args.n0, args.levels, boundary="reflect")
    n = int(np.prod(chart.final_shape))
    rho = 0.06 * n
    icr = ICR(chart=chart, kernel=matern32.with_defaults(rho=rho),
              use_pallas=True)

    # synthetic data: a prior draw observed at half the pixels
    rng = np.random.default_rng(0)
    mats = icr.matrices_cached(None)
    truth = np.asarray(
        icr.apply_sqrt(mats, icr.init_xi(jax.random.PRNGKey(7)))
    ).reshape(-1)
    # observe the left half of the domain only — the unobserved right half
    # shows the predictive std relaxing back toward the prior
    obs_idx = np.arange(n // 2)
    y = (truth[obs_idx]
         + args.noise * rng.standard_normal(obs_idx.size)).astype(np.float32)
    print(f"N={n} points, {obs_idx.size} noisy observations, "
          f"rho={rho:.0f}, sigma={args.noise}")

    # -- route 1: cg_posterior ------------------------------------------------
    t0 = time.perf_counter()
    post, report = cg_posterior(icr, obs_idx, y, noise_std=args.noise)
    mean = np.asarray(icr.apply_sqrt(mats, post.mean)).reshape(-1)
    dt = time.perf_counter() - t0
    s = report.summary()
    print(f"cg_posterior: {dt:.2f}s rungs={s['rungs']} "
          f"iterations={s['iterations']} relres={s['final_relres']:.1e} "
          f"status={s['status']}")
    assert report.ok, s
    rmse = float(np.sqrt(np.mean((mean - truth) ** 2)))
    prior_rms = float(np.sqrt(np.mean(truth ** 2)))
    print(f"posterior-mean RMSE vs truth: {rmse:.3f} "
          f"(prior field RMS {prior_rms:.3f})")
    assert rmse < prior_rms  # conditioning must beat the prior

    # -- route 2: kind="condition" serving ------------------------------------
    srv = GPFieldServer(demo_posterior(chart, rho), slab=4)
    req = GPRequest(kind="condition", n=args.samples, seed=11, y=y,
                    obs_idx=obs_idx, noise_std=args.noise)
    t0 = time.perf_counter()
    srv.run([req])
    dt = time.perf_counter() - t0
    assert req.done and req.error is None, req.error
    std = req.std.reshape(-1)
    met = srv.metrics()
    print(f"served condition request: {dt:.2f}s "
          f"{args.samples} Matheron draws, "
          f"report={met['solve_reports'][-1]['status']}")
    print(f"predictive std: observed pixels {std[obs_idx].mean():.3f}, "
          f"unobserved {np.delete(std, obs_idx).mean():.3f}")
    assert std[obs_idx].mean() < np.delete(std, obs_idx).mean()
    print("conditioned posterior served OK")


if __name__ == "__main__":
    main()
