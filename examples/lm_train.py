"""Train a small LM from the assigned-architecture zoo on CPU.

Uses the full production substrate — sharded step, deterministic pipeline,
async checkpoints, fault supervisor — on a reduced config (~1-10M params).
Every one of the 10 assigned archs works: try --arch zamba2-7b or
--arch deepseek-v2-236b to train a tiny hybrid/MoE.

Run:  PYTHONPATH=src python examples/lm_train.py --arch gemma3-4b --steps 100
"""
import argparse

from repro.configs import arch_names, get_arch
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-15b",
                    choices=arch_names())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    mesh = make_host_mesh()
    res = train_loop(cfg, mesh, steps=args.steps, global_batch=args.batch,
                     seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                     log_every=10)
    first = res.losses[0] if res.losses else float("nan")
    print(f"\n{args.arch} (reduced): loss {first:.3f} -> "
          f"{res.final_loss:.3f} over {res.steps_done} steps")
    assert res.final_loss < first, "loss should decrease"


if __name__ == "__main__":
    main()
