"""Batched serving demo: continuous batching over 4 decode slots.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b
"""
import argparse
import time

import numpy as np

from repro.configs import arch_names, get_arch
from repro.launch.serve import BatchedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-15b",
                    choices=arch_names())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    server = BatchedServer(cfg, batch_slots=4, s_max=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=8),
                    max_new=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt={list(r.prompt[:4])}... -> {r.out}")
    print(f"\n{server.decode_tokens} decode + {server.prefill_tokens} "
          f"prefill tokens in {dt:.1f}s ({server.decode_tokens/dt:.1f} "
          f"decode tok/s, {args.arch} reduced)")


if __name__ == "__main__":
    main()
