"""The paper's flagship application, scaled to a laptop: a 3-D 'dust map'
GP on a (log-r, u, v) chart (paper §6, ref [24] — the 122-billion-DOF run).

Radial axis charted (per-pixel refinement matrices), angular axes
translation-invariant (matrices broadcast — the §4.3 symmetry trick). With
``use_pallas=True`` every refinement level runs through the fused N-D
kernel path (DESIGN.md §4–5): Pallas on TPU; off-TPU the production
backend executes the jnp oracle of the same fused structure
(``REPRO_BACKEND=interpret`` emulates the exact kernel tiling instead) —
the *routing* never falls back to the unstructured joint reference.
The same DistributedICR used here runs the 512-chip dry-run cell
``icr-dust122b`` (launch/dryrun.py).

Run:  PYTHONPATH=src python examples/dust_map_3d.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ICR, matern32
from repro.core.charts import galactic_dust_chart
from repro.core.distributed import DistributedICR
from repro.compat import use_mesh
from repro.kernels import dispatch
from repro.launch.mesh import make_mesh


def main():
    chart = galactic_dust_chart((8, 16, 16), n_levels=3)
    icr = ICR(chart=chart, kernel=matern32.with_defaults(rho=0.5),
              use_pallas=True)
    shape = chart.final_shape
    print(f"dust chart: {shape} = {np.prod(shape):,} voxels, "
          f"{chart.n_levels} refinement levels")
    print("radial spacings (kpc-ish):",
          np.round(np.diff(np.exp(chart.axis_coords(chart.n_levels, 0)))[:5],
                   4))

    # this chart's three levels fit VMEM *together*: the whole forward is
    # ONE pyramid launch (DESIGN.md §11) — intermediate fields never touch
    # HBM. Per level the plan reports the position-aware bytes (only the
    # first level reads the coarse field, only the last writes the fine
    # one) at both storage dtypes; bf16 must halve every estimate.
    plan = dispatch.plan(chart)
    plan16 = dispatch.plan(chart, dtype="bfloat16")
    for entry, e16 in zip(plan, plan16):
        hb, hb16 = entry["hbm_bytes"], e16["hbm_bytes"]
        print(f"  level {entry['level']}: route={entry['route']} "
              f"backend={entry['backend']} vjp={entry['vjp']['route']} "
              f"est HBM {hb['selected']/1e6:.2f} MB f32 / "
              f"{hb16['selected']/1e6:.2f} MB bf16 "
              f"({hb['nd-axes']/hb['selected']:.1f}x less than per-axis)")
        assert entry["route"] == dispatch.ROUTE_PYRAMID, (
            "dust-map level fell off the pyramid", entry)
        assert hb["selected"] >= 1.9 * hb16["selected"], (hb, hb16)

    # the per-level view underneath (what runs with use_pyramid=False, and
    # what a level too big for the shared budget falls back to): the
    # single-launch megakernel — never the jnp reference
    for entry in dispatch.plan(chart, pyramid=False):
        assert entry["route"] == dispatch.ROUTE_ND_FUSED, (
            "dust-map level fell off the megakernel route", entry)
        assert entry["vjp"]["route"] != dispatch.ROUTE_REFERENCE, (
            "fused backward fell back", entry)

    # single-device sample through the fused kernels
    sample = icr.sample(jax.random.PRNGKey(0))
    print(f"sample: shape={sample.shape} mean={float(sample.mean()):+.3f} "
          f"std={float(sample.std()):.3f}")

    # the same model under the mixed-precision policy (DESIGN.md §11):
    # bf16 storage + f32 accumulation — half the HBM bytes per level.
    # Same excitation values (cast), so the two fields are comparable.
    icr16 = ICR(chart=chart, kernel=matern32.with_defaults(rho=0.5),
                use_pallas=True, dtype_policy="bf16")
    xi = icr.init_xi(jax.random.PRNGKey(0))
    s32 = icr.apply_sqrt(icr.matrices(), xi)
    s16 = icr16.apply_sqrt(icr16.matrices(),
                           [x.astype(jnp.bfloat16) for x in xi])
    rel = float(jnp.abs(s16.astype(jnp.float32) - s32).max()
                / jnp.abs(s32).max())
    print(f"bf16 sample: dtype={s16.dtype} rel-err vs f32 {rel:.3f} "
          "(bf16 rounding, fp32 accumulation)")
    assert s16.dtype == jnp.bfloat16 and rel < 0.05


    # one inference-style gradient through the fused path: MAP/ADVI cost is
    # two sqrt applications + the VJP (paper §1) — all adjoint kernels here
    # (demoed on a half-size chart: interpret mode off-TPU pays emulation
    # overhead per launch, and the example must stay laptop-sized)
    small = galactic_dust_chart((6, 8, 8), n_levels=2)
    icr_s = ICR(chart=small, kernel=matern32.with_defaults(rho=0.5),
                use_pallas=True)
    mats = icr_s.matrices()
    xi = icr_s.init_xi(jax.random.PRNGKey(1))
    grad = jax.grad(
        lambda xs: 0.5 * jnp.sum(icr_s.apply_sqrt(mats, xs) ** 2))(xi)
    gnorm = float(sum(jnp.sum(g**2) for g in grad)) ** 0.5
    print(f"fused VJP: |d loss/d xi| over {len(grad)} levels = {gnorm:.2f}")
    # Wiener-filter-style transpose diagnostics share the same adjoints
    back = icr_s.apply_sqrt_T(mats, icr_s.sample(jax.random.PRNGKey(2)))
    print(f"sqrt(K)^T residual map: level sizes = {[b.size for b in back]}")

    # batched posterior-style sampling: the sample batch rides natively
    # inside the kernel tiles (matrices fetched once per tile slab) instead
    # of looping — the serving fast path (demoed on the half-size chart;
    # interpret mode pays emulation overhead per launch)
    batch = icr_s.sample_batch(jax.random.PRNGKey(42), 3)
    print(f"sample_batch(3): shape={batch.shape} "
          f"per-sample std={[round(float(b.std()), 3) for b in batch]}")

    # distributed sample across every local device (spatial ring over the
    # middle angular axis — halo exchange via collective_permute)
    n_dev = len(jax.devices())
    if n_dev > 1:
        mesh = make_mesh((n_dev,), ("space",))
        dist = DistributedICR(icr=icr, mesh=mesh, axis_names=("space",),
                              shard_axis=1)
        with use_mesh(mesh):
            s2 = dist.sample(jax.random.PRNGKey(0))
        print(f"distributed over {n_dev} devices: shape={s2.shape}, "
              "sharded along the angular axis")
    else:
        print("(1 device visible — run under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 to see "
              "the halo-exchange path)")

    # radial correlation structure: nearby shells correlate strongly
    v = np.asarray(sample)
    c01 = np.corrcoef(v[0].ravel(), v[1].ravel())[0, 1]
    c0n = np.corrcoef(v[0].ravel(), v[-1].ravel())[0, 1]
    print(f"corr(shell0, shell1)={c01:.2f}  corr(shell0, shell-1)={c0n:.2f} "
          "(decaying with distance, as the Matern kernel dictates)")


if __name__ == "__main__":
    main()
