"""Single-launch fused N-D level megakernel (DESIGN.md §10).

Acceptance (ISSUE 3): the fused route is exact vs the per-axis passes and
the joint ``refine_level`` reference at 1e-5 for 2-D/3-D, both boundaries,
mixed stationary/charted axes, including gradients through the custom VJP;
the plan() HBM-bytes model shows >= 2x traffic reduction per 3-D level.
All kernels run in interpret mode on CPU (exact BlockSpec tiling).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import matern32, regular_chart
from repro.core.charts import Chart, galactic_dust_chart
from repro.core.refine import (
    LevelGeom,
    axis_refinement_matrices_level,
    refine_level,
)
from repro.kernels import dispatch, nd, nd_fused
from repro.roofline import refine_level_traffic


# this module covers the kernel tiling: pin the interpret backend through
# dispatch/ICR (the production CPU default is the jnp oracle)
pytestmark = pytest.mark.usefixtures("interpret_backend")

ND_CHARTS = [
    (lambda: regular_chart((12, 10), 2, boundary="shrink"), "2d-shrink"),
    (lambda: regular_chart((12, 16), 2, boundary="reflect"), "2d-reflect"),
    (lambda: Chart(  # 2-D, charted (log) axis 0, invariant axis 1
        shape0=(14, 12), n_levels=2, delta0=(0.05, 1.0), boundary="shrink",
        phi_inv=lambda x: jnp.stack(
            [jnp.exp(x[..., 0]), x[..., 1]], axis=-1),
        invariant=(False, True)), "2d-mixed-shrink"),
    (lambda: regular_chart((8, 8, 12), 1, boundary="shrink"), "3d-shrink"),
    (lambda: galactic_dust_chart((6, 8, 8), n_levels=2), "3d-dust-reflect"),
]
IDS = [n for _, n in ND_CHARTS]


def _level_data(c, lvl, seed_name):
    k = matern32.with_defaults(rho=3.0)()
    geom = LevelGeom.for_level(c, lvl)
    rs, ds = axis_refinement_matrices_level(c, k, lvl)
    rng = np.random.default_rng([lvl, *seed_name.encode()])
    field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
    f = int(np.prod(geom.T))
    xi = jnp.asarray(
        rng.normal(size=(f, geom.n_fsz ** len(geom.T))), jnp.float32)
    return geom, rs, ds, field, xi, rng


def _kron_joint(rs, ds):
    """Joint (*kept_T, fsz^d, csz^d) matrices from per-axis factors."""
    rs = [m if m.ndim == 3 else m[None] for m in rs]
    ds = [m if m.ndim == 3 else m[None] for m in ds]
    kept = tuple(m.shape[0] for m in rs)

    def build(mats):
        out = mats[0]
        for m in mats[1:]:
            out = jnp.einsum("...FC,tfc->...tFfCc", out, m)
            sh = out.shape
            out = out.reshape(sh[:-4] + (sh[-4] * sh[-3], sh[-2] * sh[-1]))
        return out

    r = build(rs)
    d = build(ds)
    return r.reshape(kept + r.shape[1:]), d.reshape(kept + d.shape[1:])


@pytest.mark.parametrize("chartf,name", ND_CHARTS, ids=IDS)
def test_fused_matches_axes_and_joint(chartf, name):
    """Megakernel == per-axis passes == joint refine_level (Kronecker
    matrices), every level, both boundaries, mixed axes — pinned 1e-5."""
    c = chartf()
    for lvl in range(c.n_levels):
        geom, rs, ds, field, xi, _ = _level_data(c, lvl, name)
        got = nd_fused.refine_nd_fused(field, xi, rs, ds, geom,
                                       interpret=True)
        assert got.shape == geom.fine_shape
        axes = nd.refine_axes(field, xi, rs, ds, geom, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(axes),
                                   rtol=1e-5, atol=1e-5)
        r_j, d_j = _kron_joint(rs, ds)
        joint = refine_level(field, xi, r_j, d_j, geom)
        np.testing.assert_allclose(np.asarray(got), np.asarray(joint),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chartf,name", ND_CHARTS, ids=IDS)
def test_fused_vjp_matches_axes(chartf, name):
    """jax.grad through the megakernel's custom VJP (fixed matrices: the
    hand-composed 1-D adjoint chain) == grad through the per-axis passes."""
    c = chartf()
    for lvl in range(c.n_levels):
        geom, rs, ds, field, xi, rng = _level_data(c, lvl, name)
        v = jnp.asarray(rng.normal(size=geom.fine_shape), jnp.float32)
        loss_f = lambda fl, x: jnp.sum(
            nd_fused.refine_nd_fused(fl, x, rs, ds, geom, interpret=True) * v)
        loss_a = lambda fl, x: jnp.sum(
            nd.refine_axes(fl, x, rs, ds, geom, interpret=True) * v)
        got = jax.grad(loss_f, argnums=(0, 1))(field, xi)
        want = jax.grad(loss_a, argnums=(0, 1))(field, xi)
        for a, b in zip(want, got):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chartf,name", [ND_CHARTS[1], ND_CHARTS[-1]],
                         ids=["2d-reflect", "3d-dust-reflect"])
def test_fused_matrix_cotangents(chartf, name):
    """Learned-θ path: perturbing the factors flips the backward onto the
    jnp-reference VJP — matrix cotangents must match the per-axis route."""
    c = chartf()
    geom, rs, ds, field, xi, rng = _level_data(c, 0, name)
    v = jnp.asarray(rng.normal(size=geom.fine_shape), jnp.float32)
    g_f = jax.grad(lambda rr, dd: jnp.sum(
        nd_fused.refine_nd_fused(field, xi, rr, dd, geom, interpret=True)
        * v), argnums=(0, 1))(rs, ds)
    g_a = jax.grad(lambda rr, dd: jnp.sum(
        nd.refine_axes(field, xi, rr, dd, geom, interpret=True) * v),
        argnums=(0, 1))(rs, ds)
    for a, b in zip(jax.tree_util.tree_leaves(g_a),
                    jax.tree_util.tree_leaves(g_f)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("block", [2, 4, 1024])
def test_fused_block_size_invariance(block):
    """Output must not depend on the axis-0 family tile size."""
    c = galactic_dust_chart((6, 8, 8), n_levels=2)
    geom, rs, ds, field, xi, _ = _level_data(c, 1, "blocks")
    base = nd_fused.refine_nd_fused(field, xi, rs, ds, geom, interpret=True,
                                    block_families=8)
    got = nd_fused.refine_nd_fused(field, xi, rs, ds, geom, interpret=True,
                                   block_families=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)


@pytest.mark.parametrize("s_blk", [1, 2, 8])
def test_fused_sample_block_invariance(s_blk):
    """Sample-slab size must not change values; parity vs per-sample loop."""
    c = regular_chart((12, 16), 1, boundary="reflect")
    geom, rs, ds, _, _, rng = _level_data(c, 0, "samples")
    n_s = 5
    field = jnp.asarray(rng.normal(size=(n_s,) + geom.coarse_shape),
                        jnp.float32)
    f = int(np.prod(geom.T))
    xi = jnp.asarray(rng.normal(size=(n_s, f, geom.n_fsz**2)), jnp.float32)
    got = nd_fused.refine_nd_fused(field, xi, rs, ds, geom, interpret=True,
                                   sample_axis=True, sample_block=s_blk)
    want = jnp.stack([
        nd_fused.refine_nd_fused(field[i], xi[i], rs, ds, geom,
                                 interpret=True)
        for i in range(n_s)
    ])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6,
                               atol=1e-6)


class TestDispatchFused:
    def test_dust_routes_fused_everywhere(self):
        # pyramid=False: the per-level routing this suite pins (the pyramid
        # overlay on top of it is covered by test_pyramid/test_plan_smoke)
        c = galactic_dust_chart((8, 16, 16), n_levels=3)
        for e in dispatch.plan(c, platform="cpu", pyramid=False):
            assert e["route"] == dispatch.ROUTE_ND_FUSED, e
            assert e["vjp"]["route"] == dispatch.ROUTE_ND_FUSED + "-adjoint"

    def test_vmem_fallback_rule(self):
        """A tile that busts the budget falls back to the per-axis passes;
        the autotuner is the single source of the decision."""
        geom = LevelGeom.for_level(
            galactic_dust_chart((6, 8, 8), n_levels=2), 0)
        assert dispatch.autotune_nd_fused(geom) is not None
        assert dispatch.autotune_nd_fused(geom, vmem_budget=256) is None
        assert dispatch.route_for(geom, have_axis_mats=True) \
            == dispatch.ROUTE_ND_FUSED

    def test_autotune_blocks_bounded(self):
        geom = LevelGeom.for_level(
            galactic_dust_chart((8, 16, 16), n_levels=3), 2)
        b_f, s_b = dispatch.autotune_nd_fused(geom, samples=16)
        assert 1 <= b_f <= geom.T[0]
        assert 1 <= s_b <= 16
        # the chosen tile obeys the working-set model
        charted = tuple(k > 1 for k in geom.kept_T)
        assert dispatch._fused_tile_bytes(geom, charted, b_f, s_b, 4) \
            <= dispatch.VMEM_BUDGET_BYTES

    def test_refine_routes_fused(self):
        """dispatch.refine end-to-end on the fused route == reference
        refine_level with Kronecker-joint matrices."""
        c = regular_chart((12, 16), 1, boundary="reflect")
        geom, rs, ds, field, xi, _ = _level_data(c, 0, "dispatch")
        out = dispatch.refine(field, xi, None, None, geom,
                              axis_mats=(rs, ds),
                              backend=dispatch.BACKEND_INTERPRET)
        r_j, d_j = _kron_joint(rs, ds)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(refine_level(field, xi, r_j, d_j,
                                                     geom)),
            rtol=1e-5, atol=1e-5)


class TestTrafficModel:
    def test_3d_level_traffic_reduction(self):
        """Acceptance: >= 2x modeled HBM traffic reduction per 3-D level,
        fused vs per-axis."""
        c = galactic_dust_chart((8, 16, 16), n_levels=3)
        for e in dispatch.plan(c, platform="cpu"):
            hb = e["hbm_bytes"]
            assert hb["nd-fused"] * 2 <= hb["nd-axes"], hb
            assert hb["selected"] == hb[e["route"]]

    def test_model_matches_first_principles(self):
        """The fused estimate is read L + read ξ + write N + matrices —
        recomputed here from the chart shapes alone (guards the plan wiring
        against drifting from the roofline model)."""
        c = galactic_dust_chart((6, 8, 8), n_levels=2)
        for lvl in range(c.n_levels):
            geom = LevelGeom.for_level(c, lvl)
            got = refine_level_traffic(geom, "nd-fused")["total"]
            s = geom.n_fsz // 2
            q = (geom.n_csz - 1) // s
            read_l = 1
            for a, n in enumerate(geom.coarse_shape):
                read_l *= max(n + 2 * geom.b, (geom.T[a] + q) * s)
            n_out = int(np.prod(geom.fine_shape))
            approx = 4 * (read_l + 2 * n_out)  # field + ξ + fine, f32
            assert abs(got - approx) / approx < 0.10, (got, approx)

    def test_samples_amortize_matrices(self):
        geom = LevelGeom.for_level(galactic_dust_chart((6, 8, 8), 2), 1)
        one = refine_level_traffic(geom, "nd-fused", samples=1)
        many = refine_level_traffic(geom, "nd-fused", samples=8)
        assert many["matrices"] == one["matrices"]
        assert many["fine_write"] == 8 * one["fine_write"]
