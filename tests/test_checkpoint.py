"""Checkpointing: atomicity, async, retention, restore, restart-resume."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree(key, scale=1.0):
    return {
        "layer": {"w": scale * jax.random.normal(key, (8, 16)),
                  "b": jnp.zeros((16,), jnp.bfloat16)},
        "step_count": jnp.asarray(7, jnp.int32),
        "nested": [jnp.ones((3,)), jnp.full((2, 2), 2.0)],
    }


def test_roundtrip(tmp_path, key):
    tree = _tree(key)
    save_pytree(tree, str(tmp_path / "ck"))
    out = load_pytree(str(tmp_path / "ck"), tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_atomic_publish_no_tmp_left(tmp_path, key):
    save_pytree(_tree(key), str(tmp_path / "ck"))
    assert not os.path.exists(str(tmp_path / "ck.tmp"))
    assert os.path.exists(str(tmp_path / "ck" / "manifest.json"))


def test_manager_async_and_retention(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in [10, 20, 30, 40]:
        mgr.save(step, _tree(key, scale=step))
    mgr.wait()
    assert mgr.steps() == [30, 40]
    s, restored = mgr.restore(_tree(key))
    assert s == 40
    np.testing.assert_allclose(
        np.asarray(restored["layer"]["w"]),
        np.asarray(_tree(key, scale=40)["layer"]["w"]), rtol=1e-6)


def test_restore_specific_step(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(key, 1.0))
    mgr.save(2, _tree(key, 2.0), blocking=True)
    s, restored = mgr.restore(_tree(key), step=1)
    assert s == 1
    np.testing.assert_allclose(np.asarray(restored["layer"]["w"]),
                               np.asarray(_tree(key, 1.0)["layer"]["w"]),
                               rtol=1e-6)


def test_restore_missing_raises(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree(key))


def test_train_loop_resume(tmp_path):
    """End-to-end: crash mid-training, resume from checkpoint, same result
    as an uninterrupted run (determinism incl. data order)."""
    from repro.configs import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import train_loop

    cfg = get_arch("starcoder2-15b").reduced()
    mesh = make_host_mesh()

    r_full = train_loop(cfg, mesh, steps=6, global_batch=2, seq_len=16,
                        ckpt_dir=str(tmp_path / "a"), ckpt_every=2,
                        log_every=100)
    # interrupted run: injected failure at step 4 -> restores from step 4's
    # checkpoint region and continues
    r_fail = train_loop(cfg, mesh, steps=6, global_batch=2, seq_len=16,
                        ckpt_dir=str(tmp_path / "b"), ckpt_every=2,
                        fail_at=4, log_every=100)
    assert r_fail.restarts == 1
    assert r_fail.steps_done == 6
    np.testing.assert_allclose(r_fail.final_loss, r_full.final_loss,
                               rtol=1e-4)
