"""Mesh-safety analyzer (DESIGN.md §17): injected-regression fixtures.

One fixture per pass, each proving the analyzer catches exactly its
target defect and nothing else:

  dropped psum                    -> collective
  unkeyed / mesh-dependent PRNG   -> determinism
  mesh-size-dependent local gemm  -> remesh
  theta dropped from _cache_key   -> cachekey

plus clean-entry-point checks over the real serving shard modes (the
zero-false-positive matrix) and the 8-virtual-device CLI acceptance run.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.analysis.mesh_verify import (
    MeshFinding,
    analyze_entry,
    cachekey_audit,
    check_remesh,
    local_dot_signatures,
    plan_key_audit,
    shardcheck_scenario,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh(n=1, axis="d"):
    return Mesh(np.asarray(jax.devices()[:n]), (axis,))


def _passes(findings):
    return sorted({f.pass_name for f in findings})


# -- pass (a): collective soundness ----------------------------------------------
def test_collective_clean_when_psum_backs_the_claim():
    mesh = _mesh()

    def entry(v):
        body = lambda u: jax.lax.psum(u.sum(), "d")
        return shard_map(body, mesh=mesh, in_specs=P("d"), out_specs=P(),
                         check_vma=False)(v)

    assert analyze_entry(entry, (jnp.arange(8.0),), entry="good") == []


def test_collective_catches_dropped_psum():
    """The injected regression: out_specs claim replication, but the
    reducing collective was dropped from the body."""
    mesh = _mesh()

    def entry(v):
        body = lambda u: u.sum()  # psum dropped
        return shard_map(body, mesh=mesh, in_specs=P("d"), out_specs=P(),
                         check_vma=False)(v)

    findings = analyze_entry(entry, (jnp.arange(8.0),), entry="bad")
    assert _passes(findings) == ["collective"]
    assert any(f.severity == "error" and "claim replication" in f.message
               for f in findings)
    # the finding carries a jaxpr path into the shard_map
    assert all("shard_map" in f.location for f in findings)


def test_collective_flags_redundant_psum_as_warning():
    mesh = _mesh()

    def entry(v):
        body = lambda u: jax.lax.psum(jnp.float32(1.0), "d") * u
        return shard_map(body, mesh=mesh, in_specs=P("d"),
                         out_specs=P("d"), check_vma=False)(v)

    findings = analyze_entry(entry, (jnp.arange(8.0),), entry="red")
    assert _passes(findings) == ["collective"]
    assert [f.severity for f in findings] == ["warning"]
    assert "redundant psum" in findings[0].message


# -- pass (b): determinism -------------------------------------------------------
def test_determinism_catches_unkeyed_prng():
    """The injected regression: a draw keyed by a baked-in PRNGKey(0)
    instead of the request's traced seed."""
    mesh = _mesh()

    def entry(v):
        def body(u):
            return u + jax.random.normal(jax.random.PRNGKey(0), u.shape)
        return shard_map(body, mesh=mesh, in_specs=P("d"),
                         out_specs=P("d"), check_vma=False)(v)

    findings = analyze_entry(entry, (jnp.arange(8.0),), entry="unkeyed",
                             replay_sensitive=True)
    assert _passes(findings) == ["determinism"]
    assert any("unkeyed PRNG" in f.message for f in findings)
    # caught even off the replay-sensitive path: constant keys are wrong
    # in every serving mode
    assert _passes(analyze_entry(entry, (jnp.arange(8.0),),
                                 entry="unkeyed")) == ["determinism"]


def test_determinism_clean_for_seed_keyed_draws():
    mesh = _mesh()

    def entry(seed, v):
        def body(s, u):
            k = jax.random.fold_in(jax.random.PRNGKey(s[0]), 3)
            return u + jax.random.normal(k, u.shape)
        return shard_map(body, mesh=mesh, in_specs=(P(), P("d")),
                         out_specs=P("d"), check_vma=False)(seed, v)

    findings = analyze_entry(entry, (jnp.zeros(1, jnp.int32),
                                     jnp.arange(8.0)),
                             entry="keyed", replay_sensitive=True)
    assert findings == []


def test_determinism_catches_mesh_dependent_prng_on_replay_path():
    mesh = _mesh()

    def entry(seed, v):
        def body(s, u):
            i = jax.lax.axis_index("d")
            k = jax.random.fold_in(jax.random.PRNGKey(s[0]), i)
            return u + jax.random.normal(k, u.shape)
        return shard_map(body, mesh=mesh, in_specs=(P(), P("d")),
                         out_specs=P("d"), check_vma=False)(seed, v)

    args = (jnp.zeros(1, jnp.int32), jnp.arange(8.0))
    findings = analyze_entry(entry, args, entry="meshy",
                             replay_sensitive=True)
    assert _passes(findings) == ["determinism"]
    assert any("mesh-dependent PRNG" in f.message for f in findings)
    # chart-style entries only promise fp tolerance: not flagged there
    assert analyze_entry(entry, args, entry="meshy") == []


def test_determinism_flags_collectives_only_on_replay_path():
    mesh = _mesh()

    def entry(v):
        def body(u):
            return u - jax.lax.pmax(u.max(), "d")
        return shard_map(body, mesh=mesh, in_specs=P("d"),
                         out_specs=P("d"), check_vma=False)(v)

    args = (jnp.arange(8.0),)
    findings = analyze_entry(entry, args, entry="replay",
                             replay_sensitive=True)
    assert _passes(findings) == ["determinism"]
    assert any("cross-device collective" in f.message for f in findings)
    assert analyze_entry(entry, args, entry="tolerant") == []


# -- pass (c): remesh invariance -------------------------------------------------
def _sigs_for_local_rows(rows):
    """Local dot signatures of a slab body whose per-device gemm height is
    ``rows`` — the quantity GPFieldServer pins via ``_local_rows``."""
    mesh = _mesh()
    W = jnp.ones((16, 16))

    def entry(v):
        body = lambda u: u @ W
        return shard_map(body, mesh=mesh, in_specs=P("d"),
                         out_specs=P("d"), check_vma=False)(v)

    return local_dot_signatures(
        jax.make_jaxpr(entry)(jnp.ones((rows, 16))))


def test_remesh_catches_mesh_size_dependent_local_shape():
    """The injected regression: local rows derived from capacity // n_dev
    at build time instead of pinned — the local gemm height changes when
    the mesh shrinks (8 rows over 8, 4, 2 devices -> 1, 2, 4 local)."""
    sigs = {n: _sigs_for_local_rows(8 // n) for n in (8, 4, 2)}
    findings = check_remesh("serve[samples]:fixture", sigs)
    assert _passes(findings) == ["remesh"]
    assert len(findings) == 2  # 8-vs-4 and 8-vs-2
    assert all("depend on the mesh size" in f.message for f in findings)


def test_remesh_clean_when_local_rows_pinned():
    sigs = {n: _sigs_for_local_rows(4) for n in (8, 4, 2)}
    assert check_remesh("serve[samples]:fixture", sigs) == []


def test_remesh_contract_only_tolerates_scaled_batch_extents():
    """Chart-sharded bodies scale spatial/batch extents with the ring;
    the contraction extents (matrix dims) are the invariant there."""
    mesh = _mesh()
    W = jnp.ones((16, 16))

    def entry_with_rows(rows):
        def entry(v):
            body = lambda u: u @ W
            return shard_map(body, mesh=mesh, in_specs=P("d"),
                             out_specs=P("d"), check_vma=False)(v)
        return jax.make_jaxpr(entry)(jnp.ones((rows, 16)))

    full = {n: local_dot_signatures(entry_with_rows(16 // n))
            for n in (1, 2, 4)}
    contract = {n: local_dot_signatures(entry_with_rows(16 // n),
                                        contract_only=True)
                for n in (1, 2, 4)}
    assert check_remesh("chart", full) != []
    assert check_remesh("chart", contract) == []


# -- pass (d): cache-key soundness -----------------------------------------------
def test_cachekey_catches_theta_dropped_from_key():
    """The injected regression: a server whose _cache_key drops every
    theta-bearing component — two fits at different rho collide on the
    key while their baked-in matrices differ."""
    from repro.launch.serve_gp import GPFieldServer

    class Doctored(GPFieldServer):
        def _cache_key(self, post):
            k = super()._cache_key(post)
            # strip the kernel fingerprint and the theta key
            return k[:1] + ("<no-kernel>",) + k[2:3] + ("<no-theta>",) \
                + k[4:]

    findings = cachekey_audit("tod", server_cls=Doctored)
    assert _passes(findings) == ["cachekey"]
    assert any("mats" in f.message and "collide" in f.message
               for f in findings)


def test_cachekey_clean_on_the_real_server():
    assert cachekey_audit("tod") == []


def test_plan_cached_key_covers_every_input():
    assert plan_key_audit("tod") == []


# -- finding records -------------------------------------------------------------
def test_finding_record_shape():
    f = MeshFinding("collective", "serve[samples]:tod", "top/eqn0",
                    "error", "msg")
    assert "[collective/error]" in str(f)
    assert f.to_dict() == {"pass_name": "collective",
                           "entry": "serve[samples]:tod",
                           "location": "top/eqn0", "severity": "error",
                           "message": "msg"}


# -- clean entry points over the real serving shard modes ------------------------
def test_shardcheck_clean_on_tod_all_modes():
    """All four passes over the real entry points (samples + chart
    serving, DistributedICR, PCG matvec, cache-key audits) — the
    zero-false-positive guarantee on the current device set."""
    checked = []
    findings = shardcheck_scenario("tod", checked=checked)
    assert findings == [], [str(f) for f in findings]
    assert "serve[samples]:tod" in checked
    assert "pcg_matvec:tod" in checked
    assert "cachekey:tod" in checked


@pytest.mark.slow
def test_shardcheck_cli_8dev():
    """The CI step: ``python -m repro.analysis shardcheck`` on 8 virtual
    devices (the CLI forces them itself) — full sweep, zero findings,
    JSON artifact written."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_BACKEND", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "shardcheck"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "shardcheck OK" in out.stdout
    assert "FAIL" not in out.stdout, out.stdout
