"""Examples must run end-to-end (subprocess, tiny sizes)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable] + args, env=env, timeout=timeout,
                         capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    return out.stdout


@pytest.mark.slow
def test_quickstart():
    out = _run(["examples/quickstart.py"])
    assert "covariance errors vs exact GP" in out
    assert "1M-point sample" in out


@pytest.mark.slow
def test_gp_regression_vi():
    out = _run(["examples/gp_regression_vi.py", "--steps", "80",
                "--n0", "32", "--levels", "4"])
    assert "MAP:" in out and "ADVI:" in out


@pytest.mark.slow
def test_dust_map():
    out = _run(["examples/dust_map_3d.py"])
    assert "voxels" in out and "corr(shell0, shell1)" in out


@pytest.mark.slow
def test_lm_train_example():
    out = _run(["examples/lm_train.py", "--arch", "xlstm-1.3b",
                "--steps", "20", "--batch", "4", "--seq-len", "64"])
    assert "loss" in out


@pytest.mark.slow
def test_serve_example():
    out = _run(["examples/serve_lm.py", "--requests", "3",
                "--max-new", "4"])
    assert "tok/s" in out


@pytest.mark.slow
def test_gp_regression_cg_example():
    out = _run(["examples/gp_regression_cg.py", "--n0", "16",
                "--levels", "3", "--samples", "8"])
    assert "cg_posterior:" in out
    assert "conditioned posterior served OK" in out
