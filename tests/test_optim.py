"""Optimizer substrate tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import (
    adafactor,
    adamw,
    clip_by_global_norm,
    constant,
    cosine_decay,
    global_norm,
    linear_warmup_cosine,
    sgd,
)


def _rosenbrock_ish(params):
    return jnp.sum((params["a"] - 1.0) ** 2) + jnp.sum(params["b"] ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(constant(5e-2)),
    lambda: adafactor(constant(5e-1), min_dim_size_to_factor=4),
    lambda: sgd(constant(1e-1), momentum=0.9),
])
def test_optimizers_minimize(make_opt):
    opt = make_opt()
    params = {"a": jnp.zeros((8, 8)), "b": jnp.ones((8,))}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        l, g = jax.value_and_grad(_rosenbrock_ish)(params)
        params, state = opt.update(g, state, params)
        return params, state, l

    l0 = None
    for _ in range(200):
        params, state, l = step(params, state)
        l0 = l if l0 is None else l0
    assert float(l) < 0.01 * float(l0)


def test_adafactor_state_is_factored():
    opt = adafactor(constant(1e-2), min_dim_size_to_factor=8)
    params = {"w": jnp.zeros((128, 64)), "b": jnp.zeros((64,))}
    state = opt.init(params)
    assert state.inner["w"]["vr"].shape == (128,)
    assert state.inner["w"]["vc"].shape == (64,)
    assert state.inner["b"]["v"].shape == (64,)
    # factored state is ~64x smaller than an AdamW moment
    full = 128 * 64
    fact = 128 + 64
    assert fact < full / 40


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(norm) == pytest.approx(20.0)
    small = {"a": jnp.full((4,), 0.01)}
    same, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01, rtol=1e-6)


def test_schedules():
    s = linear_warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, abs=1e-5)
    assert float(s(100)) < 0.2
    c = cosine_decay(2.0, 50, final_frac=0.5)
    assert float(c(0)) == pytest.approx(2.0)
    assert float(c(50)) == pytest.approx(1.0)


def test_bf16_params_fp32_state():
    """Moments stay fp32 even for bf16 params (mixed-precision training)."""
    opt = adamw(constant(1e-2))
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state.inner["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new_p, new_s = opt.update(g, state, params)
    assert new_p["w"].dtype == jnp.bfloat16
