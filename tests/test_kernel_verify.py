"""Launch-plan verifier (DESIGN.md §14): injected-regression self-tests.

The verifier is itself verified: each class of defect it exists to catch
is injected into a real exported plan (shifted index map, dropped halo
view, swapped forward/adjoint, out-of-bounds read, busted byte budget,
nonlinear forward, missing preferred_element_type) and must be caught by
the *named* pass. Clean cells must verify clean — the full 6-cell matrix
runs as ``python -m repro.analysis verify`` in the CI static-analysis
job; the fast cells are asserted clean here too.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import kernel_verify as kv
from repro.analysis.scenarios import SCENARIOS
from repro.core import matern32
from repro.core.refine import LevelGeom
from repro.kernels import dispatch as dsp
from repro.kernels.launch import IndexMap


def scenario(label):
    return next(s for s in SCENARIOS() if s.label == label)


@pytest.fixture(scope="module")
def tod_plans():
    """Forward + adjoint 1-D plans of the tod quick chart's last level."""
    scn = scenario("tod-fp32")
    geom = LevelGeom.for_level(scn.chart(), 2)
    fwd, adj = dsp.level_launch_plans(geom, samples=scn.samples,
                                     dtype="float32")
    return geom, fwd, adj


def passes(findings):
    return {f.pass_name for f in findings}


class TestInjectedRegressions:
    """The three canonical injections, each caught by its named pass."""

    def test_shifted_index_map_is_a_coverage_finding(self, tod_plans):
        _, fwd, _ = tod_plans
        out = fwd.outputs[0]
        ndim = len(out.block_shape)
        shifted = IndexMap("(b, i + 1)",
                           lambda i, b: (b, i + 1) + (0,) * (ndim - 2))
        doctored = dataclasses.replace(
            fwd, outputs=(dataclasses.replace(out, index_map=shifted),))
        findings = kv.check_coverage(doctored)
        assert findings, "shifted output index map went unnoticed"
        assert passes(findings) == {"coverage"}
        text = " ".join(f.message for f in findings)
        assert "never written" in text or "out-of-range" in text
        # the untouched plan is clean
        assert kv.check_coverage(fwd) == []

    def test_dropped_halo_view_is_a_halo_finding(self, tod_plans):
        _, fwd, adj = tod_plans
        for plan in (fwd, adj):
            doctored = dataclasses.replace(
                plan,
                inputs=tuple(op for op in plan.inputs if not op.halo_of))
            findings = kv.check_halo(doctored)
            assert findings, f"{plan.kernel}: dropped halo went unnoticed"
            assert passes(findings) == {"halo"}
            assert "not covered" in findings[0].message
            assert kv.check_halo(plan) == []

    def test_swapped_adjoint_is_a_transpose_finding(self):
        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)

        @jax.custom_vjp
        def apply(x):
            return A @ x

        # BUG under test: the backward applies A, not A.T
        apply.defvjp(lambda x: (A @ x, None), lambda _res, g: (A @ g,))

        x = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
        findings = kv.transpose_dot_check(apply, (x,), rtol=1e-4)
        assert passes(findings) == {"transpose"}
        assert "not the transpose" in findings[0].message

        # the fixed pair passes
        apply.defvjp(lambda x: (A @ x, None), lambda _res, g: (A.T @ g,))
        assert kv.transpose_dot_check(apply, (x,), rtol=1e-4) == []


class TestPermanentNegatives:
    """One negative fixture per remaining pass, kept as regression guards."""

    def test_out_of_bounds_read_is_a_bounds_finding(self, tod_plans):
        _, fwd, _ = tod_plans
        op = fwd.inputs[0]
        ndim = len(op.block_shape)
        way_out = IndexMap("(b, i + 99)",
                           lambda i, b: (b, i + 99) + (0,) * (ndim - 2))
        doctored = dataclasses.replace(
            fwd, inputs=(dataclasses.replace(op, index_map=way_out),)
            + fwd.inputs[1:])
        findings = kv.check_bounds(doctored)
        assert passes(findings) == {"bounds"}
        assert "outside the padded operand extent" in findings[0].message
        assert kv.check_bounds(fwd) == []

    def test_budget_bust_is_a_bytes_finding(self):
        scn = scenario("image-fp32")
        geom = LevelGeom.for_level(scn.chart(), 0)
        plan = dsp.level_launch_plans(geom, samples=scn.samples,
                                      dtype="float32")[0]
        assert plan.kernel == "refine_nd_fused"
        findings = kv.check_bytes(plan, geom=geom, route=dsp.ROUTE_ND_FUSED,
                                  samples=scn.samples, vmem_budget=1)
        assert "bytes" in passes(findings)
        assert any("exceeds the VMEM budget" in f.message for f in findings)

    def test_model_undercount_is_a_bytes_finding(self, tod_plans):
        _, fwd, _ = tod_plans
        op = fwd.inputs[0]
        bloated = dataclasses.replace(
            op, block_shape=tuple(64 * b for b in op.block_shape))
        doctored = dataclasses.replace(fwd, inputs=(bloated,)
                                       + fwd.inputs[1:])
        findings = kv.check_bytes(doctored)
        assert any("block1d_bytes" in f.message for f in findings)

    def test_nonlinear_forward_is_caught_by_the_taint_walk(self):
        x = jnp.ones((8,), jnp.float32)
        findings = kv.check_linearity(lambda v: v * v, (x,))
        assert findings and "bilinear" in findings[0].message
        findings = kv.check_linearity(jnp.exp, (x,))
        assert findings and "not linear" in findings[0].message
        assert kv.check_linearity(lambda v: 3.0 * v + 1.0, (x,)) == []

    def test_hygiene_flags_pet_and_control_flow(self):
        x = jnp.ones((8, 8), jnp.float32)

        def bad(v):
            y = jax.lax.dot(v, v)  # no preferred_element_type
            return jax.lax.while_loop(lambda c: jnp.sum(c) < 0.0,
                                      lambda c: c + 1.0, y)

        findings = kv.check_hygiene(bad, (x,))
        text = " ".join(f.message for f in findings)
        assert "preferred_element_type" in text
        assert "control flow" in text


class TestCleanCells:
    """Exported plans of the fast cells verify clean end to end."""

    @pytest.mark.parametrize("label", ["tod-fp32", "tod-bf16"])
    def test_cell_is_clean(self, label):
        findings = kv.verify_scenario(scenario(label))
        assert findings == [], "\n".join(str(f) for f in findings)


class TestAxesRoute:
    """The per-axis N-D route has no quick-chart cell; verify it
    explicitly so its plans and custom VJP stay covered."""

    def test_axes_nd_group_verifies_clean(self):
        scn = scenario("image-fp32")
        chart = scn.chart()
        geom = LevelGeom.for_level(chart, 0)
        plans = dsp.level_launch_plans(geom, dsp.ROUTE_AXES_ND,
                                       samples=scn.samples,
                                       dtype="float32")
        assert len(plans) == 4  # fwd + adjoint per axis
        grp = {"level": 0, "route": dsp.ROUTE_AXES_ND, "geom": geom,
               "plans": plans}
        kernel = matern32.with_defaults(rho=scn.rho)()
        findings = kv.verify_group(grp, chart, kernel,
                                   samples=scn.samples,
                                   storage=jnp.float32,
                                   scenario=scn.label)
        assert findings == [], "\n".join(str(f) for f in findings)


class TestRebaselineGate:
    """tools/update_fingerprints.py refuses --update while the verifier
    reports findings (unless --force)."""

    def _load_tool(self):
        import importlib.util
        import pathlib
        path = (pathlib.Path(__file__).resolve().parents[1] / "tools"
                / "update_fingerprints.py")
        spec = importlib.util.spec_from_file_location("upd_fp_tool", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    def test_gate_refuses_on_findings(self, monkeypatch, capsys):
        from repro.analysis import kernel_verify
        from repro.analysis.lint import LintFinding
        tool = self._load_tool()
        monkeypatch.setattr(
            kernel_verify, "verify_scenario",
            lambda scn, **kw: [LintFinding("coverage", scn.label, "level=0",
                                           "injected")])
        assert tool._verifier_gate(["--scenario", "tod-fp32"]) == 1
        err = capsys.readouterr().err
        assert "refusing to re-baseline" in err
        assert "injected" in err

    def test_gate_passes_clean(self, monkeypatch):
        from repro.analysis import kernel_verify
        tool = self._load_tool()
        monkeypatch.setattr(kernel_verify, "verify_scenario",
                            lambda scn, **kw: [])
        assert tool._verifier_gate([]) == 0
