"""KISS-GP baseline tests (paper §2 Eq. 1/15, §5.2)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import KissGP, exact_cov, cov_errors, matern32
from tests.test_icr_math import paper_log_setup


@pytest.fixture(scope="module")
def setup():
    c, rho = paper_log_setup()
    xs = np.asarray(c.grid_positions(5))[:, 0]
    k = matern32.with_defaults(rho=rho)()
    return c, xs, k, rho


def test_dense_cov_matches_operator(setup):
    """Dense W·K_UU·Wᵀ must agree with the FFT operator path."""
    _, xs, k, _ = setup
    kiss = KissGP(x=xs, kernel_fn=k)
    dense = np.asarray(kiss.dense_cov())
    v = np.random.default_rng(0).normal(size=len(xs))
    lhs = np.asarray(kiss.matvec(jnp.asarray(v))) - kiss.jitter * v
    np.testing.assert_allclose(lhs, dense @ v, rtol=2e-4, atol=2e-5)


def test_paper_fig3_accuracy(setup):
    """Paper §5.2: KISS-GP MAE ≈ 1.8e-3 (31% of ICR's), max err on diag."""
    c, xs, k, _ = setup
    errs = {n: float(v) for n, v in
            cov_errors(KissGP(x=xs, kernel_fn=k).dense_cov(),
                       exact_cov(c, k)).items()}
    assert errs["mae"] < 3e-3          # paper: 1.8e-3
    assert errs["max_abs_err"] < 8e-2  # paper: 4.9e-2
    # paper: the max error occurs on the diagonal
    assert np.isclose(errs["max_abs_err"], errs["max_diag_err"], rtol=0.3)


def test_cg_converges_well_conditioned():
    xs = np.sort(np.random.default_rng(0).uniform(0, 10, 128))
    k = matern32.with_defaults(rho=1.0)()
    kiss = KissGP(x=xs, kernel_fn=k, jitter=1e-1)
    y = jnp.asarray(np.random.default_rng(1).normal(size=128))
    sol = kiss.solve_cg(y, 40)
    res = float(jnp.linalg.norm(kiss.matvec(sol) - y) / jnp.linalg.norm(y))
    assert res < 5e-4  # float32


def test_slq_logdet_close_to_exact():
    xs = np.sort(np.random.default_rng(0).uniform(0, 10, 64))
    k = matern32.with_defaults(rho=0.5)()
    kiss = KissGP(x=xs, kernel_fn=k, jitter=1e-1)
    dense = np.asarray(kiss.dense_cov()) + kiss.jitter * np.eye(64)
    exact = float(np.linalg.slogdet(dense)[1])
    est = float(kiss.logdet_slq(jax.random.PRNGKey(0), probes=30,
                                lanczos_iters=20))
    assert abs(est - exact) / abs(exact) < 0.2


def test_forward_pass_jits(setup):
    _, xs, k, _ = setup
    kiss = KissGP(x=xs, kernel_fn=k)
    y = jnp.asarray(np.random.default_rng(0).normal(size=len(xs)))
    sol, ld = jax.jit(kiss.forward_pass)(y, jax.random.PRNGKey(0))
    assert np.isfinite(np.asarray(sol)).all() and np.isfinite(float(ld))


def test_singularity_contrast_with_icr(setup):
    """Paper §5.2: KISS-GP's K can be (near-)singular for irregular spacing,
    ICR's is full-rank by construction."""
    c, xs, k, rho = setup
    from repro.core import ICR
    kiss_cov = np.asarray(KissGP(x=xs, kernel_fn=k).dense_cov())
    icr = ICR(chart=c, kernel=matern32.with_defaults(rho=rho))
    icr_cov = np.asarray(icr.implicit_cov(dtype=jnp.float32))
    ev_kiss = np.linalg.eigvalsh(kiss_cov)
    ev_icr = np.linalg.eigvalsh(icr_cov)
    # ICR minimum eigenvalue is orders of magnitude healthier
    assert ev_icr.min() > 1e3 * max(ev_kiss.min(), 0.0) or ev_kiss.min() <= 0


def test_solve_early_exit_reports_convergence():
    """§16: `solve` exits on rtol instead of burning the full budget."""
    from repro.solvers.reports import CONVERGED

    xs = np.sort(np.random.default_rng(0).uniform(0, 10, 128))
    k = matern32.with_defaults(rho=1.0)()
    kiss = KissGP(x=xs, kernel_fn=k, jitter=1e-1)
    y = jnp.asarray(np.random.default_rng(1).normal(size=128))
    x, stats = kiss.solve(y, rtol=1e-4, max_iters=200)
    assert int(stats["status"]) == CONVERGED
    assert int(stats["iters"]) < 200  # early exit, not budget exhaustion
    res = float(jnp.linalg.norm(kiss.matvec(x) - y) / jnp.linalg.norm(y))
    assert res < 2e-4


def test_solve_cg_shim_warns_and_matches_solve():
    xs = np.sort(np.random.default_rng(0).uniform(0, 10, 64))
    k = matern32.with_defaults(rho=1.0)()
    kiss = KissGP(x=xs, kernel_fn=k, jitter=1e-1)
    y = jnp.asarray(np.random.default_rng(1).normal(size=64))
    with pytest.warns(DeprecationWarning, match="solve_cg is deprecated"):
        x_shim = kiss.solve_cg(y, 40)
    x_new, _ = kiss.solve(y, max_iters=40)
    assert np.array_equal(np.asarray(x_shim), np.asarray(x_new))


def test_slq_logdet_survives_lanczos_breakdown():
    """Constant kernel => K = 11ᵀ (rank 1), the Krylov space saturates at
    dim 2 and Lanczos breaks down. The truncated recurrence must still
    return a finite estimate near the dense log-det (the old
    normalize-by-eps path emitted junk directions)."""
    xs = np.sort(np.random.default_rng(0).uniform(0, 10, 80))
    kiss = KissGP(x=xs, kernel_fn=lambda d: jnp.ones_like(d), jitter=1e-4)
    est = float(kiss.logdet_slq(jax.random.PRNGKey(1), probes=10,
                                lanczos_iters=15))
    dense = np.asarray(kiss.dense_cov()) + kiss.jitter * np.eye(len(xs))
    exact = float(np.linalg.slogdet(dense)[1])
    assert np.isfinite(est)
    assert abs(est - exact) / abs(exact) < 0.05
