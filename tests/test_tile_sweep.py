"""Seeded-random tile sweep: verifier + numerical parity at off-default
tiles (DESIGN.md §14).

The autotuners pick ONE tile per geometry, so CI would only ever
exercise that point of the (block_families, batch_block, sample_block)
space. This sweep draws seeded-random *valid* configs per route, exports
the launch plans at those tiles (``level_launch_plans`` /
``chart_launch_plans`` overrides — the same records the kernel impls
launch through), requires every static verifier pass to hold, and checks
numerical parity of the interpret-mode run against the jnp reference at
the same tile.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.analysis import kernel_verify as kv
from repro.analysis.scenarios import SCENARIOS
from repro.core import matern32
from repro.core.refine import LevelGeom, axis_refinement_matrices_level
from repro.kernels import dispatch as dsp
from repro.kernels.nd_fused import refine_nd_fused
from repro.kernels.pyramid import refine_pyramid

SEED = 20260808
SAMPLES = 4


def scenario(label):
    return next(s for s in SCENARIOS() if s.label == label)


def draw_1d_configs(rng, t, floor, n):
    """Valid (block_families, batch_block) pairs, non-powers included."""
    cfgs = set()
    while len(cfgs) < n:
        b_f = int(rng.integers(floor, t + 1))
        b_b = int(rng.integers(1, SAMPLES + 1))
        cfgs.add((b_f, b_b))
    return sorted(cfgs)


def assert_plans_clean(plans, geom, route, *, label):
    for plan in plans:
        findings = kv.verify_plan(plan, geom=geom, route=route,
                                  samples=SAMPLES, scenario=label)
        assert findings == [], "\n".join(str(f) for f in findings)


class TestSweep1D:
    def test_stationary_1d_tiles(self):
        scn = scenario("tod-fp32")
        chart = scn.chart()
        kernel = matern32.with_defaults(rho=scn.rho)()
        geom = LevelGeom.for_level(chart, 2)
        t = geom.T[0]
        floor = dsp.block1d_floor(t, geom.n_csz, geom.n_fsz)
        rng = np.random.default_rng(SEED)
        rs, ds = axis_refinement_matrices_level(chart, kernel, 2)
        r, d = jnp.asarray(rs[0]), jnp.asarray(ds[0])
        field = jnp.asarray(
            rng.normal(size=(SAMPLES,) + tuple(geom.coarse_shape)),
            jnp.float32)
        xi = jnp.asarray(rng.normal(size=(SAMPLES, t, geom.n_fsz)),
                         jnp.float32)
        want = dsp.refine(field, xi, r, d, geom,
                          backend=dsp.BACKEND_REFERENCE, sample_axis=True)
        for b_f, b_b in draw_1d_configs(rng, t, floor, 4):
            plans = dsp.level_launch_plans(
                geom, samples=SAMPLES, dtype="float32",
                block_families=b_f, sample_block=b_b)
            assert plans[0].params["b_f"] == b_f
            assert plans[0].params["b_b"] == b_b
            assert_plans_clean(plans, geom, dsp.route_for(geom),
                               label=f"tod b_f={b_f} b_b={b_b}")
            got = dsp.refine(field, xi, r, d, geom,
                             backend=dsp.BACKEND_INTERPRET,
                             block_families=b_f, sample_block=b_b,
                             sample_axis=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)


class TestSweepFused:
    def test_nd_fused_tiles(self):
        scn = scenario("image-fp32")
        chart = scn.chart()
        kernel = matern32.with_defaults(rho=scn.rho)()
        geom = LevelGeom.for_level(chart, 1)
        route = dsp.route_for(geom, have_axis_mats=True)
        assert route == dsp.ROUTE_ND_FUSED
        rng = np.random.default_rng(SEED + 1)
        rs, ds = axis_refinement_matrices_level(chart, kernel, 1)
        rs = [jnp.asarray(r) for r in rs]
        ds = [jnp.asarray(d) for d in ds]
        nd = len(geom.coarse_shape)
        field = jnp.asarray(
            rng.normal(size=(SAMPLES,) + tuple(geom.coarse_shape)),
            jnp.float32)
        xi = jnp.asarray(
            rng.normal(size=(SAMPLES, int(np.prod(geom.T)),
                             geom.n_fsz ** nd)), jnp.float32)
        want = refine_nd_fused(field, xi, rs, ds, geom,
                               interpret="reference", sample_axis=True)
        q_max = (geom.n_csz - 1) // max(1, geom.n_fsz // 2)
        cfgs = {(int(rng.integers(max(q_max, 1), geom.T[0] + 1)),
                 int(rng.integers(1, SAMPLES + 1))) for _ in range(3)}
        for b_f, s_b in sorted(cfgs):
            plans = dsp.level_launch_plans(
                geom, route, samples=SAMPLES, dtype="float32",
                block_families=b_f, sample_block=s_b)
            assert_plans_clean(plans, geom, route,
                               label=f"image b_f={b_f} s_b={s_b}")
            got = refine_nd_fused(field, xi, rs, ds, geom, interpret=True,
                                  block_families=b_f, sample_block=s_b,
                                  sample_axis=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)


class TestSweepPyramid:
    @pytest.mark.parametrize("s_b", [1, 3])
    def test_pyramid_cover_sample_blocks(self, s_b):
        scn = scenario("dust-fp32")
        chart = scn.chart()
        kernel = matern32.with_defaults(rho=scn.rho)()
        groups = dsp.chart_launch_plans(chart, samples=SAMPLES,
                                        dtype="float32", sample_block=s_b)
        grp = groups[0]
        assert grp["route"] == dsp.ROUTE_PYRAMID
        plan = grp["plans"][0]
        assert plan.params["s_b"] == s_b
        geoms = grp["geom"]
        findings = kv.verify_plan(plan, samples=SAMPLES,
                                  scenario=f"dust s_b={s_b}")
        assert findings == [], "\n".join(str(f) for f in findings)

        rng = np.random.default_rng(SEED + 2)
        mats, xis = [], []
        for lvl, g in enumerate(geoms):
            rs, ds = axis_refinement_matrices_level(chart, kernel, lvl)
            mats.append(([jnp.asarray(r) for r in rs],
                         [jnp.asarray(d) for d in ds]))
            nd = len(g.coarse_shape)
            xis.append(jnp.asarray(
                rng.normal(size=(SAMPLES, int(np.prod(g.T)),
                                 g.n_fsz ** nd)), jnp.float32))
        field = jnp.asarray(
            rng.normal(size=(SAMPLES,) + tuple(geoms[0].coarse_shape)),
            jnp.float32)
        want = refine_pyramid(field, xis, mats, geoms,
                              interpret="reference", sample_axis=True)
        got = refine_pyramid(field, xis, mats, geoms, interpret=True,
                             sample_block=s_b, sample_axis=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
