"""Batched serving loop: continuous batching, slot refill, determinism."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.serve import BatchedServer, Request


@pytest.fixture(scope="module")
def server():
    cfg = get_arch("starcoder2-15b").reduced()
    return BatchedServer(cfg, batch_slots=2, s_max=32), cfg


def test_serves_more_requests_than_slots(server):
    srv, cfg = server
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=4),
                    max_new=4) for _ in range(5)]
    srv.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_generation_deterministic():
    cfg = get_arch("starcoder2-15b").reduced()
    prompt = np.arange(1, 6)

    def gen():
        srv = BatchedServer(cfg, batch_slots=2, s_max=32, seed=9)
        reqs = [Request(prompt=prompt.copy(), max_new=6)]
        srv.run(reqs)
        return reqs[0].out

    assert gen() == gen()


def test_batching_does_not_change_output():
    """A request decoded alone must match the same request decoded
    alongside others (slot isolation)."""
    cfg = get_arch("starcoder2-15b").reduced()
    prompt = np.arange(2, 9)

    srv1 = BatchedServer(cfg, batch_slots=2, s_max=32, seed=5)
    solo = [Request(prompt=prompt.copy(), max_new=5)]
    srv1.run(solo)

    srv2 = BatchedServer(cfg, batch_slots=2, s_max=32, seed=5)
    rng = np.random.default_rng(1)
    both = [Request(prompt=prompt.copy(), max_new=5),
            Request(prompt=rng.integers(0, cfg.vocab_size, 3), max_new=5)]
    srv2.run(both)
    assert solo[0].out == both[0].out
