"""Batched serving loop: continuous batching, slot refill, determinism."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.serve import BatchedServer, Request


@pytest.fixture(scope="module")
def server():
    cfg = get_arch("starcoder2-15b").reduced()
    return BatchedServer(cfg, batch_slots=2, s_max=32), cfg


def test_serves_more_requests_than_slots(server):
    srv, cfg = server
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=4),
                    max_new=4) for _ in range(5)]
    srv.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_generation_deterministic():
    cfg = get_arch("starcoder2-15b").reduced()
    prompt = np.arange(1, 6)

    def gen():
        srv = BatchedServer(cfg, batch_slots=2, s_max=32, seed=9)
        reqs = [Request(prompt=prompt.copy(), max_new=6)]
        srv.run(reqs)
        return reqs[0].out

    assert gen() == gen()


def test_temperature_sampling():
    """temperature > 0 must actually sample (the old server always argmaxed)
    — deterministically for a fixed seed, and usually differently from
    greedy on a random-init model."""
    cfg = get_arch("starcoder2-15b").reduced()
    prompt = np.arange(1, 6)

    def gen(temperature, seed=3):
        srv = BatchedServer(cfg, batch_slots=1, s_max=32, seed=seed,
                            temperature=temperature)
        reqs = [Request(prompt=prompt.copy(), max_new=8)]
        srv.run(reqs)
        return reqs[0].out

    assert gen(1.5) == gen(1.5)  # same seed -> same sample path
    greedy = gen(0.0)
    assert len(greedy) == 8
    assert all(0 <= t < cfg.vocab_size for t in gen(1.5))
    # near-uniform logits at init: 8 sampled tokens matching greedy exactly
    # is (1/vocab)^8-unlikely; two seeds make a flake astronomically so
    assert gen(5.0, seed=3) != greedy or gen(5.0, seed=4) != greedy


def test_prefill_decode_token_accounting():
    """tok/s reporting: prefill and decode counted separately (the old
    tokens_served lumped prompt ingestion into the throughput figure)."""
    cfg = get_arch("starcoder2-15b").reduced()
    prompt = np.arange(1, 5)  # 4 prompt tokens
    srv = BatchedServer(cfg, batch_slots=1, s_max=32, seed=0)
    reqs = [Request(prompt=prompt.copy(), max_new=6)]
    srv.run(reqs)
    # the step that ingests the last prompt token emits the first decode
    # token, so prefill counts len(prompt) - 1 steps
    assert srv.decode_tokens == 6
    assert srv.prefill_tokens == len(prompt) - 1
    assert srv.tokens_served == srv.prefill_tokens + srv.decode_tokens


def test_long_prompt_rejected_not_hung():
    """A prompt >= s_max used to hang the server: the prefill branch never
    set req.done, so run() spun to max_iters while pos grew past the KV
    cache bounds. It must now be rejected at admission, marked done."""
    cfg = get_arch("starcoder2-15b").reduced()
    s_max = 16
    srv = BatchedServer(cfg, batch_slots=2, s_max=s_max, seed=0)
    rng = np.random.default_rng(2)
    long1 = Request(prompt=rng.integers(0, cfg.vocab_size, s_max),
                    max_new=4)
    long2 = Request(prompt=rng.integers(0, cfg.vocab_size, s_max + 7),
                    max_new=4)
    ok = Request(prompt=rng.integers(0, cfg.vocab_size, 4), max_new=4)
    srv.run([long1, ok, long2], max_iters=200)  # far below the old spin
    assert long1.done and long1.error and long1.out == []
    assert long2.done and long2.error and long2.out == []
    assert ok.done and ok.error is None and len(ok.out) == 4
    # the rejected requests never touched a slot or the position counters
    assert (srv.pos < s_max).all()


def test_slot_reuse_decode_consistent():
    """Admitting a second request into a previously used slot must produce
    exactly the output a fresh server gives it: the slot's cache rows are
    cleared on reuse (attention KV is position-masked, but recurrent
    states would carry the finished request's state forward)."""
    prompt_a = np.arange(3, 10)
    prompt_b = np.arange(11, 16)
    for arch in ("starcoder2-15b", "zamba2-7b"):
        cfg = get_arch(arch).reduced()
        # one slot: request B necessarily reuses request A's slot
        srv = BatchedServer(cfg, batch_slots=1, s_max=32, seed=7)
        a = Request(prompt=prompt_a.copy(), max_new=5)
        b = Request(prompt=prompt_b.copy(), max_new=5)
        srv.run([a, b])

        fresh = BatchedServer(cfg, batch_slots=1, s_max=32, seed=7)
        b_fresh = Request(prompt=prompt_b.copy(), max_new=5)
        fresh.run([b_fresh])
        assert b.out == b_fresh.out, arch


def test_batching_does_not_change_output():
    """A request decoded alone must match the same request decoded
    alongside others (slot isolation)."""
    cfg = get_arch("starcoder2-15b").reduced()
    prompt = np.arange(2, 9)

    srv1 = BatchedServer(cfg, batch_slots=2, s_max=32, seed=5)
    solo = [Request(prompt=prompt.copy(), max_new=5)]
    srv1.run(solo)

    srv2 = BatchedServer(cfg, batch_slots=2, s_max=32, seed=5)
    rng = np.random.default_rng(1)
    both = [Request(prompt=prompt.copy(), max_new=5),
            Request(prompt=rng.integers(0, cfg.vocab_size, 3), max_new=5)]
    srv2.run(both)
    assert solo[0].out == both[0].out
