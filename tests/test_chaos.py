"""Fault-tolerant sharded serving (ISSUE 8; DESIGN.md §15).

In-process tests run on the suite's single real device: retry/backoff
semantics, structured admission errors (poisoned-ξ isolation, geometry
mismatch, θ pinning), degradation reports from `elastic.remesh`, the
mesh-aware executable cache key, straggler detection from serving step
times, and 1-device-mesh parity for both shard modes.

The multi-device chaos acceptance suite (kill a device mid-stream on 8
virtual CPU devices, re-plan to a mesh of 7, bit-identical replay,
cache-miss assertion) runs in a subprocess because
``--xla_force_host_platform_device_count`` must be set before jax
initializes; see ``repro.distributed.chaos``.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import regular_chart
from repro.distributed import elastic
from repro.distributed.chaos import (
    ChaosInjector,
    KillDevice,
    Straggler,
    poison_request,
)
from repro.distributed.fault import (
    DeviceLossError,
    RetryPolicy,
    ServingFaultSupervisor,
    StragglerMonitor,
)
from repro.launch.mesh import make_mesh
from repro.launch.serve_gp import GPFieldServer, GPRequest, demo_posterior

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHART = regular_chart(32, 3, boundary="reflect")


def _post(rho=8.0, chart=CHART):
    return demo_posterior(chart, rho)


# -- retry / timeout / backoff ---------------------------------------------------
def test_transient_errors_retry_with_backoff():
    sup = ServingFaultSupervisor(
        retry=RetryPolicy(max_retries=3, backoff_s=0.001))
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient collective failure")
        return 42

    assert sup.execute(flaky) == 42
    assert sup.transient_retries == 2
    assert sup.monitor._times  # successful attempt fed the monitor


def test_retries_exhausted_reraises():
    sup = ServingFaultSupervisor(
        retry=RetryPolicy(max_retries=1, backoff_s=0.001))
    with pytest.raises(RuntimeError, match="persistent"):
        sup.execute(lambda: (_ for _ in ()).throw(
            RuntimeError("persistent failure")))
    assert sup.transient_retries == 1


def test_device_loss_is_never_retried_in_place():
    sup = ServingFaultSupervisor(retry=RetryPolicy(max_retries=5))
    calls = {"n": 0}

    def dead():
        calls["n"] += 1
        raise DeviceLossError([3])

    with pytest.raises(DeviceLossError):
        sup.execute(dead)
    assert calls["n"] == 1  # no in-place retry on a dead mesh
    assert sup.device_losses == 1


def test_posthoc_timeout_counted():
    sup = ServingFaultSupervisor(retry=RetryPolicy(timeout_s=0.0))
    sup.execute(lambda: 1)
    assert sup.timeouts == 1


# -- remesh degradation reports --------------------------------------------------
def test_remesh_report_flags_missing_axis_and_indivisible():
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    tree = {"w": np.zeros((8, 4)), "b": np.zeros(3)}
    specs = {"w": P("model"), "b": P("data")}
    out, report = elastic.remesh_report(tree, mesh, specs)
    assert out["w"].shape == (8, 4)  # placed (replicated), not dropped
    assert len(report) == 1
    d = report[0]
    assert d.path == "['w']" or "w" in d.path
    assert "model" in d.reason and d.applied == str(P(None, None))
    # divisible specs are honored silently
    _, clean = elastic.remesh_report({"b": np.zeros(3)}, mesh,
                                     {"b": P("data")})
    assert clean == []


def test_remesh_logs_and_callbacks_on_degrade():
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((1,), ("data",))
    seen = []
    elastic.remesh({"w": np.zeros(4)}, mesh, {"w": P("model")},
                   on_degrade=seen.append)
    assert len(seen) == 1 and isinstance(seen[0], elastic.Degradation)
    assert "model" in str(seen[0])


def test_shrink_mesh():
    mesh = make_mesh((1,), ("data",))
    dev_id = int(np.asarray(mesh.devices).flat[0].id)
    assert elastic.shrink_mesh(mesh, [dev_id + 999]) is None  # 1 survivor
    with pytest.raises(RuntimeError, match="no devices survive"):
        elastic.shrink_mesh(mesh, [dev_id])


# -- admission validation (request-level isolation) ------------------------------
def test_poisoned_request_cannot_touch_healthy_neighbors():
    """Regression (ISSUE 8): a NaN-ξ request packed next to a healthy one
    is rejected at admission; the neighbor's moments are bit-identical to
    a run without the poisoned request in the queue."""
    post = _post()
    clean = GPRequest(kind="moments", n=6, seed=2)
    GPFieldServer(post, slab=8).run([clean])

    bad = poison_request(post.icr)
    good = GPRequest(kind="moments", n=6, seed=2)
    GPFieldServer(post, slab=8).run([bad, good])

    assert bad.done and bad.error is not None
    assert bad.error.code == "xi-nonfinite"
    assert good.error is None
    assert np.isfinite(good.mean).all() and np.isfinite(good.std).all()
    np.testing.assert_array_equal(good.mean, clean.mean)
    np.testing.assert_array_equal(good.std, clean.std)


def test_xi_geometry_mismatch_rejected():
    srv = GPFieldServer(_post(), slab=2)
    wrong = GPRequest(kind="sample", n=1,
                      xi=[np.zeros(3, np.float32)])
    srv.run([wrong])
    assert wrong.error is not None and wrong.error.code == "xi-geometry"
    assert "xi_shapes" in wrong.error.message


def test_theta_pinning():
    srv = GPFieldServer(_post(rho=8.0), slab=2)
    nan = GPRequest(kind="sample", n=1, theta={"rho": float("nan")})
    stale = GPRequest(kind="sample", n=1, theta={"rho": 99.0})
    ok = GPRequest(kind="sample", n=1, theta={"rho": 8.0})
    srv.run([nan, stale, ok])
    assert nan.error.code == "theta-nonfinite"
    assert stale.error.code == "theta-mismatch"
    assert ok.error is None and len(ok.fields) == 1


def test_xi_override_draws_around_client_excitation():
    """A request's ξ replaces the posterior mean for its rows only."""
    import jax.numpy as jnp

    post = _post()
    icr = post.icr
    rng = np.random.RandomState(0)
    xi = [rng.randn(*s).astype(np.float32) for s in icr.xi_shapes()]
    req = GPRequest(kind="sample", n=1, seed=7, xi=xi)
    plain = GPRequest(kind="sample", n=1, seed=7)
    GPFieldServer(post, slab=4).run([req, plain])
    assert req.error is None and plain.error is None

    k = jax.random.fold_in(jax.random.PRNGKey(7), 0)
    ks = jax.random.split(k, len(xi))
    mats = icr.matrices_cached(post.theta)
    xs = [jnp.asarray(x) + s * jax.random.normal(kk, x.shape, jnp.float32)
          for kk, x, s in zip(ks, xi, post.std())]
    want = np.asarray(icr.apply_sqrt(mats, xs))
    np.testing.assert_allclose(req.fields[0], want, rtol=1e-5, atol=1e-5)
    assert np.abs(req.fields[0] - plain.fields[0]).max() > 1e-3


def test_nonfinite_posterior_rejected_at_install():
    post = _post()
    poisoned = post.mean[0].at[0].set(np.nan)
    bad = type(post)(icr=post.icr, mean=[poisoned, *post.mean[1:]],
                     log_std=post.log_std, theta=post.theta)
    with pytest.raises(ValueError, match="non-finite"):
        GPFieldServer(bad, slab=2)


# -- mesh-aware executable cache -------------------------------------------------
def test_mesh_is_part_of_the_cache_key_and_fingerprint():
    post = _post()
    plain = GPFieldServer(post, slab=4)
    mesh = make_mesh((1,), ("data",))
    meshed = GPFieldServer(post, slab=4, mesh=mesh)
    fp_plain = plain.cache_key_fingerprint()
    fp_mesh = meshed.cache_key_fingerprint()
    assert fp_plain["mesh"] == "unsharded"
    assert fp_mesh["mesh"].startswith("samples:1:")
    assert fp_plain["digest"] != fp_mesh["digest"]
    assert plain._cache_key(post) != meshed._cache_key(post)
    # chart sharding is a third distinct key
    charted = GPFieldServer(post, slab=4,
                            mesh=make_mesh((1,), ("space",)), shard="chart")
    assert charted.cache_key_fingerprint()["digest"] not in (
        fp_plain["digest"], fp_mesh["digest"])


def test_plan_cached_mesh_key():
    from repro.kernels import dispatch

    dispatch.plan_cache_clear()
    p1 = dispatch.plan_cached(CHART, samples=4)
    p2 = dispatch.plan_cached(CHART, samples=4,
                              mesh_key=("samples", ("data",), (8,)))
    assert p1 is not p2  # a re-mesh re-plans, never a stale hit
    assert p1 == p2      # ...but the per-device routing is unchanged
    assert dispatch.plan_cache_stats["misses"] == 2


def test_single_device_mesh_matches_unsharded_bitwise():
    """shard="samples" on a trivial 1-device mesh reduces to the plain
    server exactly — (seed, row) keying is mesh-independent."""
    post = _post()
    mesh = make_mesh((1,), ("data",))
    a = GPRequest(kind="sample", n=3, seed=11)
    b = GPRequest(kind="sample", n=3, seed=11)
    GPFieldServer(post, slab=4).run([a])
    srv = GPFieldServer(post, slab=4, mesh=mesh)
    srv.run([b])
    assert srv.serving_mode.startswith("sharded-samples")
    for fa, fb in zip(a.fields, b.fields):
        np.testing.assert_array_equal(fa, fb)


def test_chart_sharded_single_device_matches_unsharded():
    post = _post()
    mesh = make_mesh((1,), ("space",))
    a = GPRequest(kind="moments", n=5, seed=3)
    b = GPRequest(kind="moments", n=5, seed=3)
    GPFieldServer(post, slab=4).run([a])
    GPFieldServer(post, slab=4, mesh=mesh, shard="chart").run([b])
    np.testing.assert_allclose(a.mean, b.mean, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(a.std, b.std, rtol=1e-5, atol=1e-5)


# -- fault injection on the single real device -----------------------------------
def test_straggler_detection_from_serving_step_times():
    sup = ServingFaultSupervisor(monitor=StragglerMonitor(min_samples=6))
    inj = ChaosInjector([Straggler(at_slab=8, delay_s=0.4)])
    srv = GPFieldServer(_post(), slab=4, supervisor=sup, fault_injector=inj)
    srv.run([GPRequest(kind="sample", n=40, seed=5)])  # 10 slabs of 4
    assert inj.fired
    assert sup.monitor.stragglers >= 1


def test_device_loss_without_mesh_is_fatal():
    inj = ChaosInjector([KillDevice(at_slab=0)])
    srv = GPFieldServer(_post(), slab=2, fault_injector=inj)
    with pytest.raises(DeviceLossError):
        srv.run([GPRequest(kind="sample", n=1, seed=1)])


def test_metrics_surface_fault_and_degradation_state():
    srv = GPFieldServer(_post(), slab=2)
    srv.run([GPRequest(kind="sample", n=1, seed=1)])
    m = srv.metrics()
    for key in ("slabs_run", "replans", "replayed_slabs", "degradations",
                "mesh", "mode", "fault_device_losses", "fault_stragglers",
                "last_recovery_s", "capacity"):
        assert key in m, key
    assert m["mesh"] == "unsharded" and m["replans"] == 0


# -- the 8-virtual-device acceptance suite ---------------------------------------
@pytest.mark.slow
def test_chaos_acceptance_suite_8dev():
    """Kill-mid-stream (mesh 8 -> 7, bit-identical replay, cache-miss
    assertion), collapse-to-1 degradation, straggler detection, chart-ring
    shrink and poison isolation — in a subprocess, because XLA_FLAGS must
    be set before jax initializes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("REPRO_BACKEND", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.distributed.chaos", "--check"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert out.stdout.count("PASS") == 5, out.stdout
    assert "FAIL" not in out.stdout, out.stdout
