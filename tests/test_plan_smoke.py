"""Plan-introspection smoke checks (ISSUE 4 satellite; run as its own CI
step, fp32 and bf16 separately): the flagship dust-map chart must be fully
covered by the VMEM-resident pyramid route (zero inter-level field
traffic), must still route every level through the megakernel when the
pyramid is disabled, and the ``dispatch.plan`` byte estimates must agree
with the roofline traffic model at BOTH storage dtypes — with bf16
reporting >= 1.9x fewer bytes per level than fp32 (ISSUE 4 acceptance).
"""
import numpy as np
import pytest

from repro.core.charts import galactic_dust_chart
from repro.core.refine import LevelGeom
from repro.kernels import dispatch
from repro.roofline import refine_level_traffic

# the examples/dust_map_3d.py chart
CHART = galactic_dust_chart((8, 16, 16), n_levels=3)
DTYPES = ["float32", "bfloat16"]


def _plan(dtype, **kw):
    return dispatch.plan(CHART, platform="cpu", dtype=dtype, **kw)


@pytest.mark.parametrize("dtype", DTYPES)
def test_dust_map_pyramid_covers_every_level(dtype):
    """All three levels fit VMEM together (14.8 MiB at bf16, 33.8 at fp32):
    the whole chart is ONE pyramid launch, at either storage dtype."""
    entries = _plan(dtype)
    assert [e["route"] for e in entries] \
        == [dispatch.ROUTE_PYRAMID] * CHART.n_levels
    assert all(e["dtype"] == dtype for e in entries)
    assert all(e["vjp"]["route"] == dispatch.ROUTE_PYRAMID + "-ref"
               for e in entries)


@pytest.mark.parametrize("dtype", DTYPES)
def test_pyramid_off_falls_back_to_megakernel(dtype):
    """With the pyramid disabled every level still runs the single-launch
    megakernel — forward AND backward, never the jnp reference."""
    for e in _plan(dtype, pyramid=False):
        assert e["route"] == dispatch.ROUTE_ND_FUSED, e
        assert e["vjp"]["route"] == dispatch.ROUTE_ND_FUSED + "-adjoint", e
        # the route labels above pin the *structure*; the backend column is
        # the executor (the jnp oracle of that same structure on CPU)
        assert e["vjp"]["backend"] == e["backend"]


@pytest.mark.parametrize("dtype", DTYPES)
def test_pyramid_zero_interlevel_field_traffic(dtype):
    """ISSUE 4 acceptance: covered levels move no field bytes through HBM
    except the first's coarse read and the last's fine write."""
    entries = _plan(dtype)
    k = len(entries)
    for e in entries:
        geom = LevelGeom.for_level(CHART, e["level"])
        br = refine_level_traffic(geom, "pyramid", dtype=dtype,
                                  first=e["level"] == 0,
                                  last=e["level"] == k - 1)
        assert e["hbm_bytes"]["pyramid"] == br["total"]
        assert e["hbm_bytes"]["selected"] == br["total"]
        if e["level"] > 0:
            assert br["field_read"] == 0, e
        if e["level"] < k - 1:
            assert br["fine_write"] == 0, e
        assert br["xi_read"] > 0 and br["dtype"] == dtype


def test_bf16_at_least_1p9x_fewer_bytes_per_level():
    """ISSUE 4 acceptance: >= 1.9x fewer modeled HBM bytes per large level
    in bf16 vs fp32 — on the selected route and on every candidate."""
    for pyramid in (True, False):
        p32 = _plan("float32", pyramid=pyramid)
        p16 = _plan("bfloat16", pyramid=pyramid)
        for e32, e16 in zip(p32, p16):
            assert set(e32["hbm_bytes"]) == set(e16["hbm_bytes"])
            for route, b32 in e32["hbm_bytes"].items():
                assert b32 >= 1.9 * e16["hbm_bytes"][route], (route, e32)


@pytest.mark.parametrize("dtype", DTYPES)
def test_plan_bytes_match_roofline_within_10pct(dtype):
    """plan() must report the roofline model's numbers at each dtype (and
    the model must be dominated by the minimal-traffic terms)."""
    itemsize = np.dtype(dtype).itemsize
    for e in _plan(dtype, pyramid=False):
        geom = LevelGeom.for_level(CHART, e["level"])
        for route in (dispatch.ROUTE_ND_FUSED, dispatch.ROUTE_AXES_ND,
                      dispatch.ROUTE_REFERENCE):
            model = refine_level_traffic(geom, route, dtype=dtype)["total"]
            got = e["hbm_bytes"][route]
            assert abs(got - model) <= 0.10 * model, (route, got, model)
        # sanity: the fused estimate is within 10% of the irreducible
        # field + ξ + output traffic (matrices are a rounding error here)
        n_out = int(np.prod(geom.fine_shape))
        minimal = itemsize * (int(np.prod(geom.coarse_shape)) + 2 * n_out)
        fused = e["hbm_bytes"][dispatch.ROUTE_ND_FUSED]
        assert fused <= 1.35 * minimal, (fused, minimal)


def test_plan_quantifies_fused_and_pyramid_wins():
    """The traffic reductions that motivate the megakernel (>= 2x vs
    per-axis on every 3-D level) and the pyramid (interior levels drop the
    whole field term) are visible straight from plan()."""
    per_level = _plan("float32", pyramid=False)
    covered = _plan("float32")
    for e in per_level:
        hb = e["hbm_bytes"]
        assert hb[dispatch.ROUTE_ND_FUSED] * 2 <= hb[dispatch.ROUTE_AXES_ND]
        assert hb[dispatch.ROUTE_ND_FUSED] * 2 \
            <= hb[dispatch.ROUTE_REFERENCE]
    # interior pyramid levels: no field read, no fine write — only ξ + mats
    for e_pl, e_py in zip(per_level[1:-1], covered[1:-1]):
        assert e_py["hbm_bytes"]["selected"] * 2 \
            <= e_pl["hbm_bytes"]["selected"]


def test_pyramid_budget_fallback():
    """A budget too small for two levels disables the overlay — plan then
    shows the per-level megakernel routing (the §11 fallback rule)."""
    assert dispatch.pyramid_cover(CHART, vmem_budget=1024) is None
    entries = dispatch.plan(CHART, platform="cpu", vmem_budget=1024)
    assert [e["route"] for e in entries] \
        == [dispatch.ROUTE_ND_FUSED] * CHART.n_levels


def test_pyramid_partial_coverage_on_deeper_chart():
    """One more level (234 MiB working set at fp32) busts the budget: the
    prefix stays covered, the big tail level runs the megakernel."""
    deep = galactic_dust_chart((8, 16, 16), n_levels=4)
    cover = dispatch.pyramid_cover(deep, itemsize=4)
    assert cover is not None and cover[0] == 3
    routes = [e["route"] for e in dispatch.plan(deep, platform="cpu")]
    assert routes == [dispatch.ROUTE_PYRAMID] * 3 + [dispatch.ROUTE_ND_FUSED]
