"""Plan-introspection smoke checks (ISSUE 3 satellite; run as its own CI
step): the flagship dust-map chart must route every level through the fused
megakernel — forward AND backward — and the ``dispatch.plan`` byte
estimates must agree with the roofline traffic model within 10%.
"""
import numpy as np

from repro.core.charts import galactic_dust_chart
from repro.core.refine import LevelGeom
from repro.kernels import dispatch
from repro.roofline import refine_level_traffic

# the examples/dust_map_3d.py chart
CHART = galactic_dust_chart((8, 16, 16), n_levels=3)


def test_dust_map_levels_route_nd_fused():
    """Every level: nd-fused forward, nd-fused-adjoint backward. If a level
    legitimately falls off the fused path (VMEM fallback rule), it must land
    on nd-axes — never the jnp reference."""
    for e in dispatch.plan(CHART, platform="cpu"):
        assert e["route"] in (dispatch.ROUTE_ND_FUSED,
                              dispatch.ROUTE_AXES_ND), e
        assert e["route"] == dispatch.ROUTE_ND_FUSED, (
            "dust-map level fell back off the megakernel", e)
        assert e["vjp"]["route"] == dispatch.ROUTE_ND_FUSED + "-adjoint", e
        assert e["vjp"]["backend"] != dispatch.BACKEND_REFERENCE


def test_plan_bytes_match_roofline_within_10pct():
    """plan() must report the roofline model's numbers (and the model must
    be dominated by the minimal-traffic terms: read L + read ξ + write N)."""
    for e in dispatch.plan(CHART, platform="cpu"):
        geom = LevelGeom.for_level(CHART, e["level"])
        for route in (dispatch.ROUTE_ND_FUSED, dispatch.ROUTE_AXES_ND,
                      dispatch.ROUTE_REFERENCE):
            model = refine_level_traffic(geom, route)["total"]
            got = e["hbm_bytes"][route]
            assert abs(got - model) <= 0.10 * model, (route, got, model)
        # sanity: the fused estimate is within 10% of the irreducible
        # field + ξ + output traffic (matrices are a rounding error here)
        n_out = int(np.prod(geom.fine_shape))
        minimal = 4 * (int(np.prod(geom.coarse_shape)) + 2 * n_out)
        fused = e["hbm_bytes"][dispatch.ROUTE_ND_FUSED]
        assert fused <= 1.35 * minimal, (fused, minimal)


def test_plan_quantifies_fused_win():
    """The per-level traffic reduction that motivates the megakernel
    (>= 2x on every 3-D level) is visible straight from plan()."""
    for e in dispatch.plan(CHART, platform="cpu"):
        hb = e["hbm_bytes"]
        assert hb[dispatch.ROUTE_ND_FUSED] * 2 <= hb[dispatch.ROUTE_AXES_ND]
        assert hb[dispatch.ROUTE_ND_FUSED] * 2 <= hb[dispatch.ROUTE_REFERENCE]
