"""VMEM-resident pyramid route (ISSUE 4 tentpole / DESIGN.md §11).

Acceptance: the single-launch pyramid is exact vs the per-level megakernel
chain (and the 1-D kernel chain) at 1e-5 — forward, fixed-matrix VJP and
learned-θ matrix cotangents — for 1-D/2-D/3-D charts, both boundaries,
sample batches and every sample-block size; the residency autotuner covers
exactly the prefix whose §11 working-set model fits the budget. All
kernels run in interpret mode on CPU (exact BlockSpec machinery).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ICR, matern32, regular_chart
from repro.core.charts import galactic_dust_chart, log_chart
from repro.core.refine import (
    LevelGeom,
    axis_refinement_matrices_level,
    refinement_matrices_level,
)
from repro.kernels import dispatch, nd_fused, pyramid


# this module covers the kernel tiling: pin the interpret backend through
# dispatch/ICR (the production CPU default is the jnp oracle)
pytestmark = pytest.mark.usefixtures("interpret_backend")

CHARTS = [
    ("1d-stationary", lambda: regular_chart(32, 3, boundary="reflect"), 10.0),
    ("1d-charted", lambda: log_chart(32, 3, n_csz=5, n_fsz=4, delta0=0.05),
     1.0),
    ("2d-shrink", lambda: regular_chart((12, 10), 2, boundary="shrink"), 4.0),
    ("2d-reflect", lambda: regular_chart((12, 16), 2, boundary="reflect"),
     4.0),
    ("3d-dust-reflect", lambda: galactic_dust_chart((6, 8, 8), n_levels=2),
     0.5),
]
IDS = [n for n, _, _ in CHARTS]


def _pyramid_inputs(c, rho, seed, *, batch=None):
    """(geoms, mats, field, xis): per-axis factor convention for any ndim."""
    k = matern32.with_defaults(rho=rho)()
    geoms = [LevelGeom.for_level(c, l) for l in range(c.n_levels)]
    mats = []
    for l in range(c.n_levels):
        if c.ndim > 1:
            mats.append(axis_refinement_matrices_level(c, k, l))
        else:
            r, d = refinement_matrices_level(c, k, l)
            if r.shape[0] == 1:
                r, d = r.reshape(r.shape[-2:]), d.reshape(d.shape[-2:])
            mats.append(([r], [d]))
    rng = np.random.default_rng(seed)
    lead = () if batch is None else (batch,)
    field = jnp.asarray(
        rng.normal(size=lead + tuple(geoms[0].coarse_shape)), jnp.float32)
    xis = [jnp.asarray(rng.normal(
        size=lead + (int(np.prod(g.T)), g.n_fsz ** c.ndim)), jnp.float32)
        for g in geoms]
    return geoms, mats, field, xis


def _chain(field, xis, mats, geoms):
    """Per-level ground truth: the megakernel on N-D levels, dispatch's 1-D
    kernels on 1-D levels — what the pyramid must reproduce exactly."""
    x = field
    for l, geom in enumerate(geoms):
        if len(geom.coarse_shape) > 1:
            x = nd_fused.refine_nd_fused(x, xis[l], mats[l][0], mats[l][1],
                                         geom, interpret=True)
        else:
            r, d = mats[l]
            x = dispatch.refine(x, xis[l], r[0], d[0], geom,
                                backend=dispatch.BACKEND_INTERPRET)
    return x


@pytest.mark.parametrize("name,chartf,rho", CHARTS, ids=IDS)
def test_pyramid_matches_per_level_chain(name, chartf, rho):
    c = chartf()
    geoms, mats, field, xis = _pyramid_inputs(c, rho, [1, *name.encode()])
    got = pyramid.refine_pyramid(field, xis, mats, geoms, interpret=True)
    want = _chain(field, xis, mats, geoms)
    assert got.shape == tuple(geoms[-1].fine_shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,chartf,rho", CHARTS, ids=IDS)
def test_pyramid_vjp_matches_chain(name, chartf, rho):
    """Fixed matrices: grad w.r.t. (field, every level's ξ) through the
    pyramid's custom VJP == grad through the per-level chain."""
    c = chartf()
    geoms, mats, field, xis = _pyramid_inputs(c, rho, [2, *name.encode()])
    rng = np.random.default_rng([3, *name.encode()])
    v = jnp.asarray(rng.normal(size=geoms[-1].fine_shape), jnp.float32)
    g_p = jax.grad(lambda f, xs: jnp.sum(
        pyramid.refine_pyramid(f, xs, mats, geoms, interpret=True) * v),
        argnums=(0, 1))(field, xis)
    g_c = jax.grad(lambda f, xs: jnp.sum(
        _chain(f, xs, mats, geoms) * v), argnums=(0, 1))(field, xis)
    for a, b in zip(jax.tree.leaves(g_c), jax.tree.leaves(g_p)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name,chartf,rho",
                         [CHARTS[1], CHARTS[-1]],
                         ids=["1d-charted", "3d-dust-reflect"])
def test_pyramid_matrix_cotangents(name, chartf, rho):
    """Learning θ: perturbing the factors flips the backward onto the
    reference VJP — matrix cotangents must match the per-level chain."""
    c = chartf()
    geoms, mats, field, xis = _pyramid_inputs(c, rho, [4, *name.encode()])
    rng = np.random.default_rng([5, *name.encode()])
    v = jnp.asarray(rng.normal(size=geoms[-1].fine_shape), jnp.float32)
    g_p = jax.grad(lambda ms: jnp.sum(
        pyramid.refine_pyramid(field, xis, ms, geoms, interpret=True) * v)
        )(mats)
    g_c = jax.grad(lambda ms: jnp.sum(
        _chain(field, xis, ms, geoms) * v))(mats)
    for a, b in zip(jax.tree.leaves(g_c), jax.tree.leaves(g_p)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("s_blk", [1, 2, 8])
def test_pyramid_sample_block_invariance(s_blk):
    """Sample-slab size must not change values; parity vs per-sample calls."""
    c = galactic_dust_chart((6, 8, 8), n_levels=2)
    geoms, mats, field, xis = _pyramid_inputs(c, 0.5, 7, batch=5)
    got = pyramid.refine_pyramid(field, xis, mats, geoms, interpret=True,
                                 sample_axis=True, sample_block=s_blk)
    want = jnp.stack([
        pyramid.refine_pyramid(field[i], [x[i] for x in xis], mats, geoms,
                               interpret=True)
        for i in range(5)
    ])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_pyramid_rejects_non_consecutive_levels():
    c = galactic_dust_chart((6, 8, 8), n_levels=2)
    geoms, mats, field, xis = _pyramid_inputs(c, 0.5, 8)
    with pytest.raises(ValueError, match="consecutive"):
        pyramid.refine_pyramid(field, [xis[0], xis[0]],
                               [mats[0], mats[0]], [geoms[0], geoms[0]],
                               interpret=True)


class TestICREndToEnd:
    def test_pyramid_on_equals_off(self):
        """ICR(use_pallas) with the pyramid overlay == per-level routing —
        the overlay is a pure execution-plan change."""
        c = galactic_dust_chart((6, 8, 8), n_levels=2)
        kern = matern32.with_defaults(rho=0.5)
        on = ICR(chart=c, kernel=kern, use_pallas=True)
        off = ICR(chart=c, kernel=kern, use_pallas=True, use_pyramid=False)
        xi = on.init_xi(jax.random.PRNGKey(0))
        mats = on.matrices()
        np.testing.assert_allclose(
            np.asarray(on.apply_sqrt(mats, xi)),
            np.asarray(off.apply_sqrt(mats, xi)), rtol=1e-5, atol=1e-5)

    def test_apply_sqrt_T_through_pyramid(self):
        """The Wiener-style transpose (VJP at the origin) runs through the
        pyramid backward and matches the pyramid-off adjoint chain."""
        c = galactic_dust_chart((6, 8, 8), n_levels=2)
        kern = matern32.with_defaults(rho=0.5)
        on = ICR(chart=c, kernel=kern, use_pallas=True)
        off = ICR(chart=c, kernel=kern, use_pallas=True, use_pyramid=False)
        mats = on.matrices()
        v = on.sample(jax.random.PRNGKey(2))
        for a, b in zip(on.apply_sqrt_T(mats, v), off.apply_sqrt_T(mats, v)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)

    def test_jit_grad_through_pyramid(self):
        """MAP-style jitted value_and_grad runs (and is finite) through the
        pyramid forward + replayed backward."""
        c = regular_chart(64, 3, boundary="reflect")
        icr = ICR(chart=c, kernel=matern32.with_defaults(rho=10.0),
                  use_pallas=True)
        mats = icr.matrices()
        xi = icr.init_xi(jax.random.PRNGKey(0))
        val, grad = jax.jit(jax.value_and_grad(
            lambda xs: 0.5 * jnp.sum(icr.apply_sqrt(mats, xs) ** 2)))(xi)
        assert bool(jnp.isfinite(val))
        assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grad))


class TestAutotunePyramid:
    def test_cover_is_prefix_and_budget_monotone(self):
        deep = galactic_dust_chart((8, 16, 16), n_levels=4)
        geoms = [LevelGeom.for_level(deep, l) for l in range(4)]
        ks = []
        for budget in (2**20, 8 * 2**20, 64 * 2**20, 2**40):
            cover = dispatch.autotune_pyramid(geoms, vmem_budget=budget)
            ks.append(0 if cover is None else cover[0])
        assert ks == sorted(ks) and ks[-1] == 4
        assert ks[2] == 3  # the default budget splits exactly at level 3

    def test_one_level_is_not_a_pyramid(self):
        geoms = [LevelGeom.for_level(galactic_dust_chart((6, 8, 8), 2), 0)]
        assert dispatch.autotune_pyramid(geoms) is None

    def test_sample_slab_bounded_and_modeled(self):
        c = galactic_dust_chart((6, 8, 8), n_levels=2)
        geoms = [LevelGeom.for_level(c, l) for l in range(2)]
        k, s_b = dispatch.autotune_pyramid(geoms, samples=16)
        assert k == 2 and 1 <= s_b <= 16
        total = sum(
            dispatch._fused_tile_bytes(g, dispatch._pyramid_charted(g),
                                       g.T[0], s_b, 4) for g in geoms)
        assert total <= dispatch.VMEM_BUDGET_BYTES

    def test_reference_level_ends_the_prefix(self):
        """N-D chart without axis factors: nothing is structured, no
        pyramid (the cover respects route_for)."""
        c = galactic_dust_chart((6, 8, 8), n_levels=2)
        assert dispatch.pyramid_cover(c, have_axis_mats=False) is None
