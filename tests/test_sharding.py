"""Sharding rules: param/batch/cache PartitionSpec policies."""
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.distributed.sharding import (
    batch_spec,
    cache_specs,
    opt_state_specs,
    param_specs,
)
from repro.models import build_model


class FakeMesh:
    """Spec rules only consult mesh.shape — fake the production sizes so
    divisibility logic is exercised without 256 devices."""

    def __init__(self, **shape):
        self.shape = shape


@pytest.fixture(scope="module")
def mesh():
    return FakeMesh(data=16, model=16)



def _norm(entry):
    """PartitionSpec normalizes ('model',) -> 'model'; undo for asserts."""
    if entry is None:
        return None
    return (entry,) if isinstance(entry, str) else tuple(entry)

def _find(specs, params, pred):
    out = []
    for (path, spec), (_, leaf) in zip(
            jax.tree_util.tree_flatten_with_path(specs)[0],
            jax.tree_util.tree_flatten_with_path(params)[0]):
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", "")))
                        for e in path)
        if pred(name):
            out.append((name, spec, leaf.shape))
    return out


class TestParamSpecs:
    def test_dense_rules(self, mesh):
        model = build_model(get_arch("starcoder2-15b"))
        spec_tree = param_specs(model.params_spec(), mesh)
        wq = _find(spec_tree, model.params_spec(),
                   lambda n: n.endswith("wq"))[0]
        assert _norm(wq[1][-1]) == ("model",) and _norm(wq[1][-2]) == ("data",)
        wo = _find(spec_tree, model.params_spec(),
                   lambda n: n.endswith("attn/wo"))[0]
        assert _norm(wo[1][-2]) == ("model",) and _norm(wo[1][-1]) == ("data",)
        emb = _find(spec_tree, model.params_spec(),
                    lambda n: n.endswith("table"))[0]
        assert _norm(emb[1][-2]) == ("model",) and emb[1][-1] is None

    def test_moe_expert_parallel(self, mesh):
        model = build_model(get_arch("deepseek-v2-236b"))
        spec_tree = param_specs(model.params_spec(), mesh)
        gates = _find(spec_tree, model.params_spec(),
                      lambda n: "moe/gate" in n)
        assert gates, "no MoE gate leaves found"
        for name, spec, shape in gates:
            assert _norm(spec[-3]) == ("model",), f"{name}: experts not EP-sharded"
            assert _norm(spec[-2]) == ("data",), f"{name}: no FSDP dim"

    def test_stacked_group_dim_unsharded(self, mesh):
        model = build_model(get_arch("gemma3-27b"))
        spec_tree = param_specs(model.params_spec(), mesh)
        wq = _find(spec_tree, model.params_spec(),
                   lambda n: "groups" in n and n.endswith("wq"))[0]
        assert len(wq[1]) == len(wq[2])
        assert wq[1][0] is None  # leading group-stack dim replicated

    def test_norms_replicated(self, mesh):
        model = build_model(get_arch("command-r-35b"))
        spec_tree = param_specs(model.params_spec(), mesh)
        norms = _find(spec_tree, model.params_spec(),
                      lambda n: n.endswith("scale"))
        assert all(s == P() for _, s, _ in norms)

    def test_non_divisible_replicates(self):
        big = FakeMesh(data=16, model=16)
        # 92553-vocab internvl2 pads to /128 => still shards over 16
        model = build_model(get_arch("internvl2-2b"))
        spec_tree = param_specs(model.params_spec(), big)
        emb = _find(spec_tree, model.params_spec(),
                    lambda n: n.endswith("table"))[0]
        assert emb[2][0] % 128 == 0  # padded vocab


class TestOptAndBatch:
    def test_opt_state_mirrors_params(self, mesh):
        from repro.optim import adamw, constant

        model = build_model(get_arch("xlstm-1.3b"))
        p = model.params_spec()
        opt = adamw(constant(1e-3))
        o = jax.eval_shape(opt.init, p)
        specs = opt_state_specs(o, mesh)
        m_wq = _find(specs, o, lambda n: "m/" in n and n.endswith("wqkv"))
        assert m_wq and _norm(m_wq[0][1][-1]) == ("model",)

    def test_batch_leading_dim(self, mesh):
        import jax.numpy as jnp

        batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
        specs = batch_spec(batch, mesh)
        assert _norm(specs["tokens"][0]) == ("data",)


class TestCacheSpecs:
    def test_heads_sharded_when_divisible(self, mesh):
        model = build_model(get_arch("gemma3-27b"))  # kv=16
        spec = cache_specs(model.cache_spec(128, 1024), mesh)
        flat = jax.tree_util.tree_leaves(
            spec, is_leaf=lambda x: isinstance(x, P))
        assert any(any(_norm(e) == ("model",) for e in tuple(s)) for s in flat)

    def test_sequence_sharded_when_heads_too_few(self, mesh):
        model = build_model(get_arch("starcoder2-15b"))  # kv=4 < 16
        c_spec = model.cache_spec(128, 32768)
        specs = cache_specs(c_spec, mesh)

        def leaf_and_spec(tree, spec):
            ks = jax.tree_util.tree_flatten_with_path(tree)[0]
            ss = jax.tree_util.tree_leaves(
                spec, is_leaf=lambda x: isinstance(x, P))
            return [(k, v, s) for (k, v), s in zip(ks, ss)]

        rows = leaf_and_spec(c_spec, specs)
        # (G, B, S, Hkv, Dh): S (dim 2) must carry the model axes
        k_rows = [r for r in rows if "k" in str(r[0])]
        assert all(_norm(tuple(r[2])[2]) == ("model",) for r in k_rows)

    def test_batch1_seq_takes_data_axes(self, mesh):
        model = build_model(get_arch("gemma3-27b"))
        spec = cache_specs(model.cache_spec(1, 524288), mesh)
        flat = jax.tree_util.tree_leaves(
            spec, is_leaf=lambda x: isinstance(x, P))
        # some long-cache leaf must carry BOTH axes (seq over data, heads
        # over model)
        assert any(
            any(_norm(e) == ("data",) for e in tuple(s))
            and any(_norm(e) == ("model",) for e in tuple(s))
            for s in flat)
