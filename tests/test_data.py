"""Data pipeline: determinism, restart-resume, host sharding, prefetch."""
import numpy as np

from repro.data import SyntheticLMData, make_batch_iterator


def _src(**kw):
    base = dict(vocab_size=256, seq_len=32, global_batch=8, seed=3)
    base.update(kw)
    return SyntheticLMData(**base)


def test_batch_is_pure_function_of_step():
    src = _src()
    a = src.batch(5)
    b = src.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_tokens():
    b = _src().batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_partitions_batch():
    src = _src()
    full_rows = src.global_batch
    h0 = src.batch(0, host_id=0, host_count=2)
    h1 = src.batch(0, host_id=1, host_count=2)
    assert h0["tokens"].shape[0] == full_rows // 2
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_iterator_resumes_at_step():
    src = _src()
    it = make_batch_iterator(src, start_step=3)
    got = next(it)
    it.close()
    np.testing.assert_array_equal(got["tokens"], src.batch(3)["tokens"])


def test_iterator_sequence():
    src = _src()
    it = make_batch_iterator(src, start_step=0)
    seq = [next(it) for _ in range(3)]
    it.close()
    for i, b in enumerate(seq):
        np.testing.assert_array_equal(b["tokens"], src.batch(i)["tokens"])


def test_tokens_in_range():
    b = _src().batch(1)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < 256
