"""Roofline analyzer: HLO collective parsing + term arithmetic."""
import pytest

from repro.roofline.analysis import (
    RooflineTerms,
    collective_bytes,
    collective_counts,
)
from repro.roofline.hlo_cost import is_pallas_target, module_costs

HLO = """
HloModule jit_step, entry_computation_layout={...}

ENTRY %main {
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %all-gather = bf16[256,1024]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %ar = f32[16,1024]{1,0} all-reduce(%x), channel_id=2, replica_groups=[2,8]<=[16], to_apply=%add
  %rs = f32[2,512]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[2,8]<=[16], dimensions={0}
  %a2a = bf16[8,64,32]{2,1,0} all-to-all(%z), channel_id=4, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = f32[4,128]{1,0} collective-permute(%w), channel_id=5, source_target_pairs={{0,1},{1,2}}
  ROOT %ags = (bf16[32], bf16[32]) all-gather-start(%q), channel_id=6, replica_groups=[4,4]<=[16]
}
"""


def test_collective_bytes_semantics():
    out = collective_bytes(HLO)
    # all-gather: output bytes = 256*1024*2
    ag_sync = 256 * 1024 * 2
    # -start op: two bf16[32] in the output tuple = 128 bytes
    assert out["all-gather"] == ag_sync + 128
    # all-reduce: 2x output = 2*16*1024*4
    assert out["all-reduce"] == 2 * 16 * 1024 * 4
    # reduce-scatter: out * group (8)
    assert out["reduce-scatter"] == 2 * 512 * 4 * 8
    # all-to-all: out bytes
    assert out["all-to-all"] == 8 * 64 * 32 * 2
    assert out["collective-permute"] == 4 * 128 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_collective_counts():
    counts = collective_counts(HLO)
    assert counts["all-gather"] == 2  # sync + start
    assert counts["all-reduce"] == 1
    assert counts["all-to-all"] == 1


def test_terms_and_dominance():
    t = RooflineTerms(flops=197e12, bytes_accessed=819e9 * 2,
                      coll_bytes=50e9 * 0.5, coll_breakdown={},
                      coll_counts={}, model_flops=197e12 * 0.5)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(2.0)
    assert t.t_collective == pytest.approx(0.5)
    assert t.dominant == "memory"
    assert t.bound_time == pytest.approx(2.0)
    # roofline fraction: useful flops time (0.5s) / bound (2.0s)
    assert t.flops_utilization == pytest.approx(0.25)
    s = t.summary()
    assert s["dominant"] == "memory"


def test_empty_hlo():
    out = collective_bytes("ENTRY %m { ROOT %x = f32[2] add(%a, %b) }")
    assert out["total"] == 0


CC_HLO = """
HloModule jit_refine

ENTRY %main {
  %mats = f32[24,8]{1,0} parameter(0)
  %field = bf16[128,64]{1,0} parameter(1)
  %k = bf16[256,64]{1,0} custom-call(%field, %mats), custom_call_target="tpu_custom_call", api_version=API_VERSION_STATUS_RETURNING
  %opaque = f32[16]{0} custom-call(%mats), custom_call_target="SomeVendorOp"
  ROOT %out = bf16[256,64]{1,0} add(%k, %k)
}
"""


def test_pallas_custom_call_bytes():
    costs = module_costs(CC_HLO)
    cc = costs["custom_calls"]
    assert cc["tpu_custom_call"]["pallas"] is True
    assert cc["tpu_custom_call"]["count"] == 1
    # operand bytes (bf16 field + f32 mats) + bf16 output
    expected = 128 * 64 * 2 + 24 * 8 * 4 + 256 * 64 * 2
    assert cc["tpu_custom_call"]["bytes"] == expected
    # unknown targets are inventoried but stay zero-byte opaque
    assert cc["SomeVendorOp"]["pallas"] is False
    assert cc["SomeVendorOp"]["count"] == 1
    assert cc["SomeVendorOp"]["bytes"] == 0
    # the pallas bytes flow into the module byte total
    assert costs["bytes"] >= expected


def test_pallas_custom_call_in_loop_multiplied():
    hlo = """
HloModule jit_scan

%body {
  %pb = (s32[], bf16[64]) parameter(0)
  %t = bf16[64]{0} get-tuple-element(%pb), index=1
  %kb = bf16[64]{0} custom-call(%t), custom_call_target="tpu_custom_call"
  ROOT %tb = (s32[], bf16[64]) tuple(%i, %kb)
}

%cond {
  %pc = (s32[], bf16[64]) parameter(0)
  ROOT %lt = pred[] compare(%a, %b), direction=LT
}

ENTRY %main {
  %p = (s32[], bf16[64]) parameter(0)
  ROOT %w = (s32[], bf16[64]) while(%p), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""
    costs = module_costs(hlo)
    assert costs["custom_calls"]["tpu_custom_call"]["count"] == 7
    assert costs["custom_calls"]["tpu_custom_call"]["bytes"] == \
        7 * (64 * 2 + 64 * 2)


def test_is_pallas_target_spellings():
    assert is_pallas_target("tpu_custom_call")
    assert is_pallas_target("MosaicGpuKernel".lower()) or \
        is_pallas_target("mosaic")
    assert is_pallas_target("triton_kernel_call")
    assert not is_pallas_target("cu_dnn$convForward")
    assert not is_pallas_target("Sharding")
