"""ICR core math vs. the exact GP (paper §4, validated per §5.1)."""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    ICR,
    cov_errors,
    exact_cov,
    gauss_kl,
    kernel_matrix,
    log_chart,
    matern32,
    matern52,
    rbf,
    regular_chart,
)
from repro.core.refine import refinement_matrices_level


def paper_log_setup(n_csz=5, n_fsz=4, n_levels=5, target_n=200, span=50.0):
    """The paper's §5.1 experiment: ~200 log-spaced points whose
    nearest-neighbor distances span a factor `span` (2%·rho0 .. rho0)."""
    n0 = 3
    while True:
        try:
            c = log_chart(n0, n_levels, n_csz=n_csz, n_fsz=n_fsz, delta0=1.0)
            if c.final_shape[0] >= target_n:
                break
        except ValueError:
            pass
        n0 += 1
    n = c.final_shape[0]
    scale = math.log(span) / (n - 2) / c.delta(n_levels)[0]
    c = log_chart(n0, n_levels, n_csz=n_csz, n_fsz=n_fsz, delta0=scale)
    xs = np.asarray(c.grid_positions(n_levels))[:, 0]
    rho = float(np.diff(xs).max())  # max spacing = rho0
    return c, rho


class TestGeometry:
    def test_paper_size_recursion_3_2(self):
        # paper §4.2: N_{l+1} = 2 (N_l - 2) for (3, 2) shrink
        c = regular_chart(16, 3)
        assert [c.shape(l)[0] for l in range(4)] == [16, 28, 52, 100]

    def test_fine_grid_is_regular_and_consistent(self):
        # child coords produced family-wise must equal the next level's grid
        for (ncsz, nfsz) in [(3, 2), (5, 4), (5, 6), (3, 4)]:
            c = regular_chart(32, 2, n_csz=ncsz, n_fsz=nfsz)
            for lvl in range(2):
                fam = c.axis_fine_windows(lvl, 0).reshape(-1)
                grid = c.axis_coords(lvl + 1, 0)
                np.testing.assert_allclose(fam, grid, rtol=0, atol=1e-12)

    def test_reflect_boundary_doubles(self):
        c = regular_chart(32, 3, boundary="reflect")
        assert [c.shape(l)[0] for l in range(4)] == [32, 64, 128, 256]

    def test_reflect_matches_shrink_in_interior(self):
        """Interior refinement families are identical math under both
        boundary conditions — only O(b) border families differ."""
        k = matern32.with_defaults(rho=5.0)()
        cs = regular_chart(32, 1, boundary="shrink")
        cr = regular_chart(32, 1, boundary="reflect")
        rs, ds = refinement_matrices_level(cs, k, 0)
        rr, dr = refinement_matrices_level(cr, k, 0)
        # both stationary+invariant => single broadcast matrix, equal
        np.testing.assert_allclose(np.asarray(rs), np.asarray(rr), atol=1e-6)
        np.testing.assert_allclose(np.asarray(ds), np.asarray(dr), atol=1e-6)

    def test_bad_params_raise(self):
        with pytest.raises(ValueError):
            regular_chart(16, 1, n_csz=4)  # even coarse size
        with pytest.raises(ValueError):
            regular_chart(16, 1, n_fsz=3)  # odd fine size
        with pytest.raises(ValueError):
            regular_chart(3, 2)  # grid shrinks below n_csz


class TestRefinementMatrices:
    def test_matches_exact_conditional(self):
        """R and D must equal the closed-form conditional (paper Eq. 7/8)."""
        k = matern32.with_defaults(rho=3.0)()
        c = regular_chart(8, 1, n_csz=3, n_fsz=2)
        r, sqrt_d = refinement_matrices_level(c, k, 0)
        # hand-build for one family (stationary => same for all)
        cpos = c.axis_coarse_windows(0, 0)[0][:, None]
        fpos = c.axis_fine_windows(0, 0)[0][:, None]
        k_cc = kernel_matrix(k, jnp.asarray(cpos))
        k_fc = kernel_matrix(k, jnp.asarray(fpos), jnp.asarray(cpos))
        k_ff = kernel_matrix(k, jnp.asarray(fpos))
        r_ref = np.linalg.solve(np.asarray(k_cc) + 1e-6 * np.eye(3),
                                np.asarray(k_fc).T).T
        np.testing.assert_allclose(np.asarray(r)[0], r_ref, atol=1e-4)
        d_ref = np.asarray(k_ff) - r_ref @ np.asarray(k_fc).T
        d_built = np.asarray(sqrt_d)[0] @ np.asarray(sqrt_d)[0].T
        np.testing.assert_allclose(d_built, d_ref, atol=1e-4)

    def test_invariant_axis_collapses(self):
        k = matern32.with_defaults(rho=4.0)()
        c = regular_chart((16, 16), 1, boundary="reflect")
        r, sqrt_d = refinement_matrices_level(c, k, 0)
        assert r.shape[:2] == (1, 1)  # both axes invariant -> broadcast
        assert r.shape[2:] == (4, 9)  # (n_fsz^2, n_csz^2)


class TestImplicitCovariance:
    def test_regular_grid_accuracy(self):
        cov_icr, cov_true = _covs(regular_chart(16, 3), rho=8.0)
        errs = cov_errors(cov_icr, cov_true)
        assert float(errs["mae"]) < 2e-3
        assert float(errs["max_abs_err"]) < 1e-2

    def test_paper_log_chart_fig3(self):
        """Paper §5.1: (5,4), N=200, log spacing spanning 2%–100% of rho0:
        MAE 5.8e-3, max err 0.13, diag err <= 6.5e-2."""
        c, rho = paper_log_setup()
        assert c.final_shape[0] == 200
        cov_icr, cov_true = _covs(c, rho=rho)
        errs = {k: float(v) for k, v in cov_errors(cov_icr, cov_true).items()}
        assert errs["mae"] < 8e-3          # paper: 5.8e-3
        assert errs["max_abs_err"] < 0.2   # paper: 0.13
        assert errs["max_diag_err"] < 9e-2  # paper: 6.5e-2

    def test_paper_parameter_ranking(self):
        """(5,4) must beat (3,2) on the log chart (paper §5.1 KL selection)."""
        kls = {}
        for p in [(3, 2), (5, 4)]:
            c, rho = paper_log_setup(*p)
            cov_icr, cov_true = _covs(c, rho=rho)
            kls[p] = float(gauss_kl(cov_true, cov_icr, jitter=1e-8))
        assert kls[(5, 4)] < kls[(3, 2)]

    def test_2d_accuracy(self):
        c = regular_chart((6, 6), 2)
        cov_icr, cov_true = _covs(c, rho=6.0)
        errs = cov_errors(cov_icr, cov_true)
        assert float(errs["mae"]) < 5e-3

    def test_2d_reflect_accuracy(self):
        """Production (reflect/shardable) boundary: interior math identical,
        boundary families approximate => looser tolerance (DESIGN.md §5)."""
        c = regular_chart((6, 6), 2, boundary="reflect")
        cov_icr, cov_true = _covs(c, rho=6.0)
        errs = cov_errors(cov_icr, cov_true)
        assert float(errs["mae"]) < 3e-2

    @pytest.mark.parametrize("kernel", [matern32, matern52, rbf])
    def test_kernels(self, kernel):
        cov_icr, cov_true = _covs(regular_chart(12, 2), rho=6.0, kernel=kernel)
        assert float(cov_errors(cov_icr, cov_true)["mae"]) < 5e-3


def _covs(chart, rho, kernel=matern32):
    icr = ICR(chart=chart, kernel=kernel.with_defaults(rho=rho))
    cov_icr = icr.implicit_cov(dtype=jnp.float32)
    cov_true = exact_cov(chart, kernel.with_defaults(rho=rho)())
    return cov_icr, cov_true


class TestSampling:
    def test_sample_covariance_converges(self, key):
        """Empirical covariance of ICR samples ≈ implicit covariance."""
        c = regular_chart(12, 2)
        icr = ICR(chart=c, kernel=matern32.with_defaults(rho=6.0))
        mats = icr.matrices()
        n_samp = 4096
        keys = jax.random.split(key, n_samp)

        @jax.jit
        @jax.vmap
        def draw(k):
            return icr.apply_sqrt(mats, icr.init_xi(k)).reshape(-1)

        samples = draw(keys)
        emp = np.cov(np.asarray(samples).T)
        imp = np.asarray(icr.implicit_cov(dtype=jnp.float32))
        assert np.abs(emp - imp).mean() < 0.05

    def test_theta_differentiable(self):
        """Kernel parameters flow through matrices (paper: θ learned jointly)."""
        c = regular_chart(10, 1)
        icr = ICR(chart=c, kernel=matern32)

        def loss(log_rho):
            theta = {"rho": jnp.exp(log_rho), "sigma": 1.0}
            xi = icr.zero_xi()
            xi = [x + 1.0 for x in xi]
            return jnp.sum(icr(xi, theta) ** 2)

        g = jax.grad(loss)(jnp.asarray(0.5))
        assert np.isfinite(float(g)) and abs(float(g)) > 0
