"""Decode == teacher-forced consistency across architecture families.

The strongest end-to-end correctness check the zoo has: running the decode
path token-by-token (ring-buffer KV caches, latent MLA cache, recurrent
SSM/mLSTM states) must reproduce the chunked training-path logits at the
last position. Covers every cache mechanism:

  gemma3-4b      — sliding-window RING buffer + global cache + tied embed
  deepseek-v2    — absorbed-matrix MLA decode vs full-form training MLA
  zamba2         — mamba2 one-step recurrence + shared-attn cache
  xlstm          — mLSTM (C, n, m) and sLSTM carried states
  whisper        — enc-dec with precomputed cross cache
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import build_model

# (arch, rtol) — recurrences in f32 vs chunked training paths accumulate
# slightly differently
CASES = [
    ("gemma3-4b", 5e-2),
    ("deepseek-v2-236b", 5e-2),
    ("zamba2-7b", 5e-2),
    ("xlstm-1.3b", 5e-2),
]


@pytest.mark.parametrize("name,tol", CASES)
def test_decode_matches_teacher_forced(name, tol):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    full = np.asarray(model.prefill_fn(params, {"tokens": toks}))

    cache = model.init_cache(b, s)
    step = jax.jit(model.serve_step)
    for i in range(s):
        logits, cache = step(params, cache, toks[:, i : i + 1],
                             jnp.full((b,), i, jnp.int32))
    got = np.asarray(logits)
    # compare top-1 agreement and normalized logits
    assert (got.argmax(-1) == full.argmax(-1)).mean() == 1.0, \
        f"{name}: decode argmax diverges from teacher-forced"
    gf = (full - full.mean(-1, keepdims=True)) / (full.std(-1, keepdims=True)
                                                  + 1e-6)
    gg = (got - got.mean(-1, keepdims=True)) / (got.std(-1, keepdims=True)
                                                + 1e-6)
    np.testing.assert_allclose(gg, gf, rtol=tol, atol=tol)


def test_gemma_ring_buffer_wraps_correctly():
    """Decode past the sliding window: the ring buffer must overwrite the
    oldest slots and still match teacher forcing (window = 8 in reduced)."""
    cfg = get_arch("gemma3-4b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    b, s = 1, 24  # 3x the reduced window of 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.vocab_size)
    full = np.asarray(model.prefill_fn(params, {"tokens": toks}))
    cache = model.init_cache(b, s)
    step = jax.jit(model.serve_step)
    for i in range(s):
        logits, cache = step(params, cache, toks[:, i : i + 1],
                             jnp.full((b,), i, jnp.int32))
    got = np.asarray(logits)
    assert (got.argmax(-1) == full.argmax(-1)).all()


def test_whisper_decode_with_cross_cache():
    """Enc-dec: decode with the prepared cross cache matches the
    teacher-forced decoder."""
    cfg = get_arch("whisper-base").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(4))
    b, sd = 2, 8
    enc = jax.random.normal(jax.random.PRNGKey(5),
                            (b, cfg.encoder.n_frames, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(6), (b, sd), 0,
                              cfg.vocab_size)
    full = np.asarray(model.prefill_fn(
        params, {"enc_embeds": enc, "tokens": toks}))
    cache = model.init_cache(b, sd)
    cache = model.prepare_cross_cache(params, cache, enc)
    step = jax.jit(model.serve_step)
    for i in range(sd):
        logits, cache = step(params, cache, toks[:, i : i + 1],
                             jnp.full((b,), i, jnp.int32))
    got = np.asarray(logits)
    assert (got.argmax(-1) == full.argmax(-1)).all()


def test_batched_positions_independent():
    """Different sequences in a decode batch at DIFFERENT positions must
    not interfere (per-sample position vectors)."""
    cfg = get_arch("starcoder2-15b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(7))
    s = 12
    toks = jax.random.randint(jax.random.PRNGKey(8), (2, s), 0,
                              cfg.vocab_size)
    # decode both rows together, row 1 lagging row 0 by hand-staggered calls
    cache = model.init_cache(2, s)
    step = jax.jit(model.serve_step)
    for i in range(s):
        logits_both, cache = step(params, cache, toks[:, i : i + 1],
                                  jnp.full((2,), i, jnp.int32))
    # row 0 decoded alone must match row 0 of the batch
    cache0 = model.init_cache(1, s)
    for i in range(s):
        logits0, cache0 = step(params, cache0, toks[:1, i : i + 1],
                               jnp.full((1,), i, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits0[0]),
                               np.asarray(logits_both[0]),
                               rtol=2e-4, atol=2e-4)
