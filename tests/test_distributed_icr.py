"""Distributed (shard_map + halo exchange) ICR == single-device ICR.

The multi-device checks run in a subprocess because
``--xla_force_host_platform_device_count`` must be set before jax
initializes, and the rest of the suite requires the real 1-device view.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ICR, matern32, regular_chart
from repro.core.distributed import DistributedICR
from repro.compat import use_mesh
from repro.launch.mesh import make_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_sharded_equals_unsharded_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch._dist_icr_check"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert out.stdout.count("max_abs_diff") >= 4, out.stdout


def test_single_device_mesh_roundtrip(key):
    """DistributedICR on a trivial 1-device ring reduces to plain ICR."""
    icr = ICR(chart=regular_chart(32, 3, boundary="reflect"),
              kernel=matern32.with_defaults(rho=10.0))
    mesh = make_mesh((1,), ("space",))
    dist = DistributedICR(icr=icr, mesh=mesh, axis_names=("space",))
    with use_mesh(mesh):
        xi = dist.init_xi(key)
        mats = dist.matrices()
        sharded = dist.apply_sqrt(mats, xi)
    xi_flat = [xi[0]] + [x.reshape(-1, icr.chart.n_fsz) for x in xi[1:]]
    ref = icr.apply_sqrt(icr.matrices(), xi_flat)
    np.testing.assert_allclose(np.asarray(sharded), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_single_device_mesh_pallas_interior(key):
    """ISSUE 4 satellite: the sharded body's interior compute goes through
    dispatch.refine when the wrapped ICR has use_pallas=True — same values
    as the jnp reference interior on the same ring."""
    kern = matern32.with_defaults(rho=10.0)
    chart = regular_chart(32, 3, boundary="reflect")
    mesh = make_mesh((1,), ("space",))
    outs = {}
    for pallas in (False, True):
        icr = ICR(chart=chart, kernel=kern, use_pallas=pallas)
        dist = DistributedICR(icr=icr, mesh=mesh, axis_names=("space",))
        with use_mesh(mesh):
            xi = dist.init_xi(key)
            mats = dist.matrices()
            outs[pallas] = np.asarray(dist.apply_sqrt(mats, xi))
    np.testing.assert_allclose(outs[True], outs[False], rtol=1e-5,
                               atol=1e-5)


def test_single_device_mesh_bf16_policy(key):
    """The dtype policy threads through the distributed interior: bf16
    sharded output matches the fp32 sharded reference at the dtype-scaled
    bar — on the fused 1-D route AND the N-D joint-reference route (which
    must upcast to the accum dtype, not run bf16 math)."""
    from repro.core.charts import galactic_dust_chart

    mesh = make_mesh((1,), ("space",))
    cases = [
        (regular_chart(32, 3, boundary="reflect"), 10.0, 0),
        (galactic_dust_chart((6, 8, 8), n_levels=2), 0.5, 1),
    ]
    for chart, rho, shard_axis in cases:
        kern = matern32.with_defaults(rho=rho)
        d32 = DistributedICR(icr=ICR(chart=chart, kernel=kern,
                                     use_pallas=True),
                             mesh=mesh, shard_axis=shard_axis)
        d16 = DistributedICR(icr=ICR(chart=chart, kernel=kern,
                                     use_pallas=True, dtype_policy="bf16"),
                             mesh=mesh, shard_axis=shard_axis)
        with use_mesh(mesh):
            xi = d32.init_xi(key)
            out32 = np.asarray(d32.apply_sqrt(d32.matrices(), xi))
            xi16 = [x.astype(jnp.bfloat16) for x in xi]
            out16 = d16.apply_sqrt(d16.matrices(), xi16)
        assert out16.dtype == jnp.bfloat16
        scale = max(float(np.abs(out32).max()), 1e-30)
        rel = float(np.abs(np.asarray(out16, np.float32)
                           - out32).max()) / scale
        assert rel <= 5e-2, (chart.ndim, rel)


def test_requires_reflect_boundary():
    icr = ICR(chart=regular_chart(32, 2, boundary="shrink"),
              kernel=matern32)
    mesh = make_mesh((1,), ("space",))
    with pytest.raises(ValueError, match="reflect"):
        DistributedICR(icr=icr, mesh=mesh)


def test_unshardable_raises():
    icr = ICR(chart=regular_chart(8, 1, boundary="reflect"),
              kernel=matern32)
    mesh = make_mesh((1,), ("space",))
    dist = DistributedICR(icr=icr, mesh=mesh)
    object.__setattr__(dist, "axis_names", ("space",))
    # fake a huge ring by monkeypatching n_dev via a tiny chart: family
    # count 4 is not divisible by 3 and block < b+1 for large rings
    big = DistributedICR(icr=icr, mesh=mesh, axis_names=("space",))
    assert big.first_sharded_level() == 0  # sanity on the real ring


def test_xi_specs_structure():
    icr = ICR(chart=regular_chart(64, 3, boundary="reflect"),
              kernel=matern32)
    mesh = make_mesh((1,), ("space",))
    dist = DistributedICR(icr=icr, mesh=mesh)
    specs = dist.xi_specs()
    shapes = dist.xi_structure()
    assert len(specs) == len(shapes) == icr.chart.n_levels + 1
    assert shapes[0] == (64,)
    assert shapes[1] == (64, 2)  # reflect: every stride-1 pixel anchors a family
