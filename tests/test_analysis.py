"""Compile-artifact static analysis: fingerprints, diff, lint passes.

The lowering-based tests pin the tod-bf16 cell (the cheapest chart) and
share one base fingerprint document via a module fixture; the lint tests
are pure geometry (no lowering) and sweep the whole scenario matrix.
"""
import copy

import pytest

from repro.analysis import (
    SCENARIOS,
    canonical_json,
    diff_docs,
    dtype_element_counts,
    fingerprint_scenario,
    format_diff,
    hlo_fingerprint,
    lint_dtype_hlo,
    lint_route_coverage,
    lint_vmem,
)
from repro.analysis.diff import ADDED, CHANGED, REMOVED
from repro.core.charts import regular_chart
from repro.kernels import dispatch


def scenario(label):
    return next(s for s in SCENARIOS() if s.label == label)


# -- fingerprint extraction on synthetic HLO (no lowering) ---------------------

SYNTH_HLO = """
HloModule jit_x, entry_computation_layout={...}

ENTRY %main {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %p1 = bf16[128,64]{1,0} parameter(1)
  %d = f32[8,64]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c = bf16[8,64]{1,0} convert(%d)
  %k = bf16[8,64]{1,0} custom-call(%c), custom_call_target="tpu_custom_call"
  ROOT %o = bf16[8,64]{1,0} custom-call(%k), custom_call_target="SomeOpaqueThing"
}
"""


def test_hlo_fingerprint_shape():
    fp = hlo_fingerprint(SYNTH_HLO)
    assert fp["ops"] == {"convert": 1, "custom-call": 2, "dot": 1,
                         "parameter": 2}
    assert fp["dtypes"] == {"bf16": 5, "f32": 1}
    assert fp["custom_calls"] == {"SomeOpaqueThing": 1, "tpu_custom_call": 1}
    assert fp["cost"]["flops"] == 2 * 8 * 64 * 128
    assert isinstance(fp["cost"]["bytes"], int)


def test_dtype_element_counts():
    counts = dtype_element_counts(SYNTH_HLO)
    assert 8 * 128 in counts["bf16"]
    assert counts["f32"] == {8 * 64}


# -- structured diff ------------------------------------------------------------

def test_diff_docs_kinds_and_format():
    golden = {"plan": [{"route": "pyramid", "b": 1}], "x": {"a": 1, "b": 2}}
    current = {"plan": [{"route": "nd-fused", "b": 1}, {"route": "ref"}],
               "x": {"a": 1, "c": 3}}
    diffs = diff_docs(golden, current)
    by_path = {p: (k, o, n) for p, k, o, n in diffs}
    assert by_path["plan[0].route"] == (CHANGED, "pyramid", "nd-fused")
    assert by_path["plan[1]"][0] == ADDED
    assert by_path["x.b"] == (REMOVED, 2, None)
    assert by_path["x.c"] == (ADDED, None, 3)
    text = format_diff(diffs)
    assert "~ plan[0].route: 'pyramid' -> 'nd-fused'" in text
    assert "- x.b: 2" in text
    assert diff_docs(golden, copy.deepcopy(golden)) == []


# -- lint: VMEM budget (pure geometry, full matrix) ------------------------------

@pytest.mark.parametrize("label", [s.label for s in SCENARIOS()])
def test_vmem_and_route_lint_clean_on_production_plans(label):
    """Zero false positives: every autotuner output across the scenario
    matrix passes the budget re-derivation, and no level routes to the
    jnp reference on the TPU path."""
    scn = scenario(label)
    chart = scn.chart()
    dtype = scn.icr().policy.storage_name
    assert lint_vmem(chart, dtype=dtype, samples=scn.samples,
                     label=label) == []
    assert lint_route_coverage(chart, dtype=dtype, samples=scn.samples,
                               label=label) == []


def test_vmem_lint_flags_oversized_tile():
    """A deliberately oversized tile (far past what the working-set model
    allows) must be flagged — over-budget AND autotuner mismatch."""
    chart = scenario("tod-fp32").chart()
    entries = dispatch.plan_signature(chart, platform="tpu", samples=4,
                                      pyramid=False)
    doctored = copy.deepcopy(entries)
    victim = next(e for e in doctored
                  if e["route"] != dispatch.ROUTE_REFERENCE)
    victim["block_families"]["0"] = 1 << 24  # absurd: ~16M families/tile
    findings = lint_vmem(chart, samples=4, entries=doctored, label="t")
    assert any("exceeds VMEM budget" in f.message for f in findings)
    # and the untouched plan is clean
    assert lint_vmem(chart, samples=4, entries=entries, label="t") == []


def test_vmem_lint_flags_degenerate_tile():
    """A tile smaller than the autotuner's answer is silent occupancy
    loss — the mismatch arm must catch it."""
    chart = scenario("image-fp32").chart()
    entries = dispatch.plan_signature(chart, platform="tpu", samples=4,
                                      pyramid=False)
    doctored = copy.deepcopy(entries)
    victim = next(e for e in doctored
                  if e["route"] == dispatch.ROUTE_ND_FUSED)
    victim["sample_block"] = 1  # autotuner fits the full slab here
    findings = lint_vmem(chart, samples=4, entries=doctored, label="t")
    assert any("degenerate" in f.message for f in findings)


def test_vmem_lint_flags_overbudget_pyramid():
    """Shrinking the budget below the pyramid's residency total must trip
    the combined-residency check against a stored cover."""
    chart = scenario("tod-fp32").chart()
    entries = dispatch.plan_signature(chart, platform="tpu", samples=4)
    assert any(e["route"] == dispatch.ROUTE_PYRAMID for e in entries)
    findings = lint_vmem(chart, samples=4, entries=entries,
                         vmem_budget=1024, label="t")
    assert any("pyramid residency" in f.message for f in findings)


def test_route_lint_flags_reference_fallback():
    """An N-D chart without axis factors routes every level to the jnp
    reference — exactly the silent fallback the pass exists to forbid."""
    chart = scenario("image-fp32").chart()
    findings = lint_route_coverage(chart, samples=4, have_axis_mats=False,
                                   label="t")
    assert findings and all("reference" in f.message for f in findings)
    assert {f.pass_name for f in findings} == {"route"}


# -- lint: dtype policy over lowered HLO -----------------------------------------

@pytest.fixture(scope="module")
def tod_bf16_doc():
    return fingerprint_scenario(scenario("tod-bf16"))


def test_dtype_lint_clean_on_policy_respecting_hlo():
    """fp32 storage has nothing to violate; and a synthetic module whose
    level fields exist at bf16 passes."""
    chart = regular_chart(64, 3)
    assert lint_dtype_hlo(SYNTH_HLO, chart=chart, policy=None) == []
    # intermediate fine_shape counts for this chart are 124 and 244
    hlo = """
ENTRY %m {
  %a = bf16[124]{0} parameter(0)
  %b = bf16[244]{0} exponential(%a)
  ROOT %c = f32[244]{0} convert(%b)
}
"""
    assert lint_dtype_hlo(hlo, chart=chart, policy="bf16") == []


def test_dtype_lint_flags_f32_resident_field():
    """A level-field-sized tensor that exists only at f32 under a bf16
    policy is a silent storage upcast."""
    chart = regular_chart(64, 3)  # intermediate fields: 124, 244 elements
    hlo = """
ENTRY %m {
  %a = bf16[64]{0} parameter(0)
  %b = f32[124]{0} exponential(%a)
  ROOT %c = f32[244]{0} add(%b, %b)
}
"""
    findings = lint_dtype_hlo(hlo, chart=chart, policy="bf16", entry="e")
    assert len(findings) == 2  # both intermediate levels f32-resident
    assert all("f32-resident" in f.message for f in findings)


def test_dtype_lint_flags_low_precision_dot():
    chart = regular_chart(64, 3)
    hlo = """
ENTRY %m {
  %a = bf16[128,4]{1,0} parameter(0)
  %w = bf16[4,2]{1,0} parameter(1)
  %d = bf16[128,2]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %o = bf16[256]{0} bitcast(%d)
}
"""
    findings = lint_dtype_hlo(hlo, chart=chart, policy="bf16")
    assert any("accumulates at bf16" in f.message for f in findings)


# -- fingerprints: determinism + injected regressions ----------------------------

def test_fingerprint_noop_relower_is_byte_identical(tod_bf16_doc):
    """The whole guard rests on this: a second lowering of the same
    scenario in the same process serializes byte-for-byte."""
    again = fingerprint_scenario(scenario("tod-bf16"))
    assert canonical_json(tod_bf16_doc) == canonical_json(again)


def test_fingerprint_catches_reference_route_regression(tod_bf16_doc):
    """use_pallas=False sends every level through the jnp reference —
    the plan signature AND the lowered op histograms must both move."""
    doc = fingerprint_scenario(scenario("tod-bf16"), use_pallas=False)
    diffs = diff_docs(tod_bf16_doc, doc)
    paths = [p for p, *_ in diffs]
    assert any(p.startswith("plan.tpu") and p.endswith(".route")
               for p in paths)
    assert any(p.startswith("entries.apply_sqrt.") for p in paths)


def test_fingerprint_catches_disabled_pyramid(tod_bf16_doc):
    """use_pyramid=False dissolves the VMEM-resident prefix back into
    per-level launches — visible in both plan routes and entry HLO."""
    doc = fingerprint_scenario(scenario("tod-bf16"), use_pyramid=False)
    diffs = diff_docs(tod_bf16_doc, doc)
    by_path = {p: (o, n) for p, _k, o, n in diffs}
    route_flips = {p: v for p, v in by_path.items()
                   if p.startswith("plan.tpu") and p.endswith(".route")
                   and ".vjp" not in p}
    assert route_flips and all(o == "pyramid" for o, _n in
                               route_flips.values())
    assert any(p.startswith("entries.") for p in by_path)


def test_fingerprint_catches_bf16_to_f32_drop(tod_bf16_doc):
    """Silently losing the bf16 policy shows up as the bf16 census
    draining out of every entry (and the plan dtype column flipping)."""
    doc = fingerprint_scenario(scenario("tod-bf16"), policy=None,
                               _policy_set=True)
    diffs = diff_docs(tod_bf16_doc, doc)
    by_path = {p: (k, o, n) for p, k, o, n in diffs}
    assert by_path["storage_dtype"] == (CHANGED, "bfloat16", "float32")
    assert any(p.endswith(".dtypes.bf16") and k == REMOVED
               for p, (k, _o, _n) in by_path.items())


def test_fingerprint_serving_section(tod_bf16_doc):
    """The serving executable-cache key rides along: deterministic digest,
    and the policy/backend it was keyed under are visible."""
    srv = tod_bf16_doc["serving"]
    assert srv["storage_dtype"] == "bfloat16"
    assert srv["backend"] == "interpret"
    assert len(srv["digest"]) == 16
    again = fingerprint_scenario(scenario("tod-bf16"))["serving"]
    assert again["digest"] == srv["digest"]
