"""Batched-sampling throughput path (ISSUE 3 satellite; DESIGN.md §10).

Parity of ``vmap(apply_sqrt)`` and the native sample-batch kernel dimension
(``apply_sqrt_batch`` / ``sample_batch``) against a per-sample Python loop
on every dispatch route — stationary/charted x 1-D/2-D/3-D, interpret
backend — pinned at 1e-5.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ICR, log_chart, matern32, regular_chart
from repro.core.charts import galactic_dust_chart
from repro.kernels import dispatch

CASES = [
    ("stationary-1d", lambda: regular_chart(64, 2, boundary="reflect"), 8.0),
    ("stationary-1d-shrink", lambda: regular_chart(64, 2), 8.0),
    ("charted-1d",
     lambda: log_chart(32, 2, n_csz=5, n_fsz=4, delta0=0.05), 1.0),
    ("nd-fused-2d",
     lambda: regular_chart((12, 16), 2, boundary="reflect"), 4.0),
    ("nd-fused-3d", lambda: galactic_dust_chart((6, 8, 8), n_levels=2), 0.5),
]
IDS = [c[0] for c in CASES]
S = 4


# this module covers the kernel tiling: pin the interpret backend through
# dispatch/ICR (the production CPU default is the jnp oracle)
pytestmark = pytest.mark.usefixtures("interpret_backend")


def _setup(chartf, rho):
    icr = ICR(chart=chartf(), kernel=matern32.with_defaults(rho=rho),
              use_pallas=True)
    mats = icr.matrices()
    xi = icr.init_xi(jax.random.PRNGKey(0), batch=S)
    loop = jnp.stack([
        icr.apply_sqrt(mats, [x[i] for x in xi]) for i in range(S)
    ])
    return icr, mats, xi, loop


@pytest.mark.parametrize("name,chartf,rho", CASES, ids=IDS)
def test_native_batch_matches_loop(name, chartf, rho):
    """apply_sqrt_batch (sample slab inside the kernel tiles) == loop."""
    icr, mats, xi, loop = _setup(chartf, rho)
    if name.startswith("nd-fused"):
        routes = {e["route"] for e in dispatch.plan(icr.chart,
                                                    pyramid=False)}
        assert routes == {dispatch.ROUTE_ND_FUSED}, routes
    got = icr.apply_sqrt_batch(mats, xi)
    assert got.shape == (S,) + icr.out_shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(loop),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,chartf,rho", CASES, ids=IDS)
def test_vmap_matches_loop(name, chartf, rho):
    """jax.vmap through apply_sqrt (batching rule lifts the batch into the
    launch grid) must agree too — it is the convenience path."""
    icr, mats, xi, loop = _setup(chartf, rho)
    got = jax.vmap(lambda *xs: icr.apply_sqrt(mats, list(xs)))(*xi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(loop),
                               rtol=1e-5, atol=1e-5)


def test_sample_batch_reference_path():
    """The non-Pallas reference model batches via vmap of refine_level."""
    icr = ICR(chart=regular_chart(32, 2, boundary="reflect"),
              kernel=matern32.with_defaults(rho=8.0))
    mats = icr.matrices()
    xi = icr.init_xi(jax.random.PRNGKey(1), batch=3)
    got = icr.apply_sqrt_batch(mats, xi)
    want = jnp.stack([icr.apply_sqrt(mats, [x[i] for x in xi])
                      for i in range(3)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_sample_batch_end_to_end():
    """ICR.sample_batch draws n independent, correctly-shaped samples."""
    icr = ICR(chart=galactic_dust_chart((6, 8, 8), n_levels=2),
              kernel=matern32.with_defaults(rho=0.5), use_pallas=True)
    s = icr.sample_batch(jax.random.PRNGKey(2), 3)
    assert s.shape == (3,) + icr.out_shape
    assert bool(jnp.isfinite(s).all())
    # distinct excitations -> distinct samples
    assert float(jnp.abs(s[0] - s[1]).max()) > 1e-3


def test_batched_gradient_through_fused_routes():
    """value_and_grad through the batched apply on the fused 3-D route:
    grads match the summed per-sample gradients (the adjoint kernels see
    the sample slab natively)."""
    icr = ICR(chart=galactic_dust_chart((6, 8, 8), n_levels=2),
              kernel=matern32.with_defaults(rho=0.5), use_pallas=True)
    mats = icr.matrices()
    xi = icr.init_xi(jax.random.PRNGKey(3), batch=S)
    g_batch = jax.grad(
        lambda xs: 0.5 * jnp.sum(icr.apply_sqrt_batch(mats, xs) ** 2))(xi)
    for i in range(S):
        g_one = jax.grad(
            lambda xs: 0.5 * jnp.sum(icr.apply_sqrt(mats, xs) ** 2))(
                [x[i] for x in xi])
        for a, b in zip(g_one, g_batch):
            # 1e-4: the batched level-0 matmul reduces in a different order
            # than the per-sample one (f32 accumulation noise)
            np.testing.assert_allclose(np.asarray(b[i]), np.asarray(a),
                                       rtol=1e-4, atol=1e-5)
