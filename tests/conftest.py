"""Shared test fixtures.

NOTE: do NOT set XLA_FLAGS / host-device-count here — smoke tests and
benchmarks must see the real single CPU device; only launch/dryrun.py forces
512 placeholder devices (and it does so before importing jax).
"""
import os

# Keep XLA single-threaded-ish and quiet for CI stability.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    config.addinivalue_line("markers", "x64: requires float64")
