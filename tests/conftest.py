"""Shared test fixtures.

NOTE: do NOT set XLA_FLAGS / host-device-count here — smoke tests and
benchmarks must see the real single CPU device; only launch/dryrun.py forces
512 placeholder devices (and it does so before importing jax).
"""
import os

# Keep XLA single-threaded-ish and quiet for CI stability.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture()
def interpret_backend(monkeypatch):
    """Pin dispatch.select_backend() to Pallas interpret mode.

    Off-TPU the production backend is the jnp reference/oracle path
    (interpret emulation is slower than plain jnp on CPU) — test modules
    whose point is exercising the exact BlockSpec tiling through
    dispatch/ICR declare this fixture autouse so they keep running the
    kernels bit-for-bit regardless of the production default.
    """
    monkeypatch.setenv("REPRO_BACKEND", "interpret")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    config.addinivalue_line("markers", "x64: requires float64")
