"""Mixed-precision dtype policy (ISSUE 4 / DESIGN.md §11).

bf16-storage + fp32-accum must agree with the fp32 reference at
dtype-scaled tolerances on EVERY route — 1-D stationary/charted, the N-D
per-axis passes, the megakernel, the pyramid — forward and VJP, and the
byte accounting must scale exactly with the storage itemsize.

Tolerance note: bf16 has ~8 mantissa bits (eps ~ 7.8e-3); a multi-level
refinement chain rounds the field to bf16 once per level, so relative
errors of a few eps are expected and 5e-2 is the dtype-scaled bar
(the fp32 suites pin 1e-5 — that bar is untouched).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ICR, matern32, regular_chart
from repro.core.charts import galactic_dust_chart, log_chart
from repro.core.refine import LevelGeom, axis_refinement_matrices_level
from repro.kernels import dispatch, nd
from repro.kernels.policy import BF16, FP32, DtypePolicy, resolve
from repro.roofline import refine_level_traffic

BF16_TOL = 5e-2


# this module covers the kernel tiling: pin the interpret backend through
# dispatch/ICR (the production CPU default is the jnp oracle)
pytestmark = pytest.mark.usefixtures("interpret_backend")


def _rel_close(got_bf16, want_f32, tol=BF16_TOL):
    got = np.asarray(got_bf16, np.float32)
    want = np.asarray(want_f32, np.float32)
    scale = max(float(np.abs(want).max()), 1e-30)
    rel = float(np.abs(got - want).max()) / scale
    assert rel <= tol, rel


# -- the policy object ----------------------------------------------------------
class TestPolicyObject:
    def test_default_is_bf16_storage_f32_accum(self):
        pol = DtypePolicy()
        assert jnp.dtype(pol.storage_dtype) == jnp.bfloat16
        assert jnp.dtype(pol.accum_dtype) == jnp.float32
        assert pol.storage_itemsize == 2

    def test_fp32_opt_out_and_aliases(self):
        assert resolve("fp32") == FP32
        assert resolve("float32") == FP32
        assert resolve("bf16") == BF16
        assert resolve("mixed") == BF16
        assert resolve(None) == FP32  # back-compat: no policy == fp32
        assert resolve(BF16) is BF16
        with pytest.raises(ValueError, match="unknown dtype policy"):
            resolve("fp8")

    def test_cast_storage_passes_none_leaves(self):
        tree = {"a": jnp.ones(3, jnp.float32), "b": None}
        out = BF16.cast_storage(tree)
        assert out["a"].dtype == jnp.bfloat16 and out["b"] is None


# -- forward + VJP parity, every route ------------------------------------------
CASES = [
    ("stationary-1d", lambda: regular_chart(64, 3, boundary="reflect"),
     10.0, {}),
    ("charted-1d", lambda: log_chart(32, 3, n_csz=5, n_fsz=4, delta0=0.05),
     1.0, {}),
    ("pyramid", lambda: galactic_dust_chart((6, 8, 8), n_levels=2),
     0.5, {}),
    ("nd-fused", lambda: galactic_dust_chart((6, 8, 8), n_levels=2),
     0.5, {"use_pyramid": False}),
]
IDS = [c[0] for c in CASES]


def _models(chartf, rho, extra):
    kern = matern32.with_defaults(rho=rho)
    f32 = ICR(chart=chartf(), kernel=kern, use_pallas=True, **extra)
    b16 = ICR(chart=chartf(), kernel=kern, use_pallas=True,
              dtype_policy="bf16", **extra)
    return f32, b16


@pytest.mark.parametrize("name,chartf,rho,extra", CASES, ids=IDS)
def test_forward_parity(name, chartf, rho, extra):
    f32, b16 = _models(chartf, rho, extra)
    xi = f32.init_xi(jax.random.PRNGKey(0))
    out32 = f32.apply_sqrt(f32.matrices(), xi)
    mats16 = b16.matrices()
    out16 = b16.apply_sqrt(mats16, [x.astype(jnp.bfloat16) for x in xi])
    assert out16.dtype == jnp.bfloat16
    _rel_close(out16, out32)


@pytest.mark.parametrize("name,chartf,rho,extra", CASES, ids=IDS)
def test_vjp_parity(name, chartf, rho, extra):
    """jax.grad of the §3.2-style quadratic loss through each route: the
    bf16 adjoint chain tracks the fp32 one at the dtype-scaled bar."""
    f32, b16 = _models(chartf, rho, extra)
    xi32 = f32.init_xi(jax.random.PRNGKey(1))
    mats32, mats16 = f32.matrices(), b16.matrices()
    xi16 = [x.astype(jnp.bfloat16) for x in xi32]

    def loss(icr, mats, xs):
        s = icr.apply_sqrt(mats, xs).astype(jnp.float32)
        return 0.5 * jnp.sum(s * s)

    g32 = jax.grad(lambda xs: loss(f32, mats32, xs))(xi32)
    g16 = jax.grad(lambda xs: loss(b16, mats16, xs))(xi16)
    for a16, a32 in zip(g16, g32):
        assert a16.dtype == jnp.bfloat16
        _rel_close(a16, a32)


def test_nd_axes_route_parity():
    """The per-axis fallback route, bf16 vs fp32, forward + VJP (driven at
    the kernel layer: the dust chart prefers the megakernel, so the route
    is exercised directly)."""
    c = galactic_dust_chart((6, 8, 8), n_levels=2)
    k = matern32.with_defaults(rho=0.5)()
    geom = LevelGeom.for_level(c, 1)
    rs, ds = axis_refinement_matrices_level(c, k, 1)
    rng = np.random.default_rng(3)
    field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
    xi = jnp.asarray(
        rng.normal(size=(int(np.prod(geom.T)), geom.n_fsz**3)), jnp.float32)
    bf = lambda t: jax.tree.map(lambda a: a.astype(jnp.bfloat16), t)

    out32 = nd.refine_axes(field, xi, rs, ds, geom, interpret=True)
    out16 = nd.refine_axes(bf(field), bf(xi), bf(rs), bf(ds), geom,
                           interpret=True)
    assert out16.dtype == jnp.bfloat16
    _rel_close(out16, out32)

    v = jnp.asarray(rng.normal(size=geom.fine_shape), jnp.float32)
    g32 = jax.grad(lambda f, x: jnp.sum(
        nd.refine_axes(f, x, rs, ds, geom, interpret=True) * v),
        argnums=(0, 1))(field, xi)
    g16 = jax.grad(lambda f, x: jnp.sum(
        nd.refine_axes(f, x, bf(rs), bf(ds), geom, interpret=True)
        .astype(jnp.float32) * v), argnums=(0, 1))(bf(field), bf(xi))
    for a16, a32 in zip(g16, g32):
        _rel_close(a16, a32)


def test_batched_sampling_bf16():
    """apply_sqrt_batch under the mixed policy: native sample slab == the
    per-sample loop, in bf16."""
    c = galactic_dust_chart((6, 8, 8), n_levels=2)
    icr = ICR(chart=c, kernel=matern32.with_defaults(rho=0.5),
              use_pallas=True, dtype_policy="bf16")
    mats = icr.matrices()
    xi = icr.init_xi(jax.random.PRNGKey(0), batch=3)
    assert xi[1].dtype == jnp.bfloat16
    batched = icr.apply_sqrt_batch(mats, xi)
    looped = jnp.stack([
        icr.apply_sqrt(mats, [x[i] for x in xi]) for i in range(3)])
    assert batched.dtype == jnp.bfloat16
    _rel_close(batched, looped.astype(jnp.float32), tol=1e-2)


# -- byte accounting ------------------------------------------------------------
class TestDtypeBytes:
    def test_traffic_scales_exactly_with_itemsize(self):
        """Regression: every term of every route's byte model is linear in
        the storage itemsize — bf16 totals are exactly half of fp32."""
        geom = LevelGeom.for_level(galactic_dust_chart((6, 8, 8), 2), 1)
        for route in ("nd-fused", "nd-axes", "reference", "pyramid"):
            t32 = refine_level_traffic(geom, route, dtype="float32")
            t16 = refine_level_traffic(geom, route, dtype="bfloat16")
            assert t32["total"] == 2 * t16["total"], route
            assert t16["dtype"] == "bfloat16"

    def test_autotune_is_itemsize_aware(self):
        """Half the bytes per element -> at least as many families per
        VMEM tile, strictly more when the fp32 block was budget-bound."""
        b32 = dispatch.autotune_block_families(10**6, 5, 4, charted=True,
                                               itemsize=4)
        b16 = dispatch.autotune_block_families(10**6, 5, 4, charted=True,
                                               itemsize=2)
        assert b16 >= 2 * b32

    def test_pyramid_cover_grows_at_bf16(self):
        """A chart whose fp32 working set busts the budget can still be
        fully covered at bf16 (the §11 residency criterion is dtype-aware).
        """
        deep = galactic_dust_chart((8, 16, 16), n_levels=4)
        geoms = [LevelGeom.for_level(deep, l) for l in range(4)]
        budget = 160 * 2**20  # between the fp32 (~268 MiB) and bf16 totals
        k32, _ = dispatch.autotune_pyramid(geoms, itemsize=4,
                                           vmem_budget=budget)
        k16, _ = dispatch.autotune_pyramid(geoms, itemsize=2,
                                           vmem_budget=budget)
        assert k16 > k32
