"""Production backend selection off-TPU (ISSUE 5 satellite).

Pallas interpret mode is slower than plain jnp on CPU, so the runtime
default off-TPU is the jnp reference/oracle backend; REPRO_BACKEND
overrides the runtime decision only (an explicit platform= stays a pure
what-would-run-there question). The oracle executes the SAME factored
structure the kernels run — parity pinned against the interpret backend
here, forward and gradient.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ICR, matern32, regular_chart
from repro.core.charts import galactic_dust_chart
from repro.core.refine import LevelGeom, axis_refinement_matrices_level
from repro.kernels import dispatch


def test_select_backend_default_and_override(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    assert dispatch.select_backend() == dispatch.BACKEND_REFERENCE  # CPU CI
    assert dispatch.select_backend(platform="cpu") \
        == dispatch.BACKEND_REFERENCE
    assert dispatch.select_backend(platform="tpu") == dispatch.BACKEND_PALLAS
    monkeypatch.setenv("REPRO_BACKEND", "interpret")
    assert dispatch.select_backend() == dispatch.BACKEND_INTERPRET
    # explicit platform is introspection — the override must not leak in
    assert dispatch.select_backend(platform="tpu") == dispatch.BACKEND_PALLAS
    monkeypatch.setenv("REPRO_BACKEND", "bogus")
    with pytest.raises(ValueError):
        dispatch.select_backend()


def test_refine_oracle_branch_matches_interpret():
    """dispatch.refine on a factored N-D level with backend=reference (no
    joint matrices!) must equal the interpret megakernel at 1e-5 — the
    branch that used to raise ValueError."""
    c = regular_chart((12, 16), 1, boundary="reflect")
    geom = LevelGeom.for_level(c, 0)
    rs, ds = axis_refinement_matrices_level(
        c, matern32.with_defaults(rho=4.0)(), 0)
    rng = np.random.default_rng(0)
    field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
    xi = jnp.asarray(rng.normal(size=(int(np.prod(geom.T)),
                                      geom.n_fsz**2)), jnp.float32)
    ref = dispatch.refine(field, xi, None, None, geom, axis_mats=(rs, ds),
                          backend=dispatch.BACKEND_REFERENCE)
    itp = dispatch.refine(field, xi, None, None, geom, axis_mats=(rs, ds),
                          backend=dispatch.BACKEND_INTERPRET)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(itp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("use_pyramid", [True, False],
                         ids=["pyramid", "per-level"])
def test_default_backend_apply_and_grad_parity(monkeypatch, use_pyramid):
    """ICR(use_pallas=True) under the production CPU default (reference):
    forward and gradient match the interpret-backend run at 1e-5 on the 3-D
    chart — with the pyramid (one jnp jit region) and without (per-level
    oracle, the refine() branch jax.grad differentiates directly)."""
    c = galactic_dust_chart((6, 8, 8), n_levels=2)
    icr = ICR(chart=c, kernel=matern32.with_defaults(rho=0.5),
              use_pallas=True, use_pyramid=use_pyramid)
    mats = icr.matrices()
    xi = icr.init_xi(jax.random.PRNGKey(0))
    loss = lambda xs: 0.5 * jnp.sum(icr.apply_sqrt(mats, xs) ** 2)

    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    out_ref = icr.apply_sqrt(mats, xi)
    g_ref = jax.grad(loss)(xi)
    monkeypatch.setenv("REPRO_BACKEND", "interpret")
    out_itp = icr.apply_sqrt(mats, xi)
    g_itp = jax.grad(loss)(xi)

    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_itp),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(g_ref, g_itp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-4)
