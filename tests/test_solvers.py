"""Guarded batched CG + data-conditioned posteriors (ISSUE 9; DESIGN.md §16).

Covers the per-RHS masking/quarantine isolation contract (a NaN or
diverging column must leave its slab-mates bit-identical to a clean
run), the monitor statuses (breakdown, stagnation, maxiter), the
fallback ladder with structured FallbackEvents and the dense last rung,
checkpoint/resume across an injected device loss, the ICR-whitened
preconditioner's iteration advantage, `core.vi.cg_posterior` against
the dense exact posterior on the ICR covariance, and `kind="condition"`
serving end to end (admission codes, SolveReport in metrics, Matheron
predictive std). The 8-virtual-device solver chaos suite (mid-solve
kill + sharded divergence isolation) runs in a subprocess because
XLA_FLAGS must be set before jax initializes.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import matern32, regular_chart
from repro.core.vi import cg_posterior
from repro.distributed.fault import DeviceLossError
from repro.launch.serve_gp import GPFieldServer, GPRequest, demo_posterior
from repro.solvers import (
    CGConfig,
    build_condition_system,
    obs_operator,
    pcg_iterate,
    pcg_solve,
    solve_guarded,
)
from repro.solvers.reports import BREAKDOWN, CONVERGED, DIVERGED, NONFINITE

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spd_system(n=40, k=5, seed=0, cond=50.0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    evals = np.geomspace(1.0, cond, n)
    a = (q * evals) @ q.T
    b = rng.standard_normal((k, n))
    return (jnp.asarray(a, jnp.float32),
            jnp.asarray(b, jnp.float32),
            np.linalg.solve(a, b.T).T)


def _mv(a):
    return lambda v: v @ a.T


# -- core engine ----------------------------------------------------------------
def test_batched_pcg_converges_against_dense():
    a, b, x_ref = _spd_system()
    x, stats, _ = pcg_iterate(_mv(a), b, cfg=CGConfig(rtol=1e-6))
    assert np.all(np.asarray(stats["status"]) == CONVERGED)
    np.testing.assert_allclose(np.asarray(x), x_ref, rtol=2e-4, atol=1e-5)
    assert np.all(np.asarray(stats["relres"]) <= 1e-5)


def test_pcg_is_jit_traceable():
    a, b, x_ref = _spd_system()

    @jax.jit
    def solve(bb):
        x, stats, _ = pcg_iterate(_mv(a), bb, cfg=CGConfig(rtol=1e-6))
        return x, stats["status"]

    x, st = solve(b)
    assert np.all(np.asarray(st) == CONVERGED)
    np.testing.assert_allclose(np.asarray(x), x_ref, rtol=2e-4, atol=1e-5)


def test_zero_rhs_converges_at_iteration_zero():
    a, b, _ = _spd_system()
    b = b.at[2].set(0.0)
    _, stats, _ = pcg_iterate(_mv(a), b)
    assert int(np.asarray(stats["iters"])[2]) == 0
    assert int(np.asarray(stats["status"])[2]) == CONVERGED


# -- isolation: the §16 quarantine contract --------------------------------------
def test_nonfinite_rhs_column_is_quarantined_and_siblings_bit_identical():
    a, b, _ = _spd_system(k=6)
    x_clean, _, _ = pcg_iterate(_mv(a), b)
    bad = np.asarray(b).copy()
    bad[3, 1] = np.nan
    x_bad, stats, _ = pcg_iterate(_mv(a), jnp.asarray(bad))
    st = np.asarray(stats["status"])
    assert st[3] == NONFINITE
    assert np.all(np.asarray(x_bad)[3] == 0.0)
    assert np.isinf(np.asarray(stats["relres"])[3])
    keep = [i for i in range(6) if i != 3]
    assert np.array_equal(np.asarray(x_clean)[keep],
                          np.asarray(x_bad)[keep]), \
        "a poisoned RHS perturbed its slab-mates"


def test_divergent_column_is_quarantined_and_siblings_bit_identical():
    """A per-column operator whose column 2 is a scaled rotation
    (nonsymmetric, positive pᵀAp, spectral radius > 1): CG on it runs
    away, the divergence monitor quarantines it, and the SPD siblings
    are bit-identical to a clean run."""
    a, b, x_ref = _spd_system(n=40, k=5)
    rot = np.eye(40, dtype=np.float32)
    c, s = np.cos(1.2), np.sin(1.2)
    for i in range(0, 40, 2):
        rot[i:i + 2, i:i + 2] = [[c, -s], [s, c]]
    rot = jnp.asarray(3.0 * rot)

    def mv_mixed(v):
        sane = v @ a.T
        crazy = v @ rot.T
        col = jnp.arange(v.shape[0])[:, None] == 2
        return jnp.where(col, crazy, sane)

    def mv_clean(v):
        sane = v @ a.T
        col = jnp.arange(v.shape[0])[:, None] == 2
        return jnp.where(col, 0.0 * sane, sane)

    cfg = CGConfig(rtol=1e-6, divergence_factor=10.0, stall_window=100,
                   max_iters=300)
    b_clean = jnp.asarray(np.asarray(b)).at[2].set(0.0)
    x_clean, _, _ = pcg_iterate(mv_clean, b_clean, cfg=cfg)
    x_bad, stats, _ = pcg_iterate(mv_mixed, b, cfg=cfg)
    st = np.asarray(stats["status"])
    assert st[2] == DIVERGED, st
    keep = [i for i in range(5) if i != 2]
    assert np.all(st[keep] == CONVERGED)
    assert np.array_equal(np.asarray(x_clean)[keep],
                          np.asarray(x_bad)[keep]), \
        "a runaway column perturbed its slab-mates"
    assert np.all(np.asarray(x_bad)[2] == 0.0)  # quarantined ⇒ zeroed


# -- monitors and the fallback ladder --------------------------------------------
def test_breakdown_guard_freezes_column_without_nan():
    """pᵀAp <= 0 (indefinite operator) must freeze with status
    breakdown — never the classic silent-garbage division."""
    a, b, _ = _spd_system(k=3)
    neg = -jnp.eye(40, dtype=jnp.float32)

    def mv(v):
        col = jnp.arange(v.shape[0])[:, None] == 1
        return jnp.where(col, v @ neg.T, v @ a.T)

    _, stats, _ = pcg_iterate(mv, b)
    st = np.asarray(stats["status"])
    assert st[1] == BREAKDOWN
    assert st[0] == CONVERGED and st[2] == CONVERGED


def test_bad_preconditioner_falls_back_down_the_ladder():
    """A non-SPD preconditioner breaks every column at init; the ladder
    retries them unpreconditioned and the report records the transition."""
    a, b, x_ref = _spd_system()
    x, report = solve_guarded(
        _mv(a), b, preconds=[("bad", lambda r: -r), ("none", None)],
        cfg=CGConfig(rtol=1e-6))
    assert report.rungs == ("bad", "none")
    assert all(s == "converged" for s in report.status)
    assert len(report.fallbacks) == 1
    ev = report.fallbacks[0]
    assert ev.rung_from == "bad" and ev.rung_to == "none"
    assert dict(ev.reasons) == {"breakdown": 5}
    np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=1e-5)
    assert report.ok


def test_maxiter_columns_fall_through_to_dense_rung():
    a, b, x_ref = _spd_system(cond=1e4)
    dense = lambda bb: jnp.linalg.solve(a, jnp.asarray(bb).T).T
    x, report = solve_guarded(
        _mv(a), b, preconds=[("none", None)],
        cfg=CGConfig(rtol=1e-7, max_iters=3), dense_solve=dense)
    assert report.rungs == ("none", "dense")
    assert all(s == "dense" for s in report.status)
    assert report.ok
    # f32 direct solve at cond 1e4 vs the f64 numpy oracle
    np.testing.assert_allclose(x, x_ref, rtol=5e-3, atol=2e-4)


def test_nonfinite_rhs_never_reaches_the_dense_rung():
    a, b, _ = _spd_system(k=4)
    bad = np.asarray(b).copy()
    bad[1, 0] = np.inf
    dense = lambda bb: jnp.linalg.solve(a, jnp.asarray(bb).T).T
    x, report = solve_guarded(_mv(a), jnp.asarray(bad),
                              preconds=[("none", None)],
                              cfg=CGConfig(rtol=1e-6), dense_solve=dense)
    assert report.status[1] == "nonfinite"
    assert report.quarantined == (1,)
    assert np.all(x[1] == 0.0)
    assert not report.ok


# -- checkpoint / resume ----------------------------------------------------------
def test_midsolve_device_loss_resumes_from_checkpoint(tmp_path):
    from repro.checkpoint.checkpointer import CheckpointManager

    a, b, x_ref = _spd_system(cond=500.0)
    x_ref_run, stats_ref, _, _ = pcg_solve(
        _mv(a), b, cfg=CGConfig(rtol=1e-7, max_iters=200))

    fired = {"n": 0}

    def fault_hook(it):
        if it >= 6 and not fired["n"]:
            fired["n"] += 1
            raise DeviceLossError([0])

    def on_device_loss(exc):
        return None, None, None  # same operator, same width

    mgr = CheckpointManager(str(tmp_path / "cg"))
    x, stats, resumes, n_ckpt = pcg_solve(
        _mv(a), b, cfg=CGConfig(rtol=1e-7, max_iters=200),
        manager=mgr, checkpoint_every=3, fault_hook=fault_hook,
        on_device_loss=on_device_loss)
    assert fired["n"] == 1
    assert len(resumes) == 1
    assert resumes[0].restored_step == 6
    assert n_ckpt >= 3
    assert np.all(np.asarray(stats["status"]) == CONVERGED)
    # the restored carry is the saved carry: the continuation reproduces
    # the uninterrupted solve bit-for-bit
    assert np.array_equal(np.asarray(x), np.asarray(x_ref_run))


def test_device_loss_without_manager_restarts_from_init():
    a, b, _ = _spd_system()
    fired = {"n": 0}

    def fault_hook(it):
        if it >= 2 and not fired["n"]:
            fired["n"] += 1
            raise DeviceLossError([1])

    x, stats, resumes, _ = pcg_solve(
        _mv(a), b, cfg=CGConfig(rtol=1e-6, max_iters=200),
        checkpoint_every=2, fault_hook=fault_hook,
        on_device_loss=lambda exc: (None, None, None))
    assert resumes and resumes[0].restored_step == 0
    assert np.all(np.asarray(stats["status"]) == CONVERGED)


# -- cg_posterior vs the dense exact posterior ------------------------------------
@pytest.mark.parametrize("chart,rho", [
    (regular_chart(32, 2, boundary="reflect"), 8.0),          # 128-pt tod
    (regular_chart((8, 8), 2, boundary="reflect"), 4.0),      # 32x32 image
], ids=["tod", "image"])
def test_cg_posterior_matches_dense_reference(chart, rho):
    """Acceptance: CG posterior mean matches the dense exact posterior on
    the materialized ICR covariance at rel <= 1e-5 (tod and image)."""
    from repro.core import ICR, exact_posterior

    icr = ICR(chart=chart, kernel=matern32.with_defaults(rho=rho))
    n = int(np.prod(chart.final_shape))
    rng = np.random.default_rng(1)
    obs_idx = np.sort(rng.choice(n, size=n // 2, replace=False))
    cov = np.asarray(icr.implicit_cov(dtype=jnp.float32))
    truth = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (n,)))
    y = ((cov @ truth)[obs_idx]
         + 0.05 * rng.standard_normal(obs_idx.size)).astype(np.float32)
    noise = 0.25

    post, report = cg_posterior(icr, obs_idx, y, noise_std=noise)
    assert report.ok, report.summary()
    mean = np.asarray(
        icr.apply_sqrt(post.matrices(), post.mean)).reshape(-1)
    m_ref, _ = exact_posterior(jnp.asarray(cov), jnp.asarray(obs_idx),
                               jnp.asarray(y), noise ** 2)
    m_ref = np.asarray(m_ref).reshape(-1)
    rel = np.linalg.norm(mean - m_ref) / np.linalg.norm(m_ref)
    assert rel <= 1e-5, f"CG posterior mean off by rel {rel:.2e}"


def test_icr_preconditioner_halves_iterations():
    """Acceptance: the ICR-whitened rung must need <= 0.5x the
    unpreconditioned iteration count (it is typically 10-30x better)."""
    from repro.core import ICR

    chart = regular_chart(32, 3, boundary="reflect")
    icr = ICR(chart=chart, kernel=matern32.with_defaults(rho=8.0),
              use_pallas=True)
    n = int(np.prod(chart.final_shape))
    obs_idx = np.arange(0, n, 2)
    rng = np.random.default_rng(2)
    y = rng.standard_normal(obs_idx.size).astype(np.float32)

    _, rep_pre = cg_posterior(icr, obs_idx, y, use_precond=True)
    _, rep_raw = cg_posterior(icr, obs_idx, y, use_precond=False)
    assert rep_pre.ok and rep_raw.ok
    assert rep_pre.rungs[0] == "icr"
    ratio = rep_pre.max_iterations / max(rep_raw.max_iterations, 1)
    assert ratio <= 0.5, \
        (f"icr precond took {rep_pre.max_iterations} iters vs "
         f"{rep_raw.max_iterations} unpreconditioned (ratio {ratio:.2f})")


def test_cg_posterior_offgrid_interpolation_1d():
    from repro.core import ICR

    chart = regular_chart(32, 3, boundary="reflect")
    icr = ICR(chart=chart, kernel=matern32.with_defaults(rho=8.0),
              use_pallas=True)
    grid = np.asarray(chart.axis_coords(chart.n_levels, 0))
    rng = np.random.default_rng(3)
    x_obs = rng.uniform(grid[2], grid[-3], 40)
    y = np.sin(x_obs / 8.0).astype(np.float32)
    post, report = cg_posterior(icr, x_obs.astype(np.float32), y,
                                noise_std=0.05)
    assert report.ok, report.summary()
    mats = icr.matrices_cached(None)
    mean = np.asarray(icr.apply_sqrt(mats, post.mean)).reshape(-1)
    # the posterior mean interpolated back at the observation points
    # explains the data to within a few noise sigma
    op = obs_operator(icr, x_obs=x_obs)
    pred = np.asarray(op.apply(jnp.asarray(mean)[None, :]))[0]
    assert np.sqrt(np.mean((pred - y) ** 2)) < 0.1


# -- kind="condition" serving -----------------------------------------------------
CHART = regular_chart(32, 3, boundary="reflect")


def _cond_req(y, obs_idx, n=6, seed=9, **kw):
    kw.setdefault("noise_std", 0.05)
    return GPRequest(kind="condition", n=n, seed=seed, y=y,
                     obs_idx=obs_idx, **kw)


def _obs_y(chart=CHART, step=4, seed=0):
    n = int(np.prod(chart.final_shape))
    obs_idx = np.arange(0, n, step)
    rng = np.random.default_rng(seed)
    y = (np.sin(np.linspace(0.0, 6.0, obs_idx.size))
         + 0.05 * rng.standard_normal(obs_idx.size)).astype(np.float32)
    return y, obs_idx


def test_condition_request_serves_exact_mean_and_report():
    post = demo_posterior(CHART, 8.0)
    icr = post.icr
    y, obs_idx = _obs_y()
    srv = GPFieldServer(post, slab=4)
    req = _cond_req(y, obs_idx)
    srv.run([req])
    assert req.done and req.error is None, req.error
    assert req.report is not None and req.report.ok
    assert req.report.rungs[0] == "icr"

    op = obs_operator(icr, obs_idx=obs_idx)
    system = build_condition_system(icr, op, 0.05 ** 2)
    alpha_d = system.dense_solve(jnp.asarray(y)[None, :])
    m_ref = np.asarray(system.correct(alpha_d)).reshape(-1)
    rel = (np.linalg.norm(req.mean.reshape(-1) - m_ref)
           / np.linalg.norm(m_ref))
    assert rel <= 1e-5, rel

    met = srv.metrics()
    assert met["condition_requests"] == 1
    assert met["condition_rhs"] == 1 + req.n
    assert met["solve_reports"] and \
        met["solve_reports"][-1]["tag"].startswith("condition:")
    assert met["solve_reports"][-1]["ok"]


def test_condition_matheron_std_tracks_exact_posterior():
    """Pathwise (Matheron) predictive std must track the exact posterior
    std and be depressed at observed pixels."""
    from repro.core import ICR, exact_posterior

    post = demo_posterior(CHART, 8.0)
    y, obs_idx = _obs_y(step=8)
    srv = GPFieldServer(post, slab=4)
    req = _cond_req(y, obs_idx, n=64)
    srv.run([req])
    assert req.error is None and np.isfinite(req.std).all()
    std = req.std.reshape(-1)
    unobs = np.setdiff1d(np.arange(std.size), obs_idx)
    assert std[obs_idx].mean() < std[unobs].mean()

    # non-pallas twin: implicit_cov differentiates the sqrt, which the
    # pallas pyramid forbids (custom_vjp has no jvp)
    ref = ICR(chart=CHART, kernel=matern32.with_defaults(rho=8.0))
    cov = ref.implicit_cov(post.theta, dtype=jnp.float32)
    _, cov_post = exact_posterior(cov, jnp.asarray(obs_idx),
                                  jnp.asarray(y), 0.05 ** 2)
    exact_std = np.sqrt(np.asarray(jnp.diagonal(cov_post)))
    # 64 Matheron draws: the pixel-mean std has ~9% MC error
    ratio = std.mean() / exact_std.mean()
    assert 0.75 < ratio < 1.25, f"Matheron std off exact by x{ratio:.3f}"


def test_condition_admission_rejects_structured():
    post = demo_posterior(CHART, 8.0)
    srv = GPFieldServer(post, slab=4)
    y, obs_idx = _obs_y()
    n = int(np.prod(CHART.final_shape))
    cases = [
        (_cond_req(None, obs_idx), "y-missing"),
        (_cond_req(np.array([np.nan] * len(obs_idx)), obs_idx),
         "y-nonfinite"),
        (GPRequest(kind="condition", n=4, y=y), "obs-spec"),
        (GPRequest(kind="condition", n=4, y=y, obs_idx=obs_idx,
                   x_obs=np.zeros(len(y))), "obs-spec"),
        (_cond_req(y[:3], np.array([0, 5, n + 7])), "obs-range"),
        (_cond_req(y[:3], np.array([0.5, 1.5, 2.5])), "obs-dtype"),
        (_cond_req(y[:4], obs_idx[:3]), "obs-length"),
        (_cond_req(y, obs_idx, noise_std=0.0), "noise-invalid"),
        (_cond_req(y, obs_idx, noise_std=float("nan")), "noise-invalid"),
    ]
    reqs = [r for r, _ in cases]
    srv.run(reqs)
    for (req, code) in cases:
        assert req.done and req.error is not None, code
        assert req.error.code == code, (req.error, code)
    assert srv.condition_requests == 0  # rejected before any solve work


def test_condition_rides_with_sampling_traffic():
    """A mixed queue: the condition solve and the sampling slabs both
    complete, and the sampling results are unaffected by the solve."""
    post = demo_posterior(CHART, 8.0)
    y, obs_idx = _obs_y()

    # baseline: the same sampling queue WITHOUT the condition request
    # (slab packing depends on queue composition, so the baseline must
    # keep the sampling rows identical)
    clean = GPRequest(kind="moments", n=6, seed=2)
    GPFieldServer(post, slab=4).run(
        [GPRequest(kind="sample", n=3, seed=1), clean])

    srv = GPFieldServer(post, slab=4)
    mixed = [GPRequest(kind="sample", n=3, seed=1),
             _cond_req(y, obs_idx),
             GPRequest(kind="moments", n=6, seed=2)]
    srv.run(mixed)
    assert all(r.done and r.error is None for r in mixed), \
        [r.error for r in mixed]
    assert np.array_equal(mixed[2].mean, clean.mean)
    assert np.array_equal(mixed[2].std, clean.std)


def test_condition_system_cache_hits_on_repeat_traffic():
    post = demo_posterior(CHART, 8.0)
    y, obs_idx = _obs_y()
    srv = GPFieldServer(post, slab=4)
    srv.run([_cond_req(y, obs_idx)])
    sys_first = next(iter(srv._cond_cache.values()))
    srv.run([_cond_req(2.0 * y, obs_idx, seed=5)])
    assert len(srv._cond_cache) == 1
    assert next(iter(srv._cond_cache.values())) is sys_first


# -- 8-virtual-device solver chaos (subprocess) -----------------------------------
@pytest.mark.slow
def test_solver_chaos_suite_8dev():
    """Mid-solve device kill (checkpoint/resume on the 7-survivor mesh,
    zero dropped RHS) and sharded divergence isolation — in a subprocess
    because XLA_FLAGS must be set before jax initializes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("REPRO_BACKEND", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.distributed.chaos",
         "--check-solvers"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert out.stdout.count("PASS") == 2, out.stdout
    assert "FAIL" not in out.stdout, out.stdout
