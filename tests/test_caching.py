"""Cache-key integrity: dispatch.plan_cached and ICR.matrices_cached.

The serving warm path (DESIGN.md §12) leans on both caches; a key
collision would silently serve one configuration's routing/matrices to
another. These tests enumerate the axes that must separate entries and
the events that must evict them.
"""
import itertools

import jax
import jax.numpy as jnp
import pytest

from repro.core import ICR, matern32
from repro.core.charts import regular_chart
from repro.kernels import dispatch


@pytest.fixture(autouse=True)
def fresh_plan_cache():
    dispatch.plan_cache_clear()
    yield
    dispatch.plan_cache_clear()


def test_plan_cached_distinct_keys_never_collide():
    """Every (chart, dtype, backend-platform, samples) combination gets
    its own entry — same-argument repeats hit, nothing collides."""
    charts = [regular_chart(64, 2), regular_chart(64, 3),
              regular_chart((16, 16), 2)]
    combos = list(itertools.product(charts, ["float32", "bfloat16"],
                                    ["tpu", "cpu"], [1, 4]))
    plans = {}
    for chart, dtype, platform, samples in combos:
        plans[(chart, dtype, platform, samples)] = dispatch.plan_cached(
            chart, dtype=dtype, platform=platform, samples=samples)
    assert dispatch.plan_cache_stats["misses"] == len(combos)
    assert dispatch.plan_cache_stats["hits"] == 0
    # repeat traffic: all hits, and identical objects (shared, read-only)
    for chart, dtype, platform, samples in combos:
        again = dispatch.plan_cached(chart, dtype=dtype, platform=platform,
                                     samples=samples)
        assert again is plans[(chart, dtype, platform, samples)]
    assert dispatch.plan_cache_stats["hits"] == len(combos)
    # and the cached plans really differ along each axis
    assert (plans[(charts[0], "float32", "tpu", 1)]
            != plans[(charts[1], "float32", "tpu", 1)])
    assert (plans[(charts[0], "float32", "tpu", 1)][0]["dtype"]
            != plans[(charts[0], "bfloat16", "tpu", 1)][0]["dtype"])
    assert (plans[(charts[0], "float32", "tpu", 1)][0]["backend"]
            != plans[(charts[0], "float32", "cpu", 1)][0]["backend"])


def test_plan_cached_backend_override_changes_key(monkeypatch):
    """A REPRO_BACKEND flip must be a miss: the override changes what
    select_backend answers at runtime, so a cached plan from before the
    flip would report the wrong backend."""
    chart = regular_chart(64, 2)
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    base = dispatch.plan_cached(chart)
    monkeypatch.setenv("REPRO_BACKEND", "interpret")
    flipped = dispatch.plan_cached(chart)
    assert dispatch.plan_cache_stats["misses"] == 2
    assert flipped is not base
    assert any(e["backend"] == dispatch.BACKEND_INTERPRET
               for e in flipped)
    # flipping back hits the original entry
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    assert dispatch.plan_cached(chart) is base


def test_plan_cache_clear_evicts():
    chart = regular_chart(64, 2)
    first = dispatch.plan_cached(chart)
    dispatch.plan_cache_clear()
    assert dispatch.plan_cache_stats == {"hits": 0, "misses": 0}
    second = dispatch.plan_cached(chart)
    assert dispatch.plan_cache_stats["misses"] == 1
    assert second is not first  # recomputed, not resurrected


def _icr():
    return ICR(chart=regular_chart(32, 2),
               kernel=matern32.with_defaults(rho=8.0), use_pallas=True)


def test_matrices_cached_theta_keying():
    icr = _icr()
    m_none = icr.matrices_cached()
    assert icr.matrices_cached() is m_none  # None-θ repeat hits
    m_a = icr.matrices_cached({"rho": jnp.asarray(4.0)})
    m_b = icr.matrices_cached({"rho": jnp.asarray(2.0)})
    assert m_a is not m_b and m_a is not m_none
    # same θ value under a fresh array object: same bytes, same entry
    assert icr.matrices_cached({"rho": jnp.asarray(4.0)}) is m_a
    assert icr.matrices_cache_stats == {"hits": 2, "misses": 3}
    # the cached matrices actually differ (not just the keys)
    assert not jnp.allclose(m_a["sqrt0"], m_b["sqrt0"])


def test_matrices_cached_tracer_bypasses_cache():
    """Learning θ inside a jitted step must not poison the cache: traced
    values are unhashable as data, so the cache is bypassed entirely."""
    icr = _icr()
    icr.matrices_cached({"rho": jnp.asarray(4.0)})  # seed one real entry
    stats_before = dict(icr.matrices_cache_stats)

    @jax.jit
    def sqrt0_of(rho):
        return icr.matrices_cached({"rho": rho})["sqrt0"]

    out = sqrt0_of(jnp.asarray(2.0))
    assert out.shape == icr.matrices()["sqrt0"].shape
    assert icr.matrices_cache_stats == stats_before  # untouched by tracing
    # and the traced result is correct, not the cached-θ one
    assert jnp.allclose(out, icr.matrices({"rho": jnp.asarray(2.0)})["sqrt0"])
