"""The public API must import cleanly against the pinned jax.

This is the regression net for jax API drift (jax.shard_map /
jax.sharding.AxisType / jax.set_mesh do not exist on 0.4.x): at the seed,
6 of 18 test modules failed COLLECTION on these imports. Every version-
dependent name must be resolved through repro.compat.
"""
import importlib

import pytest


PUBLIC_MODULES = [
    "repro",
    "repro.compat",
    "repro.core",
    "repro.core.charts",
    "repro.core.distributed",
    "repro.core.exact",
    "repro.core.icr",
    "repro.core.kernels",
    "repro.core.kissgp",
    "repro.core.refine",
    "repro.core.standardize",
    "repro.core.vi",
    "repro.data",
    "repro.distributed",
    "repro.distributed.compression",
    "repro.distributed.elastic",
    "repro.distributed.fault",
    "repro.distributed.sharding",
    "repro.kernels",
    "repro.kernels.dispatch",
    "repro.kernels.icr_refine",
    "repro.kernels.launch",
    "repro.kernels.nd",
    "repro.kernels.nd_fused",
    "repro.kernels.policy",
    "repro.kernels.pyramid",
    "repro.kernels.ref",
    "repro.launch.mesh",
    "repro.launch.serve",
    "repro.launch.steps",
    "repro.models",
    "repro.optim",
    "repro.roofline",
    "repro.roofline.analysis",
    "repro.checkpoint",
    "repro.configs",
]


@pytest.mark.parametrize("mod", PUBLIC_MODULES)
def test_module_imports(mod):
    importlib.import_module(mod)


def test_compat_shard_map_resolves():
    from repro import compat

    assert callable(compat.shard_map)
    # the modern keyword signature must be accepted on this jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    mesh = compat.make_mesh((1,), ("x",))
    fn = compat.shard_map(lambda a: a * 2, mesh=mesh, in_specs=(P(),),
                          out_specs=P(), check_vma=False)
    assert float(fn(jnp.ones(()))) == 2.0


def test_compat_make_mesh_no_axis_types_needed():
    from repro import compat

    mesh = compat.make_mesh((1,), ("data",))
    assert tuple(mesh.axis_names) == ("data",)


def test_compat_use_mesh_context():
    from repro import compat

    mesh = compat.make_mesh((1,), ("data",))
    with compat.use_mesh(mesh):
        pass
