"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py.

Kernels execute in interpret mode on CPU (the body runs as pure jnp), which
checks the BlockSpec tiling, halo views and window construction exactly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ICR, log_chart, matern32, regular_chart
from repro.core.charts import Chart, galactic_dust_chart
from repro.core.refine import (
    LevelGeom,
    axis_refinement_matrices_level,
    refine_level,
    refine_level_T,
    refinement_matrices_level,
)
from repro.kernels import dispatch, nd
from repro.kernels import ref as R
from repro.kernels.icr_refine import (
    refine_charted_pallas,
    refine_stationary_pallas,
)


# this module covers the kernel tiling: pin the interpret backend through
# dispatch/ICR (the production CPU default is the jnp oracle)
pytestmark = pytest.mark.usefixtures("interpret_backend")


PARAMS = [(3, 2), (3, 4), (5, 2), (5, 4), (5, 6)]


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.mark.parametrize("ncsz,nfsz", PARAMS)
@pytest.mark.parametrize("t", [7, 64, 300])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stationary_matches_ref(ncsz, nfsz, t, dtype):
    rng = np.random.default_rng(ncsz * 100 + nfsz + t)
    batch = 3
    coarse = _rand(rng, (batch, R.coarse_len(t, ncsz, nfsz)), dtype)
    xi = _rand(rng, (batch, t, nfsz), dtype)
    r = _rand(rng, (nfsz, ncsz), dtype)
    d = _rand(rng, (nfsz, nfsz), dtype)
    want = R.refine_stationary_ref(coarse, xi, r, d)
    got = refine_stationary_pallas(coarse, xi, r, d, n_csz=ncsz, n_fsz=nfsz,
                                   block_families=32, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("ncsz,nfsz", PARAMS)
@pytest.mark.parametrize("t", [9, 128])
def test_charted_matches_ref(ncsz, nfsz, t):
    rng = np.random.default_rng(ncsz * 10 + nfsz + t)
    coarse = _rand(rng, (2, R.coarse_len(t, ncsz, nfsz)), jnp.float32)
    xi = _rand(rng, (2, t, nfsz), jnp.float32)
    r = _rand(rng, (t, nfsz, ncsz), jnp.float32)
    d = _rand(rng, (t, nfsz, nfsz), jnp.float32)
    want = R.refine_charted_ref(coarse, xi, r, d)
    got = refine_charted_pallas(coarse, xi, r, d, n_csz=ncsz, n_fsz=nfsz,
                                block_families=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block", [16, 64, 1024])
def test_block_size_invariance(block):
    """Output must not depend on the VMEM tile size."""
    rng = np.random.default_rng(0)
    ncsz, nfsz, t = 5, 4, 200
    coarse = _rand(rng, (1, R.coarse_len(t, ncsz, nfsz)), jnp.float32)
    xi = _rand(rng, (1, t, nfsz), jnp.float32)
    r = _rand(rng, (nfsz, ncsz), jnp.float32)
    d = _rand(rng, (nfsz, nfsz), jnp.float32)
    base = R.refine_stationary_ref(coarse, xi, r, d)
    got = refine_stationary_pallas(coarse, xi, r, d, n_csz=ncsz, n_fsz=nfsz,
                                   block_families=block, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)


class TestOpsIntegration:
    """dispatch.refine must agree with core.refine.refine_level end-to-end."""

    def test_stationary_shrink_end_to_end(self):
        c = regular_chart(64, 2, n_csz=5, n_fsz=4)
        icr_ref = ICR(chart=c, kernel=matern32.with_defaults(rho=8.0))
        icr_pal = ICR(chart=c, kernel=matern32.with_defaults(rho=8.0),
                      use_pallas=True)
        key = jax.random.PRNGKey(3)
        xi = icr_ref.init_xi(key)
        mats = icr_ref.matrices()
        a = icr_ref.apply_sqrt(mats, xi)
        b = icr_pal.apply_sqrt(mats, xi)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_stationary_reflect_end_to_end(self):
        c = regular_chart(64, 2, boundary="reflect")
        icr_ref = ICR(chart=c, kernel=matern32.with_defaults(rho=8.0))
        icr_pal = ICR(chart=c, kernel=matern32.with_defaults(rho=8.0),
                      use_pallas=True)
        key = jax.random.PRNGKey(4)
        xi = icr_ref.init_xi(key)
        mats = icr_ref.matrices()
        np.testing.assert_allclose(
            np.asarray(icr_ref.apply_sqrt(mats, xi)),
            np.asarray(icr_pal.apply_sqrt(mats, xi)),
            rtol=1e-5, atol=1e-5,
        )

    def test_charted_op_matches_core(self):
        """Charted per-family kernel == core refine on the log chart."""
        c = log_chart(32, 1, n_csz=5, n_fsz=4, delta0=0.05)
        k = matern32.with_defaults(rho=1.0)()
        r, d = refinement_matrices_level(c, k, 0)
        geom = LevelGeom.for_level(c, 0)
        rng = np.random.default_rng(1)
        field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
        t = geom.T[0]
        xi = jnp.asarray(rng.normal(size=(t, geom.n_fsz)), jnp.float32)
        from repro.core.refine import refine_level

        want = refine_level(field, xi, r, d, geom)
        got = dispatch.refine(field, xi, r, d, geom,
                              backend=dispatch.BACKEND_INTERPRET)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_nd_falls_back_to_core(self):
        """Without per-axis factors, N-D joint matrices use the reference."""
        c = regular_chart((8, 8), 1)
        k = matern32.with_defaults(rho=4.0)()
        r, d = refinement_matrices_level(c, k, 0)
        geom = LevelGeom.for_level(c, 0)
        assert dispatch.route_for(geom) == dispatch.ROUTE_REFERENCE
        rng = np.random.default_rng(2)
        field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
        f = int(np.prod(geom.T))
        xi = jnp.asarray(rng.normal(size=(f, geom.n_fsz**2)), jnp.float32)
        out = dispatch.refine(field, xi, r, d, geom)
        assert out.shape == geom.fine_shape
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(refine_level(field, xi, r, d, geom)),
            rtol=1e-6, atol=1e-6,
        )


# -- N-D fused path (per-axis passes, DESIGN.md §4) ----------------------------
def _kron_joint(rs, ds, geom):
    """Joint (*kept_T, fsz^d, csz^d) matrices from per-axis factors."""
    rs = [m if m.ndim == 3 else m[None] for m in rs]
    ds = [m if m.ndim == 3 else m[None] for m in ds]
    kept = tuple(m.shape[0] for m in rs)

    def build(mats):
        out = mats[0]
        for m in mats[1:]:
            out = jnp.einsum("...FC,tfc->...tFfCc", out, m)
            sh = out.shape
            out = out.reshape(sh[:-4] + (sh[-4] * sh[-3], sh[-2] * sh[-1]))
        return out

    r = build(rs)
    return r.reshape(kept + r.shape[1:]), (
        lambda d: d.reshape(kept + d.shape[1:]))(build(ds))


ND_CHARTS = [
    # (chart factory, id) — stationary / charted axes x shrink / reflect
    (lambda: regular_chart((12, 10), 2, boundary="shrink"), "2d-shrink"),
    (lambda: regular_chart((12, 16), 2, boundary="reflect"), "2d-reflect"),
    (lambda: Chart(  # 2-D, charted (log) axis 0, invariant axis 1
        shape0=(14, 12), n_levels=2, delta0=(0.05, 1.0), boundary="shrink",
        phi_inv=lambda x: jnp.stack(
            [jnp.exp(x[..., 0]), x[..., 1]], axis=-1),
        invariant=(False, True)), "2d-mixed-shrink"),
    (lambda: regular_chart((8, 8, 12), 1, boundary="shrink"), "3d-shrink"),
    (lambda: galactic_dust_chart((6, 8, 8), n_levels=2), "3d-dust-reflect"),
]


@pytest.mark.parametrize("chartf,name", ND_CHARTS, ids=[n for _, n in ND_CHARTS])
def test_nd_axes_matches_refine_level(chartf, name):
    """Per-axis fused passes == joint refine_level on Kronecker matrices.

    This pins the implementation exactly: given factored matrices, the N-D
    Pallas path (interpret mode) must reproduce the joint jnp reference."""
    c = chartf()
    k = matern32.with_defaults(rho=3.0)()
    for lvl in range(c.n_levels):
        geom = LevelGeom.for_level(c, lvl)
        rs, ds = axis_refinement_matrices_level(c, k, lvl)
        rng = np.random.default_rng([lvl, *name.encode()])
        field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
        f = int(np.prod(geom.T))
        xi = jnp.asarray(
            rng.normal(size=(f, geom.n_fsz ** len(geom.T))), jnp.float32)
        got = nd.refine_axes(field, xi, rs, ds, geom, interpret=True)
        r_j, d_j = _kron_joint(rs, ds, geom)
        want = refine_level(field, xi, r_j, d_j, geom)
        assert got.shape == geom.fine_shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chartf,name", ND_CHARTS[:2] + ND_CHARTS[-1:],
                         ids=["2d-shrink", "2d-reflect", "3d-dust-reflect"])
def test_nd_axes_matches_oracle(chartf, name):
    """Fused N-D passes are bit-exact vs the independent jnp oracle."""
    c = chartf()
    k = matern32.with_defaults(rho=3.0)()
    geom = LevelGeom.for_level(c, 0)
    rs, ds = axis_refinement_matrices_level(c, k, 0)
    rng = np.random.default_rng(7)
    field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
    f = int(np.prod(geom.T))
    xi = jnp.asarray(
        rng.normal(size=(f, geom.n_fsz ** len(geom.T))), jnp.float32)
    got = nd.refine_axes(field, xi, rs, ds, geom, interpret=True)
    want = R.refine_axes_ref(field, xi, rs, ds, T=geom.T, n_fsz=geom.n_fsz,
                             boundary=geom.boundary, b=geom.b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block", [8, 32, 1024])
def test_nd_block_size_invariance(block):
    c = regular_chart((16, 24), 1, boundary="reflect")
    k = matern32.with_defaults(rho=4.0)()
    geom = LevelGeom.for_level(c, 0)
    rs, ds = axis_refinement_matrices_level(c, k, 0)
    rng = np.random.default_rng(3)
    field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
    f = int(np.prod(geom.T))
    xi = jnp.asarray(rng.normal(size=(f, geom.n_fsz**2)), jnp.float32)
    base = nd.refine_axes(field, xi, rs, ds, geom, interpret=True,
                          block_families=16)
    got = nd.refine_axes(field, xi, rs, ds, geom, interpret=True,
                         block_families=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)


def test_icr_nd_use_pallas_end_to_end():
    """ICR(use_pallas=True) routes every dust-chart level through the fused
    path (no reference fallback) and produces a finite, correlated field."""
    c = galactic_dust_chart((6, 8, 8), n_levels=2)
    icr = ICR(chart=c, kernel=matern32.with_defaults(rho=0.5),
              use_pallas=True)
    for entry in dispatch.plan(c):
        assert entry["route"] != dispatch.ROUTE_REFERENCE, entry
    # the fused path skips the joint O(n_csz^3d) matrix build entirely
    mats = icr.matrices()
    assert "Rax" in mats and "R" not in mats
    assert "R" in icr.matrices(joint=True)
    s = icr.sample(jax.random.PRNGKey(0))
    assert s.shape == c.final_shape
    assert bool(jnp.isfinite(s).all())
    # the separable fast path is an approximation of the joint model; it
    # must stay within a few percent on this chart (non-separable Matérn)
    icr_ref = ICR(chart=c, kernel=matern32.with_defaults(rho=0.5))
    xi = icr.init_xi(jax.random.PRNGKey(0))
    sj = icr_ref.apply_sqrt(icr_ref.matrices(), xi)
    rel = float(jnp.linalg.norm(s - sj) / jnp.linalg.norm(sj))
    assert rel < 0.15, rel


# -- dispatch layer ------------------------------------------------------------
class TestDispatch:
    def test_routes(self):
        geom_1d_st = LevelGeom.for_level(regular_chart(32, 1), 0)
        assert dispatch.route_for(geom_1d_st) == dispatch.ROUTE_STATIONARY_1D
        geom_1d_ch = LevelGeom.for_level(log_chart(32, 1, delta0=0.05), 0)
        assert dispatch.route_for(geom_1d_ch) == dispatch.ROUTE_CHARTED_1D
        geom_2d = LevelGeom.for_level(regular_chart((8, 8), 1), 0)
        assert dispatch.route_for(geom_2d) == dispatch.ROUTE_REFERENCE
        # small N-D level: the single-launch megakernel fits VMEM
        assert (dispatch.route_for(geom_2d, have_axis_mats=True)
                == dispatch.ROUTE_ND_FUSED)
        # when the joint tile + halos bust the budget, fall back to the
        # per-axis passes (the DESIGN.md §10 fallback rule)
        assert dispatch.autotune_nd_fused(geom_2d, vmem_budget=64) is None

    def test_autotune_monotone_and_bounded(self):
        small = dispatch.autotune_block_families(10**6, 5, 4, charted=True)
        big = dispatch.autotune_block_families(10**6, 5, 4, charted=False)
        assert big >= small >= 8
        # charted per-family matrices dominate the working set at large b_f:
        # the block must fit the VMEM budget
        s, fsz, csz = 2, 4, 5
        per = (2 * small * s + 2 * small * fsz + fsz * csz + fsz * fsz
               + small * (fsz * csz + fsz * fsz))
        assert 2 * 4 * per <= dispatch.VMEM_BUDGET_BYTES

    def test_autotune_clamps_to_tiny_family_counts(self):
        """Regression: levels with t < 8 used to get the floor-8 block (pure
        padding); the block is now clamped to the family count."""
        assert dispatch.autotune_block_families(5, 5, 4, charted=False) == 5
        for t in range(1, 8):
            b = dispatch.autotune_block_families(t, 5, 4, charted=True)
            # never exceed t except to cover the halo overhang q_max
            assert b == max(t, (5 - 1) // 2)
        # the q_max floor: big window over a tiny level still gets a halo-
        # covering block (q_max = 4 here), and the kernel must stay correct
        rng = np.random.default_rng(5)
        ncsz, nfsz, t = 5, 2, 3
        b = dispatch.autotune_block_families(t, ncsz, nfsz, charted=False)
        assert b == 4
        coarse = _rand(rng, (1, R.coarse_len(t, ncsz, nfsz)), jnp.float32)
        xi = _rand(rng, (1, t, nfsz), jnp.float32)
        r = _rand(rng, (nfsz, ncsz), jnp.float32)
        d = _rand(rng, (nfsz, nfsz), jnp.float32)
        got = refine_stationary_pallas(coarse, xi, r, d, n_csz=ncsz,
                                       n_fsz=nfsz, block_families=b,
                                       interpret=True)
        np.testing.assert_allclose(
            np.asarray(got),
            np.asarray(R.refine_stationary_ref(coarse, xi, r, d)),
            rtol=1e-5, atol=1e-5,
        )

    def test_plan_dust_chart(self):
        c = galactic_dust_chart((6, 8, 8), n_levels=2)
        # default plan: both tiny levels ride the VMEM-resident pyramid
        assert [e["route"] for e in dispatch.plan(c, platform="cpu")] \
            == [dispatch.ROUTE_PYRAMID] * 2
        # per-level view (pyramid off): the §10 megakernel everywhere
        plan = dispatch.plan(c, platform="cpu", pyramid=False)
        assert [e["route"] for e in plan] == [dispatch.ROUTE_ND_FUSED] * 2
        # off-TPU the production executor of the fused structure is the jnp
        # oracle (interpret emulation is slower than jnp on CPU)
        assert all(e["backend"] == dispatch.BACKEND_REFERENCE for e in plan)
        plan_tpu = dispatch.plan(c, platform="tpu", pyramid=False)
        assert all(e["backend"] == dispatch.BACKEND_PALLAS for e in plan_tpu)


# -- _stationary_level regression (the lvl-ignoring bug) -----------------------
class TestStationaryLevel:
    def test_mixed_invariant_charted_chart(self):
        """A chart with one charted + invariant axes is NOT stationary."""
        icr = ICR(chart=galactic_dust_chart((6, 8, 8), n_levels=2),
                  kernel=matern32.with_defaults(rho=0.5))
        assert not any(icr._stationary_level(l) for l in range(2))

    def test_fully_invariant_chart(self):
        icr = ICR(chart=regular_chart(64, 2), kernel=matern32)
        assert all(icr._stationary_level(l) for l in range(2))

    def test_charted_level_with_single_family_is_stationary(self):
        """Per-level behavior: a charted axis collapses to one shared matrix
        at levels with family count 1 (the old code ignored `lvl`)."""
        c = log_chart(3, 2, n_csz=3, n_fsz=4, delta0=0.05)
        icr = ICR(chart=c, kernel=matern32)
        geoms = [icr._stationary_level(l) for l in range(2)]
        assert geoms == [
            all(k == 1 for k in LevelGeom.for_level(c, l).kept_T)
            for l in range(2)
        ]
        assert any(geoms)  # T==1 levels exist on this tiny chart

    def test_charted_1d_use_pallas_end_to_end(self):
        """Charted levels now reach the charted kernel under use_pallas and
        agree with the reference (previously they silently fell back)."""
        c = log_chart(32, 2, n_csz=5, n_fsz=4, delta0=0.05)
        icr_ref = ICR(chart=c, kernel=matern32.with_defaults(rho=1.0))
        icr_pal = ICR(chart=c, kernel=matern32.with_defaults(rho=1.0),
                      use_pallas=True)
        xi = icr_ref.init_xi(jax.random.PRNGKey(2))
        mats = icr_ref.matrices()
        np.testing.assert_allclose(
            np.asarray(icr_ref.apply_sqrt(mats, xi)),
            np.asarray(icr_pal.apply_sqrt(mats, xi)),
            rtol=1e-5, atol=1e-5,
        )


# -- adjoint kernels / custom VJP (DESIGN.md §9) --------------------------------
def _vjp_all(fn, args, g):
    """All input cotangents of fn at args for output cotangent g."""
    _, vjp = jax.vjp(fn, *args)
    return vjp(g)


@pytest.mark.parametrize("ncsz,nfsz", PARAMS)
@pytest.mark.parametrize("t", [7, 64, 300])
def test_stationary_vjp_matches_ref(ncsz, nfsz, t):
    """jax.vjp of the fused kernel == jax.vjp of the jnp reference, all four
    cotangents (coarse / xi / R / sqrtD), pinned at 1e-5."""
    rng = np.random.default_rng(ncsz * 100 + nfsz + t)
    batch = 2
    coarse = _rand(rng, (batch, R.coarse_len(t, ncsz, nfsz)), jnp.float32)
    xi = _rand(rng, (batch, t, nfsz), jnp.float32)
    r = _rand(rng, (nfsz, ncsz), jnp.float32)
    d = _rand(rng, (nfsz, nfsz), jnp.float32)
    g = _rand(rng, (batch, t * nfsz), jnp.float32)
    want = _vjp_all(R.refine_stationary_ref, (coarse, xi, r, d), g)
    got = _vjp_all(
        lambda c, x, rr, dd: refine_stationary_pallas(
            c, x, rr, dd, n_csz=ncsz, n_fsz=nfsz, block_families=32,
            interpret=True),
        (coarse, xi, r, d), g)
    # the hand-derived oracle must agree with autodiff of the reference too
    oracle = R.refine_stationary_vjp_ref(coarse, xi, r, d, g)
    for name, a, b, o in zip("coarse xi r d".split(), want, got, oracle):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5, err_msg=f"d{name}")
        np.testing.assert_allclose(np.asarray(o), np.asarray(a),
                                   rtol=1e-4, atol=1e-5, err_msg=f"d{name}")


@pytest.mark.parametrize("ncsz,nfsz", PARAMS)
@pytest.mark.parametrize("t", [9, 128])
def test_charted_vjp_matches_ref(ncsz, nfsz, t):
    rng = np.random.default_rng(ncsz * 10 + nfsz + t)
    coarse = _rand(rng, (2, R.coarse_len(t, ncsz, nfsz)), jnp.float32)
    xi = _rand(rng, (2, t, nfsz), jnp.float32)
    r = _rand(rng, (t, nfsz, ncsz), jnp.float32)
    d = _rand(rng, (t, nfsz, nfsz), jnp.float32)
    g = _rand(rng, (2, t * nfsz), jnp.float32)
    want = _vjp_all(R.refine_charted_ref, (coarse, xi, r, d), g)
    got = _vjp_all(
        lambda c, x, rr, dd: refine_charted_pallas(
            c, x, rr, dd, n_csz=ncsz, n_fsz=nfsz, block_families=32,
            interpret=True),
        (coarse, xi, r, d), g)
    oracle = R.refine_charted_vjp_ref(coarse, xi, r, d, g)
    for name, a, b, o in zip("coarse xi r d".split(), want, got, oracle):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5, err_msg=f"d{name}")
        np.testing.assert_allclose(np.asarray(o), np.asarray(a),
                                   rtol=1e-4, atol=1e-5, err_msg=f"d{name}")


@pytest.mark.parametrize("block", [4, 16, 1024])
def test_adjoint_block_size_invariance(block):
    """The backward must not depend on the VMEM tile size either."""
    rng = np.random.default_rng(11)
    ncsz, nfsz, t = 5, 4, 200
    coarse = _rand(rng, (1, R.coarse_len(t, ncsz, nfsz)), jnp.float32)
    xi = _rand(rng, (1, t, nfsz), jnp.float32)
    r = _rand(rng, (nfsz, ncsz), jnp.float32)
    d = _rand(rng, (nfsz, nfsz), jnp.float32)
    g = _rand(rng, (1, t * nfsz), jnp.float32)
    base = R.refine_stationary_vjp_ref(coarse, xi, r, d, g)
    got = _vjp_all(
        lambda c, x, rr, dd: refine_stationary_pallas(
            c, x, rr, dd, n_csz=ncsz, n_fsz=nfsz, block_families=block,
            interpret=True),
        (coarse, xi, r, d), g)
    for a, b in zip(base, got):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("chartf,name", ND_CHARTS,
                         ids=[n for _, n in ND_CHARTS])
def test_nd_axes_vjp_matches_oracle(chartf, name):
    """jax.grad through the fused N-D per-axis passes == grad of the
    independent jnp oracle, every level, both boundaries."""
    c = chartf()
    k = matern32.with_defaults(rho=3.0)()
    for lvl in range(c.n_levels):
        geom = LevelGeom.for_level(c, lvl)
        rs, ds = axis_refinement_matrices_level(c, k, lvl)
        rng = np.random.default_rng([lvl, *name.encode()])
        field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
        f = int(np.prod(geom.T))
        xi = jnp.asarray(
            rng.normal(size=(f, geom.n_fsz ** len(geom.T))), jnp.float32)
        v = jnp.asarray(rng.normal(size=geom.fine_shape), jnp.float32)
        loss_pal = lambda fl, x: jnp.sum(
            nd.refine_axes(fl, x, rs, ds, geom, interpret=True) * v)
        loss_ref = lambda fl, x: jnp.sum(
            R.refine_axes_ref(fl, x, rs, ds, T=geom.T, n_fsz=geom.n_fsz,
                              boundary=geom.boundary, b=geom.b) * v)
        got = jax.grad(loss_pal, argnums=(0, 1))(field, xi)
        want = jax.grad(loss_ref, argnums=(0, 1))(field, xi)
        for a, b in zip(want, got):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-5, atol=1e-5)


class TestICRGradParity:
    """Acceptance: jax.grad through ICR(use_pallas=True).apply_sqrt matches
    the reference-path gradient on 1-D/2-D/3-D charts."""

    def _parity(self, icr_ref, icr_pal, mats, key, tol=1e-5):
        xi = icr_ref.init_xi(key)
        g_ref = jax.grad(
            lambda xs: 0.5 * jnp.sum(icr_ref.apply_sqrt(mats, xs) ** 2))(xi)
        g_pal = jax.grad(
            lambda xs: 0.5 * jnp.sum(icr_pal.apply_sqrt(mats, xs) ** 2))(xi)
        for a, b in zip(g_ref, g_pal):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=tol, atol=tol)

    @pytest.mark.parametrize("boundary", ["shrink", "reflect"])
    def test_1d_stationary(self, boundary):
        c = regular_chart(64, 2, boundary=boundary)
        kern = matern32.with_defaults(rho=8.0)
        icr_ref = ICR(chart=c, kernel=kern)
        icr_pal = ICR(chart=c, kernel=kern, use_pallas=True)
        self._parity(icr_ref, icr_pal, icr_ref.matrices(),
                     jax.random.PRNGKey(0))

    def test_1d_charted(self):
        c = log_chart(32, 2, n_csz=5, n_fsz=4, delta0=0.05)
        kern = matern32.with_defaults(rho=1.0)
        icr_ref = ICR(chart=c, kernel=kern)
        icr_pal = ICR(chart=c, kernel=kern, use_pallas=True)
        self._parity(icr_ref, icr_pal, icr_ref.matrices(),
                     jax.random.PRNGKey(1))

    def test_theta_gradient_through_matrices(self):
        """Learned-θ path: matrices are perturbed, so the VJP also carries
        the (R, sqrtD) cotangents — fused must match reference."""
        c = regular_chart(32, 2, boundary="reflect")
        kern = matern32.with_defaults(rho=8.0)
        icr_ref = ICR(chart=c, kernel=kern)
        icr_pal = ICR(chart=c, kernel=kern, use_pallas=True)
        xi = icr_ref.init_xi(jax.random.PRNGKey(3))
        theta = lambda lr: {"rho": jnp.exp(lr), "sigma": 1.0}
        g_ref = jax.grad(
            lambda lr: 0.5 * jnp.sum(icr_ref(xi, theta(lr)) ** 2))(
                jnp.asarray(2.0))
        g_pal = jax.grad(
            lambda lr: 0.5 * jnp.sum(icr_pal(xi, theta(lr)) ** 2))(
                jnp.asarray(2.0))
        np.testing.assert_allclose(float(g_pal), float(g_ref), rtol=1e-4)


class TestApplySqrtT:
    def test_adjoint_identity_3d_fused(self):
        """<sqrt(K) ξ, v> == <ξ, sqrt(K)ᵀ v> through the fused adjoints."""
        c = galactic_dust_chart((6, 8, 8), n_levels=2)
        icr = ICR(chart=c, kernel=matern32.with_defaults(rho=0.5),
                  use_pallas=True)
        mats = icr.matrices()
        xi = icr.init_xi(jax.random.PRNGKey(1))
        v = jax.random.normal(jax.random.PRNGKey(2), icr.out_shape)
        lhs = float(jnp.vdot(icr.apply_sqrt(mats, xi), v))
        back = icr.apply_sqrt_T(mats, v)
        assert [b.shape for b in back] == [tuple(s) for s in icr.xi_shapes()]
        rhs = float(sum(jnp.vdot(a, b) for a, b in zip(xi, back)))
        np.testing.assert_allclose(rhs, lhs, rtol=1e-4)

    def test_matches_reference_transpose_1d(self):
        """Fused apply_sqrt_T == reference apply_sqrt_T == per-level
        refine_level_T chain."""
        c = regular_chart(64, 2, boundary="reflect")
        kern = matern32.with_defaults(rho=8.0)
        icr_ref = ICR(chart=c, kernel=kern)
        icr_pal = ICR(chart=c, kernel=kern, use_pallas=True)
        mats = icr_ref.matrices()
        v = jax.random.normal(jax.random.PRNGKey(4), icr_ref.out_shape)
        want = icr_ref.apply_sqrt_T(mats, v)
        got = icr_pal.apply_sqrt_T(mats, v)
        for a, b in zip(want, got):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-5, atol=1e-5)
        # hand-walk the levels in reverse with refine_level_T
        cot = v
        manual = []
        for lvl in reversed(range(c.n_levels)):
            geom = LevelGeom.for_level(c, lvl)
            cot, dxi = refine_level_T(cot, mats["R"][lvl],
                                      mats["sqrtD"][lvl], geom)
            manual.append(dxi)
        manual.append(mats["sqrt0"].T @ cot.reshape(-1))
        for a, b in zip(want, reversed(manual)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-5, atol=1e-5)

    def test_plan_reports_fused_vjp(self):
        c = galactic_dust_chart((6, 8, 8), n_levels=2)
        for entry in dispatch.plan(c, platform="cpu", pyramid=False):
            assert entry["vjp"]["route"] == dispatch.ROUTE_ND_FUSED + "-adjoint"
            assert entry["vjp"]["backend"] == entry["backend"]
            assert entry["vjp"]["block_families"] == entry["block_families"]
