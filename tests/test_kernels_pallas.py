"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py.

Kernels execute in interpret mode on CPU (the body runs as pure jnp), which
checks the BlockSpec tiling, halo views and window construction exactly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ICR, log_chart, matern32, regular_chart
from repro.core.refine import LevelGeom, refinement_matrices_level
from repro.kernels import ref as R
from repro.kernels import ops
from repro.kernels.icr_refine import (
    refine_charted_pallas,
    refine_stationary_pallas,
)

PARAMS = [(3, 2), (3, 4), (5, 2), (5, 4), (5, 6)]


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.mark.parametrize("ncsz,nfsz", PARAMS)
@pytest.mark.parametrize("t", [7, 64, 300])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stationary_matches_ref(ncsz, nfsz, t, dtype):
    rng = np.random.default_rng(ncsz * 100 + nfsz + t)
    batch = 3
    coarse = _rand(rng, (batch, R.coarse_len(t, ncsz, nfsz)), dtype)
    xi = _rand(rng, (batch, t, nfsz), dtype)
    r = _rand(rng, (nfsz, ncsz), dtype)
    d = _rand(rng, (nfsz, nfsz), dtype)
    want = R.refine_stationary_ref(coarse, xi, r, d)
    got = refine_stationary_pallas(coarse, xi, r, d, n_csz=ncsz, n_fsz=nfsz,
                                   block_families=32, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("ncsz,nfsz", PARAMS)
@pytest.mark.parametrize("t", [9, 128])
def test_charted_matches_ref(ncsz, nfsz, t):
    rng = np.random.default_rng(ncsz * 10 + nfsz + t)
    coarse = _rand(rng, (2, R.coarse_len(t, ncsz, nfsz)), jnp.float32)
    xi = _rand(rng, (2, t, nfsz), jnp.float32)
    r = _rand(rng, (t, nfsz, ncsz), jnp.float32)
    d = _rand(rng, (t, nfsz, nfsz), jnp.float32)
    want = R.refine_charted_ref(coarse, xi, r, d)
    got = refine_charted_pallas(coarse, xi, r, d, n_csz=ncsz, n_fsz=nfsz,
                                block_families=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block", [16, 64, 1024])
def test_block_size_invariance(block):
    """Output must not depend on the VMEM tile size."""
    rng = np.random.default_rng(0)
    ncsz, nfsz, t = 5, 4, 200
    coarse = _rand(rng, (1, R.coarse_len(t, ncsz, nfsz)), jnp.float32)
    xi = _rand(rng, (1, t, nfsz), jnp.float32)
    r = _rand(rng, (nfsz, ncsz), jnp.float32)
    d = _rand(rng, (nfsz, nfsz), jnp.float32)
    base = R.refine_stationary_ref(coarse, xi, r, d)
    got = refine_stationary_pallas(coarse, xi, r, d, n_csz=ncsz, n_fsz=nfsz,
                                   block_families=block, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)


class TestOpsIntegration:
    """ops.refine_* must agree with core.refine.refine_level end-to-end."""

    def test_stationary_shrink_end_to_end(self):
        c = regular_chart(64, 2, n_csz=5, n_fsz=4)
        icr_ref = ICR(chart=c, kernel=matern32.with_defaults(rho=8.0))
        icr_pal = ICR(chart=c, kernel=matern32.with_defaults(rho=8.0),
                      use_pallas=True)
        key = jax.random.PRNGKey(3)
        xi = icr_ref.init_xi(key)
        mats = icr_ref.matrices()
        a = icr_ref.apply_sqrt(mats, xi)
        b = icr_pal.apply_sqrt(mats, xi)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_stationary_reflect_end_to_end(self):
        c = regular_chart(64, 2, boundary="reflect")
        icr_ref = ICR(chart=c, kernel=matern32.with_defaults(rho=8.0))
        icr_pal = ICR(chart=c, kernel=matern32.with_defaults(rho=8.0),
                      use_pallas=True)
        key = jax.random.PRNGKey(4)
        xi = icr_ref.init_xi(key)
        mats = icr_ref.matrices()
        np.testing.assert_allclose(
            np.asarray(icr_ref.apply_sqrt(mats, xi)),
            np.asarray(icr_pal.apply_sqrt(mats, xi)),
            rtol=1e-5, atol=1e-5,
        )

    def test_charted_op_matches_core(self):
        """Charted per-family kernel == core refine on the log chart."""
        c = log_chart(32, 1, n_csz=5, n_fsz=4, delta0=0.05)
        k = matern32.with_defaults(rho=1.0)()
        r, d = refinement_matrices_level(c, k, 0)
        geom = LevelGeom.for_level(c, 0)
        rng = np.random.default_rng(1)
        field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
        t = geom.T[0]
        xi = jnp.asarray(rng.normal(size=(t, geom.n_fsz)), jnp.float32)
        from repro.core.refine import refine_level

        want = refine_level(field, xi, r, d, geom)
        got = ops.refine_charted(field, xi, r, d, geom, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_nd_falls_back_to_core(self):
        c = regular_chart((8, 8), 1)
        k = matern32.with_defaults(rho=4.0)()
        r, d = refinement_matrices_level(c, k, 0)
        geom = LevelGeom.for_level(c, 0)
        rng = np.random.default_rng(2)
        field = jnp.asarray(rng.normal(size=geom.coarse_shape), jnp.float32)
        f = int(np.prod(geom.T))
        xi = jnp.asarray(rng.normal(size=(f, geom.n_fsz**2)), jnp.float32)
        out = ops.refine_stationary(field, xi, r, d, geom)
        assert out.shape == geom.fine_shape
