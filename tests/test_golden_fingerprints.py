"""Committed compile-fingerprint goldens vs a fresh lowering.

This is the regression guard itself, run as part of tier-1: every
scenario cell's fingerprint document is recomputed in this process and
must serialize byte-identically to the JSON committed under
``tests/golden/``. An intentional compile-structure change regenerates
them with ``python tools/update_fingerprints.py`` and reviews the git
diff; an *unintentional* one fails here with a structured diff.
"""
import json
import pathlib

import pytest

from repro.analysis import (
    SCENARIOS,
    canonical_json,
    diff_docs,
    fingerprint_scenario,
    format_diff,
)
from repro.analysis.__main__ import golden_path

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
LABELS = [s.label for s in SCENARIOS()]


def test_golden_set_is_exactly_the_scenario_matrix():
    have = sorted(p.name for p in GOLDEN_DIR.glob("fingerprint-*.json"))
    want = sorted(f"fingerprint-{lb}.json" for lb in LABELS)
    assert have == want, (
        "goldens out of sync with the scenario matrix — run "
        "tools/update_fingerprints.py and commit the result"
    )


@pytest.mark.parametrize("label", LABELS)
def test_fingerprint_matches_golden(label):
    scn = next(s for s in SCENARIOS() if s.label == label)
    gpath = golden_path(GOLDEN_DIR, label)
    golden = json.loads(gpath.read_text())
    doc = fingerprint_scenario(scn)
    diffs = diff_docs(golden, doc)
    assert not diffs, (
        f"compile fingerprint for {label} drifted from {gpath.name} "
        f"({len(diffs)} change(s)):\n{format_diff(diffs)}\n"
        f"If intentional: python tools/update_fingerprints.py and review "
        f"the git diff."
    )
    # the stored text itself is the canonical serialization (update tool
    # and golden round-trip agree byte for byte)
    assert gpath.read_text() == canonical_json(golden)
