"""Fault tolerance: supervisor restore/retry, straggler detection,
elastic re-mesh, gradient compression."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.fault import FaultSupervisor, StragglerMonitor
from repro.distributed.elastic import remesh
from repro.launch.mesh import make_mesh


class TestStragglerMonitor:
    def test_flags_outlier(self):
        mon = StragglerMonitor(min_samples=8)
        for _ in range(20):
            assert not mon.observe(0.10 + np.random.default_rng(0).normal()
                                   * 1e-4)
        assert mon.observe(1.0)  # 10x median
        assert mon.stragglers == 1

    def test_tolerates_noise(self):
        rng = np.random.default_rng(1)
        mon = StragglerMonitor(min_samples=8)
        flags = [mon.observe(0.1 + abs(rng.normal()) * 0.005)
                 for _ in range(100)]
        assert sum(flags) <= 2


class TestFaultSupervisor:
    def test_restores_on_failure(self):
        saved = {"step": 3, "state": 30.0}
        sup = FaultSupervisor(
            restore_fn=lambda: (saved["step"], saved["state"]))
        calls = {"n": 0}

        def flaky(state):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("chip fell over")
            return state + 1

        state, step, failed = sup.run(flaky, 50.0, 5)
        assert failed and step == 3 and state == 30.0
        state, step, failed = sup.run(flaky, state, step)
        assert not failed and step == 4 and state == 31.0
        assert sup.restarts == 1

    def test_gives_up_after_max(self):
        sup = FaultSupervisor(restore_fn=lambda: (0, 0.0), max_restarts=2)

        def always_fails(_):
            raise RuntimeError("dead host")

        for _ in range(2):
            _, _, failed = sup.run(always_fails, 0.0, 0)
            assert failed
        with pytest.raises(RuntimeError):
            sup.run(always_fails, 0.0, 0)


def test_remesh_roundtrip():
    """Params sharded on a 1-dev mesh re-shard onto a renamed mesh and
    degrade gracefully for non-divisible dims."""
    mesh_a = make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(32.0).reshape(8, 4), "b": jnp.ones((3,))}
    specs = {"w": P("data", None), "b": P(None)}
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh_a, s)),
        tree, specs)
    mesh_b = make_mesh((1,), ("data",))
    out = remesh(placed, mesh_b, specs)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    # non-divisible: b (3,) against data axis — replicated, value intact
    out_b = remesh({"b": placed["b"]}, mesh_b, {"b": P("data")})
    np.testing.assert_array_equal(np.asarray(out_b["b"]), np.ones((3,)))
