"""Standardized inference tests (paper §3.2, Eq. 2/3)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    ICR,
    StandardizedModel,
    advi_fit,
    gaussian_log_likelihood,
    lognormal_prior,
    map_fit,
    matern32,
    normal_prior,
    poisson_log_likelihood,
    regular_chart,
    uniform_prior,
)


@pytest.fixture(scope="module")
def problem():
    c = regular_chart(16, 2)  # 52 points
    icr = ICR(chart=c, kernel=matern32.with_defaults(rho=10.0))
    mats = icr.matrices()
    key = jax.random.PRNGKey(7)
    truth = icr.apply_sqrt(mats, icr.init_xi(key)).reshape(-1)
    obs_idx = jnp.arange(0, truth.size, 2)
    noise = 0.05
    y = truth[obs_idx] + noise * jax.random.normal(
        jax.random.fold_in(key, 1), obs_idx.shape)
    return icr, mats, truth, obs_idx, y, noise


def test_map_recovers_field(problem):
    icr, mats, truth, obs_idx, y, noise = problem
    ll = gaussian_log_likelihood(noise, obs_idx)
    fwd = lambda xi: icr.apply_sqrt(mats, xi)
    xi, losses = map_fit(ll, fwd, icr.zero_xi(), y, steps=250)
    assert float(losses[-1]) < float(losses[0]) * 0.1
    rec = np.asarray(fwd(xi).reshape(-1))
    rmse = np.sqrt(np.mean((rec[np.asarray(obs_idx)] - np.asarray(y)) ** 2))
    assert rmse < 3 * noise


def test_map_fit_use_pallas_converges(problem):
    """MAP through the fused Pallas path (custom-VJP adjoint kernels): every
    gradient step runs the fused backward and converges like the reference."""
    icr_ref, mats, truth, obs_idx, y, noise = problem
    icr = ICR(chart=icr_ref.chart, kernel=icr_ref.kernel, use_pallas=True)
    ll = gaussian_log_likelihood(noise, obs_idx)
    fwd = lambda xi: icr.apply_sqrt(mats, xi)
    xi, losses = map_fit(ll, fwd, icr.zero_xi(), y, steps=250)
    assert float(losses[-1]) < float(losses[0]) * 0.1
    rec = np.asarray(fwd(xi).reshape(-1))
    rmse = np.sqrt(np.mean((rec[np.asarray(obs_idx)] - np.asarray(y)) ** 2))
    assert rmse < 3 * noise
    # and it lands on (essentially) the same optimum as the reference path:
    # per-step gradients match to 1e-5 but 250 f32 steps compound, so only
    # the optimum itself is compared, loosely
    fwd_ref = lambda xi: icr_ref.apply_sqrt(mats, xi)
    xi_r, losses_r = map_fit(ll, fwd_ref, icr_ref.zero_xi(), y, steps=250)
    np.testing.assert_allclose(float(losses[-1]), float(losses_r[-1]),
                               rtol=5e-2)


def test_map_fit_jit_flag(problem):
    """jit=False must run (eagerly) and agree with the jitted scan — the old
    code built a jitted scan and then never used it."""
    icr, mats, truth, obs_idx, y, noise = problem
    ll = gaussian_log_likelihood(noise, obs_idx)
    fwd = lambda xi: icr.apply_sqrt(mats, xi)
    _, l_jit = map_fit(ll, fwd, icr.zero_xi(), y, steps=5, jit=True)
    _, l_eager = map_fit(ll, fwd, icr.zero_xi(), y, steps=5, jit=False)
    np.testing.assert_allclose(np.asarray(l_eager), np.asarray(l_jit),
                               rtol=1e-5)


def test_advi_improves_elbo(problem):
    icr, mats, truth, obs_idx, y, noise = problem
    ll = gaussian_log_likelihood(noise, obs_idx)
    fwd = lambda xi: icr.apply_sqrt(mats, xi)
    (mean, logstd), elbos = advi_fit(jax.random.PRNGKey(0), ll, fwd,
                                     icr.zero_xi(), y, steps=200)
    assert float(elbos[-1]) > float(elbos[0])
    # posterior std must have shrunk below the prior's at observed points
    assert float(jnp.mean(jnp.exp(logstd[0]))) < 1.0


def test_joint_theta_field_inference(problem):
    """Learn kernel params θ jointly with the field (paper Eq. 2/3):
    matrices are recomputed inside the differentiated step."""
    icr, mats, truth, obs_idx, y, noise = problem
    priors = StandardizedModel({"rho": lognormal_prior(8.0, 4.0)})
    ll = gaussian_log_likelihood(noise, obs_idx)

    def fwd(latent):
        xi_s, xi_t = latent
        theta = priors(xi_t)
        theta["sigma"] = 1.0
        return icr(xi_s, theta)

    latent0 = (icr.zero_xi(), priors.zero_xi())
    latent, losses = map_fit(ll, fwd, latent0, y, steps=150)
    assert float(losses[-1]) < float(losses[0])
    rho_hat = float(priors(latent[1])["rho"])
    assert 1.0 < rho_hat < 100.0  # stayed in a sane range while learning


def test_poisson_likelihood(problem):
    """Non-Gaussian likelihood works without any kernel inversion."""
    icr, mats, truth, obs_idx, _, _ = problem
    lam = jnp.exp(truth[obs_idx])
    counts = jax.random.poisson(jax.random.PRNGKey(3), lam).astype(jnp.float32)
    ll = poisson_log_likelihood(obs_idx)
    fwd = lambda xi: icr.apply_sqrt(mats, xi)
    xi, losses = map_fit(ll, fwd, icr.zero_xi(), counts, steps=200)
    assert float(losses[-1]) < float(losses[0])


def test_priors_pushforward():
    assert float(lognormal_prior(3.0, 1.0)(jnp.zeros(()))) > 0
    assert np.isclose(float(normal_prior(2.0, 0.5)(jnp.zeros(()))), 2.0)
    u = uniform_prior(1.0, 3.0)
    assert 1.0 < float(u(jnp.zeros(()))) < 3.0
    assert np.isclose(float(u(jnp.asarray(-8.0))), 1.0, atol=1e-3)
