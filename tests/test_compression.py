"""Gradient compression: int8 + error feedback (DESIGN.md §7).

Multi-device correctness runs in a subprocess (host device count must be
set before jax init); single-device semantics and the error-feedback
telescoping property are tested in-process.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.compression import (
        compressed_psum, make_error_feedback_state)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    # per-shard gradients: replicated pytree whose VALUE differs per shard
    # is hard to express; instead shard a leading 'shard' axis and treat
    # rows as per-device grads by slicing inside shard_map — here we just
    # check the mean-psum semantics with identical grads (mean == grad) and
    # the EF carry with non-representable values.
    g = {"w": jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)}
    err = make_error_feedback_state(g)
    mean, err2 = compressed_psum(g, err, mesh, ("data",))
    resid = float(jnp.abs(mean["w"] - g["w"]).max())
    # int8 quantization error bounded by scale = max|g|/127
    bound = float(jnp.abs(g["w"]).max()) / 127.0 + 1e-9
    assert resid <= bound * 1.01, (resid, bound)
    # error feedback carries the residual: two steps of a CONSTANT gradient
    # must average out the quantization error
    mean2, err3 = compressed_psum(g, err2, mesh, ("data",))
    two_step = (np.asarray(mean["w"]) + np.asarray(mean2["w"])) / 2
    resid2 = np.abs(two_step - np.asarray(g["w"])).max()
    assert resid2 <= bound * 0.75, (resid2, bound)
    print("OK")
""")


@pytest.mark.slow
def test_compressed_psum_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


def test_quantize_dequantize_bounds():
    import jax.numpy as jnp
    from repro.distributed.compression import _quantize

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64,)) * 10, jnp.float32)
    q, scale = _quantize(x)
    err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6
    assert q.dtype == jnp.int8


def test_error_feedback_state_shapes():
    import jax.numpy as jnp
    from repro.distributed.compression import make_error_feedback_state

    params = {"a": jnp.zeros((4, 4), jnp.bfloat16), "b": jnp.ones((3,))}
    err = make_error_feedback_state(params)
    assert err["a"].shape == (4, 4) and err["a"].dtype == jnp.float32
    assert err["b"].shape == (3,)
