"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement §f)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.models import build_model
from repro.models.transformer import layer_plan

ARCH_NAMES = sorted(ARCHS)


def _batch_for(cfg, b=2, s=32, key=None):
    key = key or jax.random.PRNGKey(0)
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    # labels must be the NEXT token, never the current one: with tied
    # embeddings predicting the current token is trivial (nll -> 0)
    lab = jnp.roll(tok, -1, axis=1)
    batch = {"tokens": tok, "labels": lab}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.encoder is not None:
        dec = min(s, cfg.encoder.max_target)
        batch = {
            "tokens": tok[:, :dec],
            "labels": lab[:, :dec],
            "enc_embeds": jax.random.normal(
                key, (b, cfg.encoder.n_frames, cfg.d_model), jnp.float32),
        }
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_loss(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    assert float(loss) > 0
    # plausible initial loss for a ~uniform predictor: ~log(vocab)
    assert float(metrics["nll"]) < 3 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_reduces_loss(name):
    from repro.optim import adamw, constant

    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch_for(cfg)
    opt = adamw(constant(3e-3))
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        (l, m), g = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch)
        params, state = opt.update(g, state, params)
        return params, state, l

    losses = []
    for _ in range(8):
        params, state, l = step(params, state)
        losses.append(float(l))
        assert np.isfinite(losses[-1]), f"{name}: loss NaN at step"
    assert losses[-1] < losses[0], f"{name}: loss did not decrease {losses}"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    b, s_max = 2, 64
    cache = model.init_cache(b, s_max)
    tok = jnp.array([[3], [5]], jnp.int32)
    pos = jnp.array([0, 0], jnp.int32)
    logits, cache = jax.jit(model.serve_step)(params, cache, tok, pos)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{name}: decode NaN"
    # a second step at position 1
    logits2, cache = jax.jit(model.serve_step)(params, cache, tok, pos + 1)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch_for(cfg)
    batch.pop("labels")
    logits = jax.jit(model.prefill_fn)(params, batch)
    assert logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_layer_plan_covers_all_layers(name):
    cfg = get_arch(name)
    if cfg.encoder is not None:
        return  # whisper: explicit 6+6 stack, no plan
    head, period, n_groups, tail = layer_plan(cfg)
    assert len(head) + len(period) * n_groups + len(tail) == cfg.n_layers


def test_decode_matches_prefill_causality():
    """Decoding token-by-token must reproduce the teacher-forced logits
    (KV-cache correctness) for a dense arch."""
    cfg = get_arch("starcoder2-15b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.vocab_size)
    # teacher-forced last-token logits
    full = model.prefill_fn(params, {"tokens": toks})
    # token-by-token decode
    cache = model.init_cache(b, s)
    for i in range(s):
        logits, cache = model.serve_step(
            params, cache, toks[:, i : i + 1],
            jnp.full((b,), i, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_spec():
    """Full-config parameter counts are in the advertised ballpark."""
    expect = {
        "starcoder2-15b": (14e9, 17e9),
        "gemma3-27b": (26e9, 30e9),
        # note: the assigned config says GQA kv=8 (the real 35B checkpoint is
        # MHA); with kv=8 the count is ~30B — the config is authoritative.
        "command-r-35b": (28e9, 33e9),
        "gemma3-4b": (3.5e9, 5e9),
        "internvl2-2b": (1.5e9, 2.5e9),
        "xlstm-1.3b": (1.0e9, 1.7e9),
        "deepseek-v2-236b": (220e9, 250e9),
        "llama4-maverick-400b-a17b": (380e9, 420e9),
        "whisper-base": (0.04e9, 0.12e9),
        # zamba2: the assigned config (54 mamba + shared attn block, weights
        # counted once) yields ~4.6B; the 7.4B checkpoint additionally has
        # dual 2*d_model-wide shared blocks + per-use LoRA (DESIGN.md §6)
        "zamba2-7b": (4e9, 8.5e9),
    }
    for name, (lo, hi) in expect.items():
        model = build_model(get_arch(name))
        n = model.param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params not in " \
                              f"[{lo/1e9:.0f}B, {hi/1e9:.0f}B]"
