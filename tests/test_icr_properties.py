"""Property-based tests (hypothesis) on ICR system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core import ICR, matern32, matern52, regular_chart, log_chart
from repro.core.kernels import kernel_matrix


valid_params = st.tuples(
    st.sampled_from([(3, 2), (3, 4), (5, 2), (5, 4)]),  # (n_csz, n_fsz)
    st.integers(min_value=8, max_value=20),              # shape0
    st.integers(min_value=1, max_value=3),               # n_levels
    st.floats(min_value=1.0, max_value=20.0),            # rho
)


@settings(max_examples=15, deadline=None)
@given(valid_params)
def test_psd_by_construction(params):
    """Paper §5.1: K_ICR = sqrt·sqrtᵀ is PSD for ANY refinement setting."""
    (ncsz, nfsz), n0, nlvl, rho = params
    try:
        c = regular_chart(n0, nlvl, n_csz=ncsz, n_fsz=nfsz)
    except ValueError:
        return  # grid shrank below n_csz — invalid config, rejected upstream
    icr = ICR(chart=c, kernel=matern32.with_defaults(rho=rho))
    cov = np.asarray(icr.implicit_cov(dtype=jnp.float32))
    evals = np.linalg.eigvalsh(cov)
    assert evals.min() > -1e-4 * max(evals.max(), 1.0)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=8, max_value=24),
    st.integers(min_value=1, max_value=3),
    st.floats(min_value=0.5, max_value=8.0),
)
def test_apply_sqrt_is_linear(n0, nlvl, alpha):
    """s(ξ) is linear in ξ (paper §4.1: generative map is linear)."""
    try:
        c = regular_chart(n0, nlvl)
    except ValueError:
        return
    icr = ICR(chart=c, kernel=matern32.with_defaults(rho=4.0))
    mats = icr.matrices()
    key = jax.random.PRNGKey(n0 * 7 + nlvl)
    xi1 = icr.init_xi(key)
    xi2 = icr.init_xi(jax.random.fold_in(key, 1))
    lhs = icr.apply_sqrt(mats, [a + alpha * b for a, b in zip(xi1, xi2)])
    rhs = icr.apply_sqrt(mats, xi1) + alpha * icr.apply_sqrt(mats, xi2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.floats(min_value=0.2, max_value=5.0),
       st.floats(min_value=0.5, max_value=2.0))
def test_kernel_properties(rho, sigma):
    """k(0) = sigma², k decays, kernel matrix symmetric PSD."""
    for kern in (matern32, matern52):
        k = kern.with_defaults(rho=rho, sigma=sigma)()
        assert np.isclose(float(k(jnp.zeros(()))), sigma**2, rtol=1e-5)
        d = jnp.linspace(0.0, 10 * rho, 64)
        vals = np.asarray(k(d))
        assert (np.diff(vals) <= 1e-7).all()
        x = jnp.linspace(0, 3 * rho, 16)
        km = np.asarray(kernel_matrix(k, x))
        np.testing.assert_allclose(km, km.T, atol=1e-6)
        assert np.linalg.eigvalsh(km).min() > -1e-4


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_sample_determinism(seed):
    """Same key => identical sample (reproducible pipelines)."""
    c = regular_chart(10, 2)
    icr = ICR(chart=c, kernel=matern32.with_defaults(rho=4.0))
    k = jax.random.PRNGKey(seed)
    s1 = icr.sample(k)
    s2 = icr.sample(k)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


@settings(max_examples=6, deadline=None)
@given(st.floats(min_value=0.01, max_value=0.05),
       st.integers(min_value=6, max_value=12))
def test_log_chart_monotone_positions(delta, n0):
    """Charted positions must stay strictly ordered at every level."""
    try:
        c = log_chart(n0, 3, delta0=delta)
    except ValueError:
        return
    for lvl in range(4):
        pos = np.asarray(c.grid_positions(lvl))[:, 0]
        assert (np.diff(pos) > 0).all()


def test_xi_shapes_cover_output():
    """Total excitation dims >= output dims (sqrt is square or tall)."""
    for p in [(3, 2), (5, 4)]:
        c = regular_chart(16, 3, n_csz=p[0], n_fsz=p[1])
        icr = ICR(chart=c, kernel=matern32)
        assert icr.xi_size() >= int(np.prod(icr.out_shape))
