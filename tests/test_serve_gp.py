"""GP posterior serving subsystem (ISSUE 5; DESIGN.md §12).

Covers the serving cache key semantics (θ / chart shape / dtype policy
must miss, identical traffic must hit), slab-packing parity against a
per-request loop, the streaming Welford moment path, and the warm-path
speedup acceptance bar (identical-shape batch >= 5x faster after the
first, with no retrace and no matrix rebuild).
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ICR, matern32, regular_chart
from repro.core.vi import Posterior, map_posterior
from repro.kernels import dispatch
from repro.launch.serve_gp import (
    GPFieldServer,
    GPRequest,
    demo_posterior,
    mixed_requests,
    scenario_chart,
)

CHART = regular_chart(32, 3, boundary="reflect")  # 256-pt 1-D, fast


def _posterior(theta=None, chart=CHART, dtype_policy=None, seed=0):
    icr = ICR(chart=chart, kernel=matern32, use_pallas=True,
              dtype_policy=dtype_policy)
    theta = {"rho": 8.0} if theta is None else theta
    key = jax.random.PRNGKey(seed)
    mean = icr.init_xi(key, dtype=jnp.float32)
    log_std = [jnp.full_like(m, -1.0) for m in mean]
    return Posterior(icr=icr, mean=mean, log_std=log_std, theta=theta)


# -- slab packing ---------------------------------------------------------------
def test_packed_heterogeneous_batch_matches_per_request_loop():
    """Parity at 1e-5: a packed mixed batch == the same requests served one
    row at a time (slab=1 degenerates to a per-request loop), == a manual
    reference applying sqrt(K) to each row's ξ draw directly."""
    post = _posterior()
    reqs = lambda: [GPRequest(kind="sample", n=3, seed=11),
                    GPRequest(kind="moments", n=5, seed=12),
                    GPRequest(kind="sample", n=2, seed=13)]

    packed = reqs()
    GPFieldServer(post, slab=4).run(packed)
    looped = reqs()
    GPFieldServer(post, slab=1).run(looped)

    # manual reference: the documented (seed, row) eps contract
    mats = post.matrices()
    icr = post.icr

    def row_field(seed, row):
        k = jax.random.fold_in(jax.random.PRNGKey(seed), row)
        ks = jax.random.split(k, len(post.mean))
        xi = [m + s * jax.random.normal(kk, m.shape, m.dtype)
              for kk, m, s in zip(ks, post.mean, post.std())]
        return np.asarray(icr.apply_sqrt(mats, xi))

    for p, l in zip(packed, looped):
        assert p.done and l.done and p.error is None
        if p.kind == "sample":
            assert len(p.fields) == p.n
            for row, (fp, fl) in enumerate(zip(p.fields, l.fields)):
                np.testing.assert_allclose(fp, fl, rtol=1e-5, atol=1e-5)
                np.testing.assert_allclose(fp, row_field(p.seed, row),
                                           rtol=1e-5, atol=1e-5)
        else:
            np.testing.assert_allclose(p.mean, l.mean, rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(p.std, l.std, rtol=1e-5, atol=1e-5)
            draws = np.stack([row_field(p.seed, r) for r in range(p.n)])
            np.testing.assert_allclose(p.mean, draws.mean(0),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(p.std, draws.std(0),
                                       rtol=1e-4, atol=1e-5)


def test_welford_moments_stream_across_slabs():
    """An MC budget far larger than the slab exercises the Chan merge path;
    the result must equal the one-shot mean/std over the same draws."""
    post = _posterior()
    req = GPRequest(kind="moments", n=13, seed=3)  # 13 rows through slab 4
    srv = GPFieldServer(post, slab=4)
    srv.run([req])
    assert srv.slabs_run == 4  # ceil(13/4)
    sample = GPRequest(kind="sample", n=13, seed=3)
    GPFieldServer(post, slab=4).run([sample])
    draws = np.stack(sample.fields)
    np.testing.assert_allclose(req.mean, draws.mean(0), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(req.std, draws.std(0), rtol=1e-5, atol=1e-6)


def test_map_posterior_moments_are_delta():
    """A MAP export is a delta posterior: served mean == sqrt(K) ξ̂ exactly,
    served std == 0."""
    icr = ICR(chart=CHART, kernel=matern32, use_pallas=True)
    xi_hat = icr.init_xi(jax.random.PRNGKey(5), dtype=jnp.float32)
    post = map_posterior(icr, xi_hat, theta={"rho": 8.0})
    req = GPRequest(kind="moments", n=6, seed=1)
    GPFieldServer(post, slab=4).run([req])
    want = np.asarray(icr.apply_sqrt(icr.matrices_cached(post.theta), xi_hat))
    np.testing.assert_allclose(req.mean, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(req.std, 0.0, atol=1e-5)


def test_bad_request_rejected():
    srv = GPFieldServer(_posterior(), slab=2)
    bad = GPRequest(kind="quantiles", n=3)
    zero = GPRequest(kind="sample", n=0)
    wide = GPRequest(kind="sample", n=1, seed=2**31)  # int32 overflow
    ok = GPRequest(kind="sample", n=1)
    srv.run([bad, zero, wide, ok])
    assert bad.done and bad.error and zero.done and zero.error
    assert wide.done and wide.error
    assert ok.done and ok.error is None and len(ok.fields) == 1


# -- cache key semantics --------------------------------------------------------
def test_cache_hits_and_misses():
    """(chart geometry, θ, dtype policy) is the cache key: identical
    traffic hits; changing any component misses and rebuilds."""
    srv = GPFieldServer(_posterior(theta={"rho": 8.0}), slab=4)
    assert (srv.cache_misses, srv.cache_hits) == (1, 0)
    srv.run(mixed_requests(2, 4))
    srv.run(mixed_requests(2, 4))
    assert srv.cache_misses == 1 and srv.cache_hits == 2

    # θ change: miss (fresh matrices; same shapes, so no new jit cache is
    # strictly needed — the server still isolates per-key executables)
    srv.set_posterior(_posterior(theta={"rho": 2.0}))
    assert srv.cache_misses == 2
    # back to the first θ from a *re-fit* (new Posterior object, new ICR
    # instance, equal chart/θ/policy values): hit
    srv.set_posterior(_posterior(theta={"rho": 8.0}, seed=9))
    assert srv.cache_misses == 2 and srv.cache_hits == 3

    # chart shape change: miss
    srv.set_posterior(_posterior(chart=regular_chart(64, 3,
                                                     boundary="reflect")))
    assert srv.cache_misses == 3
    # dtype policy change: miss
    srv.set_posterior(_posterior(dtype_policy="bf16"))
    assert srv.cache_misses == 4


def test_kernel_defaults_are_part_of_the_cache_key():
    """θ baked into kernel defaults (theta=None) must not collide: two
    posteriors differing only in with_defaults(rho=...) are different
    matrices — a hit here served the wrong field."""
    from repro.launch.serve_gp import demo_posterior

    srv = GPFieldServer(demo_posterior(CHART, 8.0), slab=2)
    req_a = GPRequest(kind="sample", n=1, seed=1)
    srv.run([req_a])
    srv.set_posterior(demo_posterior(CHART, 0.5))
    assert srv.cache_misses == 2  # not a hit
    req_b = GPRequest(kind="sample", n=1, seed=1)
    srv.run([req_b])

    fresh = GPFieldServer(demo_posterior(CHART, 0.5), slab=2)
    req_f = GPRequest(kind="sample", n=1, seed=1)
    fresh.run([req_f])
    np.testing.assert_allclose(req_b.fields[0], req_f.fields[0],
                               rtol=1e-6, atol=1e-6)
    assert np.abs(req_b.fields[0] - req_a.fields[0]).max() > 0.1


def test_matrices_cached_on_icr():
    icr = ICR(chart=CHART, kernel=matern32, use_pallas=True)
    m1 = icr.matrices_cached({"rho": 4.0})
    m2 = icr.matrices_cached({"rho": 4.0})
    assert m1 is m2
    assert icr.matrices_cache_stats == {"hits": 1, "misses": 1}
    m3 = icr.matrices_cached({"rho": 5.0})
    assert m3 is not m1
    assert icr.matrices_cache_stats == {"hits": 1, "misses": 2}
    # traced θ bypasses the cache (matrices rebuilt inside the trace)
    jax.jit(lambda r: icr.matrices_cached({"rho": r})["sqrt0"])(4.0)
    assert icr.matrices_cache_stats == {"hits": 1, "misses": 2}


def test_plan_cached():
    dispatch.plan_cache_clear()
    p1 = dispatch.plan_cached(CHART, samples=4)
    p2 = dispatch.plan_cached(CHART, samples=4)
    assert p1 is p2
    assert dispatch.plan_cache_stats == {"hits": 1, "misses": 1}
    p3 = dispatch.plan_cached(CHART, samples=4, dtype="bfloat16")
    assert p3 is not p1
    assert dispatch.plan_cache_stats["misses"] == 2
    assert p1 == dispatch.plan(CHART, samples=4)


# -- warm-path acceptance (ISSUE 5) ---------------------------------------------
def test_warm_identical_batch_at_least_5x_faster():
    """After the first batch, an identical-shape batch must run >= 5x
    faster wall-clock: no retrace (the jitted slab executable's cache stays
    at one entry), no matrix rebuild (ICR matrices cache reports a hit,
    not a miss), and the server's executable cache hits."""
    post = demo_posterior(scenario_chart("dust", quick=True), 0.5)
    srv = GPFieldServer(post, slab=8)
    t0 = time.perf_counter()
    srv.run(mixed_requests(3, 8))
    cold = time.perf_counter() - t0

    mats_misses = post.icr.matrices_cache_stats["misses"]
    hits_before = srv.cache_hits
    t0 = time.perf_counter()
    srv.run(mixed_requests(3, 8))
    warm = time.perf_counter() - t0

    assert cold >= 5.0 * warm, (cold, warm)
    assert srv.cache_hits > hits_before
    assert post.icr.matrices_cache_stats["misses"] == mats_misses
    fn = srv._entry["fn"]
    if hasattr(fn, "_cache_size"):  # retrace detector (jax >= 0.4)
        assert fn._cache_size() == 1
