"""Paper Fig. 3 + §5.1 reproduction: implicit-covariance accuracy.

Reproduces, on the paper's own setup (N≈200 log-spaced points whose
nearest-neighbor distances span 2%–100% of rho0, Matérn-3/2, n_lvl=5):
  * the (n_csz, n_fsz) selection sweep via the KL measure (§5.1),
  * ICR covariance errors (paper: MAE 5.8e-3, max 0.13, diag 6.5e-2),
  * KISS-GP covariance errors (paper: MAE 1.8e-3 = 31% of ICR's,
    max 4.9e-2 on the diagonal).
"""
import math

import numpy as np

import jax
import jax.numpy as jnp


def paper_log_setup(n_csz, n_fsz, n_levels=5, target_n=200, span=50.0):
    from repro.core import log_chart
    n0 = 3
    while True:
        try:
            c = log_chart(n0, n_levels, n_csz=n_csz, n_fsz=n_fsz, delta0=1.0)
            if c.final_shape[0] >= target_n:
                break
        except ValueError:
            pass
        n0 += 1
    n = c.final_shape[0]
    scale = math.log(span) / (n - 2) / c.delta(n_levels)[0]
    c = log_chart(n0, n_levels, n_csz=n_csz, n_fsz=n_fsz, delta0=scale)
    xs = np.asarray(c.grid_positions(n_levels))[:, 0]
    rho = float(np.diff(xs).max())
    return c, xs, rho


def run_nd_cov(report):
    """Covariance cost of the separable N-D fast path (DESIGN.md §4).

    The fused N-D path applies Kronecker-factored per-axis matrices — exact
    interpolation for product kernels (rbf), a surrogate for isotropic ones
    (matern32). This measures the implicit-covariance error of the factored
    model vs the exact kernel, next to the joint ICR reference, on a small
    2-D chart (dense covs via one jacobian, so N stays tiny).
    """
    from repro.core import (
        ICR, cov_errors, exact_cov, matern32, rbf, regular_chart,
    )
    from repro.core.refine import LevelGeom, axis_refinement_matrices_level
    from repro.kernels import ref as kref

    jax.config.update("jax_enable_x64", True)
    c = regular_chart((7, 7), 2, boundary="shrink")
    for kern_name, kern in [("rbf", rbf.with_defaults(rho=2.0)),
                            ("matern32", matern32.with_defaults(rho=2.0))]:
        icr = ICR(chart=c, kernel=kern)
        cov_joint = icr.implicit_cov()
        k = kern()
        geoms = [LevelGeom.for_level(c, l) for l in range(c.n_levels)]
        factors = [axis_refinement_matrices_level(c, k, l)
                   for l in range(c.n_levels)]
        sqrt0 = icr.matrices()["sqrt0"]
        shapes = icr.xi_shapes()
        sizes = [int(np.prod(s)) for s in shapes]

        def flat_apply(xi_flat):
            xs, o = [], 0
            for s, n in zip(shapes, sizes):
                xs.append(xi_flat[o : o + n].reshape(s))
                o += n
            field = (sqrt0 @ xs[0]).reshape(c.shape0)
            for lvl, geom in enumerate(geoms):
                rs, ds = factors[lvl]
                field = kref.refine_axes_ref(
                    field, xs[lvl + 1], rs, ds, T=geom.T, n_fsz=geom.n_fsz,
                    boundary=geom.boundary, b=geom.b)
            return field.reshape(-1)

        a = jax.jacfwd(flat_apply)(jnp.zeros(sum(sizes), jnp.float64))
        cov_sep = a @ a.T
        cov_true = exact_cov(c, k)
        e_sep = {k2: float(v) for k2, v in
                 cov_errors(cov_sep, cov_true).items()}
        e_joint = {k2: float(v) for k2, v in
                   cov_errors(cov_joint, cov_true).items()}
        report(f"accuracy/nd_sep_{kern_name}", e_sep["mae"],
               f"N={cov_true.shape[0]} sep mae={e_sep['mae']:.2e} "
               f"joint mae={e_joint['mae']:.2e} "
               f"ratio={e_sep['mae']/max(e_joint['mae'], 1e-300):.1f}x")
    jax.config.update("jax_enable_x64", False)


def run(report):
    from repro.core import (
        ICR, KissGP, cov_errors, exact_cov, gauss_kl, matern32,
    )

    jax.config.update("jax_enable_x64", True)
    rows = []
    best = None
    for (ncsz, nfsz) in [(3, 2), (3, 4), (5, 2), (5, 4), (5, 6)]:
        c, xs, rho = paper_log_setup(ncsz, nfsz)
        kern = matern32.with_defaults(rho=rho)
        icr = ICR(chart=c, kernel=kern)
        cov_icr = icr.implicit_cov()
        cov_true = exact_cov(c, kern())
        errs = {k: float(v) for k, v in cov_errors(cov_icr, cov_true).items()}
        kl = float(gauss_kl(cov_true, cov_icr, jitter=1e-8))
        rows.append(((ncsz, nfsz), len(xs), errs, kl))
        report(f"accuracy/icr_{ncsz}_{nfsz}", kl,
               f"N={len(xs)} mae={errs['mae']:.2e} "
               f"max={errs['max_abs_err']:.2e} "
               f"diag={errs['max_diag_err']:.2e} KL={kl:.1f}")
        if best is None or kl < best[1]:
            best = ((ncsz, nfsz), kl)
    report("accuracy/kl_optimal_params", 0.0,
           f"KL-optimal (n_csz,n_fsz)={best[0]} (paper: (5,4))")

    # paper-quoted numbers for the (5,4) setting
    (p54, n54, errs54, _) = next(r for r in rows if r[0] == (5, 4))
    report("accuracy/icr_mae_paper", errs54["mae"],
           f"ICR MAE={errs54['mae']:.2e} (paper: 5.8e-3)")

    c, xs, rho = paper_log_setup(5, 4)
    kern = matern32.with_defaults(rho=rho)
    kiss = KissGP(x=xs, kernel_fn=kern())
    errs_k = {k: float(v) for k, v in
              cov_errors(kiss.dense_cov(), exact_cov(c, kern())).items()}
    report("accuracy/kissgp_mae", errs_k["mae"],
           f"KISS-GP MAE={errs_k['mae']:.2e} (paper: 1.8e-3) "
           f"max={errs_k['max_abs_err']:.2e} on-diag="
           f"{np.isclose(errs_k['max_abs_err'], errs_k['max_diag_err'], rtol=0.3)}")
    report("accuracy/kissgp_vs_icr_ratio",
           errs_k["mae"] / errs54["mae"],
           f"KISS-GP/ICR MAE ratio={errs_k['mae']/errs54['mae']:.2f} "
           "(paper: 0.31)")
    jax.config.update("jax_enable_x64", False)
